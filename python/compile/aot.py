"""AOT pipeline: lower every Layer-2 entry point to HLO text artifacts.

Run once at build time (`make artifacts`); the Rust coordinator loads the
resulting ``artifacts/*.hlo.txt`` through the PJRT CPU client and Python is
never on the request path again.

Interchange is HLO **text**, not a serialized ``HloModuleProto``: jax >= 0.5
emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every entry is lowered with ``return_tuple=True`` so the Rust side always
unwraps a tuple.  A ``manifest.json`` records the signature of every
artifact so the Rust runtime can validate shapes/dtypes before execution.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

I32 = jnp.int32
F32 = jnp.float32


def _spec(shape, dtype=I32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _gemm_entry(m, k, n):
    def fn(x, w, p, s):
        q, acc = model.gemm_requant(x, w, p, s)
        return (q, acc)

    args = [
        _spec((m, k)),
        _spec((k, n)),
        _spec((m, n)),
        _spec((1,), F32),
    ]
    return fn, args


def _gemm_acc_entry(m, k, n):
    """Accumulate-only tile (interior K-rounds skip the requant SIMD)."""

    def fn(x, w, p):
        from .kernels.gemm import gemm_os_int8

        return (gemm_os_int8(x, w, p, tm=model.DEF_TM, tn=model.DEF_TN),)

    args = [_spec((m, k)), _spec((k, n)), _spec((m, n))]
    return fn, args


def _conv_entry(n, h, w, c, kh, kw, f, stride):
    def fn(x, wt, s):
        return (model.conv2d_im2col(x, wt, s, stride=stride, padding="SAME"),)

    args = [_spec((n, h, w, c)), _spec((kh, kw, c, f)), _spec((1,), F32)]
    return fn, args


def _mha_entry(t, d, dh):
    def fn(x, wq, wk, wv, s_qkv, s_attn):
        return (model.mha_head(x, wq, wk, wv, s_qkv, s_attn),)

    args = [
        _spec((t, d)),
        _spec((d, dh)),
        _spec((d, dh)),
        _spec((d, dh)),
        _spec((1,), F32),
        _spec((1,), F32),
    ]
    return fn, args


def _lstm_entry(b, hidden):
    def fn(x, h, c, wx, wh, bias, s):
        hq, cn = model.lstm_cell(x, h, c, wx, wh, bias, s)
        return (hq, cn)

    args = [
        _spec((b, hidden)),
        _spec((b, hidden)),
        _spec((b, hidden), F32),
        _spec((hidden, 4 * hidden)),
        _spec((hidden, 4 * hidden)),
        _spec((4 * hidden,), F32),
        _spec((1,), F32),
    ]
    return fn, args


def _residual_entry(m, n):
    def fn(a, b, s):
        from .kernels.quant import add_requant_int8

        return (add_requant_int8(a, b, s, relu=True),)

    return fn, [_spec((m, n)), _spec((m, n)), _spec((1,), F32)]


def _maxpool_entry(n, h, w, c, window, stride):
    def fn(x):
        return (model.maxpool2d(x, window=window, stride=stride),)

    return fn, [_spec((n, h, w, c))]


# name -> (builder fn, arg specs).  Shapes are the tile sizes the Rust
# coordinator dispatches (see rust/src/runtime/artifacts.rs).
ENTRIES = {
    # One chip-native tile: the 8x8x8 array's natural unit.
    "gemm8": _gemm_entry(8, 8, 8),
    # The standard 64x64x64 working tile used by the tiled layer executor.
    "gemm64": _gemm_entry(64, 64, 64),
    # A 2x larger working tile: fewer PJRT dispatches per layer (§Perf).
    "gemm128": _gemm_entry(128, 128, 128),
    # Accumulate-only 64-tile: interior K-rounds of the tiled executor
    # skip the requant epilogue (§Perf iteration 5).
    "gemm64_acc": _gemm_acc_entry(64, 64, 64),
    # The paper's peak-efficiency workload (Fig. 7b): M = N = K = 96.
    "gemm96": _gemm_entry(96, 96, 96),
    # A ragged tile (non-multiple of 8 in M) exercising the padding path.
    "gemm_ragged": _gemm_entry(40, 64, 64),
    # Conv2D 3x3 stride-1 SAME on a small feature map, implicit im2col.
    "conv3x3": _conv_entry(1, 8, 8, 16, 3, 3, 16, 1),
    # Strided conv (stride 2) — the downsampling layers of ResNet/MobileNet.
    "conv3x3s2": _conv_entry(1, 16, 16, 8, 3, 3, 16, 2),
    # One BERT-Base MHA head at token size 64 (Fig. 4's example).
    "mha64": _mha_entry(64, 768, 64),
    # LSTM cell, batch 8, hidden 64.
    "lstm64": _lstm_entry(8, 64),
    # Maxpool 2x2/2, the auxiliary unit.
    "maxpool2x2": _maxpool_entry(1, 8, 8, 16, 2, 2),
    # Fused residual add + ReLU + requant on the SIMD unit (64x64 tile).
    "residual64": _residual_entry(64, 64),
}


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(dt) -> str:
    return {jnp.int32.dtype: "i32", jnp.float32.dtype: "f32"}[jnp.dtype(dt)]


def lower_all(out_dir: str, only: list[str] | None = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text/v1", "artifacts": {}}
    names = only or list(ENTRIES)
    for name in names:
        fn, args = ENTRIES[name]
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *args)
        manifest["artifacts"][name] = {
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {"shape": list(a.shape), "dtype": _dtype_tag(a.dtype)} for a in args
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": _dtype_tag(o.dtype)} for o in outs
            ],
        }
        print(f"  lowered {name:12s} -> {fname} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", nargs="*", help="subset of entries to lower")
    ns = ap.parse_args()
    lower_all(ns.out, ns.only)
    print(f"wrote manifest to {ns.out}/manifest.json")


if __name__ == "__main__":
    main()
