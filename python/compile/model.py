"""Layer-2 JAX graphs: the workload building blocks Voltra executes.

Every function here composes the Layer-1 Pallas kernels (`kernels.gemm`,
`kernels.quant`) into the compute graphs the paper maps onto the chip:

  * `gemm_requant`     — one tiled GEMM + quantization epilogue (the
                         fundamental unit every layer lowers to);
  * `conv2d_im2col`    — Conv2D lowered by implicit im2col to the GEMM
                         core, exactly as the 6-D input streamer does;
  * `mha_head`         — the BERT multi-head-attention sequence of Fig. 4;
  * `lstm_cell`        — the recurrent cell used by the LSTM workload;
  * `maxpool2d`        — the auxiliary maxpool unit.

These are *build-time only*: `aot.py` lowers them once to HLO text and the
Rust coordinator executes the artifacts through PJRT.  All artifact I/O is
int32/float32 because the `xla` crate's literal API has no i8 — values on
int8 paths stay within [-128, 127] and the kernels cast to int8 internally,
so the numerics are bit-identical to an int8 datapath.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.gemm import ARRAY_K, ARRAY_M, ARRAY_N, gemm_os_int8, pad_to_multiple
from .kernels.quant import maxpool2d_int8, requant_int8

# Default Pallas block: 4x4 chip tiles per grid step keeps the interpret
# grid small while remaining 8-aligned (see DESIGN.md §Perf / L1).
DEF_TM = 32
DEF_TN = 32


def _pick_tile(dim: int, pref: int) -> int:
    """Largest multiple-of-8 block <= pref that divides `dim`."""
    t = min(pref, dim)
    t -= t % 8
    while t > 8 and dim % t:
        t -= 8
    return max(t, 8)


def gemm_requant(x, w, psum, scale):
    """acc = psum + x@w ; q = requant(acc).  Returns (q, acc).

    The chip streams psum in, holds acc output-stationary, and drains
    through the 8-lane SIMD quantizer; `q` is what is written back to the
    shared memory, `acc` is what the psum streamer would forward to a
    following K-tile.
    """
    m, k = x.shape
    _, n = w.shape
    tm = _pick_tile(m, DEF_TM)
    tn = _pick_tile(n, DEF_TN)
    acc = gemm_os_int8(x, w, psum, tm=tm, tn=tn)
    q = requant_int8(acc, scale)
    return q, acc


def gemm_requant_ragged(x, w, psum, scale):
    """gemm_requant for shapes that are not 8-aligned (pads, then crops)."""
    m, k = x.shape
    _, n = w.shape
    xp = pad_to_multiple(x, ARRAY_M, ARRAY_K)
    wp = pad_to_multiple(w, ARRAY_K, ARRAY_N)
    pp = pad_to_multiple(psum, ARRAY_M, ARRAY_N)
    q, acc = gemm_requant(xp, wp, pp, scale)
    return q[:m, :n], acc[:m, :n]


def im2col(x, kh: int, kw: int, stride: int = 1, padding: str = "SAME"):
    """Implicit-im2col as data movement (NHWC -> patch matrix).

    On the chip this is performed by the input streamer's 6-D affine AGU
    (Sec. II-B): no patch matrix is materialized, addresses are simply
    generated in this order.  In the AOT graph the gather is explicit but
    fuses into the GEMM's operand load.
    """
    n, h, w, c = x.shape
    if padding == "SAME":
        ho = -(-h // stride)
        wo = -(-w // stride)
        ph = max((ho - 1) * stride + kh - h, 0)
        pw = max((wo - 1) * stride + kw - w, 0)
        x = jnp.pad(
            x, ((0, 0), (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2), (0, 0))
        )
    elif padding == "VALID":
        ho = (h - kh) // stride + 1
        wo = (w - kw) // stride + 1
    else:
        raise ValueError(f"padding {padding!r}")
    cols = []
    for di in range(kh):
        for dj in range(kw):
            sl = x[:, di : di + stride * ho : stride, dj : dj + stride * wo : stride, :]
            cols.append(sl.reshape(n * ho * wo, c))
    return jnp.concatenate(cols, axis=1), (n, ho, wo)


def conv2d_im2col(x, w, scale, stride: int = 1, padding: str = "SAME"):
    """Conv2D on the GEMM core: implicit im2col + 8x8x8 OS GEMM + requant.

    x: (N, H, W, C) int8-range, w: (KH, KW, C, F) int8-range,
    scale: (1,) f32.  Returns (N, Ho, Wo, F) int8-range int32.
    """
    kh, kw, c, f = w.shape
    patches, (n, ho, wo) = im2col(x, kh, kw, stride, padding)
    wmat = w.reshape(kh * kw * c, f)
    m = n * ho * wo
    psum = jnp.zeros((m, f), jnp.int32)
    q, _ = gemm_requant_ragged(patches, wmat, psum, scale)
    return q.reshape(n, ho, wo, f)


def mha_head(x, wq, wk, wv, s_qkv, s_attn):
    """One MHA head (Fig. 4): the exact GEMM sequence the chip schedules.

    x: (T, D) int8-range; wq/wk/wv: (D, dh) int8-range; scales f32(1,).
    Q/K/V projections requantize to int8; S = Q K^T runs on the GEMM core
    with the weight streamer's on-the-fly transposer providing K^T
    (Sec. II-C); softmax runs at f32 (host/SIMD precision); A requantizes
    to int8 for the final A@V GEMM.  Returns (T, dh) int32 accumulators.
    """
    t, d = x.shape
    dh = wq.shape[1]
    zero_td = jnp.zeros((t, dh), jnp.int32)
    q, _ = gemm_requant_ragged(x, wq, zero_td, s_qkv)
    k, _ = gemm_requant_ragged(x, wk, zero_td, s_qkv)
    v, _ = gemm_requant_ragged(x, wv, zero_td, s_qkv)
    # K^T via the weight streamer's built-in transposer: free at runtime.
    s = gemm_os_int8(
        q.astype(jnp.int8),
        k.T.astype(jnp.int8),
        jnp.zeros((t, t), jnp.int32),
        tm=_pick_tile(t, DEF_TM),
        tn=_pick_tile(t, DEF_TN),
    )
    a = jax.nn.softmax(s.astype(jnp.float32) / jnp.sqrt(jnp.float32(dh)), axis=-1)
    a8 = jnp.clip(jnp.round(a * s_attn.reshape(())), -128, 127).astype(jnp.int32)
    o = gemm_os_int8(
        a8.astype(jnp.int8),
        v.astype(jnp.int8),
        zero_td,
        tm=_pick_tile(t, DEF_TM),
        tn=_pick_tile(dh, DEF_TN),
    )
    return o


def lstm_cell(x, h, c, wx, wh, b, s_gate):
    """One LSTM step: two INT8 GEMMs into shared accumulators + f32 gates.

    x, h: (B, hidden) int8-range; wx, wh: (hidden, 4*hidden); b: (4*hidden,)
    f32; s_gate: (1,) f32 dequant scale.  Returns (h_q int32, c_new f32).
    """
    b_sz, hidden = h.shape
    acc = gemm_os_int8(
        x.astype(jnp.int8),
        wx.astype(jnp.int8),
        jnp.zeros((b_sz, 4 * hidden), jnp.int32),
        tm=_pick_tile(b_sz, DEF_TM),
        tn=_pick_tile(4 * hidden, DEF_TN),
    )
    # Output-stationary chaining: the h-projection accumulates straight on
    # top of the x-projection's partial sums (the chip's psum streamer).
    acc = gemm_os_int8(
        h.astype(jnp.int8),
        wh.astype(jnp.int8),
        acc,
        tm=_pick_tile(b_sz, DEF_TM),
        tn=_pick_tile(4 * hidden, DEF_TN),
    )
    gates = acc.astype(jnp.float32) * s_gate.reshape(()) + b.astype(jnp.float32)
    i, f, g, o = jnp.split(gates, 4, axis=1)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f)
    g = jnp.tanh(g)
    o = jax.nn.sigmoid(o)
    c_new = f * c.astype(jnp.float32) + i * g
    h_new = o * jnp.tanh(c_new)
    h_q = jnp.clip(jnp.round(h_new * 127.0), -128, 127).astype(jnp.int32)
    return h_q, c_new


def maxpool2d(x, window: int = 2, stride: int = 2):
    """(N, H, W, C) -> pooled, through the 8-lane maxpool unit kernel."""
    n, h, w, c = x.shape
    xc = jnp.transpose(x, (0, 3, 1, 2)).reshape(n * c, h, w)
    pooled = maxpool2d_int8(xc, window=window, stride=stride)
    _, ho, wo = pooled.shape
    return jnp.transpose(pooled.reshape(n, c, ho, wo), (0, 2, 3, 1))
