"""Pure-jnp oracles for every Layer-1 kernel and Layer-2 graph.

These are the correctness ground truth: no Pallas, no cleverness — the
mathematically obvious implementation.  pytest asserts the Pallas kernels
and the model layer against these (exact equality for integer paths,
allclose for float paths).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

QMIN = -128
QMAX = 127


def gemm_ref(x, w, psum):
    """psum + x @ w with int32 accumulation (exact)."""
    return (
        x.astype(jnp.int32) @ w.astype(jnp.int32) + psum.astype(jnp.int32)
    ).astype(jnp.int32)


def requant_ref(acc, scale, relu=False):
    """Scale, round, (relu), saturate to [-128, 127]; int32 out."""
    v = acc.astype(jnp.float32) * jnp.asarray(scale, jnp.float32).reshape(())
    q = jnp.round(v)
    if relu:
        q = jnp.maximum(q, 0.0)
    return jnp.clip(q, QMIN, QMAX).astype(jnp.int32)


def add_requant_ref(a, b, scale, relu=False):
    """q8(scale * (a + b)) with optional ReLU; int32 out."""
    v = (a.astype(jnp.float32) + b.astype(jnp.float32)) * jnp.asarray(
        scale, jnp.float32
    ).reshape(())
    q = jnp.round(v)
    if relu:
        q = jnp.maximum(q, 0.0)
    return jnp.clip(q, QMIN, QMAX).astype(jnp.int32)


def maxpool2d_ref(x, window=2, stride=2):
    """(C, H, W) max pooling, int32."""
    x = x.astype(jnp.int32)
    c, h, w = x.shape
    ho = (h - window) // stride + 1
    wo = (w - window) // stride + 1
    out = jnp.full((c, ho, wo), jnp.iinfo(jnp.int32).min, jnp.int32)
    for di in range(window):
        for dj in range(window):
            sl = x[:, di : di + stride * ho : stride, dj : dj + stride * wo : stride]
            out = jnp.maximum(out, sl)
    return out


def conv2d_ref(x, w, stride=1, padding="SAME"):
    """NHWC x HWIO -> NHWC conv with int32 accumulation via lax.conv."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    )
    return out.astype(jnp.int32)


def mha_head_ref(x, wq, wk, wv, s_qkv, s_attn):
    """One MHA head as the chip computes it (Fig. 4): INT8 GEMM chain.

    Q = q8(x @ wq), K = q8(x @ wk), V = q8(x @ wv)         (requant s_qkv)
    S = Q @ K^T ;  A = softmax(S / sqrt(d)) in f32
    A8 = round(A * s_attn)  -> O = A8 @ V  (int32 accumulators out)
    """
    d = wq.shape[1]
    q = requant_ref(gemm_ref(x, wq, jnp.zeros((x.shape[0], d), jnp.int32)), s_qkv)
    k = requant_ref(gemm_ref(x, wk, jnp.zeros((x.shape[0], d), jnp.int32)), s_qkv)
    v = requant_ref(gemm_ref(x, wv, jnp.zeros((x.shape[0], d), jnp.int32)), s_qkv)
    s = gemm_ref(q, k.T, jnp.zeros((q.shape[0], k.shape[0]), jnp.int32))
    a = jax.nn.softmax(s.astype(jnp.float32) / jnp.sqrt(jnp.float32(d)), axis=-1)
    a8 = jnp.clip(jnp.round(a * s_attn), QMIN, QMAX).astype(jnp.int32)
    o = gemm_ref(a8, v, jnp.zeros((a8.shape[0], v.shape[1]), jnp.int32))
    return o


def lstm_cell_ref(x, h, c, wx, wh, b, s_gate):
    """One LSTM cell step with INT8 GEMMs for the two projections.

    Gates = x@wx + h@wh + b (int32 acc -> f32 via s_gate), then standard
    sigmoid/tanh recurrence in f32; new h is requantized to int8 range.
    """
    hidden = h.shape[1]
    acc = gemm_ref(x, wx, jnp.zeros((x.shape[0], 4 * hidden), jnp.int32))
    acc = gemm_ref(h, wh, acc)
    gates = acc.astype(jnp.float32) * jnp.asarray(s_gate, jnp.float32) + b.astype(
        jnp.float32
    )
    i, f, g, o = jnp.split(gates, 4, axis=1)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f)
    g = jnp.tanh(g)
    o = jax.nn.sigmoid(o)
    c_new = f * c.astype(jnp.float32) + i * g
    h_new = o * jnp.tanh(c_new)
    h_q = jnp.clip(jnp.round(h_new * 127.0), QMIN, QMAX).astype(jnp.int32)
    return h_q, c_new


def im2col_ref(x, kh, kw, stride=1, padding="SAME"):
    """NHWC -> (N*Ho*Wo, kh*kw*C) patch matrix (explicit im2col).

    The chip's 6-D input-streamer AGU performs this *implicitly* by strided
    addressing (Sec. II-B, [21]); the explicit matrix is the functional
    equivalent.
    """
    n, h, w, c = x.shape
    if padding == "SAME":
        ho = -(-h // stride)
        wo = -(-w // stride)
        ph = max((ho - 1) * stride + kh - h, 0)
        pw = max((wo - 1) * stride + kw - w, 0)
        x = jnp.pad(
            x, ((0, 0), (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2), (0, 0))
        )
    else:
        ho = (h - kh) // stride + 1
        wo = (w - kw) // stride + 1
    cols = []
    for di in range(kh):
        for dj in range(kw):
            sl = x[:, di : di + stride * ho : stride, dj : dj + stride * wo : stride, :]
            cols.append(sl.reshape(n * ho * wo, c))
    # Patch layout must match the HWIO weight reshape (kh, kw, C) -> rows.
    return jnp.concatenate(cols, axis=1), (n, ho, wo)
