"""Layer-1 Pallas kernel: Voltra's time-multiplexed quantization SIMD unit.

The chip (paper Sec. II-D) converts the GEMM core's INT32 outputs to INT8
with a SIMD unit of only eight PE lanes: one 8x8 output tile (64 results)
is drained through the eight lanes over eight cycles by a hardware loop
unroller.  Because the GEMM core is output stationary, results leave the
array at a low rate and the 8-lane unit costs only 0.7% performance while
saving 4.92x SIMD area versus a 64-lane design.

The Pallas kernel mirrors that structure: a `fori_loop` over rows (the
hardware loop unroller), each iteration quantizing LANES=8 results (the
eight PE lanes).  Per lane: scale multiply, round-to-nearest, optional
ReLU, saturate to [-128, 127].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 8  # quantization PE lanes on the chip
QMIN = -128
QMAX = 127


def _requant_kernel(acc_ref, scale_ref, o_ref, *, relu: bool):
    """Quantize a (TM, TN) int32 block to int8-range int32, 1 row / step."""
    s = scale_ref[0]
    rows = acc_ref.shape[0]

    def row(i, _):
        # Eight lanes consume one row (TN is a multiple of LANES; the
        # hardware loop unroller steps TN/8 times per row, which is
        # subsumed in the vectorized row op here).
        v = acc_ref[pl.dslice(i, 1), :].astype(jnp.float32) * s
        q = jnp.round(v)
        if relu:
            q = jnp.maximum(q, 0.0)
        q = jnp.clip(q, QMIN, QMAX).astype(jnp.int32)
        o_ref[pl.dslice(i, 1), :] = q
        return 0

    jax.lax.fori_loop(0, rows, row, 0)


@functools.partial(jax.jit, static_argnames=("relu",))
def requant_int8(acc, scale, *, relu: bool = False):
    """Requantize INT32 accumulators to INT8-range values.

    Args:
      acc:   (M, N) int32 GEMM outputs, N a multiple of 8.
      scale: (1,) float32 requantization scale (programmed over CSR on the
             chip; a runtime operand here).
      relu:  fuse the activation, as the chip's SIMD unit does.

    Returns:
      (M, N) int32 tensor whose values lie in [-128, 127].
    """
    acc = acc.astype(jnp.int32)
    scale = scale.astype(jnp.float32).reshape((1,))
    m, n = acc.shape
    if n % LANES:
        raise ValueError(f"N={n} must be a multiple of {LANES} lanes")
    kernel = functools.partial(_requant_kernel, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m, n), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(acc, scale)


def _add_requant_kernel(a_ref, b_ref, scale_ref, o_ref, *, relu: bool):
    """Residual fusion on the SIMD unit: q8(scale * (a + b)), 8 lanes.

    The chip's quantization PEs take the GEMM core's 32-bit outputs and a
    second 32-bit stream (the residual branch read back through the SIMD
    input streamer), add, rescale and saturate — one row of 8 lanes per
    loop-unroller step, like `_requant_kernel`.
    """
    s = scale_ref[0]
    rows = a_ref.shape[0]

    def row(i, _):
        va = a_ref[pl.dslice(i, 1), :].astype(jnp.float32)
        vb = b_ref[pl.dslice(i, 1), :].astype(jnp.float32)
        q = jnp.round((va + vb) * s)
        if relu:
            q = jnp.maximum(q, 0.0)
        o_ref[pl.dslice(i, 1), :] = jnp.clip(q, QMIN, QMAX).astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, rows, row, 0)


@functools.partial(jax.jit, static_argnames=("relu",))
def add_requant_int8(a, b, scale, *, relu: bool = False):
    """Fused residual-add + requantization (Sec. II-D SIMD activation).

    a, b: (M, N) int32 (accumulators / int8-range residual); scale (1,)
    f32. Returns int8-range int32.
    """
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    scale = scale.astype(jnp.float32).reshape((1,))
    m, n = a.shape
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch {a.shape} vs {b.shape}")
    if n % LANES:
        raise ValueError(f"N={n} must be a multiple of {LANES} lanes")
    kernel = functools.partial(_add_requant_kernel, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m, n), lambda i: (0, 0)),
            pl.BlockSpec((m, n), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a, b, scale)


def _maxpool_kernel(x_ref, o_ref, *, window: int, stride: int):
    """Voltra's maxpool unit: 8 comparison lanes, arbitrary windows (II-E).

    x_ref: (H, W) int32 single-channel plane; o_ref: (Ho, Wo) int32.
    The chip scans windows sequentially through its comparison lanes; here
    one `fori_loop` step reduces one window position (a row of them).
    """
    ho, wo = o_ref.shape

    def out_row(i, _):
        def out_col(j, row_acc):
            win = x_ref[
                pl.dslice(i * stride, window), pl.dslice(j * stride, window)
            ]
            m = jnp.max(win)
            return jax.lax.dynamic_update_index_in_dim(row_acc, m, j, 0)

        row = jax.lax.fori_loop(
            0, wo, out_col, jnp.full((wo,), jnp.iinfo(jnp.int32).min, jnp.int32)
        )
        o_ref[pl.dslice(i, 1), :] = row.reshape(1, wo)
        return 0

    jax.lax.fori_loop(0, ho, out_row, 0)


@functools.partial(jax.jit, static_argnames=("window", "stride"))
def maxpool2d_int8(x, *, window: int = 2, stride: int = 2):
    """Max pooling over the trailing two dims of an (C, H, W) int tensor."""
    x = x.astype(jnp.int32)
    c, h, w = x.shape
    ho = (h - window) // stride + 1
    wo = (w - window) // stride + 1
    kernel = functools.partial(_maxpool_kernel, window=window, stride=stride)
    pool = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((h, w), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((ho, wo), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((ho, wo), jnp.int32),
        interpret=True,
    )
    return jax.vmap(pool)(x)
