"""Layer-1 Pallas kernel: Voltra's 8x8x8 output-stationary INT8 GEMM core.

The Voltra GEMM core (paper Sec. II-A) is a 3D spatial array of 512 MACs:
an 8x8 grid of dot-product units (Dot-ProdU), each combinationally reducing
an 8-element INT8 x INT8 product into a single INT32 partial sum.  The
dataflow is *output stationary*: an 8x8 tile of INT32 accumulators stays
resident in the array while 8-wide slices of the input/weight operands
stream through along K.

Mapping onto Pallas (see DESIGN.md "Hardware adaptation"):

  * the 8x8 spatial output tile  -> the Pallas grid over (M/TM, N/TN)
    output blocks (TM, TN are multiples of 8 so blocks compose exactly
    from chip-sized 8x8 tiles);
  * the 8-deep combinational reduction inside a Dot-ProdU -> the KU=8
    slice consumed per `fori_loop` step;
  * output stationarity -> the accumulator is carried through the K loop
    and written back exactly once, seeded from the partial-sum operand
    (the chip's psum streamer re-injects prior partial results the same
    way).

The kernel MUST be lowered with ``interpret=True``: real-TPU Pallas emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.  Numerics are exact
integer arithmetic, so interpret mode is bit-identical to the chip.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Chip constants (paper Sec. II-A): 8x8 Dot-ProdUs x 8-wide dot product.
ARRAY_M = 8  # spatial unrolling of output rows
ARRAY_N = 8  # spatial unrolling of output cols
ARRAY_K = 8  # dot-product width inside one Dot-ProdU (KU)
MACS = ARRAY_M * ARRAY_N * ARRAY_K  # 512


def _gemm_os_kernel(x_ref, w_ref, p_ref, o_ref):
    """One output-stationary (TM, TN) block: acc = p + sum_k x[:,k8] @ w[k8,:].

    x_ref: (TM, K) int8, w_ref: (K, TN) int8, p_ref/o_ref: (TM, TN) int32.
    """
    k_total = x_ref.shape[1]

    acc0 = p_ref[...]

    def body(kb, acc):
        # One temporal step of the chip: every Dot-ProdU consumes an
        # 8-element input slice and an 8-element weight slice.
        x8 = x_ref[:, pl.dslice(kb * ARRAY_K, ARRAY_K)].astype(jnp.int32)
        w8 = w_ref[pl.dslice(kb * ARRAY_K, ARRAY_K), :].astype(jnp.int32)
        prod = jax.lax.dot_general(
            x8, w8, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )
        return acc + prod

    o_ref[...] = jax.lax.fori_loop(0, k_total // ARRAY_K, body, acc0)


def _check_dims(m: int, k: int, n: int, tm: int, tn: int) -> None:
    if m % tm or n % tn:
        raise ValueError(f"M={m} / N={n} must tile by (TM={tm}, TN={tn})")
    if tm % ARRAY_M or tn % ARRAY_N or k % ARRAY_K:
        raise ValueError(
            f"tile ({tm},{tn}) and K={k} must be multiples of the "
            f"{ARRAY_M}x{ARRAY_N}x{ARRAY_K} array"
        )


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def gemm_os_int8(x, w, psum, *, tm: int = ARRAY_M, tn: int = ARRAY_N):
    """Output-stationary INT8 GEMM: ``psum + x @ w`` with INT32 accumulation.

    Args:
      x:    (M, K) int8 (or int32 holding int8-range values) inputs.
      w:    (K, N) int8 weights.
      psum: (M, N) int32 partial sums (the chip's psum stream).
      tm, tn: Pallas block size; multiples of 8.  The chip computes the
        block as (tm/8)x(tn/8) successive 8x8 output-stationary tiles.

    Returns:
      (M, N) int32 accumulator, exactly ``psum + x.int32 @ w.int32``.
    """
    x = x.astype(jnp.int8)
    w = w.astype(jnp.int8)
    psum = psum.astype(jnp.int32)
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or psum.shape != (m, n):
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} p{psum.shape}")
    _check_dims(m, k, n, tm, tn)

    grid = (m // tm, n // tn)
    return pl.pallas_call(
        _gemm_os_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(x, w, psum)


def pad_to_multiple(a, mult_rows: int, mult_cols: int):
    """Zero-pad a 2-D operand up to array-aligned dimensions.

    The chip handles ragged workloads by under-filling the spatial array
    (spatial utilization < 1, Fig. 6a); numerically that is identical to
    zero padding, which is what we do here.
    """
    r, c = a.shape
    pr = (-r) % mult_rows
    pc = (-c) % mult_cols
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


def gemm_os_int8_ragged(x, w, psum, *, tm: int = ARRAY_M, tn: int = ARRAY_N):
    """GEMM for arbitrary (M, K, N): zero-pads to the 8x8x8 array and crops.

    Mirrors the chip's behaviour on workloads whose dimensions do not match
    the array (the source of the spatial-utilization loss in Fig. 6a).
    """
    m, k = x.shape
    _, n = w.shape
    xp = pad_to_multiple(x.astype(jnp.int8), tm, ARRAY_K)
    wp = pad_to_multiple(w.astype(jnp.int8), ARRAY_K, tn)
    pp = pad_to_multiple(psum.astype(jnp.int32), tm, tn)
    out = gemm_os_int8(xp, wp, pp, tm=tm, tn=tn)
    return out[:m, :n]
