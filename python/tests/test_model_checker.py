"""Executable spec for the Rust concurrency model checker.

This is a line-faithful Python port of ``rust/src/check/`` — the
DFS interleaving scheduler (``sched.rs``) and all five protocol models
(``flight.rs``, ``plancache.rs``, ``dispatch.rs``, ``pool.rs``,
``lockorder.rs``) with the full 18-entry mutation catalog. The Rust
implementation mirrors this file state machine for state machine; the
assertions below are the same contract ``tests/check_mutations.rs``
pins natively:

* clean (unmutated) models explore to quiescence with zero findings
  and zero truncation at the default depth bound of 64;
* every mutation is caught, and caught with its *pinned* finding id;
* mutations are inert outside their own protocol.

Run ``python test_model_checker.py`` for a verbose sweep.
"""

import sys

DEFAULT_DEPTH = 64

# ---------------------------------------------------------------------------
# sched.rs


class Violation:
    def __init__(self, vid, detail):
        self.id = vid
        self.detail = detail


class Finding:
    def __init__(self, protocol, vid, detail, trace):
        self.protocol = protocol
        self.id = vid
        self.detail = detail
        self.trace = trace

    def __repr__(self):
        return f"Finding({self.protocol}, {self.id}: {self.detail})"


class Exploration:
    def __init__(self):
        self.states = 0
        self.max_depth = 0
        self.truncated = False


def explore(protocol, initial, depth_limit, findings):
    """Port of ``sched::explore``: DFS with visited-set pruning, the
    first counterexample per finding id kept."""
    stats = Exploration()
    seen = set()
    path = []
    reported = set()

    def report(v):
        if v.id not in reported:
            reported.add(v.id)
            findings.append(Finding(protocol, v.id, v.detail, list(path)))

    def dfs(m, depth):
        fp = m.key()
        if fp in seen:
            return
        seen.add(fp)
        stats.states += 1
        stats.max_depth = max(stats.max_depth, depth)
        v = m.invariant()
        if v is not None:
            report(v)
            return
        enabled = [t for t in range(m.threads()) if not m.done(t) and m.enabled(t)]
        if not enabled:
            if all(m.done(t) for t in range(m.threads())):
                q = m.at_quiescence()
                if q is not None:
                    report(q)
            else:
                stuck = ", ".join(f"t{t}" for t in range(m.threads()) if not m.done(t))
                report(Violation("deadlock", f"no runnable thread; stuck: {stuck}"))
            return
        if depth >= depth_limit:
            stats.truncated = True
            return
        for t in enabled:
            child = m.clone()
            label = child.step(t)
            path.append(f"t{t}: {label}")
            dfs(child, depth + 1)
            path.pop()

    dfs(initial, 0)
    return stats


# ---------------------------------------------------------------------------
# Mutation catalog (check/mod.rs)

MUTATIONS = {
    # id: (protocol, expected finding)
    "flight-dropped-notify": ("flight", "deadlock"),
    "flight-abort-silent": ("flight", "deadlock"),
    "flight-wait-if": ("flight", "value-canonical"),
    "flight-missed-abort-retry": ("flight", "value-canonical"),
    "cache-double-count-miss": ("plancache", "accounting"),
    "cache-lost-coalesced": ("plancache", "accounting"),
    "cache-hit-uncounted": ("plancache", "accounting"),
    "cache-skip-double-check": ("plancache", "plan-once"),
    "cache-retire-early": ("plancache", "plan-once"),
    "dispatch-unbounded-queue": ("dispatch", "queue-bound"),
    "dispatch-silent-drop": ("dispatch", "deadlock"),
    "dispatch-worker-exit-on-empty": ("dispatch", "deadlock"),
    "dispatch-numerics-unbounded": ("dispatch", "numerics-bound"),
    "dispatch-reply-dropped": ("dispatch", "deadlock"),
    "pool-claim-skip": ("pool", "item-lost"),
    "pool-racy-claim": ("pool", "claim-once"),
    "pool-wrong-slot": ("pool", "index-order"),
    "lock-rank-inversion": ("lockorder", "rank-monotone"),
}

PROTOCOLS = ["flight", "plancache", "dispatch", "pool", "lockorder"]


# ---------------------------------------------------------------------------
# check/flight.rs

R_READ, R_JOIN, R_LEADERCHECK, R_COMPUTE, R_INSERT, R_RETIRE = range(6)
R_PUBLISH, R_ABORT_RETIRE, R_ABORT_PUBLISH, R_WAIT, R_DONE = range(6, 11)


class FlightCaller:
    __slots__ = (
        "pc", "leading", "waiting_on", "value", "result",
        "spurious_budget", "will_abort", "aborted", "retired_early",
    )

    def __init__(self, will_abort=False):
        self.pc = R_READ
        self.leading = None
        self.waiting_on = None
        self.value = None
        self.result = None
        self.spurious_budget = 1
        self.will_abort = will_abort
        self.aborted = False
        self.retired_early = False

    def copy(self):
        c = FlightCaller()
        for s in self.__slots__:
            setattr(c, s, getattr(self, s))
        return c

    def key(self):
        return tuple(getattr(self, s) for s in self.__slots__)


class FlightModel:
    def __init__(self, mutation=None):
        self.mutation = mutation
        self.cache = None
        self.inflight = None
        self.slots = []  # (published, notified); published: None | ('v', x) | ('abort',)
        self.next_value = 1
        self.planner_runs = 0
        self.callers = [FlightCaller(True), FlightCaller(), FlightCaller()]

    def clone(self):
        m = FlightModel(self.mutation)
        m.cache = self.cache
        m.inflight = self.inflight
        m.slots = [tuple(s) for s in self.slots]
        m.next_value = self.next_value
        m.planner_runs = self.planner_runs
        m.callers = [c.copy() for c in self.callers]
        return m

    def key(self):
        return (
            self.cache, self.inflight, tuple(self.slots), self.next_value,
            self.planner_runs, tuple(c.key() for c in self.callers),
        )

    def is_mut(self, m):
        return self.mutation == m

    def threads(self):
        return len(self.callers)

    def done(self, t):
        return self.callers[t].pc == R_DONE

    def real_wake(self, g):
        published, notified = self.slots[g]
        return published is not None and notified

    def enabled(self, t):
        c = self.callers[t]
        if c.pc == R_DONE:
            return False
        if c.pc == R_WAIT:
            return self.real_wake(c.waiting_on) or c.spurious_budget > 0
        return True

    def consume_wake(self, t, g):
        published, _ = self.slots[g]
        c = self.callers[t]
        c.waiting_on = None
        if published is not None and published[0] == "v":
            c.result = published[1]
            c.pc = R_DONE
            return f"wake(g{g}) -> value"
        if published is not None:  # abort sentinel
            if self.is_mut("flight-missed-abort-retry"):
                c.pc = R_DONE
                return f"wake(g{g}) -> abort taken as value"
            c.pc = R_READ
            return f"wake(g{g}) -> abort, retry"
        c.pc = R_DONE
        return f"wake(g{g}) -> unpublished slot consumed"

    def step(self, t):
        c = self.callers[t]
        pc = c.pc
        if pc == R_READ:
            if self.cache is not None:
                c.result = self.cache
                c.pc = R_DONE
                return "read-hit"
            c.pc = R_JOIN
            return "read-miss"
        if pc == R_JOIN:
            if self.inflight is not None:
                g = self.inflight
                c.waiting_on = g
                c.pc = R_WAIT
                return f"join-follow(g{g})"
            g = len(self.slots)
            self.slots.append((None, False))
            self.inflight = g
            c.leading = g
            c.pc = R_LEADERCHECK
            return f"join-lead(g{g})"
        if pc == R_LEADERCHECK:
            if self.cache is not None:
                c.value = self.cache
                c.pc = R_RETIRE
                return "double-check-hit"
            c.pc = R_COMPUTE
            return "double-check-miss"
        if pc == R_COMPUTE:
            self.planner_runs += 1
            c.value = self.next_value
            self.next_value += 1
            if c.will_abort and not c.aborted:
                c.pc = R_ABORT_RETIRE
                return "compute -> panic"
            c.pc = R_INSERT
            return "compute"
        if pc == R_INSERT:
            if self.cache is None:
                self.cache = c.value
            c.value = self.cache
            c.pc = R_RETIRE
            return "insert(or_insert)"
        if pc == R_RETIRE:
            self.inflight = None
            c.pc = R_PUBLISH
            return "retire"
        if pc == R_PUBLISH:
            g = c.leading
            notified = not self.is_mut("flight-dropped-notify")
            self.slots[g] = (("v", c.value), notified)
            c.leading = None
            c.result = c.value
            c.pc = R_DONE
            return f"publish(g{g})"
        if pc == R_ABORT_RETIRE:
            self.inflight = None
            c.pc = R_ABORT_PUBLISH
            return "abort: retire"
        if pc == R_ABORT_PUBLISH:
            g = c.leading
            if not self.is_mut("flight-abort-silent"):
                self.slots[g] = (("abort",), True)
            c.leading = None
            c.aborted = True
            c.pc = R_DONE
            return f"abort: publish-none(g{g})"
        if pc == R_WAIT:
            g = c.waiting_on
            if self.real_wake(g):
                return self.consume_wake(t, g)
            c.spurious_budget -= 1
            if self.is_mut("flight-wait-if"):
                return self.consume_wake(t, g)
            if self.slots[g][0] is not None:
                return self.consume_wake(t, g)
            return f"spurious-wake(g{g}) -> repark"
        raise AssertionError("done callers are never scheduled")

    def invariant(self):
        if self.planner_runs > 2:
            return Violation(
                "plan-once",
                f"{self.planner_runs} planner runs for one key (abort allows at most 2)",
            )
        return None

    def at_quiescence(self):
        for i, c in enumerate(self.callers):
            if c.aborted:
                continue
            if c.result is None or c.result != self.cache:
                return Violation(
                    "value-canonical",
                    f"caller {i} finished with {c.result}, store holds {self.cache}",
                )
        return None


# ---------------------------------------------------------------------------
# check/plancache.rs (same flight machinery, abort-free, three counters)

P_READ, P_JOIN, P_LEADERCHECK, P_PLAN, P_INSERT, P_RETIRE, P_PUBLISH, P_WAIT, P_DONE = range(9)


class PlanCacheModel:
    def __init__(self, mutation=None):
        self.mutation = mutation
        self.shard = None
        self.inflight = None
        self.slots = []
        self.next_value = 1
        self.planner_runs = 0
        self.hits = 0
        self.misses = 0
        self.coalesced = 0
        # caller: [pc, leading, waiting_on, value, result, budget, retired_early]
        self.callers = [[P_READ, None, None, None, None, 1, False] for _ in range(3)]

    def clone(self):
        m = PlanCacheModel(self.mutation)
        m.shard = self.shard
        m.inflight = self.inflight
        m.slots = [tuple(s) for s in self.slots]
        m.next_value = self.next_value
        m.planner_runs = self.planner_runs
        m.hits, m.misses, m.coalesced = self.hits, self.misses, self.coalesced
        m.callers = [list(c) for c in self.callers]
        return m

    def key(self):
        return (
            self.shard, self.inflight, tuple(self.slots), self.next_value,
            self.planner_runs, self.hits, self.misses, self.coalesced,
            tuple(tuple(c) for c in self.callers),
        )

    def is_mut(self, m):
        return self.mutation == m

    def threads(self):
        return 3

    def done(self, t):
        return self.callers[t][0] == P_DONE

    def real_wake(self, g):
        published, notified = self.slots[g]
        return published is not None and notified

    def enabled(self, t):
        pc, _, waiting_on, _, _, budget, _ = self.callers[t]
        if pc == P_DONE:
            return False
        if pc == P_WAIT:
            return self.real_wake(waiting_on) or budget > 0
        return True

    def step(self, t):
        c = self.callers[t]
        pc = c[0]
        if pc == P_READ:
            if self.shard is not None:
                if not self.is_mut("cache-hit-uncounted"):
                    self.hits += 1
                c[4] = self.shard
                c[0] = P_DONE
                return "shard-hit"
            c[0] = P_JOIN
            return "shard-miss"
        if pc == P_JOIN:
            if self.inflight is not None:
                g = self.inflight
                if not self.is_mut("cache-lost-coalesced"):
                    self.coalesced += 1
                c[2] = g
                c[0] = P_WAIT
                return f"join-follow(g{g})"
            g = len(self.slots)
            self.slots.append((None, False))
            self.inflight = g
            c[1] = g
            c[0] = P_LEADERCHECK
            return f"join-lead(g{g})"
        if pc == P_LEADERCHECK:
            if not self.is_mut("cache-skip-double-check") and self.shard is not None:
                self.hits += 1
                if self.is_mut("cache-double-count-miss"):
                    self.misses += 1
                c[3] = self.shard
                c[0] = P_RETIRE
                return "double-check-hit"
            c[0] = P_PLAN
            return "double-check-miss"
        if pc == P_PLAN:
            self.planner_runs += 1
            self.misses += 1
            c[3] = self.next_value
            self.next_value += 1
            if self.is_mut("cache-retire-early"):
                c[6] = True
                c[0] = P_RETIRE
            else:
                c[0] = P_INSERT
            return "plan (count miss)"
        if pc == P_INSERT:
            if self.shard is None:
                self.shard = c[3]
            c[3] = self.shard
            c[0] = P_PUBLISH if c[6] else P_RETIRE
            return "insert(or_insert)"
        if pc == P_RETIRE:
            self.inflight = None
            c[0] = P_INSERT if c[6] else P_PUBLISH
            return "retire"
        if pc == P_PUBLISH:
            g = c[1]
            self.slots[g] = (("v", c[3]), True)
            c[1] = None
            c[4] = c[3]
            c[0] = P_DONE
            return f"publish(g{g})"
        if pc == P_WAIT:
            g = c[2]
            if not self.real_wake(g):
                c[5] -= 1
                if self.slots[g][0] is None:
                    return f"spurious-wake(g{g}) -> repark"
            c[2] = None
            c[4] = self.slots[g][0][1]
            c[0] = P_DONE
            return f"wake(g{g}) -> value"
        raise AssertionError("done callers are never scheduled")

    def invariant(self):
        return None

    def at_quiescence(self):
        calls = len(self.callers)
        total = self.hits + self.misses + self.coalesced
        if total != calls:
            return Violation(
                "accounting",
                f"hits({self.hits}) + misses({self.misses}) + "
                f"coalesced({self.coalesced}) = {total} != {calls} calls",
            )
        if self.planner_runs > 1:
            return Violation("plan-once", f"{self.planner_runs} planner runs for one key")
        for i, c in enumerate(self.callers):
            if c[4] is None or c[4] != self.shard:
                return Violation(
                    "value-canonical",
                    f"caller {i} finished with {c[4]}, shard holds {self.shard}",
                )
        return None


# ---------------------------------------------------------------------------
# check/dispatch.rs

QUEUE_CAP = 1
NUM_CAP = 1
CONNS = 2
WORKERS = 2

SUBMIT, AWAIT_REPLY, FINISHED = range(3)
W_RECV, W_SENDNUM, W_AWAITNUM, W_EXITED = range(4)
N_RECV, N_EXITED = range(2)
PENDING, REJECTED, DONE_ST = range(3)


class DispatchModel:
    def __init__(self, mutation=None):
        self.mutation = mutation
        self.queue = []
        self.senders = CONNS
        self.workers_alive = WORKERS
        self.numq = []
        self.num_done = [False] * CONNS
        self.status = [PENDING] * CONNS
        self.conns = [SUBMIT] * CONNS
        self.workers = [(W_RECV, None)] * WORKERS
        self.numerics = N_RECV

    def clone(self):
        m = DispatchModel(self.mutation)
        m.queue = list(self.queue)
        m.senders = self.senders
        m.workers_alive = self.workers_alive
        m.numq = list(self.numq)
        m.num_done = list(self.num_done)
        m.status = list(self.status)
        m.conns = list(self.conns)
        m.workers = list(self.workers)
        m.numerics = self.numerics
        return m

    def key(self):
        return (
            tuple(self.queue), self.senders, self.workers_alive, tuple(self.numq),
            tuple(self.num_done), tuple(self.status), tuple(self.conns),
            tuple(self.workers), self.numerics,
        )

    def is_mut(self, m):
        return self.mutation == m

    def threads(self):
        return CONNS + WORKERS + 1

    def done(self, t):
        if t < CONNS:
            return self.conns[t] == FINISHED
        if t < CONNS + WORKERS:
            return self.workers[t - CONNS][0] == W_EXITED
        return self.numerics == N_EXITED

    def enabled(self, t):
        if t < CONNS:
            pc = self.conns[t]
            if pc == SUBMIT:
                return True
            if pc == AWAIT_REPLY:
                return self.status[t] == DONE_ST
            return False
        if t < CONNS + WORKERS:
            pc, req = self.workers[t - CONNS]
            if pc == W_RECV:
                return (
                    bool(self.queue)
                    or self.senders == 0
                    or self.is_mut("dispatch-worker-exit-on-empty")
                )
            if pc == W_SENDNUM:
                return len(self.numq) < NUM_CAP or self.is_mut("dispatch-numerics-unbounded")
            if pc == W_AWAITNUM:
                return self.num_done[req]
            return False
        if self.numerics == N_RECV:
            return bool(self.numq) or self.workers_alive == 0
        return False

    def step(self, t):
        if t < CONNS:
            pc = self.conns[t]
            if pc == SUBMIT:
                if len(self.queue) < QUEUE_CAP or self.is_mut("dispatch-unbounded-queue"):
                    self.queue.append(t)
                    self.conns[t] = AWAIT_REPLY
                    return f"submit(r{t}) admitted"
                if self.is_mut("dispatch-silent-drop"):
                    self.conns[t] = AWAIT_REPLY
                    return f"submit(r{t}) dropped silently"
                self.status[t] = REJECTED
                self.senders -= 1
                self.conns[t] = FINISHED
                return f"submit(r{t}) -> ERR busy"
            self.senders -= 1
            self.conns[t] = FINISHED
            return f"reply(r{t}) received, disconnect"
        if t < CONNS + WORKERS:
            w = t - CONNS
            pc, req = self.workers[w]
            if pc == W_RECV:
                if self.queue:
                    req = self.queue.pop(0)
                    self.workers[w] = (W_SENDNUM, req)
                    return f"recv -> r{req}"
                self.workers_alive -= 1
                self.workers[w] = (W_EXITED, None)
                return "recv -> disconnected, exit"
            if pc == W_SENDNUM:
                self.numq.append(req)
                self.workers[w] = (W_AWAITNUM, req)
                return f"numerics-send(r{req})"
            if not self.is_mut("dispatch-reply-dropped"):
                self.status[req] = DONE_ST
            self.workers[w] = (W_RECV, None)
            return f"reply(r{req}) sent"
        if self.numq:
            req = self.numq.pop(0)
            self.num_done[req] = True
            return f"numerics r{req} computed"
        self.numerics = N_EXITED
        return "numerics channel closed, exit"

    def invariant(self):
        if len(self.queue) > QUEUE_CAP:
            return Violation(
                "queue-bound",
                f"{len(self.queue)} queued jobs exceed queue_depth {QUEUE_CAP}",
            )
        if len(self.numq) > NUM_CAP:
            return Violation(
                "numerics-bound",
                f"{len(self.numq)} numerics jobs exceed channel cap {NUM_CAP}",
            )
        return None

    def at_quiescence(self):
        for r, st in enumerate(self.status):
            if st == PENDING:
                return Violation("request-lost", f"request r{r} neither served nor rejected")
        if self.queue:
            return Violation(
                "drain-incomplete",
                f"{len(self.queue)} jobs left in the queue after shutdown",
            )
        return None


# ---------------------------------------------------------------------------
# check/pool.rs

ITEMS = 3
POOL_WORKERS = 2

PC_CLAIM, PC_CLAIMSTORE, PC_WRITE, PC_EXITED = range(4)


def pool_f(i):
    return 10 + i


class PoolModel:
    def __init__(self, mutation=None):
        self.mutation = mutation
        self.next = 0
        self.claims = [0] * ITEMS
        self.slots = [None] * ITEMS
        self.pcs = [(PC_CLAIM, None)] * POOL_WORKERS
        self.seq = [0] * POOL_WORKERS

    def clone(self):
        m = PoolModel(self.mutation)
        m.next = self.next
        m.claims = list(self.claims)
        m.slots = list(self.slots)
        m.pcs = list(self.pcs)
        m.seq = list(self.seq)
        return m

    def key(self):
        return (self.next, tuple(self.claims), tuple(self.slots), tuple(self.pcs), tuple(self.seq))

    def is_mut(self, m):
        return self.mutation == m

    def threads(self):
        return POOL_WORKERS

    def done(self, t):
        return self.pcs[t][0] == PC_EXITED

    def enabled(self, t):
        return self.pcs[t][0] != PC_EXITED

    def commit(self, w, i):
        if i < ITEMS:
            self.claims[i] += 1
            self.pcs[w] = (PC_WRITE, i)
            return f"claim {i}"
        self.pcs[w] = (PC_EXITED, None)
        return "claim past end, exit"

    def step(self, t):
        pc, i = self.pcs[t]
        if pc == PC_CLAIM:
            if self.is_mut("pool-racy-claim"):
                self.pcs[t] = (PC_CLAIMSTORE, self.next)
                return f"racy load {self.next}"
            i = self.next
            self.next += 2 if self.is_mut("pool-claim-skip") else 1
            return self.commit(t, i)
        if pc == PC_CLAIMSTORE:
            self.next = i + 1
            return self.commit(t, i)
        if pc == PC_WRITE:
            target = self.seq[t] if self.is_mut("pool-wrong-slot") else i
            if target < ITEMS:
                self.slots[target] = pool_f(i)
            self.seq[t] += 1
            self.pcs[t] = (PC_CLAIM, None)
            return f"write f({i}) -> slot {target}"
        raise AssertionError("exited workers are never scheduled")

    def invariant(self):
        for i, c in enumerate(self.claims):
            if c > 1:
                return Violation("claim-once", f"item {i} claimed {c} times")
        return None

    def at_quiescence(self):
        for i in range(ITEMS):
            if self.claims[i] == 0 or self.slots[i] is None:
                return Violation("item-lost", f"item {i} never claimed/completed")
            if self.slots[i] != pool_f(i):
                return Violation(
                    "index-order",
                    f"slot {i} holds {self.slots[i]}, expected {pool_f(i)}",
                )
        return None


# ---------------------------------------------------------------------------
# check/lockorder.rs

PLAN_SHARD, TILE_CLASS_MAP, MAPPER_SHARD, TILE_SHARD = 10, 20, 30, 40
FLIGHT_MAP, FLIGHT_SLOT, DISPATCH_QUEUE, POOL_SLOT = 50, 60, 70, 80


def script_planner():
    return [
        ("acq", PLAN_SHARD), ("rel", PLAN_SHARD),
        ("acq", FLIGHT_MAP), ("rel", FLIGHT_MAP),
        ("acq", TILE_CLASS_MAP),
        ("acq", TILE_SHARD), ("rel", TILE_SHARD),
        ("rel", TILE_CLASS_MAP),
        ("acq", PLAN_SHARD), ("rel", PLAN_SHARD),
        ("acq", FLIGHT_MAP), ("rel", FLIGHT_MAP),
        ("acq", FLIGHT_SLOT), ("rel", FLIGHT_SLOT),
    ]


def script_simulator(inverted):
    s = [
        ("acq", TILE_SHARD), ("rel", TILE_SHARD),
        ("acq", FLIGHT_MAP), ("rel", FLIGHT_MAP),
    ]
    if inverted:
        s += [
            ("acq", FLIGHT_SLOT), ("acq", FLIGHT_MAP),
            ("rel", FLIGHT_MAP), ("rel", FLIGHT_SLOT),
        ]
    else:
        s += [("acq", FLIGHT_SLOT), ("rel", FLIGHT_SLOT)]
    s += [("acq", POOL_SLOT), ("rel", POOL_SLOT)]
    return s


def script_planner_nested():
    return script_planner() + [
        ("acq", FLIGHT_MAP), ("acq", FLIGHT_SLOT),
        ("rel", FLIGHT_SLOT), ("rel", FLIGHT_MAP),
    ]


class LockOrderModel:
    def __init__(self, mutation=None):
        inverted = mutation == "lock-rank-inversion"
        if inverted:
            self.scripts = [script_planner_nested(), script_simulator(True)]
        else:
            self.scripts = [script_planner(), script_simulator(False)]
        n = len(self.scripts)
        self.idx = [0] * n
        self.held = [[] for _ in range(n)]
        self.owner = {}

    def clone(self):
        m = LockOrderModel.__new__(LockOrderModel)
        m.scripts = self.scripts  # immutable per exploration
        m.idx = list(self.idx)
        m.held = [list(h) for h in self.held]
        m.owner = dict(self.owner)
        return m

    def key(self):
        return (
            tuple(self.idx),
            tuple(tuple(h) for h in self.held),
            tuple(sorted(self.owner.items())),
        )

    def threads(self):
        return len(self.scripts)

    def done(self, t):
        return self.idx[t] == len(self.scripts[t])

    def enabled(self, t):
        if self.done(t):
            return False
        op, lock = self.scripts[t][self.idx[t]]
        if op == "acq":
            return lock not in self.owner
        return True

    def step(self, t):
        op, lock = self.scripts[t][self.idx[t]]
        self.idx[t] += 1
        if op == "acq":
            self.owner[lock] = t
            self.held[t].append(lock)
            return f"acquire rank {lock}"
        self.owner.pop(lock, None)
        if lock in self.held[t]:
            # remove the latest holding of that rank
            for pos in range(len(self.held[t]) - 1, -1, -1):
                if self.held[t][pos] == lock:
                    del self.held[t][pos]
                    break
        return f"release rank {lock}"

    def invariant(self):
        for t, held in enumerate(self.held):
            for a, b in zip(held, held[1:]):
                if a >= b:
                    return Violation(
                        "rank-monotone",
                        f"t{t} acquired rank {b} while holding rank {a} "
                        "(acquisition order must strictly increase)",
                    )
        return None

    def at_quiescence(self):
        for t, held in enumerate(self.held):
            if held:
                return Violation("lock-leak", f"t{t} terminated holding ranks {held}")
        return None


# ---------------------------------------------------------------------------
# check/mod.rs surface

MODELS = {
    "flight": FlightModel,
    "plancache": PlanCacheModel,
    "dispatch": DispatchModel,
    "pool": PoolModel,
    "lockorder": LockOrderModel,
}


def check_protocol(protocol, depth=DEFAULT_DEPTH, mutation=None):
    findings = []
    stats = explore(protocol, MODELS[protocol](mutation), depth, findings)
    return stats, findings


# ---------------------------------------------------------------------------
# Tests (the same contract as rust/tests/check_mutations.rs)


def test_clean_models_explore_to_quiescence_with_zero_findings():
    for protocol in PROTOCOLS:
        stats, findings = check_protocol(protocol)
        assert not findings, f"{protocol}: {findings}"
        assert not stats.truncated, f"{protocol}: truncated"
        assert stats.states > 1, f"{protocol}: trivial exploration"


def test_every_mutation_is_caught_with_its_pinned_finding():
    for mid, (protocol, expected) in MUTATIONS.items():
        _, findings = check_protocol(protocol, mutation=mid)
        ids = [f.id for f in findings]
        assert expected in ids, f"{mid}: expected {expected}, got {ids}"


def test_every_finding_carries_a_counterexample_trace():
    for mid, (protocol, expected) in MUTATIONS.items():
        _, findings = check_protocol(protocol, mutation=mid)
        f = next(f for f in findings if f.id == expected)
        assert f.trace, f"{mid}: empty trace"
        for step in f.trace:
            assert step.startswith("t") and ": " in step, f"{mid}: bad step {step!r}"


def test_mutations_are_inert_outside_their_own_protocol():
    for mid, (home, _) in MUTATIONS.items():
        for protocol in PROTOCOLS:
            if protocol == home:
                continue
            _, findings = check_protocol(protocol, mutation=mid)
            assert not findings, f"{mid} leaked into {protocol}: {findings}"


def test_rig_meets_its_coverage_floor():
    assert len(MUTATIONS) >= 10
    assert len({p for p, _ in MUTATIONS.values()}) >= 4


if __name__ == "__main__":
    sys.setrecursionlimit(100_000)
    for protocol in PROTOCOLS:
        stats, findings = check_protocol(protocol)
        status = "clean" if not findings else f"{len(findings)} finding(s)"
        print(f"{protocol:<10} {status} ({stats.states} states, depth {stats.max_depth})")
        assert not findings and not stats.truncated, findings
    caught = 0
    for mid, (protocol, expected) in sorted(MUTATIONS.items()):
        _, findings = check_protocol(protocol, mutation=mid)
        ids = [f.id for f in findings]
        ok = expected in ids
        caught += ok
        print(f"  {mid:<30} -> {ids} (want {expected}) {'OK' if ok else 'MISSED'}")
        assert ok, f"{mid}: {ids}"
    print(f"all {caught}/{len(MUTATIONS)} mutations caught with their pinned findings")
