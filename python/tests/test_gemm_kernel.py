"""Pallas 8x8x8 output-stationary GEMM kernel vs the pure-jnp oracle.

This is the CORE correctness signal for Layer 1: the kernel must be
*bit-exact* against int32 reference accumulation for every shape, tiling
and operand distribution, including the saturating edges of int8.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from compile.kernels.gemm import (
    ARRAY_K,
    ARRAY_M,
    ARRAY_N,
    MACS,
    gemm_os_int8,
    gemm_os_int8_ragged,
    pad_to_multiple,
)
from compile.kernels.ref import gemm_ref

RNG = np.random.default_rng(1234)


def rand_i8(shape, rng=RNG):
    return rng.integers(-128, 128, shape, dtype=np.int32)


def test_array_constants_match_paper():
    # Paper Sec. II-A: 512 MACs organised 8x8x8.
    assert (ARRAY_M, ARRAY_N, ARRAY_K) == (8, 8, 8)
    assert MACS == 512


def test_single_tile_exact():
    x = rand_i8((8, 8))
    w = rand_i8((8, 8))
    p = RNG.integers(-(2**20), 2**20, (8, 8), dtype=np.int32)
    out = gemm_os_int8(x, w, p)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(gemm_ref(x, w, p)))


def test_extreme_values_saturate_nowhere():
    # All -128 x -128 over K=64: 64 * 16384 = 1048576, well inside int32.
    x = np.full((8, 64), -128, np.int32)
    w = np.full((64, 8), -128, np.int32)
    p = np.zeros((8, 8), np.int32)
    out = np.asarray(gemm_os_int8(x, w, p))
    assert (out == 64 * 128 * 128).all()


def test_psum_seeding_is_pure_addition():
    x = rand_i8((16, 24))
    w = rand_i8((24, 16))
    p = RNG.integers(-(2**24), 2**24, (16, 16), dtype=np.int32)
    z = np.zeros_like(p)
    with_p = np.asarray(gemm_os_int8(x, w, p))
    without = np.asarray(gemm_os_int8(x, w, z))
    np.testing.assert_array_equal(with_p, without + p)


def test_block_size_does_not_change_result():
    x = rand_i8((64, 32))
    w = rand_i8((32, 64))
    p = np.zeros((64, 64), np.int32)
    ref = np.asarray(gemm_os_int8(x, w, p, tm=8, tn=8))
    for tm, tn in [(16, 16), (32, 32), (64, 64), (8, 64), (64, 8)]:
        got = np.asarray(gemm_os_int8(x, w, p, tm=tm, tn=tn))
        np.testing.assert_array_equal(got, ref, err_msg=f"tm={tm} tn={tn}")


@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (8, 64, 8), (32, 16, 24), (96, 96, 96)])
def test_aligned_shapes(m, k, n):
    x = rand_i8((m, k))
    w = rand_i8((k, n))
    p = RNG.integers(-1000, 1000, (m, n), dtype=np.int32)
    out = gemm_os_int8(x, w, p, tm=8, tn=8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(gemm_ref(x, w, p)))


@settings(max_examples=25, deadline=None)
@given(
    mb=st.integers(1, 6),
    kb=st.integers(1, 6),
    nb=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_aligned_sweep(mb, kb, nb, seed):
    """Property: exact vs oracle for random 8-aligned shapes and data."""
    rng = np.random.default_rng(seed)
    m, k, n = 8 * mb, 8 * kb, 8 * nb
    x = rand_i8((m, k), rng)
    w = rand_i8((k, n), rng)
    p = rng.integers(-(2**16), 2**16, (m, n), dtype=np.int32)
    out = gemm_os_int8(x, w, p, tm=8, tn=8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(gemm_ref(x, w, p)))


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_ragged_sweep(m, k, n, seed):
    """Property: the padded path matches the oracle for ARBITRARY shapes,
    mirroring the chip's under-filled-array behaviour (Fig. 6a)."""
    rng = np.random.default_rng(seed)
    x = rand_i8((m, k), rng)
    w = rand_i8((k, n), rng)
    p = rng.integers(-(2**16), 2**16, (m, n), dtype=np.int32)
    out = gemm_os_int8_ragged(x, w, p)
    assert out.shape == (m, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(gemm_ref(x, w, p)))


def test_pad_to_multiple_identity_when_aligned():
    a = jnp.ones((16, 24), jnp.int8)
    assert pad_to_multiple(a, 8, 8) is a


def test_pad_to_multiple_zero_fills():
    a = jnp.ones((3, 5), jnp.int8)
    p = pad_to_multiple(a, 8, 8)
    assert p.shape == (8, 8)
    assert int(p.sum()) == 15


def test_rejects_misaligned_without_padding():
    x = jnp.zeros((9, 8), jnp.int8)
    w = jnp.zeros((8, 8), jnp.int8)
    p = jnp.zeros((9, 8), jnp.int32)
    with pytest.raises(ValueError):
        gemm_os_int8(x, w, p)


def test_rejects_shape_mismatch():
    x = jnp.zeros((8, 16), jnp.int8)
    w = jnp.zeros((8, 8), jnp.int8)
    p = jnp.zeros((8, 8), jnp.int32)
    with pytest.raises(ValueError):
        gemm_os_int8(x, w, p)
