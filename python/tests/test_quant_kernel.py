"""Quantization-SIMD and maxpool Pallas kernels vs the jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from compile.kernels.quant import LANES, QMAX, QMIN, maxpool2d_int8, requant_int8
from compile.kernels.ref import maxpool2d_ref, requant_ref

RNG = np.random.default_rng(99)


def test_lane_count_matches_paper():
    # Sec. II-D: eight quantization PE lanes.
    assert LANES == 8


@pytest.mark.parametrize("scale", [1.0, 0.5, 0.01, 2.0, 1e-4])
def test_requant_matches_ref(scale):
    acc = RNG.integers(-(2**20), 2**20, (16, 16), dtype=np.int32)
    got = requant_int8(acc, np.array([scale], np.float32))
    exp = requant_ref(acc, scale)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_requant_saturates():
    acc = np.array([[10**9, -(10**9), 0, 127, -128, 128, -129, 1] * 1], np.int32)
    got = np.asarray(requant_int8(acc, np.array([1.0], np.float32)))
    assert got.max() == QMAX and got.min() == QMIN
    np.testing.assert_array_equal(got[0, :5], [127, -128, 0, 127, -128])


def test_requant_relu():
    acc = np.array([[-5, 5, -1, 0, 100, -100, 7, -7]], np.int32)
    got = np.asarray(requant_int8(acc, np.array([1.0], np.float32), relu=True))
    assert (got >= 0).all()
    np.testing.assert_array_equal(got[0], [0, 5, 0, 0, 100, 0, 7, 0])


def test_requant_rejects_non_lane_multiple():
    with pytest.raises(ValueError):
        requant_int8(np.zeros((4, 7), np.int32), np.array([1.0], np.float32))


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 32),
    cols=st.integers(1, 8),
    scale=st.floats(1e-5, 4.0, allow_nan=False, allow_infinity=False),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_requant_sweep(rows, cols, scale, relu, seed):
    rng = np.random.default_rng(seed)
    acc = rng.integers(-(2**24), 2**24, (rows, cols * LANES), dtype=np.int32)
    got = requant_int8(acc, np.array([scale], np.float32), relu=relu)
    exp = requant_ref(acc, scale, relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


@pytest.mark.parametrize("window,stride,h,w", [(2, 2, 8, 8), (3, 2, 9, 9), (3, 1, 6, 7), (2, 1, 5, 5)])
def test_maxpool_matches_ref(window, stride, h, w):
    x = RNG.integers(-128, 128, (4, h, w), dtype=np.int32)
    got = maxpool2d_int8(x, window=window, stride=stride)
    exp = maxpool2d_ref(x, window=window, stride=stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


@settings(max_examples=15, deadline=None)
@given(
    c=st.integers(1, 4),
    h=st.integers(3, 12),
    w=st.integers(3, 12),
    window=st.integers(1, 3),
    stride=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_maxpool_sweep(c, h, w, window, stride, seed):
    if window > h or window > w:
        return
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (c, h, w), dtype=np.int32)
    got = maxpool2d_int8(x, window=window, stride=stride)
    exp = maxpool2d_ref(x, window=window, stride=stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


# ------------------------------------------------------- residual fusion


from compile.kernels.quant import add_requant_int8
from compile.kernels.ref import add_requant_ref


@pytest.mark.parametrize("relu", [False, True])
def test_add_requant_matches_ref(relu):
    rng = np.random.default_rng(21)
    a = rng.integers(-(2**20), 2**20, (16, 16), dtype=np.int32)
    b = rng.integers(-128, 128, (16, 16), dtype=np.int32)
    got = add_requant_int8(a, b, np.array([0.01], np.float32), relu=relu)
    exp = add_requant_ref(a, b, 0.01, relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_add_requant_rejects_mismatched_shapes():
    with pytest.raises(ValueError):
        add_requant_int8(
            np.zeros((8, 8), np.int32),
            np.zeros((8, 16), np.int32),
            np.array([1.0], np.float32),
        )


@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(1, 24),
    cols=st.integers(1, 6),
    scale=st.floats(1e-4, 2.0, allow_nan=False, allow_infinity=False),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_add_requant_sweep(rows, cols, scale, relu, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(-(2**22), 2**22, (rows, cols * LANES), dtype=np.int32)
    b = rng.integers(-128, 128, (rows, cols * LANES), dtype=np.int32)
    got = add_requant_int8(a, b, np.array([scale], np.float32), relu=relu)
    exp = add_requant_ref(a, b, scale, relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
