"""AOT pipeline sanity: every entry lowers to parseable HLO text and the
manifest describes its true signature."""

import json

import jax
import pytest

from compile import aot


@pytest.mark.parametrize("name", list(aot.ENTRIES))
def test_entry_lowers_to_hlo_text(name):
    fn, args = aot.ENTRIES[name]
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text, f"{name}: no ENTRY computation in HLO text"
    assert "HloModule" in text
    # jax>=0.5 emits 64-bit ids in *protos*; text keeps parseable ids.
    assert len(text) > 200


def test_manifest_roundtrip(tmp_path):
    mani = aot.lower_all(str(tmp_path), only=["gemm8"])
    on_disk = json.loads((tmp_path / "manifest.json").read_text())
    assert on_disk == mani
    art = on_disk["artifacts"]["gemm8"]
    assert art["file"] == "gemm8.hlo.txt"
    assert (tmp_path / art["file"]).exists()
    assert art["inputs"] == [
        {"shape": [8, 8], "dtype": "i32"},
        {"shape": [8, 8], "dtype": "i32"},
        {"shape": [8, 8], "dtype": "i32"},
        {"shape": [1], "dtype": "f32"},
    ]
    assert art["outputs"] == [
        {"shape": [8, 8], "dtype": "i32"},
        {"shape": [8, 8], "dtype": "i32"},
    ]


def test_entry_set_covers_paper_workload_kinds():
    """The artifact zoo must cover GEMM, Conv2D, MHA, LSTM, maxpool —
    the operation set of Table I's 'GEMM/CONV2D/MHA' row plus auxiliaries."""
    names = set(aot.ENTRIES)
    assert {"gemm8", "gemm64", "gemm96"} <= names
    assert any(n.startswith("conv") for n in names)
    assert "mha64" in names
    assert "lstm64" in names
    assert "maxpool2x2" in names
