"""Differential oracle for the Rust tile engine's steady-state fast path.

This is a line-faithful Python port of ``rust/src/sim/engine.rs``
(``simulate_tile``) plus the row-recurrence fast path that PR 6 adds to
it: at each subtile-row boundary the engine captures a *relative* state
key (FIFO fills, in-flight landing offsets, next-request bank phases,
psum/output progress, the arbiter's round-robin pointer); when the same
key recurs at a later row boundary the dynamics are provably periodic,
so the walk jumps ``n`` whole periods at once by adding per-period
deltas to every counter — bit-identical by construction, because the
key captures the complete state of the machine relative to the row
boundary and the per-cycle model is deterministic.

The Rust implementation mirrors this file statement for statement; the
CI-sized fuzz below (seeded PRNG, both memory organisations, folds,
psum/spill variants, raw/blocked layouts) is the executable spec the
Rust ``tests/differential.rs`` re-runs natively at larger sample sizes.

Run ``python test_fastpath_differential.py N`` for an N-spec soak.
"""

import sys
from collections import deque

MAX_CHANNELS = 8
MAX_WEIGHT_CHANNELS = 128
SUPER_BANK_BANKS = 8
DATA_MEM_BYTES = 128 * 1024
UMAX = (1 << 64) - 1
SNAPSHOT_CAP = 64


def div_ceil(a, b):
    return -(-a // b)


def block_residue(dim, unroll, i):
    full = dim // unroll
    return unroll if i < full else dim - full * unroll


class Cfg:
    def __init__(
        self,
        array=("3d", 8, 8, 8),
        separated=False,
        prefetch=True,
        stream_fifo_depth=8,
        simd_lanes=8,
        tmux_psum_output=True,
        num_banks=32,
        mem_latency=2,
    ):
        self.array = array
        self.separated = separated
        self.prefetch = prefetch
        self.stream_fifo_depth = stream_fifo_depth
        self.simd_lanes = simd_lanes
        self.tmux_psum_output = tmux_psum_output
        self.num_banks = num_banks
        self.mem_latency = mem_latency

    def macs(self):
        if self.array[0] == "3d":
            return self.array[1] * self.array[2] * self.array[3]
        return self.array[1] * self.array[2]


class Spec:
    def __init__(
        self,
        tm,
        tk,
        tn,
        psum_in=False,
        spill_out=False,
        input_blocked=True,
        fold=1,
        in_base=0,
        w_base=8,
        p_base=16,
        o_base=24,
    ):
        self.tm, self.tk, self.tn = tm, tk, tn
        self.psum_in, self.spill_out = psum_in, spill_out
        self.input_blocked, self.fold = input_blocked, fold
        self.in_base, self.w_base, self.p_base, self.o_base = in_base, w_base, p_base, o_base


METRIC_FIELDS = (
    "total_cycles",
    "active_cycles",
    "useful_macs",
    "offered_macs",
    "bank_reads",
    "bank_writes",
    "bank_conflicts",
    "stall_cycles",
    "simd_cycles",
    "fifo_events",
)


class Channel:
    __slots__ = ("issued", "fill", "ready")

    def __init__(self):
        self.issued = 0
        self.fill = 0
        self.ready = deque()

    def arrive(self, cycle):
        if self.ready and self.ready[0] == cycle:
            self.ready.popleft()
            self.fill += 1
            return True
        return False


class TileSim:
    """Port of the Rust TileSim: geometry derivation + per-cycle step."""

    def __init__(self, cfg, spec):
        self.cfg, self.spec = cfg, spec
        self.macs = cfg.macs()
        self.separate_ports = cfg.separated
        if cfg.array[0] == "3d":
            am_, an_, ak_ = cfg.array[1], cfg.array[2], cfg.array[3]
            fold = min(max(spec.fold, 1), am_, MAX_WEIGHT_CHANNELS)
            self.fold = fold
            self.am = max(am_ // fold, 1)
            self.an = an_
            self.ak = ak_ * fold
            self.n_in = min(am_, MAX_CHANNELS)
            self.n_w_ch = fold
            self.w_stride = 8
            self.w_super = True
        else:
            am_, an_ = cfg.array[1], cfg.array[2]
            self.fold = 1
            self.am, self.an, self.ak = am_, an_, 1
            self.n_in = min(max(am_ // 8, 1), MAX_CHANNELS)
            self.n_w_ch = 1
            self.w_stride = max(an_ // 8, 1)
            self.w_super = False
        self.sub_m = max(div_ceil(spec.tm, self.am), 1)
        self.sub_n = max(div_ceil(spec.tn, self.an), 1)
        self.ksteps = max(div_ceil(spec.tk, self.ak), 1)
        self.n_sub = self.sub_m * self.sub_n
        self.total_steps = self.n_sub * self.ksteps
        self.outputs_per_sub = self.am * self.an
        self.psum_words_per_sub = div_ceil(self.outputs_per_sub * 4, 8)
        obr = 4 if spec.spill_out else 1
        self.out_total_bytes = 0
        for ti in range(self.sub_m):
            for tj in range(self.sub_n):
                mr = block_residue(spec.tm, self.am, ti)
                nr = block_residue(spec.tn, self.an, tj)
                self.out_total_bytes += mr * nr * obr
        self.fifo_depth = cfg.stream_fifo_depth if cfg.prefetch else 1
        self.nb = cfg.num_banks
        self.mem_rr = 0
        self.inputs = [Channel() for _ in range(MAX_CHANNELS)]
        self.weights = [Channel() for _ in range(self.n_w_ch)]
        self.psum_issued = 0
        self.psum_fill = 0
        self.psum_pending = UMAX
        self.psum_total = self.n_sub * self.psum_words_per_sub if spec.psum_in else 0
        self.simd_queue = 0
        self.out_bytes = 0
        self.out_written_bytes = 0
        self.fired = 0
        # Fire evaluations where psum_ready was false (fast-path guard:
        # a jump over an active psum stream is only sound if the stream
        # never gated the array during the observed period).
        self.psum_unready = 0
        self.m = dict.fromkeys(METRIC_FIELDS, 0)
        self.cycle = 0
        self.row_stride_words = self.ksteps
        self.max_cycles = 1_000_000 + self.total_steps * 64
        self.row_steps = self.sub_n * self.ksteps
        self.psum_row = self.sub_n * self.psum_words_per_sub

    def done(self):
        return not (
            self.fired < self.total_steps
            or self.simd_queue > 0
            or self.out_written_bytes < self.out_total_bytes
        )

    # -- bank arbitration (port of BankedMemory::arbitrate) ------------
    def arbitrate(self, reqs):
        # reqs: list of (addr, write, is_psum, super_bank)
        granted, denied = [], []
        reads = writes = 0
        if not reqs:
            return granted, denied, reads, writes
        busy = [False] * self.nb

        def try_grant(i):
            nonlocal reads, writes
            addr, write, _, sb = reqs[i]
            if sb:
                g = (addr % self.nb) // SUPER_BANK_BANKS
                lo = g * SUPER_BANK_BANKS
                if any(busy[lo : lo + SUPER_BANK_BANKS]):
                    denied.append(i)
                else:
                    for b in range(lo, lo + SUPER_BANK_BANKS):
                        busy[b] = True
                    granted.append(i)
                    if write:
                        writes += SUPER_BANK_BANKS
                    else:
                        reads += SUPER_BANK_BANKS
            else:
                b = addr % self.nb
                if busy[b]:
                    denied.append(i)
                else:
                    busy[b] = True
                    granted.append(i)
                    if write:
                        writes += 1
                    else:
                        reads += 1

        n = len(reqs)
        for i in range(n):
            if reqs[i][2]:
                try_grant(i)
        for k in range(n):
            i = (self.mem_rr + k) % n
            if not reqs[i][2]:
                try_grant(i)
        self.mem_rr = (self.mem_rr + 1) % max(n, 1)
        return granted, denied, reads, writes

    # -- one loop body iteration (port of the Rust while body) ---------
    def cycle_once(self):
        spec, m = self.spec, self.m
        # 1. arrivals
        for r in range(self.n_in):
            if self.inputs[r].arrive(self.cycle):
                m["fifo_events"] += 1
        for ch in self.weights:
            if ch.arrive(self.cycle):
                m["fifo_events"] += 1
        if self.psum_pending == self.cycle:
            self.psum_pending = UMAX
            self.psum_fill += 1
            m["fifo_events"] += 1

        # 2. fire
        if self.fired < self.total_steps:
            sub = self.fired // self.ksteps
            ks = self.fired % self.ksteps
            ti = sub // self.sub_n
            tj = sub % self.sub_n
            inputs_ready = all(self.inputs[r].fill > 0 for r in range(self.n_in))
            weight_ready = all(c.fill > 0 for c in self.weights)
            psum_ready = (
                not spec.psum_in
                or self.psum_fill >= (sub + 1) * self.psum_words_per_sub
                or self.psum_fill == self.psum_total
            )
            if not psum_ready:
                self.psum_unready += 1
            regs_free = ks < self.ksteps - 1 or self.simd_queue <= self.outputs_per_sub
            if inputs_ready and weight_ready and psum_ready and regs_free:
                for r in range(self.n_in):
                    self.inputs[r].fill -= 1
                    m["fifo_events"] += 1
                for ch in self.weights:
                    ch.fill -= 1
                    m["fifo_events"] += 1
                self.fired += 1
                m["active_cycles"] += 1
                mr = block_residue(spec.tm, self.am, ti)
                nr = block_residue(spec.tn, self.an, tj)
                kr = block_residue(spec.tk, self.ak, ks)
                m["useful_macs"] += mr * nr * kr
                m["offered_macs"] += self.macs
                if self.fired % self.ksteps == 0:
                    valid = mr * nr
                    if spec.spill_out:
                        self.out_bytes += valid * 4
                    else:
                        self.simd_queue += valid
            else:
                m["stall_cycles"] += 1

        # 3. SIMD drain
        if self.simd_queue > 0:
            done = min(self.simd_queue, self.cfg.simd_lanes)
            self.simd_queue -= done
            m["simd_cycles"] += 1
            if not spec.spill_out:
                self.out_bytes += done

        # 4. issue + arbitrate
        reqs = []  # (addr, write, is_psum, super_bank)
        kinds = []
        for r in range(self.n_in):
            ch = self.inputs[r]
            if ch.issued < self.total_steps and ch.fill + len(ch.ready) < self.fifo_depth:
                demand_ok = self.cfg.prefetch or (
                    ch.fill == 0 and not ch.ready and ch.issued == self.fired
                )
                if demand_ok:
                    reqs.append((self.in_addr(r, ch.issued), False, False, False))
                    kinds.append(r)
        for c, ch in enumerate(self.weights):
            if ch.issued < self.total_steps and ch.fill + len(ch.ready) < self.fifo_depth:
                demand_ok = self.cfg.prefetch or (
                    ch.fill == 0 and not ch.ready and ch.issued == self.fired
                )
                if demand_ok:
                    reqs.append((self.w_addr(c, ch.issued), False, False, self.w_super))
                    kinds.append(100 + c)
        psum_wants = spec.psum_in and self.psum_issued < self.psum_total and self.psum_pending == UMAX
        drained = self.fired >= self.total_steps and self.simd_queue == 0
        out_wants = self.out_bytes >= 8 or (drained and self.out_bytes > 0)
        if self.cfg.tmux_psum_output:
            psum_go, out_go = (True, False) if psum_wants else (False, out_wants)
        else:
            psum_go, out_go = psum_wants, out_wants
        if psum_go:
            reqs.append((spec.p_base + self.psum_issued, False, True, False))
            kinds.append(250)
        if out_go:
            reqs.append((spec.o_base + self.out_written_bytes // 8, True, False, False))
            kinds.append(251)

        if self.separate_ports:
            for i, (_, write, _, sb) in enumerate(reqs):
                kind = kinds[i]
                if kind <= 99:
                    ch = self.inputs[kind]
                    ch.issued += 1
                    ch.ready.append(self.cycle + self.cfg.mem_latency)
                elif kind <= 249:
                    ch = self.weights[kind - 100]
                    ch.issued += 1
                    ch.ready.append(self.cycle + self.cfg.mem_latency)
                elif kind == 250:
                    self.psum_issued += 1
                    self.psum_pending = self.cycle + self.cfg.mem_latency
                else:
                    chunk = min(self.out_bytes, 8)
                    self.out_written_bytes += chunk
                    self.out_bytes -= chunk
                    m["bank_writes"] += 1
                if not write:
                    m["bank_reads"] += 8 if sb else 1
        else:
            granted, denied, reads, writes = self.arbitrate(reqs)
            m["bank_reads"] += reads
            m["bank_writes"] += writes
            m["bank_conflicts"] += len(denied)
            for gi in granted:
                kind = kinds[gi]
                if kind <= 99:
                    ch = self.inputs[kind]
                    ch.issued += 1
                    ch.ready.append(self.cycle + self.cfg.mem_latency)
                elif kind <= 249:
                    ch = self.weights[kind - 100]
                    ch.issued += 1
                    ch.ready.append(self.cycle + self.cfg.mem_latency)
                elif kind == 250:
                    self.psum_issued += 1
                    self.psum_pending = self.cycle + self.cfg.mem_latency
                else:
                    chunk = min(self.out_bytes, 8)
                    self.out_written_bytes += chunk
                    self.out_bytes -= chunk

        self.cycle += 1

    def in_addr(self, r, s):
        if self.spec.input_blocked:
            return self.spec.in_base + s * self.n_in + r
        sub = s // self.ksteps
        ks = s % self.ksteps
        ti = sub // self.sub_n
        return self.spec.in_base + (ti * self.am + r) * self.row_stride_words + ks

    def w_addr(self, c, s):
        sub = s // self.ksteps
        ks = s % self.ksteps
        tj = sub % self.sub_n
        return self.spec.w_base + ((tj * self.ksteps + ks) * self.n_w_ch + c) * self.w_stride

    def finish(self):
        self.m["total_cycles"] = self.cycle
        return dict(self.m)

    # -- fast path -----------------------------------------------------
    def state_key(self):
        """Relative machine state at a subtile-row boundary."""
        row = self.fired // self.row_steps
        k = [self.mem_rr]
        for r in range(self.n_in):
            ch = self.inputs[r]
            k.append(ch.fill)
            k.append(ch.issued - self.fired)
            k.append(len(ch.ready))
            for t in ch.ready:
                k.append(t - self.cycle)
            k.append(-1 if ch.issued >= self.total_steps else self.in_addr(r, ch.issued) % self.nb)
        for c in range(self.n_w_ch):
            ch = self.weights[c]
            k.append(ch.fill)
            k.append(ch.issued - self.fired)
            k.append(len(ch.ready))
            for t in ch.ready:
                k.append(t - self.cycle)
            k.append(-1 if ch.issued >= self.total_steps else self.w_addr(c, ch.issued) % self.nb)
        # Psum stream state. The stream is a deterministic ramp (one
        # word per mem_latency cycles, always granted in arbitration
        # pass 1), so its absolute progress is NOT translation-invariant
        # across rows; instead of keying raw progress (which would only
        # ever match a perfectly paced stream) the key distinguishes
        # three regimes — absent, done, active — and `try_jump` proves
        # an active-stream jump sound via the unready counter + slack.
        if not self.spec.psum_in:
            k += (0, 0, -1, -1)
        elif self.psum_issued >= self.psum_total and self.psum_pending == UMAX:
            k += (-2, -2, -1, -1)  # stream complete: inert forever
        else:
            k.append(-3)  # stream active
            k.append(-1 if self.psum_pending == UMAX else self.psum_pending - self.cycle)
            k.append((self.spec.p_base + self.psum_issued) % self.nb)
            k.append(0)
        k.append(self.simd_queue)
        k.append(self.out_bytes)
        k.append((self.spec.o_base + self.out_written_bytes // 8) % self.nb)
        k.append(self.out_written_bytes % 8)
        return tuple(k)

    def marks(self, row):
        return (
            row,
            self.cycle,
            self.fired,
            tuple(self.inputs[r].issued for r in range(self.n_in)),
            tuple(c.issued for c in self.weights),
            self.psum_issued,
            self.psum_fill,
            self.out_written_bytes,
            tuple(self.m[f] for f in METRIC_FIELDS),
            self.psum_unready,
        )

    def try_jump(self, prev, row):
        p = row - prev[0]
        margin = self.fifo_depth // self.row_steps + 1
        landing_max = self.sub_m - margin
        if landing_max <= row:
            return 0
        n = (landing_max - row) // p
        if self.spec.psum_in and self.psum_issued < self.psum_total:
            # Active psum stream (key matched, so both marks are in the
            # active regime). The jump mirrors the observed period, so it
            # is sound only if (a) the stream never gated a fire in that
            # period, (b) its slack over the consumption threshold is
            # non-decreasing (then it keeps not gating), and (c) it
            # stays active through the whole jumped span (the ramp's
            # issue guard must not flip inside it).
            if self.psum_unready != prev[9]:
                return 0
            dpsum = self.psum_issued - prev[5]
            if dpsum < p * self.psum_row:
                return 0
            if dpsum > 0:
                n = min(n, (self.psum_total - 1 - self.psum_issued) // dpsum)
        if n <= 0:
            return 0
        dc = self.cycle - prev[1]
        self.cycle += n * dc
        self.fired += n * (self.fired - prev[2])
        for r in range(self.n_in):
            ch = self.inputs[r]
            ch.issued += n * (ch.issued - prev[3][r])
            ch.ready = deque(t + n * dc for t in ch.ready)
        for c, ch in enumerate(self.weights):
            ch.issued += n * (ch.issued - prev[4][c])
            ch.ready = deque(t + n * dc for t in ch.ready)
        self.psum_issued += n * (self.psum_issued - prev[5])
        self.psum_fill += n * (self.psum_fill - prev[6])
        if self.psum_pending != UMAX:
            self.psum_pending += n * dc
        self.out_written_bytes += n * (self.out_written_bytes - prev[7])
        for i, f in enumerate(METRIC_FIELDS):
            self.m[f] += n * (self.m[f] - prev[8][i])
        return n * p


def fast_path_eligible(cfg, spec):
    s = TileSim(cfg, spec)
    margin_io = s.fifo_depth // s.row_steps + 1
    return s.sub_m >= margin_io + 3


def simulate_tile_reference(cfg, spec):
    s = TileSim(cfg, spec)
    while not s.done() and s.cycle < s.max_cycles:
        s.cycle_once()
    return s.finish()


def simulate_tile_fast(cfg, spec):
    """Reference walk + row-recurrence jump. Returns (metrics, jumped_rows)."""
    s = TileSim(cfg, spec)
    snaps = {}
    last_marked = -1
    jumped = 0
    while not s.done() and s.cycle < s.max_cycles:
        if not jumped and s.fired % s.row_steps == 0:
            row = s.fired // s.row_steps
            if row > last_marked and row + 2 <= s.sub_m:
                last_marked = row
                key = s.state_key()
                prev = snaps.get(key)
                if prev is not None:
                    jumped = s.try_jump(prev, row)
                elif len(snaps) < SNAPSHOT_CAP:
                    snaps[key] = s.marks(row)
        s.cycle_once()
    return s.finish(), jumped


def simulate_tile(cfg, spec):
    if fast_path_eligible(cfg, spec):
        return simulate_tile_fast(cfg, spec)[0]
    return simulate_tile_reference(cfg, spec)


# ---------------------------------------------------------------- fuzz

class Lcg:
    """The same deterministic PRNG rust/tests/differential.rs uses."""

    def __init__(self, seed):
        self.s = seed & UMAX

    def next(self):
        self.s = (self.s * 6364136223846793005 + 1442695040888963407) & UMAX
        return self.s >> 33

    def below(self, n):
        return self.next() % n


def config_pool():
    return [
        ("voltra", Cfg()),
        ("no_prefetch", Cfg(prefetch=False)),
        ("separated", Cfg(separated=True)),
        ("array2d", Cfg(array=("2d", 16, 32))),
        ("simd64", Cfg(simd_lanes=64)),
        ("full_crossbar", Cfg(tmux_psum_output=False)),
        ("deep_fifo_slow_mem", Cfg(stream_fifo_depth=16, mem_latency=12)),
        ("banks16", Cfg(num_banks=16)),
    ]


def random_spec(rng, dim_cap=256):
    return Spec(
        tm=1 + rng.below(dim_cap),
        tk=1 + rng.below(dim_cap),
        tn=1 + rng.below(dim_cap),
        psum_in=rng.below(2) == 1,
        spill_out=rng.below(2) == 1,
        input_blocked=rng.below(4) != 0,
        fold=1 << rng.below(4),
        in_base=rng.below(2048),
        w_base=rng.below(2048),
        p_base=rng.below(2048),
        o_base=rng.below(2048),
    )


def check_one(name, cfg, spec):
    ref = simulate_tile_reference(cfg, spec)
    fast, jumped = simulate_tile_fast(cfg, spec)
    assert ref == fast, (
        f"fast path diverged on {name} tm={spec.tm} tk={spec.tk} tn={spec.tn} "
        f"psum={spec.psum_in} spill={spec.spill_out} blocked={spec.input_blocked} "
        f"fold={spec.fold} bases=({spec.in_base},{spec.w_base},{spec.p_base},{spec.o_base}) "
        f"jumped={jumped}\nref={ref}\nfast={fast}"
    )
    return jumped


def run_fuzz(samples, dim_cap, seed=0xC0FFEE):
    rng = Lcg(seed)
    pool = config_pool()
    jumped_total = specs_jumped = 0
    for i in range(samples):
        name, cfg = pool[rng.below(len(pool))]
        spec = random_spec(rng, dim_cap)
        j = check_one(name, cfg, spec)
        jumped_total += j
        specs_jumped += 1 if j else 0
    return specs_jumped, jumped_total


def test_fast_path_bit_identical_sample():
    # CI-sized: the Rust differential test runs the large-sample version.
    # dim_cap 128 is the smallest cap at which the random sample reliably
    # contains steady tiles deep enough to jump (row count > warm-up margin).
    jumped_specs, jumped_rows = run_fuzz(samples=120, dim_cap=128)
    assert jumped_specs > 0, "sample never exercised a jump"
    assert jumped_rows > 0


def test_eligibility_gates_small_tiles():
    cfg = Cfg()
    # One subtile row: nothing to recur over.
    assert not fast_path_eligible(cfg, Spec(8, 64, 64))
    # GEMV fold-8 collapses to a single row: ineligible by construction.
    assert not fast_path_eligible(cfg, Spec(1, 128, 256, fold=8))
    # Many rows: eligible.
    assert fast_path_eligible(cfg, Spec(64, 512, 64))


def test_fast_path_actually_jumps_on_steady_tiles():
    cfg = Cfg()
    spec = Spec(128, 256, 64)
    ref = simulate_tile_reference(cfg, spec)
    fast, jumped = simulate_tile_fast(cfg, spec)
    assert jumped > 0, "steady 16-row tile must find a recurrence"
    assert ref == fast


if __name__ == "__main__":
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 400
    cap = int(sys.argv[2]) if len(sys.argv) > 2 else 128
    js, jr = run_fuzz(samples=n, dim_cap=cap)
    print(f"OK: {n} specs bit-identical; {js} specs jumped ({jr} rows skipped)")
