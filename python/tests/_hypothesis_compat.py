"""Hypothesis, or a deterministic fallback when it is not installed.

The hermetic build image (see DESIGN.md: Substrate) has no package
index, so `hypothesis` may be absent. Property tests import `given`,
`settings` and `st` from this module: when hypothesis is installed they
get the real library; otherwise a tiny shim draws `max_examples`
seeded-deterministic samples per property, covering the same strategy
surface the suites use (integers, floats, booleans, sampled_from).
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:

    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=2**63 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, allow_nan=False, allow_infinity=False):
            del allow_nan, allow_infinity  # the shim never generates either
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(items):
            pool = list(items)
            return _Strategy(lambda rng: rng.choice(pool))

    st = _Strategies()

    def settings(max_examples=20, deadline=None, **_ignored):
        del deadline

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**kw_strategies):
        def deco(fn):
            def runner():
                n = getattr(runner, "_max_examples", 20)
                # Seeded per test name: failures reproduce exactly.
                rng = random.Random("voltra::" + fn.__name__)
                for _ in range(n):
                    kwargs = {k: s.draw(rng) for k, s in kw_strategies.items()}
                    fn(**kwargs)

            # No functools.wraps here: pytest must see a ZERO-argument
            # signature, or it would treat the property's parameters as
            # missing fixtures. Copy only the identity attributes.
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            # Honour @settings applied below @given (decorator order is
            # insensitive in real hypothesis): inherit, don't overwrite.
            runner._max_examples = getattr(fn, "_max_examples", 20)
            return runner

        return deco
