"""Layer-2 model graphs vs oracles: conv-as-im2col, MHA, LSTM, maxpool."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(7)


def rand_i8(shape, rng=RNG):
    return rng.integers(-128, 128, shape, dtype=np.int32)


# ---------------------------------------------------------------- im2col


def test_im2col_matches_ref():
    x = rand_i8((2, 7, 9, 3))
    got, dims = model.im2col(x, 3, 3, stride=1, padding="SAME")
    exp, dims2 = ref.im2col_ref(x, 3, 3, stride=1, padding="SAME")
    assert dims == dims2
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"), (1, "VALID"), (2, "VALID")])
def test_im2col_strided(stride, padding):
    x = rand_i8((1, 8, 8, 4))
    got, dims = model.im2col(x, 3, 3, stride=stride, padding=padding)
    exp, dims2 = ref.im2col_ref(x, 3, 3, stride=stride, padding=padding)
    assert dims == dims2
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


# ---------------------------------------------------------------- conv2d


@pytest.mark.parametrize(
    "n,h,w,c,kh,kw,f,stride",
    [
        (1, 8, 8, 16, 3, 3, 16, 1),
        (1, 16, 16, 8, 3, 3, 16, 2),
        (2, 7, 7, 4, 1, 1, 8, 1),   # pointwise (MobileNet)
        (1, 9, 9, 3, 5, 5, 8, 1),   # large kernel, ragged M
        (1, 8, 8, 8, 3, 3, 8, 2),   # strided downsample
    ],
)
def test_conv2d_im2col_matches_lax_conv(n, h, w, c, kh, kw, f, stride):
    """Implicit-im2col GEMM == lax.conv (then requant), both int32-exact."""
    x = rand_i8((n, h, w, c))
    wt = rand_i8((kh, kw, c, f))
    scale = np.array([0.01], np.float32)
    got = model.conv2d_im2col(x, wt, scale, stride=stride, padding="SAME")
    acc = ref.conv2d_ref(x, wt, stride=stride, padding="SAME")
    exp = ref.requant_ref(acc, 0.01)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(4, 10),
    c=st.integers(1, 8),
    f=st.integers(1, 12),
    kh=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_conv_sweep(h, c, f, kh, stride, seed):
    rng = np.random.default_rng(seed)
    x = rand_i8((1, h, h, c), rng)
    wt = rand_i8((kh, kh, c, f), rng)
    scale = np.array([0.05], np.float32)
    got = model.conv2d_im2col(x, wt, scale, stride=stride, padding="SAME")
    exp = ref.requant_ref(ref.conv2d_ref(x, wt, stride=stride, padding="SAME"), 0.05)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


# ---------------------------------------------------------------- MHA


def test_mha_head_matches_ref():
    t, d, dh = 16, 32, 16
    x = rand_i8((t, d))
    wq, wk, wv = rand_i8((d, dh)), rand_i8((d, dh)), rand_i8((d, dh))
    s_qkv = np.array([0.001], np.float32)
    s_attn = np.array([127.0], np.float32)
    got = model.mha_head(x, wq, wk, wv, s_qkv, s_attn)
    exp = ref.mha_head_ref(x, wq, wk, wv, 0.001, 127.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_mha_head_bert_geometry():
    """Fig. 4's exact shape: one BERT-Base head, token size 64."""
    t, d, dh = 64, 768, 64
    rng = np.random.default_rng(42)
    x = rand_i8((t, d), rng)
    wq, wk, wv = (rand_i8((d, dh), rng) for _ in range(3))
    s_qkv = np.array([0.0005], np.float32)
    s_attn = np.array([127.0], np.float32)
    got = model.mha_head(x, wq, wk, wv, s_qkv, s_attn)
    exp = ref.mha_head_ref(x, wq, wk, wv, 0.0005, 127.0)
    assert got.shape == (t, dh)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


# ---------------------------------------------------------------- LSTM


def test_lstm_cell_matches_ref():
    b, hidden = 8, 64
    rng = np.random.default_rng(3)
    x, h = rand_i8((b, hidden), rng), rand_i8((b, hidden), rng)
    c = rng.standard_normal((b, hidden)).astype(np.float32)
    wx, wh = rand_i8((hidden, 4 * hidden), rng), rand_i8((hidden, 4 * hidden), rng)
    bias = rng.standard_normal(4 * hidden).astype(np.float32)
    s = np.array([0.0002], np.float32)
    hq, cn = model.lstm_cell(x, h, c, wx, wh, bias, s)
    hq_ref, cn_ref = ref.lstm_cell_ref(x, h, c, wx, wh, bias, 0.0002)
    np.testing.assert_array_equal(np.asarray(hq), np.asarray(hq_ref))
    np.testing.assert_allclose(np.asarray(cn), np.asarray(cn_ref), rtol=1e-5, atol=1e-5)


def test_lstm_state_stays_bounded():
    """Recurrence invariant: |c| can't blow up when f,i in (0,1)."""
    b, hidden = 8, 16
    rng = np.random.default_rng(5)
    c = np.zeros((b, hidden), np.float32)
    h = np.zeros((b, hidden), np.int32)
    wx, wh = rand_i8((hidden, 4 * hidden), rng), rand_i8((hidden, 4 * hidden), rng)
    bias = np.zeros(4 * hidden, np.float32)
    s = np.array([0.001], np.float32)
    for step in range(10):
        x = rand_i8((b, hidden), rng)
        h, c = model.lstm_cell(x, h, c, wx, wh, bias, s)
        h = np.asarray(h)
        c = np.asarray(c)
        assert np.abs(c).max() <= step + 2  # |c_t| <= |c_{t-1}| + 1
        assert np.abs(h).max() <= 127


# ---------------------------------------------------------------- maxpool


def test_maxpool_nhwc():
    x = rand_i8((2, 8, 8, 4))
    got = model.maxpool2d(x, window=2, stride=2)
    xc = np.transpose(np.asarray(x), (0, 3, 1, 2)).reshape(8, 8, 8)
    exp = np.asarray(ref.maxpool2d_ref(xc, 2, 2))  # (2*4, 4, 4)
    exp_nhwc = np.transpose(exp.reshape(2, 4, 4, 4), (0, 2, 3, 1))
    np.testing.assert_array_equal(np.asarray(got), exp_nhwc)


# ---------------------------------------------------------------- tiles


def test_pick_tile_divides_and_aligns():
    for dim in [8, 16, 24, 40, 64, 96, 128, 256, 768]:
        t = model._pick_tile(dim, 32)
        assert t % 8 == 0
        assert dim % t == 0 or t == 8
