//! Vendored stand-in for the `anyhow` crate.
//!
//! Substrate note (DESIGN.md §Substrate): the build image has no
//! crates.io access, so this path crate provides the subset of the
//! anyhow API the workspace uses — `Error`, `Result`, `anyhow!`,
//! `bail!` and the `Context` extension trait — with the same semantics:
//!
//! * `{e}` displays the outermost message/context;
//! * `{e:#}` displays the whole context chain joined by `": "`;
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`;
//! * `Error` itself deliberately does NOT implement `std::error::Error`
//!   (exactly like real anyhow, which is what makes the blanket `From`
//!   impl coherent).

use std::fmt;

/// A dynamic error: an ordered context chain, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error {
            chain: vec![m.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`, with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = io_err().into();
        let e = e.context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: no such file");
    }

    #[test]
    fn context_trait_on_results_and_options() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "step 3");
        let n: Option<u32> = None;
        let e = n.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero is not allowed (got {x})");
            }
            Err(anyhow!("value {} rejected", x))
        }
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero is not allowed (got 0)");
        assert_eq!(format!("{}", f(7).unwrap_err()), "value 7 rejected");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<String> {
            let s = std::str::from_utf8(&[0xFF])?;
            Ok(s.to_string())
        }
        assert!(g().is_err());
    }

    #[test]
    fn error_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
