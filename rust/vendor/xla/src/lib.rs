//! Vendored stand-in for the `xla` crate (xla-rs PJRT bindings).
//!
//! Substrate note (DESIGN.md §Substrate): the build image ships neither
//! the native XLA/PJRT shared libraries nor crates.io access, so this
//! path crate keeps the workspace compiling against the exact API
//! surface `voltra::runtime` uses. The [`Literal`] container is fully
//! functional (typed buffer + shape, reshape/to_vec round-trips); the
//! PJRT client/executable surface compiles but reports at *runtime*
//! that the native backend is unavailable — `ArtifactLib::load` then
//! fails cleanly and every artifact-dependent path (tests, examples,
//! the serving engine's numerics worker) falls back or skips, exactly
//! as on a machine without `make artifacts`.
//!
//! Swapping the real binding back in is a one-line Cargo.toml change;
//! no source file mentions the stub.

use std::fmt;

/// Error type mirroring xla-rs's: displayable, a real `std::error::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT native runtime is not available in this build (vendored xla stub; \
         swap in the real xla crate in rust/Cargo.toml to execute AOT artifacts)"
            .to_string(),
    ))
}

/// Element types the manifest declares (int8 values ride in i32).
#[derive(Clone, Debug, PartialEq)]
enum Buf {
    I32(Vec<i32>),
    F32(Vec<f32>),
    Tuple(Vec<Literal>),
}

/// Marker trait for element types a [`Literal`] can hold.
pub trait NativeType: Sized + Copy {
    fn wrap(v: Vec<Self>) -> Buf;
    fn unwrap(b: &Buf) -> Option<Vec<Self>>;
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Buf {
        Buf::I32(v)
    }
    fn unwrap(b: &Buf) -> Option<Vec<Self>> {
        match b {
            Buf::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Buf {
        Buf::F32(v)
    }
    fn unwrap(b: &Buf) -> Option<Vec<Self>> {
        match b {
            Buf::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host-side tensor value: typed buffer + shape. Fully functional.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    buf: Buf,
    dims: Vec<i64>,
}

impl Literal {
    /// A rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            buf: T::wrap(v.to_vec()),
            dims: vec![v.len() as i64],
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal {
            buf: self.buf.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn element_count(&self) -> usize {
        match &self.buf {
            Buf::I32(v) => v.len(),
            Buf::F32(v) => v.len(),
            Buf::Tuple(t) => t.iter().map(Literal::element_count).sum(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the buffer out as `Vec<T>`; errors on a dtype mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.buf).ok_or_else(|| Error("literal dtype mismatch in to_vec".to_string()))
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.buf {
            Buf::Tuple(t) => Ok(t),
            _ => Err(Error("literal is not a tuple".to_string())),
        }
    }

    /// Build a tuple literal (used by tests / future host backends).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        let n = elems.len() as i64;
        Literal {
            buf: Buf::Tuple(elems),
            dims: vec![n],
        }
    }
}

/// Parsed HLO module handle. The stub never parses: construction fails.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable()
    }
}

/// Computation handle wrapping a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle. `cpu()` reports the backend as unavailable.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device buffer handle returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_i32_and_f32() {
        let l = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]);
        assert_eq!(l.element_count(), 6);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(r.to_vec::<f32>().is_err());
        let f = Literal::vec1(&[0.5f32]);
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![0.5]);
    }

    #[test]
    fn reshape_rejects_bad_counts() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.reshape(&[2, 2]).is_err());
        assert!(l.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn tuple_destructures() {
        let t = Literal::tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2.0f32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::vec1(&[1i32]).to_tuple().is_err());
    }

    #[test]
    fn pjrt_surface_reports_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e}").contains("not available"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
