//! Differential fuzz of the steady-state fast path (DESIGN.md §12).
//!
//! The row-recurrence jump in `sim::engine` must be *bit-identical* to
//! the per-cycle reference walk — not approximately right, identical in
//! every `TileMetrics` field — wherever the eligibility predicate lets
//! it run, and ineligible specs must take the reference fallback. The
//! generator, PRNG and config pool mirror the Python oracle
//! (`python/tests/test_fastpath_differential.py`) line for line, so the
//! same seed exercises the same `(config, spec)` stream in both
//! languages.

use voltra::config::ChipConfig;
use voltra::sim::{
    fast_path_eligible, simulate_tile, simulate_tile_fast, simulate_tile_reference, TileSpec,
};

/// The deterministic PRNG shared with the Python oracle: a 64-bit LCG
/// (Knuth's MMIX multiplier) whose top bits are the output.
struct Lcg {
    s: u64,
}

impl Lcg {
    fn new(seed: u64) -> Self {
        Lcg { s: seed }
    }

    fn next(&mut self) -> u64 {
        self.s = self
            .s
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.s >> 33
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

/// Every config axis the tile engine reads: memory org, array geometry,
/// prefetch, SIMD width, crossbar discipline, FIFO depth x latency and
/// bank count.
fn config_pool() -> Vec<(&'static str, ChipConfig)> {
    let mut deep_fifo_slow_mem = ChipConfig::voltra();
    deep_fifo_slow_mem.stream_fifo_depth = 16;
    deep_fifo_slow_mem.mem_latency = 12;
    let mut banks16 = ChipConfig::voltra();
    banks16.num_banks = 16;
    vec![
        ("voltra", ChipConfig::voltra()),
        ("no_prefetch", ChipConfig::no_prefetch()),
        ("separated", ChipConfig::separated_memory()),
        ("array2d", ChipConfig::array2d()),
        ("simd64", ChipConfig::simd64()),
        ("full_crossbar", ChipConfig::full_crossbar()),
        ("deep_fifo_slow_mem", deep_fifo_slow_mem),
        ("banks16", banks16),
    ]
}

/// Random spec: dims 1..=dim_cap, every psum/spill/layout combination,
/// folds 1/2/4/8, arbitrary region bases (bank alignment is part of the
/// search space — collisions change the arbitration pattern).
fn random_spec(rng: &mut Lcg, dim_cap: u64) -> TileSpec {
    TileSpec {
        tm: 1 + rng.below(dim_cap),
        tk: 1 + rng.below(dim_cap),
        tn: 1 + rng.below(dim_cap),
        psum_in: rng.below(2) == 1,
        spill_out: rng.below(2) == 1,
        input_blocked: rng.below(4) != 0,
        fold: 1u8 << rng.below(4),
        in_base: rng.below(2048),
        w_base: rng.below(2048),
        p_base: rng.below(2048),
        o_base: rng.below(2048),
    }
}

/// One differential probe; returns the rows the fast path jumped.
fn check_one(name: &str, cfg: &ChipConfig, spec: &TileSpec) -> u64 {
    let refm = simulate_tile_reference(cfg, spec);
    let (fast, jumped) = simulate_tile_fast(cfg, spec);
    assert_eq!(
        refm, fast,
        "fast path diverged on {name} spec={spec:?} jumped={jumped}"
    );
    // The dispatcher must agree with both sides of its own branch.
    assert_eq!(simulate_tile(cfg, spec), refm, "{name} dispatcher diverged");
    jumped
}

/// Shared fuzz driver (the Python oracle's `run_fuzz`, same sampling
/// order): returns (specs that jumped, total rows jumped, ineligible
/// specs seen).
fn run_fuzz(samples: u64, dim_cap: u64, seed: u64) -> (u64, u64, u64) {
    let mut rng = Lcg::new(seed);
    let pool = config_pool();
    let mut specs_jumped = 0u64;
    let mut rows_jumped = 0u64;
    let mut ineligible = 0u64;
    for _ in 0..samples {
        let (name, cfg) = &pool[rng.below(pool.len() as u64) as usize];
        let spec = random_spec(&mut rng, dim_cap);
        let j = check_one(name, cfg, &spec);
        rows_jumped += j;
        if j > 0 {
            specs_jumped += 1;
        }
        // Ineligible specs are counted, not asserted jump-free: the
        // predicate is deliberately one row more conservative than the
        // jump's own landing guard. What matters — the dispatcher taking
        // the reference walk for them — is pinned inside `check_one`.
        if !fast_path_eligible(cfg, &spec) {
            ineligible += 1;
        }
    }
    (specs_jumped, rows_jumped, ineligible)
}

#[test]
fn fast_path_is_bit_identical_under_fuzz() {
    // Debug builds (the plain CI test leg) run the Python-oracle-sized
    // sample; release builds (the `--release` CI leg) run the full
    // dims-to-256 soak. dim_cap 128 is the smallest cap at which the
    // random sample reliably contains steady tiles deep enough to jump.
    let (samples, dim_cap) = if cfg!(debug_assertions) {
        (120, 128)
    } else {
        (400, 256)
    };
    let (specs_jumped, rows_jumped, ineligible) = run_fuzz(samples, dim_cap, 0xC0FFEE);
    assert!(specs_jumped > 0, "sample never exercised a jump");
    assert!(rows_jumped > 0);
    assert!(
        ineligible > 0,
        "sample never exercised the ineligible fallback"
    );
}

#[test]
fn eligibility_gates_and_fallback_agree() {
    let cfg = ChipConfig::voltra();
    // One subtile row: nothing to recur over.
    assert!(!fast_path_eligible(&cfg, &TileSpec::simple(8, 64, 64)));
    // GEMV fold-8 collapses to a single row: ineligible by construction.
    assert!(!fast_path_eligible(&cfg, &TileSpec::folded(1, 128, 256, 8)));
    // Many rows: eligible.
    assert!(fast_path_eligible(&cfg, &TileSpec::simple(64, 512, 64)));
    for spec in [TileSpec::simple(8, 64, 64), TileSpec::folded(1, 128, 256, 8)] {
        assert_eq!(
            simulate_tile(&cfg, &spec),
            simulate_tile_reference(&cfg, &spec),
            "ineligible spec must take the reference walk"
        );
    }
}

#[test]
fn steady_suite_tiles_jump_and_match() {
    // The planner-realistic shapes the cold-plan bench budget leans on:
    // these must not silently regress to the walked path.
    let cfg = ChipConfig::voltra();
    for (tm, tk, tn) in [(128, 256, 64), (128, 512, 64), (128, 1024, 128)] {
        let spec = TileSpec::simple(tm, tk, tn);
        let refm = simulate_tile_reference(&cfg, &spec);
        let (fast, jumped) = simulate_tile_fast(&cfg, &spec);
        assert_eq!(refm, fast, "{tm}x{tk}x{tn}");
        assert!(jumped > 0, "{tm}x{tk}x{tn}: steady tile must jump");
    }
}
