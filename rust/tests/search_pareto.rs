//! Pins the shipped chip as Pareto-optimal within its one-step search
//! neighborhood (DESIGN.md §15) — the design-point acceptance test of
//! the co-search: no config reachable by moving a single axis (banks
//! 16/64, FIFO depth 4/16, 2D array, separated memory) may dominate
//! the fabricated design on all three score axes simultaneously.
//!
//! Each neighbor loses somewhere by construction of the model:
//! smaller fabrics (16 banks, depth-4 FIFOs) win TOPS/mm² but pay
//! latency (bank conflicts / the depth-8 knee of `ablation_arch`);
//! bigger fabrics (64 banks, depth-16 FIFOs) can win latency but pay
//! area; the 2D array and separated memory pay utilization and DMA
//! serialization at equal area. This test keeps that argument true as
//! the model evolves.
//!
//! Debug builds score a three-workload subset (the verifier checks
//! every compiled plan at insert, so the full suite is slow there);
//! the release leg scores all eight.

use voltra::config::ChipConfig;
use voltra::search;
use voltra::tiling::mapper::MapperCache;
use voltra::tiling::IncrementalMapper;
use voltra::workloads::{self, Workload};
use voltra::PlanCache;

fn suite() -> Vec<Workload> {
    if cfg!(debug_assertions) {
        ["resnet50", "bert", "llama-prefill"]
            .iter()
            .map(|n| workloads::by_name(n).expect("suite workload"))
            .collect()
    } else {
        workloads::evaluation_suite()
    }
}

#[test]
fn no_one_step_neighbor_dominates_the_shipped_config() {
    let suite = suite();
    let plans = PlanCache::new();
    let mappers = MapperCache::new();
    let mut im = IncrementalMapper::new(&mappers);
    let shipped = search::score_config(
        "3d8x8x8/b32/f8/shared",
        &ChipConfig::voltra(),
        &suite,
        &plans,
        &mut im,
    );
    let mut all = vec![shipped];
    for (label, cfg) in search::one_step_neighbors() {
        let p = search::score_config(&label, &cfg, &suite, &plans, &mut im);
        all.push(p);
    }
    for n in &all[1..] {
        assert!(
            !search::dominates(n, &all[0]),
            "{} dominates the shipped config: \
             latency {} vs {} cyc, {:.3} vs {:.3} TOPS/W, {:.3} vs {:.3} TOPS/mm^2",
            n.label,
            n.suite_latency_cycles,
            all[0].suite_latency_cycles,
            n.tops_per_watt,
            all[0].tops_per_watt,
            n.tops_per_mm2,
            all[0].tops_per_mm2,
        );
    }
    search::mark_pareto(&mut all);
    assert!(
        all[0].pareto,
        "the shipped config must sit on the neighborhood's Pareto frontier"
    );
}
