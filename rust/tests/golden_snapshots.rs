//! Golden per-layer snapshots of the planning + scheduling model.
//!
//! Every layer of all eight Fig. 6 suite workloads, planned and executed
//! under three presets (the full chip, the separated-memory baseline and
//! the swap-only mapper ablation), is serialized field-exactly to
//! `tests/golden/<preset>.json` and compared against the checked-in
//! snapshot through the runtime's own JSON parser. Any model change that
//! shifts a single cycle, byte or MAC in any layer shows up as a diff of
//! the specific field — the safety net under refactors like the
//! steady-state fast path (DESIGN.md §12), which must change *nothing*
//! here.
//!
//! Bless protocol: a missing snapshot file is written and the test
//! passes (bootstrap); set `GOLDEN_BLESS=1` to intentionally regenerate
//! after a reviewed model change. Mismatches print the first divergent
//! workload/layer/field.

use std::path::PathBuf;

use voltra::config::ChipConfig;
use voltra::metrics::LayerMetrics;
use voltra::plan::PlanCache;
use voltra::runtime::json::{self, Json};
use voltra::workloads::evaluation_suite;

fn presets() -> Vec<(&'static str, ChipConfig)> {
    vec![
        ("voltra", ChipConfig::voltra()),
        ("separated", ChipConfig::separated_memory()),
        ("swap_only", ChipConfig::swap_only()),
    ]
}

fn num(v: u64) -> Json {
    // Json numbers are f64: every counter in the model stays far below
    // 2^53, so the round trip is exact (guarded here).
    assert!(v < (1u64 << 53), "counter {v} would lose precision in JSON");
    Json::Num(v as f64)
}

fn layer_json(l: &LayerMetrics) -> Json {
    let mut tiles = std::collections::BTreeMap::new();
    tiles.insert("total_cycles".into(), num(l.tiles.total_cycles));
    tiles.insert("active_cycles".into(), num(l.tiles.active_cycles));
    tiles.insert("useful_macs".into(), num(l.tiles.useful_macs));
    tiles.insert("offered_macs".into(), num(l.tiles.offered_macs));
    tiles.insert("bank_reads".into(), num(l.tiles.bank_reads));
    tiles.insert("bank_writes".into(), num(l.tiles.bank_writes));
    tiles.insert("bank_conflicts".into(), num(l.tiles.bank_conflicts));
    tiles.insert("stall_cycles".into(), num(l.tiles.stall_cycles));
    tiles.insert("simd_cycles".into(), num(l.tiles.simd_cycles));
    tiles.insert("fifo_events".into(), num(l.tiles.fifo_events));
    let mut m = std::collections::BTreeMap::new();
    m.insert("name".into(), Json::Str(l.name.clone()));
    m.insert("mapping".into(), Json::Str(l.mapping.clone()));
    m.insert("tiles".into(), Json::Obj(tiles));
    m.insert("dma_bytes".into(), num(l.dma_bytes));
    m.insert("dma_cycles".into(), num(l.dma_cycles));
    m.insert("latency_cycles".into(), num(l.latency_cycles));
    m.insert("overlap_cycles".into(), num(l.overlap_cycles));
    m.insert("aux_cycles".into(), num(l.aux_cycles));
    m.insert("chained_bytes".into(), num(l.chained_bytes));
    m.insert("tile_footprint_bytes".into(), num(l.tile_footprint_bytes));
    m.insert("macs".into(), num(l.macs));
    Json::Obj(m)
}

/// Serialize with stable key order (BTreeMap) and integer-exact numbers
/// — the writer half the runtime's parser never needed until now.
fn write_json(j: &Json, out: &mut String, indent: usize) {
    let pad = "  ".repeat(indent);
    match j {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < (1u64 << 53) as f64 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push_str("[\n");
            for (i, v) in a.iter().enumerate() {
                out.push_str(&pad);
                out.push_str("  ");
                write_json(v, out, indent + 1);
                if i + 1 < a.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&pad);
            out.push(']');
        }
        Json::Obj(m) => {
            if m.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push_str("{\n");
            for (i, (k, v)) in m.iter().enumerate() {
                out.push_str(&pad);
                out.push_str(&format!("  \"{k}\": "));
                write_json(v, out, indent + 1);
                if i + 1 < m.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&pad);
            out.push('}');
        }
    }
}

fn render(j: &Json) -> String {
    let mut s = String::new();
    write_json(j, &mut s, 0);
    s.push('\n');
    s
}

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Compare two Json trees, reporting the path of the first divergence.
fn diff(path: &str, a: &Json, b: &Json) -> Option<String> {
    match (a, b) {
        (Json::Obj(ma), Json::Obj(mb)) => {
            for k in ma.keys().chain(mb.keys()) {
                match (ma.get(k), mb.get(k)) {
                    (Some(va), Some(vb)) => {
                        if let Some(d) = diff(&format!("{path}.{k}"), va, vb) {
                            return Some(d);
                        }
                    }
                    _ => return Some(format!("{path}.{k}: present on one side only")),
                }
            }
            None
        }
        (Json::Arr(aa), Json::Arr(ab)) => {
            if aa.len() != ab.len() {
                return Some(format!("{path}: length {} vs {}", aa.len(), ab.len()));
            }
            for (i, (va, vb)) in aa.iter().zip(ab).enumerate() {
                if let Some(d) = diff(&format!("{path}[{i}]"), va, vb) {
                    return Some(d);
                }
            }
            None
        }
        _ => {
            if a == b {
                None
            } else {
                Some(format!("{path}: golden {a:?} vs current {b:?}"))
            }
        }
    }
}

#[test]
fn per_layer_metrics_match_golden_snapshots() {
    let plans = PlanCache::new();
    for (preset, cfg) in presets() {
        let mut workloads = std::collections::BTreeMap::new();
        for w in evaluation_suite() {
            let report = plans.run(&cfg, &w);
            let layers: Vec<Json> = report.metrics.layers.iter().map(layer_json).collect();
            workloads.insert(w.name.clone(), Json::Arr(layers));
        }
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("config".into(), Json::Str(preset.into()));
        doc.insert("workloads".into(), Json::Obj(workloads));
        let current = Json::Obj(doc);

        let path = golden_dir().join(format!("{preset}.json"));
        let bless = std::env::var("GOLDEN_BLESS").map(|v| v == "1").unwrap_or(false);
        if bless || !path.exists() {
            std::fs::create_dir_all(golden_dir()).expect("create tests/golden");
            std::fs::write(&path, render(&current)).expect("write golden snapshot");
            eprintln!("blessed golden snapshot {}", path.display());
            continue;
        }
        let text = std::fs::read_to_string(&path).expect("read golden snapshot");
        let golden = json::parse(&text).unwrap_or_else(|e| {
            panic!("golden snapshot {} is not valid JSON: {e}", path.display())
        });
        if let Some(d) = diff(preset, &golden, &current) {
            panic!(
                "golden snapshot mismatch ({}): {d}\n\
                 If the model change is intentional and reviewed, regenerate with \
                 GOLDEN_BLESS=1 cargo test --test golden_snapshots",
                path.display()
            );
        }
    }
}

#[test]
fn golden_writer_round_trips_through_the_parser() {
    // The snapshot only protects what the parser can faithfully read
    // back: pin the writer/parser round trip on a representative layer.
    let l = LayerMetrics {
        name: "conv_1x1 \"edge\"".into(),
        mapping: "8x8x8+1x8x64T".into(),
        tiles: Default::default(),
        dma_bytes: 123_456_789_012,
        dma_cycles: 42,
        latency_cycles: 7,
        overlap_cycles: 0,
        aux_cycles: 9,
        chained_bytes: 1,
        tile_footprint_bytes: 131072,
        macs: u64::MAX >> 12,
    };
    let j = layer_json(&l);
    let parsed = json::parse(&render(&j)).expect("writer output must parse");
    assert_eq!(parsed, j);
    assert!(diff("layer", &j, &parsed).is_none());
}
