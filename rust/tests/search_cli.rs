//! End-to-end test of `voltra search --json` (DESIGN.md §15).
//!
//! One CLI invocation over the quick grid: the machine-readable output
//! must parse through the runtime's own JSON parser, carry the
//! documented schema, and match the golden snapshot byte-for-byte —
//! the search scores are pure functions of (config, workload), so the
//! whole document is deterministic across thread counts and profiles.
//!
//! Bless protocol (as `tests/golden_snapshots.rs`): a missing snapshot
//! is written and the test passes (bootstrap); set `GOLDEN_BLESS=1` to
//! intentionally regenerate after a reviewed model change.

use std::path::PathBuf;
use std::process::Command;

use voltra::runtime::json::{self, Json};

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/search_quick.json")
}

#[test]
fn search_quick_json_matches_schema_and_golden() {
    let out = Command::new(env!("CARGO_BIN_EXE_voltra"))
        .args(["search", "--grid", "quick", "--json"])
        .output()
        .expect("spawn voltra binary");
    assert!(out.status.success(), "search exit: {out:?}");
    let text = String::from_utf8(out.stdout).expect("search output must be UTF-8");
    let doc = json::parse(&text).expect("search --json must parse");

    // Schema: top-level fields.
    assert_eq!(doc.get("grid").and_then(Json::as_str), Some("quick"));
    assert_eq!(doc.get("points").and_then(Json::as_usize), Some(6));
    assert_eq!(
        doc.get("shipped").and_then(Json::as_str),
        Some("3d8x8x8/b32/f8/shared"),
        "the shipped chip must appear as one grid point"
    );
    let tile_classes = doc.get("tile_classes").and_then(Json::as_usize).unwrap();
    let mapper_classes = doc.get("mapper_classes").and_then(Json::as_usize).unwrap();
    assert!(
        tile_classes < 6,
        "structural keying must collapse the quick grid ({tile_classes} classes)"
    );
    assert!(mapper_classes < 6, "got {mapper_classes} mapper classes");

    // Schema: per-point records.
    let results = doc.get("results").and_then(Json::as_arr).unwrap();
    assert_eq!(results.len(), 6);
    let frontier = doc.get("frontier").and_then(Json::as_arr).unwrap();
    assert!(!frontier.is_empty(), "a finite grid always has a frontier");
    let mut shipped_seen = false;
    for p in results {
        for key in [
            "label",
            "geometry",
            "banks",
            "fifo_depth",
            "memory",
            "area_mm2",
            "suite_latency_cycles",
            "suite_energy_mj",
            "tops_per_watt",
            "tops_per_mm2",
            "pareto",
            "shipped",
        ] {
            assert!(p.get(key).is_some(), "point missing {key}: {p:?}");
        }
        assert!(p.get("area_mm2").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(p.get("tops_per_watt").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(
            p.get("suite_latency_cycles")
                .and_then(Json::as_usize)
                .unwrap()
                > 0
        );
        if p.get("shipped") == Some(&Json::Bool(true)) {
            shipped_seen = true;
            assert_eq!(
                p.get("label").and_then(Json::as_str),
                Some("3d8x8x8/b32/f8/shared")
            );
        }
    }
    assert!(shipped_seen, "exactly the shipped point carries the flag");

    // Golden comparison: byte-exact, cross-profile (debug blesses on
    // first run, the release leg then compares — a determinism check).
    let path = golden_path();
    let bless = std::env::var("GOLDEN_BLESS").map(|v| v == "1").unwrap_or(false);
    if bless || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).expect("create tests/golden");
        std::fs::write(&path, &text).expect("write golden search snapshot");
        eprintln!("blessed golden snapshot {}", path.display());
        return;
    }
    let golden = std::fs::read_to_string(&path).expect("read golden search snapshot");
    assert_eq!(
        golden, text,
        "search --json diverged from {}; if the model change is intentional \
         and reviewed, regenerate with GOLDEN_BLESS=1 cargo test --test search_cli",
        path.display()
    );
}
