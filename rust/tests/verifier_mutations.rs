//! Mutation rig for the plan verifier (`plan::verify`, DESIGN.md §13).
//!
//! A static verifier that has never seen a broken plan is just comments:
//! this rig seeds single-field corruptions into known-good plans and
//! asserts every one is caught with its expected rule id — plus the dual
//! obligation, zero false positives on every clean plan the evaluation
//! suite can produce (8 workloads x 3 memory/mapping presets).

use std::collections::BTreeSet;

use voltra::config::{ArrayGeometry, ChipConfig};
use voltra::plan::{self, verify, WorkloadPlan};
use voltra::workloads::{self, Workload};
use voltra::TileCache;

fn built(cfg: &ChipConfig, name: &str) -> (Workload, WorkloadPlan) {
    let w = workloads::by_name(name).expect("suite workload");
    let mut cache = TileCache::new();
    let p = plan::build(cfg, &w, &mut cache);
    (w, p)
}

/// Every plan the suite can produce must verify clean: a verifier that
/// cries wolf on valid plans would turn the CI gate into noise.
#[test]
fn suite_plans_have_zero_false_positives() {
    let presets = [
        ("voltra", ChipConfig::voltra()),
        ("separated", ChipConfig::separated_memory()),
        ("swap-only", ChipConfig::swap_only()),
    ];
    for (preset, cfg) in presets {
        for w in workloads::evaluation_suite() {
            let mut cache = TileCache::new();
            let p = plan::build(&cfg, &w, &mut cache);
            let f = verify(&cfg, &w, &p);
            assert!(
                f.is_empty(),
                "false positive(s) on {preset}/{}:\n{}",
                w.name,
                plan::verify::render(&f)
            );
        }
    }
}

/// Seed ~20 single-field corruptions into a clean plan and assert each
/// one surfaces its expected rule — and that together they exercise at
/// least 12 distinct invariant classes.
#[test]
fn every_seeded_corruption_is_caught() {
    let cfg = ChipConfig::voltra();
    // llama-decode: many layers, folded GEMV mappings, and (asserted in
    // the residency unit tests) chained projection layers — every rule
    // in the catalog has something real to bite on.
    let (w, base) = built(&cfg, "llama-decode");
    assert!(
        verify(&cfg, &w, &base).is_empty(),
        "the mutation base plan must start clean"
    );

    let mut caught: BTreeSet<&'static str> = BTreeSet::new();
    let mut check = |label: &str, rule: &'static str, mutate: fn(&mut WorkloadPlan)| {
        let mut p = base.clone();
        mutate(&mut p);
        let f = verify(&cfg, &w, &p);
        assert!(!f.is_empty(), "{label}: seeded corruption went undetected");
        assert!(
            f.iter().any(|x| x.rule == rule),
            "{label}: expected rule '{rule}', got:\n{}",
            plan::verify::render(&f)
        );
        caught.insert(rule);
    };

    // Plan-level identity.
    check("fingerprint-xor", "plan-fingerprint", |p| p.fingerprint ^= 1);
    check("workload-rename", "plan-shape", |p| p.workload.push('x'));
    check("layer-rename", "plan-shape", |p| p.layers[0].name.push('x'));
    check("plan-total-tiles", "plan-shape", |p| p.dispatched_tiles += 1);
    check("layer-dropped", "plan-shape", |p| {
        p.layers.pop();
    });

    // MAC + tile-activity conservation.
    check("macs-plus-one", "mac-conservation", |p| p.layers[0].macs += 1);
    check("useful-macs", "mac-conservation", |p| {
        p.layers[0].tiles.useful_macs += 1
    });
    check("offered-macs", "tile-activity", |p| {
        p.layers[0].tiles.offered_macs += 1
    });
    check("active-cycles", "tile-activity", |p| {
        p.layers[0].tiles.active_cycles += 1
    });

    // Tile population + DMA accounting.
    check("run-count", "tile-population", |p| {
        p.layers[0].timeline.gemms[0].runs[0].count += 1
    });
    check("layer-tiles", "tile-population", |p| {
        p.layers[0].dispatched_tiles += 1
    });
    check("run-dma-share", "dma-cycle-attribution", |p| {
        p.layers[0].timeline.gemms[0].runs[0].dma_cycles += 1
    });
    check("dma-bytes", "dma-byte-conservation", |p| {
        p.layers[0].dma_bytes += 1
    });
    check("dma-cycles", "dma-cycle-envelope", |p| p.layers[0].dma_cycles += 1);

    // Footprint + mapping legality.
    check("footprint", "footprint-capacity", |p| {
        p.layers[0].tile_footprint_bytes += 1
    });
    check("fold-illegal", "mapping-legality", |p| {
        p.layers[0].mappings[0].fold = 3
    });
    check("swap-flip", "mapping-legality", |p| {
        p.layers[0].mappings[0].swapped = !p.layers[0].mappings[0].swapped
    });
    check("geometry-inflated", "stream-demand-bounds", |p| {
        // 64 array rows demand 64 fine input channels; the fabric has 8.
        p.layers[0].mappings[0].geometry = ArrayGeometry::Spatial3D { m: 64, n: 8, k: 8 }
    });

    // Pipeline schedule + aux accounting.
    check("pingpong-flip", "pingpong-exclusivity", |p| {
        let db = &mut p.layers[0].timeline.gemms[0].double_buffered;
        *db = !*db;
    });
    check("latency", "schedule-consistency", |p| {
        p.layers[0].latency_cycles += 1
    });
    check("overlap", "schedule-consistency", |p| {
        p.layers[0].overlap_cycles += 1
    });
    check("tile-cycles", "schedule-consistency", |p| {
        p.layers[0].tiles.total_cycles += 1
    });
    check("aux-cycles", "aux-accounting", |p| p.layers[0].aux_cycles += 1);
    check("reshuffle", "aux-accounting", |p| {
        p.layers[0].timeline.reshuffle_cycles += 1
    });

    // Residency replay (llama-decode chains its projection layers).
    check("chained-bytes", "residency-legality", |p| {
        p.layers[1].residency.chained_bytes += 1
    });
    check("saved-bytes", "residency-legality", |p| {
        p.layers[1].residency.saved_dma_bytes += 1
    });
    check("resident-out", "residency-legality", |p| {
        p.layers[0].residency.resident_out_bytes += 1
    });

    assert!(
        caught.len() >= 12,
        "mutations must exercise >= 12 invariant classes, got {}: {caught:?}",
        caught.len()
    );
}

/// The config-side rules: a plan presented under a config it was not
/// compiled for, or under a config describing unrealizable hardware,
/// must be rejected before any layer math is trusted.
#[test]
fn config_corruptions_are_caught() {
    let cfg = ChipConfig::voltra();
    let (w, p) = built(&cfg, "lstm");

    let mut zero_fifo = ChipConfig::voltra();
    zero_fifo.stream_fifo_depth = 0;
    let f = verify(&zero_fifo, &w, &p);
    assert!(f.iter().any(|x| x.rule == "fifo-depth"), "{f:?}");
    // A different config also means a different fingerprint.
    assert!(f.iter().any(|x| x.rule == "plan-fingerprint"), "{f:?}");

    let mut zero_dma = ChipConfig::voltra();
    zero_dma.dma_bytes_per_cycle = 0;
    let f = verify(&zero_dma, &w, &p);
    assert!(f.iter().any(|x| x.rule == "config-legality"), "{f:?}");

    // Cross-preset plan reuse: the exact bug PlanCache keying prevents.
    let separated = ChipConfig::separated_memory();
    let f = verify(&separated, &w, &p);
    assert!(f.iter().any(|x| x.rule == "plan-fingerprint"), "{f:?}");
}
