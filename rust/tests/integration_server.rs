//! End-to-end serving test: client threads talk to the single-threaded
//! coordinator server over a real TCP socket; responses carry both the
//! PJRT-computed checksum and the chip model's cost estimate.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use voltra::config::ChipConfig;
use voltra::coordinator::server::{bind, serve_blocking};
use voltra::runtime::{default_dir, ArtifactLib};

#[test]
fn serves_gemm_requests_over_tcp() {
    let lib = match ArtifactLib::load(default_dir()) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("SKIP (run `make artifacts` first): {e}");
            return;
        }
    };
    let listener = bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    // Client on its own thread (the PJRT side must stay on this one).
    let client = std::thread::spawn(move || {
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut responses = Vec::new();
        for req in [
            "GEMM 64 64 64 1",
            "GEMM 96 96 96 2",
            "GEMM 64 64 64 1", // identical request -> identical checksum
            "GEMM 0 0 0 0",    // must be rejected
            "NONSENSE",
            "QUIT",
        ] {
            writeln!(conn, "{req}").unwrap();
            if req == "QUIT" {
                break;
            }
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            responses.push(line.trim().to_string());
        }
        responses
    });

    let cfg = ChipConfig::voltra();
    serve_blocking(lib, &cfg, listener, Some(1)).unwrap();
    let responses = client.join().unwrap();

    assert_eq!(responses.len(), 5);
    assert!(responses[0].starts_with("OK checksum="), "{}", responses[0]);
    assert!(responses[1].starts_with("OK checksum="), "{}", responses[1]);
    // Determinism: same request, same checksum.
    let checksum = |s: &str| {
        s.split_whitespace()
            .find_map(|t| t.strip_prefix("checksum="))
            .map(str::to_string)
    };
    assert_eq!(checksum(&responses[0]), checksum(&responses[2]));
    assert_ne!(checksum(&responses[0]), checksum(&responses[1]));
    assert!(responses[3].starts_with("ERR"), "{}", responses[3]);
    assert!(responses[4].starts_with("ERR"), "{}", responses[4]);
    // The chip-model estimate rides along.
    assert!(responses[0].contains("sim_cycles="));
}
