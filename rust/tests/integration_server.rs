//! End-to-end serving test: a client thread talks to the single-threaded
//! reference engine over a real TCP socket. Runs on the host numerics
//! backend, so it never skips; the PJRT backend path is exercised by the
//! same engine whenever artifacts are present (see `integration_runtime`
//! for the bit-exactness proof that makes the two interchangeable).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use voltra::config::ChipConfig;
use voltra::coordinator::server::{bind, serve_blocking};
use voltra::coordinator::SharedTileCache;
use voltra::plan::PlanCache;
use voltra::runtime::{HostBackend, PjrtBackend};

#[test]
fn serves_gemm_requests_over_tcp() {
    let listener = bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let client = std::thread::spawn(move || {
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut responses = Vec::new();
        for req in [
            "GEMM 64 64 64 1",
            "GEMM 96 96 96 2",
            "GEMM 64 64 64 1",  // identical request -> identical checksum
            "WORKLOAD lstm",    // compiled once, answered from the plan
            "WORKLOAD lstm",    // cache hit -> byte-identical response
            "WORKLOAD nothere", // unknown network -> rejected
            "GEMM 0 0 0 0",     // must be rejected
            "GEMM a b c 1",     // malformed numbers -> distinct parse error
            "NONSENSE",
            "LINT lstm",        // verifier over the already-cached plan
            "STATS",            // serving counters for everything above
            "QUIT",
        ] {
            writeln!(conn, "{req}").unwrap();
            if req == "QUIT" {
                break;
            }
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            responses.push(line.trim().to_string());
        }
        responses
    });

    let cfg = ChipConfig::voltra();
    let cache = SharedTileCache::new();
    let plans = PlanCache::new();
    let mut backend = HostBackend;
    let stats = serve_blocking(&mut backend, &cfg, listener, Some(1), &cache, &plans).unwrap();
    let responses = client.join().unwrap();

    assert_eq!(stats.served, 1);
    assert_eq!(stats.failed, 0);
    assert_eq!(responses.len(), 11);
    assert!(responses[0].starts_with("OK checksum="), "{}", responses[0]);
    assert!(responses[1].starts_with("OK checksum="), "{}", responses[1]);
    // Determinism: same request, same checksum.
    let checksum = |s: &str| {
        s.split_whitespace()
            .find_map(|t| t.strip_prefix("checksum="))
            .map(str::to_string)
    };
    assert_eq!(checksum(&responses[0]), checksum(&responses[2]));
    assert_ne!(checksum(&responses[0]), checksum(&responses[1]));
    // WORKLOAD requests answer from the plan cache: a repeated request is
    // byte-identical (no wall-clock token in the response).
    assert!(responses[3].starts_with("OK workload="), "{}", responses[3]);
    assert_eq!(responses[3], responses[4]);
    assert!(responses[5].starts_with("ERR unknown workload"), "{}", responses[5]);
    assert!(responses[6].starts_with("ERR unreasonable"), "{}", responses[6]);
    assert!(responses[7].starts_with("ERR bad integer"), "{}", responses[7]);
    assert!(responses[8].starts_with("ERR expected"), "{}", responses[8]);
    // LINT answers from the same plan cache; the suite plans are clean.
    assert_eq!(responses[9], "OK lint workload=lstm findings=0");
    // STATS reports every request above it, deterministically: 4 GEMM
    // verbs (the rejected size parsed fine), 3 WORKLOAD (unknown names
    // parsed fine), 1 LINT, 2 parse errors, no admissions refused; the
    // plan cache compiled lstm once and answered the repeat WORKLOAD
    // and the LINT from it. A STATS response never counts itself.
    assert!(
        responses[10].starts_with(
            "OK stats served=8 gemm=4 workload=3 lint=1 stats=0 errors=2 busy=0 \
             plan_hits=2 plan_misses=1 plan_waits=0 tile_hits="
        ),
        "{}",
        responses[10]
    );
    // The chip-model estimate rides along.
    assert!(responses[0].contains("sim_cycles="));
    // The serving caches were populated by the connection and survive it.
    assert!(!cache.is_empty());
    assert_eq!(plans.len(), 1, "one workload plan compiled");
    assert_eq!(plans.stats().misses, 1, "repeat WORKLOAD was a pure hit");
}

#[test]
fn pjrt_backend_loads_or_fails_cleanly() {
    // Without `make artifacts` (or without the native PJRT runtime) the
    // artifact backend must fail with a diagnostic, never panic — the
    // serving engine falls back to the host oracle in that case.
    match PjrtBackend::load(voltra::runtime::default_dir()) {
        Ok(_) => eprintln!("PJRT artifacts present; serve will use them"),
        Err(e) => {
            let msg = format!("{e:#}");
            assert!(!msg.is_empty());
            eprintln!("SKIP pjrt path (expected without `make artifacts`): {msg}");
        }
    }
}
