//! Differential and cache-reuse tests for the concurrent serving engine:
//! * N parallel clients against `serve_threaded` receive responses
//!   byte-identical to the single-threaded `serve_blocking` reference
//!   (modulo the wall-clock `us=` field, the protocol's only
//!   nondeterministic bytes);
//! * the shared tile cache survives across connections — repeated
//!   identical connections add no new unique tiles and no cache misses.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::thread;

use voltra::config::ChipConfig;
use voltra::coordinator::server::{bind, serve_blocking, serve_threaded, ServeOptions};
use voltra::coordinator::SharedTileCache;
use voltra::plan::PlanCache;
use voltra::runtime::HostBackend;

/// Default dispatch tuning with an accepted-connection cap.
fn opts(max_conns: usize) -> ServeOptions {
    ServeOptions {
        max_conns: Some(max_conns),
        ..ServeOptions::default()
    }
}

/// The request script every client plays (mix of cached-shape repeats,
/// ragged shapes, plan-cache workload/lint queries, a stats probe,
/// rejects and parse errors). WORKLOAD and LINT responses carry no
/// wall-clock token, so they must compare byte-identical across engines
/// and cache temperature.
const REQS: [&str; 12] = [
    "GEMM 64 64 64 1",
    "GEMM 96 96 96 2",
    "GEMM 40 64 72 3",
    "WORKLOAD lstm",
    "GEMM 64 64 64 1",
    "WORKLOAD lstm",
    "LINT lstm",
    "WORKLOAD nope",
    "GEMM 0 0 0 0",
    "GEMM 1x 2 3 4",
    "STATS",
    "QUIT",
];

/// Strip the wall-clock token so responses compare byte-identically.
/// STATS counters depend on how requests interleave across clients and
/// engines; the script only checks the verb answers.
fn normalize(resp: &str) -> String {
    if resp.starts_with("OK stats ") {
        return "OK stats".to_string();
    }
    resp.split_whitespace()
        .filter(|t| !t.starts_with("us="))
        .collect::<Vec<_>>()
        .join(" ")
}

/// One request, one response, over a fresh connection.
fn one_shot(addr: SocketAddr, req: &str) -> String {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    writeln!(conn, "{req}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    writeln!(conn, "QUIT").unwrap();
    line.trim().to_string()
}

/// Play the request script over one connection; normalized responses.
fn client(addr: SocketAddr) -> Vec<String> {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut responses = Vec::new();
    for req in REQS {
        writeln!(conn, "{req}").unwrap();
        if req == "QUIT" {
            break;
        }
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "server hung up mid-script on {req:?}");
        responses.push(normalize(line.trim()));
    }
    responses
}

#[test]
fn concurrent_clients_match_sequential_responses() {
    // Reference: the single-threaded engine, fresh cache.
    let listener = bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = thread::spawn(move || {
        let cfg = ChipConfig::voltra();
        let cache = SharedTileCache::new();
        let plans = PlanCache::new();
        serve_blocking(&mut HostBackend, &cfg, listener, Some(1), &cache, &plans).unwrap()
    });
    let reference = client(addr);
    let stats = server.join().unwrap();
    assert_eq!(stats.served, 1);
    assert_eq!(reference.len(), REQS.len() - 1);
    assert!(reference[0].starts_with("OK checksum="), "{}", reference[0]);

    // The concurrent engine: 4 clients in parallel, one shared cache.
    let listener = bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let cache = Arc::new(SharedTileCache::new());
    let server = {
        let cache = Arc::clone(&cache);
        thread::spawn(move || {
            let cfg = ChipConfig::voltra();
            let plans = PlanCache::new();
            serve_threaded(|| Ok(HostBackend), &cfg, listener, opts(4), &cache, &plans).unwrap()
        })
    };
    let clients: Vec<_> = (0..4).map(|_| thread::spawn(move || client(addr))).collect();
    for c in clients {
        assert_eq!(
            c.join().unwrap(),
            reference,
            "a concurrent client diverged from the sequential reference"
        );
    }
    let stats = server.join().unwrap();
    assert_eq!(stats.served, 4);
    assert_eq!(stats.failed, 0);
}

#[test]
fn shared_cache_survives_across_connections() {
    let listener = bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let cache = Arc::new(SharedTileCache::new());
    let plans = Arc::new(PlanCache::new());
    let server = {
        let cache = Arc::clone(&cache);
        let plans = Arc::clone(&plans);
        thread::spawn(move || {
            let cfg = ChipConfig::voltra();
            serve_threaded(|| Ok(HostBackend), &cfg, listener, opts(3), &cache, &plans).unwrap()
        })
    };

    // First connection populates the caches (responses received => all
    // sim-cost lookups and plan compilations for it have completed).
    let first = client(addr);
    let unique_after_first = cache.len();
    let misses_after_first = cache.stats().misses;
    assert!(unique_after_first > 0, "first connection must simulate tiles");
    assert_eq!(plans.len(), 1, "the script plans exactly one workload");
    let plan_misses_after_first = plans.stats().misses;
    assert_eq!(plan_misses_after_first, 1);

    // Identical connections answer from the caches: same bytes, no growth.
    for _ in 0..2 {
        assert_eq!(client(addr), first);
    }
    let stats = server.join().unwrap();
    assert_eq!(stats.served, 3);
    assert_eq!(
        cache.len(),
        unique_after_first,
        "unique tiles must not grow across identical connections"
    );
    assert_eq!(
        cache.stats().misses,
        misses_after_first,
        "repeat connections must be pure cache hits"
    );
    assert!(cache.stats().hits > 0);
    assert_eq!(
        plans.stats().misses,
        plan_misses_after_first,
        "repeat connections must re-plan zero workloads"
    );
    assert!(plans.stats().hits > 0);
}

#[test]
fn backend_factory_failure_surfaces_at_startup() {
    let listener = bind("127.0.0.1:0").unwrap();
    let cache = SharedTileCache::new();
    let plans = PlanCache::new();
    let cfg = ChipConfig::voltra();
    let r = serve_threaded::<HostBackend, _>(
        || Err(anyhow::anyhow!("backend deliberately unavailable")),
        &cfg,
        listener,
        opts(1),
        &cache,
        &plans,
    );
    let e = r.expect_err("factory failure must abort serving");
    assert!(format!("{e}").contains("deliberately unavailable"));
}

#[test]
fn cold_workload_herd_plans_once_with_identical_responses() {
    // Sequential reference answer for a cold WORKLOAD.
    let listener = bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = thread::spawn(move || {
        let cfg = ChipConfig::voltra();
        let cache = SharedTileCache::new();
        let plans = PlanCache::new();
        serve_blocking(&mut HostBackend, &cfg, listener, Some(1), &cache, &plans).unwrap()
    });
    let reference = one_shot(addr, "WORKLOAD bert");
    server.join().unwrap();
    assert!(reference.starts_with("OK workload=bert "), "{reference}");

    // The herd: 32 connected clients fire the same cold WORKLOAD at a
    // barrier, into a pool wide enough to admit all of them at once.
    const HERD: usize = 32;
    let listener = bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let plans = Arc::new(PlanCache::new());
    let server = {
        let plans = Arc::clone(&plans);
        thread::spawn(move || {
            let cfg = ChipConfig::voltra();
            let cache = SharedTileCache::new();
            serve_threaded(
                || Ok(HostBackend),
                &cfg,
                listener,
                ServeOptions {
                    max_conns: Some(HERD),
                    workers: HERD,
                    queue_depth: HERD,
                },
                &cache,
                &plans,
            )
            .unwrap()
        })
    };
    let barrier = Arc::new(std::sync::Barrier::new(HERD));
    let clients: Vec<_> = (0..HERD)
        .map(|_| {
            let barrier = Arc::clone(&barrier);
            thread::spawn(move || {
                let mut conn = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(conn.try_clone().unwrap());
                barrier.wait();
                writeln!(conn, "WORKLOAD bert").unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                writeln!(conn, "QUIT").unwrap();
                line.trim().to_string()
            })
        })
        .collect();
    for c in clients {
        assert_eq!(
            c.join().unwrap(),
            reference,
            "a herd response diverged from the sequential answer"
        );
    }
    let stats = server.join().unwrap();
    assert_eq!((stats.served, stats.failed), (HERD, 0));
    // The thundering-herd invariant: ONE compile for the whole burst.
    // Every other request either coalesced onto the in-flight compile
    // or arrived after it published (a plain hit); nobody re-planned.
    // (The exact 1-miss/31-coalesced split is pinned deterministically
    // in tests/plan_cache.rs, where the compile can be held open.)
    let p = plans.plan_stats();
    assert_eq!(p.misses, 1, "{p:?}");
    assert_eq!(p.hits + p.coalesced, (HERD - 1) as u64, "{p:?}");
}

#[test]
fn saturated_queue_answers_busy_and_stats_reports_it() {
    let listener = bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = thread::spawn(move || {
        let cfg = ChipConfig::voltra();
        let cache = SharedTileCache::new();
        let plans = PlanCache::new();
        serve_threaded(
            || Ok(HostBackend),
            &cfg,
            listener,
            // One worker, zero queue slots: a submit is admitted only
            // at the rendezvous with the idle worker — any overlap is
            // refused, never parked.
            ServeOptions {
                max_conns: Some(3),
                workers: 1,
                queue_depth: 0,
            },
            &cache,
            &plans,
        )
        .unwrap()
    });
    // Two clients hammer small GEMMs concurrently: whenever both have
    // a request in flight, one is executing and the other is refused.
    let hammer = |addr: SocketAddr| {
        thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut busy = 0u64;
            for i in 0..100 {
                writeln!(conn, "GEMM 8 8 8 {i}").unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let line = line.trim();
                if line == "ERR busy" {
                    busy += 1;
                } else {
                    assert!(line.starts_with("OK checksum="), "{line}");
                }
            }
            writeln!(conn, "QUIT").unwrap();
            busy
        })
    };
    let a = hammer(addr);
    let b = hammer(addr);
    let busy = a.join().unwrap() + b.join().unwrap();
    assert!(
        busy >= 1,
        "200 racing requests against a rendezvous queue never collided"
    );
    // STATS bypasses the dispatch queue, so a saturated server stays
    // observable; its busy tally matches what the clients saw (every
    // response was recorded before it was written).
    let stats_line = one_shot(addr, "STATS");
    let server_stats = server.join().unwrap();
    assert_eq!((server_stats.served, server_stats.failed), (3, 0));
    assert!(
        stats_line.contains(&format!(" busy={busy} ")),
        "{stats_line} (clients observed busy={busy})"
    );
}
