//! Edge-of-envelope tests for [`voltra::runtime::pool::scoped_indexed`]
//! (ISSUE 10 satellite): zero items, one worker, more workers than
//! items, and a panicking work closure — the cases where a claim-loop
//! bug would manifest as a hang, a partial result vector, or a skipped
//! index rather than a wrong value. The interleaving-level claim
//! protocol itself is model-checked (`voltra check --protocol pool`);
//! these pin the real implementation's degenerate paths.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Duration;

use voltra::runtime::pool::scoped_indexed;

#[test]
fn zero_items_returns_empty_for_any_thread_count() {
    for threads in [0, 1, 2, 8] {
        let calls = AtomicUsize::new(0);
        let out: Vec<u32> = scoped_indexed(0, threads, || (), |_, _| {
            calls.fetch_add(1, Ordering::Relaxed);
            0
        });
        assert!(out.is_empty(), "threads={threads}");
        assert_eq!(calls.load(Ordering::Relaxed), 0, "threads={threads}");
    }
}

#[test]
fn one_worker_visits_every_item_in_order() {
    // The single-worker path runs inline: claim order IS item order,
    // observable through a side log, and results stay index-ordered.
    let log = voltra::sync::Mutex::new(voltra::sync::Rank::PoolSlot, Vec::new());
    let out = scoped_indexed(5, 1, || (), |_, i| {
        log.lock().push(i);
        i * 2
    });
    assert_eq!(out, vec![0, 2, 4, 6, 8]);
    assert_eq!(*log.lock(), vec![0, 1, 2, 3, 4]);
}

#[test]
fn more_workers_than_items_completes_every_item_exactly_once() {
    let claims: [AtomicUsize; 3] = std::array::from_fn(|_| AtomicUsize::new(0));
    let out = scoped_indexed(3, 16, || (), |_, i| {
        claims[i].fetch_add(1, Ordering::Relaxed);
        i + 100
    });
    assert_eq!(out, vec![100, 101, 102]);
    for (i, c) in claims.iter().enumerate() {
        assert_eq!(c.load(Ordering::Relaxed), 1, "item {i} claimed more than once");
    }
}

/// A panicking work closure must propagate the panic to the caller —
/// never hang the pool, never return a partial vector. Run inside a
/// watchdog thread so a deadlock regression fails the test instead of
/// wedging the whole test binary.
#[test]
fn panicking_worker_propagates_and_never_deadlocks() {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let result = catch_unwind(AssertUnwindSafe(|| {
            scoped_indexed(8, 4, || (), |_, i| {
                if i == 3 {
                    panic!("injected worker failure");
                }
                i
            })
        }));
        tx.send(result.is_err()).ok();
    });
    match rx.recv_timeout(Duration::from_secs(30)) {
        Ok(panicked) => assert!(panicked, "pool swallowed the worker panic"),
        Err(_) => panic!("pool deadlocked after a worker panic"),
    }
}

/// Same for the inline (single-worker) path: the panic surfaces from
/// the caller's own frame.
#[test]
fn panicking_inline_worker_propagates() {
    let result = catch_unwind(AssertUnwindSafe(|| {
        scoped_indexed(4, 1, || (), |_, i| {
            if i == 2 {
                panic!("injected inline failure");
            }
            i
        })
    }));
    assert!(result.is_err(), "inline pool swallowed the panic");
}
