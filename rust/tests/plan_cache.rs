//! Plan determinism and plan-cache coherence (ISSUE 4 acceptance):
//!
//! * a warm [`PlanCache`] hit must produce a bit-identical
//!   [`WorkloadReport`] to the cold plan, for every suite workload under
//!   both the `voltra` and `separated` presets;
//! * the plan path must agree exactly with the legacy private-cache run
//!   path (`run_workload`) on every metric;
//! * a warm suite pass re-plans zero layers (miss counter flat);
//! * planning is deterministic across independent caches (the IR itself
//!   compares equal, not just the executed reports).

use std::sync::Arc;

use voltra::config::ChipConfig;
use voltra::coordinator::{run_suite_planned, run_workload, SharedTileCache, TileCache};
use voltra::plan::{self, PlanCache};
use voltra::tiling::mapper::MapperCache;
use voltra::workloads::evaluation_suite;

#[test]
fn warm_hits_are_bit_identical_to_cold_plans_for_the_whole_suite() {
    for cfg in [ChipConfig::voltra(), ChipConfig::separated_memory()] {
        let plans = PlanCache::new();
        for w in evaluation_suite() {
            let cold = plans.run(&cfg, &w);
            let warm = plans.run(&cfg, &w);
            assert_eq!(cold, warm, "{}: warm report diverged", w.name);
            // The plan-cache path (shared per-fingerprint tile cache)
            // and a fresh private-cache run agree on every metric —
            // cache backing must never leak into the numbers.
            // (unique_tiles legitimately differs: private caches count
            // per-run, the plan cache counts globally. Equality against
            // the PRE-refactor arithmetic cannot be asserted in-repo —
            // run_workload is itself the plan path now — and was
            // established out of band when the refactor landed.)
            let private = run_workload(&cfg, &w);
            assert_eq!(cold.metrics, private.metrics, "{}: plan path diverged", w.name);
            assert_eq!(cold.dispatched_tiles, private.dispatched_tiles, "{}", w.name);
        }
        let s = plans.stats();
        assert_eq!(s.misses, 8, "each suite workload plans exactly once");
        assert_eq!(s.hits, 8, "each warm run must hit the plan cache");
    }
}

#[test]
fn warm_suite_replans_zero_layers() {
    let cfg = ChipConfig::voltra();
    let suite = evaluation_suite();
    let plans = PlanCache::new();
    let cold = run_suite_planned(&cfg, &suite, 4, &plans);
    let cold_stats = plans.stats();
    assert_eq!(cold_stats.misses, suite.len() as u64);
    let cold_tiles = plans.tile_stats().misses;

    let warm = run_suite_planned(&cfg, &suite, 4, &plans);
    assert_eq!(cold, warm, "warm sweep must be bit-identical");
    let warm_stats = plans.stats();
    assert_eq!(
        warm_stats.misses, cold_stats.misses,
        "a warm sweep must re-plan zero workloads"
    );
    assert_eq!(
        plans.tile_stats().misses,
        cold_tiles,
        "a warm sweep must re-simulate zero tiles"
    );
    assert_eq!(warm_stats.hits, cold_stats.hits + suite.len() as u64);
}

#[test]
fn plans_are_deterministic_across_independent_caches() {
    // Not just the executed reports: the IR itself — tile runs, grants,
    // residency decisions, DMA attribution — must compare equal when
    // built twice from scratch.
    for cfg in [ChipConfig::voltra(), ChipConfig::separated_memory()] {
        for w in evaluation_suite() {
            let mut c1 = TileCache::new();
            let mut c2 = TileCache::new();
            let a = plan::build(&cfg, &w, &mut c1);
            let b = plan::build(&cfg, &w, &mut c2);
            assert_eq!(a, b, "{}: plan IR not deterministic", w.name);
        }
    }
}

#[test]
fn concurrent_planners_agree_on_one_canonical_plan() {
    // Racing threads may duplicate planning work, but every caller must
    // end up executing the same canonical Arc'd plan.
    let cfg = ChipConfig::voltra();
    let w = voltra::workloads::by_name("pointnext").unwrap();
    let plans = PlanCache::new();
    let got: Vec<Arc<plan::WorkloadPlan>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8).map(|_| s.spawn(|| plans.plan(&cfg, &w))).collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for p in &got[1..] {
        assert!(
            Arc::ptr_eq(&got[0], p) || **p == *got[0],
            "racing planners must agree on plan content"
        );
    }
    // And every later lookup returns the canonical Arc.
    let canonical = plans.plan(&cfg, &w);
    let again = plans.plan(&cfg, &w);
    assert!(Arc::ptr_eq(&canonical, &again));
    assert_eq!(plans.len(), 1);
}

#[test]
fn thundering_herd_compiles_once_and_coalesces_the_rest() {
    // ISSUE 8 acceptance, pinned deterministically: under 32 concurrent
    // identical cold requests the cache records exactly 1 miss/compile
    // and 31 coalesced waits. The flight leader's resolver HOLDS the
    // compile open until every other thread has registered on the
    // flight, so the split cannot depend on scheduling.
    const HERD: usize = 32;
    let cfg = ChipConfig::voltra();
    let plans = PlanCache::new();
    let barrier = std::sync::Barrier::new(HERD);
    let got: Vec<Arc<plan::WorkloadPlan>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..HERD)
            .map(|_| {
                let plans = &plans;
                let cfg = &cfg;
                let barrier = &barrier;
                s.spawn(move || {
                    barrier.wait();
                    plans
                        .plan_named(cfg, "bert", || {
                            // Only the flight leader runs this. Refuse
                            // to produce the workload until all 31
                            // followers are blocked on the flight
                            // (bounded, so a coalescing regression
                            // fails loudly instead of hanging).
                            let t0 = std::time::Instant::now();
                            while plans.plan_stats().coalesced < (HERD - 1) as u64 {
                                assert!(
                                    t0.elapsed() < std::time::Duration::from_secs(10),
                                    "followers never coalesced: {:?}",
                                    plans.plan_stats()
                                );
                                std::thread::yield_now();
                            }
                            voltra::workloads::by_name("bert")
                        })
                        .expect("bert resolves")
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for p in &got[1..] {
        assert!(
            Arc::ptr_eq(&got[0], p),
            "every herd caller must share the one compiled plan"
        );
    }
    let s = plans.plan_stats();
    assert_eq!((s.hits, s.misses, s.coalesced), (0, 1, (HERD - 1) as u64));
    // The herd's answer is the canonical cached plan for later callers.
    let w = voltra::workloads::by_name("bert").unwrap();
    assert!(Arc::ptr_eq(&got[0], &plans.plan(&cfg, &w)));
}

#[test]
fn panicking_flight_leader_aborts_and_the_herd_retries() {
    // ISSUE 10 satellite (lock poisoning policy, DESIGN.md §16): a
    // flight leader that panics mid-compile must not poison the cache
    // for everyone else. The abort guard retires the flight and wakes
    // the herd empty-handed; exactly one caller observes the panic,
    // every survivor retries, and a healthy leader compiles the one
    // canonical plan.
    use std::sync::atomic::{AtomicBool, Ordering};
    const HERD: usize = 8;
    let cfg = ChipConfig::voltra();
    let plans = Arc::new(PlanCache::new());
    let panicked = Arc::new(AtomicBool::new(false));
    let aborts_before = voltra::sync::flight_aborts();
    let handles: Vec<_> = (0..HERD)
        .map(|_| {
            let plans = Arc::clone(&plans);
            let cfg = cfg.clone();
            let panicked = Arc::clone(&panicked);
            std::thread::spawn(move || {
                plans.plan_named(&cfg, "lstm", || {
                    // Only flight leaders run resolvers; the first one
                    // dies before producing anything.
                    if !panicked.swap(true, Ordering::SeqCst) {
                        panic!("injected leader failure");
                    }
                    voltra::workloads::by_name("lstm")
                })
            })
        })
        .collect();
    let mut failed = 0usize;
    let mut survivors = Vec::new();
    for h in handles {
        match h.join() {
            Ok(plan) => survivors.push(plan.expect("lstm resolves")),
            Err(_) => failed += 1,
        }
    }
    assert_eq!(failed, 1, "exactly the injected panic propagates");
    assert_eq!(survivors.len(), HERD - 1);
    for p in &survivors[1..] {
        assert!(Arc::ptr_eq(&survivors[0], p), "survivors must share the canonical plan");
    }
    assert_eq!(plans.len(), 1, "one canonical entry after the retry");
    assert!(
        voltra::sync::flight_aborts() > aborts_before,
        "the aborted leadership must be counted"
    );
    // The cache stays fully serviceable: a later caller hits.
    let w = voltra::workloads::by_name("lstm").unwrap();
    assert!(Arc::ptr_eq(&survivors[0], &plans.plan(&cfg, &w)));
}

#[test]
fn parallel_compiled_plans_are_byte_equal_to_sequential_for_the_suite() {
    // PR 6 tentpole acceptance: fanning layer planning over a scoped
    // pool (what `PlanCache::plan_named` now does on every cold plan)
    // must change nothing — the WorkloadPlan IR, field for field, run
    // for run, residency decision for residency decision, compares
    // equal to the sequential build at every thread count.
    for cfg in [ChipConfig::voltra(), ChipConfig::separated_memory()] {
        for w in evaluation_suite() {
            let seq_tiles = SharedTileCache::new();
            let mut handle = &seq_tiles;
            let seq = plan::build(&cfg, &w, &mut handle);
            for threads in [1usize, 2, 8] {
                let tiles = SharedTileCache::new();
                let par = plan::build_parallel(&cfg, &w, &tiles, threads);
                assert_eq!(par, seq, "{}: threads={threads} diverged", w.name);
                assert_eq!(
                    tiles.len(),
                    seq_tiles.len(),
                    "{}: parallel build simulated a different tile set",
                    w.name
                );
            }
        }
    }
}

#[test]
fn shared_tile_cache_stats_stay_coherent_under_parallel_builds() {
    // Hits + misses must equal the total simulate() calls the planner
    // made, and the distinct-spec count can never exceed the misses
    // (racing threads may duplicate a miss, never invent one).
    let cfg = ChipConfig::voltra();
    let w = voltra::workloads::by_name("resnet50").unwrap();
    let tiles = SharedTileCache::new();
    let par = plan::build_parallel(&cfg, &w, &tiles, 8);
    let s = tiles.stats();
    assert!(s.misses >= tiles.len() as u64, "misses {} < distinct {}", s.misses, tiles.len());
    assert!(!tiles.is_empty(), "resnet50 must simulate tiles");
    assert_eq!(par.unique_tiles, tiles.len());
    // A second, warm build touches no new specs: misses stay flat.
    let warm = plan::build_parallel(&cfg, &w, &tiles, 8);
    assert_eq!(warm, par);
    assert_eq!(tiles.stats().misses, s.misses, "warm build must re-simulate nothing");
    assert!(tiles.stats().hits > s.hits);
}

#[test]
fn mapper_cache_stats_stay_coherent_under_parallel_builds() {
    // The per-worker IncrementalMapper seeds go through one shared
    // MapperCache: every distinct (fingerprint, shape) resolves at most
    // once per miss, warm resolutions only add hits, and the resolved
    // winners match the unseeded search.
    let cfg = ChipConfig::voltra();
    let w = voltra::workloads::by_name("resnet50").unwrap();
    let mapper = MapperCache::new();
    let mut shapes: Vec<(u64, u64, u64)> = Vec::new();
    for l in &w.layers {
        for g in l.gemms() {
            shapes.push((g.m, g.k, g.n));
        }
    }
    std::thread::scope(|s| {
        for worker in 0..4usize {
            let shapes = &shapes;
            let mapper = &mapper;
            let cfg = &cfg;
            s.spawn(move || {
                let mut inc = voltra::tiling::IncrementalMapper::new(mapper);
                // Different traversal orders → different hint chains.
                let iter: Box<dyn Iterator<Item = &(u64, u64, u64)>> = if worker % 2 == 0 {
                    Box::new(shapes.iter())
                } else {
                    Box::new(shapes.iter().rev())
                };
                for &(m, k, n) in iter {
                    let got = inc.resolve(cfg, m, k, n);
                    assert_eq!(
                        got,
                        voltra::tiling::mapper::search(cfg, m, k, n),
                        "seeded winner diverged on ({m},{k},{n})"
                    );
                }
            });
        }
    });
    let s = mapper.stats();
    let distinct: std::collections::HashSet<_> = shapes.iter().collect();
    assert!(mapper.len() <= distinct.len());
    assert!(s.misses >= mapper.len() as u64);
    assert_eq!(
        s.hits + s.misses,
        4 * shapes.len() as u64,
        "every resolve must count exactly one hit or miss"
    );
}

#[test]
fn chaining_reduces_traffic_against_an_unchained_plan() {
    // The residency pass must strictly reduce off-chip traffic for the
    // decode workload (known chained layers) relative to summing the
    // same layers planned standalone — and never increase latency.
    let cfg = ChipConfig::voltra();
    let w = voltra::workloads::by_name("llama-decode").unwrap();
    let mut cache = TileCache::new();
    let p = plan::build(&cfg, &w, &mut cache);
    let chained_traffic: u64 = p.layers.iter().map(|l| l.dma_bytes).sum();
    let saved: u64 = p.layers.iter().map(|l| l.residency.saved_dma_bytes).sum();
    assert!(saved > 0, "decode must chain activations");
    let mut solo = TileCache::new();
    let standalone: u64 = w
        .layers
        .iter()
        .map(|l| plan::planner::plan_layer(&cfg, l, &mut solo).dma_bytes)
        .sum();
    assert_eq!(chained_traffic + saved, standalone);
}
