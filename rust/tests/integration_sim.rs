//! Cross-module integration tests: full workloads through tiling +
//! cycle simulation, checking the invariants and orderings the paper's
//! evaluation depends on.

use voltra::config::ChipConfig;
use voltra::coordinator::run_workload;
use voltra::metrics::geomean;
use voltra::workloads::{self, evaluation_suite};

#[test]
fn mac_conservation_across_all_workloads_and_configs() {
    // The simulator must perform exactly the analytic MAC count — no
    // work dropped at tile edges, no double counting — under every
    // configuration of the Fig. 6 study.
    for cfg in [
        ChipConfig::voltra(),
        ChipConfig::no_prefetch(),
        ChipConfig::separated_memory(),
        ChipConfig::array2d(),
    ] {
        for w in evaluation_suite() {
            let r = run_workload(&cfg, &w);
            let sim: u64 = r.metrics.layers.iter().map(|l| l.tiles.useful_macs).sum();
            assert_eq!(sim, w.total_macs(), "{} under {:?}", w.name, cfg.array);
        }
    }
}

#[test]
fn fig6a_ordering_3d_beats_2d_in_aggregate() {
    let v = ChipConfig::voltra();
    let b = ChipConfig::array2d();
    let mut ratios = Vec::new();
    for w in evaluation_suite() {
        let s3 = run_workload(&v, &w).metrics.spatial_utilization();
        let s2 = run_workload(&b, &w).metrics.spatial_utilization();
        ratios.push(s3 / s2);
        // Per-workload: the 3D array may lose only marginally (ragged-K
        // layers like PointNeXt trade K-residue against M/N fill).
        assert!(
            s3 / s2 > 0.92,
            "{}: 3D {s3:.3} vs 2D {s2:.3} — more than a marginal loss",
            w.name
        );
    }
    let g = geomean(&ratios);
    assert!(g > 1.1, "geomean 3D/2D spatial ratio too small: {g:.3}");
    // The paper's "up to 2.0x" (Fig. 6a) is the permutation-only
    // dimension-mismatch regime (pinned in tests/mapper.rs). With the
    // mapping search, the GEMV-heavy decode stage K-extends to ~full
    // fill — something the 2D array (no spatial K axis) cannot follow —
    // so the best case now lands at ~2.7x.
    let max = ratios.iter().cloned().fold(0.0, f64::max);
    assert!((2.5..=2.9).contains(&max), "max ratio {max:.2}");
}

#[test]
fn fig6b_ordering_prefetch_beats_demand_everywhere() {
    let v = ChipConfig::voltra();
    let np = ChipConfig::no_prefetch();
    let mut ratios = Vec::new();
    for w in evaluation_suite() {
        let tv = run_workload(&v, &w).metrics.temporal_utilization();
        let tn = run_workload(&np, &w).metrics.temporal_utilization();
        assert!(tv > tn, "{}: MGDP must beat demand fetching", w.name);
        ratios.push(tv / tn);
    }
    // Paper: 2.12 - 2.94x improvement; allow a modestly wider band.
    let g = geomean(&ratios);
    assert!(
        (1.9..=3.2).contains(&g),
        "geomean temporal improvement {g:.2} outside the plausible band"
    );
}

#[test]
fn fig6c_ordering_pdma_never_slower() {
    let v = ChipConfig::voltra();
    let s = ChipConfig::separated_memory();
    for w in evaluation_suite() {
        let lv = run_workload(&v, &w).metrics.total_latency_cycles();
        let ls = run_workload(&s, &w).metrics.total_latency_cycles();
        assert!(
            ls as f64 >= 0.99 * lv as f64,
            "{}: separated ({ls}) must not beat PDMA ({lv})",
            w.name
        );
    }
}

#[test]
fn fig6c_band_matches_paper_shape() {
    let v = ChipConfig::voltra();
    let s = ChipConfig::separated_memory();
    let mut ratios = Vec::new();
    for w in evaluation_suite() {
        let lv = run_workload(&v, &w).metrics.total_latency_cycles() as f64;
        let ls = run_workload(&s, &w).metrics.total_latency_cycles() as f64;
        ratios.push(ls / lv);
    }
    let max = ratios.iter().cloned().fold(0.0, f64::max);
    // Paper: 1.15 - 2.36x total-latency advantage. The event-driven
    // scheduler exposes the recurrent suite's per-step DMA tails a bit
    // more than the old analytic bubble did (LSTM lands at ~2.59x), so
    // allow modest headroom above the paper's max — and pin the suite
    // geomean tightly so a broad inflation of the separated baseline
    // cannot hide inside the widened per-workload ceiling.
    assert!((1.3..=2.7).contains(&max), "max PDMA speedup {max:.2}");
    let g = geomean(&ratios);
    assert!((1.3..=1.7).contains(&g), "geomean PDMA speedup {g:.2}");
}

#[test]
fn k_extension_lifts_decode_off_the_utilization_floor() {
    // Pre-mapper, the LLM decode stage was the suite's spatial floor:
    // the paper-faithful ~0.70 (69.71%) that the swap-only baseline
    // still reproduces. The mapping search K-extends decode's GEMV
    // attention (M=1 -> 1x8x64) and folds the batch-6 projections
    // (2x8x32), lifting the stage to ~full fill — the suite floor is
    // now MobileNetV2's depthwise-heavy profile.
    let v = ChipConfig::voltra();
    let mut utils: Vec<(String, f64)> = evaluation_suite()
        .iter()
        .map(|w| {
            (
                w.name.clone(),
                run_workload(&v, w).metrics.spatial_utilization(),
            )
        })
        .collect();
    utils.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let decode = utils
        .iter()
        .find(|(n, _)| n == "LLaMA3.2-3B-decode")
        .unwrap()
        .1;
    assert!(decode > 0.99, "mapped decode should reach ~1.0: {decode:.3}");
    assert_eq!(utils[0].0, "MobileNetV2");
    assert!(
        (0.85..0.93).contains(&utils[0].1),
        "floor {:.3} should be MobileNetV2 at ~0.90",
        utils[0].1
    );
    assert!(utils.last().unwrap().1 > 0.96);

    // The swap-only baseline still pins the paper's decode number.
    let base = run_workload(&ChipConfig::swap_only(), &workloads::by_name("llama-decode").unwrap())
        .metrics
        .spatial_utilization();
    assert!(
        (0.65..0.80).contains(&base),
        "swap-only decode {base:.3} should be ~0.70 (paper 69.71%)"
    );
    // The acceptance ratio: mapping search over swap-only on decode.
    assert!(
        decode / base > 1.3,
        "decode spatial gain {:.2}x below the K-extension target",
        decode / base
    );
}

#[test]
fn voltra_temporal_utilization_band() {
    // Paper: 76.99 - 97.32% with MGDP across the suite. Our floor is
    // MobileNetV2 (~0.60): its skinny-K expand layers were already
    // output-bound at ~0.69, and the mapper's K-extended depthwise
    // layers trade a further slice of temporal utilization (the doubled
    // weight fetch stalls) for 2x spatial fill and net-lower latency.
    let v = ChipConfig::voltra();
    for w in evaluation_suite() {
        let t = run_workload(&v, &w).metrics.temporal_utilization();
        assert!(
            (0.55..=1.0).contains(&t),
            "{}: temporal {t:.3} outside band",
            w.name
        );
    }
}

#[test]
fn separated_memory_has_higher_temporal_utilization() {
    // The paper notes the separated configuration's GEMM cycles are
    // slightly *lower* (dedicated buffers never contend) — the PDMA win
    // comes from DMA, not compute.
    let v = ChipConfig::voltra();
    let s = ChipConfig::separated_memory();
    for w in evaluation_suite() {
        let tv = run_workload(&v, &w).metrics.temporal_utilization();
        let ts = run_workload(&s, &w).metrics.temporal_utilization();
        assert!(
            ts >= tv - 0.03,
            "{}: separated temporal {ts:.3} should be >= shared {tv:.3}",
            w.name
        );
    }
}

#[test]
fn workload_lookup_and_suite_agree() {
    for w in evaluation_suite() {
        let via_name = workloads::by_name(
            &w.name
                .to_ascii_lowercase()
                .replace("llama3.2-3b-", "llama-"),
        );
        assert!(via_name.is_some(), "{} not found by name", w.name);
        assert_eq!(via_name.unwrap().total_macs(), w.total_macs());
    }
}
