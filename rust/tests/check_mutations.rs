//! Mutation rig for the concurrency model checker (DESIGN.md §16),
//! mirroring `tests/verifier_mutations.rs` for the plan-IR verifier:
//! the checker is itself checked. Every [`Mutation`] seeds one concrete
//! concurrency bug into one protocol model, and the exploration must
//! produce that mutation's pinned finding id — "any finding" is not
//! good enough, because a bug caught for the wrong reason means the
//! intended invariant has silently stopped pulling its weight.
//!
//! The clean direction is pinned too: unmutated models must explore to
//! quiescence with zero findings and zero truncation (the checker's
//! zero-false-positive contract — a flaky checker is an ignored one).

use voltra::check::{check_all, check_protocol, Mutation, DEFAULT_DEPTH, PROTOCOLS};

/// Every seeded bug is caught, and caught for the pinned reason.
#[test]
fn every_mutation_is_caught_with_its_pinned_finding() {
    for &m in Mutation::all() {
        let report = check_protocol(m.protocol(), DEFAULT_DEPTH, Some(m))
            .unwrap_or_else(|| panic!("{}: unknown protocol {}", m.id(), m.protocol()));
        let ids: Vec<&str> = report.findings.iter().map(|f| f.id).collect();
        assert!(
            ids.contains(&m.expected_finding()),
            "{}: expected finding {:?}, got {:?}",
            m.id(),
            m.expected_finding(),
            ids
        );
    }
}

/// Counterexamples are actionable: every finding carries a nonempty
/// schedule trace in `t<i>: <label>` form.
#[test]
fn every_finding_carries_a_counterexample_trace() {
    for &m in Mutation::all() {
        let report = check_protocol(m.protocol(), DEFAULT_DEPTH, Some(m)).unwrap();
        let f = report
            .findings
            .iter()
            .find(|f| f.id == m.expected_finding())
            .unwrap_or_else(|| panic!("{}: pinned finding missing", m.id()));
        assert!(!f.trace.is_empty(), "{}: empty trace", m.id());
        for step in &f.trace {
            assert!(
                step.starts_with('t') && step.contains(": "),
                "{}: malformed trace step {step:?}",
                m.id()
            );
        }
    }
}

/// The zero-false-positive direction: a clean tree explores every
/// protocol to quiescence with nothing to report.
#[test]
fn clean_models_explore_to_quiescence_with_zero_findings() {
    let reports = check_all(DEFAULT_DEPTH);
    assert_eq!(reports.len(), PROTOCOLS.len());
    for r in &reports {
        assert!(r.findings.is_empty(), "{}: {:?}", r.protocol, r.findings);
        assert!(!r.truncated, "{}: truncated at depth {DEFAULT_DEPTH}", r.protocol);
        assert!(r.states > 1, "{}: trivial exploration", r.protocol);
    }
}

/// A mutation seeded into a *different* protocol's model is inert —
/// mutations are keyed, not ambient (guards against a model accidentally
/// reacting to a foreign mutation enum value).
#[test]
fn mutations_are_inert_outside_their_own_protocol() {
    for &m in Mutation::all() {
        for &p in PROTOCOLS {
            if p == m.protocol() {
                continue;
            }
            let report = check_protocol(p, DEFAULT_DEPTH, Some(m)).unwrap();
            assert!(
                report.findings.is_empty(),
                "{} leaked into {p}: {:?}",
                m.id(),
                report.findings
            );
        }
    }
}

/// Rig floor from the issue: at least 10 distinct seeded bugs spanning
/// at least 4 protocol models.
#[test]
fn rig_meets_its_coverage_floor()  {
    let all = Mutation::all();
    assert!(all.len() >= 10, "only {} mutations", all.len());
    let protocols: std::collections::HashSet<_> = all.iter().map(|m| m.protocol()).collect();
    assert!(protocols.len() >= 4, "only {} protocols mutated", protocols.len());
}
