//! End-to-end plumbing tests for `voltra check` (DESIGN.md §16),
//! mirroring `tests/lint_cli.rs`: the command's stdout is deterministic
//! (DFS over a fixed state graph — no timings, no thread scheduling),
//! so its shape is asserted exactly; `--selftest` proves the
//! nonzero-exit wiring end to end by seeding a known bug on purpose.

use std::process::{Command, Output};

fn voltra(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_voltra"))
        .args(args)
        .output()
        .expect("spawn voltra binary")
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

/// Clean tree: all five protocols explore clean, one line each plus a
/// summary, exit 0.
#[test]
fn check_all_protocols_clean() {
    let out = voltra(&["check"]);
    let text = stdout(&out);
    assert!(out.status.success(), "exit: {out:?}");
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 6, "5 protocols + summary:\n{text}");
    for (line, proto) in lines[..5]
        .iter()
        .zip(["flight", "plancache", "dispatch", "pool", "lockorder"])
    {
        assert!(line.starts_with(&format!("check {proto}")), "{line}");
        assert!(line.contains(" clean ("), "{line}");
        assert!(line.contains(" states, depth "), "{line}");
        assert!(!line.contains("TRUNCATED"), "{line}");
    }
    assert_eq!(lines[5], "check: 5 protocol(s), 0 finding(s)");
}

/// One-protocol mode explores exactly that protocol.
#[test]
fn check_single_protocol() {
    let out = voltra(&["check", "--protocol", "pool"]);
    assert!(out.status.success(), "{out:?}");
    let text = stdout(&out);
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2, "{text}");
    assert!(lines[0].starts_with("check pool"), "{text}");
    assert_eq!(lines[1], "check: 1 protocol(s), 0 finding(s)");
}

/// Machine-readable mode: a clean run reports `"clean":true` with all
/// five protocols present, and is byte-stable across runs.
#[test]
fn check_json_clean_and_deterministic() {
    let a = voltra(&["check", "--json"]);
    assert!(a.status.success(), "{a:?}");
    let text = stdout(&a);
    assert!(text.contains("\"clean\":true"), "{text}");
    assert!(text.contains("\"findings\":0"), "{text}");
    for proto in ["flight", "plancache", "dispatch", "pool", "lockorder"] {
        assert!(text.contains(&format!("\"protocol\":\"{proto}\"")), "{text}");
    }
    let b = voltra(&["check", "--json"]);
    assert_eq!(text, stdout(&b), "check --json must be deterministic");
}

/// The nonzero-exit path, end to end: `--selftest` seeds a dropped
/// notify and must exit 1 having caught it as a deadlock. Exit 2 would
/// mean the checker MISSED the seeded bug — the rig's worst outcome.
#[test]
fn check_selftest_exits_nonzero_having_caught_the_bug() {
    let out = voltra(&["check", "--selftest"]);
    assert_eq!(out.status.code(), Some(1), "{out:?}");
    let text = stdout(&out);
    assert!(text.contains("[deadlock]"), "{text}");
    assert!(text.contains("caught the seeded flight-dropped-notify bug"), "{text}");
}

/// An over-tight depth bound is reported as truncation and exits 1 —
/// incomplete coverage must never look like a clean run, in the exit
/// code OR the per-protocol line (it says "incomplete", not "clean").
#[test]
fn check_truncated_exploration_is_not_clean() {
    let out = voltra(&["check", "--protocol", "flight", "--depth", "3"]);
    assert_eq!(out.status.code(), Some(1), "{out:?}");
    let text = stdout(&out);
    assert!(text.contains("check flight     incomplete ("), "{text}");
    assert!(text.contains("TRUNCATED"), "{text}");
    assert!(!text.contains(" clean ("), "{text}");
}

/// Unknown protocols are a usage error (exit 2), not a finding.
#[test]
fn check_unknown_protocol_is_a_usage_error() {
    let out = voltra(&["check", "--protocol", "nope"]);
    assert_eq!(out.status.code(), Some(2), "{out:?}");
}

/// A non-integer --depth is a usage error (exit 2), mirroring the
/// unknown-protocol path — never a panic (exit 101).
#[test]
fn check_bad_depth_is_a_usage_error() {
    let out = voltra(&["check", "--depth", "lots"]);
    assert_eq!(out.status.code(), Some(2), "{out:?}");
    let err = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(err.contains("--depth must be an integer"), "{err}");
}
