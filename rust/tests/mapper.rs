//! The mapping-search subsystem's acceptance tests (DESIGN.md §11):
//!
//! * the mapper never returns lower spatial utilization than the legacy
//!   swap-only choice, on any GEMM of any of the eight suite workloads;
//! * the Fig. 6a claim stays pinned: in the permutation-only regime the
//!   3D/2D spatial-utilization ratio never exceeds 2.0x (+ ragged-N
//!   slack) and reaches exactly 2.0x on a skinny-M layer;
//! * GEMV K-extension: an M = 1 layer maps above the 12.5% row-idle
//!   floor (to ~full fill);
//! * the process-wide mapper cache is coherent under contention and
//!   plan-cache warm hits stay bit-identical with mapping resolved at
//!   plan time.

use std::collections::BTreeSet;

use voltra::config::{ArrayGeometry, ChipConfig};
use voltra::sim::gemm_core::Mapping;
use voltra::tiling::mapper::{self, MapperCache};
use voltra::workloads::evaluation_suite;

/// Every distinct GEMM shape the eight suite workloads dispatch.
fn suite_gemm_shapes() -> Vec<(u64, u64, u64)> {
    let mut shapes = BTreeSet::new();
    for w in evaluation_suite() {
        for l in &w.layers {
            for g in l.gemms() {
                shapes.insert((g.m, g.k, g.n));
            }
        }
    }
    shapes.into_iter().collect()
}

#[test]
fn mapper_never_below_the_swap_only_choice_on_any_suite_layer() {
    let cfg = ChipConfig::voltra();
    for (m, k, n) in suite_gemm_shapes() {
        let (mapping, _) = mapper::search(&cfg, m, k, n)
            .unwrap_or_else(|| panic!("no mapping for {m}x{k}x{n}"));
        let searched = mapping.spatial_utilization(m, k, n);
        let legacy = Mapping::swap_only(cfg.array, m, n).spatial_utilization(m, k, n);
        assert!(
            searched >= legacy - 1e-12,
            "{m}x{k}x{n}: searched {searched:.4} < swap-only {legacy:.4} ({mapping:?})"
        );
    }
}

#[test]
fn fig6a_two_x_claim_is_pinned_in_the_permutation_regime() {
    // The paper's "up to 2.0x over the 2D design" is a statement about
    // M/N dimension mismatch: the 3D array's (8, 8) output tile
    // under-fills at most half as much as the 2D's (16, 32). Pin it in
    // the regime the formula describes — permutation-only mapping,
    // 8-aligned dims (a ragged dim compounds on the 2D side's wider
    // unroll and can push past 2.0x even without folding; K-extension,
    // which the 2D array cannot follow, is the separate decode story).
    let a3 = ChipConfig::voltra().array;
    let a2 = ChipConfig::array2d().array;
    for (m, k, n) in suite_gemm_shapes() {
        if m % 8 != 0 || n % 8 != 0 || k % 8 != 0 {
            continue;
        }
        let u3 = Mapping::swap_only(a3, m, n).spatial_utilization(m, k, n);
        let u2 = Mapping::swap_only(a2, m, n).spatial_utilization(m, k, n);
        let ratio = u3 / u2;
        assert!(
            ratio <= 2.0 + 1e-9,
            "{m}x{k}x{n}: permutation-only 3D/2D ratio {ratio:.3} breaks the 2.0x claim"
        );
    }
    // The skinny-M worst case lands exactly on 2.0x.
    let u3 = Mapping::swap_only(a3, 8, 512).spatial_utilization(8, 512, 512);
    let u2 = Mapping::swap_only(a2, 8, 512).spatial_utilization(8, 512, 512);
    assert!((u3 / u2 - 2.0).abs() < 1e-12, "skinny-M ratio {:.3}", u3 / u2);
}

#[test]
fn gemv_k_extension_beats_the_row_idle_floor() {
    // M = 1 on the 8x8x8 array idles at 12.5% under any permutation;
    // the mapper's K-extension folds the idle rows onto 64 K lanes.
    let cfg = ChipConfig::voltra();
    for (m, k, n) in [(1u64, 3072u64, 3072u64), (1, 128, 256), (1, 768, 1000)] {
        let (mapping, _) = mapper::search(&cfg, m, k, n).unwrap();
        let u = mapping.spatial_utilization(m, k, n);
        assert!(u > 0.125, "GEMV {m}x{k}x{n} stuck at the floor: {u:.4}");
        assert!(mapping.fold > 1, "GEMV must fold: {mapping:?}");
    }
}

#[test]
fn two_d_baseline_has_no_k_axis_to_extend() {
    let cfg = ChipConfig::array2d();
    let (mapping, _) = mapper::search(&cfg, 1, 3072, 3072).unwrap();
    assert_eq!(mapping.fold, 1);
    assert!(matches!(mapping.geometry, ArrayGeometry::Spatial2D { .. }));
}

#[test]
fn mapper_cache_is_coherent_under_contention() {
    // Racing threads resolving the same shapes must all read values
    // equal to an uncached search, and populate each key exactly once.
    let cfg = ChipConfig::voltra();
    let cache = MapperCache::new();
    let shapes: Vec<(u64, u64, u64)> = suite_gemm_shapes().into_iter().take(24).collect();
    std::thread::scope(|s| {
        for _ in 0..8 {
            s.spawn(|| {
                for &(m, k, n) in &shapes {
                    let got = cache.resolve(&cfg, m, k, n);
                    assert_eq!(got, mapper::search(&cfg, m, k, n));
                }
            });
        }
    });
    assert_eq!(cache.len(), shapes.len());
    let stats = cache.stats();
    assert_eq!(stats.lookups(), 8 * shapes.len() as u64);
}

#[test]
fn suite_runs_resolve_each_shape_once_per_fingerprint() {
    // Warm plan-cache hits never re-map: a second suite pass through the
    // plan cache must not change any report (mapping resolved at plan
    // time, memoized process-wide).
    let cfg = ChipConfig::voltra();
    let plans = voltra::PlanCache::new();
    for w in evaluation_suite() {
        let cold = plans.run(&cfg, &w);
        let warm = plans.run(&cfg, &w);
        assert_eq!(cold, warm, "{}: warm report diverged", w.name);
        // Every GEMM layer reports its resolved mapping.
        for l in &warm.metrics.layers {
            if l.macs > 0 {
                assert!(!l.mapping.is_empty(), "{}/{} lost its mapping", w.name, l.name);
            }
        }
    }
}

#[test]
fn decode_report_shows_k_extended_mappings() {
    let cfg = ChipConfig::voltra();
    let w = voltra::workloads::by_name("llama-decode").unwrap();
    let r = voltra::coordinator::run_workload(&cfg, &w);
    let scores = r
        .metrics
        .layers
        .iter()
        .find(|l| l.name == "scores")
        .expect("decode has a scores layer");
    assert_eq!(scores.mapping, "1x8x64", "GEMV attention must K-extend fully");
    let q = r.metrics.layers.iter().find(|l| l.name == "q_proj").unwrap();
    assert_eq!(q.mapping, "2x8x32", "batch-6 projections fold by 4");
}
