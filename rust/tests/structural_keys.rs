//! Property tests for structural cache-key soundness (DESIGN.md §15).
//!
//! The co-search's cross-config sharing is only legal if the structural
//! fingerprints are *exactly* as wide as what they guard:
//!
//! * configs that differ **only** in non-structural fields must share a
//!   cache entry AND produce bit-identical results from the guarded
//!   computation (sharing is sound);
//! * **any** structural change must produce a distinct key (sharing is
//!   never wrong).
//!
//! Two fingerprints are under test: [`voltra::sim::tile_fingerprint`]
//! (guards `simulate_tile` memoization — the fields the tile engine
//! reads) and [`voltra::tiling::mapper::fingerprint`] (guards mapping
//! search memoization — the fields the search scores with).

use std::sync::Arc;

use voltra::config::{ArrayGeometry, ChipConfig, MappingSearch, MemoryOrg, OperatingPoint};
use voltra::sim::{simulate_tile, tile_fingerprint, TileSpec};
use voltra::tiling::mapper;
use voltra::workloads;
use voltra::PlanCache;

/// Configs differing from the shipped chip ONLY in fields the tile
/// engine never reads (planner-side and power-side knobs).
fn tile_nonstructural_variants() -> Vec<(&'static str, ChipConfig)> {
    let base = ChipConfig::voltra;
    let mut out: Vec<(&'static str, ChipConfig)> = Vec::new();
    let mut c = base();
    c.psum_fifo_depth = 4;
    out.push(("psum_fifo_depth", c));
    let mut c = base();
    c.dma_bytes_per_cycle = 16;
    out.push(("dma_bytes_per_cycle", c));
    let mut c = base();
    c.dma_burst_latency = 8;
    out.push(("dma_burst_latency", c));
    let mut c = base();
    c.double_buffer = false;
    out.push(("double_buffer", c));
    let mut c = base();
    c.mapping = MappingSearch::SwapOnly;
    out.push(("mapping", c));
    let mut c = base();
    c.operating_point = OperatingPoint::efficiency();
    out.push(("operating_point", c));
    out
}

/// One config per tile-structural axis, each moved off the shipped
/// value.
fn tile_structural_variants() -> Vec<(&'static str, ChipConfig)> {
    let base = ChipConfig::voltra;
    let mut out: Vec<(&'static str, ChipConfig)> = Vec::new();
    let mut c = base();
    c.array = ArrayGeometry::Spatial2D { m: 16, n: 32 };
    out.push(("array", c));
    let mut c = base();
    c.prefetch = false;
    out.push(("prefetch", c));
    let mut c = base();
    c.stream_fifo_depth = 4;
    out.push(("stream_fifo_depth", c));
    let mut c = base();
    c.simd_lanes = 64;
    out.push(("simd_lanes", c));
    let mut c = base();
    c.tmux_psum_output = false;
    out.push(("tmux_psum_output", c));
    let mut c = base();
    c.num_banks = 16;
    out.push(("num_banks", c));
    let mut c = base();
    c.mem_latency = 3;
    out.push(("mem_latency", c));
    let mut c = base();
    c.memory = MemoryOrg::separated_default();
    out.push(("memory_kind", c));
    out
}

fn probe_specs() -> Vec<TileSpec> {
    let mut specs = vec![
        TileSpec::simple(128, 256, 64),
        TileSpec::simple(96, 96, 96),
        TileSpec::simple(64, 512, 64),
        TileSpec::simple(1, 1, 1),
        TileSpec::simple(7, 33, 5), // ragged residues
    ];
    let mut edge = TileSpec::simple(128, 512, 64);
    edge.psum_in = true;
    edge.spill_out = true;
    specs.push(edge);
    specs
}

#[test]
fn tile_nonstructural_differences_share_entries_bit_identically() {
    let base = ChipConfig::voltra();
    let key = tile_fingerprint(&base);
    let plans = PlanCache::new();
    let shared = plans.tile_cache(&base);
    for (field, cfg) in tile_nonstructural_variants() {
        assert_eq!(
            tile_fingerprint(&cfg),
            key,
            "{field} is not a tile-engine input and must not change the key"
        );
        assert!(
            Arc::ptr_eq(&shared, &plans.tile_cache(&cfg)),
            "{field}: same class must share one tile cache instance"
        );
        // Soundness of the shared entry: the engine really is blind to
        // the field, bit for bit, on every probe shape.
        for spec in probe_specs() {
            assert_eq!(
                simulate_tile(&base, &spec),
                simulate_tile(&cfg, &spec),
                "{field}: simulate_tile diverged on {spec:?}"
            );
        }
    }
}

#[test]
fn tile_structural_changes_produce_distinct_keys() {
    let mut all = vec![("shipped", ChipConfig::voltra())];
    all.extend(tile_structural_variants());
    for i in 0..all.len() {
        for j in i + 1..all.len() {
            assert_ne!(
                tile_fingerprint(&all[i].1),
                tile_fingerprint(&all[j].1),
                "{} vs {}: structural configs must never share a tile key",
                all[i].0,
                all[j].0
            );
        }
    }
    // Separated splits beyond the kind boolean are NOT structural for
    // the tile engine: the planner already carved tiles to fit, so two
    // splits simulate identically and deliberately share a class.
    let mut a = ChipConfig::voltra();
    a.memory = MemoryOrg::separated_default();
    let mut b = ChipConfig::voltra();
    b.memory = MemoryOrg::Separated {
        input: 48 * 1024,
        weight: 48 * 1024,
        output: 24 * 1024,
        psum: 8 * 1024,
    };
    assert_eq!(tile_fingerprint(&a), tile_fingerprint(&b));
}

#[test]
fn mapper_nonstructural_differences_share_search_results() {
    let base = ChipConfig::voltra();
    let key = mapper::fingerprint(&base);
    // Fields the mapping search never scores with: streamer/SIMD/latency
    // knobs and the operating point.
    let mut variants: Vec<(&'static str, ChipConfig)> = Vec::new();
    let mut c = base.clone();
    c.prefetch = false;
    variants.push(("prefetch", c));
    let mut c = base.clone();
    c.stream_fifo_depth = 4;
    variants.push(("stream_fifo_depth", c));
    let mut c = base.clone();
    c.psum_fifo_depth = 4;
    variants.push(("psum_fifo_depth", c));
    let mut c = base.clone();
    c.simd_lanes = 64;
    variants.push(("simd_lanes", c));
    let mut c = base.clone();
    c.mem_latency = 3;
    variants.push(("mem_latency", c));
    let mut c = base.clone();
    c.operating_point = OperatingPoint::efficiency();
    variants.push(("operating_point", c));
    // GEMM, GEMV (K-extension fold territory), and a ragged shape.
    let shapes = [(192u64, 768u64, 768u64), (1, 2048, 512), (7, 33, 5)];
    for (field, cfg) in variants {
        assert_eq!(
            mapper::fingerprint(&cfg),
            key,
            "{field} must not change the mapper key"
        );
        for (m, k, n) in shapes {
            assert_eq!(
                mapper::resolve(&base, m, k, n),
                mapper::resolve(&cfg, m, k, n),
                "{field}: mapping search diverged on {m}x{k}x{n}"
            );
        }
    }
}

#[test]
fn mapper_structural_changes_produce_distinct_keys() {
    let base = ChipConfig::voltra;
    let mut all: Vec<(&'static str, ChipConfig)> = vec![("shipped", base())];
    let mut c = base();
    c.array = ArrayGeometry::Spatial2D { m: 16, n: 32 };
    all.push(("array", c));
    let mut c = base();
    c.memory = MemoryOrg::separated_default();
    all.push(("memory", c));
    let mut c = base();
    c.num_banks = 16;
    all.push(("num_banks", c));
    let mut c = base();
    c.dma_bytes_per_cycle = 16;
    all.push(("dma_bytes_per_cycle", c));
    let mut c = base();
    c.double_buffer = false;
    all.push(("double_buffer", c));
    let mut c = base();
    c.mapping = MappingSearch::SwapOnly;
    all.push(("mapping", c));
    for i in 0..all.len() {
        for j in i + 1..all.len() {
            assert_ne!(
                mapper::fingerprint(&all[i].1),
                mapper::fingerprint(&all[j].1),
                "{} vs {}: mapper-structural configs must never share a key",
                all[i].0,
                all[j].0
            );
        }
    }
    // Unlike the tile key, separated SPLITS are mapper-structural
    // (tiling feasibility depends on the exact partition).
    let mut a = base();
    a.memory = MemoryOrg::separated_default();
    let mut b = base();
    b.memory = MemoryOrg::Separated {
        input: 48 * 1024,
        weight: 48 * 1024,
        output: 24 * 1024,
        psum: 8 * 1024,
    };
    assert_ne!(mapper::fingerprint(&a), mapper::fingerprint(&b));
}

/// Sharing must be invisible to results: a plan built through a cache
/// that already served a different same-class config is bit-identical
/// to one built in isolation.
#[test]
fn cross_config_sharing_never_changes_metrics() {
    let w = workloads::by_name("lstm").expect("suite workload");
    let voltra = ChipConfig::voltra();
    let mut swap = ChipConfig::voltra();
    swap.mapping = MappingSearch::SwapOnly;

    let isolated = PlanCache::new().run(&voltra, &w);
    let shared = PlanCache::new();
    let _warm = shared.run(&swap, &w); // populates the shared tile class
    let through_shared = shared.run(&voltra, &w);
    assert_eq!(
        isolated.metrics, through_shared.metrics,
        "planning through a pre-warmed shared tile class must not move a cycle"
    );
}
