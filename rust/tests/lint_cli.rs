//! End-to-end plumbing tests for `voltra lint` (DESIGN.md §13).
//!
//! The lint command's stdout is deliberately deterministic — no
//! timings, no cache counters — so its shape can be asserted exactly:
//! one `clean` line per workload plus a summary, exit 0; and the
//! `--selftest` path proves the nonzero-exit wiring end to end by
//! corrupting a plan on purpose.

use std::process::{Command, Output};

fn voltra(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_voltra"))
        .args(args)
        .output()
        .expect("spawn voltra binary")
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

/// Clean sweep across the three memory/mapping presets: every suite
/// workload verifies clean, stdout keeps the golden shape, exit is 0.
#[test]
fn lint_all_presets_clean() {
    for preset in ["voltra", "separated", "swap-only"] {
        let out = voltra(&["lint", "--config", preset]);
        let text = stdout(&out);
        assert!(out.status.success(), "{preset} exit: {out:?}");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 9, "{preset}: 8 workloads + summary:\n{text}");
        for line in &lines[..8] {
            assert!(line.starts_with("lint "), "{preset}: {line}");
            assert!(line.contains(" clean ("), "{preset}: {line}");
            assert!(line.contains(" tiles dispatched)"), "{preset}: {line}");
        }
        assert_eq!(lines[8], "lint: 8 workload(s), 0 finding(s)", "{preset}");
    }
}

/// One-workload mode plans (and verifies) exactly that workload.
#[test]
fn lint_single_workload() {
    let out = voltra(&["lint", "--workload", "lstm"]);
    assert!(out.status.success(), "{out:?}");
    let text = stdout(&out);
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2, "{text}");
    assert!(lines[0].contains(" clean ("), "{text}");
    assert_eq!(lines[1], "lint: 1 workload(s), 0 finding(s)");
}

/// Machine-readable mode: a clean run is exactly the empty JSON array.
#[test]
fn lint_json_clean_is_empty_array() {
    let out = voltra(&["lint", "--workload", "lstm", "--json"]);
    assert!(out.status.success(), "{out:?}");
    assert_eq!(stdout(&out).trim(), "[]");
}

/// The nonzero-exit path, end to end: `--selftest` corrupts a plan on
/// purpose and must exit 1 with the seeded rule on stdout. Exit 2 would
/// mean the verifier MISSED the corruption — the rig's worst outcome.
#[test]
fn lint_selftest_exits_nonzero_with_findings() {
    let out = voltra(&["lint", "--selftest"]);
    assert_eq!(out.status.code(), Some(1), "{out:?}");
    let text = stdout(&out);
    assert!(text.contains("mac-conservation"), "{text}");
    assert!(text.contains("caught the seeded corruption"), "{text}");
}

/// Unknown workloads are a usage error (exit 2), not a lint finding.
#[test]
fn lint_unknown_workload_is_a_usage_error() {
    let out = voltra(&["lint", "--workload", "nope"]);
    assert_eq!(out.status.code(), Some(2), "{out:?}");
}
