//! Runtime integration: every AOT artifact executed through PJRT and
//! checked against host oracles. Requires `make artifacts` to have run
//! (the Makefile's `test` target guarantees it); tests are skipped with
//! a notice when the artifact directory is absent.

use voltra::runtime::{default_dir, gemm_ref, gemm_tiled, requant_ref, ArtifactLib, MatI32};

fn lib() -> Option<ArtifactLib> {
    match ArtifactLib::load(default_dir()) {
        Ok(l) => Some(l),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts` first): {e}");
            None
        }
    }
}

struct Rng(u64);
impl Rng {
    fn next_i8(&mut self) -> i32 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        ((z ^ (z >> 31)) % 255) as i32 - 127
    }
    fn mat(&mut self, r: usize, c: usize) -> MatI32 {
        MatI32::from_fn(r, c, |_, _| self.next_i8())
    }
}

fn lit(m: &MatI32) -> xla::Literal {
    xla::Literal::vec1(&m.data)
        .reshape(&[m.rows as i64, m.cols as i64])
        .unwrap()
}

#[test]
fn manifest_covers_all_entry_points() {
    let Some(lib) = lib() else { return };
    let names = lib.names();
    for expected in [
        "gemm8",
        "gemm64",
        "gemm96",
        "gemm_ragged",
        "conv3x3",
        "conv3x3s2",
        "mha64",
        "lstm64",
        "maxpool2x2",
    ] {
        assert!(names.contains(&expected), "missing artifact {expected}");
    }
}

#[test]
fn gemm_artifacts_are_bit_exact() {
    let Some(mut lib) = lib() else { return };
    let mut rng = Rng(1);
    for (name, m, k, n) in [
        ("gemm8", 8, 8, 8),
        ("gemm64", 64, 64, 64),
        ("gemm96", 96, 96, 96),
        ("gemm_ragged", 40, 64, 64),
    ] {
        let x = rng.mat(m, k);
        let w = rng.mat(k, n);
        let p = rng.mat(m, n);
        let outs = lib
            .run(name, &[lit(&x), lit(&w), lit(&p), xla::Literal::vec1(&[0.01f32])])
            .unwrap();
        let acc = outs[1].to_vec::<i32>().unwrap();
        let expect = gemm_ref(&x, &w, &p);
        assert_eq!(acc, expect.data, "{name}: accumulator mismatch");
        let q = outs[0].to_vec::<i32>().unwrap();
        let q_expect = requant_ref(&expect, 0.01);
        assert_eq!(q, q_expect.data, "{name}: requant mismatch");
    }
}

#[test]
fn signature_validation_rejects_bad_inputs() {
    let Some(mut lib) = lib() else { return };
    let bad = MatI32::zeros(7, 8);
    let err = match lib.run("gemm8", &[lit(&bad)]) {
        Ok(_) => panic!("wrong arity must fail"),
        Err(e) => e,
    };
    assert!(format!("{err}").contains("expected 4 inputs"));
    let err2 = match lib.run(
        "gemm8",
        &[
            lit(&bad),
            lit(&MatI32::zeros(8, 8)),
            lit(&MatI32::zeros(8, 8)),
            xla::Literal::vec1(&[1.0f32]),
        ],
    ) {
        Ok(_) => panic!("wrong shape must fail"),
        Err(e) => e,
    };
    assert!(format!("{err2}").contains("elements"));
}

#[test]
fn conv_artifact_matches_direct_convolution() {
    let Some(mut lib) = lib() else { return };
    let mut rng = Rng(3);
    // conv3x3: x (1,8,8,16), w (3,3,16,16), SAME stride 1.
    let x: Vec<i32> = (0..8 * 8 * 16).map(|_| rng.next_i8()).collect();
    let w: Vec<i32> = (0..3 * 3 * 16 * 16).map(|_| rng.next_i8()).collect();
    let scale = 0.01f32;
    let outs = lib
        .run(
            "conv3x3",
            &[
                xla::Literal::vec1(&x).reshape(&[1, 8, 8, 16]).unwrap(),
                xla::Literal::vec1(&w).reshape(&[3, 3, 16, 16]).unwrap(),
                xla::Literal::vec1(&[scale]),
            ],
        )
        .unwrap();
    let got = outs[0].to_vec::<i32>().unwrap();

    // Host direct convolution (SAME padding).
    let mut expect = vec![0i32; 8 * 8 * 16];
    for oy in 0..8i32 {
        for ox in 0..8i32 {
            for f in 0..16usize {
                let mut acc: i64 = 0;
                for dy in 0..3i32 {
                    for dx in 0..3i32 {
                        let iy = oy + dy - 1;
                        let ix = ox + dx - 1;
                        if iy < 0 || iy >= 8 || ix < 0 || ix >= 8 {
                            continue;
                        }
                        for c in 0..16usize {
                            let xv = x[((iy * 8 + ix) as usize) * 16 + c] as i64;
                            let wv =
                                w[(((dy * 3 + dx) as usize) * 16 + c) * 16 + f] as i64;
                            acc += xv * wv;
                        }
                    }
                }
                let q = (acc as f32 * scale).round_ties_even().clamp(-128.0, 127.0);
                expect[((oy * 8 + ox) as usize) * 16 + f] = q as i32;
            }
        }
    }
    assert_eq!(got, expect, "conv3x3 artifact vs direct convolution");
}

#[test]
fn maxpool_artifact_matches_host_model() {
    let Some(mut lib) = lib() else { return };
    let mut rng = Rng(5);
    let x: Vec<i32> = (0..8 * 8 * 16).map(|_| rng.next_i8()).collect();
    let outs = lib
        .run(
            "maxpool2x2",
            &[xla::Literal::vec1(&x).reshape(&[1, 8, 8, 16]).unwrap()],
        )
        .unwrap();
    let got = outs[0].to_vec::<i32>().unwrap();
    // Host: maxpool via the simulator's functional unit.
    let xi8: Vec<i8> = x.iter().map(|&v| v as i8).collect();
    let (pooled, ph, pw) = voltra::sim::maxpool::maxpool_hwc(&xi8, 8, 8, 16, 2, 2);
    assert_eq!((ph, pw), (4, 4));
    let expect: Vec<i32> = pooled.iter().map(|&v| v as i32).collect();
    assert_eq!(got, expect);
}

#[test]
fn lstm_artifact_produces_bounded_state() {
    let Some(mut lib) = lib() else { return };
    let mut rng = Rng(9);
    let b = 8usize;
    let hidden = 64usize;
    let x = rng.mat(b, hidden);
    let h = rng.mat(b, hidden);
    let c = vec![0f32; b * hidden];
    let wx = rng.mat(hidden, 4 * hidden);
    let wh = rng.mat(hidden, 4 * hidden);
    let bias = vec![0f32; 4 * hidden];
    let outs = lib
        .run(
            "lstm64",
            &[
                lit(&x),
                lit(&h),
                xla::Literal::vec1(&c).reshape(&[b as i64, hidden as i64]).unwrap(),
                lit(&wx),
                lit(&wh),
                xla::Literal::vec1(&bias),
                xla::Literal::vec1(&[0.0002f32]),
            ],
        )
        .unwrap();
    let hq = outs[0].to_vec::<i32>().unwrap();
    let cn = outs[1].to_vec::<f32>().unwrap();
    assert!(hq.iter().all(|&v| (-128..=127).contains(&v)));
    // |c_1| <= |c_0| + 1 = 1 elementwise.
    assert!(cn.iter().all(|&v| v.abs() <= 1.0 + 1e-5));
}

#[test]
fn tiled_executor_handles_ragged_shapes() {
    let Some(mut lib) = lib() else { return };
    let mut rng = Rng(11);
    for (m, k, n) in [(1, 100, 10), (65, 64, 63), (130, 200, 70)] {
        let x = rng.mat(m, k);
        let w = rng.mat(k, n);
        let p = rng.mat(m, n);
        let (q, acc) = gemm_tiled(&mut lib, &x, &w, &p, 0.002).unwrap();
        let expect = gemm_ref(&x, &w, &p);
        assert_eq!(acc, expect, "{m}x{k}x{n}");
        assert_eq!(q, requant_ref(&expect, 0.002), "{m}x{k}x{n} quant");
    }
}
