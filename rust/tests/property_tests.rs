//! Property-based tests over the coordinator/simulator invariants.
//!
//! Substrate note (DESIGN.md): no property-testing crate is vendored in
//! the build image, so this file carries its own SplitMix64-driven
//! harness — hundreds of randomized cases per property, with the failing
//! seed printed for reproduction.

use voltra::config::ChipConfig;
use voltra::coordinator::{run_layer, SharedTileCache, TileCache};
use voltra::sim::agu::{AffineAgu, LoopDim};
use voltra::sim::engine::{simulate_tile, TileSpec};
use voltra::sim::fifo::Fifo;
use voltra::sim::simd::{requant_one, QuantParams};
use voltra::tiling::engine::{choose_tiling, compulsory_traffic, traffic_bytes};
use voltra::tiling::fits;
use voltra::workloads::layer::{Layer, LayerKind};

/// SplitMix64: tiny, deterministic, good-enough PRNG for case generation.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }
}

#[test]
fn prop_simulated_tiles_conserve_macs() {
    let cfg = ChipConfig::voltra();
    let mut rng = Rng(0xC0FFEE);
    for case in 0..150 {
        let tm = rng.range(1, 96);
        let tk = rng.range(1, 256);
        let tn = rng.range(1, 96);
        let mut spec = TileSpec::simple(tm, tk, tn);
        spec.psum_in = rng.next() % 2 == 0;
        spec.spill_out = rng.next() % 2 == 0;
        // K-extension folds must conserve work like any other tile.
        spec.fold = [1u8, 2, 4, 8][(rng.next() % 4) as usize];
        let m = simulate_tile(&cfg, &spec);
        assert_eq!(
            m.useful_macs,
            tm * tk * tn,
            "case {case}: tile {tm}x{tk}x{tn} fold {} (seed-reproducible)",
            spec.fold
        );
        assert!(m.active_cycles <= m.total_cycles);
        assert!(m.spatial_utilization() <= 1.0 + 1e-12);
        assert!(m.temporal_utilization() <= 1.0 + 1e-12);
    }
}

#[test]
fn prop_prefetch_never_hurts() {
    let with = ChipConfig::voltra();
    let without = ChipConfig::no_prefetch();
    let mut rng = Rng(0xBADC0DE);
    for case in 0..60 {
        let tm = rng.range(1, 12) * 8;
        let tk = rng.range(1, 32) * 8;
        let tn = rng.range(1, 12) * 8;
        let spec = TileSpec::simple(tm, tk, tn);
        let a = simulate_tile(&with, &spec);
        let b = simulate_tile(&without, &spec);
        // Tiny-K tiles can see a few cycles of extra arbitration noise
        // from the run-ahead prefetcher; anything beyond 5% is a bug.
        assert!(
            a.total_cycles as f64 <= 1.05 * b.total_cycles as f64,
            "case {case}: MGDP slower on {tm}x{tk}x{tn}: {} vs {}",
            a.total_cycles,
            b.total_cycles
        );
    }
}

#[test]
fn prop_tiling_always_fits_and_meets_compulsory_bound() {
    let mut rng = Rng(0x7117E);
    for cfg in [ChipConfig::voltra(), ChipConfig::separated_memory()] {
        for case in 0..120 {
            let m = rng.range(1, 4096);
            let k = rng.range(1, 8192);
            let n = rng.range(1, 4096);
            let t = choose_tiling(&cfg, m, k, n)
                .unwrap_or_else(|| panic!("case {case}: no tiling for {m}x{k}x{n}"));
            assert!(fits(&cfg.memory, &t.footprint), "case {case}");
            assert!(
                t.traffic_bytes >= compulsory_traffic(m, k, n),
                "case {case}: traffic below compulsory"
            );
            assert!(t.tm <= m.max(8) && t.tk <= k.max(8) && t.tn <= n.max(8));
        }
    }
}

#[test]
fn prop_traffic_monotone_in_tile_size_along_k() {
    // Growing tk (deeper output-stationary accumulation) never increases
    // traffic: fewer psum round-trips, fewer operand revisits.
    let mut rng = Rng(0x5EED);
    for case in 0..80 {
        let m = rng.range(2, 64) * 8;
        let k = rng.range(4, 128) * 8;
        let n = rng.range(2, 64) * 8;
        let tm = 64.min(m);
        let tn = 64.min(n);
        let tk_small = rng.range(1, k / 8 / 2).max(1) * 8;
        let tk_big = (tk_small * 2).min(k);
        let small = traffic_bytes(m, k, n, tm, tk_small, tn);
        let big = traffic_bytes(m, k, n, tm, tk_big, tn);
        assert!(
            big <= small,
            "case {case}: tk {tk_small}->{tk_big} raised traffic {small}->{big} (m={m} k={k} n={n})"
        );
    }
}

#[test]
fn prop_layer_runner_matches_analytic_macs() {
    let cfg = ChipConfig::voltra();
    let mut rng = Rng(0xFACADE);
    for case in 0..40 {
        let layer = match rng.next() % 3 {
            0 => Layer::new(
                "g",
                LayerKind::Gemm {
                    m: rng.range(1, 512),
                    k: rng.range(1, 1024),
                    n: rng.range(1, 512),
                },
            ),
            1 => Layer::new(
                "c",
                LayerKind::Conv2d {
                    h: rng.range(4, 32),
                    w: rng.range(4, 32),
                    cin: rng.range(1, 64),
                    cout: rng.range(1, 64),
                    kh: 3,
                    kw: 3,
                    stride: rng.range(1, 2),
                },
            ),
            _ => Layer::new(
                "b",
                LayerKind::BatchedMatmul {
                    batch: rng.range(1, 8),
                    m: rng.range(1, 128),
                    k: rng.range(1, 128),
                    n: rng.range(1, 128),
                },
            ),
        };
        let mut cache = TileCache::new();
        let lm = run_layer(&cfg, &layer, &mut cache);
        assert_eq!(lm.tiles.useful_macs, layer.macs(), "case {case}: {layer:?}");
        assert!(lm.latency_cycles >= lm.tiles.total_cycles.min(lm.dma_cycles));
    }
}

#[test]
fn prop_shared_cache_equals_fresh_cache_on_tiles() {
    // The shared serving cache must be a pure memoization: for any tile
    // spec, it returns exactly what a fresh private cache (and the raw
    // simulator) returns — first as a miss, then as a hit.
    let cfg = ChipConfig::voltra();
    let shared = SharedTileCache::new();
    let mut rng = Rng(0x5AFE);
    for case in 0..120 {
        let tm = rng.range(1, 96);
        let tk = rng.range(1, 256);
        let tn = rng.range(1, 96);
        let mut spec = TileSpec::simple(tm, tk, tn);
        spec.psum_in = rng.next() % 2 == 0;
        spec.spill_out = rng.next() % 2 == 0;
        let mut fresh = TileCache::new();
        let a = fresh.simulate(&cfg, &spec);
        let b = shared.simulate(&cfg, &spec);
        let c = shared.simulate(&cfg, &spec); // guaranteed hit path
        assert_eq!(a, b, "case {case}: miss path diverged on {spec:?}");
        assert_eq!(b, c, "case {case}: hit path diverged on {spec:?}");
    }
    assert!(shared.stats().hits >= 120, "hit path never exercised");
}

#[test]
fn prop_layer_runs_identical_on_both_caches() {
    // Whole layers (tiling search + tile enumeration + DMA folding) must
    // produce identical LayerMetrics whichever cache backs them.
    let cfg = ChipConfig::voltra();
    let shared = SharedTileCache::new();
    let mut rng = Rng(0xCACHE);
    for case in 0..30 {
        let layer = Layer::new(
            "p",
            LayerKind::Gemm {
                m: rng.range(1, 512),
                k: rng.range(1, 1024),
                n: rng.range(1, 512),
            },
        );
        let mut fresh = TileCache::new();
        let a = run_layer(&cfg, &layer, &mut fresh);
        let mut handle = &shared;
        let b = run_layer(&cfg, &layer, &mut handle);
        assert_eq!(a, b, "case {case}: {layer:?}");
    }
}

#[test]
fn prop_agu_emits_exactly_total_addresses() {
    let mut rng = Rng(0xA61);
    for case in 0..200 {
        let ndims = rng.range(1, 4) as usize;
        let dims: Vec<LoopDim> = (0..ndims)
            .map(|_| LoopDim {
                bound: rng.range(1, 9),
                stride: rng.range(0, 64) as i64,
            })
            .collect();
        let mut agu = AffineAgu::new(rng.range(0, 1024), dims);
        let expect = agu.total();
        let mut n = 0u64;
        while agu.next_addr().is_some() {
            n += 1;
            assert!(n <= expect, "case {case}: AGU emitted too many");
        }
        assert_eq!(n, expect, "case {case}");
    }
}

#[test]
fn prop_fifo_is_order_preserving() {
    let mut rng = Rng(0xF1F0);
    for _ in 0..100 {
        let cap = rng.range(1, 16) as usize;
        let mut f = Fifo::new(cap);
        let mut model: std::collections::VecDeque<u64> = Default::default();
        for _ in 0..200 {
            if rng.next() % 2 == 0 {
                let v = rng.next();
                assert_eq!(f.push(v), model.len() < cap);
                if model.len() < cap {
                    model.push_back(v);
                }
            } else {
                assert_eq!(f.pop(), model.pop_front());
            }
            assert_eq!(f.len(), model.len());
        }
    }
}

#[test]
fn prop_requant_is_always_saturated_and_monotone() {
    let mut rng = Rng(0x0DD);
    let p = QuantParams {
        scale: 0.037,
        relu: false,
    };
    let mut prev_in = i32::MIN;
    let mut prev_out = i8::MIN;
    let mut cases: Vec<i32> = (0..300).map(|_| rng.next() as i32).collect();
    cases.sort_unstable();
    for v in cases {
        let q = requant_one(v, p);
        assert!((-128..=127).contains(&(q as i32)));
        if v >= prev_in {
            assert!(q >= prev_out, "requant not monotone at {v}");
        }
        prev_in = v;
        prev_out = q;
    }
}
