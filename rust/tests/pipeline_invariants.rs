//! Workload-level invariants of the event-driven layer pipeline
//! scheduler (`sim::pipeline`, DESIGN.md §9) that replaced the analytic
//! overlap heuristic — plus the regression tests for the two standalone
//! bugfixes that rode along:
//!
//! * per-GEMM double-buffer accounting (a fused layer must not inherit
//!   the LAST GEMM's ping-pong grant for the whole layer);
//! * config-sized streamer in-flight queues (depth-16 sweep points).

use voltra::config::ChipConfig;
use voltra::coordinator::{run_layer, run_workload, TileCache};
use voltra::metrics::LayerMetrics;
use voltra::sim::dma::overlap_latency;
use voltra::workloads::layer::{Layer, LayerKind};
use voltra::workloads::{by_name, evaluation_suite};

fn compute_cycles(l: &LayerMetrics) -> u64 {
    l.tiles.total_cycles + l.aux_cycles
}

#[test]
fn every_layer_latency_sits_in_the_overlap_envelope() {
    // max(compute, dma) <= latency <= compute + dma for every layer of
    // every network under every Fig. 6 configuration: the old analytic
    // heuristic survives as this cross-check on the scheduler.
    for cfg in [
        ChipConfig::voltra(),
        ChipConfig::separated_memory(),
        ChipConfig::no_prefetch(),
    ] {
        for w in evaluation_suite() {
            let r = run_workload(&cfg, &w);
            for l in &r.metrics.layers {
                if l.macs == 0 {
                    continue;
                }
                let c = compute_cycles(l);
                let d = l.dma_cycles;
                assert!(
                    l.latency_cycles >= c.max(d),
                    "{} / {}: latency {} < max({c}, {d})",
                    w.name,
                    l.name,
                    l.latency_cycles
                );
                assert!(
                    l.latency_cycles <= overlap_latency(c, d, false),
                    "{} / {}: latency {} > serial {c} + {d}",
                    w.name,
                    l.name,
                    l.latency_cycles
                );
                assert_eq!(
                    l.overlap_cycles,
                    (c + d) - l.latency_cycles,
                    "{} / {}: overlap breakdown inconsistent",
                    w.name,
                    l.name
                );
            }
        }
    }
}

#[test]
fn simulated_macs_match_analytic_macs_for_all_networks() {
    let cfg = ChipConfig::voltra();
    for w in evaluation_suite() {
        let r = run_workload(&cfg, &w);
        let sim: u64 = r.metrics.layers.iter().map(|l| l.tiles.useful_macs).sum();
        assert_eq!(sim, w.total_macs(), "{}", w.name);
    }
}

#[test]
fn prefetch_on_total_latency_never_exceeds_prefetch_off() {
    // MGDP prefetching only removes stall cycles from the tile engine;
    // the DMA side is identical, so the scheduled workload latency must
    // not grow. (Per-tile arbitration noise is allowed up to 1% per
    // workload — see prop_prefetch_never_hurts — but never in the suite
    // aggregate.)
    let on = ChipConfig::voltra();
    let off = ChipConfig::no_prefetch();
    let mut total_on = 0u64;
    let mut total_off = 0u64;
    for w in evaluation_suite() {
        let a = run_workload(&on, &w).metrics.total_latency_cycles();
        let b = run_workload(&off, &w).metrics.total_latency_cycles();
        assert!(
            a as f64 <= 1.01 * b as f64,
            "{}: prefetch-on {a} > prefetch-off {b}",
            w.name
        );
        total_on += a;
        total_off += b;
    }
    assert!(total_on <= total_off, "suite: {total_on} > {total_off}");
}

#[test]
fn pdma_prefetch_speedup_lands_in_paper_band() {
    // The paper's headline Fig. 6c claim: shared PDMA memory + MGDP
    // prefetching vs separated buffers without prefetching cuts total
    // latency 1.15 - 2.36x. Assert the transformer and ResNet-50
    // workloads land inside that band under the event-driven scheduler.
    let best = ChipConfig::voltra();
    let base = ChipConfig {
        prefetch: false,
        ..ChipConfig::separated_memory()
    };
    for name in ["bert", "resnet50"] {
        let w = by_name(name).unwrap();
        let fast = run_workload(&best, &w).metrics.total_latency_cycles() as f64;
        let slow = run_workload(&base, &w).metrics.total_latency_cycles() as f64;
        let ratio = slow / fast;
        assert!(
            (1.15..=2.36).contains(&ratio),
            "{name}: speedup {ratio:.2} outside the paper's 1.15-2.36x band"
        );
    }
}

#[test]
fn mixed_double_buffer_fused_layer_accounts_per_gemm() {
    // Regression: the layer runner used to recompute the WHOLE layer's
    // latency inside the per-GEMM loop using the CURRENT GEMM's
    // double-buffer flag — so a fused layer ending in a small ping-pong
    // GEMM reported the big single-buffered GEMM's DMA as hidden.
    let cfg = ChipConfig::voltra();
    let big = (512u64, 768u64, 768u64); // no ping-pong residency: serial
    let small = (64u64, 64u64, 64u64); // fits doubled: ping-pong granted
    let mut c1 = TileCache::new();
    let lm_big = run_layer(
        &cfg,
        &Layer::new("big", LayerKind::Gemm { m: big.0, k: big.1, n: big.2 }),
        &mut c1,
    );
    // Fixture sanity: the big GEMM really is single-buffered (its
    // standalone latency is the full serial sum).
    assert_eq!(
        lm_big.latency_cycles,
        lm_big.tiles.total_cycles + lm_big.aux_cycles + lm_big.dma_cycles
    );
    let mut c2 = TileCache::new();
    let lm_small = run_layer(
        &cfg,
        &Layer::new("small", LayerKind::Gemm { m: small.0, k: small.1, n: small.2 }),
        &mut c2,
    );
    let mut c3 = TileCache::new();
    let fused = Layer::new("fused", LayerKind::Fused(vec![big, small]));
    let lm = run_layer(&cfg, &fused, &mut c3);
    // Per-GEMM accounting: the serial GEMM's cost cannot hide behind the
    // trailing GEMM's ping-pong grant (the pre-fix code reported the
    // fused layer faster than its serial member alone).
    assert!(
        lm.latency_cycles >= lm_big.latency_cycles,
        "fused {} < its serial member {}",
        lm.latency_cycles,
        lm_big.latency_cycles
    );
    // And pipelining across the GEMM boundary can only help, never hurt.
    assert!(
        lm.latency_cycles <= lm_big.latency_cycles + lm_small.latency_cycles,
        "fused {} > serial members {} + {}",
        lm.latency_cycles,
        lm_big.latency_cycles,
        lm_small.latency_cycles
    );
}

#[test]
fn depth16_sweep_point_runs_a_full_workload_clean() {
    // Regression companion to the engine-level test: a deep-FIFO /
    // high-latency sweep point must survive a whole network end to end
    // (the fixed 8-slot in-flight ring corrupted this configuration).
    let mut cfg = ChipConfig::voltra();
    cfg.stream_fifo_depth = 16;
    cfg.mem_latency = 12;
    let w = by_name("pointnext").unwrap();
    let r = run_workload(&cfg, &w);
    let sim: u64 = r.metrics.layers.iter().map(|l| l.tiles.useful_macs).sum();
    assert_eq!(sim, w.total_macs());
    for l in &r.metrics.layers {
        if l.macs == 0 {
            continue;
        }
        let c = compute_cycles(l);
        assert!(l.latency_cycles >= c.max(l.dma_cycles), "{}", l.name);
        assert!(l.latency_cycles <= c + l.dma_cycles, "{}", l.name);
    }
}
