//! The conventional 2D spatial-array baseline of Fig. 6a.
//!
//! Same 512-MAC budget as Voltra, arranged as a 16 x 32 output-stationary
//! plane: M and N are unrolled spatially, K iterates temporally. This is
//! the "similar architectural template" of Sec. I (Fig. 1a) that suffers
//! on skinny/ragged M x N workloads — up to 2.0x lower spatial
//! utilization than the 3D array.

use crate::config::ArrayGeometry;
use crate::sim::gemm_core;

/// The baseline geometry used throughout the Fig. 6a comparison.
pub const BASELINE_2D: ArrayGeometry = ArrayGeometry::Spatial2D { m: 16, n: 32 };

/// Spatial utilization of a GEMM on the 2D baseline (best M/N mapping).
pub fn spatial_utilization(m: u64, k: u64, n: u64) -> f64 {
    gemm_core::spatial_utilization(BASELINE_2D, m, k, n)
}

/// Active cycles on the 2D baseline (K is temporal: one K-element per
/// cycle per output tile round).
pub fn ideal_active_cycles(m: u64, k: u64, n: u64) -> u64 {
    gemm_core::ideal_active_cycles(BASELINE_2D, m, k, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch;
    use crate::config::ArrayGeometry;

    #[test]
    fn same_mac_budget_as_voltra() {
        assert_eq!(BASELINE_2D.macs(), arch::MACS);
    }

    #[test]
    fn large_aligned_gemm_is_perfect() {
        assert!((spatial_utilization(128, 512, 128) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn three_d_never_loses_by_more_than_array_shape_allows() {
        // Property over a grid: the 3D array's utilization is >= the 2D's
        // whenever K is a multiple of 8 (no dot-product residue), since
        // its M/N unrolls (8, 8) divide the 2D's (16, 32).
        let a3 = ArrayGeometry::Spatial3D { m: 8, n: 8, k: 8 };
        for m in [1u64, 3, 6, 8, 13, 16, 24, 49, 64, 100, 112, 3136] {
            for n in [8u64, 16, 21, 24, 32, 64, 96, 1000] {
                let k = 64;
                let u3 = gemm_core::spatial_utilization(a3, m, k, n);
                let u2 = spatial_utilization(m, k, n);
                assert!(
                    u3 >= u2 - 1e-12,
                    "3D lost at m={m} n={n}: {u3:.4} vs {u2:.4}"
                );
            }
        }
    }

    #[test]
    fn cycle_count_trade_off() {
        // For a 64x64x64 GEMM both arrays need the same ideal cycles
        // (same MAC count): 512.
        assert_eq!(ideal_active_cycles(64, 64, 64), 512);
    }
}
