//! The RISC-V Snitch control core (Sec. II): a lightweight 32-bit integer
//! core that orchestrates the functional blocks and data streamers
//! through CSR writes.
//!
//! We model the *programming interface*, not the RV32I pipeline: a CSR
//! address map covering every streamer's base/bounds/strides registers,
//! the GEMM core's matrix-dimension registers and the SIMD unit's
//! quantization parameters, plus a cost model (one CSR write per cycle —
//! the configuration overhead the chip pays per tile launch).

use std::collections::BTreeMap;

use crate::sim::agu::LoopDim;
use crate::sim::streamer::{Grain, StreamerProgram};

/// CSR address blocks (one per programmable unit).
pub const CSR_GEMM_BASE: u32 = 0x3C0;
pub const CSR_STREAMER_BASE: u32 = 0x400;
/// CSRs per streamer: base_lo, base_hi, 6x(bound,stride), flags.
pub const CSR_PER_STREAMER: u32 = 0x20;
pub const CSR_SIMD_BASE: u32 = 0x600;

/// Streamer indices (the seven streamers of Fig. 2b).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum StreamerId {
    GemmInput = 0,
    GemmWeight = 1,
    GemmPsum = 2,
    GemmOutput = 3,
    SimdIn = 4,
    SimdOut = 5,
    Reshuffler = 6,
}

/// One CSR write (address, value) — the unit of control cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CsrWrite {
    pub addr: u32,
    pub value: u32,
}

/// A complete per-tile control program, as the Snitch core would emit.
#[derive(Clone, Debug, Default)]
pub struct CsrProgram {
    pub writes: Vec<CsrWrite>,
}

impl CsrProgram {
    /// Cycles to issue: one CSR instruction per write plus the launch.
    pub fn cycles(&self) -> u64 {
        self.writes.len() as u64 + 1
    }

    pub fn push(&mut self, addr: u32, value: u32) {
        self.writes.push(CsrWrite { addr, value });
    }

    /// Program the GEMM core's hardware loop controller with the tile
    /// dimensions (it clears accumulators at each output-tile boundary).
    pub fn program_gemm_dims(&mut self, tm: u32, tk: u32, tn: u32, psum_in: bool) {
        self.push(CSR_GEMM_BASE, tm);
        self.push(CSR_GEMM_BASE + 1, tk);
        self.push(CSR_GEMM_BASE + 2, tn);
        self.push(CSR_GEMM_BASE + 3, psum_in as u32);
    }

    /// Program one streamer's AGU (base pointer, loop bounds, strides,
    /// grain/transpose flags).
    pub fn program_streamer(&mut self, id: StreamerId, prog: &StreamerProgram) {
        let base = CSR_STREAMER_BASE + (id as u32) * CSR_PER_STREAMER;
        self.push(base, (prog.base_word & 0xFFFF_FFFF) as u32);
        self.push(base + 1, (prog.base_word >> 32) as u32);
        for (i, d) in prog.dims.iter().enumerate() {
            let i = i as u32;
            self.push(base + 2 + 2 * i, d.bound as u32);
            self.push(base + 3 + 2 * i, d.stride as u32);
        }
        let flags = match prog.grain {
            Grain::Fine => 0u32,
            Grain::Coarse => 1,
        } | ((prog.transpose as u32) << 1)
            | ((prog.dims.len() as u32) << 2);
        self.push(base + 2 + 12, flags);
    }

    pub fn program_simd(&mut self, scale_bits: u32, relu: bool) {
        self.push(CSR_SIMD_BASE, scale_bits);
        self.push(CSR_SIMD_BASE + 1, relu as u32);
    }
}

/// A CSR register file that accepts programs and can reconstruct the
/// streamer configuration (used by tests to verify round-tripping).
#[derive(Clone, Debug, Default)]
pub struct CsrFile {
    regs: BTreeMap<u32, u32>,
}

impl CsrFile {
    pub fn apply(&mut self, prog: &CsrProgram) {
        for w in &prog.writes {
            self.regs.insert(w.addr, w.value);
        }
    }

    pub fn read(&self, addr: u32) -> u32 {
        *self.regs.get(&addr).unwrap_or(&0)
    }

    /// Reconstruct a streamer program from the register file.
    pub fn decode_streamer(&self, id: StreamerId) -> StreamerProgram {
        let base = CSR_STREAMER_BASE + (id as u32) * CSR_PER_STREAMER;
        let base_word =
            (self.read(base) as u64) | ((self.read(base + 1) as u64) << 32);
        let flags = self.read(base + 2 + 12);
        let ndims = (flags >> 2) as usize;
        let mut dims = Vec::with_capacity(ndims);
        for i in 0..ndims as u32 {
            dims.push(LoopDim {
                bound: self.read(base + 2 + 2 * i) as u64,
                stride: self.read(base + 3 + 2 * i) as i32 as i64,
            });
        }
        let grain = if flags & 1 == 1 {
            Grain::Coarse
        } else {
            Grain::Fine
        };
        let mut p = StreamerProgram::new(base_word, dims, grain);
        if flags & 2 != 0 {
            p = p.with_transpose();
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streamer_program_roundtrips_through_csrs() {
        let prog = StreamerProgram::new(
            0x1_0000_0010,
            vec![
                LoopDim { bound: 8, stride: 1 },
                LoopDim {
                    bound: 4,
                    stride: -64,
                },
                LoopDim {
                    bound: 2,
                    stride: 512,
                },
            ],
            Grain::Coarse,
        )
        .with_transpose();
        let mut cp = CsrProgram::default();
        cp.program_streamer(StreamerId::GemmWeight, &prog);
        let mut rf = CsrFile::default();
        rf.apply(&cp);
        let got = rf.decode_streamer(StreamerId::GemmWeight);
        assert_eq!(got, prog);
    }

    #[test]
    fn programs_cost_one_cycle_per_write() {
        let mut cp = CsrProgram::default();
        cp.program_gemm_dims(64, 512, 64, false);
        assert_eq!(cp.cycles(), 4 + 1);
    }

    #[test]
    fn streamer_blocks_do_not_overlap() {
        // Each streamer owns CSR_PER_STREAMER addresses; the highest
        // register used (flags at +14) must fit.
        assert!(2 + 12 < CSR_PER_STREAMER);
        let mut cp = CsrProgram::default();
        let p = StreamerProgram::new(0, vec![LoopDim { bound: 1, stride: 0 }; 6], Grain::Fine);
        cp.program_streamer(StreamerId::GemmInput, &p);
        cp.program_streamer(StreamerId::GemmWeight, &p);
        let addrs: Vec<u32> = cp.writes.iter().map(|w| w.addr).collect();
        let unique: std::collections::BTreeSet<u32> = addrs.iter().copied().collect();
        assert_eq!(addrs.len(), unique.len(), "overlapping CSR addresses");
    }

    #[test]
    fn negative_strides_survive() {
        let prog = StreamerProgram::new(
            0,
            vec![LoopDim {
                bound: 3,
                stride: -8,
            }],
            Grain::Fine,
        );
        let mut cp = CsrProgram::default();
        cp.program_streamer(StreamerId::GemmPsum, &prog);
        let mut rf = CsrFile::default();
        rf.apply(&cp);
        assert_eq!(
            rf.decode_streamer(StreamerId::GemmPsum).dims[0].stride,
            -8
        );
    }
}
