//! The cycle-accurate tile engine: one on-chip GEMM tile, cycle by cycle.
//!
//! This is the model that produces Fig. 6b's temporal-utilization numbers.
//! Every cycle:
//!   1. memory responses arrive (after `mem_latency`) and fill the
//!      streamers' FIFOs;
//!   2. the spatial array fires iff every operand FIFO can supply this
//!      step (and, for a continuation tile, the partial sums have been
//!      re-injected) — otherwise it stalls;
//!   3. finished 8x8 output tiles drain through the quantization SIMD
//!      (`simd_lanes` results per cycle) and the output streamer writes
//!      words back through the (possibly time-multiplexed) psum/output
//!      crossbar port;
//!   4. streamer MICs issue next bank requests — running *ahead* of the
//!      array when MGDP prefetching is on, or only on demand when it is
//!      off — and the banks arbitrate.
//!
//! With prefetching, the eight-deep FIFOs absorb bank-conflict jitter and
//! access latency; without it, every conflict and every latency cycle
//! lands on the array — the "severe bank contention" of Sec. I.

use crate::config::{ArrayGeometry, ChipConfig, MemoryOrg};
use crate::metrics::TileMetrics;
use crate::sim::gemm_core::block_residue;
use crate::sim::memory::{BankRequest, BankedMemory, Requester};

/// Static description of one tile execution (the memoization key).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TileSpec {
    pub tm: u64,
    pub tk: u64,
    pub tn: u64,
    /// Continuation of a K-tiled accumulation: psums stream in first.
    pub psum_in: bool,
    /// Not the last K-round: spill int32 psums (bypass quantization).
    pub spill_out: bool,
    /// Input operand was reshuffled to the blocked layout (C8HWC8 /
    /// blocked row-major, Sec. II-E). Raw row-major layouts conflict.
    pub input_blocked: bool,
    /// K-extension fold of the mapping this tile runs under (array rows
    /// re-mapped onto extra K lanes, 3D only; 1 = none). Part of the
    /// memoization key: the same (tm, tk, tn) fires differently per
    /// fold — fewer, denser steps, with `fold` weight super-bank
    /// fetches per step.
    pub fold: u8,
    /// Region base word addresses (from the allocator). Bank alignment
    /// of these bases decides which accesses collide.
    pub in_base: u64,
    pub w_base: u64,
    pub p_base: u64,
    pub o_base: u64,
}

impl TileSpec {
    /// A standalone tile with the default PDMA-style placement.
    pub fn simple(tm: u64, tk: u64, tn: u64) -> Self {
        TileSpec {
            tm,
            tk,
            tn,
            psum_in: false,
            spill_out: false,
            input_blocked: true,
            fold: 1,
            in_base: 0,
            w_base: 8, // next super-bank group
            p_base: 16,
            o_base: 24,
        }
    }

    /// A standalone tile under a K-extension fold.
    pub fn folded(tm: u64, tk: u64, tn: u64, fold: u8) -> Self {
        TileSpec {
            fold,
            ..Self::simple(tm, tk, tn)
        }
    }
}

const MAX_CHANNELS: usize = 8;

/// Weight-channel cap: bounds the folded super-bank fetch fan-out and
/// keeps the per-request kind codes (inputs 0..=99, weights
/// 100..=249, psum 250, output 251) collision-free for any `TileSpec`.
const MAX_WEIGHT_CHANNELS: usize = 128;

/// Per-channel streamer state (input lanes + weight lane). The MIC
/// pipelines requests: it may have several accesses in flight (the bank
/// accepts one per cycle), bounded by the FIFO space it reserved.
///
/// The in-flight queue is sized from the *configured* FIFO depth
/// (`ChipConfig::stream_fifo_depth` is a sweep axis, not a hardware
/// constant): a fixed 8-slot ring silently corrupted depth > 8 sweep
/// points whenever the memory latency let more than eight requests pile
/// up (regression-tested below).
#[derive(Clone)]
struct Channel {
    issued: u64,
    /// Words sitting in the FIFO, not yet consumed.
    fill: u64,
    /// In-flight queue: landing cycles of outstanding requests, in
    /// issue order (the MIC issues <= 1/cycle, so landings are FIFO).
    ready: std::collections::VecDeque<u64>,
    /// Reserved FIFO space bounds outstanding requests: `fill +
    /// inflight < cap` is the issue condition, so `cap` slots suffice.
    cap: usize,
}

impl Channel {
    fn new(cap: usize) -> Self {
        Channel {
            issued: 0,
            fill: 0,
            ready: std::collections::VecDeque::with_capacity(cap.max(1)),
            cap: cap.max(1),
        }
    }

    fn inflight(&self) -> u64 {
        self.ready.len() as u64
    }

    fn launch(&mut self, lands_at: u64) {
        debug_assert!(self.ready.len() < self.cap, "in-flight overflow: issue gating broken");
        self.ready.push_back(lands_at);
    }

    /// Pop at most one arrival this cycle (the MIC issues <= 1/cycle so
    /// landings are also <= 1/cycle).
    fn arrive(&mut self, cycle: u64) -> bool {
        if self.ready.front() == Some(&cycle) {
            self.ready.pop_front();
            self.fill += 1;
            true
        } else {
            false
        }
    }
}

/// Simulate one tile on the configured array, under the tile's
/// K-extension fold. Returns activity counters.
pub fn simulate_tile(cfg: &ChipConfig, spec: &TileSpec) -> TileMetrics {
    let macs = cfg.array.macs() as u64;
    let separate_ports = matches!(cfg.memory, MemoryOrg::Separated { .. });

    // Effective unrolls after folding `fold` array rows onto extra K
    // lanes (3D only), plus the mapped streamer channel structure:
    // `n_in` fine input fetches and `n_w_ch` weight fetches of
    // `w_stride` words per step. Folding multiplies the weight fetches
    // (each folded row group needs its own K-slice of the weights).
    // The fold cannot exceed the physical row count, and the weight
    // request encoding below reserves codes 100..=249 for the weight
    // channels (psum/output live at 250/251) — clamp rather than let a
    // hostile TileSpec alias another channel's code.
    let fold = match cfg.array {
        ArrayGeometry::Spatial3D { m, .. } => {
            (spec.fold.max(1) as u64).min(m as u64).min(MAX_WEIGHT_CHANNELS as u64)
        }
        ArrayGeometry::Spatial2D { .. } => 1,
    };
    let (am, an, ak, n_in, n_w_ch, w_stride, w_super) = match cfg.array {
        ArrayGeometry::Spatial3D { m, n, k } => (
            (m as u64 / fold).max(1),
            n as u64,
            k as u64 * fold,
            m.min(MAX_CHANNELS),
            fold as usize,
            8u64, // one aligned super bank per fetch
            true,
        ),
        ArrayGeometry::Spatial2D { m, n } => (
            m as u64,
            n as u64,
            1u64,
            (m / 8).max(1).min(MAX_CHANNELS),
            1usize,
            (n / 8).max(1) as u64,
            false,
        ),
    };
    let sub_m = spec.tm.div_ceil(am).max(1);
    let sub_n = spec.tn.div_ceil(an).max(1);
    let ksteps = spec.tk.div_ceil(ak).max(1);
    let n_sub = sub_m * sub_n;
    let total_steps = n_sub * ksteps;
    let outputs_per_sub = am * an;
    // Psum words per subtile: int32 accumulators, 2 per 64-bit word.
    let psum_words_per_sub = (outputs_per_sub * 4).div_ceil(8);
    // Valid (non-padding) results per subtile and their output bytes
    // (int8 after quantization, int32 if spilled): residue-aware — the
    // SIMD and the output streamer only handle real results.
    let out_bytes_per_result: u64 = if spec.spill_out { 4 } else { 1 };
    let mut out_total_bytes: u64 = 0;
    for ti in 0..sub_m {
        for tj in 0..sub_n {
            let mr = block_residue(spec.tm, am, ti);
            let nr = block_residue(spec.tn, an, tj);
            out_total_bytes += mr * nr * out_bytes_per_result;
        }
    }

    let fifo_depth = if cfg.prefetch {
        cfg.stream_fifo_depth as u64
    } else {
        1
    };

    let mut mem = BankedMemory::with_size(crate::arch::DATA_MEM_BYTES, cfg.num_banks);
    let mut inputs: Vec<Channel> =
        (0..MAX_CHANNELS).map(|_| Channel::new(fifo_depth as usize)).collect();
    let mut weights: Vec<Channel> =
        (0..n_w_ch).map(|_| Channel::new(fifo_depth as usize)).collect();
    // Psum prefetch progress (words delivered / issued).
    let mut psum_issued: u64 = 0;
    let mut psum_fill: u64 = 0;
    let mut psum_pending: u64 = u64::MAX;
    let psum_total = if spec.psum_in {
        n_sub * psum_words_per_sub
    } else {
        0
    };

    // SIMD queue (results awaiting quantization) and output byte queue.
    let mut simd_queue: u64 = 0;
    let mut out_bytes: u64 = 0;
    let mut out_written_bytes: u64 = 0;

    let mut fired: u64 = 0;
    let mut m = TileMetrics::default();
    let mut cycle: u64 = 0;
    // Reused request buffer: keep the hot loop allocation-free.
    let mut reqs: Vec<BankRequest> = Vec::with_capacity(MAX_CHANNELS + 4);
    let mut req_kind: Vec<u8> = Vec::with_capacity(MAX_CHANNELS + 4);

    let row_stride_words = ksteps; // raw row-major: one K-row per array row
    let max_cycles = 1_000_000 + total_steps * 64;

    while (fired < total_steps || simd_queue > 0 || out_written_bytes < out_total_bytes)
        && cycle < max_cycles
    {
        // ---- 1. arrivals ------------------------------------------------
        for ch in inputs.iter_mut().take(n_in) {
            if ch.arrive(cycle) {
                m.fifo_events += 1;
            }
        }
        for ch in weights.iter_mut() {
            if ch.arrive(cycle) {
                m.fifo_events += 1;
            }
        }
        if psum_pending == cycle {
            psum_pending = u64::MAX;
            psum_fill += 1;
            m.fifo_events += 1;
        }

        // ---- 2. fire the array ------------------------------------------
        if fired < total_steps {
            let sub = fired / ksteps;
            let ks = fired % ksteps;
            let ti = sub / sub_n;
            let tj = sub % sub_n;
            let inputs_ready = inputs.iter().take(n_in).all(|c| c.fill > 0);
            let weight_ready = weights.iter().all(|c| c.fill > 0);
            let psum_ready = !spec.psum_in || psum_fill >= (sub + 1) * psum_words_per_sub
                || psum_fill == psum_total; // degenerate tail
            // Output registers are double-buffered: a subtile may finish
            // while the *previous* subtile's results still drain through
            // the SIMD, but not while two subtiles' worth are pending.
            let regs_free = ks < ksteps - 1 || simd_queue <= outputs_per_sub;
            if inputs_ready && weight_ready && psum_ready && regs_free {
                for ch in inputs.iter_mut().take(n_in) {
                    ch.fill -= 1;
                    m.fifo_events += 1;
                }
                for ch in weights.iter_mut() {
                    ch.fill -= 1;
                    m.fifo_events += 1;
                }
                fired += 1;
                m.active_cycles += 1;
                let mr = block_residue(spec.tm, am, ti);
                let nr = block_residue(spec.tn, an, tj);
                let kr = block_residue(spec.tk, ak, ks);
                m.useful_macs += mr * nr * kr;
                m.offered_macs += macs;
                // Subtile complete: valid results to the SIMD / spill path.
                if fired % ksteps == 0 {
                    let valid = mr * nr;
                    if spec.spill_out {
                        out_bytes += valid * 4;
                    } else {
                        simd_queue += valid;
                    }
                }
            } else {
                m.stall_cycles += 1;
            }
        }

        // ---- 3. SIMD drain + output write -------------------------------
        if simd_queue > 0 {
            let done = simd_queue.min(cfg.simd_lanes as u64);
            simd_queue -= done;
            m.simd_cycles += 1;
            if !spec.spill_out {
                // Quantized int8 results pack into the output FIFO.
                out_bytes += done;
            }
        }

        // ---- 4. issue requests + arbitration -----------------------------
        reqs.clear();
        req_kind.clear();
        // Input channels (fine-grained 64-bit, Fig. 3a).
        for (r, ch) in inputs.iter_mut().enumerate().take(n_in) {
            if ch.issued < total_steps && ch.fill + ch.inflight() < fifo_depth {
                let demand_ok =
                    cfg.prefetch || (ch.fill == 0 && ch.inflight() == 0 && ch.issued == fired);
                if demand_ok {
                    let s = ch.issued;
                    let sub = s / ksteps;
                    let ks = s % ksteps;
                    let ti = sub / sub_n;
                    let addr = if spec.input_blocked {
                        spec.in_base + s * n_in as u64 + r as u64
                    } else {
                        spec.in_base + (ti * am + r as u64) * row_stride_words + ks
                    };
                    reqs.push(BankRequest {
                        word_addr: addr,
                        write: false,
                        requester: Requester::Input(r as u8),
                        super_bank: false,
                    });
                    req_kind.push(r as u8);
                }
            }
        }
        // Weight channels (coarse-grained 512-bit super banks, Fig. 3b;
        // a folded mapping fetches `fold` parallel K-slices per step).
        for (c, ch) in weights.iter_mut().enumerate() {
            if ch.issued < total_steps && ch.fill + ch.inflight() < fifo_depth {
                let demand_ok =
                    cfg.prefetch || (ch.fill == 0 && ch.inflight() == 0 && ch.issued == fired);
                if demand_ok {
                    let s = ch.issued;
                    let sub = s / ksteps;
                    let ks = s % ksteps;
                    let tj = sub % sub_n;
                    let addr =
                        spec.w_base + ((tj * ksteps + ks) * n_w_ch as u64 + c as u64) * w_stride;
                    reqs.push(BankRequest {
                        word_addr: addr,
                        write: false,
                        requester: Requester::Weight,
                        super_bank: w_super,
                    });
                    req_kind.push(100 + c as u8);
                }
            }
        }
        // Psum read & output write share a crossbar port when tmux'd;
        // psum has priority (Sec. II-D).
        let psum_wants = spec.psum_in && psum_issued < psum_total && psum_pending == u64::MAX;
        // Write a 64-bit word when one is full, or flush the tail once
        // compute has finished.
        let drained = fired >= total_steps && simd_queue == 0;
        let out_wants = out_bytes >= 8 || (drained && out_bytes > 0);
        let (psum_go, out_go) = if cfg.tmux_psum_output {
            if psum_wants {
                (true, false)
            } else {
                (false, out_wants)
            }
        } else {
            (psum_wants, out_wants)
        };
        if psum_go {
            reqs.push(BankRequest {
                word_addr: spec.p_base + psum_issued,
                write: false,
                requester: Requester::Psum,
                super_bank: false,
            });
            req_kind.push(250);
        }
        if out_go {
            reqs.push(BankRequest {
                word_addr: spec.o_base + out_written_bytes / 8,
                write: true,
                requester: Requester::Output,
                super_bank: false,
            });
            req_kind.push(251);
        }

        if separate_ports {
            // Dedicated per-operand buffers: every request is served by
            // its own SRAM — no cross-class arbitration (Fig. 1a).
            for (i, r) in reqs.iter().enumerate() {
                let kind = req_kind[i];
                match kind {
                    0..=99 => {
                        let ch = &mut inputs[kind as usize];
                        ch.issued += 1;
                        ch.launch(cycle + cfg.mem_latency);
                    }
                    w @ 100..=249 => {
                        let ch = &mut weights[(w - 100) as usize];
                        ch.issued += 1;
                        ch.launch(cycle + cfg.mem_latency);
                    }
                    250 => {
                        psum_issued += 1;
                        psum_pending = cycle + cfg.mem_latency;
                    }
                    251 => {
                        let chunk = out_bytes.min(8);
                        out_written_bytes += chunk;
                        out_bytes -= chunk;
                        m.bank_writes += 1;
                    }
                    _ => unreachable!(),
                }
                if !r.write {
                    m.bank_reads += if r.super_bank { 8 } else { 1 };
                }
            }
        } else {
            let res = mem.arbitrate(&reqs);
            m.bank_reads += res.reads;
            m.bank_writes += res.writes;
            m.bank_conflicts += res.denied.len() as u64;
            for &gi in &res.granted {
                match req_kind[gi] {
                    r @ 0..=99 => {
                        let ch = &mut inputs[r as usize];
                        ch.issued += 1;
                        ch.launch(cycle + cfg.mem_latency);
                    }
                    w @ 100..=249 => {
                        let ch = &mut weights[(w - 100) as usize];
                        ch.issued += 1;
                        ch.launch(cycle + cfg.mem_latency);
                    }
                    250 => {
                        psum_issued += 1;
                        psum_pending = cycle + cfg.mem_latency;
                    }
                    251 => {
                        let chunk = out_bytes.min(8);
                        out_written_bytes += chunk;
                        out_bytes -= chunk;
                    }
                    _ => unreachable!(),
                }
            }
        }

        cycle += 1;
    }

    debug_assert!(cycle < max_cycles, "tile simulation did not converge");
    m.total_cycles = cycle;
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    fn total_useful(tm: u64, tk: u64, tn: u64) -> u64 {
        tm * tk * tn
    }

    #[test]
    fn aligned_tile_counts_exact_macs() {
        let cfg = ChipConfig::voltra();
        let spec = TileSpec::simple(64, 64, 64);
        let m = simulate_tile(&cfg, &spec);
        assert_eq!(m.useful_macs, total_useful(64, 64, 64));
        // 64 subtiles x 8 ksteps of firing.
        assert_eq!(m.active_cycles, 512);
        assert!(m.total_cycles >= 512);
    }

    #[test]
    fn prefetch_beats_demand_fetch() {
        let spec = TileSpec::simple(64, 256, 64);
        let with = simulate_tile(&ChipConfig::voltra(), &spec);
        let without = simulate_tile(&ChipConfig::no_prefetch(), &spec);
        assert_eq!(with.useful_macs, without.useful_macs);
        let ru = with.temporal_utilization() / without.temporal_utilization();
        assert!(
            ru > 1.5,
            "MGDP should dominate demand fetching, got ratio {ru:.2} \
             ({:.3} vs {:.3})",
            with.temporal_utilization(),
            without.temporal_utilization()
        );
    }

    #[test]
    fn voltra_reaches_high_temporal_utilization() {
        let spec = TileSpec::simple(64, 512, 64);
        let m = simulate_tile(&ChipConfig::voltra(), &spec);
        let u = m.temporal_utilization();
        assert!(u > 0.75, "expected >0.75 temporal utilization, got {u:.3}");
    }

    #[test]
    fn separated_memory_has_no_conflicts() {
        let spec = TileSpec::simple(64, 128, 64);
        let m = simulate_tile(&ChipConfig::separated_memory(), &spec);
        assert_eq!(m.bank_conflicts, 0);
        assert!(m.temporal_utilization() > 0.85);
    }

    #[test]
    fn ragged_tile_underfills_spatially() {
        let cfg = ChipConfig::voltra();
        let m = simulate_tile(&cfg, &TileSpec::simple(6, 64, 64));
        assert_eq!(m.useful_macs, 6 * 64 * 64);
        let su = m.spatial_utilization();
        assert!((su - 0.75).abs() < 1e-9, "6/8 fill expected, got {su}");
    }

    #[test]
    fn folded_gemv_tile_fills_the_array() {
        // K-extension (fold 8): a GEMV tile fires 1 row x 8 cols x 64 K
        // lanes per step — full spatial fill instead of 12.5%, at 8x
        // fewer steps.
        let cfg = ChipConfig::voltra();
        let folded = simulate_tile(&cfg, &TileSpec::folded(1, 128, 256, 8));
        assert_eq!(folded.useful_macs, total_useful(1, 128, 256));
        assert_eq!(folded.active_cycles, 32 * 2); // 32 subtiles x 2 ksteps
        assert!((folded.spatial_utilization() - 1.0).abs() < 1e-12);
        let flat = simulate_tile(&cfg, &TileSpec::simple(1, 128, 256));
        assert_eq!(flat.useful_macs, folded.useful_macs);
        assert_eq!(flat.active_cycles, 8 * folded.active_cycles);
        assert!((flat.spatial_utilization() - 0.125).abs() < 1e-12);
        // The fold trades weight bandwidth for fill: fewer total cycles
        // despite the 8 super-bank fetches per step.
        assert!(folded.total_cycles < flat.total_cycles);
    }

    #[test]
    fn folded_tiles_conserve_macs_at_every_fold() {
        let cfg = ChipConfig::voltra();
        for fold in [1u8, 2, 4, 8] {
            for (tm, tk, tn) in [(1, 128, 256), (6, 96, 40), (13, 57, 9)] {
                let m = simulate_tile(&cfg, &TileSpec::folded(tm, tk, tn, fold));
                assert_eq!(m.useful_macs, total_useful(tm, tk, tn), "fold {fold}");
                assert!(m.spatial_utilization() <= 1.0 + 1e-12);
                assert!(m.temporal_utilization() <= 1.0 + 1e-12);
            }
        }
    }

    #[test]
    fn fold_is_inert_on_the_2d_array() {
        // The 2D baseline has no spatial K axis: the fold field must be
        // ignored, not misinterpreted.
        let cfg = ChipConfig::array2d();
        let a = simulate_tile(&cfg, &TileSpec::simple(32, 64, 32));
        let b = simulate_tile(&cfg, &TileSpec::folded(32, 64, 32, 8));
        assert_eq!(a, b);
    }

    #[test]
    fn continuation_tile_reads_psums() {
        let cfg = ChipConfig::voltra();
        let mut spec = TileSpec::simple(32, 64, 32);
        spec.psum_in = true;
        let m = simulate_tile(&cfg, &spec);
        // 16 subtiles x 32 psum words must have been read.
        assert!(m.bank_reads > 16 * 32);
        assert_eq!(m.useful_macs, 32 * 64 * 32);
    }

    #[test]
    fn spill_tile_writes_int32() {
        let cfg = ChipConfig::voltra();
        let mut spill = TileSpec::simple(32, 64, 32);
        spill.spill_out = true;
        let mut quant = TileSpec::simple(32, 64, 32);
        quant.spill_out = false;
        let ms = simulate_tile(&cfg, &spill);
        let mq = simulate_tile(&cfg, &quant);
        assert!(
            ms.bank_writes > mq.bank_writes,
            "int32 spill ({}) must write more words than int8 ({})",
            ms.bank_writes,
            mq.bank_writes
        );
    }

    #[test]
    fn raw_layout_conflicts_more_than_blocked() {
        let cfg = ChipConfig::no_prefetch();
        let mut raw = TileSpec::simple(64, 256, 64);
        raw.input_blocked = false;
        let blocked = TileSpec::simple(64, 256, 64);
        let mr = simulate_tile(&cfg, &raw);
        let mb = simulate_tile(&cfg, &blocked);
        assert!(
            mr.bank_conflicts >= mb.bank_conflicts,
            "row-major input should not conflict less ({} vs {})",
            mr.bank_conflicts,
            mb.bank_conflicts
        );
    }

    #[test]
    fn simulation_terminates_on_minimal_tile() {
        let cfg = ChipConfig::voltra();
        let m = simulate_tile(&cfg, &TileSpec::simple(1, 1, 1));
        assert_eq!(m.useful_macs, 1);
        assert_eq!(m.active_cycles, 1);
    }

    #[test]
    fn deep_fifo_with_slow_memory_keeps_inflight_queue_consistent() {
        // Regression: `stream_fifo_depth` is configurable but the
        // in-flight ring was hardcoded to 8 slots — a depth-16 sweep
        // point with a memory latency that lets >8 requests pile up
        // tripped the debug assertion (and corrupted the ring in
        // release). The queue is now sized from the config.
        let mut cfg = ChipConfig::voltra();
        cfg.stream_fifo_depth = 16;
        cfg.mem_latency = 12;
        let spec = TileSpec::simple(64, 256, 64);
        let m = simulate_tile(&cfg, &spec);
        assert_eq!(m.useful_macs, 64 * 256 * 64);
        // The deep FIFO must actually cover the latency: utilization
        // stays pipelined, nowhere near demand-fetch levels.
        let u = m.temporal_utilization();
        assert!(u > 0.5, "depth-16 pipelining collapsed: {u:.3}");
    }
}
