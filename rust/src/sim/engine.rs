//! The cycle-accurate tile engine: one on-chip GEMM tile, cycle by cycle.
//!
//! This is the model that produces Fig. 6b's temporal-utilization numbers.
//! Every cycle:
//!   1. memory responses arrive (after `mem_latency`) and fill the
//!      streamers' FIFOs;
//!   2. the spatial array fires iff every operand FIFO can supply this
//!      step (and, for a continuation tile, the partial sums have been
//!      re-injected) — otherwise it stalls;
//!   3. finished 8x8 output tiles drain through the quantization SIMD
//!      (`simd_lanes` results per cycle) and the output streamer writes
//!      words back through the (possibly time-multiplexed) psum/output
//!      crossbar port;
//!   4. streamer MICs issue next bank requests — running *ahead* of the
//!      array when MGDP prefetching is on, or only on demand when it is
//!      off — and the banks arbitrate.
//!
//! With prefetching, the eight-deep FIFOs absorb bank-conflict jitter and
//! access latency; without it, every conflict and every latency cycle
//! lands on the array — the "severe bank contention" of Sec. I.
//!
//! # The steady-state fast path (DESIGN.md §12)
//!
//! Walking every cycle is exact but slow, and the mapper multiplied the
//! walk by its candidate count. [`simulate_tile`] therefore dispatches
//! eligible tiles to a *row-recurrence* fast path: at each subtile-row
//! boundary it captures the machine's complete state **relative to the
//! boundary** (FIFO fills, in-flight landing offsets, next-request bank
//! phases, psum/output progress, the arbiter's round-robin pointer).
//! When the same relative key recurs at a later row boundary, the
//! dynamics between the two boundaries are provably periodic — the model
//! is deterministic and time-invariant, and every address stream is
//! linear or row-periodic, so equal bank phases at matched boundaries
//! stay equal forever. The walk then jumps whole periods at once by
//! adding the observed per-period deltas to every counter, landing far
//! enough from the final rows that no end-of-tile guard can bind inside
//! the jumped span. Bit-identity to the reference walk is pinned by the
//! differential fuzz (`tests/differential.rs`, mirrored by the Python
//! oracle `python/tests/test_fastpath_differential.py`) and by the unit
//! tests below; ineligible tiles fall back to the per-cycle walk.

use std::collections::HashMap;

use crate::config::{ArrayGeometry, ChipConfig, MemoryOrg};
use crate::metrics::TileMetrics;
use crate::sim::gemm_core::{block_residue, TileGeometry, MAX_INPUT_CHANNELS};
use crate::sim::memory::{BankRequest, BankedMemory, Requester};

/// Static description of one tile execution (the memoization key).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TileSpec {
    pub tm: u64,
    pub tk: u64,
    pub tn: u64,
    /// Continuation of a K-tiled accumulation: psums stream in first.
    pub psum_in: bool,
    /// Not the last K-round: spill int32 psums (bypass quantization).
    pub spill_out: bool,
    /// Input operand was reshuffled to the blocked layout (C8HWC8 /
    /// blocked row-major, Sec. II-E). Raw row-major layouts conflict.
    pub input_blocked: bool,
    /// K-extension fold of the mapping this tile runs under (array rows
    /// re-mapped onto extra K lanes, 3D only; 1 = none). Part of the
    /// memoization key: the same (tm, tk, tn) fires differently per
    /// fold — fewer, denser steps, with `fold` weight super-bank
    /// fetches per step.
    pub fold: u8,
    /// Region base word addresses (from the allocator). Bank alignment
    /// of these bases decides which accesses collide.
    pub in_base: u64,
    pub w_base: u64,
    pub p_base: u64,
    pub o_base: u64,
}

impl TileSpec {
    /// A standalone tile with the default PDMA-style placement.
    pub fn simple(tm: u64, tk: u64, tn: u64) -> Self {
        TileSpec {
            tm,
            tk,
            tn,
            psum_in: false,
            spill_out: false,
            input_blocked: true,
            fold: 1,
            in_base: 0,
            w_base: 8, // next super-bank group
            p_base: 16,
            o_base: 24,
        }
    }

    /// A standalone tile under a K-extension fold.
    pub fn folded(tm: u64, tk: u64, tn: u64, fold: u8) -> Self {
        TileSpec {
            fold,
            ..Self::simple(tm, tk, tn)
        }
    }
}

/// Row-boundary snapshots retained while hunting for a recurrence —
/// a bound, not a tuning knob: distinct keys at successive boundaries
/// mean the machine is still in a transient; 64 rows of transient means
/// the tile is irregular enough that walking it is the honest answer.
const SNAPSHOT_CAP: usize = 64;

/// Per-channel streamer state (input lanes + weight lane). The MIC
/// pipelines requests: it may have several accesses in flight (the bank
/// accepts one per cycle), bounded by the FIFO space it reserved.
///
/// The in-flight queue is sized from the *configured* FIFO depth
/// (`ChipConfig::stream_fifo_depth` is a sweep axis, not a hardware
/// constant): a fixed 8-slot ring silently corrupted depth > 8 sweep
/// points whenever the memory latency let more than eight requests pile
/// up (regression-tested below).
#[derive(Clone)]
struct Channel {
    issued: u64,
    /// Words sitting in the FIFO, not yet consumed.
    fill: u64,
    /// In-flight queue: landing cycles of outstanding requests, in
    /// issue order (the MIC issues <= 1/cycle, so landings are FIFO).
    ready: std::collections::VecDeque<u64>,
    /// Reserved FIFO space bounds outstanding requests: `fill +
    /// inflight < cap` is the issue condition, so `cap` slots suffice.
    cap: usize,
}

impl Channel {
    fn new(cap: usize) -> Self {
        Channel {
            issued: 0,
            fill: 0,
            ready: std::collections::VecDeque::with_capacity(cap.max(1)),
            cap: cap.max(1),
        }
    }

    fn inflight(&self) -> u64 {
        self.ready.len() as u64
    }

    fn launch(&mut self, lands_at: u64) {
        debug_assert!(self.ready.len() < self.cap, "in-flight overflow: issue gating broken");
        self.ready.push_back(lands_at);
    }

    /// Pop at most one arrival this cycle (the MIC issues <= 1/cycle so
    /// landings are also <= 1/cycle).
    fn arrive(&mut self, cycle: u64) -> bool {
        if self.ready.front() == Some(&cycle) {
            self.ready.pop_front();
            self.fill += 1;
            true
        } else {
            false
        }
    }
}

/// Everything `marks()` freezes at a row boundary: the absolute
/// counters whose per-period deltas `try_jump` replays, plus the
/// psum-gating count that proves an active-stream jump sound.
#[derive(Clone)]
struct RowMark {
    row: u64,
    cycle: u64,
    fired: u64,
    in_issued: Vec<u64>,
    w_issued: Vec<u64>,
    psum_issued: u64,
    psum_fill: u64,
    out_written_bytes: u64,
    metrics: TileMetrics,
    psum_unready: u64,
}

/// The per-tile cycle simulator, factored into explicit state so the
/// steady-state fast path can snapshot, compare and advance it. The
/// reference walk is `cycle_once` in a loop — the refactor changes no
/// behavior (the pre-refactor unit tests below are untouched).
struct TileSim<'a> {
    cfg: &'a ChipConfig,
    spec: TileSpec,
    g: TileGeometry,
    macs: u64,
    separate_ports: bool,
    nb: u64,
    mem: BankedMemory,
    inputs: Vec<Channel>,
    weights: Vec<Channel>,
    psum_issued: u64,
    psum_fill: u64,
    psum_pending: u64,
    simd_queue: u64,
    out_bytes: u64,
    out_written_bytes: u64,
    fired: u64,
    /// Fire evaluations where `psum_ready` was false. Fast-path guard:
    /// a jump over an *active* psum stream is only sound if the stream
    /// never gated the array during the observed period.
    psum_unready: u64,
    m: TileMetrics,
    cycle: u64,
    // Reused request buffers: keep the hot loop allocation-free.
    reqs: Vec<BankRequest>,
    req_kind: Vec<u8>,
}

impl<'a> TileSim<'a> {
    fn new(cfg: &'a ChipConfig, spec: &TileSpec) -> Self {
        let g = TileGeometry::derive(cfg, spec);
        TileSim {
            cfg,
            spec: *spec,
            g,
            macs: cfg.array.macs() as u64,
            separate_ports: matches!(cfg.memory, MemoryOrg::Separated { .. }),
            nb: cfg.num_banks as u64,
            mem: BankedMemory::with_size(crate::arch::DATA_MEM_BYTES, cfg.num_banks),
            inputs: (0..MAX_INPUT_CHANNELS)
                .map(|_| Channel::new(g.fifo_depth as usize))
                .collect(),
            weights: (0..g.n_w_ch).map(|_| Channel::new(g.fifo_depth as usize)).collect(),
            psum_issued: 0,
            psum_fill: 0,
            psum_pending: u64::MAX,
            simd_queue: 0,
            out_bytes: 0,
            out_written_bytes: 0,
            fired: 0,
            psum_unready: 0,
            m: TileMetrics::default(),
            cycle: 0,
            reqs: Vec::with_capacity(MAX_INPUT_CHANNELS + 4),
            req_kind: Vec::with_capacity(MAX_INPUT_CHANNELS + 4),
        }
    }

    fn done(&self) -> bool {
        !(self.fired < self.g.total_steps
            || self.simd_queue > 0
            || self.out_written_bytes < self.g.out_total_bytes)
    }

    fn in_addr(&self, r: usize, s: u64) -> u64 {
        if self.spec.input_blocked {
            self.spec.in_base + s * self.g.n_in as u64 + r as u64
        } else {
            let sub = s / self.g.ksteps;
            let ks = s % self.g.ksteps;
            let ti = sub / self.g.sub_n;
            self.spec.in_base + (ti * self.g.am + r as u64) * self.g.row_stride_words + ks
        }
    }

    fn w_addr(&self, c: usize, s: u64) -> u64 {
        let sub = s / self.g.ksteps;
        let ks = s % self.g.ksteps;
        let tj = sub % self.g.sub_n;
        self.spec.w_base + ((tj * self.g.ksteps + ks) * self.g.n_w_ch as u64 + c as u64) * self.g.w_stride
    }

    /// One iteration of the reference loop body (unchanged semantics).
    fn cycle_once(&mut self) {
        let g = self.g;
        let spec = self.spec;
        let fifo_depth = g.fifo_depth;

        // ---- 1. arrivals ------------------------------------------------
        for ch in self.inputs.iter_mut().take(g.n_in) {
            if ch.arrive(self.cycle) {
                self.m.fifo_events += 1;
            }
        }
        for ch in self.weights.iter_mut() {
            if ch.arrive(self.cycle) {
                self.m.fifo_events += 1;
            }
        }
        if self.psum_pending == self.cycle {
            self.psum_pending = u64::MAX;
            self.psum_fill += 1;
            self.m.fifo_events += 1;
        }

        // ---- 2. fire the array ------------------------------------------
        if self.fired < g.total_steps {
            let sub = self.fired / g.ksteps;
            let ks = self.fired % g.ksteps;
            let ti = sub / g.sub_n;
            let tj = sub % g.sub_n;
            let inputs_ready = self.inputs.iter().take(g.n_in).all(|c| c.fill > 0);
            let weight_ready = self.weights.iter().all(|c| c.fill > 0);
            let psum_ready = !spec.psum_in
                || self.psum_fill >= (sub + 1) * g.psum_words_per_sub
                || self.psum_fill == g.psum_total; // degenerate tail
            if !psum_ready {
                self.psum_unready += 1;
            }
            // Output registers are double-buffered: a subtile may finish
            // while the *previous* subtile's results still drain through
            // the SIMD, but not while two subtiles' worth are pending.
            let regs_free = ks < g.ksteps - 1 || self.simd_queue <= g.outputs_per_sub;
            if inputs_ready && weight_ready && psum_ready && regs_free {
                for ch in self.inputs.iter_mut().take(g.n_in) {
                    ch.fill -= 1;
                    self.m.fifo_events += 1;
                }
                for ch in self.weights.iter_mut() {
                    ch.fill -= 1;
                    self.m.fifo_events += 1;
                }
                self.fired += 1;
                self.m.active_cycles += 1;
                let mr = block_residue(spec.tm, g.am, ti);
                let nr = block_residue(spec.tn, g.an, tj);
                let kr = block_residue(spec.tk, g.ak, ks);
                self.m.useful_macs += mr * nr * kr;
                self.m.offered_macs += self.macs;
                // Subtile complete: valid results to the SIMD / spill path.
                if self.fired % g.ksteps == 0 {
                    let valid = mr * nr;
                    if spec.spill_out {
                        self.out_bytes += valid * 4;
                    } else {
                        self.simd_queue += valid;
                    }
                }
            } else {
                self.m.stall_cycles += 1;
            }
        }

        // ---- 3. SIMD drain + output write -------------------------------
        if self.simd_queue > 0 {
            let done = self.simd_queue.min(self.cfg.simd_lanes as u64);
            self.simd_queue -= done;
            self.m.simd_cycles += 1;
            if !spec.spill_out {
                // Quantized int8 results pack into the output FIFO.
                self.out_bytes += done;
            }
        }

        // ---- 4. issue requests + arbitration -----------------------------
        let mut reqs = std::mem::take(&mut self.reqs);
        let mut req_kind = std::mem::take(&mut self.req_kind);
        reqs.clear();
        req_kind.clear();
        // Input channels (fine-grained 64-bit, Fig. 3a).
        for (r, ch) in self.inputs.iter().enumerate().take(g.n_in) {
            if ch.issued < g.total_steps && ch.fill + ch.inflight() < fifo_depth {
                let demand_ok = self.cfg.prefetch
                    || (ch.fill == 0 && ch.inflight() == 0 && ch.issued == self.fired);
                if demand_ok {
                    reqs.push(BankRequest {
                        word_addr: self.in_addr(r, ch.issued),
                        write: false,
                        requester: Requester::Input(r as u8),
                        super_bank: false,
                    });
                    req_kind.push(r as u8);
                }
            }
        }
        // Weight channels (coarse-grained 512-bit super banks, Fig. 3b;
        // a folded mapping fetches `fold` parallel K-slices per step).
        for (c, ch) in self.weights.iter().enumerate() {
            if ch.issued < g.total_steps && ch.fill + ch.inflight() < fifo_depth {
                let demand_ok = self.cfg.prefetch
                    || (ch.fill == 0 && ch.inflight() == 0 && ch.issued == self.fired);
                if demand_ok {
                    reqs.push(BankRequest {
                        word_addr: self.w_addr(c, ch.issued),
                        write: false,
                        requester: Requester::Weight,
                        super_bank: g.w_super,
                    });
                    req_kind.push(100 + c as u8);
                }
            }
        }
        // Psum read & output write share a crossbar port when tmux'd;
        // psum has priority (Sec. II-D).
        let psum_wants =
            spec.psum_in && self.psum_issued < self.g.psum_total && self.psum_pending == u64::MAX;
        // Write a 64-bit word when one is full, or flush the tail once
        // compute has finished.
        let drained = self.fired >= g.total_steps && self.simd_queue == 0;
        let out_wants = self.out_bytes >= 8 || (drained && self.out_bytes > 0);
        let (psum_go, out_go) = if self.cfg.tmux_psum_output {
            if psum_wants {
                (true, false)
            } else {
                (false, out_wants)
            }
        } else {
            (psum_wants, out_wants)
        };
        if psum_go {
            reqs.push(BankRequest {
                word_addr: spec.p_base + self.psum_issued,
                write: false,
                requester: Requester::Psum,
                super_bank: false,
            });
            req_kind.push(250);
        }
        if out_go {
            reqs.push(BankRequest {
                word_addr: spec.o_base + self.out_written_bytes / 8,
                write: true,
                requester: Requester::Output,
                super_bank: false,
            });
            req_kind.push(251);
        }

        if self.separate_ports {
            // Dedicated per-operand buffers: every request is served by
            // its own SRAM — no cross-class arbitration (Fig. 1a).
            for (i, r) in reqs.iter().enumerate() {
                let kind = req_kind[i];
                match kind {
                    0..=99 => {
                        let ch = &mut self.inputs[kind as usize];
                        ch.issued += 1;
                        ch.launch(self.cycle + self.cfg.mem_latency);
                    }
                    w @ 100..=249 => {
                        let ch = &mut self.weights[(w - 100) as usize];
                        ch.issued += 1;
                        ch.launch(self.cycle + self.cfg.mem_latency);
                    }
                    250 => {
                        self.psum_issued += 1;
                        self.psum_pending = self.cycle + self.cfg.mem_latency;
                    }
                    251 => {
                        let chunk = self.out_bytes.min(8);
                        self.out_written_bytes += chunk;
                        self.out_bytes -= chunk;
                        self.m.bank_writes += 1;
                    }
                    _ => unreachable!(),
                }
                if !r.write {
                    self.m.bank_reads += if r.super_bank { 8 } else { 1 };
                }
            }
        } else {
            let res = self.mem.arbitrate(&reqs);
            self.m.bank_reads += res.reads;
            self.m.bank_writes += res.writes;
            self.m.bank_conflicts += res.denied.len() as u64;
            for &gi in &res.granted {
                match req_kind[gi] {
                    r @ 0..=99 => {
                        let ch = &mut self.inputs[r as usize];
                        ch.issued += 1;
                        ch.launch(self.cycle + self.cfg.mem_latency);
                    }
                    w @ 100..=249 => {
                        let ch = &mut self.weights[(w - 100) as usize];
                        ch.issued += 1;
                        ch.launch(self.cycle + self.cfg.mem_latency);
                    }
                    250 => {
                        self.psum_issued += 1;
                        self.psum_pending = self.cycle + self.cfg.mem_latency;
                    }
                    251 => {
                        let chunk = self.out_bytes.min(8);
                        self.out_written_bytes += chunk;
                        self.out_bytes -= chunk;
                    }
                    _ => unreachable!(),
                }
            }
        }
        self.reqs = reqs;
        self.req_kind = req_kind;

        self.cycle += 1;
    }

    fn finish(mut self) -> TileMetrics {
        debug_assert!(self.cycle < self.g.max_cycles, "tile simulation did not converge");
        self.m.total_cycles = self.cycle;
        self.m
    }

    // ---------------------------------------------------- fast path --

    /// The machine's complete state *relative to the current row
    /// boundary*: everything the per-cycle dynamics read, expressed so
    /// that two boundaries with equal keys evolve identically. Absolute
    /// progress counters enter only through their bank phases (the
    /// address streams are linear or row-periodic, so phase equality at
    /// matched boundaries propagates to every later request).
    fn state_key(&self) -> Vec<i64> {
        let mut k: Vec<i64> = Vec::with_capacity(8 + 12 * (self.g.n_in + self.g.n_w_ch));
        k.push(self.mem.rr_phase() as i64);
        for r in 0..self.g.n_in {
            let ch = &self.inputs[r];
            k.push(ch.fill as i64);
            k.push((ch.issued - self.fired) as i64);
            k.push(ch.ready.len() as i64);
            for &t in &ch.ready {
                k.push((t - self.cycle) as i64);
            }
            k.push(if ch.issued >= self.g.total_steps {
                -1
            } else {
                (self.in_addr(r, ch.issued) % self.nb) as i64
            });
        }
        for c in 0..self.g.n_w_ch {
            let ch = &self.weights[c];
            k.push(ch.fill as i64);
            k.push((ch.issued - self.fired) as i64);
            k.push(ch.ready.len() as i64);
            for &t in &ch.ready {
                k.push((t - self.cycle) as i64);
            }
            k.push(if ch.issued >= self.g.total_steps {
                -1
            } else {
                (self.w_addr(c, ch.issued) % self.nb) as i64
            });
        }
        // Psum stream state. The stream is a deterministic ramp (one
        // word per mem_latency cycles, always granted in arbitration
        // pass 1), so its absolute progress is NOT translation-invariant
        // across rows; instead of keying raw progress (which would only
        // ever match a perfectly paced stream) the key distinguishes
        // three regimes — absent, done, active — and `try_jump` proves
        // an active-stream jump sound via the unready counter + slack.
        if !self.spec.psum_in {
            k.extend_from_slice(&[0, 0, -1, -1]);
        } else if self.psum_issued >= self.g.psum_total && self.psum_pending == u64::MAX {
            k.extend_from_slice(&[-2, -2, -1, -1]); // stream complete: inert forever
        } else {
            k.push(-3); // stream active
            k.push(if self.psum_pending == u64::MAX {
                -1
            } else {
                (self.psum_pending - self.cycle) as i64
            });
            k.push(((self.spec.p_base + self.psum_issued) % self.nb) as i64);
            k.push(0);
        }
        k.push(self.simd_queue as i64);
        k.push(self.out_bytes as i64);
        k.push(((self.spec.o_base + self.out_written_bytes / 8) % self.nb) as i64);
        k.push((self.out_written_bytes % 8) as i64);
        k
    }

    fn marks(&self, row: u64) -> RowMark {
        RowMark {
            row,
            cycle: self.cycle,
            fired: self.fired,
            in_issued: self.inputs.iter().take(self.g.n_in).map(|c| c.issued).collect(),
            w_issued: self.weights.iter().map(|c| c.issued).collect(),
            psum_issued: self.psum_issued,
            psum_fill: self.psum_fill,
            out_written_bytes: self.out_written_bytes,
            metrics: self.m,
            psum_unready: self.psum_unready,
        }
    }

    /// Jump as many whole periods as the landing margin allows; returns
    /// the number of subtile rows skipped (0 = no jump, keep walking).
    fn try_jump(&mut self, prev: &RowMark, row: u64) -> u64 {
        let p = row - prev.row;
        // Land at least `margin` rows before the last one: the final
        // rows run ragged residues and the end-of-stream issue guards;
        // the margin keeps every `issued < total_steps` guard strictly
        // un-bound inside the jumped span (fifo_depth extra steps of
        // lookahead per channel, amortized over row_steps).
        let margin = self.g.fifo_depth / self.g.row_steps + 1;
        if self.g.sub_m <= margin {
            return 0;
        }
        let landing_max = self.g.sub_m - margin;
        if landing_max <= row {
            return 0;
        }
        let mut n = (landing_max - row) / p;
        if self.spec.psum_in && self.psum_issued < self.g.psum_total {
            // Active psum stream (key matched, so both marks are in the
            // active regime). The jump mirrors the observed period, so
            // it is sound only if (a) the stream never gated a fire in
            // that period, (b) its slack over the consumption threshold
            // is non-decreasing (then it keeps not gating), and (c) it
            // stays active through the whole jumped span (the ramp's
            // issue guard must not flip inside it).
            if self.psum_unready != prev.psum_unready {
                return 0;
            }
            let dpsum = self.psum_issued - prev.psum_issued;
            if dpsum < p * self.g.psum_row {
                return 0;
            }
            if dpsum > 0 {
                n = n.min((self.g.psum_total - 1 - self.psum_issued) / dpsum);
            }
        }
        if n == 0 {
            return 0;
        }
        let dc = self.cycle - prev.cycle;
        self.cycle += n * dc;
        self.fired += n * (self.fired - prev.fired);
        for r in 0..self.g.n_in {
            let ch = &mut self.inputs[r];
            ch.issued += n * (ch.issued - prev.in_issued[r]);
            for t in ch.ready.iter_mut() {
                *t += n * dc;
            }
        }
        for (c, ch) in self.weights.iter_mut().enumerate() {
            ch.issued += n * (ch.issued - prev.w_issued[c]);
            for t in ch.ready.iter_mut() {
                *t += n * dc;
            }
        }
        self.psum_issued += n * (self.psum_issued - prev.psum_issued);
        self.psum_fill += n * (self.psum_fill - prev.psum_fill);
        if self.psum_pending != u64::MAX {
            self.psum_pending += n * dc;
        }
        self.out_written_bytes += n * (self.out_written_bytes - prev.out_written_bytes);
        add_scaled_delta(&mut self.m, &prev.metrics, n);
        n * p
    }
}

/// `m += n * (m - prev)` per metric field — replay `n` periods' deltas.
fn add_scaled_delta(m: &mut TileMetrics, prev: &TileMetrics, n: u64) {
    m.total_cycles += n * (m.total_cycles - prev.total_cycles);
    m.active_cycles += n * (m.active_cycles - prev.active_cycles);
    m.useful_macs += n * (m.useful_macs - prev.useful_macs);
    m.offered_macs += n * (m.offered_macs - prev.offered_macs);
    m.bank_reads += n * (m.bank_reads - prev.bank_reads);
    m.bank_writes += n * (m.bank_writes - prev.bank_writes);
    m.bank_conflicts += n * (m.bank_conflicts - prev.bank_conflicts);
    m.stall_cycles += n * (m.stall_cycles - prev.stall_cycles);
    m.simd_cycles += n * (m.simd_cycles - prev.simd_cycles);
    m.fifo_events += n * (m.fifo_events - prev.fifo_events);
}

/// Whether the steady-state fast path may run for this tile: enough
/// subtile rows that a recurrence can be observed AND a jump can land
/// `margin` rows short of the ragged tail. Tiles below the threshold
/// (including every GEMV fold-8 tile, whose row grid collapses to 1)
/// take the per-cycle walk — `tests/differential.rs` asserts both sides.
pub fn fast_path_eligible(cfg: &ChipConfig, spec: &TileSpec) -> bool {
    let g = TileGeometry::derive(cfg, spec);
    let margin_io = g.fifo_depth / g.row_steps + 1;
    g.sub_m >= margin_io + 3
}

/// The per-cycle reference walk (the pre-PR-6 `simulate_tile`, verbatim
/// semantics). Public so the differential tests and the cold-plan bench
/// baseline can pin the fast path against it.
pub fn simulate_tile_reference(cfg: &ChipConfig, spec: &TileSpec) -> TileMetrics {
    let mut s = TileSim::new(cfg, spec);
    while !s.done() && s.cycle < s.g.max_cycles {
        s.cycle_once();
    }
    s.finish()
}

/// The row-recurrence fast path: reference walk + analytic jump over
/// the steady state. Returns the metrics and the number of subtile rows
/// skipped (0 = the walk never found a sound recurrence — still exact,
/// just not faster). Callers wanting plain metrics use
/// [`simulate_tile`]; the split return is for the differential tests.
pub fn simulate_tile_fast(cfg: &ChipConfig, spec: &TileSpec) -> (TileMetrics, u64) {
    let mut s = TileSim::new(cfg, spec);
    let mut snaps: HashMap<Vec<i64>, RowMark> = HashMap::new();
    let mut last_marked: i64 = -1;
    let mut jumped: u64 = 0;
    while !s.done() && s.cycle < s.g.max_cycles {
        if jumped == 0 && s.fired % s.g.row_steps == 0 {
            let row = s.fired / s.g.row_steps;
            if row as i64 > last_marked && row + 2 <= s.g.sub_m {
                last_marked = row as i64;
                let key = s.state_key();
                if let Some(prev) = snaps.get(&key) {
                    let prev = prev.clone();
                    jumped = s.try_jump(&prev, row);
                } else if snaps.len() < SNAPSHOT_CAP {
                    snaps.insert(key, s.marks(row));
                }
            }
        }
        s.cycle_once();
    }
    (s.finish(), jumped)
}

/// Simulate one tile on the configured array, under the tile's
/// K-extension fold. Returns activity counters. Dispatches eligible
/// tiles to the steady-state fast path (bit-identical by construction
/// and by differential test); everything else walks cycle by cycle.
pub fn simulate_tile(cfg: &ChipConfig, spec: &TileSpec) -> TileMetrics {
    if fast_path_eligible(cfg, spec) {
        simulate_tile_fast(cfg, spec).0
    } else {
        simulate_tile_reference(cfg, spec)
    }
}

/// Fingerprint of the *tile-structural* config slice: exactly the
/// fields [`simulate_tile`] reads. Two configs with equal tile
/// fingerprints produce bit-identical [`TileMetrics`] for every
/// [`TileSpec`], so tile-simulation caches keyed by this fingerprint
/// can be shared across configs that differ only in planner-side knobs
/// (DMA bandwidth/burst, psum FIFO depth, double buffering, mapping
/// mode, separated buffer *sizes*, operating point).
///
/// The slice, field by field (kept in lockstep with the `TileSim`
/// constructor above — `tests/structural_keys.rs` property-tests the
/// correspondence in both directions):
/// * array geometry — firing pattern, subtile grid, fold legality;
/// * memory *kind* only — the engine models separated buffers as
///   conflict-free dedicated ports (`separate_ports`); the split sizes
///   constrain tiling at plan time, never the per-tile walk;
/// * `prefetch`, `stream_fifo_depth` — MGDP streamer behavior;
/// * `simd_lanes`, `tmux_psum_output` — output drain rate and the
///   psum/output port discipline;
/// * `num_banks`, `mem_latency` — bank arbitration and response timing.
pub fn tile_fingerprint(cfg: &ChipConfig) -> u64 {
    use std::collections::hash_map::DefaultHasher;
    use std::hash::{Hash, Hasher};
    let mut h = DefaultHasher::new();
    match cfg.array {
        ArrayGeometry::Spatial3D { m, n, k } => {
            0u8.hash(&mut h);
            (m, n, k).hash(&mut h);
        }
        ArrayGeometry::Spatial2D { m, n } => {
            1u8.hash(&mut h);
            (m, n).hash(&mut h);
        }
    }
    matches!(cfg.memory, MemoryOrg::Separated { .. }).hash(&mut h);
    cfg.prefetch.hash(&mut h);
    cfg.stream_fifo_depth.hash(&mut h);
    cfg.simd_lanes.hash(&mut h);
    cfg.tmux_psum_output.hash(&mut h);
    cfg.num_banks.hash(&mut h);
    cfg.mem_latency.hash(&mut h);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    fn total_useful(tm: u64, tk: u64, tn: u64) -> u64 {
        tm * tk * tn
    }

    #[test]
    fn tile_fingerprint_tracks_only_engine_inputs() {
        let v = tile_fingerprint(&ChipConfig::voltra());
        // Planner-side knobs are invisible to the tile engine.
        let mut dma = ChipConfig::voltra();
        dma.dma_bytes_per_cycle = 16;
        dma.dma_burst_latency = 8;
        dma.double_buffer = false;
        assert_eq!(v, tile_fingerprint(&dma));
        assert_eq!(v, tile_fingerprint(&ChipConfig::swap_only()));
        // Engine-visible knobs split the key.
        assert_ne!(v, tile_fingerprint(&ChipConfig::no_prefetch()));
        assert_ne!(v, tile_fingerprint(&ChipConfig::array2d()));
        assert_ne!(v, tile_fingerprint(&ChipConfig::simd64()));
        assert_ne!(v, tile_fingerprint(&ChipConfig::full_crossbar()));
        assert_ne!(v, tile_fingerprint(&ChipConfig::separated_memory()));
    }

    #[test]
    fn aligned_tile_counts_exact_macs() {
        let cfg = ChipConfig::voltra();
        let spec = TileSpec::simple(64, 64, 64);
        let m = simulate_tile(&cfg, &spec);
        assert_eq!(m.useful_macs, total_useful(64, 64, 64));
        // 64 subtiles x 8 ksteps of firing.
        assert_eq!(m.active_cycles, 512);
        assert!(m.total_cycles >= 512);
    }

    #[test]
    fn prefetch_beats_demand_fetch() {
        let spec = TileSpec::simple(64, 256, 64);
        let with = simulate_tile(&ChipConfig::voltra(), &spec);
        let without = simulate_tile(&ChipConfig::no_prefetch(), &spec);
        assert_eq!(with.useful_macs, without.useful_macs);
        let ru = with.temporal_utilization() / without.temporal_utilization();
        assert!(
            ru > 1.5,
            "MGDP should dominate demand fetching, got ratio {ru:.2} \
             ({:.3} vs {:.3})",
            with.temporal_utilization(),
            without.temporal_utilization()
        );
    }

    #[test]
    fn voltra_reaches_high_temporal_utilization() {
        let spec = TileSpec::simple(64, 512, 64);
        let m = simulate_tile(&ChipConfig::voltra(), &spec);
        let u = m.temporal_utilization();
        assert!(u > 0.75, "expected >0.75 temporal utilization, got {u:.3}");
    }

    #[test]
    fn separated_memory_has_no_conflicts() {
        let spec = TileSpec::simple(64, 128, 64);
        let m = simulate_tile(&ChipConfig::separated_memory(), &spec);
        assert_eq!(m.bank_conflicts, 0);
        assert!(m.temporal_utilization() > 0.85);
    }

    #[test]
    fn ragged_tile_underfills_spatially() {
        let cfg = ChipConfig::voltra();
        let m = simulate_tile(&cfg, &TileSpec::simple(6, 64, 64));
        assert_eq!(m.useful_macs, 6 * 64 * 64);
        let su = m.spatial_utilization();
        assert!((su - 0.75).abs() < 1e-9, "6/8 fill expected, got {su}");
    }

    #[test]
    fn folded_gemv_tile_fills_the_array() {
        // K-extension (fold 8): a GEMV tile fires 1 row x 8 cols x 64 K
        // lanes per step — full spatial fill instead of 12.5%, at 8x
        // fewer steps.
        let cfg = ChipConfig::voltra();
        let folded = simulate_tile(&cfg, &TileSpec::folded(1, 128, 256, 8));
        assert_eq!(folded.useful_macs, total_useful(1, 128, 256));
        assert_eq!(folded.active_cycles, 32 * 2); // 32 subtiles x 2 ksteps
        assert!((folded.spatial_utilization() - 1.0).abs() < 1e-12);
        let flat = simulate_tile(&cfg, &TileSpec::simple(1, 128, 256));
        assert_eq!(flat.useful_macs, folded.useful_macs);
        assert_eq!(flat.active_cycles, 8 * folded.active_cycles);
        assert!((flat.spatial_utilization() - 0.125).abs() < 1e-12);
        // The fold trades weight bandwidth for fill: fewer total cycles
        // despite the 8 super-bank fetches per step.
        assert!(folded.total_cycles < flat.total_cycles);
    }

    #[test]
    fn folded_tiles_conserve_macs_at_every_fold() {
        let cfg = ChipConfig::voltra();
        for fold in [1u8, 2, 4, 8] {
            for (tm, tk, tn) in [(1, 128, 256), (6, 96, 40), (13, 57, 9)] {
                let m = simulate_tile(&cfg, &TileSpec::folded(tm, tk, tn, fold));
                assert_eq!(m.useful_macs, total_useful(tm, tk, tn), "fold {fold}");
                assert!(m.spatial_utilization() <= 1.0 + 1e-12);
                assert!(m.temporal_utilization() <= 1.0 + 1e-12);
            }
        }
    }

    #[test]
    fn fold_is_inert_on_the_2d_array() {
        // The 2D baseline has no spatial K axis: the fold field must be
        // ignored, not misinterpreted.
        let cfg = ChipConfig::array2d();
        let a = simulate_tile(&cfg, &TileSpec::simple(32, 64, 32));
        let b = simulate_tile(&cfg, &TileSpec::folded(32, 64, 32, 8));
        assert_eq!(a, b);
    }

    #[test]
    fn continuation_tile_reads_psums() {
        let cfg = ChipConfig::voltra();
        let mut spec = TileSpec::simple(32, 64, 32);
        spec.psum_in = true;
        let m = simulate_tile(&cfg, &spec);
        // 16 subtiles x 32 psum words must have been read.
        assert!(m.bank_reads > 16 * 32);
        assert_eq!(m.useful_macs, 32 * 64 * 32);
    }

    #[test]
    fn spill_tile_writes_int32() {
        let cfg = ChipConfig::voltra();
        let mut spill = TileSpec::simple(32, 64, 32);
        spill.spill_out = true;
        let mut quant = TileSpec::simple(32, 64, 32);
        quant.spill_out = false;
        let ms = simulate_tile(&cfg, &spill);
        let mq = simulate_tile(&cfg, &quant);
        assert!(
            ms.bank_writes > mq.bank_writes,
            "int32 spill ({}) must write more words than int8 ({})",
            ms.bank_writes,
            mq.bank_writes
        );
    }

    #[test]
    fn raw_layout_conflicts_more_than_blocked() {
        let cfg = ChipConfig::no_prefetch();
        let mut raw = TileSpec::simple(64, 256, 64);
        raw.input_blocked = false;
        let blocked = TileSpec::simple(64, 256, 64);
        let mr = simulate_tile(&cfg, &raw);
        let mb = simulate_tile(&cfg, &blocked);
        assert!(
            mr.bank_conflicts >= mb.bank_conflicts,
            "row-major input should not conflict less ({} vs {})",
            mr.bank_conflicts,
            mb.bank_conflicts
        );
    }

    #[test]
    fn simulation_terminates_on_minimal_tile() {
        let cfg = ChipConfig::voltra();
        let m = simulate_tile(&cfg, &TileSpec::simple(1, 1, 1));
        assert_eq!(m.useful_macs, 1);
        assert_eq!(m.active_cycles, 1);
    }

    #[test]
    fn deep_fifo_with_slow_memory_keeps_inflight_queue_consistent() {
        // Regression: `stream_fifo_depth` is configurable but the
        // in-flight ring was hardcoded to 8 slots — a depth-16 sweep
        // point with a memory latency that lets >8 requests pile up
        // tripped the debug assertion (and corrupted the ring in
        // release). The queue is now sized from the config.
        let mut cfg = ChipConfig::voltra();
        cfg.stream_fifo_depth = 16;
        cfg.mem_latency = 12;
        let spec = TileSpec::simple(64, 256, 64);
        let m = simulate_tile(&cfg, &spec);
        assert_eq!(m.useful_macs, 64 * 256 * 64);
        // The deep FIFO must actually cover the latency: utilization
        // stays pipelined, nowhere near demand-fetch levels.
        let u = m.temporal_utilization();
        assert!(u > 0.5, "depth-16 pipelining collapsed: {u:.3}");
    }

    // ------------------------------------------------------ fast path

    #[test]
    fn fast_path_is_bit_identical_on_steady_tiles() {
        // The planner-realistic shapes the cold-plan bench leans on.
        let cfg = ChipConfig::voltra();
        for (tm, tk, tn) in [(128, 256, 64), (128, 512, 64), (96, 256, 96), (64, 512, 64)] {
            let spec = TileSpec::simple(tm, tk, tn);
            let refm = simulate_tile_reference(&cfg, &spec);
            let (fast, jumped) = simulate_tile_fast(&cfg, &spec);
            assert_eq!(refm, fast, "{tm}x{tk}x{tn}");
            assert!(jumped > 0, "{tm}x{tk}x{tn}: steady tile must jump");
        }
    }

    #[test]
    fn fast_path_is_bit_identical_on_psum_and_spill_variants() {
        let cfg = ChipConfig::voltra();
        for psum_in in [false, true] {
            for spill_out in [false, true] {
                let mut spec = TileSpec::simple(128, 512, 64);
                spec.psum_in = psum_in;
                spec.spill_out = spill_out;
                let refm = simulate_tile_reference(&cfg, &spec);
                let (fast, _) = simulate_tile_fast(&cfg, &spec);
                assert_eq!(refm, fast, "psum={psum_in} spill={spill_out}");
            }
        }
    }

    #[test]
    fn eligibility_gates_shallow_row_grids() {
        let cfg = ChipConfig::voltra();
        // One subtile row: nothing to recur over.
        assert!(!fast_path_eligible(&cfg, &TileSpec::simple(8, 64, 64)));
        // GEMV fold-8 collapses to a single row: ineligible by construction.
        assert!(!fast_path_eligible(&cfg, &TileSpec::folded(1, 128, 256, 8)));
        // Many rows: eligible.
        assert!(fast_path_eligible(&cfg, &TileSpec::simple(64, 512, 64)));
        // The dispatcher agrees with the reference on an ineligible spec.
        let spec = TileSpec::simple(8, 64, 64);
        assert_eq!(simulate_tile(&cfg, &spec), simulate_tile_reference(&cfg, &spec));
    }

    #[test]
    fn fast_path_actually_saves_cycles() {
        // Not just correct: the jump must skip most of a steady tile's
        // rows, or the bench's >=5x cold-plan budget is fiction.
        let cfg = ChipConfig::voltra();
        let spec = TileSpec::simple(128, 256, 64);
        let (_, jumped) = simulate_tile_fast(&cfg, &spec);
        // 16 subtile rows; the jump must cover more than half of them.
        assert!(jumped >= 8, "jumped only {jumped} of 16 rows");
    }
}
