//! The unified multi-bank shared data memory (Sec. II: 32 banks x 64 bit).
//!
//! Two roles:
//! * **functional** — stores real bytes, so the DMA, reshuffler and
//!   runtime integration tests can move actual tensor data through it;
//! * **timing** — per-cycle arbitration: each bank serves one 64-bit
//!   access per cycle; the weight streamer's 512-bit *super-bank* access
//!   claims eight aligned banks at once (Sec. II-B, Fig. 3b).
//!
//! Addresses are bank *words* (64-bit). Word `a` lives in bank
//! `a % NUM_BANKS`, row `a / NUM_BANKS` — the word-interleaved mapping
//! that makes consecutive words hit consecutive banks (what the
//! reshuffler's blocked layouts exploit).

use crate::arch::{BANK_WIDTH_BYTES, DATA_MEM_BYTES, NUM_BANKS, SUPER_BANK_BANKS};

/// Identifies the requesting channel class for arbitration/energy stats.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Requester {
    Input(u8),
    Weight,
    Psum,
    Output,
    Simd,
    Reshuffler,
    Dma,
}

/// One access request in a cycle.
#[derive(Clone, Copy, Debug)]
pub struct BankRequest {
    pub word_addr: u64,
    pub write: bool,
    pub requester: Requester,
    /// 512-bit super-bank access: claims the whole aligned 8-bank group.
    pub super_bank: bool,
}

/// Outcome of one cycle of bank arbitration. Reused across cycles to
/// keep the simulator's inner loop allocation-free (§Perf): `granted`
/// and `denied` are cleared, not reallocated, by `arbitrate`.
#[derive(Clone, Debug, Default)]
pub struct ArbitrationResult {
    /// Indices (into the request slice) that were granted.
    pub granted: Vec<usize>,
    /// Indices that lost arbitration and must retry.
    pub denied: Vec<usize>,
    pub reads: u64,
    pub writes: u64,
}

impl ArbitrationResult {
    fn clear(&mut self) {
        self.granted.clear();
        self.denied.clear();
        self.reads = 0;
        self.writes = 0;
    }
}

/// The banked memory: functional byte store + per-cycle arbiter.
pub struct BankedMemory {
    data: Vec<u8>,
    num_banks: usize,
    /// Round-robin priority pointer, rotated every cycle for fairness.
    rr: usize,
    /// busy[b] = this cycle's bank b already granted (scratch, reused).
    busy: Vec<bool>,
    /// Reused result buffer (§Perf: no allocation per cycle).
    scratch: ArbitrationResult,
}

impl BankedMemory {
    pub fn new() -> Self {
        Self::with_size(DATA_MEM_BYTES, NUM_BANKS)
    }

    pub fn with_size(bytes: usize, num_banks: usize) -> Self {
        BankedMemory {
            data: vec![0; bytes],
            num_banks,
            rr: 0,
            busy: vec![false; num_banks],
            scratch: ArbitrationResult::default(),
        }
    }

    pub fn num_banks(&self) -> usize {
        self.num_banks
    }

    /// Current round-robin priority phase. Part of the tile engine's
    /// fast-path state key (DESIGN.md §12): two machine states can only
    /// evolve identically if the arbiter favors the same request slot.
    pub fn rr_phase(&self) -> usize {
        self.rr
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len()
    }

    pub fn words(&self) -> u64 {
        (self.data.len() / BANK_WIDTH_BYTES) as u64
    }

    #[inline]
    pub fn bank_of(&self, word_addr: u64) -> usize {
        (word_addr as usize) % self.num_banks
    }

    /// The aligned 8-bank group a super-bank access occupies.
    #[inline]
    pub fn super_group_of(&self, word_addr: u64) -> usize {
        self.bank_of(word_addr) / SUPER_BANK_BANKS
    }

    // ------------------------------------------------------ functional

    pub fn read_word(&self, word_addr: u64) -> u64 {
        let off = word_addr as usize * BANK_WIDTH_BYTES;
        let mut b = [0u8; 8];
        b.copy_from_slice(&self.data[off..off + 8]);
        u64::from_le_bytes(b)
    }

    pub fn write_word(&mut self, word_addr: u64, value: u64) {
        let off = word_addr as usize * BANK_WIDTH_BYTES;
        self.data[off..off + 8].copy_from_slice(&value.to_le_bytes());
    }

    pub fn read_bytes(&self, byte_addr: usize, out: &mut [u8]) {
        out.copy_from_slice(&self.data[byte_addr..byte_addr + out.len()]);
    }

    pub fn write_bytes(&mut self, byte_addr: usize, src: &[u8]) {
        self.data[byte_addr..byte_addr + src.len()].copy_from_slice(src);
    }

    // ---------------------------------------------------------- timing

    /// Arbitrate one cycle's requests: every bank serves at most one
    /// access; a super-bank request needs its whole aligned group free.
    ///
    /// Priority: psum first (the chip prioritises partial-sum reads,
    /// Sec. II-D), then round-robin over the remaining requests so no
    /// streamer starves.
    pub fn arbitrate(&mut self, reqs: &[BankRequest]) -> &ArbitrationResult {
        self.scratch.clear();
        if reqs.is_empty() {
            return &self.scratch;
        }
        for b in &mut self.busy {
            *b = false;
        }

        // Pass 1: psum (highest priority, Sec. II-D).
        // Pass 2: everyone else starting from the round-robin pointer.
        // Both passes grant in place — no order buffer is materialized.
        let n = reqs.len();
        for i in 0..n {
            if matches!(reqs[i].requester, Requester::Psum) {
                self.try_grant(reqs, i);
            }
        }
        for k in 0..n {
            let i = (self.rr + k) % n;
            if !matches!(reqs[i].requester, Requester::Psum) {
                self.try_grant(reqs, i);
            }
        }
        self.rr = (self.rr + 1) % n.max(1);
        &self.scratch
    }

    #[inline]
    fn try_grant(&mut self, reqs: &[BankRequest], i: usize) {
        let r = &reqs[i];
        if r.super_bank {
            let g = (r.word_addr as usize % self.num_banks) / SUPER_BANK_BANKS;
            let lo = g * SUPER_BANK_BANKS;
            if self.busy[lo..lo + SUPER_BANK_BANKS].iter().any(|&b| b) {
                self.scratch.denied.push(i);
            } else {
                for b in &mut self.busy[lo..lo + SUPER_BANK_BANKS] {
                    *b = true;
                }
                self.scratch.granted.push(i);
                if r.write {
                    self.scratch.writes += SUPER_BANK_BANKS as u64;
                } else {
                    self.scratch.reads += SUPER_BANK_BANKS as u64;
                }
            }
        } else {
            let b = (r.word_addr as usize) % self.num_banks;
            if self.busy[b] {
                self.scratch.denied.push(i);
            } else {
                self.busy[b] = true;
                self.scratch.granted.push(i);
                if r.write {
                    self.scratch.writes += 1;
                } else {
                    self.scratch.reads += 1;
                }
            }
        }
    }
}

impl Default for BankedMemory {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(addr: u64, requester: Requester) -> BankRequest {
        BankRequest {
            word_addr: addr,
            write: false,
            requester,
            super_bank: false,
        }
    }

    #[test]
    fn word_interleaving() {
        let m = BankedMemory::new();
        assert_eq!(m.bank_of(0), 0);
        assert_eq!(m.bank_of(31), 31);
        assert_eq!(m.bank_of(32), 0);
        assert_eq!(m.super_group_of(0), 0);
        assert_eq!(m.super_group_of(8), 1);
        assert_eq!(m.super_group_of(31), 3);
    }

    #[test]
    fn functional_read_write() {
        let mut m = BankedMemory::new();
        m.write_word(100, 0xDEAD_BEEF_CAFE_F00D);
        assert_eq!(m.read_word(100), 0xDEAD_BEEF_CAFE_F00D);
        m.write_bytes(16, &[1, 2, 3, 4]);
        let mut out = [0u8; 4];
        m.read_bytes(16, &mut out);
        assert_eq!(out, [1, 2, 3, 4]);
    }

    #[test]
    fn distinct_banks_all_granted() {
        let mut m = BankedMemory::new();
        let reqs: Vec<_> = (0..8).map(|i| req(i, Requester::Input(i as u8))).collect();
        let r = m.arbitrate(&reqs);
        assert_eq!(r.granted.len(), 8);
        assert!(r.denied.is_empty());
        assert_eq!(r.reads, 8);
    }

    #[test]
    fn same_bank_serializes() {
        let mut m = BankedMemory::new();
        // words 0 and 32 both live in bank 0.
        let reqs = vec![req(0, Requester::Input(0)), req(32, Requester::Input(1))];
        let r = m.arbitrate(&reqs);
        assert_eq!(r.granted.len(), 1);
        assert_eq!(r.denied.len(), 1);
    }

    #[test]
    fn super_bank_claims_group() {
        let mut m = BankedMemory::new();
        let mut reqs = vec![BankRequest {
            word_addr: 8, // group 1: banks 8..16
            write: false,
            requester: Requester::Weight,
            super_bank: true,
        }];
        reqs.push(req(9, Requester::Input(0))); // bank 9: conflicts
        reqs.push(req(0, Requester::Input(1))); // bank 0: fine
        let r = m.arbitrate(&reqs);
        assert_eq!(r.granted.len(), 2);
        assert_eq!(r.denied, vec![1]);
        assert_eq!(r.reads, 8 + 1);
    }

    #[test]
    fn psum_wins_over_output_on_same_bank() {
        let mut m = BankedMemory::new();
        for _ in 0..5 {
            // Whatever the round-robin pointer, psum must win.
            let reqs = vec![req(0, Requester::Output), req(32, Requester::Psum)];
            let r = m.arbitrate(&reqs);
            assert!(r.granted.contains(&1), "psum must be granted");
        }
    }

    #[test]
    fn round_robin_is_fair() {
        let mut m = BankedMemory::new();
        let mut wins = [0u32; 2];
        for _ in 0..100 {
            let reqs = vec![req(0, Requester::Input(0)), req(32, Requester::Input(1))];
            let r = m.arbitrate(&reqs);
            wins[r.granted[0]] += 1;
        }
        assert_eq!(wins[0], 50);
        assert_eq!(wins[1], 50);
    }
}
