//! Event-driven layer pipeline scheduler (DESIGN.md §9).
//!
//! Replaces the analytic `overlap_latency` heuristic on the workload
//! path: instead of blending a layer's *total* compute and DMA cycles
//! with a scalar max/sum formula, the coordinator emits the layer's
//! dispatched tile sequence as [`TilePlan`]s and this module walks it as
//! an event timeline over two serial resources:
//!
//! * the **DMA engine** — fetches every tile's working set (per-tile
//!   cycles attributed from the layer's reuse-model traffic, bandwidth
//!   and burst setup included), one tile at a time;
//! * the **tile engine** — executes each tile for its memoized simulated
//!   cycle count plus the Snitch CSR program that launches it.
//!
//! The dependence rules are the hardware's ping-pong discipline:
//!
//! * a tile's compute starts once its DMA completed AND the previous
//!   tile's compute retired (there is one array);
//! * with double buffering (the allocator granted ping-pong regions for
//!   *this* GEMM), tile `i`'s DMA may start as soon as tile `i-2`
//!   released its half of the region — the transfer overlaps tile
//!   `i-1`'s compute;
//! * without double buffering there is a single region, so tile `i`'s
//!   DMA waits for tile `i-1`'s compute — transfer and compute fully
//!   serialize.
//!
//! Prefetch depth, psum-spill round-trips and GEMM boundaries thereby
//! emerge from the schedule instead of a fixed `/8` bubble term. The old
//! [`crate::sim::dma::overlap_latency`] survives as the analytic
//! cross-check: every schedule lands inside its serial/overlapped
//! envelope `[max(compute, dma), compute + dma]` by construction
//! (property-tested below and at workload level).
//!
//! Runs of identical tiles advance in closed form: the recurrence's
//! increments become constant within three steps (both resources then
//! advance by `max(c, d)` when double-buffered, `c + d` when not), so a
//! million-tile layer schedules in microseconds. The equality of the
//! closed form against the tile-by-tile walk is itself a unit test.

/// A run of identical tiles inside one GEMM's dispatch sequence (the
/// interior/edge x K-round variants the coordinator enumerates share
/// per-tile costs, so each variant is one run).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TileRun {
    pub count: u64,
    /// Tile-engine busy cycles per tile (simulated + CSR programming).
    pub compute_cycles: u64,
    /// DMA-engine busy cycles per tile (bandwidth + burst share).
    pub dma_cycles: u64,
}

/// One GEMM's dispatched tile sequence plus its double-buffer grant.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TilePlan {
    pub runs: Vec<TileRun>,
    /// The allocator granted ping-pong regions for THIS GEMM. A layer
    /// may mix grants across its GEMMs (LSTM gate bundles, attention
    /// QKV) — the flag must never leak from one GEMM to the whole
    /// layer, which is exactly the accounting bug the scheduler fixed.
    pub double_buffered: bool,
}

/// A whole layer as the scheduler consumes it.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LayerPlan {
    pub gemms: Vec<TilePlan>,
    /// Serial reshuffler pass charged after the tile timeline.
    pub reshuffle_cycles: u64,
}

/// Resolved timeline of one layer.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Schedule {
    /// End-to-end cycles from first DMA issue to last compute retire
    /// (plus the serial reshuffle pass for [`LayerPlan`] scheduling).
    pub latency_cycles: u64,
    /// Total tile-engine busy cycles (sum of count x compute).
    pub compute_cycles: u64,
    /// Total DMA-engine busy cycles (sum of count x dma).
    pub dma_cycles: u64,
}

impl Schedule {
    /// Cycles the schedule hid by overlapping the two resources:
    /// `compute + dma - latency` (zero when fully serialized).
    pub fn hidden_cycles(&self) -> u64 {
        self.compute_cycles
            .saturating_add(self.dma_cycles)
            .saturating_sub(self.latency_cycles)
    }
}

/// Pipeline state: absolute cycle stamps of the two resources.
///
/// Invariants maintained by `step`: `prev >= dma_free` (a tile retires
/// after its own DMA) and `prev >= prev2` (retire order is dispatch
/// order). Both are what make the closed-form tail exact.
#[derive(Clone, Copy, Debug, Default)]
struct Timeline {
    /// When the DMA engine finishes its latest transfer.
    dma_free: u64,
    /// Compute-retire time of the last tile.
    prev: u64,
    /// Compute-retire time of the tile before it (ping/pong release).
    prev2: u64,
}

impl Timeline {
    /// Advance the timeline by one tile.
    fn step(&mut self, compute: u64, dma: u64, double_buffered: bool) {
        let buffer_ready = if double_buffered {
            self.prev2
        } else {
            self.prev
        };
        let dma_done = self.dma_free.max(buffer_ready) + dma;
        self.dma_free = dma_done;
        let retired = dma_done.max(self.prev) + compute;
        self.prev2 = self.prev;
        self.prev = retired;
    }

    /// Shift every stamp forward (the steady-state closed form).
    fn shift(&mut self, cycles: u64) {
        self.dma_free += cycles;
        self.prev += cycles;
        self.prev2 += cycles;
    }
}

/// Tiles of a run to walk explicitly before the steady-state increments
/// are provably constant (see the case analysis in the unit tests).
const WARMUP_TILES: u64 = 3;

/// Checked `total + count * per_tile`: a hostile tile run (count or
/// per-tile cost near `u64::MAX`) must fail loudly, never wrap the
/// schedule into a plausible-looking short latency.
fn acc(total: u64, count: u64, per_tile: u64) -> u64 {
    count
        .checked_mul(per_tile)
        .and_then(|c| total.checked_add(c))
        .expect("schedule cycle accumulation overflows u64")
}

/// Resolve the event timeline of a GEMM sequence. The timeline is
/// continuous across GEMM boundaries: a double-buffered GEMM's first
/// transfer may overlap the previous GEMM's tail compute, a
/// single-buffered GEMM's may not.
pub fn schedule(plans: &[TilePlan]) -> Schedule {
    let mut t = Timeline::default();
    let mut compute: u64 = 0;
    let mut dma: u64 = 0;
    for plan in plans {
        for run in &plan.runs {
            if run.count == 0 {
                continue;
            }
            compute = acc(compute, run.count, run.compute_cycles);
            dma = acc(dma, run.count, run.dma_cycles);
            let explicit = run.count.min(WARMUP_TILES);
            for _ in 0..explicit {
                t.step(run.compute_cycles, run.dma_cycles, plan.double_buffered);
            }
            let rest = run.count - explicit;
            if rest > 0 {
                let delta = if plan.double_buffered {
                    run.compute_cycles.max(run.dma_cycles)
                } else {
                    run.compute_cycles
                        .checked_add(run.dma_cycles)
                        .expect("per-tile serial cycles overflow u64")
                };
                t.shift(acc(0, rest, delta));
            }
        }
    }
    Schedule {
        latency_cycles: t.prev,
        compute_cycles: compute,
        dma_cycles: dma,
    }
}

/// Resolve a whole layer: the GEMM timeline plus the serial reshuffler
/// pass (raw-layout feature maps must be re-laid-out before streaming).
/// The pass extends both the latency and the engine-side busy time —
/// nothing overlaps it, so `hidden_cycles` is unchanged by it and keeps
/// matching the layer's `(compute + aux + dma) - latency` accounting.
pub fn schedule_layer(plan: &LayerPlan) -> Schedule {
    let mut s = schedule(&plan.gemms);
    s.latency_cycles += plan.reshuffle_cycles;
    s.compute_cycles += plan.reshuffle_cycles;
    s
}

/// Integer-exact largest-remainder distributor: hands a fixed `total`
/// out across successive `(count, weight)` slices proportionally to
/// `count * weight`, emitting tile runs whose shares always sum to
/// exactly the cumulative rounded target — no cycle lost or invented.
/// Shared by the coordinator's byte-proportional DMA attribution and by
/// [`scale_dma`]'s re-scaling, so the two stay arithmetically identical.
pub struct DmaSplitter {
    total_weight: u128,
    total: u64,
    acc_weight: u128,
    acc: u64,
}

impl DmaSplitter {
    /// `total_weight` must equal the sum of `count as u128 * weight as
    /// u128` over every slice subsequently pushed; zero disables the
    /// splitter (nothing to distribute against).
    pub fn new(total_weight: u128, total: u64) -> Self {
        DmaSplitter {
            total_weight,
            total,
            acc_weight: 0,
            acc: 0,
        }
    }

    /// Attribute the next slice of `count` tiles (each `compute_cycles`
    /// on the tile engine, proportional weight `weight`) and append its
    /// run(s) — a floor-share run plus a remainder run of `+1` tiles —
    /// to `out`.
    pub fn push(&mut self, out: &mut Vec<TileRun>, count: u64, compute_cycles: u64, weight: u64) {
        if count == 0 || self.total_weight == 0 {
            return;
        }
        self.acc_weight += count as u128 * weight as u128;
        let cum = (self.acc_weight * self.total as u128 / self.total_weight) as u64;
        let share = cum - self.acc;
        self.acc = cum;
        let per_tile = share / count;
        let extra = share % count;
        if count > extra {
            out.push(TileRun {
                count: count - extra,
                compute_cycles,
                dma_cycles: per_tile,
            });
        }
        if extra > 0 {
            out.push(TileRun {
                count: extra,
                compute_cycles,
                dma_cycles: per_tile + 1,
            });
        }
    }
}

/// Rescale a layer's per-tile DMA attribution to a new layer total —
/// how the plan-time residency pass (`plan::residency`, DESIGN.md §10)
/// folds activation chaining's removed off-chip round-trips into the
/// tile runs before the executor ever schedules them. Distribution is
/// proportional per run, integer-exact: the new run totals sum to
/// exactly `new_total`, so the scheduled latency keeps satisfying the
/// overlap envelope against the layer's accounted DMA cycles.
pub fn scale_dma(plans: &mut [TilePlan], new_total: u64) {
    let old_total: u128 = plans
        .iter()
        .flat_map(|p| p.runs.iter())
        .map(|r| r.count as u128 * r.dma_cycles as u128)
        .sum();
    if old_total == 0 || old_total == new_total as u128 {
        return;
    }
    let mut split = DmaSplitter::new(old_total, new_total);
    for plan in plans.iter_mut() {
        let mut runs = Vec::with_capacity(plan.runs.len() + 1);
        for r in &plan.runs {
            split.push(&mut runs, r.count, r.compute_cycles, r.dma_cycles);
        }
        plan.runs = runs;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Expand every run to count-1 runs: the closed form never kicks in,
    /// so this is the tile-by-tile reference walk.
    fn expand(plans: &[TilePlan]) -> Vec<TilePlan> {
        plans
            .iter()
            .map(|p| TilePlan {
                double_buffered: p.double_buffered,
                runs: p
                    .runs
                    .iter()
                    .flat_map(|r| {
                        std::iter::repeat(TileRun { count: 1, ..*r }).take(r.count as usize)
                    })
                    .collect(),
            })
            .collect()
    }

    fn plan(db: bool, runs: &[(u64, u64, u64)]) -> TilePlan {
        TilePlan {
            double_buffered: db,
            runs: runs
                .iter()
                .map(|&(count, compute_cycles, dma_cycles)| TileRun {
                    count,
                    compute_cycles,
                    dma_cycles,
                })
                .collect(),
        }
    }

    #[test]
    fn empty_schedule_is_zero() {
        assert_eq!(schedule(&[]), Schedule::default());
        let s = schedule(&[plan(true, &[(0, 10, 10)])]);
        assert_eq!(s.latency_cycles, 0);
    }

    #[test]
    fn single_tile_always_serializes() {
        for db in [false, true] {
            let s = schedule(&[plan(db, &[(1, 700, 300)])]);
            assert_eq!(s.latency_cycles, 1000);
            assert_eq!(s.hidden_cycles(), 0);
        }
    }

    #[test]
    fn single_buffered_run_is_fully_serial() {
        let s = schedule(&[plan(false, &[(10, 700, 300)])]);
        assert_eq!(s.latency_cycles, 10_000);
        assert_eq!(s.compute_cycles, 7000);
        assert_eq!(s.dma_cycles, 3000);
        assert_eq!(s.hidden_cycles(), 0);
    }

    #[test]
    fn double_buffered_run_hides_the_shorter_side() {
        // 10 tiles, compute-bound: first transfer exposed, rest hidden.
        let s = schedule(&[plan(true, &[(10, 700, 300)])]);
        assert_eq!(s.latency_cycles, 300 + 10 * 700);
        assert_eq!(s.hidden_cycles(), 9 * 300);
        // DMA-bound: compute tail exposed instead.
        let s = schedule(&[plan(true, &[(10, 300, 700)])]);
        assert_eq!(s.latency_cycles, 10 * 700 + 300);
        assert_eq!(s.hidden_cycles(), 9 * 300);
    }

    #[test]
    fn closed_form_matches_tile_by_tile_walk() {
        // SplitMix64-driven: random mixed plans must schedule identically
        // whether runs advance in closed form or one tile at a time.
        let mut state = 0x5EEDu64;
        let mut next = move || {
            state = state.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = state;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        for case in 0..200 {
            let nplans = 1 + next() % 4;
            let plans: Vec<TilePlan> = (0..nplans)
                .map(|_| {
                    let nruns = 1 + next() % 4;
                    plan(
                        next() % 2 == 0,
                        &(0..nruns)
                            .map(|_| (next() % 40, next() % 500, next() % 500))
                            .collect::<Vec<_>>(),
                    )
                })
                .collect();
            let fast = schedule(&plans);
            let slow = schedule(&expand(&plans));
            assert_eq!(fast, slow, "case {case}: {plans:?}");
        }
    }

    #[test]
    #[should_panic(expected = "overflows u64")]
    fn hostile_run_totals_fail_loudly() {
        // Overflow audit (DESIGN.md §13): a pathologically large
        // synthetic run must panic in the accumulator, never wrap into a
        // short schedule.
        schedule(&[plan(false, &[(u64::MAX, 3, 5)])]);
    }

    #[test]
    fn schedule_stays_in_the_overlap_envelope() {
        let plans = vec![
            plan(true, &[(100, 431, 377), (3, 97, 911)]),
            plan(false, &[(57, 200, 1000)]),
            plan(true, &[(1000, 12, 13)]),
        ];
        let s = schedule(&plans);
        assert!(s.latency_cycles >= s.compute_cycles.max(s.dma_cycles));
        assert!(s.latency_cycles <= s.compute_cycles + s.dma_cycles);
        assert!(s.hidden_cycles() > 0);
    }

    #[test]
    fn layer_plan_adds_serial_reshuffle() {
        let lp = LayerPlan {
            gemms: vec![plan(true, &[(4, 100, 50)])],
            reshuffle_cycles: 777,
        };
        let base = schedule(&lp.gemms);
        let s = schedule_layer(&lp);
        assert_eq!(s.latency_cycles, base.latency_cycles + 777);
        assert_eq!(s.hidden_cycles(), base.hidden_cycles());
    }

    #[test]
    fn scale_dma_is_integer_exact_and_proportional() {
        let mut plans = vec![
            plan(true, &[(7, 100, 33), (5, 100, 101)]),
            plan(false, &[(13, 50, 67)]),
        ];
        let old: u64 = plans
            .iter()
            .flat_map(|p| p.runs.iter())
            .map(|r| r.count * r.dma_cycles)
            .sum();
        let new_total = old / 3;
        scale_dma(&mut plans, new_total);
        let got: u64 = plans
            .iter()
            .flat_map(|p| p.runs.iter())
            .map(|r| r.count * r.dma_cycles)
            .sum();
        assert_eq!(got, new_total);
        // Tile population is preserved (runs may split, never shrink).
        let tiles: u64 = plans.iter().flat_map(|p| p.runs.iter()).map(|r| r.count).sum();
        assert_eq!(tiles, 7 + 5 + 13);
        // Scaling to zero empties the DMA side entirely.
        scale_dma(&mut plans, 0);
        let gone: u64 = plans
            .iter()
            .flat_map(|p| p.runs.iter())
            .map(|r| r.count * r.dma_cycles)
            .sum();
        assert_eq!(gone, 0);
        let s = schedule(&plans);
        assert_eq!(s.latency_cycles, s.compute_cycles);
    }
}
