//! The quantization SIMD unit (Sec. II-D): functional model + the
//! time-multiplexing cost arithmetic reproduced by `ablation_tmux`.
//!
//! The unit converts the GEMM core's 32-bit outputs to 8-bit, fusing the
//! activation. Exploiting output stationarity, only eight PE lanes are
//! instantiated; a hardware loop unroller walks 64 results through them
//! over eight cycles.

/// Quantization parameters, programmed over CSR.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    pub scale: f32,
    pub relu: bool,
}

/// Functional requantization of one result (bit-exact with the Pallas
/// kernel `python/compile/kernels/quant.py` and its jnp oracle).
#[inline]
pub fn requant_one(acc: i32, p: QuantParams) -> i8 {
    let mut v = (acc as f32 * p.scale).round_ties_even();
    // f32 rounding of .5 cases: the kernel uses jnp.round (banker's
    // rounding), matched by round_ties_even above.
    if p.relu && v < 0.0 {
        v = 0.0;
    }
    v.clamp(-128.0, 127.0) as i8
}

/// The SIMD unit with `lanes` parallel quantization PEs.
#[derive(Clone, Debug)]
pub struct QuantSimd {
    pub lanes: usize,
    pub params: QuantParams,
    /// Total busy cycles (for utilization/energy accounting).
    pub busy_cycles: u64,
    pub results: u64,
}

impl QuantSimd {
    pub fn new(lanes: usize, params: QuantParams) -> Self {
        assert!(lanes > 0);
        QuantSimd {
            lanes,
            params,
            busy_cycles: 0,
            results: 0,
        }
    }

    /// Quantize a block of accumulators, counting the cycles the loop
    /// unroller needs: ceil(len / lanes).
    pub fn process(&mut self, accs: &[i32], out: &mut Vec<i8>) -> u64 {
        let cycles = (accs.len() as u64).div_ceil(self.lanes as u64);
        self.busy_cycles += cycles;
        self.results += accs.len() as u64;
        out.extend(accs.iter().map(|&a| requant_one(a, self.params)));
        cycles
    }

    /// Cycles to drain one 8x8 output tile (64 results).
    pub fn tile_drain_cycles(&self) -> u64 {
        64u64.div_ceil(self.lanes as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const Q1: QuantParams = QuantParams {
        scale: 1.0,
        relu: false,
    };

    #[test]
    fn requant_saturates() {
        assert_eq!(requant_one(1_000_000, Q1), 127);
        assert_eq!(requant_one(-1_000_000, Q1), -128);
        assert_eq!(requant_one(5, Q1), 5);
        assert_eq!(requant_one(-128, Q1), -128);
    }

    #[test]
    fn requant_scales_and_rounds() {
        let q = QuantParams {
            scale: 0.5,
            relu: false,
        };
        assert_eq!(requant_one(5, q), 2); // 2.5 rounds to even
        assert_eq!(requant_one(7, q), 4); // 3.5 rounds to even
        assert_eq!(requant_one(-5, q), -2);
    }

    #[test]
    fn requant_relu() {
        let q = QuantParams {
            scale: 1.0,
            relu: true,
        };
        assert_eq!(requant_one(-7, q), 0);
        assert_eq!(requant_one(7, q), 7);
    }

    #[test]
    fn eight_lane_unit_takes_eight_cycles_per_tile() {
        let mut s = QuantSimd::new(8, Q1);
        let mut out = Vec::new();
        let c = s.process(&[1; 64], &mut out);
        assert_eq!(c, 8); // the paper's 64-results-over-8-cycles
        assert_eq!(out.len(), 64);
        assert_eq!(s.tile_drain_cycles(), 8);
    }

    #[test]
    fn sixtyfour_lane_unit_takes_one_cycle() {
        let s = QuantSimd::new(64, Q1);
        assert_eq!(s.tile_drain_cycles(), 1);
    }

    #[test]
    fn partial_blocks_round_up() {
        let mut s = QuantSimd::new(8, Q1);
        let mut out = Vec::new();
        assert_eq!(s.process(&[0; 9], &mut out), 2);
    }
}
