//! The GEMM core's spatial organisation: Voltra's 8x8x8 3D array and the
//! conventional 2D baseline, with the dimension-mapping and utilization
//! arithmetic of Fig. 6a.
//!
//! The 3D array (Sec. II-A) unrolls all three GEMM dimensions spatially:
//! M and N across the 8x8 Dot-ProdU grid, K across the 8-wide dot product
//! inside each Dot-ProdU. A workload whose dimensions are not multiples
//! of (8, 8, 8) under-fills the array; the *spatial utilization* is the
//! fraction of the 512 MACs doing useful work while the array is firing.
//!
//! The 2D baseline spends all 512 MACs on M x N (16 x 32) and iterates K
//! temporally — so it wastes nothing on K but suffers roughly double the
//! under-fill on skinny M/N (up to 2.0x, Fig. 6a).
//!
//! Both geometries may swap the M/N mapping per layer (a free choice for
//! the hardware loop controller); the model picks the better one, as the
//! chip's compiler would.

use crate::config::ArrayGeometry;

/// Per-compute-step operand demand of an array geometry, used by the
/// cycle engine to drive the streamers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepDemand {
    /// Parallel input channels, each fetching one 64-bit word per step.
    pub input_channels: usize,
    /// Weight words per step when fetched through ordinary 64-bit ports.
    pub weight_words: usize,
    /// Whether the weight fetch is one 512-bit super-bank access.
    pub weight_super_bank: bool,
    /// K elements consumed per compute step.
    pub k_per_step: usize,
    /// Output-stationary tile shape held in the array (rows, cols).
    pub tile_m: usize,
    pub tile_n: usize,
}

/// Resolved mapping of a GEMM onto an array geometry.
#[derive(Clone, Copy, Debug)]
pub struct Mapping {
    pub geometry: ArrayGeometry,
    /// Whether M and N were swapped relative to the workload's (M, N).
    pub swapped: bool,
    pub demand: StepDemand,
}

impl Mapping {
    /// Choose the better of (M, N) and (N, M) for this geometry.
    pub fn choose(geometry: ArrayGeometry, m: u64, n: u64) -> Mapping {
        let direct = spatial_utilization_mapped(geometry, m, n, false);
        let swapped = spatial_utilization_mapped(geometry, m, n, true);
        let swap = swapped > direct + 1e-12;
        Mapping {
            geometry,
            swapped: swap,
            demand: step_demand(geometry),
        }
    }

    /// Effective array dims (am, an, ak) after the swap decision.
    pub fn array_dims(&self) -> (u64, u64, u64) {
        let (am, an, ak) = match self.geometry {
            ArrayGeometry::Spatial3D { m, n, k } => (m as u64, n as u64, k as u64),
            ArrayGeometry::Spatial2D { m, n } => (m as u64, n as u64, 1),
        };
        if self.swapped {
            (an, am, ak)
        } else {
            (am, an, ak)
        }
    }
}

/// Per-step operand demand for a geometry (INT8 operands, 8-byte words).
pub fn step_demand(geometry: ArrayGeometry) -> StepDemand {
    match geometry {
        ArrayGeometry::Spatial3D { m, n, k } => StepDemand {
            // One 64-bit word per array row: 8 input channels (Fig. 3a).
            input_channels: m,
            // 8 rows x 8 K-elems of weights = 64 B = one super bank
            // (Fig. 3b).
            weight_words: k * n / 8,
            weight_super_bank: true,
            k_per_step: k,
            tile_m: m,
            tile_n: n,
        },
        ArrayGeometry::Spatial2D { m, n } => StepDemand {
            // One K-element per MAC column per cycle: m INT8 values for
            // the input vector = m/8 words; n values for the weight row.
            input_channels: (m / 8).max(1),
            weight_words: (n / 8).max(1),
            weight_super_bank: false,
            k_per_step: 1,
            tile_m: m,
            tile_n: n,
        },
    }
}

#[inline]
fn fill(dim: u64, unroll: u64) -> f64 {
    if dim == 0 {
        return 0.0;
    }
    let rounds = dim.div_ceil(unroll);
    dim as f64 / (rounds * unroll) as f64
}

fn spatial_utilization_mapped(geometry: ArrayGeometry, m: u64, n: u64, swap: bool) -> f64 {
    let (m, n) = if swap { (n, m) } else { (m, n) };
    match geometry {
        ArrayGeometry::Spatial3D {
            m: am,
            n: an,
            k: _,
        } => fill(m, am as u64) * fill(n, an as u64),
        ArrayGeometry::Spatial2D { m: am, n: an } => fill(m, am as u64) * fill(n, an as u64),
    }
}

/// Spatial utilization of one GEMM (M, K, N) on a geometry, best mapping.
///
/// For the 3D array the K dimension is spatially unrolled 8-wide, so a
/// ragged K under-fills the Dot-ProdUs; for the 2D array K is temporal
/// and contributes no spatial loss.
pub fn spatial_utilization(geometry: ArrayGeometry, m: u64, k: u64, n: u64) -> f64 {
    let mn = spatial_utilization_mapped(geometry, m, n, false)
        .max(spatial_utilization_mapped(geometry, m, n, true));
    match geometry {
        ArrayGeometry::Spatial3D { k: ak, .. } => mn * fill(k, ak as u64),
        ArrayGeometry::Spatial2D { .. } => mn,
    }
}

/// Ideal active compute cycles for a GEMM on a geometry (no stalls):
/// every (am x an) output tile needs ceil(K / ak) steps.
pub fn ideal_active_cycles(geometry: ArrayGeometry, m: u64, k: u64, n: u64) -> u64 {
    let (am, an, ak) = match geometry {
        ArrayGeometry::Spatial3D { m, n, k } => (m as u64, n as u64, k as u64),
        ArrayGeometry::Spatial2D { m, n } => (m as u64, n as u64, 1),
    };
    // Best mapping (swap M/N if it reduces rounds).
    let direct = m.div_ceil(am) * n.div_ceil(an);
    let swapped = n.div_ceil(am) * m.div_ceil(an);
    direct.min(swapped) * k.div_ceil(ak)
}

/// The residue of `dim` in its `i`-th block of size `unroll`
/// (full blocks return `unroll`, the last may be partial).
#[inline]
pub fn block_residue(dim: u64, unroll: u64, i: u64) -> u64 {
    let full = dim / unroll;
    if i < full {
        unroll
    } else {
        dim - full * unroll
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const A3: ArrayGeometry = ArrayGeometry::Spatial3D { m: 8, n: 8, k: 8 };
    const A2: ArrayGeometry = ArrayGeometry::Spatial2D { m: 16, n: 32 };

    #[test]
    fn aligned_gemm_is_fully_utilized() {
        assert!((spatial_utilization(A3, 96, 96, 96) - 1.0).abs() < 1e-12);
        assert!((spatial_utilization(A2, 96, 96, 96) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ragged_k_hurts_3d_not_2d() {
        // K = 9 fills 9/16 of two dot-product rounds on the 3D array.
        let u3 = spatial_utilization(A3, 64, 9, 64);
        assert!((u3 - 9.0 / 16.0).abs() < 1e-12);
        let u2 = spatial_utilization(A2, 64, 9, 64);
        assert!((u2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn skinny_m_hurts_2d_twice_as_much() {
        // M = 8: 3D fills 8/8 = 1.0; 2D fills 8/16 = 0.5 -> the "up to
        // 2.0x" of Fig. 6a.
        let u3 = spatial_utilization(A3, 8, 512, 512);
        let u2 = spatial_utilization(A2, 8, 512, 512);
        assert!((u3 - 1.0).abs() < 1e-12);
        assert!((u2 - 0.5).abs() < 1e-12);
        assert!((u3 / u2 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn swap_is_used_when_beneficial() {
        // M = 32, N = 16 on the 16x32 2D array: direct fill = 1.0 after
        // swap; without swap it is (32/32)*(16/32) = 0.5.
        let u = spatial_utilization(A2, 32, 64, 16);
        assert!((u - 1.0).abs() < 1e-12);
        let m = Mapping::choose(A2, 32, 16);
        assert!(m.swapped);
    }

    #[test]
    fn gemv_utilization_gap_is_bounded() {
        // Single-token GEMV (M=1): 12.5% on 3D, 6.25% on 2D.
        let u3 = spatial_utilization(A3, 1, 3072, 3072);
        let u2 = spatial_utilization(A2, 1, 3072, 3072);
        assert!((u3 - 0.125).abs() < 1e-12);
        // 2D swaps to place N on the 32 side; M=1 on the 16 side.
        assert!((u2 - 1.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn ideal_cycles_match_mac_count_when_aligned() {
        // 64x64x64 on 8x8x8: 64 tiles x 8 ksteps = 512 cycles; equals
        // MACs / 512.
        let c = ideal_active_cycles(A3, 64, 64, 64);
        assert_eq!(c, 512);
        assert_eq!(c, 64 * 64 * 64 / 512);
    }

    #[test]
    fn step_demand_matches_paper_channels() {
        let d = step_demand(A3);
        assert_eq!(d.input_channels, 8); // 64-bit fine-grained channels
        assert!(d.weight_super_bank); // 512-bit coarse channel
        assert_eq!(d.weight_words, 8);
        assert_eq!(d.tile_m * d.tile_n, 64);
    }

    #[test]
    fn residues() {
        assert_eq!(block_residue(20, 8, 0), 8);
        assert_eq!(block_residue(20, 8, 1), 8);
        assert_eq!(block_residue(20, 8, 2), 4);
    }
}
