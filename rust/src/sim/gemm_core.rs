//! The GEMM core's spatial organisation: Voltra's 8x8x8 3D array and the
//! conventional 2D baseline, with the dimension-mapping and utilization
//! arithmetic of Fig. 6a.
//!
//! The 3D array (Sec. II-A) unrolls all three GEMM dimensions spatially:
//! M and N across the 8x8 Dot-ProdU grid, K across the 8-wide dot product
//! inside each Dot-ProdU. A workload whose dimensions are not multiples
//! of (8, 8, 8) under-fills the array; the *spatial utilization* is the
//! fraction of the 512 MACs doing useful work while the array is firing.
//!
//! The 2D baseline spends all 512 MACs on M x N (16 x 32) and iterates K
//! temporally — so it wastes nothing on K but suffers roughly double the
//! under-fill on skinny M/N (up to 2.0x, Fig. 6a).
//!
//! A [`Mapping`] is the resolved placement of one GEMM onto a geometry
//! and the **single authority** for every mapping-derived quantity
//! (utilization, ideal cycles, streamer demand). Two degrees of freedom:
//!
//! * **M/N permutation** — both geometries may transpose the output tile
//!   (a free choice for the hardware loop controller);
//! * **K-extension folding** (3D only, Sec. II-A / OpenGeMM): when a
//!   spatial dimension under-fills its 8-wide axis, idle array rows are
//!   re-mapped onto extra K lanes — `fold = f` leaves `8/f` rows and
//!   accumulates `8*f` K elements per step. The GEMV case (M = 1) folds
//!   all eight rows into a 64-deep spatial dot product instead of idling
//!   at 12.5% fill.
//!
//! Which candidate wins for a given layer is decided by the cycle-domain
//! search in [`crate::tiling::mapper`]; this module only provides the
//! mapping arithmetic.

use crate::config::{ArrayGeometry, ChipConfig};
use crate::sim::engine::TileSpec;

/// Fine-grained input streamer channels available to the tile engine.
pub const MAX_INPUT_CHANNELS: usize = 8;

/// Weight-channel cap: bounds the folded super-bank fetch fan-out and
/// keeps the engine's per-request kind codes (inputs 0..=99, weights
/// 100..=249, psum 250, output 251) collision-free for any `TileSpec`.
pub const MAX_WEIGHT_CHANNELS: usize = 128;

/// Per-compute-step operand demand of a mapped array, used by the
/// cycle engine to drive the streamers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepDemand {
    /// Parallel fetches serving the (logical) input operand, one 64-bit
    /// word each per step.
    pub input_channels: usize,
    /// Weight words consumed per step (64-bit words, all channels).
    pub weight_words: usize,
    /// Parallel fetch requests serving the weight operand per step (a
    /// folded 3D mapping needs `fold` super-bank accesses: folding
    /// destroys the weight reuse across the folded rows).
    pub weight_channels: usize,
    /// Whether the weight fetch uses 512-bit super-bank accesses.
    pub weight_super_bank: bool,
    /// K elements consumed per compute step.
    pub k_per_step: usize,
    /// Output-stationary tile shape held in the array, in LOGICAL (M, N)
    /// orientation — a swapped mapping exchanges these (the regression
    /// this field's old unswapped value caused is pinned in the tests).
    pub tile_m: usize,
    pub tile_n: usize,
}

/// Resolved mapping of a GEMM onto an array geometry: the M/N
/// permutation plus the K-extension fold. Every consumer (tiling
/// search, planner, cycle engine, report) derives from this one value —
/// no second place re-decides the orientation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Mapping {
    pub geometry: ArrayGeometry,
    /// Whether M and N were swapped relative to the workload's (M, N).
    pub swapped: bool,
    /// K-extension fold factor on the array's row axis (1 = none): the
    /// mapped array keeps `rows / fold` rows and extends the spatial K
    /// depth to `k * fold`. Must divide the row count; 3D only.
    pub fold: u8,
}

impl Mapping {
    /// The trivial mapping: no swap, no folding.
    pub fn identity(geometry: ArrayGeometry) -> Mapping {
        Mapping {
            geometry,
            swapped: false,
            fold: 1,
        }
    }

    /// The legacy permutation-only chooser: the better of (M, N) and
    /// (N, M) by M/N fill, no folding. This is the pre-mapper model and
    /// the `MappingSearch::SwapOnly` baseline.
    pub fn swap_only(geometry: ArrayGeometry, m: u64, n: u64) -> Mapping {
        let direct = Mapping::identity(geometry);
        let swapped = Mapping {
            swapped: true,
            ..direct
        };
        if swapped.mn_fill(m, n) > direct.mn_fill(m, n) + 1e-12 {
            swapped
        } else {
            direct
        }
    }

    /// Effective array unrolls `(um, un, uk)` in LOGICAL (M, N, K)
    /// orientation: rows folded onto K first, then the swap applied.
    pub fn array_dims(&self) -> (u64, u64, u64) {
        let f = self.fold.max(1) as u64;
        let (um, un, uk) = match self.geometry {
            ArrayGeometry::Spatial3D { m, n, k } => {
                ((m as u64 / f).max(1), n as u64, k as u64 * f)
            }
            ArrayGeometry::Spatial2D { m, n } => (m as u64, n as u64, 1),
        };
        if self.swapped {
            (un, um, uk)
        } else {
            (um, un, uk)
        }
    }

    /// M/N fill product (the permutation-only objective; K excluded).
    fn mn_fill(&self, m: u64, n: u64) -> f64 {
        let (um, un, _) = self.array_dims();
        fill(m, um) * fill(n, un)
    }

    /// Spatial utilization of GEMM (M, K, N) under this mapping. For the
    /// 3D array a ragged K under-fills the (possibly extended) spatial
    /// dot product; the 2D array iterates K temporally, no spatial loss.
    pub fn spatial_utilization(&self, m: u64, k: u64, n: u64) -> f64 {
        let (um, un, uk) = self.array_dims();
        let mn = fill(m, um) * fill(n, un);
        match self.geometry {
            ArrayGeometry::Spatial3D { .. } => mn * fill(k, uk),
            ArrayGeometry::Spatial2D { .. } => mn,
        }
    }

    /// Ideal active compute cycles (no stalls) under this mapping: every
    /// mapped output tile needs `ceil(K / uk)` steps. The mapping is the
    /// authority — this no longer re-derives a swap of its own.
    pub fn ideal_active_cycles(&self, m: u64, k: u64, n: u64) -> u64 {
        let (um, un, uk) = self.array_dims();
        m.div_ceil(um) * n.div_ceil(un) * k.div_ceil(uk)
    }

    /// Per-step operand demand under this mapping, in LOGICAL operand
    /// terms: `input_channels`/`tile_m` describe the M-side operand
    /// wherever the permutation placed it (the swap-blind demand of the
    /// old `choose` drove streamers with exchanged channel counts).
    ///
    /// This is the CSR-programming view consumers configure streamers
    /// from; the cycle engine derives the equivalent array-space channel
    /// structure from `(geometry, fold)` directly (its word counts match
    /// this function's on every shipped geometry — `(d / 8).max(1)` and
    /// `ceil(d / 8)` agree on the multiple-of-8 unrolls).
    pub fn demand(&self) -> StepDemand {
        let (um, un, uk) = self.array_dims();
        let in_words = ((um * uk) / 8).max(1) as usize;
        let w_words = ((un * uk) / 8).max(1) as usize;
        let three_d = matches!(self.geometry, ArrayGeometry::Spatial3D { .. });
        // The 512-bit super-bank channel serves the array's column side
        // (Fig. 3b); the logical weight operand streams through it
        // unless the mapping transposed the tile.
        let weight_super_bank = three_d && !self.swapped;
        let weight_channels = if !three_d {
            1
        } else if self.swapped {
            // Transposed: the weight operand rides the fine row channels.
            w_words
        } else {
            // Folding multiplies the super-bank fetches: each folded row
            // group needs its own K-slice of the weight matrix.
            self.fold.max(1) as usize
        };
        StepDemand {
            input_channels: in_words,
            weight_words: w_words,
            weight_channels,
            weight_super_bank,
            k_per_step: uk as usize,
            tile_m: um as usize,
            tile_n: un as usize,
        }
    }

    /// Compact human form for the per-layer report column: the effective
    /// unrolls, `T`-suffixed when transposed (e.g. `8x8x8`, `1x8x64`,
    /// `4x8x16T`, `32x16T` for the 2D baseline).
    pub fn describe(&self) -> String {
        let (um, un, uk) = self.array_dims();
        let t = if self.swapped { "T" } else { "" };
        match self.geometry {
            ArrayGeometry::Spatial3D { .. } => format!("{um}x{un}x{uk}{t}"),
            ArrayGeometry::Spatial2D { .. } => format!("{um}x{un}{t}"),
        }
    }
}

/// Per-step operand demand of an unmapped geometry (identity mapping).
pub fn step_demand(geometry: ArrayGeometry) -> StepDemand {
    Mapping::identity(geometry).demand()
}

#[inline]
pub(crate) fn fill(dim: u64, unroll: u64) -> f64 {
    if dim == 0 {
        return 0.0;
    }
    let rounds = dim.div_ceil(unroll);
    dim as f64 / (rounds * unroll) as f64
}

/// Spatial utilization of one GEMM (M, K, N) on a geometry under the
/// legacy permutation-only mapping (no K-extension) — the analytic
/// Fig. 6a formula. The searched quantity lives in
/// [`crate::tiling::mapper`].
pub fn spatial_utilization(geometry: ArrayGeometry, m: u64, k: u64, n: u64) -> f64 {
    Mapping::swap_only(geometry, m, n).spatial_utilization(m, k, n)
}

/// Ideal active compute cycles for a GEMM on a geometry under the legacy
/// permutation-only mapping. Delegates to the resolved [`Mapping`] — the
/// old version re-derived the orientation by min rounds, independently
/// of the utilization-based swap choice (the split-authority bug).
pub fn ideal_active_cycles(geometry: ArrayGeometry, m: u64, k: u64, n: u64) -> u64 {
    Mapping::swap_only(geometry, m, n).ideal_active_cycles(m, k, n)
}

/// The residue of `dim` in its `i`-th block of size `unroll`
/// (full blocks return `unroll`, the last may be partial).
#[inline]
pub fn block_residue(dim: u64, unroll: u64, i: u64) -> u64 {
    let full = dim / unroll;
    if i < full {
        unroll
    } else {
        dim - full * unroll
    }
}

/// Resolved streaming geometry of one tile on one chip config: the
/// effective unrolls after K-extension folding, the streamer channel
/// structure, the step/row counts and the derived totals the cycle
/// engine iterates over. Factored out of `simulate_tile` so the
/// steady-state fast path's eligibility predicate (DESIGN.md §12) can
/// be evaluated without constructing a simulator, and so the engine and
/// the fast path can never disagree on a derived quantity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileGeometry {
    /// Effective K-extension fold (clamped to the row count; 1 on 2D).
    pub fold: u64,
    /// Effective array unrolls (rows after folding, cols, K depth).
    pub am: u64,
    pub an: u64,
    pub ak: u64,
    /// Fine-grained input channels and weight channels per step.
    pub n_in: usize,
    pub n_w_ch: usize,
    /// Weight request stride in words, and whether it is super-banked.
    pub w_stride: u64,
    pub w_super: bool,
    /// Subtile grid and temporal K steps per subtile.
    pub sub_m: u64,
    pub sub_n: u64,
    pub ksteps: u64,
    pub n_sub: u64,
    pub total_steps: u64,
    pub outputs_per_sub: u64,
    /// Psum words per subtile (int32 accumulators, 2 per 64-bit word).
    pub psum_words_per_sub: u64,
    /// Total psum words streamed in (0 unless a continuation tile).
    pub psum_total: u64,
    /// Residue-aware output bytes the streamer must write back.
    pub out_total_bytes: u64,
    pub fifo_depth: u64,
    /// Raw row-major input row stride (one K-row per array row).
    pub row_stride_words: u64,
    pub max_cycles: u64,
    /// Compute steps per subtile row (`sub_n * ksteps`) — the period
    /// unit of the fast path's row recurrence.
    pub row_steps: u64,
    /// Psum words consumed per subtile row.
    pub psum_row: u64,
}

impl TileGeometry {
    pub fn derive(cfg: &ChipConfig, spec: &TileSpec) -> TileGeometry {
        // The fold cannot exceed the physical row count, and the weight
        // request encoding reserves codes 100..=249 for the weight
        // channels — clamp rather than let a hostile TileSpec alias
        // another channel's code.
        let fold = match cfg.array {
            ArrayGeometry::Spatial3D { m, .. } => {
                (spec.fold.max(1) as u64).min(m as u64).min(MAX_WEIGHT_CHANNELS as u64)
            }
            ArrayGeometry::Spatial2D { .. } => 1,
        };
        let (am, an, ak, n_in, n_w_ch, w_stride, w_super) = match cfg.array {
            ArrayGeometry::Spatial3D { m, n, k } => (
                (m as u64 / fold).max(1),
                n as u64,
                k as u64 * fold,
                m.min(MAX_INPUT_CHANNELS),
                fold as usize,
                8u64, // one aligned super bank per fetch
                true,
            ),
            ArrayGeometry::Spatial2D { m, n } => (
                m as u64,
                n as u64,
                1u64,
                (m / 8).max(1).min(MAX_INPUT_CHANNELS),
                1usize,
                (n / 8).max(1) as u64,
                false,
            ),
        };
        let sub_m = spec.tm.div_ceil(am).max(1);
        let sub_n = spec.tn.div_ceil(an).max(1);
        let ksteps = spec.tk.div_ceil(ak).max(1);
        let n_sub = sub_m * sub_n;
        let total_steps = n_sub * ksteps;
        let outputs_per_sub = am * an;
        let psum_words_per_sub = (outputs_per_sub * 4).div_ceil(8);
        let out_bytes_per_result: u64 = if spec.spill_out { 4 } else { 1 };
        let mut out_total_bytes: u64 = 0;
        for ti in 0..sub_m {
            for tj in 0..sub_n {
                let mr = block_residue(spec.tm, am, ti);
                let nr = block_residue(spec.tn, an, tj);
                out_total_bytes += mr * nr * out_bytes_per_result;
            }
        }
        TileGeometry {
            fold,
            am,
            an,
            ak,
            n_in,
            n_w_ch,
            w_stride,
            w_super,
            sub_m,
            sub_n,
            ksteps,
            n_sub,
            total_steps,
            outputs_per_sub,
            psum_words_per_sub,
            psum_total: if spec.psum_in {
                n_sub * psum_words_per_sub
            } else {
                0
            },
            out_total_bytes,
            fifo_depth: if cfg.prefetch {
                cfg.stream_fifo_depth as u64
            } else {
                1
            },
            row_stride_words: ksteps,
            max_cycles: 1_000_000 + total_steps * 64,
            row_steps: sub_n * ksteps,
            psum_row: sub_n * psum_words_per_sub,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const A3: ArrayGeometry = ArrayGeometry::Spatial3D { m: 8, n: 8, k: 8 };
    const A2: ArrayGeometry = ArrayGeometry::Spatial2D { m: 16, n: 32 };

    #[test]
    fn aligned_gemm_is_fully_utilized() {
        assert!((spatial_utilization(A3, 96, 96, 96) - 1.0).abs() < 1e-12);
        assert!((spatial_utilization(A2, 96, 96, 96) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ragged_k_hurts_3d_not_2d() {
        // K = 9 fills 9/16 of two dot-product rounds on the 3D array.
        let u3 = spatial_utilization(A3, 64, 9, 64);
        assert!((u3 - 9.0 / 16.0).abs() < 1e-12);
        let u2 = spatial_utilization(A2, 64, 9, 64);
        assert!((u2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn skinny_m_hurts_2d_twice_as_much() {
        // M = 8: 3D fills 8/8 = 1.0; 2D fills 8/16 = 0.5 -> the "up to
        // 2.0x" of Fig. 6a.
        let u3 = spatial_utilization(A3, 8, 512, 512);
        let u2 = spatial_utilization(A2, 8, 512, 512);
        assert!((u3 - 1.0).abs() < 1e-12);
        assert!((u2 - 0.5).abs() < 1e-12);
        assert!((u3 / u2 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn swap_is_used_when_beneficial() {
        // M = 32, N = 16 on the 16x32 2D array: direct fill = 1.0 after
        // swap; without swap it is (32/32)*(16/32) = 0.5.
        let u = spatial_utilization(A2, 32, 64, 16);
        assert!((u - 1.0).abs() < 1e-12);
        let m = Mapping::swap_only(A2, 32, 16);
        assert!(m.swapped);
    }

    #[test]
    fn swapped_demand_exchanges_the_operand_channels() {
        // Regression: `swapped: true` used to return the UNSWAPPED
        // demand — tile_m/tile_n, input_channels and weight_words were
        // never exchanged, so a consumer of a swapped 2D 16x32 mapping
        // drove the streamers with the wrong channel counts.
        let m = Mapping::swap_only(A2, 32, 16);
        assert!(m.swapped);
        let d = m.demand();
        assert_eq!((d.tile_m, d.tile_n), (32, 16));
        assert_eq!(d.input_channels, 4, "logical M rides the 32-wide side");
        assert_eq!(d.weight_words, 2, "logical N rides the 16-wide side");
        let unswapped = Mapping::identity(A2).demand();
        assert_eq!((unswapped.tile_m, unswapped.tile_n), (16, 32));
        assert_eq!(unswapped.input_channels, 2);
        assert_eq!(unswapped.weight_words, 4);
    }

    #[test]
    fn gemv_utilization_gap_is_bounded_without_folding() {
        // Single-token GEMV (M=1), permutation-only: 12.5% on 3D, 6.25%
        // on 2D. (The mapper's K-extension lifts the 3D case; see
        // tests/mapper.rs.)
        let u3 = spatial_utilization(A3, 1, 3072, 3072);
        let u2 = spatial_utilization(A2, 1, 3072, 3072);
        assert!((u3 - 0.125).abs() < 1e-12);
        // 2D swaps to place N on the 32 side; M=1 on the 16 side.
        assert!((u2 - 1.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn k_extension_folds_idle_rows_onto_k() {
        // GEMV, fold 8: one row, 64-deep spatial accumulation — full
        // fill on an aligned K instead of 12.5%.
        let m = Mapping {
            geometry: A3,
            swapped: false,
            fold: 8,
        };
        assert_eq!(m.array_dims(), (1, 8, 64));
        assert!((m.spatial_utilization(1, 3072, 3072) - 1.0).abs() < 1e-12);
        // fold 4 on a batch-6 GEMM: 2 rows fill exactly (3 rounds of 2).
        let m4 = Mapping {
            geometry: A3,
            swapped: false,
            fold: 4,
        };
        assert_eq!(m4.array_dims(), (2, 8, 32));
        assert!((m4.spatial_utilization(6, 3072, 3072) - 1.0).abs() < 1e-12);
        assert_eq!(m4.ideal_active_cycles(6, 3072, 3072), 3 * 384 * 96);
    }

    #[test]
    fn folded_demand_multiplies_weight_channels() {
        let m = Mapping {
            geometry: A3,
            swapped: false,
            fold: 8,
        };
        let d = m.demand();
        // Input side: 1 row x 64 K-elems = 64 B = 8 words, unchanged.
        assert_eq!(d.input_channels, 8);
        // Weight side: 8 cols x 64 K-elems = 512 B = 8 super banks —
        // folding destroys the weight reuse across the folded rows.
        assert_eq!(d.weight_channels, 8);
        assert_eq!(d.weight_words, 64);
        assert!(d.weight_super_bank);
        assert_eq!(d.k_per_step, 64);
        assert_eq!((d.tile_m, d.tile_n), (1, 8));
    }

    #[test]
    fn ideal_cycles_match_mac_count_when_aligned() {
        // 64x64x64 on 8x8x8: 64 tiles x 8 ksteps = 512 cycles; equals
        // MACs / 512.
        let c = ideal_active_cycles(A3, 64, 64, 64);
        assert_eq!(c, 512);
        assert_eq!(c, 64 * 64 * 64 / 512);
    }

    #[test]
    fn ideal_cycles_follow_the_resolved_mapping() {
        // Single-authority consistency sweep: the utilization-based swap
        // choice and the old independent min-rounds derivation must
        // agree in VALUE for every dim pair — i.e. the resolved mapping
        // never costs more cycles than either orientation (ties and
        // ragged dims were where the split authorities could diverge).
        // The min over both orientations is the independent oracle (the
        // pre-refactor free function's own formula).
        for m in 1..=96u64 {
            for n in 1..=96u64 {
                for k in [1u64, 7, 64] {
                    for geo in [A3, A2] {
                        let direct = Mapping::identity(geo);
                        let swapped = Mapping {
                            swapped: true,
                            ..direct
                        };
                        let oracle = direct
                            .ideal_active_cycles(m, k, n)
                            .min(swapped.ideal_active_cycles(m, k, n));
                        let resolved = Mapping::swap_only(geo, m, n);
                        assert_eq!(
                            resolved.ideal_active_cycles(m, k, n),
                            oracle,
                            "geo {geo:?} m={m} n={n} k={k}: swap choice costs cycles"
                        );
                        assert_eq!(
                            ideal_active_cycles(geo, m, k, n),
                            oracle,
                            "geo {geo:?} m={m} n={n} k={k}: free fn diverged"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn step_demand_matches_paper_channels() {
        let d = step_demand(A3);
        assert_eq!(d.input_channels, 8); // 64-bit fine-grained channels
        assert!(d.weight_super_bank); // 512-bit coarse channel
        assert_eq!(d.weight_words, 8);
        assert_eq!(d.weight_channels, 1);
        assert_eq!(d.tile_m * d.tile_n, 64);
    }

    #[test]
    fn describe_is_compact() {
        assert_eq!(Mapping::identity(A3).describe(), "8x8x8");
        let f = Mapping {
            geometry: A3,
            swapped: false,
            fold: 8,
        };
        assert_eq!(f.describe(), "1x8x64");
        let s = Mapping {
            geometry: A2,
            swapped: true,
            fold: 1,
        };
        assert_eq!(s.describe(), "32x16T");
    }

    #[test]
    fn residues() {
        assert_eq!(block_residue(20, 8, 0), 8);
        assert_eq!(block_residue(20, 8, 1), 8);
        assert_eq!(block_residue(20, 8, 2), 4);
    }
}
