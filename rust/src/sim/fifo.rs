//! Fixed-capacity FIFO: the prefetch buffers inside the data streamers.
//!
//! The chip inserts eight-deep FIFOs into the input/weight access channels
//! (MGDP, Sec. II-B) and one-deep FIFOs into the psum/output channels.
//! The FIFO is the *only* elasticity between the shared memory and the
//! GEMM core: its depth decides how much bank-conflict jitter can be
//! hidden.

/// A bounded FIFO with O(1) push/pop, generic over the queued token.
#[derive(Clone, Debug)]
pub struct Fifo<T> {
    buf: Vec<Option<T>>,
    head: usize,
    len: usize,
}

impl<T: Clone> Fifo<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "FIFO capacity must be positive");
        Fifo {
            buf: vec![None; capacity],
            head: 0,
            len: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_full(&self) -> bool {
        self.len == self.buf.len()
    }

    /// Space left, in tokens — the MIC prefetches only when this is > 0.
    pub fn space(&self) -> usize {
        self.buf.len() - self.len
    }

    pub fn push(&mut self, v: T) -> bool {
        if self.is_full() {
            return false;
        }
        let tail = (self.head + self.len) % self.buf.len();
        self.buf[tail] = Some(v);
        self.len += 1;
        true
    }

    pub fn pop(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        let v = self.buf[self.head].take();
        self.head = (self.head + 1) % self.buf.len();
        self.len -= 1;
        v
    }

    pub fn peek(&self) -> Option<&T> {
        self.buf[self.head].as_ref()
    }

    pub fn clear(&mut self) {
        for s in &mut self.buf {
            *s = None;
        }
        self.head = 0;
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_pop_order() {
        let mut f = Fifo::new(3);
        assert!(f.push(1) && f.push(2) && f.push(3));
        assert!(f.is_full());
        assert!(!f.push(4));
        assert_eq!(f.pop(), Some(1));
        assert!(f.push(4));
        assert_eq!(f.pop(), Some(2));
        assert_eq!(f.pop(), Some(3));
        assert_eq!(f.pop(), Some(4));
        assert_eq!(f.pop(), None);
    }

    #[test]
    fn wraps_many_times() {
        let mut f = Fifo::new(2);
        for i in 0..100 {
            assert!(f.push(i));
            assert_eq!(f.pop(), Some(i));
        }
        assert!(f.is_empty());
    }

    #[test]
    fn space_accounting() {
        let mut f = Fifo::new(8);
        assert_eq!(f.space(), 8);
        f.push(0u64);
        f.push(1);
        assert_eq!(f.space(), 6);
        f.pop();
        assert_eq!(f.space(), 7);
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        let _ = Fifo::<u32>::new(0);
    }
}
