//! Flexible data streamer: the programmable front-end between the shared
//! memory and a functional block (Sec. II-B, Fig. 3).
//!
//! A streamer = multi-dimensional AGU + Memory Interface Controllers
//! (one per access channel) + data FIFOs. This module is the
//! *programming-level* view used by the Snitch CSR interface and the
//! functional data paths (reshuffler, runtime staging); the cycle-level
//! behaviour of the channels lives in `sim::engine`.

use crate::arch;
use crate::sim::agu::{AffineAgu, LoopDim};
use crate::sim::memory::{BankedMemory, Requester};

/// Channel granularity of a streamer (the "mixed-grained" in MGDP).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Grain {
    /// 64-bit channels — fine-grained strided access (input streamer).
    Fine,
    /// 512-bit super-bank channel — coarse-grained bulk access (weight
    /// streamer).
    Coarse,
}

/// A complete streamer program, as written over CSRs by the Snitch core.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StreamerProgram {
    pub base_word: u64,
    pub dims: Vec<LoopDim>,
    pub grain: Grain,
    /// On-the-fly transpose in the weight streamer (Sec. II-C): swaps the
    /// two innermost loop dimensions while streaming.
    pub transpose: bool,
}

impl StreamerProgram {
    pub fn new(base_word: u64, dims: Vec<LoopDim>, grain: Grain) -> Self {
        StreamerProgram {
            base_word,
            dims,
            grain,
            transpose: false,
        }
    }

    pub fn with_transpose(mut self) -> Self {
        self.transpose = true;
        self
    }

    /// Validate against the hardware AGU depth of the target streamer.
    pub fn check_dims(&self, requester: Requester) -> Result<(), String> {
        let max = match requester {
            Requester::Input(_) => arch::INPUT_AGU_DIMS,
            Requester::Weight => arch::WEIGHT_AGU_DIMS,
            _ => 3,
        };
        if self.dims.len() > max {
            return Err(format!(
                "{:?} streamer supports {}-D programs, got {}-D",
                requester,
                max,
                self.dims.len()
            ));
        }
        Ok(())
    }

    /// Build the AGU (applying the transposer's dimension swap).
    pub fn agu(&self) -> AffineAgu {
        let mut dims = self.dims.clone();
        if self.transpose && dims.len() >= 2 {
            dims.swap(0, 1);
        }
        AffineAgu::new(self.base_word, dims)
    }

    /// Words transferred per AGU step (1 fine, 8 coarse).
    pub fn words_per_access(&self) -> u64 {
        match self.grain {
            Grain::Fine => 1,
            Grain::Coarse => arch::SUPER_BANK_BANKS as u64,
        }
    }

    /// Total words the program touches.
    pub fn total_words(&self) -> u64 {
        self.agu().total() * self.words_per_access()
    }
}

/// Functionally stream words out of the memory in program order
/// (build/debug path — the hot path never materializes this).
pub fn read_stream(mem: &BankedMemory, prog: &StreamerProgram) -> Vec<u64> {
    let mut agu = prog.agu();
    let mut out = Vec::with_capacity(prog.total_words() as usize);
    while let Some(a) = agu.next_addr() {
        match prog.grain {
            Grain::Fine => out.push(mem.read_word(a)),
            Grain::Coarse => {
                // A super-bank access returns the whole aligned 64-byte
                // group.
                for i in 0..arch::SUPER_BANK_BANKS as u64 {
                    out.push(mem.read_word(a + i));
                }
            }
        }
    }
    out
}

/// Functionally write a stream into memory in program order.
pub fn write_stream(mem: &mut BankedMemory, prog: &StreamerProgram, words: &[u64]) {
    let mut agu = prog.agu();
    let mut it = words.iter();
    while let Some(a) = agu.next_addr() {
        match prog.grain {
            Grain::Fine => {
                if let Some(w) = it.next() {
                    mem.write_word(a, *w);
                }
            }
            Grain::Coarse => {
                for i in 0..arch::SUPER_BANK_BANKS as u64 {
                    if let Some(w) = it.next() {
                        mem.write_word(a + i, *w);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dim_limits_match_paper() {
        let p6 = StreamerProgram::new(
            0,
            vec![LoopDim { bound: 2, stride: 1 }; 6],
            Grain::Fine,
        );
        assert!(p6.check_dims(Requester::Input(0)).is_ok());
        let p7 = StreamerProgram::new(
            0,
            vec![LoopDim { bound: 2, stride: 1 }; 7],
            Grain::Fine,
        );
        assert!(p7.check_dims(Requester::Input(0)).is_err());
        let w4 = StreamerProgram::new(
            0,
            vec![LoopDim { bound: 2, stride: 1 }; 4],
            Grain::Coarse,
        );
        assert!(w4.check_dims(Requester::Weight).is_err());
    }

    #[test]
    fn fine_stream_roundtrip() {
        let mut mem = BankedMemory::new();
        let prog = StreamerProgram::new(
            10,
            vec![LoopDim { bound: 4, stride: 2 }],
            Grain::Fine,
        );
        write_stream(&mut mem, &prog, &[1, 2, 3, 4]);
        assert_eq!(read_stream(&mem, &prog), vec![1, 2, 3, 4]);
        // Strided placement: words at 10, 12, 14, 16.
        assert_eq!(mem.read_word(12), 2);
        assert_eq!(mem.read_word(11), 0);
    }

    #[test]
    fn coarse_stream_moves_super_banks() {
        let mut mem = BankedMemory::new();
        for i in 0..16 {
            mem.write_word(i, 100 + i);
        }
        let prog = StreamerProgram::new(
            0,
            vec![LoopDim { bound: 2, stride: 8 }],
            Grain::Coarse,
        );
        let got = read_stream(&mem, &prog);
        assert_eq!(got.len(), 16);
        assert_eq!(got[0], 100);
        assert_eq!(got[15], 115);
        assert_eq!(prog.total_words(), 16);
    }

    #[test]
    fn transposer_swaps_walk_order() {
        let mut mem = BankedMemory::new();
        // 2x3 row-major matrix at base 0 (1 word per element).
        for i in 0..6 {
            mem.write_word(i, i);
        }
        let normal = StreamerProgram::new(
            0,
            vec![
                LoopDim { bound: 3, stride: 1 }, // cols
                LoopDim { bound: 2, stride: 3 }, // rows
            ],
            Grain::Fine,
        );
        assert_eq!(read_stream(&mem, &normal), vec![0, 1, 2, 3, 4, 5]);
        let t = normal.clone().with_transpose();
        // K^T on the fly: column-major order.
        assert_eq!(read_stream(&mem, &t), vec![0, 3, 1, 4, 2, 5]);
    }
}
