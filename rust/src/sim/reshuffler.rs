//! The data reshuffler (Sec. II-E): layout transformations that make the
//! streamers' accesses bank-conflict-free.
//!
//! Two transformations the paper names explicitly:
//! * row-major -> *blocked row-major* for GEMM input matrices (each
//!   8-row x 8-col block becomes contiguous, so the input streamer's
//!   eight 64-bit channels hit eight consecutive banks);
//! * HWC -> *C/8HWC8* for Conv2D feature maps (channel groups of eight
//!   become the innermost, contiguous axis).
//!
//! Functional (byte-exact) + a cycle cost model: the unit reads and
//! writes one 64-bit word per cycle per port through its streamer.

/// Row-major (rows x cols) -> blocked row-major with (br x bc) blocks.
/// Elements are bytes (INT8). `rows`/`cols` must tile exactly.
pub fn block_rowmajor(src: &[u8], rows: usize, cols: usize, br: usize, bc: usize) -> Vec<u8> {
    assert_eq!(src.len(), rows * cols);
    assert!(rows % br == 0 && cols % bc == 0, "dims must tile");
    let mut dst = vec![0u8; src.len()];
    let mut w = 0;
    for bi in 0..rows / br {
        for bj in 0..cols / bc {
            for r in 0..br {
                for c in 0..bc {
                    dst[w] = src[(bi * br + r) * cols + bj * bc + c];
                    w += 1;
                }
            }
        }
    }
    dst
}

/// Inverse of [`block_rowmajor`].
pub fn unblock_rowmajor(src: &[u8], rows: usize, cols: usize, br: usize, bc: usize) -> Vec<u8> {
    assert_eq!(src.len(), rows * cols);
    let mut dst = vec![0u8; src.len()];
    let mut r_ = 0;
    for bi in 0..rows / br {
        for bj in 0..cols / bc {
            for r in 0..br {
                for c in 0..bc {
                    dst[(bi * br + r) * cols + bj * bc + c] = src[r_];
                    r_ += 1;
                }
            }
        }
    }
    dst
}

/// HWC -> C/8 H W C8: split channels into groups of 8 and hoist the
/// group index outermost. `c` must be a multiple of 8 (pad first).
pub fn hwc_to_c8hwc8(src: &[u8], h: usize, w: usize, c: usize) -> Vec<u8> {
    assert_eq!(src.len(), h * w * c);
    assert!(c % 8 == 0, "pad channels to a multiple of 8 first");
    let groups = c / 8;
    let mut dst = vec![0u8; src.len()];
    let mut idx = 0;
    for g in 0..groups {
        for y in 0..h {
            for x in 0..w {
                for ci in 0..8 {
                    dst[idx] = src[(y * w + x) * c + g * 8 + ci];
                    idx += 1;
                }
            }
        }
    }
    dst
}

/// Inverse of [`hwc_to_c8hwc8`].
pub fn c8hwc8_to_hwc(src: &[u8], h: usize, w: usize, c: usize) -> Vec<u8> {
    assert_eq!(src.len(), h * w * c);
    let groups = c / 8;
    let mut dst = vec![0u8; src.len()];
    let mut idx = 0;
    for g in 0..groups {
        for y in 0..h {
            for x in 0..w {
                for ci in 0..8 {
                    dst[(y * w + x) * c + g * 8 + ci] = src[idx];
                    idx += 1;
                }
            }
        }
    }
    dst
}

/// Cycle cost of reshuffling `bytes` bytes: the unit streams one 64-bit
/// word per cycle in and out through its dedicated streamer pair
/// (read + write ports operate concurrently), plus a small setup cost
/// for the Snitch CSR programming.
pub fn reshuffle_cycles(bytes: u64) -> u64 {
    const SETUP: u64 = 16;
    SETUP + bytes.div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_roundtrip() {
        let rows = 16;
        let cols = 24;
        let src: Vec<u8> = (0..rows * cols).map(|i| (i % 251) as u8).collect();
        let b = block_rowmajor(&src, rows, cols, 8, 8);
        let back = unblock_rowmajor(&b, rows, cols, 8, 8);
        assert_eq!(back, src);
    }

    #[test]
    fn block_makes_tiles_contiguous() {
        // 8x16 matrix: the first 64 bytes of the blocked form must be the
        // top-left 8x8 tile.
        let rows = 8;
        let cols = 16;
        let src: Vec<u8> = (0..rows * cols).map(|i| i as u8).collect();
        let b = block_rowmajor(&src, rows, cols, 8, 8);
        for r in 0..8 {
            for c in 0..8 {
                assert_eq!(b[r * 8 + c], src[r * cols + c]);
            }
        }
    }

    #[test]
    fn c8hwc8_roundtrip() {
        let (h, w, c) = (5, 7, 16);
        let src: Vec<u8> = (0..h * w * c).map(|i| (i % 253) as u8).collect();
        let t = hwc_to_c8hwc8(&src, h, w, c);
        assert_eq!(c8hwc8_to_hwc(&t, h, w, c), src);
    }

    #[test]
    fn c8hwc8_groups_channels() {
        let (h, w, c) = (2, 2, 16);
        let src: Vec<u8> = (0..h * w * c).map(|i| i as u8).collect();
        let t = hwc_to_c8hwc8(&src, h, w, c);
        // First 8 bytes: channels 0..8 of pixel (0,0) = bytes 0..8.
        assert_eq!(&t[..8], &src[..8]);
        // Next 8: channels 0..8 of pixel (0,1) = bytes 16..24.
        assert_eq!(&t[8..16], &src[16..24]);
    }

    #[test]
    fn cycle_cost_is_streaming() {
        assert_eq!(reshuffle_cycles(0), 16);
        assert_eq!(reshuffle_cycles(64), 16 + 8);
        assert_eq!(reshuffle_cycles(65), 16 + 9);
    }
}
