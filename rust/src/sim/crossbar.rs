//! The fully-connected crossbar between streamers and memory banks, with
//! the time-multiplexed psum/output port of Sec. II-D.
//!
//! The crossbar itself is conflict-free (any port to any bank); conflicts
//! happen at the *banks* (`memory::arbitrate`). What the crossbar model
//! adds is the port discipline: when `tmux_psum_output` is on, the
//! partial-sum read channel and the output write channel share one
//! physical port — at most one of them issues per cycle, psum first
//! ("Voltra prioritizes partial-sum reads over output writes, since
//! output data are generated only after the partial sums are forwarded").
//! This halves the crossbar's access ports for a 1.46x area saving at a
//! measured 0.02% performance cost (reproduced by `ablation_tmux`).

/// Which of the two shared-port clients may issue this cycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PortGrant {
    Psum,
    Output,
    Idle,
}

/// Port-level arbiter for the shared psum/output port.
#[derive(Clone, Debug)]
pub struct PsumOutputPort {
    tmux: bool,
    /// Stats for the ablation bench.
    pub psum_grants: u64,
    pub output_grants: u64,
    pub output_blocked: u64,
}

impl PsumOutputPort {
    pub fn new(tmux: bool) -> Self {
        PsumOutputPort {
            tmux,
            psum_grants: 0,
            output_grants: 0,
            output_blocked: 0,
        }
    }

    /// Decide who may issue this cycle given who wants to.
    /// With tmux off, both can go (the caller issues both); the grant
    /// returned is whichever is pending, psum reported first.
    pub fn arbitrate(&mut self, psum_wants: bool, output_wants: bool) -> (bool, bool) {
        if !self.tmux {
            if psum_wants {
                self.psum_grants += 1;
            }
            if output_wants {
                self.output_grants += 1;
            }
            return (psum_wants, output_wants);
        }
        if psum_wants {
            self.psum_grants += 1;
            if output_wants {
                self.output_blocked += 1;
            }
            (true, false)
        } else if output_wants {
            self.output_grants += 1;
            (false, true)
        } else {
            (false, false)
        }
    }

    pub fn is_tmux(&self) -> bool {
        self.tmux
    }
}

/// Crossbar port count for the area model (`power::area`): the full
/// design wires input (8), weight (8, super-bank), psum (8) and output
/// (8) lanes; time-multiplexing merges the psum and output groups.
pub fn crossbar_ports(tmux_psum_output: bool) -> usize {
    let input = 8;
    let weight = 8;
    let psum_out = if tmux_psum_output { 8 } else { 16 };
    input + weight + psum_out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tmux_prioritizes_psum() {
        let mut p = PsumOutputPort::new(true);
        assert_eq!(p.arbitrate(true, true), (true, false));
        assert_eq!(p.arbitrate(false, true), (false, true));
        assert_eq!(p.arbitrate(true, false), (true, false));
        assert_eq!(p.arbitrate(false, false), (false, false));
        assert_eq!(p.psum_grants, 2);
        assert_eq!(p.output_grants, 1);
        assert_eq!(p.output_blocked, 1);
    }

    #[test]
    fn full_crossbar_allows_both() {
        let mut p = PsumOutputPort::new(false);
        assert_eq!(p.arbitrate(true, true), (true, true));
        assert_eq!(p.output_blocked, 0);
    }

    #[test]
    fn port_counts_for_area_model() {
        assert_eq!(crossbar_ports(true), 24);
        assert_eq!(crossbar_ports(false), 32);
    }
}
