//! The cycle-accurate Voltra chip model.
//!
//! One module per microarchitectural block of Fig. 2:
//! * [`gemm_core`] — the 8x8x8 3D spatial array (+ 2D baseline maths);
//! * [`array2d`] — the conventional 2D baseline of Fig. 6a;
//! * [`memory`] — 32-bank shared memory with super-bank accesses;
//! * [`crossbar`] — port discipline incl. the time-muxed psum/output port;
//! * [`agu`] / [`streamer`] / [`fifo`] — the flexible data streamers;
//! * [`engine`] — the per-tile cycle simulation loop;
//! * [`simd`] — the 8-lane quantization unit;
//! * [`reshuffler`] / [`maxpool`] — auxiliary blocks;
//! * [`snitch`] — CSR programming model;
//! * [`dma`] — off-chip movement;
//! * [`pipeline`] — the event-driven layer pipeline scheduler that
//!   resolves each layer's tile sequence against the DMA engine and the
//!   tile engine (DESIGN.md §9).

pub mod agu;
pub mod array2d;
pub mod crossbar;
pub mod dma;
pub mod engine;
pub mod fifo;
pub mod gemm_core;
pub mod maxpool;
pub mod memory;
pub mod pipeline;
pub mod reshuffler;
pub mod simd;
pub mod snitch;
pub mod streamer;

pub use engine::{
    fast_path_eligible, simulate_tile, simulate_tile_fast, simulate_tile_reference,
    tile_fingerprint, TileSpec,
};
pub use pipeline::{LayerPlan, Schedule, TilePlan, TileRun};
