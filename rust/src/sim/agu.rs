//! Multi-dimensional affine Address Generation Unit (Sec. II-B).
//!
//! Each flexible data streamer embeds an AGU that walks a programmable
//! N-deep loop nest and emits `base + Σ idx_d · stride_d` every step.
//! Voltra instantiates a 6-D AGU in the input streamer (enough for the
//! implicit-im2col access of any Conv2D: kernel-h, kernel-w, channel
//! block, output-x, output-y, batch/row block) and a 3-D AGU in the
//! weight streamer.  The Snitch core programs bounds/strides/base through
//! CSRs (`sim::snitch`).
//!
//! Addresses are in *bank words* (64-bit units) — the granularity at
//! which the shared memory is accessed.

/// One loop dimension: iterates `bound` times advancing by `stride` words.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LoopDim {
    pub bound: u64,
    pub stride: i64,
}

/// A programmable affine AGU with up to `MAX_DIMS` nested loops.
/// Dimension 0 is innermost (fastest varying), matching the chip's CSR
/// programming order.
#[derive(Clone, Debug)]
pub struct AffineAgu {
    base: u64,
    dims: Vec<LoopDim>,
    idx: Vec<u64>,
    done: bool,
}

pub const INPUT_AGU_MAX_DIMS: usize = 6;
pub const WEIGHT_AGU_MAX_DIMS: usize = 3;

impl AffineAgu {
    /// `dims[0]` is the innermost loop. Empty `dims` yields exactly one
    /// address (the base) — the degenerate single-access pattern.
    pub fn new(base: u64, dims: Vec<LoopDim>) -> Self {
        assert!(
            dims.iter().all(|d| d.bound > 0),
            "all loop bounds must be positive"
        );
        let n = dims.len();
        AffineAgu {
            base,
            dims,
            idx: vec![0; n],
            done: false,
        }
    }

    /// Total number of addresses this program emits.
    pub fn total(&self) -> u64 {
        self.dims.iter().map(|d| d.bound).product::<u64>().max(1)
    }

    /// Current address without advancing.
    pub fn current(&self) -> Option<u64> {
        if self.done {
            return None;
        }
        let mut a = self.base as i64;
        for (d, &i) in self.dims.iter().zip(&self.idx) {
            a += d.stride * i as i64;
        }
        debug_assert!(a >= 0, "AGU generated a negative address");
        Some(a as u64)
    }

    /// Emit the current address and step the loop nest.
    pub fn next_addr(&mut self) -> Option<u64> {
        let a = self.current()?;
        // Odometer increment, innermost first.
        let mut carry = true;
        for (d, i) in self.dims.iter().zip(self.idx.iter_mut()) {
            if !carry {
                break;
            }
            *i += 1;
            if *i == d.bound {
                *i = 0;
            } else {
                carry = false;
            }
        }
        if carry {
            self.done = true;
        }
        Some(a)
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    pub fn reset(&mut self) {
        for i in &mut self.idx {
            *i = 0;
        }
        self.done = false;
    }

    /// The 2-D pattern of a row-major matrix tile: `rows` rows of
    /// `words_per_row` consecutive words separated by `row_stride` words.
    pub fn matrix_tile(base: u64, rows: u64, words_per_row: u64, row_stride: i64) -> Self {
        AffineAgu::new(
            base,
            vec![
                LoopDim {
                    bound: words_per_row,
                    stride: 1,
                },
                LoopDim {
                    bound: rows,
                    stride: row_stride,
                },
            ],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_walk() {
        let mut a = AffineAgu::new(10, vec![LoopDim { bound: 4, stride: 2 }]);
        let got: Vec<u64> = std::iter::from_fn(|| a.next_addr()).collect();
        assert_eq!(got, vec![10, 12, 14, 16]);
        assert!(a.is_done());
        assert_eq!(a.next_addr(), None);
    }

    #[test]
    fn nested_loops_inner_first() {
        // 2 rows x 3 words, row stride 10.
        let mut a = AffineAgu::matrix_tile(0, 2, 3, 10);
        let got: Vec<u64> = std::iter::from_fn(|| a.next_addr()).collect();
        assert_eq!(got, vec![0, 1, 2, 10, 11, 12]);
    }

    #[test]
    fn total_counts_product() {
        let a = AffineAgu::new(
            0,
            vec![
                LoopDim { bound: 3, stride: 1 },
                LoopDim { bound: 5, stride: 7 },
            ],
        );
        assert_eq!(a.total(), 15);
    }

    #[test]
    fn degenerate_emits_base_once() {
        let mut a = AffineAgu::new(42, vec![]);
        assert_eq!(a.total(), 1);
        assert_eq!(a.next_addr(), Some(42));
        assert_eq!(a.next_addr(), None);
    }

    #[test]
    fn im2col_6d_pattern() {
        // A miniature implicit-im2col: 2x2 kernel over a 3x3 single-channel
        // map (1 word per pixel, row stride 3), output 2x2, stride 1:
        // 6-D nest degenerates to 4 used dims.
        let mut a = AffineAgu::new(
            0,
            vec![
                LoopDim { bound: 2, stride: 1 }, // kernel w
                LoopDim { bound: 2, stride: 3 }, // kernel h
                LoopDim { bound: 2, stride: 1 }, // out x
                LoopDim { bound: 2, stride: 3 }, // out y
            ],
        );
        let got: Vec<u64> = std::iter::from_fn(|| a.next_addr()).collect();
        assert_eq!(got.len(), 16);
        // First patch: pixels (0,0),(0,1),(1,0),(1,1) -> words 0,1,3,4.
        assert_eq!(&got[..4], &[0, 1, 3, 4]);
        // Last patch starts at pixel (1,1) -> word 4.
        assert_eq!(&got[12..], &[4, 5, 7, 8]);
    }

    #[test]
    fn reset_replays_identically() {
        let mut a = AffineAgu::matrix_tile(5, 3, 2, 4);
        let first: Vec<u64> = std::iter::from_fn(|| a.next_addr()).collect();
        a.reset();
        let second: Vec<u64> = std::iter::from_fn(|| a.next_addr()).collect();
        assert_eq!(first, second);
    }

    #[test]
    #[should_panic]
    fn zero_bound_rejected() {
        let _ = AffineAgu::new(0, vec![LoopDim { bound: 0, stride: 1 }]);
    }
}
