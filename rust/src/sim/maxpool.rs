//! The maxpool unit (Sec. II-E): eight parallel comparison lanes,
//! arbitrary window sizes processed sequentially.

/// Functional max pooling over an HWC INT8 tensor (C along lanes).
/// Returns (out, out_h, out_w).
pub fn maxpool_hwc(
    src: &[i8],
    h: usize,
    w: usize,
    c: usize,
    window: usize,
    stride: usize,
) -> (Vec<i8>, usize, usize) {
    assert!(window >= 1 && stride >= 1 && window <= h && window <= w);
    assert_eq!(src.len(), h * w * c);
    let oh = (h - window) / stride + 1;
    let ow = (w - window) / stride + 1;
    let mut out = vec![i8::MIN; oh * ow * c];
    for oy in 0..oh {
        for ox in 0..ow {
            for dy in 0..window {
                for dx in 0..window {
                    let iy = oy * stride + dy;
                    let ix = ox * stride + dx;
                    for ch in 0..c {
                        let v = src[(iy * w + ix) * c + ch];
                        let o = &mut out[(oy * ow + ox) * c + ch];
                        if v > *o {
                            *o = v;
                        }
                    }
                }
            }
        }
    }
    (out, oh, ow)
}

/// Cycle cost: the eight comparison lanes consume eight channel values
/// per cycle; each output element needs `window^2` comparisons walked
/// sequentially (Sec. II-E "arbitrary window sizes in a sequential
/// manner").
pub fn maxpool_cycles(h: usize, w: usize, c: usize, window: usize, stride: usize) -> u64 {
    let oh = (h - window) / stride + 1;
    let ow = (w - window) / stride + 1;
    let lanes = 8u64;
    let per_out = (window * window) as u64;
    (oh * ow) as u64 * per_out * (c as u64).div_ceil(lanes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool2x2_basic() {
        // 4x4 single channel.
        #[rustfmt::skip]
        let src: Vec<i8> = vec![
            1, 2,   3, 4,
            5, 6,   7, 8,
            -1, -2, -3, -4,
            -5, 0,  9, -8,
        ];
        let (out, oh, ow) = maxpool_hwc(&src, 4, 4, 1, 2, 2);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(out, vec![6, 8, 0, 9]);
    }

    #[test]
    fn pool_window3_stride1() {
        let src: Vec<i8> = (0..25).map(|i| i as i8).collect();
        let (out, oh, ow) = maxpool_hwc(&src, 5, 5, 1, 3, 1);
        assert_eq!((oh, ow), (3, 3));
        // Max of each 3x3 window is its bottom-right element.
        assert_eq!(out[0], 12);
        assert_eq!(out[8], 24);
    }

    #[test]
    fn channels_pool_independently() {
        // 2x2, 2 channels; channel 0 ascending, channel 1 descending.
        let src: Vec<i8> = vec![0, 10, 1, 9, 2, 8, 3, 7];
        let (out, ..) = maxpool_hwc(&src, 2, 2, 2, 2, 2);
        assert_eq!(out, vec![3, 10]);
    }

    #[test]
    fn cycles_scale_with_window_and_channels() {
        let base = maxpool_cycles(8, 8, 8, 2, 2);
        assert_eq!(base, 16 * 4); // 16 outputs x 4 comparisons x 1 lane-group
        let more_c = maxpool_cycles(8, 8, 64, 2, 2);
        assert_eq!(more_c, base * 8);
        let bigger_win = maxpool_cycles(8, 8, 8, 4, 4);
        assert_eq!(bigger_win, 4 * 16);
    }
}
