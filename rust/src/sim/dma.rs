//! The DMA core and off-chip memory model (Sec. II; footnote 1: off-chip
//! movement is simulated, as the paper itself does with an RTL model).
//!
//! Functional: copies bytes between a host-side `Vec<u8>` ("DRAM") and
//! the on-chip `BankedMemory`. Timing: bandwidth-limited bursts with a
//! fixed setup latency; transfers optionally overlap compute (double
//! buffering) when the allocator granted space for two tiles.

use crate::config::ChipConfig;
use crate::sim::memory::BankedMemory;

/// Timing model for one logical transfer.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DmaTransfer {
    pub bytes: u64,
    pub bursts: u64,
    pub cycles: u64,
}

/// Cycle cost of moving `bytes` off-chip<->on-chip.
/// Bursts are 1 KiB (a typical AXI-ish max burst for such SoCs).
///
/// Integer-exact: bandwidth division is `div_ceil` over the integer
/// bytes-per-cycle rate, so multi-petabyte transfer sizes (sweep
/// extremes, hostile inputs) never lose cycles to `f64` rounding and the
/// result is identical on every platform.
pub fn transfer_cost(cfg: &ChipConfig, bytes: u64) -> DmaTransfer {
    const BURST_BYTES: u64 = 1024;
    if bytes == 0 {
        return DmaTransfer::default();
    }
    let bursts = bytes.div_ceil(BURST_BYTES);
    let bw_cycles = bytes.div_ceil(cfg.dma_bytes_per_cycle.max(1));
    // Checked accumulation: a hostile (bytes, burst-latency) pair must
    // fail loudly, not wrap into a plausible-looking short transfer.
    let cycles = bursts
        .checked_mul(cfg.dma_burst_latency)
        .and_then(|b| b.checked_add(bw_cycles))
        .expect("DMA transfer cycle count overflows u64");
    DmaTransfer {
        bytes,
        bursts,
        cycles,
    }
}

/// Combine a layer's compute cycles and DMA cycles into latency,
/// honouring the double-buffering capability (Fig. 6c's "total latency"):
/// with double buffering the longer of the two pipelines dominates and
/// the shorter hides; without, they serialize.
///
/// Retained as the analytic *cross-check* for the event-driven scheduler
/// ([`crate::sim::pipeline`]) that replaced it on the workload path:
/// every schedule must land inside this function's serial/overlapped
/// envelope (asserted by `tests/pipeline_invariants.rs`).
pub fn overlap_latency(compute_cycles: u64, dma_cycles: u64, double_buffered: bool) -> u64 {
    if double_buffered {
        compute_cycles.max(dma_cycles)
            + compute_cycles.min(dma_cycles).min(compute_cycles.max(dma_cycles) / 8)
    } else {
        compute_cycles + dma_cycles
    }
}

/// The DMA engine: functional copies + accumulated statistics.
#[derive(Debug, Default)]
pub struct DmaEngine {
    pub total_bytes_in: u64,
    pub total_bytes_out: u64,
    pub total_cycles: u64,
}

impl DmaEngine {
    /// DRAM -> on-chip memory at `word_addr` (64-bit word granularity).
    pub fn load(
        &mut self,
        cfg: &ChipConfig,
        dram: &[u8],
        dram_off: usize,
        chip: &mut BankedMemory,
        word_addr: u64,
        bytes: usize,
    ) -> DmaTransfer {
        chip.write_bytes(word_addr as usize * 8, &dram[dram_off..dram_off + bytes]);
        let t = transfer_cost(cfg, bytes as u64);
        self.total_bytes_in += bytes as u64;
        self.total_cycles += t.cycles;
        t
    }

    /// On-chip memory -> DRAM.
    pub fn store(
        &mut self,
        cfg: &ChipConfig,
        chip: &BankedMemory,
        word_addr: u64,
        dram: &mut [u8],
        dram_off: usize,
        bytes: usize,
    ) -> DmaTransfer {
        chip.read_bytes(word_addr as usize * 8, &mut dram[dram_off..dram_off + bytes]);
        let t = transfer_cost(cfg, bytes as u64);
        self.total_bytes_out += bytes as u64;
        self.total_cycles += t.cycles;
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    #[test]
    fn cost_scales_with_bytes() {
        let cfg = ChipConfig::voltra();
        let small = transfer_cost(&cfg, 1024);
        let big = transfer_cost(&cfg, 64 * 1024);
        assert!(big.cycles > small.cycles * 32);
        assert_eq!(small.bursts, 1);
        assert_eq!(big.bursts, 64);
    }

    #[test]
    fn zero_transfer_is_free() {
        let cfg = ChipConfig::voltra();
        assert_eq!(transfer_cost(&cfg, 0), DmaTransfer::default());
    }

    #[test]
    fn huge_transfer_timing_is_integer_exact() {
        // Regression: the old `f64` bandwidth division rounded
        // (2^53 + 1) down to 2^53 and lost a cycle — results depended on
        // float rounding instead of being platform-deterministic.
        let cfg = ChipConfig::voltra(); // 8 bytes/cycle
        let bytes = (1u64 << 53) + 1;
        let t = transfer_cost(&cfg, bytes);
        let expect = (1u64 << 50) + 1 + bytes.div_ceil(1024) * cfg.dma_burst_latency;
        assert_eq!(t.cycles, expect);
    }

    #[test]
    #[should_panic(expected = "overflows u64")]
    fn hostile_burst_latency_fails_loudly() {
        // Overflow audit (DESIGN.md §13): bursts * burst_latency on a
        // pathologically large transfer must panic, never wrap.
        let mut cfg = ChipConfig::voltra();
        cfg.dma_burst_latency = u64::MAX;
        transfer_cost(&cfg, u64::MAX);
    }

    #[test]
    fn overlap_hides_shorter_side() {
        let l = overlap_latency(1000, 400, true);
        assert!(l < 1400 && l >= 1000);
        assert_eq!(overlap_latency(1000, 400, false), 1400);
    }

    #[test]
    fn functional_roundtrip() {
        let cfg = ChipConfig::voltra();
        let mut chip = BankedMemory::new();
        let mut dma = DmaEngine::default();
        let dram: Vec<u8> = (0..256).map(|i| i as u8).collect();
        dma.load(&cfg, &dram, 0, &mut chip, 4, 256);
        let mut back = vec![0u8; 256];
        dma.store(&cfg, &chip, 4, &mut back, 0, 256);
        assert_eq!(back, dram);
        assert_eq!(dma.total_bytes_in, 256);
        assert_eq!(dma.total_bytes_out, 256);
    }
}
