//! The coordinator's serving entry points: GEMM, workload, lint and
//! stats requests over TCP, served against process-wide shared caches.
//!
//! Since the serving-stack split (DESIGN.md §14) this file only
//! *composes* the layers; the work lives below it:
//!
//! * [`transport`](crate::coordinator::transport) — connection framing:
//!   the line protocol, response writing, graceful drain on QUIT;
//! * [`dispatch`](crate::coordinator::dispatch) — the bounded worker
//!   pool with admission control (`ERR busy` past `queue_depth`);
//! * [`engine`](crate::coordinator::engine) — the verb handlers both
//!   modes share, answering from the [`SharedTileCache`] and
//!   [`PlanCache`];
//! * [`stats`](crate::coordinator::stats) — per-verb counters and the
//!   latency histogram behind the `STATS` verb.
//!
//! Wire protocol (line-oriented, one request per line): `GEMM`,
//! `WORKLOAD`, `LINT`, `STATS`, `QUIT` — the complete grammar with
//! response forms is in DESIGN.md §14.
//!
//! Two serve modes remain, and they answer byte-identically (modulo the
//! wall-clock `us=` field) because every verb routes through the same
//! [`Engine::handle`]:
//!
//! * [`serve_blocking`] — the single-threaded reference engine:
//!   connections in arrival order, numerics inline on the calling
//!   thread. The differential tests in `tests/concurrent_server.rs`
//!   compare everything else against it.
//! * [`serve_threaded`] — the concurrent engine: one transport thread
//!   per connection, a bounded dispatch queue, [`ServeOptions::workers`]
//!   engine workers, and ONE dedicated numerics worker (PJRT handles
//!   are not `Send`; the backend factory runs on that thread) fed over
//!   a *bounded* channel so slow numerics backpressure the pool instead
//!   of buffering unboundedly.

use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::ChipConfig;
use crate::coordinator::dispatch::{self, Dispatcher};
use crate::coordinator::engine::{
    parse_request, run_numerics, Engine, InlineLane, NumericsJob, Parsed,
};
use crate::coordinator::stats::{RequestStats, Verb};
use crate::coordinator::transport::{self, Reply};
use crate::coordinator::SharedTileCache;
use crate::plan::PlanCache;
use crate::runtime::GemmBackend;

/// Serving counters returned by both engines.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServerStats {
    /// Connections fully served (handler completed without an error).
    pub served: usize,
    /// Connections whose handler failed (logged to stderr).
    pub failed: usize,
}

/// Tuning for [`serve_threaded`]'s dispatch layer.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Accepted-connection cap (`None` = serve forever).
    pub max_conns: Option<usize>,
    /// Engine worker threads draining the dispatch queue.
    pub workers: usize,
    /// Requests allowed to WAIT in the dispatch queue (beyond the one
    /// each worker is executing); a submit past this answers
    /// `ERR busy` instead of queueing.
    pub queue_depth: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_conns: None,
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(8),
            queue_depth: 64,
        }
    }
}

/// One protocol line through parse -> execute -> record: the per-line
/// step both serve modes share. `run` executes the parsed request
/// however the mode likes (inline, or through the dispatch queue);
/// `None` means the request was refused at admission (`ERR busy`).
fn handle_line(
    stats: &RequestStats,
    line: &str,
    run: &mut dyn FnMut(Parsed) -> Option<String>,
) -> Reply {
    let t0 = Instant::now();
    match parse_request(line) {
        Ok(Parsed::Quit) => Reply::Quit,
        Ok(req) => {
            let verb = req.verb();
            match run(req) {
                Some(resp) => {
                    stats.record(verb, t0.elapsed().as_micros() as u64);
                    Reply::Line(resp)
                }
                None => {
                    stats.reject();
                    Reply::Line("ERR busy".to_string())
                }
            }
        }
        Err(resp) => {
            stats.record(Verb::Error, t0.elapsed().as_micros() as u64);
            Reply::Line(resp)
        }
    }
}

/// Serve one connection with the backend inline on the current thread.
fn handle_sequential(
    stream: TcpStream,
    backend: &mut impl GemmBackend,
    engine: Engine<'_>,
) -> Result<()> {
    transport::serve_lines(stream, |line| {
        let mut lane = InlineLane {
            backend: &mut *backend,
        };
        handle_line(engine.stats, line, &mut |req| {
            Some(engine.handle(&req, &mut lane))
        })
    })
}

/// Serve one connection in threaded mode: parse on this thread, admit
/// into the dispatch queue, relay the worker's response. STATS bypasses
/// the queue — a saturated server must stay observable, and the verb is
/// a handful of atomic reads.
fn handle_dispatched(stream: TcpStream, engine: Engine<'_>, d: &Dispatcher) -> Result<()> {
    transport::serve_lines(stream, |line| {
        handle_line(engine.stats, line, &mut |req| match req {
            Parsed::Stats => Some(engine.render_stats()),
            req => d.submit(req).map(|rx| {
                rx.recv()
                    .unwrap_or_else(|_| "ERR internal: worker lost".to_string())
            }),
        })
    })
}

/// Bind the listener (so the caller learns the port before blocking).
pub fn bind(addr: &str) -> Result<TcpListener> {
    TcpListener::bind(addr).with_context(|| format!("bind {addr}"))
}

/// Single-threaded reference engine: serve connections in order on the
/// CURRENT thread. Only *successfully served* connections count toward
/// `max_conns` (`None` = forever); accept failures and handler errors
/// are logged to stderr and do not count.
pub fn serve_blocking(
    backend: &mut impl GemmBackend,
    cfg: &ChipConfig,
    listener: TcpListener,
    max_conns: Option<usize>,
    cache: &SharedTileCache,
    plans: &PlanCache,
) -> Result<ServerStats> {
    let req_stats = RequestStats::new();
    let engine = Engine {
        cfg,
        tiles: cache,
        plans,
        stats: &req_stats,
    };
    let mut stats = ServerStats::default();
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("voltra-serve: accept failed: {e}");
                continue;
            }
        };
        let peer = stream.peer_addr().ok();
        match handle_sequential(stream, backend, engine) {
            Ok(()) => stats.served += 1,
            Err(e) => {
                stats.failed += 1;
                eprintln!("voltra-serve: connection {peer:?} failed: {e:#}");
            }
        }
        if let Some(max) = max_conns {
            if stats.served >= max {
                break;
            }
        }
    }
    Ok(stats)
}

/// The concurrent serving engine: one transport thread per connection,
/// a bounded dispatch queue drained by [`ServeOptions::workers`] engine
/// workers, one dedicated numerics worker, one shared tile cache, one
/// plan cache.
///
/// `backend_factory` runs ON the numerics worker thread (PJRT handles
/// are not `Send`, so the backend must be born where it lives).
/// `opts.max_conns` counts *accepted* connections — with parallel
/// handlers the engine cannot know success before completion;
/// per-connection failures are still logged and reported in the
/// returned [`ServerStats`].
pub fn serve_threaded<B, F>(
    backend_factory: F,
    cfg: &ChipConfig,
    listener: TcpListener,
    opts: ServeOptions,
    cache: &SharedTileCache,
    plans: &PlanCache,
) -> Result<ServerStats>
where
    B: GemmBackend + 'static,
    F: FnOnce() -> Result<B> + Send + 'static,
{
    // Bounded numerics queue (at most one outstanding job per engine
    // worker): when the backend falls behind, WorkerLane's blocking
    // send stalls the pool — backpressure — instead of growing an
    // unbounded buffer.
    let (job_tx, job_rx) = mpsc::sync_channel::<NumericsJob>(opts.workers.max(1));
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    let worker = std::thread::Builder::new()
        .name("voltra-numerics".to_string())
        .spawn(move || {
            let mut backend = match backend_factory() {
                Ok(b) => {
                    let _ = ready_tx.send(Ok(()));
                    b
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(job) = job_rx.recv() {
                let result = run_numerics(&mut backend, job.m, job.k, job.n, job.seed);
                let _ = job.reply.send(result);
            }
        })
        .context("spawn numerics worker")?;
    let ready = ready_rx
        .recv()
        .unwrap_or_else(|_| Err(anyhow!("numerics worker died during startup")));
    if let Err(e) = ready {
        drop(job_tx);
        let _ = worker.join();
        return Err(e);
    }

    fn tally(
        joined: std::thread::Result<Result<(), (Option<std::net::SocketAddr>, anyhow::Error)>>,
        stats: &mut ServerStats,
    ) {
        match joined {
            Ok(Ok(())) => stats.served += 1,
            Ok(Err((peer, e))) => {
                stats.failed += 1;
                eprintln!("voltra-serve: connection {peer:?} failed: {e:#}");
            }
            Err(_) => stats.failed += 1,
        }
    }

    let req_stats = RequestStats::new();
    let mut stats = ServerStats::default();
    std::thread::scope(|s| {
        let engine = Engine {
            cfg,
            tiles: cache,
            plans,
            stats: &req_stats,
        };
        let numerics = job_tx.clone();
        let dispatcher = dispatch::start(s, engine, numerics, opts.workers, opts.queue_depth);
        let mut accepted = 0usize;
        let mut handles = Vec::new();
        for stream in listener.incoming() {
            let stream = match stream {
                Ok(st) => st,
                Err(e) => {
                    eprintln!("voltra-serve: accept failed: {e}");
                    continue;
                }
            };
            // Reap completed handlers first: a long-running server
            // (max_conns = None) must not accumulate join handles, and
            // failure logs should appear as they happen, not at shutdown.
            let mut i = 0;
            while i < handles.len() {
                if handles[i].is_finished() {
                    tally(handles.swap_remove(i).join(), &mut stats);
                } else {
                    i += 1;
                }
            }
            let d = dispatcher.clone();
            handles.push(s.spawn(move || {
                let peer = stream.peer_addr().ok();
                handle_dispatched(stream, engine, &d).map_err(|e| (peer, e))
            }));
            accepted += 1;
            if let Some(max) = opts.max_conns {
                if accepted >= max {
                    break;
                }
            }
        }
        for h in handles {
            tally(h.join(), &mut stats);
        }
        // Every handler's dispatcher clone is gone once they join; drop
        // ours so the pool drains and the scope can join its workers.
        drop(dispatcher);
    });
    drop(job_tx);
    let _ = worker.join();
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_options_default_to_a_bounded_pool() {
        let o = ServeOptions::default();
        assert!(o.max_conns.is_none());
        assert!((1..=8).contains(&o.workers));
        assert_eq!(o.queue_depth, 64);
    }
}
