//! A minimal inference server on top of the runtime: the coordinator's
//! "leader" role serving batched GEMM requests over TCP.
//!
//! Wire protocol (line-oriented, one request per line):
//!     GEMM <m> <k> <n> <seed>\n
//! Response:
//!     OK checksum=<u64> us=<micros> sim_cycles=<u64> sim_us=<f64>\n
//! The server executes the request's numerics on the PJRT runtime
//! (deterministic operands from the seed) and, in parallel, reports what
//! the chip model says the same GEMM would cost on silicon.
//!
//! Substrate note: tokio is not vendored in the build image and the
//! PJRT handles are not `Send`, so the server is a single-threaded
//! std::net accept loop that owns the artifact library — connections are
//! served in order (the heavy lifting is inside PJRT anyway); clients
//! run on their own threads.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::ChipConfig;
use crate::coordinator::{run_layer, TileCache};
use crate::runtime::{gemm_tiled, ArtifactLib, MatI32};
use crate::workloads::layer::{Layer, LayerKind};

/// Deterministic operand generator (SplitMix64 -> int8 range).
fn gen_mat(seed: u64, rows: usize, cols: usize) -> MatI32 {
    let mut s = seed;
    MatI32::from_fn(rows, cols, |_, _| {
        s = s.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = s;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        ((z ^ (z >> 31)) % 255) as i32 - 127
    })
}

/// One request's results.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GemmResponse {
    pub checksum: u64,
    pub wall_us: u128,
    pub sim_cycles: u64,
    pub sim_us: f64,
}

/// Execute one GEMM request: real numerics on PJRT + chip-model timing.
pub fn serve_gemm(
    lib: &mut ArtifactLib,
    cfg: &ChipConfig,
    cache: &mut TileCache,
    m: usize,
    k: usize,
    n: usize,
    seed: u64,
) -> Result<GemmResponse> {
    if m == 0 || k == 0 || n == 0 || m * k + k * n > 64 << 20 {
        bail!("unreasonable GEMM size {m}x{k}x{n}");
    }
    let x = gen_mat(seed, m, k);
    let w = gen_mat(seed ^ 0xABCD_EF01, k, n);
    let p = MatI32::zeros(m, n);
    let t0 = Instant::now();
    let (q, _acc) = gemm_tiled(lib, &x, &w, &p, 0.002)?;
    let wall_us = t0.elapsed().as_micros();
    let checksum = q
        .data
        .iter()
        .fold(0u64, |h, &v| h.wrapping_mul(31).wrapping_add(v as u8 as u64));

    // What would the chip cost? (memoized cycle model)
    let layer = Layer::new(
        "req",
        LayerKind::Gemm {
            m: m as u64,
            k: k as u64,
            n: n as u64,
        },
    );
    let lm = run_layer(cfg, &layer, cache);
    let sim_cycles = lm.latency_cycles;
    let sim_us = sim_cycles as f64 / cfg.operating_point.freq_mhz;
    Ok(GemmResponse {
        checksum,
        wall_us,
        sim_cycles,
        sim_us,
    })
}

fn handle(stream: TcpStream, lib: &mut ArtifactLib, cfg: &ChipConfig) -> Result<()> {
    let mut out = stream.try_clone().context("clone stream")?;
    let reader = BufReader::new(stream);
    let mut cache = TileCache::new();
    for line in reader.lines() {
        let line = line?;
        let parts: Vec<&str> = line.split_whitespace().collect();
        match parts.as_slice() {
            ["GEMM", m, k, n, seed] => {
                let (m, k, n, seed) = (
                    m.parse().unwrap_or(0),
                    k.parse().unwrap_or(0),
                    n.parse().unwrap_or(0),
                    seed.parse().unwrap_or(0),
                );
                match serve_gemm(lib, cfg, &mut cache, m, k, n, seed) {
                    Ok(r) => writeln!(
                        out,
                        "OK checksum={} us={} sim_cycles={} sim_us={:.2}",
                        r.checksum, r.wall_us, r.sim_cycles, r.sim_us
                    )?,
                    Err(e) => writeln!(out, "ERR {e}")?,
                }
            }
            ["QUIT"] => break,
            _ => writeln!(out, "ERR expected: GEMM <m> <k> <n> <seed> | QUIT")?,
        }
    }
    Ok(())
}

/// Bind the listener (so the caller learns the port before blocking).
pub fn bind(addr: &str) -> Result<TcpListener> {
    TcpListener::bind(addr).with_context(|| format!("bind {addr}"))
}

/// Run the accept loop on the CURRENT thread until `max_conns`
/// connections have been served (`None` = forever). PJRT handles are not
/// `Send`, so the artifact library lives here.
pub fn serve_blocking(
    mut lib: ArtifactLib,
    cfg: &ChipConfig,
    listener: TcpListener,
    max_conns: Option<usize>,
) -> Result<()> {
    let mut served = 0usize;
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let _ = handle(stream, &mut lib, cfg);
        served += 1;
        if let Some(max) = max_conns {
            if served >= max {
                break;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_operands_are_deterministic_and_int8() {
        let a = gen_mat(7, 16, 16);
        let b = gen_mat(7, 16, 16);
        assert_eq!(a, b);
        assert!(a.data.iter().all(|&v| (-127..=127).contains(&v)));
        let c = gen_mat(8, 16, 16);
        assert_ne!(a, c);
    }

    #[test]
    fn checksum_is_order_sensitive() {
        let h = |v: &[i32]| {
            v.iter()
                .fold(0u64, |h, &x| h.wrapping_mul(31).wrapping_add(x as u8 as u64))
        };
        assert_ne!(h(&[1, 2, 3]), h(&[3, 2, 1]));
    }
}
