//! The coordinator's serving engine: GEMM and workload requests over
//! TCP, served concurrently against process-wide shared caches.
//!
//! Wire protocol (line-oriented, one request per line):
//!     GEMM <m> <k> <n> <seed>\n
//!     WORKLOAD <name>\n
//!     LINT <name>\n
//! Responses:
//!     OK checksum=<u64> us=<micros> sim_cycles=<u64> sim_us=<f64>\n
//!     OK workload=<name> latency_cycles=<u64> compute_cycles=<u64>
//!        dma_cycles=<u64> dma_kb=<u64> tiles=<u64> sim_ms=<f64>\n
//!     OK lint workload=<name> findings=<u64>\n
//! A GEMM request executes the request's numerics (deterministic
//! operands from the seed) and, in parallel, reports what the chip model
//! says the same GEMM would cost on silicon. A WORKLOAD request answers
//! entirely from the [`PlanCache`]: the first request for a network
//! compiles its plan, every later request (from any connection) executes
//! the memoized plan — zero tiling searches, zero tile simulations.
//!
//! Concurrency model (DESIGN.md §Concurrency):
//! * every accepted connection gets its own handler thread;
//! * the chip-model cost lookup runs *on the handler thread*, answered
//!   from the [`SharedTileCache`] / [`PlanCache`] — many connections
//!   resolve sim costs concurrently, and a tile or plan any connection
//!   ever computed is never computed again for the server's lifetime;
//! * the numerics backend is confined to ONE dedicated worker thread
//!   fed over an mpsc channel (PJRT handles are not `Send`; the
//!   [`GemmBackend`] factory runs on that thread), with per-request
//!   reply channels. While the worker crunches a request's numerics the
//!   handler overlaps the sim-cost computation for the same request.
//!
//! [`serve_blocking`] remains as the single-threaded reference engine:
//! byte-identical responses (modulo the wall-clock `us=` field, the
//! protocol's only nondeterministic bytes), used by the differential
//! tests in `tests/concurrent_server.rs`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ChipConfig;
use crate::coordinator::{run_layer, SharedTileCache};
use crate::plan::{PlanCache, WorkloadPlan};
use crate::runtime::{GemmBackend, MatI32};
use crate::workloads::{self, Layer, LayerKind};

/// Deterministic operand generator (SplitMix64 -> int8 range).
fn gen_mat(seed: u64, rows: usize, cols: usize) -> MatI32 {
    let mut s = seed;
    MatI32::from_fn(rows, cols, |_, _| {
        s = s.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = s;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        ((z ^ (z >> 31)) % 255) as i32 - 127
    })
}

/// One GEMM request's results.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GemmResponse {
    pub checksum: u64,
    pub wall_us: u128,
    pub sim_cycles: u64,
    pub sim_us: f64,
}

/// Serving counters returned by both engines.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServerStats {
    /// Connections fully served (handler completed without an error).
    pub served: usize,
    /// Connections whose handler failed (logged to stderr).
    pub failed: usize,
}

/// A parsed request line.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Parsed {
    Gemm {
        m: usize,
        k: usize,
        n: usize,
        seed: u64,
    },
    Workload {
        name: String,
    },
    Lint {
        name: String,
    },
    Quit,
}

/// The usage line sent back for any request the parser cannot shape.
const USAGE: &str =
    "ERR expected: GEMM <m> <k> <n> <seed> | WORKLOAD <name> | LINT <name> | QUIT";

/// Parse one request line; `Err` carries the full `ERR ...` response.
fn parse_request(line: &str) -> std::result::Result<Parsed, String> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["GEMM", m, k, n, seed] => {
            fn int<T: std::str::FromStr>(tok: &str) -> std::result::Result<T, String> {
                tok.parse()
                    .map_err(|_| format!("ERR bad integer {tok:?}"))
            }
            Ok(Parsed::Gemm {
                m: int(m)?,
                k: int(k)?,
                n: int(n)?,
                seed: int(seed)?,
            })
        }
        ["WORKLOAD", name] => Ok(Parsed::Workload {
            name: (*name).to_string(),
        }),
        ["LINT", name] => Ok(Parsed::Lint {
            name: (*name).to_string(),
        }),
        ["QUIT"] => Ok(Parsed::Quit),
        _ => Err(USAGE.to_string()),
    }
}

/// Reject degenerate or memory-hostile requests before any work happens
/// (u128 arithmetic: a hostile request must not overflow the check).
fn check_size(m: usize, k: usize, n: usize) -> Result<()> {
    // Bound every allocation the request forces: x (m*k), w (k*n), and
    // the m*n-sized psum/quantized/accumulator outputs — a thin-K
    // request like 50000x1x50000 is output-hostile, not operand-hostile.
    let xw = (m as u128) * (k as u128);
    let ww = (k as u128) * (n as u128);
    let out = (m as u128) * (n as u128);
    let too_big = match xw.checked_add(ww).and_then(|e| e.checked_add(out)) {
        Some(elems) => elems > 64 << 20,
        None => true,
    };
    if m == 0 || k == 0 || n == 0 || too_big {
        bail!("unreasonable GEMM size {m}x{k}x{n}");
    }
    Ok(())
}

/// Execute one request's numerics on the backend: deterministic operands
/// from the seed, returning (checksum, wall_us).
fn run_numerics(
    backend: &mut impl GemmBackend,
    m: usize,
    k: usize,
    n: usize,
    seed: u64,
) -> Result<(u64, u128)> {
    check_size(m, k, n)?;
    let x = gen_mat(seed, m, k);
    let w = gen_mat(seed ^ 0xABCD_EF01, k, n);
    let p = MatI32::zeros(m, n);
    let t0 = Instant::now();
    let (q, _acc) = backend.gemm(&x, &w, &p, 0.002)?;
    let wall_us = t0.elapsed().as_micros();
    let checksum = q
        .data
        .iter()
        .fold(0u64, |h, &v| h.wrapping_mul(31).wrapping_add(v as u8 as u64));
    Ok((checksum, wall_us))
}

/// What the chip would cost for this GEMM (memoized cycle model; safe to
/// call from many threads at once).
pub(crate) fn sim_cost(
    cfg: &ChipConfig,
    cache: &SharedTileCache,
    m: usize,
    k: usize,
    n: usize,
) -> (u64, f64) {
    let layer = Layer::new(
        "req",
        LayerKind::Gemm {
            m: m as u64,
            k: k as u64,
            n: n as u64,
        },
    );
    let mut handle = cache;
    let lm = run_layer(cfg, &layer, &mut handle);
    let sim_cycles = lm.latency_cycles;
    (sim_cycles, sim_cycles as f64 / cfg.operating_point.freq_mhz)
}

/// Execute one GEMM request end to end: numerics + chip-model timing.
pub(crate) fn serve_gemm(
    backend: &mut impl GemmBackend,
    cfg: &ChipConfig,
    cache: &SharedTileCache,
    m: usize,
    k: usize,
    n: usize,
    seed: u64,
) -> Result<GemmResponse> {
    let (checksum, wall_us) = run_numerics(backend, m, k, n, seed)?;
    let (sim_cycles, sim_us) = sim_cost(cfg, cache, m, k, n);
    Ok(GemmResponse {
        checksum,
        wall_us,
        sim_cycles,
        sim_us,
    })
}

fn format_ok(r: &GemmResponse) -> String {
    format!(
        "OK checksum={} us={} sim_cycles={} sim_us={:.2}",
        r.checksum, r.wall_us, r.sim_cycles, r.sim_us
    )
}

/// Answer a WORKLOAD request from the plan cache. Every field is a pure
/// function of the memoized plan, so the response bytes are identical
/// across engines, connections and cache temperature — the differential
/// tests rely on this.
fn format_workload(cfg: &ChipConfig, name: &str, p: &WorkloadPlan) -> String {
    let latency = p.total_latency_cycles();
    format!(
        "OK workload={} latency_cycles={} compute_cycles={} dma_cycles={} dma_kb={} tiles={} sim_ms={:.3}",
        name,
        latency,
        p.total_compute_cycles(),
        p.total_dma_cycles(),
        p.total_dma_bytes() / 1024,
        p.dispatched_tiles,
        latency as f64 / (cfg.operating_point.freq_mhz * 1e3),
    )
}

/// Resolve one WORKLOAD request (shared by both engines) to its full
/// response line: plan-cache lookup, plan-once-answer-many. Warm
/// requests never materialize the layer graph or a report — the plan
/// cache is probed by the request's name before `by_name` runs, and the
/// response is formatted from the immutable plan's aggregates.
fn serve_workload(cfg: &ChipConfig, plans: &PlanCache, name: &str) -> String {
    match plans.plan_named(cfg, name, || workloads::by_name(name)) {
        Some(p) => format_workload(cfg, name, &p),
        None => format!("ERR unknown workload {name:?}"),
    }
}

/// Resolve one LINT request: plan (or reuse) the named workload, then
/// run the static verifier (`plan::verify`, DESIGN.md §13) against it.
/// The response is deterministic: a clean plan always answers
/// `OK lint workload=<name> findings=0`; a corrupt plan would enumerate
/// its findings as `rule@layer` pairs after the count.
fn serve_lint(cfg: &ChipConfig, plans: &PlanCache, name: &str) -> String {
    let Some(w) = workloads::by_name(name) else {
        return format!("ERR unknown workload {name:?}");
    };
    let plan = plans
        .plan_named(cfg, name, || Some(w.clone()))
        .expect("resolver always yields the workload");
    let findings = crate::plan::verify(cfg, &w, &plan);
    let mut resp = format!("OK lint workload={} findings={}", name, findings.len());
    for f in &findings {
        resp.push_str(&format!(" {}@{}", f.rule, f.layer));
    }
    resp
}

/// Serve one connection with the backend on the current thread.
fn handle_sequential(
    stream: TcpStream,
    backend: &mut impl GemmBackend,
    cfg: &ChipConfig,
    cache: &SharedTileCache,
    plans: &PlanCache,
) -> Result<()> {
    let mut out = stream.try_clone().context("clone stream")?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        match parse_request(&line) {
            Ok(Parsed::Gemm { m, k, n, seed }) => {
                match serve_gemm(backend, cfg, cache, m, k, n, seed) {
                    Ok(r) => writeln!(out, "{}", format_ok(&r))?,
                    Err(e) => writeln!(out, "ERR {e}")?,
                }
            }
            Ok(Parsed::Workload { name }) => {
                writeln!(out, "{}", serve_workload(cfg, plans, &name))?;
            }
            Ok(Parsed::Lint { name }) => {
                writeln!(out, "{}", serve_lint(cfg, plans, &name))?;
            }
            Ok(Parsed::Quit) => break,
            Err(resp) => writeln!(out, "{resp}")?,
        }
    }
    Ok(())
}

/// One numerics request in flight to the dedicated worker thread.
struct NumericsJob {
    m: usize,
    k: usize,
    n: usize,
    seed: u64,
    reply: mpsc::Sender<Result<(u64, u128)>>,
}

/// Serve one connection, overlapping numerics (worker thread) with the
/// shared-cache sim-cost lookup (this thread). WORKLOAD requests never
/// touch the numerics worker — they are pure plan-cache reads.
fn handle_concurrent(
    stream: TcpStream,
    cfg: &ChipConfig,
    cache: &SharedTileCache,
    plans: &PlanCache,
    jobs: &mpsc::Sender<NumericsJob>,
) -> Result<()> {
    let mut out = stream.try_clone().context("clone stream")?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        match parse_request(&line) {
            Ok(Parsed::Gemm { m, k, n, seed }) => {
                // Cheap validation here so malformed sizes never occupy
                // the (serialized) numerics worker.
                if let Err(e) = check_size(m, k, n) {
                    writeln!(out, "ERR {e}")?;
                    continue;
                }
                let (reply_tx, reply_rx) = mpsc::channel();
                jobs.send(NumericsJob {
                    m,
                    k,
                    n,
                    seed,
                    reply: reply_tx,
                })
                .map_err(|_| anyhow!("numerics worker is gone"))?;
                // Overlap: the chip-model cost resolves here while the
                // worker crunches the numerics.
                let (sim_cycles, sim_us) = sim_cost(cfg, cache, m, k, n);
                match reply_rx.recv() {
                    Ok(Ok((checksum, wall_us))) => {
                        let r = GemmResponse {
                            checksum,
                            wall_us,
                            sim_cycles,
                            sim_us,
                        };
                        writeln!(out, "{}", format_ok(&r))?;
                    }
                    Ok(Err(e)) => writeln!(out, "ERR {e}")?,
                    Err(_) => {
                        writeln!(out, "ERR numerics worker is gone")?;
                        bail!("numerics worker is gone");
                    }
                }
            }
            Ok(Parsed::Workload { name }) => {
                writeln!(out, "{}", serve_workload(cfg, plans, &name))?;
            }
            Ok(Parsed::Lint { name }) => {
                writeln!(out, "{}", serve_lint(cfg, plans, &name))?;
            }
            Ok(Parsed::Quit) => break,
            Err(resp) => writeln!(out, "{resp}")?,
        }
    }
    Ok(())
}

/// Bind the listener (so the caller learns the port before blocking).
pub fn bind(addr: &str) -> Result<TcpListener> {
    TcpListener::bind(addr).with_context(|| format!("bind {addr}"))
}

/// Single-threaded reference engine: serve connections in order on the
/// CURRENT thread. Only *successfully served* connections count toward
/// `max_conns` (`None` = forever); accept failures and handler errors
/// are logged to stderr and do not count.
pub fn serve_blocking(
    backend: &mut impl GemmBackend,
    cfg: &ChipConfig,
    listener: TcpListener,
    max_conns: Option<usize>,
    cache: &SharedTileCache,
    plans: &PlanCache,
) -> Result<ServerStats> {
    let mut stats = ServerStats::default();
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("voltra-serve: accept failed: {e}");
                continue;
            }
        };
        let peer = stream.peer_addr().ok();
        match handle_sequential(stream, backend, cfg, cache, plans) {
            Ok(()) => stats.served += 1,
            Err(e) => {
                stats.failed += 1;
                eprintln!("voltra-serve: connection {peer:?} failed: {e:#}");
            }
        }
        if let Some(max) = max_conns {
            if stats.served >= max {
                break;
            }
        }
    }
    Ok(stats)
}

/// The concurrent serving engine: one handler thread per connection, one
/// dedicated numerics worker, one shared tile cache, one plan cache.
///
/// `backend_factory` runs ON the worker thread (PJRT handles are not
/// `Send`, so the backend must be born where it lives). `max_conns`
/// counts *accepted* connections — with parallel handlers the engine
/// cannot know success before completion; per-connection failures are
/// still logged and reported in the returned [`ServerStats`].
pub fn serve_threaded<B, F>(
    backend_factory: F,
    cfg: &ChipConfig,
    listener: TcpListener,
    max_conns: Option<usize>,
    cache: &SharedTileCache,
    plans: &PlanCache,
) -> Result<ServerStats>
where
    B: GemmBackend + 'static,
    F: FnOnce() -> Result<B> + Send + 'static,
{
    let (job_tx, job_rx) = mpsc::channel::<NumericsJob>();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    let worker = std::thread::Builder::new()
        .name("voltra-numerics".to_string())
        .spawn(move || {
            let mut backend = match backend_factory() {
                Ok(b) => {
                    let _ = ready_tx.send(Ok(()));
                    b
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(job) = job_rx.recv() {
                let result = run_numerics(&mut backend, job.m, job.k, job.n, job.seed);
                let _ = job.reply.send(result);
            }
        })
        .context("spawn numerics worker")?;
    let ready = ready_rx
        .recv()
        .unwrap_or_else(|_| Err(anyhow!("numerics worker died during startup")));
    if let Err(e) = ready {
        drop(job_tx);
        let _ = worker.join();
        return Err(e);
    }

    fn tally(
        joined: std::thread::Result<Result<(), (Option<std::net::SocketAddr>, anyhow::Error)>>,
        stats: &mut ServerStats,
    ) {
        match joined {
            Ok(Ok(())) => stats.served += 1,
            Ok(Err((peer, e))) => {
                stats.failed += 1;
                eprintln!("voltra-serve: connection {peer:?} failed: {e:#}");
            }
            Err(_) => stats.failed += 1,
        }
    }

    let mut stats = ServerStats::default();
    std::thread::scope(|s| {
        let mut accepted = 0usize;
        let mut handles = Vec::new();
        for stream in listener.incoming() {
            let stream = match stream {
                Ok(st) => st,
                Err(e) => {
                    eprintln!("voltra-serve: accept failed: {e}");
                    continue;
                }
            };
            // Reap completed handlers first: a long-running server
            // (max_conns = None) must not accumulate join handles, and
            // failure logs should appear as they happen, not at shutdown.
            let mut i = 0;
            while i < handles.len() {
                if handles[i].is_finished() {
                    tally(handles.swap_remove(i).join(), &mut stats);
                } else {
                    i += 1;
                }
            }
            let jobs = job_tx.clone();
            handles.push(s.spawn(move || {
                let peer = stream.peer_addr().ok();
                handle_concurrent(stream, cfg, cache, plans, &jobs).map_err(|e| (peer, e))
            }));
            accepted += 1;
            if let Some(max) = max_conns {
                if accepted >= max {
                    break;
                }
            }
        }
        for h in handles {
            tally(h.join(), &mut stats);
        }
    });
    drop(job_tx);
    let _ = worker.join();
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostBackend;

    #[test]
    fn generated_operands_are_deterministic_and_int8() {
        let a = gen_mat(7, 16, 16);
        let b = gen_mat(7, 16, 16);
        assert_eq!(a, b);
        assert!(a.data.iter().all(|&v| (-127..=127).contains(&v)));
        let c = gen_mat(8, 16, 16);
        assert_ne!(a, c);
    }

    #[test]
    fn checksum_is_order_sensitive() {
        let h = |v: &[i32]| {
            v.iter()
                .fold(0u64, |h, &x| h.wrapping_mul(31).wrapping_add(x as u8 as u64))
        };
        assert_ne!(h(&[1, 2, 3]), h(&[3, 2, 1]));
    }

    #[test]
    fn parser_distinguishes_bad_integers_from_bad_commands() {
        assert_eq!(
            parse_request("GEMM 8 8 8 1"),
            Ok(Parsed::Gemm {
                m: 8,
                k: 8,
                n: 8,
                seed: 1
            })
        );
        assert_eq!(parse_request("QUIT"), Ok(Parsed::Quit));
        assert_eq!(
            parse_request("WORKLOAD bert"),
            Ok(Parsed::Workload {
                name: "bert".to_string()
            })
        );
        assert_eq!(
            parse_request("LINT bert"),
            Ok(Parsed::Lint {
                name: "bert".to_string()
            })
        );
        let e = parse_request("GEMM a b c 1").unwrap_err();
        assert!(e.starts_with("ERR bad integer"), "{e}");
        let e = parse_request("GEMM 8 8 8").unwrap_err();
        assert!(e.starts_with("ERR expected"), "{e}");
        let e = parse_request("NONSENSE").unwrap_err();
        assert!(e.starts_with("ERR expected"), "{e}");
        let e = parse_request("WORKLOAD").unwrap_err();
        assert!(e.starts_with("ERR expected"), "{e}");
        let e = parse_request("LINT").unwrap_err();
        assert!(e.starts_with("ERR expected"), "{e}");
        // A negative dimension is a bad integer for usize, not a usage error.
        let e = parse_request("GEMM -8 8 8 1").unwrap_err();
        assert!(e.starts_with("ERR bad integer"), "{e}");
    }

    #[test]
    fn size_check_rejects_degenerate_and_huge() {
        assert!(check_size(0, 0, 0).is_err());
        assert!(check_size(8, 8, 8).is_ok());
        // Thin-K: tiny operands, gigabyte outputs — must be rejected.
        assert!(check_size(50_000, 1, 50_000).is_err());
        // Would overflow naive usize arithmetic; must be cleanly rejected.
        assert!(check_size(usize::MAX, usize::MAX, usize::MAX).is_err());
    }

    #[test]
    fn serve_gemm_on_host_backend_is_deterministic() {
        let cfg = ChipConfig::voltra();
        let cache = SharedTileCache::new();
        let mut b = HostBackend;
        let r1 = serve_gemm(&mut b, &cfg, &cache, 64, 64, 64, 1).unwrap();
        let r2 = serve_gemm(&mut b, &cfg, &cache, 64, 64, 64, 1).unwrap();
        assert_eq!(r1.checksum, r2.checksum);
        assert_eq!(r1.sim_cycles, r2.sim_cycles);
        let r3 = serve_gemm(&mut b, &cfg, &cache, 64, 64, 64, 2).unwrap();
        assert_ne!(r1.checksum, r3.checksum);
    }

    #[test]
    fn serve_workload_answers_from_the_plan_cache() {
        let cfg = ChipConfig::voltra();
        let plans = PlanCache::new();
        let cold = serve_workload(&cfg, &plans, "lstm");
        let warm = serve_workload(&cfg, &plans, "lstm");
        // Byte-identical response, one plan compiled.
        assert_eq!(cold, warm);
        assert!(cold.starts_with("OK workload=lstm latency_cycles="), "{cold}");
        let s = plans.stats();
        assert_eq!(s.misses, 1, "second request must reuse the plan");
        assert!(s.hits >= 1);
        let e = serve_workload(&cfg, &plans, "nope");
        assert!(e.starts_with("ERR unknown workload"), "{e}");
    }

    #[test]
    fn serve_lint_reports_clean_plans_and_unknown_names() {
        let cfg = ChipConfig::voltra();
        let plans = PlanCache::new();
        let r = serve_lint(&cfg, &plans, "lstm");
        assert_eq!(r, "OK lint workload=lstm findings=0");
        // Answered from the same cache: linting after serving replans nothing.
        let before = plans.stats().misses;
        let again = serve_lint(&cfg, &plans, "lstm");
        assert_eq!(r, again);
        assert_eq!(plans.stats().misses, before);
        let e = serve_lint(&cfg, &plans, "nope");
        assert!(e.starts_with("ERR unknown workload"), "{e}");
    }
}
