//! The coordinator: runs workloads through the compile-once planning
//! layer ([`crate::plan`]) and the cycle simulator, producing the
//! paper's evaluation metrics.
//!
//! Since the planning extraction (DESIGN.md §10) this module owns three
//! things:
//!
//! * the **memoization stores** — [`TileCache`] (cheap, single-thread)
//!   and [`SharedTileCache`] (sharded `RwLock`, process-wide) behind the
//!   [`SimCache`] trait. The chip-model path is pure — `simulate_tile`
//!   depends only on `(cfg, spec)` — so any cache returns identical
//!   values; only the sharing strategy differs. (The mapping + tiling
//!   search has its own process-wide store, the
//!   [`crate::tiling::mapper::MapperCache`], shared by every path.);
//! * the **thin run API** — [`run_workload`] and friends are wrappers
//!   over `plan::build` + `plan::execute`; per-layer planning itself
//!   lives in [`crate::plan::planner`], activation chaining in
//!   [`crate::plan::residency`];
//! * the **serving engine** ([`server`]) and the suite/sweep thread
//!   pools, which amortize both tile simulation (shared tile cache) and
//!   whole-workload planning ([`crate::plan::PlanCache`]) across
//!   connections and workers.

pub(crate) mod dispatch;
pub(crate) mod engine;
pub mod server;
pub(crate) mod singleflight;
pub(crate) mod stats;
pub(crate) mod transport;

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::sync::{Rank, RwLock};

use crate::config::ChipConfig;
use crate::coordinator::singleflight::{FlightGroup, Role};
use crate::metrics::{CacheStats, LayerMetrics, TileMetrics, WorkloadMetrics};
use crate::plan::{self, PlanCache};
use crate::sim::agu::LoopDim;
use crate::sim::engine::{simulate_tile, TileSpec};
use crate::sim::snitch::{CsrProgram, StreamerId};
use crate::sim::streamer::{Grain, StreamerProgram};
use crate::workloads::{Layer, Workload};

/// Result of one workload run.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadReport {
    pub metrics: WorkloadMetrics,
    /// Tiles simulated (after memoization) vs dispatched in total. For a
    /// shared-cache run this is the cache's *global* population when the
    /// workload's plan was built (tiles may have been simulated by other
    /// runs).
    pub unique_tiles: usize,
    pub dispatched_tiles: u64,
}

/// What the planner needs from a memoization store. The tile simulation
/// is a pure function of `(cfg, spec)`, so any cache implementation
/// returns identical values — only the sharing/locking strategy
/// differs. (Mapping + tiling memoization moved to the process-wide
/// [`crate::tiling::mapper::MapperCache`].)
pub trait SimCache {
    /// Memoized tile simulation.
    fn simulate(&mut self, cfg: &ChipConfig, spec: &TileSpec) -> TileMetrics;
    /// Distinct tile specs simulated so far.
    fn unique_tiles(&self) -> usize;
}

/// Per-run tile-simulation memoization (repeated transformer blocks /
/// ResNet stages share tile shapes — §Perf). Single-threaded; for
/// cross-thread sharing use [`SharedTileCache`].
pub struct TileCache {
    map: HashMap<TileSpec, TileMetrics>,
}

impl TileCache {
    pub fn new() -> Self {
        TileCache {
            map: HashMap::new(),
        }
    }

    pub fn simulate(&mut self, cfg: &ChipConfig, spec: &TileSpec) -> TileMetrics {
        if let Some(m) = self.map.get(spec) {
            return *m;
        }
        let m = simulate_tile(cfg, spec);
        self.map.insert(*spec, m);
        m
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

impl Default for TileCache {
    fn default() -> Self {
        Self::new()
    }
}

impl SimCache for TileCache {
    fn simulate(&mut self, cfg: &ChipConfig, spec: &TileSpec) -> TileMetrics {
        TileCache::simulate(self, cfg, spec)
    }

    fn unique_tiles(&self) -> usize {
        self.len()
    }
}

/// Shard count of the shared cache: enough to keep eight sweep threads
/// plus a fleet of server connections off each other's locks.
const CACHE_SHARDS: usize = 16;

/// Process-wide, thread-safe tile memoization: the store a concurrent
/// serving engine amortizes its simulation work into (the temporal-reuse
/// argument of the paper, applied to the model itself).
///
/// Design:
/// * sharded by key hash so unrelated lookups never contend;
/// * `RwLock` per shard — the steady state is read-mostly (hits);
/// * misses simulate *outside* any lock, coalesced through a
///   [`FlightGroup`] (DESIGN.md §14): the first thread to miss a spec
///   simulates it, every concurrent requester of the same spec blocks
///   on that one simulation and shares its result — a burst of
///   identical cold requests costs one simulation, not N.
///
/// The cache is keyed by [`TileSpec`] only, so it must not be shared
/// across *different* [`ChipConfig`]s — same contract as [`TileCache`],
/// enforced by the callers that own the cache (the [`PlanCache`] scopes
/// one per config fingerprint).
pub struct SharedTileCache {
    tiles: [RwLock<HashMap<TileSpec, TileMetrics>>; CACHE_SHARDS],
    flights: FlightGroup<TileSpec, TileMetrics>,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
}

impl Default for SharedTileCache {
    fn default() -> Self {
        SharedTileCache {
            tiles: std::array::from_fn(|_| RwLock::new(Rank::TileShard, HashMap::new())),
            flights: FlightGroup::default(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
        }
    }
}

fn shard_of<K: Hash>(key: &K) -> usize {
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() as usize) % CACHE_SHARDS
}

impl SharedTileCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Memoized tile simulation, callable from any thread. Concurrent
    /// misses on the same spec coalesce onto one simulation.
    pub fn simulate(&self, cfg: &ChipConfig, spec: &TileSpec) -> TileMetrics {
        self.simulate_with(spec, |s| simulate_tile(cfg, s))
    }

    /// The single-flight engine behind [`SharedTileCache::simulate`],
    /// with the computation injectable: production passes the pure
    /// `simulate_tile`, tests inject a panicking closure to drive the
    /// abort-and-retry protocol (lock-poisoning policy, DESIGN.md §16).
    pub(crate) fn simulate_with(
        &self,
        spec: &TileSpec,
        compute: impl Fn(&TileSpec) -> TileMetrics,
    ) -> TileMetrics {
        loop {
            let shard = &self.tiles[shard_of(spec)];
            if let Some(m) = shard.read().get(spec) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return *m;
            }
            match self.flights.join(spec, || {
                self.coalesced.fetch_add(1, Ordering::Relaxed);
            }) {
                Role::Leader(lead) => {
                    // A racing leader may have published and retired its
                    // flight between our shard read and our join.
                    if let Some(m) = shard.read().get(spec) {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        lead.publish(*m);
                        return *m;
                    }
                    // Miss: simulate without holding any lock (pure).
                    // If `compute` unwinds, dropping `lead` aborts the
                    // flight: followers wake empty-handed and retry —
                    // one failed caller, never a poisoned cache.
                    let m = compute(spec);
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    shard.write().insert(*spec, m);
                    lead.publish(m);
                    return m;
                }
                Role::Waited(Some(m)) => return m,
                // The leader aborted (panic unwind in `compute`; the
                // production `simulate_tile` is total): retry.
                Role::Waited(None) => continue,
            }
        }
    }

    /// Distinct tile specs simulated so far (across all shards).
    pub fn len(&self) -> usize {
        self.tiles.iter().map(|s| s.read().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hit/miss counters since construction.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Requests that coalesced onto another thread's in-flight
    /// simulation instead of simulating (or reading a completed entry)
    /// themselves.
    pub fn coalesced_waits(&self) -> u64 {
        self.coalesced.load(Ordering::Relaxed)
    }
}

impl SimCache for &SharedTileCache {
    fn simulate(&mut self, cfg: &ChipConfig, spec: &TileSpec) -> TileMetrics {
        SharedTileCache::simulate(*self, cfg, spec)
    }

    fn unique_tiles(&self) -> usize {
        self.len()
    }
}

/// The CSR programming cost of launching one tile (Snitch writes the
/// GEMM dims + the four GEMM streamers).
pub fn tile_csr_cycles(tk: u64) -> u64 {
    let mut p = CsrProgram::default();
    p.program_gemm_dims(0, tk as u32, 0, false);
    let dims3 = vec![LoopDim { bound: 1, stride: 0 }; 3];
    let s = StreamerProgram::new(0, dims3, Grain::Fine);
    p.program_streamer(StreamerId::GemmInput, &s);
    p.program_streamer(StreamerId::GemmWeight, &s);
    p.program_streamer(StreamerId::GemmPsum, &s);
    p.program_streamer(StreamerId::GemmOutput, &s);
    p.cycles()
}

/// Run one layer's GEMMs through planning + the pipeline scheduler,
/// standalone (no workload-level residency pass).
pub fn run_layer<C: SimCache>(cfg: &ChipConfig, layer: &Layer, cache: &mut C) -> LayerMetrics {
    plan::planner::plan_layer_metrics(cfg, layer, cache).0
}

/// Like [`run_layer`], also returning the number of dispatched tiles.
pub(crate) fn run_layer_counted<C: SimCache>(
    cfg: &ChipConfig,
    layer: &Layer,
    cache: &mut C,
) -> (LayerMetrics, u64) {
    plan::planner::plan_layer_metrics(cfg, layer, cache)
}

/// Run a whole workload against a caller-supplied cache: compile the
/// [`plan::WorkloadPlan`] (per-layer planning + residency pass), then
/// execute it. The generic engine behind [`run_workload`] and
/// [`run_workload_shared`].
pub fn run_workload_with<C: SimCache>(
    cfg: &ChipConfig,
    w: &Workload,
    cache: &mut C,
) -> WorkloadReport {
    plan::execute(&plan::build(cfg, w, cache))
}

/// Run a whole workload (one bar of Fig. 6) with a fresh private cache.
pub fn run_workload(cfg: &ChipConfig, w: &Workload) -> WorkloadReport {
    let mut cache = TileCache::new();
    run_workload_with(cfg, w, &mut cache)
}

/// Run a workload against a process-wide shared cache: repeated or
/// concurrent runs reuse every tile any earlier run simulated.
pub fn run_workload_shared(
    cfg: &ChipConfig,
    w: &Workload,
    cache: &SharedTileCache,
) -> WorkloadReport {
    let mut handle = cache;
    run_workload_with(cfg, w, &mut handle)
}

/// Run many workloads across a thread pool sharing one tile cache (the
/// multi-workload sweep mode of the CLI). Results come back in input
/// order; `threads == 1` degenerates to a sequential shared-cache run.
pub fn run_suite_parallel(
    cfg: &ChipConfig,
    workloads: &[Workload],
    threads: usize,
    cache: &SharedTileCache,
) -> Vec<WorkloadReport> {
    run_suite_indexed(workloads, threads, |w| run_workload_shared(cfg, w, cache))
}

/// Run many workloads across a thread pool sharing one [`PlanCache`]:
/// each `(config, workload)` pair is planned exactly once for the life
/// of the cache — a warm sweep re-plans zero layers and only re-executes
/// the memoized plans.
pub fn run_suite_planned(
    cfg: &ChipConfig,
    workloads: &[Workload],
    threads: usize,
    plans: &PlanCache,
) -> Vec<WorkloadReport> {
    run_suite_indexed(workloads, threads, |w| plans.run(cfg, w))
}

/// Shared worker-pool skeleton of the two suite runners.
fn run_suite_indexed<F>(workloads: &[Workload], threads: usize, run: F) -> Vec<WorkloadReport>
where
    F: Fn(&Workload) -> WorkloadReport + Sync,
{
    crate::runtime::pool::scoped_indexed(workloads.len(), threads, || (), |_, i| {
        run(&workloads[i])
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;
    use crate::workloads;
    use crate::workloads::layer::{Layer, LayerKind};

    #[test]
    fn single_gemm_layer_runs() {
        let cfg = ChipConfig::voltra();
        let l = Layer::new("g", LayerKind::Gemm { m: 96, k: 96, n: 96 });
        let mut cache = TileCache::new();
        let lm = run_layer(&cfg, &l, &mut cache);
        assert_eq!(lm.macs, 96 * 96 * 96);
        assert_eq!(lm.tiles.useful_macs, lm.macs);
        assert!(lm.tiles.temporal_utilization() > 0.7);
        assert!(lm.latency_cycles > 0);
    }

    #[test]
    fn memoization_collapses_repeats() {
        let cfg = ChipConfig::voltra();
        let l = Layer::new(
            "heads",
            LayerKind::BatchedMatmul {
                batch: 12,
                m: 512,
                k: 64,
                n: 512,
            },
        );
        let mut cache = TileCache::new();
        let lm = run_layer(&cfg, &l, &mut cache);
        assert!(cache.len() <= 12, "unique tiles: {}", cache.len());
        assert_eq!(lm.macs, 12 * 512 * 64 * 512);
        assert_eq!(lm.tiles.useful_macs, lm.macs);
    }

    #[test]
    fn useful_macs_are_exact_for_every_workload() {
        // Invariant: the simulated useful MACs must equal the workload's
        // analytic MAC count — no work lost or duplicated by tiling.
        let cfg = ChipConfig::voltra();
        for w in [
            workloads::by_name("lstm").unwrap(),
            workloads::by_name("pointnext").unwrap(),
        ] {
            let r = run_workload(&cfg, &w);
            let simulated: u64 = r.metrics.layers.iter().map(|l| l.tiles.useful_macs).sum();
            assert_eq!(simulated, w.total_macs(), "{}", w.name);
        }
    }

    #[test]
    fn single_buffered_layer_fully_serializes() {
        // A GEMM too large to ping-pong in the shared space gets no
        // overlap: the schedule degenerates to compute + DMA exactly.
        let cfg = ChipConfig::voltra();
        let l = Layer::new("big", LayerKind::Gemm { m: 512, k: 768, n: 768 });
        let mut cache = TileCache::new();
        let lm = run_layer(&cfg, &l, &mut cache);
        assert_eq!(
            lm.latency_cycles,
            lm.tiles.total_cycles + lm.aux_cycles + lm.dma_cycles
        );
        assert_eq!(lm.overlap_cycles, 0);
    }

    #[test]
    fn double_buffered_layer_hides_dma_behind_compute() {
        // Twelve identical ping-pong tiles: all but the first transfer
        // overlaps a neighbour tile's compute.
        let cfg = ChipConfig::voltra();
        let l = Layer::new(
            "heads",
            LayerKind::BatchedMatmul { batch: 12, m: 64, k: 64, n: 64 },
        );
        let mut cache = TileCache::new();
        let lm = run_layer(&cfg, &l, &mut cache);
        let compute = lm.tiles.total_cycles + lm.aux_cycles;
        assert!(lm.overlap_cycles > 0, "ping-pong schedule hid nothing");
        assert!(lm.latency_cycles >= compute.max(lm.dma_cycles));
        assert!(lm.latency_cycles < compute + lm.dma_cycles);
        assert_eq!(lm.overlap_cycles, compute + lm.dma_cycles - lm.latency_cycles);
    }

    #[test]
    fn separated_memory_increases_traffic() {
        let l = Layer::new(
            "big",
            LayerKind::Gemm {
                m: 512,
                k: 768,
                n: 3072,
            },
        );
        let mut c1 = TileCache::new();
        let mut c2 = TileCache::new();
        let shared = run_layer(&ChipConfig::voltra(), &l, &mut c1);
        let sep = run_layer(&ChipConfig::separated_memory(), &l, &mut c2);
        assert!(
            sep.dma_bytes >= shared.dma_bytes,
            "separated {} vs shared {}",
            sep.dma_bytes,
            shared.dma_bytes
        );
    }

    #[test]
    fn k_round_bookkeeping_conserves_work() {
        // Force K tiling with a huge K and check MAC conservation.
        let cfg = ChipConfig::voltra();
        let l = Layer::new(
            "deep",
            LayerKind::Gemm {
                m: 256,
                k: 8192,
                n: 256,
            },
        );
        let mut cache = TileCache::new();
        let lm = run_layer(&cfg, &l, &mut cache);
        assert_eq!(lm.tiles.useful_macs, 256u64 * 8192 * 256);
    }

    #[test]
    fn conv_layer_charges_reshuffle() {
        let cfg = ChipConfig::voltra();
        let conv = Layer::new(
            "c",
            LayerKind::Conv2d {
                h: 56,
                w: 56,
                cin: 64,
                cout: 64,
                kh: 3,
                kw: 3,
                stride: 1,
            },
        );
        let fc = Layer::new("fc", LayerKind::Gemm { m: 3136, k: 576, n: 64 });
        let mut c1 = TileCache::new();
        let mut c2 = TileCache::new();
        let lc = run_layer(&cfg, &conv, &mut c1);
        let lf = run_layer(&cfg, &fc, &mut c2);
        assert!(lc.aux_cycles > lf.aux_cycles);
    }

    #[test]
    fn shared_cache_run_matches_private_cache_run() {
        let cfg = ChipConfig::voltra();
        let w = workloads::by_name("pointnext").unwrap();
        let private = run_workload(&cfg, &w);
        let shared = SharedTileCache::new();
        let a = run_workload_shared(&cfg, &w, &shared);
        let b = run_workload_shared(&cfg, &w, &shared);
        assert_eq!(private.metrics, a.metrics);
        assert_eq!(a.metrics, b.metrics);
        assert_eq!(a.dispatched_tiles, private.dispatched_tiles);
        // The second run resimulated nothing.
        assert_eq!(a.unique_tiles, b.unique_tiles);
        let s = shared.stats();
        assert!(s.hits > 0, "second run must hit the cache: {s:?}");
    }

    #[test]
    fn parallel_suite_matches_sequential_runs() {
        let cfg = ChipConfig::voltra();
        let suite = vec![
            workloads::by_name("lstm").unwrap(),
            workloads::by_name("pointnext").unwrap(),
            workloads::by_name("mobilenetv2").unwrap(),
        ];
        let cache = SharedTileCache::new();
        let par = run_suite_parallel(&cfg, &suite, 3, &cache);
        assert_eq!(par.len(), suite.len());
        for (r, w) in par.iter().zip(&suite) {
            let seq = run_workload(&cfg, w);
            assert_eq!(r.metrics, seq.metrics, "{} diverged", w.name);
            assert_eq!(r.dispatched_tiles, seq.dispatched_tiles);
        }
    }

    #[test]
    fn planned_suite_matches_sequential_runs() {
        let cfg = ChipConfig::voltra();
        let suite = vec![
            workloads::by_name("lstm").unwrap(),
            workloads::by_name("pointnext").unwrap(),
            workloads::by_name("vit").unwrap(),
        ];
        let plans = PlanCache::new();
        let par = run_suite_planned(&cfg, &suite, 3, &plans);
        assert_eq!(par.len(), suite.len());
        for (r, w) in par.iter().zip(&suite) {
            let seq = run_workload(&cfg, w);
            assert_eq!(r.metrics, seq.metrics, "{} diverged", w.name);
            assert_eq!(r.dispatched_tiles, seq.dispatched_tiles);
        }
        assert_eq!(plans.len(), suite.len());
    }

    #[test]
    fn shared_cache_is_consistent_under_contention() {
        // Many threads hammering the same small key set must all read
        // identical values and populate each key exactly once.
        let cfg = ChipConfig::voltra();
        let cache = SharedTileCache::new();
        let specs: Vec<TileSpec> = (1..=8)
            .map(|i| TileSpec::simple(8 * i, 64, 8 * i))
            .collect();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for spec in &specs {
                        let got = cache.simulate(&cfg, spec);
                        assert_eq!(got, simulate_tile(&cfg, spec));
                    }
                });
            }
        });
        assert_eq!(cache.len(), specs.len());
        // Single-flight makes the miss count exact: each spec simulated
        // once, every other lookup a hit or a coalesced wait.
        let s = cache.stats();
        assert_eq!(s.misses, specs.len() as u64);
        assert_eq!(
            s.hits + s.misses + cache.coalesced_waits(),
            (8 * specs.len()) as u64
        );
    }

    #[test]
    fn panicking_leader_aborts_and_herd_retries() {
        // The lock-poisoning policy (DESIGN.md §16) on the tile tier: a
        // leader that panics mid-compute must abort its flight so every
        // follower retries — one failed caller, no poison cascade, no
        // deadlocked herd.
        use std::sync::atomic::AtomicBool;
        let cfg = ChipConfig::voltra();
        let cache = SharedTileCache::new();
        let spec = TileSpec::simple(32, 64, 32);
        let panicked = AtomicBool::new(false);
        let aborts_before = crate::sync::flight_aborts();
        let mut failed = 0usize;
        std::thread::scope(|s| {
            let joins: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| {
                        cache.simulate_with(&spec, |sp| {
                            if !panicked.swap(true, Ordering::SeqCst) {
                                panic!("injected leader failure");
                            }
                            simulate_tile(&cfg, sp)
                        })
                    })
                })
                .collect();
            for j in joins {
                match j.join() {
                    Ok(m) => assert_eq!(m, simulate_tile(&cfg, &spec)),
                    Err(_) => failed += 1,
                }
            }
        });
        assert_eq!(failed, 1, "exactly the injected panic fails its caller");
        assert_eq!(cache.len(), 1, "survivors still populate the entry once");
        assert!(
            crate::sync::flight_aborts() > aborts_before,
            "the aborted leadership must be counted"
        );
    }
}
