//! The coordinator: runs a workload through tiling, CSR programming and
//! the cycle simulator, producing the paper's evaluation metrics.
//!
//! Per layer:
//!   1. lower to GEMMs (implicit im2col for convs);
//!   2. choose the layer-wise tiling that fits the memory organisation
//!      (PDMA shared vs separated buffers) with minimum off-chip traffic;
//!   3. enumerate the distinct tile shapes (interior/edge x first/mid/
//!      last K-round), cycle-simulate each once and scale by its count —
//!      tiles are memoized, so a ResNet-50 run simulates ~10^2 tiles,
//!      not ~10^5;
//!   4. charge auxiliary cycles (Snitch CSR programming per tile,
//!      reshuffler passes for raw-layout feature maps);
//!   5. emit the dispatched tile sequence as a per-GEMM [`sim::pipeline`]
//!      plan and resolve the layer's latency with the event-driven
//!      pipeline scheduler — DMA overlaps compute tile by tile exactly
//!      where the allocator granted ping-pong regions for *that* GEMM
//!      (a fused layer may mix grants across its GEMMs).
//!
//! Concurrency (DESIGN.md §Concurrency): the chip-model path is pure —
//! `choose_tiling` and `simulate_tile` depend only on `(cfg, key)` — so
//! memoization can be shared process-wide. [`TileCache`] is the cheap
//! single-thread cache (one run, no locking); [`SharedTileCache`] is the
//! sharded `RwLock` cache every server connection and sweep worker hits
//! concurrently. Both sit behind the [`SimCache`] trait so the layer
//! runner is written once.

pub mod server;

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, RwLock};

use crate::config::ChipConfig;
use crate::metrics::{CacheStats, LayerMetrics, TileMetrics, WorkloadMetrics};
use crate::sim::agu::LoopDim;
use crate::sim::dma::transfer_cost;
use crate::sim::engine::{simulate_tile, TileSpec};
use crate::sim::gemm_core::Mapping;
use crate::sim::pipeline::{self, LayerPlan, TilePlan, TileRun};
use crate::sim::reshuffler::reshuffle_cycles;
use crate::sim::snitch::{CsrProgram, StreamerId};
use crate::sim::streamer::{Grain, StreamerProgram};
use crate::tiling::engine::{choose_tiling, traffic_parts, Tiling};
use crate::workloads::{Layer, LayerKind, Workload};

/// Result of one workload run.
#[derive(Clone, Debug)]
pub struct WorkloadReport {
    pub metrics: WorkloadMetrics,
    /// Tiles simulated (after memoization) vs dispatched in total. For a
    /// shared-cache run this is the cache's *global* population when the
    /// workload finished (tiles may have been simulated by other runs).
    pub unique_tiles: usize,
    pub dispatched_tiles: u64,
}

/// What the layer runner needs from a memoization store. The tiling
/// search and the tile simulation are pure functions of `(cfg, key)`,
/// so any cache implementation returns identical values — only the
/// sharing/locking strategy differs.
pub trait SimCache {
    /// Memoized tiling search (the config is fixed per cache lifetime).
    fn tiling(&mut self, cfg: &ChipConfig, m: u64, k: u64, n: u64) -> Option<Tiling>;
    /// Memoized tile simulation.
    fn simulate(&mut self, cfg: &ChipConfig, spec: &TileSpec) -> TileMetrics;
    /// Distinct tile specs simulated so far.
    fn unique_tiles(&self) -> usize;
}

/// Per-run memoization: simulated tiles AND tiling decisions (repeated
/// transformer blocks / ResNet stages share layer shapes — §Perf).
/// Single-threaded; for cross-thread sharing use [`SharedTileCache`].
pub struct TileCache {
    map: HashMap<TileSpec, TileMetrics>,
    tilings: HashMap<(u64, u64, u64), Option<Tiling>>,
}

impl TileCache {
    pub fn new() -> Self {
        TileCache {
            map: HashMap::new(),
            tilings: HashMap::new(),
        }
    }

    /// Memoized tiling search (the config is fixed per cache lifetime).
    pub fn tiling(&mut self, cfg: &ChipConfig, m: u64, k: u64, n: u64) -> Option<Tiling> {
        *self
            .tilings
            .entry((m, k, n))
            .or_insert_with(|| choose_tiling(cfg, m, k, n))
    }

    pub fn simulate(&mut self, cfg: &ChipConfig, spec: &TileSpec) -> TileMetrics {
        if let Some(m) = self.map.get(spec) {
            return *m;
        }
        let m = simulate_tile(cfg, spec);
        self.map.insert(*spec, m);
        m
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

impl Default for TileCache {
    fn default() -> Self {
        Self::new()
    }
}

impl SimCache for TileCache {
    fn tiling(&mut self, cfg: &ChipConfig, m: u64, k: u64, n: u64) -> Option<Tiling> {
        TileCache::tiling(self, cfg, m, k, n)
    }

    fn simulate(&mut self, cfg: &ChipConfig, spec: &TileSpec) -> TileMetrics {
        TileCache::simulate(self, cfg, spec)
    }

    fn unique_tiles(&self) -> usize {
        self.len()
    }
}

/// Shard count of the shared cache: enough to keep eight sweep threads
/// plus a fleet of server connections off each other's locks.
const CACHE_SHARDS: usize = 16;

/// Process-wide, thread-safe tile memoization: the store a concurrent
/// serving engine amortizes its simulation work into (the temporal-reuse
/// argument of the paper, applied to the model itself).
///
/// Design:
/// * sharded by key hash so unrelated lookups never contend;
/// * `RwLock` per shard — the steady state is read-mostly (hits);
/// * misses simulate *outside* any lock: the simulation is pure, so two
///   racing threads at worst duplicate work and insert identical values
///   (last write wins, both results are equal by construction).
///
/// The cache is keyed by [`TileSpec`] / GEMM dims only, so it must not
/// be shared across *different* [`ChipConfig`]s — same contract as
/// [`TileCache`], enforced by the callers that own the cache.
#[derive(Default)]
pub struct SharedTileCache {
    tiles: [RwLock<HashMap<TileSpec, TileMetrics>>; CACHE_SHARDS],
    tilings: [RwLock<HashMap<(u64, u64, u64), Option<Tiling>>>; CACHE_SHARDS],
    hits: AtomicU64,
    misses: AtomicU64,
}

fn shard_of<K: Hash>(key: &K) -> usize {
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() as usize) % CACHE_SHARDS
}

impl SharedTileCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Memoized tile simulation, callable from any thread.
    pub fn simulate(&self, cfg: &ChipConfig, spec: &TileSpec) -> TileMetrics {
        let shard = &self.tiles[shard_of(spec)];
        if let Some(m) = shard.read().expect("tile shard poisoned").get(spec) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *m;
        }
        // Miss: simulate without holding the lock (pure + idempotent).
        let m = simulate_tile(cfg, spec);
        self.misses.fetch_add(1, Ordering::Relaxed);
        shard.write().expect("tile shard poisoned").insert(*spec, m);
        m
    }

    /// Memoized tiling search, callable from any thread.
    pub fn tiling(&self, cfg: &ChipConfig, m: u64, k: u64, n: u64) -> Option<Tiling> {
        let key = (m, k, n);
        let shard = &self.tilings[shard_of(&key)];
        if let Some(t) = shard.read().expect("tiling shard poisoned").get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *t;
        }
        let t = choose_tiling(cfg, m, k, n);
        self.misses.fetch_add(1, Ordering::Relaxed);
        shard.write().expect("tiling shard poisoned").insert(key, t);
        t
    }

    /// Distinct tile specs simulated so far (across all shards).
    pub fn len(&self) -> usize {
        self.tiles
            .iter()
            .map(|s| s.read().expect("tile shard poisoned").len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hit/miss counters since construction (tilings + tiles combined).
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

impl SimCache for &SharedTileCache {
    fn tiling(&mut self, cfg: &ChipConfig, m: u64, k: u64, n: u64) -> Option<Tiling> {
        SharedTileCache::tiling(*self, cfg, m, k, n)
    }

    fn simulate(&mut self, cfg: &ChipConfig, spec: &TileSpec) -> TileMetrics {
        SharedTileCache::simulate(*self, cfg, spec)
    }

    fn unique_tiles(&self) -> usize {
        self.len()
    }
}

/// The CSR programming cost of launching one tile (Snitch writes the
/// GEMM dims + the four GEMM streamers).
pub fn tile_csr_cycles(tk: u64) -> u64 {
    let mut p = CsrProgram::default();
    p.program_gemm_dims(0, tk as u32, 0, false);
    let dims3 = vec![LoopDim { bound: 1, stride: 0 }; 3];
    let s = StreamerProgram::new(0, dims3, Grain::Fine);
    p.program_streamer(StreamerId::GemmInput, &s);
    p.program_streamer(StreamerId::GemmWeight, &s);
    p.program_streamer(StreamerId::GemmPsum, &s);
    p.program_streamer(StreamerId::GemmOutput, &s);
    p.cycles()
}

/// Bytes of feature map a conv layer must reshuffle (HWC -> C/8HWC8).
fn reshuffle_bytes(layer: &Layer) -> u64 {
    match layer.kind {
        LayerKind::Conv2d {
            h, w, cin, kh, kw, ..
        } if kh * kw > 1 => h * w * cin.div_ceil(8) * 8,
        _ => 0,
    }
}

/// Dimension residues of round `i` over tiles of `t` covering `d`.
fn edge(d: u64, t: u64) -> (u64, u64, u64) {
    // (interior_count, edge_count, edge_size)
    let full = d / t;
    let rem = d % t;
    if rem == 0 {
        (full, 0, 0)
    } else {
        (full, 1, rem)
    }
}

/// Split one GEMM's DMA cycles across its tile runs proportional to the
/// raw bytes each tile variant moves (operands in, psums in/out, results
/// out) — integer-exact via [`pipeline::DmaSplitter`]: the run totals
/// sum to `total_dma`, so the scheduler's DMA busy time equals the
/// layer's accounted DMA cycles. `raw` entries are
/// `(count, compute_cycles_per_tile, bytes_per_tile)`.
fn attribute_dma(raw: &[(u64, u64, u64)], total_dma: u64) -> Vec<TileRun> {
    let mut total_weight: u128 = raw.iter().map(|&(c, _, b)| c as u128 * b as u128).sum();
    // Degenerate zero-byte variants (tiling never emits them): fall back
    // to uniform attribution so no DMA time is dropped.
    let uniform = total_weight == 0;
    if uniform {
        total_weight = raw.iter().map(|&(c, _, _)| c as u128).sum();
    }
    let mut runs = Vec::with_capacity(raw.len() + 1);
    let mut split = pipeline::DmaSplitter::new(total_weight, total_dma);
    for &(count, compute, bytes) in raw {
        split.push(&mut runs, count, compute, if uniform { 1 } else { bytes });
    }
    runs
}

/// Run one layer's GEMMs through tiling + simulation.
pub fn run_layer<C: SimCache>(cfg: &ChipConfig, layer: &Layer, cache: &mut C) -> LayerMetrics {
    run_layer_counted(cfg, layer, cache).0
}

/// Like [`run_layer`], also returning the number of dispatched tiles.
pub fn run_layer_counted<C: SimCache>(
    cfg: &ChipConfig,
    layer: &Layer,
    cache: &mut C,
) -> (LayerMetrics, u64) {
    let (lm, dispatched, _) = run_layer_planned(cfg, layer, cache);
    (lm, dispatched)
}

/// Full layer run: metrics, dispatch count, and the tile plan the
/// pipeline scheduler consumed. The workload runner keeps the plan so
/// activation chaining can trim the DMA attribution and *re-schedule*
/// instead of re-applying an analytic overlap formula.
pub fn run_layer_planned<C: SimCache>(
    cfg: &ChipConfig,
    layer: &Layer,
    cache: &mut C,
) -> (LayerMetrics, u64, LayerPlan) {
    let mut lm = LayerMetrics {
        name: layer.name.clone(),
        ..Default::default()
    };
    let mut plan = LayerPlan::default();
    let mut total_dispatched = 0u64;

    for mut g in layer.gemms() {
        // The hardware loop controller may map (M, N) either way onto the
        // array; pick the better-filling orientation (free transpose).
        if Mapping::choose(cfg.array, g.m, g.n).swapped {
            std::mem::swap(&mut g.m, &mut g.n);
        }
        let tiling = match cache.tiling(cfg, g.m, g.k, g.n) {
            Some(t) => t,
            None => continue, // cannot fit: skipped (never happens: 8x8x8 always fits)
        };
        let (nm, nk, nn) = tiling.rounds(g.m, g.k, g.n);
        let (m_int, m_edge, m_rem) = edge(g.m, tiling.tm);
        let (k_int, k_edge, k_rem) = edge(g.k, tiling.tk);
        let (n_int, n_edge, n_rem) = edge(g.n, tiling.tn);

        let m_variants = [(tiling.tm, m_int), (m_rem, m_edge)];
        let n_variants = [(tiling.tn, n_int), (n_rem, n_edge)];
        // K-round variants: (size, count, psum_in, spill_out).
        let mut k_variants: Vec<(u64, u64, bool, bool)> = Vec::new();
        {
            let k_sizes = [(tiling.tk, k_int), (k_rem, k_edge)];
            let last_is_edge = k_edge == 1;
            for (i, &(sz, cnt)) in k_sizes.iter().enumerate() {
                if cnt == 0 {
                    continue;
                }
                let is_edge_slot = i == 1;
                if nk == 1 {
                    k_variants.push((sz, cnt, false, false));
                } else if is_edge_slot {
                    // The edge K-round is always the last.
                    k_variants.push((sz, cnt, true, false));
                } else {
                    // Interior rounds: the first has no psum-in; the last
                    // interior one quantizes only if there is no edge.
                    let mut first = 1u64.min(cnt);
                    let mut last = if last_is_edge {
                        0
                    } else {
                        1u64.min(cnt.saturating_sub(first))
                    };
                    if cnt == 1 && !last_is_edge {
                        // Single interior round that is both first & last.
                        first = 1;
                        last = 0;
                        k_variants.push((sz, 1, false, false));
                        continue;
                    }
                    if first > 0 {
                        k_variants.push((sz, first, false, true));
                    }
                    let mid = cnt - first - last;
                    if mid > 0 {
                        k_variants.push((sz, mid, true, true));
                    }
                    if last > 0 {
                        k_variants.push((sz, last, true, false));
                    }
                }
            }
        }

        let pl = tiling.placement;
        // Control overhead: one CSR program per dispatched tile (part of
        // the tile engine's per-tile busy time in the schedule).
        let csr_cycles = tile_csr_cycles(tiling.tk);
        let mut dispatched = 0u64;
        // (count, per-tile compute cycles, per-tile raw bytes) per
        // variant, in dispatch order — the scheduler's tile runs.
        let mut raw_runs: Vec<(u64, u64, u64)> = Vec::new();
        for &(tm, mc) in &m_variants {
            if mc == 0 {
                continue;
            }
            for &(tn, nc) in &n_variants {
                if nc == 0 {
                    continue;
                }
                for &(tk, kc, psum_in, spill_out) in &k_variants {
                    if kc == 0 {
                        continue;
                    }
                    let spec = TileSpec {
                        tm,
                        tk,
                        tn,
                        psum_in,
                        spill_out,
                        input_blocked: !g.raw_input,
                        in_base: pl.input_base,
                        w_base: pl.weight_base,
                        p_base: pl.psum_base,
                        o_base: pl.output_base,
                    };
                    let tmetrics = cache.simulate(cfg, &spec);
                    let count = mc * nc * kc * g.repeat;
                    lm.tiles.add_scaled(&tmetrics, count);
                    dispatched += count;
                    // Raw byte weight of this variant for DMA
                    // attribution: operand tiles in, int32 psums
                    // round-tripped, results out.
                    let psum_bytes = if psum_in { 4 * tm * tn } else { 0 };
                    let out_bytes = if spill_out { 4 * tm * tn } else { tm * tn };
                    let tile_bytes = tm * tk + tk * tn + psum_bytes + out_bytes;
                    raw_runs.push((count, tmetrics.total_cycles + csr_cycles, tile_bytes));
                }
            }
        }

        total_dispatched += dispatched;
        lm.aux_cycles += dispatched * csr_cycles;
        // PDMA weight residency: if the whole weight operand fits in the
        // memory the organisation can give it, recurrent repeats stream
        // the weights once instead of every step. The separated baseline
        // is capped by its fixed weight buffer.
        let parts = traffic_parts(g.m, g.k, g.n, tiling.tm, tiling.tk, tiling.tn);
        let weight_budget = match cfg.memory {
            crate::config::MemoryOrg::Shared => 3 * cfg.memory.total_bytes() as u64 / 4,
            crate::config::MemoryOrg::Separated { weight, .. } => weight as u64,
        };
        let w_groups = g.repeat / g.weight_reuse.max(1);
        let gemm_traffic = if g.weight_reuse > 1 && g.k * g.n <= weight_budget {
            (parts.input + parts.psum + parts.output) * g.repeat + parts.weight * w_groups
        } else {
            parts.total() * g.repeat
        };
        lm.dma_bytes += gemm_traffic;
        lm.tile_footprint_bytes = lm.tile_footprint_bytes.max(tiling.footprint.total() as u64);
        lm.macs += g.macs();
        let _ = (nm, nn);

        // DMA timing: bandwidth-limited, plus per-tile burst setup — a
        // config that tiles finer (separated buffers) pays more burst
        // overhead for the same bytes. The total is attributed across
        // this GEMM's tile runs so the scheduler can interleave it with
        // compute at tile granularity.
        let t = transfer_cost(cfg, gemm_traffic);
        let gemm_dma_cycles = t.cycles + dispatched * cfg.dma_burst_latency;
        lm.dma_cycles += gemm_dma_cycles;
        plan.gemms.push(TilePlan {
            runs: attribute_dma(&raw_runs, gemm_dma_cycles),
            // Ping-pong regions exist only when the allocator granted
            // double-buffer space for THIS GEMM — per-GEMM, never
            // inherited from whichever GEMM the layer lowered last.
            double_buffered: tiling.double_buffered && cfg.double_buffer,
        });
    }

    // Reshuffler pass for raw conv feature maps (serial, before the
    // tile timeline can stream the blocked layout).
    let rb = reshuffle_bytes(layer);
    if rb > 0 {
        plan.reshuffle_cycles = reshuffle_cycles(rb) * layer.repeat;
        lm.aux_cycles += plan.reshuffle_cycles;
    }

    let s = pipeline::schedule_layer(&plan);
    lm.latency_cycles = s.latency_cycles;
    lm.overlap_cycles = s.hidden_cycles();

    (lm, total_dispatched, plan)
}

/// Activation bytes a layer produces (what the next layer consumes).
fn activation_out_bytes(layer: &Layer) -> u64 {
    layer
        .gemms()
        .iter()
        .map(|g| g.m * g.n * g.repeat / layer.repeat.max(1))
        .sum()
}

/// Activation bytes a layer consumes from its predecessor.
fn activation_in_bytes(layer: &Layer) -> u64 {
    match layer.kind {
        LayerKind::Conv2d { h, w, cin, .. } => h * w * cin,
        LayerKind::DepthwiseConv { h, w, c, .. } => h * w * c,
        LayerKind::Gemm { m, k, .. } => m * k,
        LayerKind::BatchedMatmul { batch, m, k, .. } => batch * m * k,
        LayerKind::Fused(ref gemms) => gemms.iter().map(|&(m, k, _)| m * k).sum(),
        LayerKind::Pool { h, w, c, .. } => h * w * c,
    }
}

/// Run a whole workload against a caller-supplied cache (the generic
/// engine behind [`run_workload`] and [`run_workload_shared`]).
///
/// PDMA's layer-chaining benefit (Fig. 4): with the shared organisation,
/// a layer's output region simply *becomes* the next layer's input
/// region (a streamer base-pointer update) whenever it fits on chip next
/// to the live tiles — the separated organisation must round-trip the
/// activation through off-chip memory because the output buffer is not
/// the input buffer.
pub fn run_workload_with<C: SimCache>(
    cfg: &ChipConfig,
    w: &Workload,
    cache: &mut C,
) -> WorkloadReport {
    let mut metrics = WorkloadMetrics {
        name: w.name.clone(),
        layers: Vec::with_capacity(w.layers.len()),
    };
    let shared = matches!(cfg.memory, crate::config::MemoryOrg::Shared);
    // Half the shared space can host a chained activation while the
    // other half holds the working tiles.
    let chain_budget = (cfg.memory.total_bytes() / 2) as u64;
    let mut dispatched = 0u64;
    let mut prev_out: u64 = 0;
    for layer in &w.layers {
        let (mut lm, d, mut plan) = run_layer_planned(cfg, layer, cache);
        dispatched += d;
        if shared {
            let a_in = activation_in_bytes(layer);
            let chained = prev_out.min(a_in);
            if chained > 0 && chained <= chain_budget {
                // Saved: the predecessor's output write + our input read,
                // once per layer invocation (not per repeat: recurrent
                // steps re-chain every iteration).
                let saved = 2 * chained * layer.repeat;
                let saved = saved.min(lm.dma_bytes / 2);
                lm.dma_bytes -= saved;
                let saved_cycles = saved.div_ceil(cfg.dma_bytes_per_cycle.max(1));
                let new_dma = lm.dma_cycles.saturating_sub(saved_cycles);
                // Trim the plan's per-tile DMA attribution to the new
                // total and re-resolve the timeline — chaining shortens
                // the transfers, it does not change the overlap rules
                // (each GEMM keeps its own ping-pong grant).
                pipeline::scale_dma(&mut plan.gemms, new_dma);
                lm.dma_cycles = new_dma;
                let s = pipeline::schedule_layer(&plan);
                lm.latency_cycles = s.latency_cycles;
                lm.overlap_cycles = s.hidden_cycles();
            }
            prev_out = activation_out_bytes(layer);
            if prev_out > chain_budget {
                prev_out = 0; // too big to keep resident
            }
        }
        metrics.layers.push(lm);
    }
    WorkloadReport {
        metrics,
        unique_tiles: cache.unique_tiles(),
        dispatched_tiles: dispatched,
    }
}

/// Run a whole workload (one bar of Fig. 6) with a fresh private cache.
pub fn run_workload(cfg: &ChipConfig, w: &Workload) -> WorkloadReport {
    let mut cache = TileCache::new();
    run_workload_with(cfg, w, &mut cache)
}

/// Run a workload against a process-wide shared cache: repeated or
/// concurrent runs reuse every tile any earlier run simulated.
pub fn run_workload_shared(
    cfg: &ChipConfig,
    w: &Workload,
    cache: &SharedTileCache,
) -> WorkloadReport {
    let mut handle = cache;
    run_workload_with(cfg, w, &mut handle)
}

/// Run many workloads across a thread pool sharing one cache (the
/// multi-workload sweep mode of the CLI). Results come back in input
/// order; `threads == 1` degenerates to a sequential shared-cache run.
pub fn run_suite_parallel(
    cfg: &ChipConfig,
    workloads: &[Workload],
    threads: usize,
    cache: &SharedTileCache,
) -> Vec<WorkloadReport> {
    let n = workloads.len();
    let workers = threads.clamp(1, n.max(1));
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<WorkloadReport>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = run_workload_shared(cfg, &workloads[i], cache);
                *slots[i].lock().expect("sweep slot poisoned") = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("sweep slot poisoned")
                .expect("sweep worker skipped a workload")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;
    use crate::workloads;
    use crate::workloads::layer::{Layer, LayerKind};

    #[test]
    fn single_gemm_layer_runs() {
        let cfg = ChipConfig::voltra();
        let l = Layer::new("g", LayerKind::Gemm { m: 96, k: 96, n: 96 });
        let mut cache = TileCache::new();
        let lm = run_layer(&cfg, &l, &mut cache);
        assert_eq!(lm.macs, 96 * 96 * 96);
        assert_eq!(lm.tiles.useful_macs, lm.macs);
        assert!(lm.tiles.temporal_utilization() > 0.7);
        assert!(lm.latency_cycles > 0);
    }

    #[test]
    fn memoization_collapses_repeats() {
        let cfg = ChipConfig::voltra();
        let l = Layer::new(
            "heads",
            LayerKind::BatchedMatmul {
                batch: 12,
                m: 512,
                k: 64,
                n: 512,
            },
        );
        let mut cache = TileCache::new();
        let lm = run_layer(&cfg, &l, &mut cache);
        assert!(cache.len() <= 12, "unique tiles: {}", cache.len());
        assert_eq!(lm.macs, 12 * 512 * 64 * 512);
        assert_eq!(lm.tiles.useful_macs, lm.macs);
    }

    #[test]
    fn useful_macs_are_exact_for_every_workload() {
        // Invariant: the simulated useful MACs must equal the workload's
        // analytic MAC count — no work lost or duplicated by tiling.
        let cfg = ChipConfig::voltra();
        for w in [
            workloads::by_name("lstm").unwrap(),
            workloads::by_name("pointnext").unwrap(),
        ] {
            let r = run_workload(&cfg, &w);
            let simulated: u64 = r.metrics.layers.iter().map(|l| l.tiles.useful_macs).sum();
            assert_eq!(simulated, w.total_macs(), "{}", w.name);
        }
    }

    #[test]
    fn single_buffered_layer_fully_serializes() {
        // A GEMM too large to ping-pong in the shared space gets no
        // overlap: the schedule degenerates to compute + DMA exactly.
        let cfg = ChipConfig::voltra();
        let l = Layer::new("big", LayerKind::Gemm { m: 512, k: 768, n: 768 });
        let mut cache = TileCache::new();
        let lm = run_layer(&cfg, &l, &mut cache);
        assert_eq!(
            lm.latency_cycles,
            lm.tiles.total_cycles + lm.aux_cycles + lm.dma_cycles
        );
        assert_eq!(lm.overlap_cycles, 0);
    }

    #[test]
    fn double_buffered_layer_hides_dma_behind_compute() {
        // Twelve identical ping-pong tiles: all but the first transfer
        // overlaps a neighbour tile's compute.
        let cfg = ChipConfig::voltra();
        let l = Layer::new(
            "heads",
            LayerKind::BatchedMatmul { batch: 12, m: 64, k: 64, n: 64 },
        );
        let mut cache = TileCache::new();
        let lm = run_layer(&cfg, &l, &mut cache);
        let compute = lm.tiles.total_cycles + lm.aux_cycles;
        assert!(lm.overlap_cycles > 0, "ping-pong schedule hid nothing");
        assert!(lm.latency_cycles >= compute.max(lm.dma_cycles));
        assert!(lm.latency_cycles < compute + lm.dma_cycles);
        assert_eq!(lm.overlap_cycles, compute + lm.dma_cycles - lm.latency_cycles);
    }

    #[test]
    fn separated_memory_increases_traffic() {
        let l = Layer::new(
            "big",
            LayerKind::Gemm {
                m: 512,
                k: 768,
                n: 3072,
            },
        );
        let mut c1 = TileCache::new();
        let mut c2 = TileCache::new();
        let shared = run_layer(&ChipConfig::voltra(), &l, &mut c1);
        let sep = run_layer(&ChipConfig::separated_memory(), &l, &mut c2);
        assert!(
            sep.dma_bytes >= shared.dma_bytes,
            "separated {} vs shared {}",
            sep.dma_bytes,
            shared.dma_bytes
        );
    }

    #[test]
    fn k_round_bookkeeping_conserves_work() {
        // Force K tiling with a huge K and check MAC conservation.
        let cfg = ChipConfig::voltra();
        let l = Layer::new(
            "deep",
            LayerKind::Gemm {
                m: 256,
                k: 8192,
                n: 256,
            },
        );
        let mut cache = TileCache::new();
        let lm = run_layer(&cfg, &l, &mut cache);
        assert_eq!(lm.tiles.useful_macs, 256u64 * 8192 * 256);
    }

    #[test]
    fn conv_layer_charges_reshuffle() {
        let cfg = ChipConfig::voltra();
        let conv = Layer::new(
            "c",
            LayerKind::Conv2d {
                h: 56,
                w: 56,
                cin: 64,
                cout: 64,
                kh: 3,
                kw: 3,
                stride: 1,
            },
        );
        let fc = Layer::new("fc", LayerKind::Gemm { m: 3136, k: 576, n: 64 });
        let mut c1 = TileCache::new();
        let mut c2 = TileCache::new();
        let lc = run_layer(&cfg, &conv, &mut c1);
        let lf = run_layer(&cfg, &fc, &mut c2);
        assert!(lc.aux_cycles > lf.aux_cycles);
    }

    #[test]
    fn shared_cache_run_matches_private_cache_run() {
        let cfg = ChipConfig::voltra();
        let w = workloads::by_name("pointnext").unwrap();
        let private = run_workload(&cfg, &w);
        let shared = SharedTileCache::new();
        let a = run_workload_shared(&cfg, &w, &shared);
        let b = run_workload_shared(&cfg, &w, &shared);
        assert_eq!(private.metrics, a.metrics);
        assert_eq!(a.metrics, b.metrics);
        assert_eq!(a.dispatched_tiles, private.dispatched_tiles);
        // The second run resimulated nothing.
        assert_eq!(a.unique_tiles, b.unique_tiles);
        let s = shared.stats();
        assert!(s.hits > 0, "second run must hit the cache: {s:?}");
    }

    #[test]
    fn parallel_suite_matches_sequential_runs() {
        let cfg = ChipConfig::voltra();
        let suite = vec![
            workloads::by_name("lstm").unwrap(),
            workloads::by_name("pointnext").unwrap(),
            workloads::by_name("mobilenetv2").unwrap(),
        ];
        let cache = SharedTileCache::new();
        let par = run_suite_parallel(&cfg, &suite, 3, &cache);
        assert_eq!(par.len(), suite.len());
        for (r, w) in par.iter().zip(&suite) {
            let seq = run_workload(&cfg, w);
            assert_eq!(r.metrics, seq.metrics, "{} diverged", w.name);
            assert_eq!(r.dispatched_tiles, seq.dispatched_tiles);
        }
    }

    #[test]
    fn shared_cache_is_consistent_under_contention() {
        // Many threads hammering the same small key set must all read
        // identical values and populate each key exactly once.
        let cfg = ChipConfig::voltra();
        let cache = SharedTileCache::new();
        let specs: Vec<TileSpec> = (1..=8)
            .map(|i| TileSpec::simple(8 * i, 64, 8 * i))
            .collect();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for spec in &specs {
                        let got = cache.simulate(&cfg, spec);
                        assert_eq!(got, simulate_tile(&cfg, spec));
                    }
                });
            }
        });
        assert_eq!(cache.len(), specs.len());
    }
}
