//! The dispatch layer of the serving stack (DESIGN.md §14): a bounded
//! worker pool with admission control between the transport (one thread
//! per connection, all parsing) and the engine (the actual work).
//!
//! Connections do not execute requests; they [`Dispatcher::submit`]
//! parsed requests into a bounded queue that `workers` pool threads
//! drain through [`Engine::handle`]. The bound is the admission
//! decision: a submit against a full queue fails *immediately* —
//! `None`, which the server answers as `ERR busy` — instead of growing
//! an unbounded buffer until memory or latency collapses. Clients get
//! an honest overload signal they can back off from, and the p99 of
//! accepted requests stays bounded by queue_depth x service time.
//!
//! Each pool worker owns a [`WorkerLane`] clone, so every in-flight
//! GEMM still overlaps its chip-model sim cost with the (single,
//! serialized) numerics backend exactly as before the split.

use std::sync::mpsc;
use std::sync::Arc;

use crate::coordinator::engine::{Engine, NumericsJob, Parsed, WorkerLane};
use crate::sync::{Mutex, Rank};

/// One admitted request: what to do and where the connection waits.
struct Job {
    req: Parsed,
    reply: mpsc::Sender<String>,
}

/// A handle for submitting requests to the worker pool. Cloned into
/// every connection handler; the pool drains when the last clone drops.
#[derive(Clone)]
pub(crate) struct Dispatcher {
    tx: mpsc::SyncSender<Job>,
}

impl Dispatcher {
    /// Admit one request, returning where its response will arrive —
    /// or `None` when the queue is full (the `ERR busy` path). Never
    /// blocks: admission is the one place the server says no.
    pub(crate) fn submit(&self, req: Parsed) -> Option<mpsc::Receiver<String>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let job = Job {
            req,
            reply: reply_tx,
        };
        match self.tx.try_send(job) {
            Ok(()) => Some(reply_rx),
            Err(_) => None,
        }
    }
}

/// Start `workers` pool threads on the caller's scope, draining a queue
/// of at most `queue_depth` waiting requests. Workers exit when every
/// [`Dispatcher`] clone has dropped and the queue is empty; the scope
/// joins them.
pub(crate) fn start<'scope, 'env>(
    s: &'scope std::thread::Scope<'scope, 'env>,
    engine: Engine<'env>,
    numerics: mpsc::SyncSender<NumericsJob>,
    workers: usize,
    queue_depth: usize,
) -> Dispatcher {
    let (tx, rx) = mpsc::sync_channel::<Job>(queue_depth);
    let rx = Arc::new(Mutex::new(Rank::DispatchQueue, rx));
    for _ in 0..workers.max(1) {
        let rx = Arc::clone(&rx);
        let mut lane = WorkerLane {
            jobs: numerics.clone(),
        };
        s.spawn(move || loop {
            // The guard drops as soon as a job is claimed: workers
            // serialize on *pickup* only, never on execution.
            let claimed = rx.lock().recv();
            let job = match claimed {
                Ok(j) => j,
                Err(_) => break,
            };
            let resp = engine.handle(&job.req, &mut lane);
            // A vanished connection is its own problem; the worker
            // moves on.
            let _ = job.reply.send(resp);
        });
    }
    Dispatcher { tx }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;
    use crate::coordinator::stats::RequestStats;
    use crate::coordinator::SharedTileCache;
    use crate::plan::PlanCache;

    /// Deterministic admission-control proof: the test HOLDS the
    /// numerics receiver, so the single pool worker provably commits to
    /// job 1 (its numerics job arrives here) and then blocks on the
    /// reply — pinning the worker while jobs 2 and 3 probe a depth-1
    /// queue. No sleeps, no racing.
    #[test]
    fn full_queue_rejects_instead_of_hanging() {
        let cfg = ChipConfig::voltra();
        let tiles = SharedTileCache::new();
        let plans = PlanCache::new();
        let stats = RequestStats::new();
        let (ntx, nrx) = mpsc::sync_channel::<NumericsJob>(1);
        std::thread::scope(|s| {
            let engine = Engine {
                cfg: &cfg,
                tiles: &tiles,
                plans: &plans,
                stats: &stats,
            };
            let d = start(s, engine, ntx, 1, 1);
            let gemm = |seed| Parsed::Gemm {
                m: 8,
                k: 8,
                n: 8,
                seed,
            };
            let r1 = d.submit(gemm(1)).expect("idle queue admits");
            // The worker dequeued job 1 (its numerics job is in our
            // hand) and is blocked awaiting the reply.
            let j1 = nrx.recv().expect("worker reached numerics");
            let r2 = d.submit(gemm(2)).expect("queue holds one waiter");
            assert!(d.submit(gemm(3)).is_none(), "full queue must reject");
            // Unblock the worker; both admitted jobs complete in order.
            j1.reply.send(Ok((1, 1))).unwrap();
            let resp1 = r1.recv().unwrap();
            assert!(resp1.starts_with("OK checksum=1 "), "{resp1}");
            let j2 = nrx.recv().expect("worker picked up job 2");
            j2.reply.send(Ok((2, 1))).unwrap();
            let resp2 = r2.recv().unwrap();
            assert!(resp2.starts_with("OK checksum=2 "), "{resp2}");
            // Close the queue so the scope can join the worker.
            drop(d);
        });
    }
}
