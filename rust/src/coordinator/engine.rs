//! The pure request engine of the serving stack (DESIGN.md §14): parse
//! one protocol line, execute the verb against the process-wide caches,
//! format the response. Both serve modes route every verb through
//! [`Engine::handle`], so `serve_blocking` and `serve_threaded` answer
//! from the same code and cannot drift — the only mode-specific choice
//! left is *where numerics run*, abstracted as a [`NumericsLane`]:
//!
//! * [`InlineLane`] — numerics on the calling thread (the sequential
//!   reference engine);
//! * [`WorkerLane`] — numerics shipped to the dedicated backend worker
//!   over a *bounded* channel, with the chip-model sim cost resolved on
//!   the calling thread while the worker crunches (the overlap the
//!   concurrent engine has always had).
//!
//! The engine itself is a bundle of shared references ([`Engine`] is
//! `Copy`): the chip config, the tile cache, the plan cache, and the
//! serving-tier counters. Handlers are pure with respect to connection
//! state — everything they touch is process-wide — which is what lets
//! the dispatch layer run them from any worker thread.

use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::config::ChipConfig;
use crate::coordinator::stats::{RequestStats, Verb};
use crate::coordinator::{run_layer, SharedTileCache};
use crate::plan::{PlanCache, WorkloadPlan};
use crate::runtime::{GemmBackend, MatI32};
use crate::workloads::{self, Layer, LayerKind};

/// Deterministic operand generator (SplitMix64 -> int8 range).
fn gen_mat(seed: u64, rows: usize, cols: usize) -> MatI32 {
    let mut s = seed;
    MatI32::from_fn(rows, cols, |_, _| {
        s = s.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = s;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        ((z ^ (z >> 31)) % 255) as i32 - 127
    })
}

/// One GEMM request's results.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct GemmResponse {
    pub(crate) checksum: u64,
    pub(crate) wall_us: u128,
    pub(crate) sim_cycles: u64,
    pub(crate) sim_us: f64,
}

/// A parsed request line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub(crate) enum Parsed {
    Gemm {
        m: usize,
        k: usize,
        n: usize,
        seed: u64,
    },
    Workload {
        name: String,
    },
    Lint {
        name: String,
    },
    Stats,
    Quit,
}

impl Parsed {
    /// The verb this request counts under in [`RequestStats`].
    pub(crate) fn verb(&self) -> Verb {
        match self {
            Parsed::Gemm { .. } => Verb::Gemm,
            Parsed::Workload { .. } => Verb::Workload,
            Parsed::Lint { .. } => Verb::Lint,
            Parsed::Stats => Verb::Stats,
            // QUIT is connection control, never recorded: the transport
            // closes the connection before any counter is touched.
            Parsed::Quit => Verb::Error,
        }
    }
}

/// The usage line sent back for any request the parser cannot shape.
const USAGE: &str =
    "ERR expected: GEMM <m> <k> <n> <seed> | WORKLOAD <name> | LINT <name> | STATS | QUIT";

/// Parse one request line; `Err` carries the full `ERR ...` response.
pub(crate) fn parse_request(line: &str) -> std::result::Result<Parsed, String> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["GEMM", m, k, n, seed] => {
            fn int<T: std::str::FromStr>(tok: &str) -> std::result::Result<T, String> {
                tok.parse()
                    .map_err(|_| format!("ERR bad integer {tok:?}"))
            }
            Ok(Parsed::Gemm {
                m: int(m)?,
                k: int(k)?,
                n: int(n)?,
                seed: int(seed)?,
            })
        }
        ["WORKLOAD", name] => Ok(Parsed::Workload {
            name: (*name).to_string(),
        }),
        ["LINT", name] => Ok(Parsed::Lint {
            name: (*name).to_string(),
        }),
        ["STATS"] => Ok(Parsed::Stats),
        ["QUIT"] => Ok(Parsed::Quit),
        _ => Err(USAGE.to_string()),
    }
}

/// Reject degenerate or memory-hostile requests before any work happens
/// (u128 arithmetic: a hostile request must not overflow the check).
fn check_size(m: usize, k: usize, n: usize) -> Result<()> {
    // Bound every allocation the request forces: x (m*k), w (k*n), and
    // the m*n-sized psum/quantized/accumulator outputs — a thin-K
    // request like 50000x1x50000 is output-hostile, not operand-hostile.
    let xw = (m as u128) * (k as u128);
    let ww = (k as u128) * (n as u128);
    let out = (m as u128) * (n as u128);
    let too_big = match xw.checked_add(ww).and_then(|e| e.checked_add(out)) {
        Some(elems) => elems > 64 << 20,
        None => true,
    };
    if m == 0 || k == 0 || n == 0 || too_big {
        bail!("unreasonable GEMM size {m}x{k}x{n}");
    }
    Ok(())
}

/// Execute one request's numerics on the backend: deterministic operands
/// from the seed, returning (checksum, wall_us).
pub(crate) fn run_numerics(
    backend: &mut impl GemmBackend,
    m: usize,
    k: usize,
    n: usize,
    seed: u64,
) -> Result<(u64, u128)> {
    check_size(m, k, n)?;
    let x = gen_mat(seed, m, k);
    let w = gen_mat(seed ^ 0xABCD_EF01, k, n);
    let p = MatI32::zeros(m, n);
    let t0 = Instant::now();
    let (q, _acc) = backend.gemm(&x, &w, &p, 0.002)?;
    let wall_us = t0.elapsed().as_micros();
    let checksum = q
        .data
        .iter()
        .fold(0u64, |h, &v| h.wrapping_mul(31).wrapping_add(v as u8 as u64));
    Ok((checksum, wall_us))
}

/// What the chip would cost for this GEMM (memoized cycle model; safe to
/// call from many threads at once).
pub(crate) fn sim_cost(
    cfg: &ChipConfig,
    cache: &SharedTileCache,
    m: usize,
    k: usize,
    n: usize,
) -> (u64, f64) {
    let layer = Layer::new(
        "req",
        LayerKind::Gemm {
            m: m as u64,
            k: k as u64,
            n: n as u64,
        },
    );
    let mut handle = cache;
    let lm = run_layer(cfg, &layer, &mut handle);
    let sim_cycles = lm.latency_cycles;
    (sim_cycles, sim_cycles as f64 / cfg.operating_point.freq_mhz)
}

fn format_ok(r: &GemmResponse) -> String {
    format!(
        "OK checksum={} us={} sim_cycles={} sim_us={:.2}",
        r.checksum, r.wall_us, r.sim_cycles, r.sim_us
    )
}

/// Answer a WORKLOAD request from the plan cache. Every field is a pure
/// function of the memoized plan, so the response bytes are identical
/// across engines, connections and cache temperature — the differential
/// tests rely on this.
fn format_workload(cfg: &ChipConfig, name: &str, p: &WorkloadPlan) -> String {
    let latency = p.total_latency_cycles();
    format!(
        "OK workload={} latency_cycles={} compute_cycles={} dma_cycles={} dma_kb={} tiles={} sim_ms={:.3}",
        name,
        latency,
        p.total_compute_cycles(),
        p.total_dma_cycles(),
        p.total_dma_bytes() / 1024,
        p.dispatched_tiles,
        latency as f64 / (cfg.operating_point.freq_mhz * 1e3),
    )
}

/// Resolve one WORKLOAD request (shared by both engines) to its full
/// response line: plan-cache lookup, plan-once-answer-many. Warm
/// requests never materialize the layer graph or a report — the plan
/// cache is probed by the request's name before `by_name` runs, and the
/// response is formatted from the immutable plan's aggregates.
pub(crate) fn serve_workload(cfg: &ChipConfig, plans: &PlanCache, name: &str) -> String {
    match plans.plan_named(cfg, name, || workloads::by_name(name)) {
        Some(p) => format_workload(cfg, name, &p),
        None => format!("ERR unknown workload {name:?}"),
    }
}

/// Resolve one LINT request: plan (or reuse) the named workload, then
/// run the static verifier (`plan::verify`, DESIGN.md §13) against it.
/// The response is deterministic: a clean plan always answers
/// `OK lint workload=<name> findings=0`; a corrupt plan would enumerate
/// its findings as `rule@layer` pairs after the count.
pub(crate) fn serve_lint(cfg: &ChipConfig, plans: &PlanCache, name: &str) -> String {
    let Some(w) = workloads::by_name(name) else {
        return format!("ERR unknown workload {name:?}");
    };
    let plan = plans
        .plan_named(cfg, name, || Some(w.clone()))
        .expect("resolver always yields the workload");
    let findings = crate::plan::verify(cfg, &w, &plan);
    let mut resp = format!("OK lint workload={} findings={}", name, findings.len());
    for f in &findings {
        resp.push_str(&format!(" {}@{}", f.rule, f.layer));
    }
    resp
}

/// One numerics request in flight to the dedicated worker thread.
pub(crate) struct NumericsJob {
    pub(crate) m: usize,
    pub(crate) k: usize,
    pub(crate) n: usize,
    pub(crate) seed: u64,
    pub(crate) reply: mpsc::Sender<Result<(u64, u128)>>,
}

/// Where a GEMM request's numerics execute. `overlap` is the engine's
/// sim-cost computation: a lane calls it exactly once per successful
/// `exec`, positioned wherever it overlaps best with the numerics.
pub(crate) trait NumericsLane {
    fn exec(
        &mut self,
        m: usize,
        k: usize,
        n: usize,
        seed: u64,
        overlap: &mut dyn FnMut(),
    ) -> Result<(u64, u128)>;
}

/// Numerics on the calling thread (the sequential reference engine).
pub(crate) struct InlineLane<'a, B: GemmBackend> {
    pub(crate) backend: &'a mut B,
}

impl<B: GemmBackend> NumericsLane for InlineLane<'_, B> {
    fn exec(
        &mut self,
        m: usize,
        k: usize,
        n: usize,
        seed: u64,
        overlap: &mut dyn FnMut(),
    ) -> Result<(u64, u128)> {
        // No worker to overlap with: resolve the sim cost, then run
        // numerics on this same thread.
        overlap();
        run_numerics(self.backend, m, k, n, seed)
    }
}

/// Numerics shipped to the dedicated backend worker over a bounded
/// channel. The blocking `send` is the satellite's backpressure: when
/// the worker falls behind, engine workers queue *here* (at most one
/// outstanding job each) instead of growing an unbounded buffer.
pub(crate) struct WorkerLane {
    pub(crate) jobs: mpsc::SyncSender<NumericsJob>,
}

impl NumericsLane for WorkerLane {
    fn exec(
        &mut self,
        m: usize,
        k: usize,
        n: usize,
        seed: u64,
        overlap: &mut dyn FnMut(),
    ) -> Result<(u64, u128)> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.jobs
            .send(NumericsJob {
                m,
                k,
                n,
                seed,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("numerics worker is gone"))?;
        // Overlap: the chip-model cost resolves on this thread while the
        // worker crunches the numerics.
        overlap();
        match reply_rx.recv() {
            Ok(r) => r,
            Err(_) => Err(anyhow!("numerics worker is gone")),
        }
    }
}

/// The shared-state bundle every handler needs: pure references, so the
/// engine is `Copy` and any worker thread can hold one.
#[derive(Clone, Copy)]
pub(crate) struct Engine<'a> {
    pub(crate) cfg: &'a ChipConfig,
    pub(crate) tiles: &'a SharedTileCache,
    pub(crate) plans: &'a PlanCache,
    pub(crate) stats: &'a RequestStats,
}

impl Engine<'_> {
    /// Execute one parsed request to its full response line. QUIT never
    /// reaches the engine (the transport drains and closes first).
    pub(crate) fn handle(&self, req: &Parsed, lane: &mut dyn NumericsLane) -> String {
        match req {
            Parsed::Gemm { m, k, n, seed } => {
                let (m, k, n, seed) = (*m, *k, *n, *seed);
                // Cheap validation here so malformed sizes never occupy
                // the (serialized) numerics worker.
                if let Err(e) = check_size(m, k, n) {
                    return format!("ERR {e}");
                }
                let mut sim = None;
                let result = lane.exec(m, k, n, seed, &mut || {
                    sim = Some(sim_cost(self.cfg, self.tiles, m, k, n));
                });
                match result {
                    Ok((checksum, wall_us)) => {
                        let (sim_cycles, sim_us) =
                            sim.unwrap_or_else(|| sim_cost(self.cfg, self.tiles, m, k, n));
                        format_ok(&GemmResponse {
                            checksum,
                            wall_us,
                            sim_cycles,
                            sim_us,
                        })
                    }
                    Err(e) => format!("ERR {e}"),
                }
            }
            Parsed::Workload { name } => serve_workload(self.cfg, self.plans, name),
            Parsed::Lint { name } => serve_lint(self.cfg, self.plans, name),
            Parsed::Stats => self.render_stats(),
            Parsed::Quit => String::new(),
        }
    }

    /// Format the STATS response from the serving counters and both
    /// cache tiers. The request being answered is not yet recorded, so
    /// a STATS line never counts itself.
    pub(crate) fn render_stats(&self) -> String {
        let s = self.stats;
        let p = self.plans.plan_stats();
        let t = self.tiles.stats();
        // The mapper tier is process-global (every plan path resolves
        // through MapperCache::global()), so its counters are reported
        // from there — the serving engine has no private mapper state.
        let mc = crate::tiling::MapperCache::global();
        let m = mc.stats();
        format!(
            "OK stats served={} gemm={} workload={} lint={} stats={} errors={} busy={} \
             plan_hits={} plan_misses={} plan_waits={} tile_hits={} tile_misses={} \
             tile_waits={} mapper_hits={} mapper_misses={} mapper_waits={} \
             p50_us={} p99_us={} max_us={} flight_aborts={} rank_depth={}",
            s.served(),
            s.count(Verb::Gemm),
            s.count(Verb::Workload),
            s.count(Verb::Lint),
            s.count(Verb::Stats),
            s.count(Verb::Error),
            s.rejected(),
            p.hits,
            p.misses,
            p.coalesced,
            t.hits,
            t.misses,
            self.tiles.coalesced_waits(),
            m.hits,
            m.misses,
            mc.coalesced_waits(),
            s.percentile_us(50.0),
            s.percentile_us(99.0),
            s.max_us(),
            // Process-global like the mapper tier: aborted single-flight
            // leaderships and the deepest lock-rank nesting observed.
            crate::sync::flight_aborts(),
            crate::sync::max_rank_depth(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostBackend;

    #[test]
    fn generated_operands_are_deterministic_and_int8() {
        let a = gen_mat(7, 16, 16);
        let b = gen_mat(7, 16, 16);
        assert_eq!(a, b);
        assert!(a.data.iter().all(|&v| (-127..=127).contains(&v)));
        let c = gen_mat(8, 16, 16);
        assert_ne!(a, c);
    }

    #[test]
    fn checksum_is_order_sensitive() {
        let h = |v: &[i32]| {
            v.iter()
                .fold(0u64, |h, &x| h.wrapping_mul(31).wrapping_add(x as u8 as u64))
        };
        assert_ne!(h(&[1, 2, 3]), h(&[3, 2, 1]));
    }

    #[test]
    fn parser_distinguishes_bad_integers_from_bad_commands() {
        assert_eq!(
            parse_request("GEMM 8 8 8 1"),
            Ok(Parsed::Gemm {
                m: 8,
                k: 8,
                n: 8,
                seed: 1
            })
        );
        assert_eq!(parse_request("QUIT"), Ok(Parsed::Quit));
        assert_eq!(parse_request("STATS"), Ok(Parsed::Stats));
        assert_eq!(
            parse_request("WORKLOAD bert"),
            Ok(Parsed::Workload {
                name: "bert".to_string()
            })
        );
        assert_eq!(
            parse_request("LINT bert"),
            Ok(Parsed::Lint {
                name: "bert".to_string()
            })
        );
        let e = parse_request("GEMM a b c 1").unwrap_err();
        assert!(e.starts_with("ERR bad integer"), "{e}");
        let e = parse_request("GEMM 8 8 8").unwrap_err();
        assert!(e.starts_with("ERR expected"), "{e}");
        let e = parse_request("NONSENSE").unwrap_err();
        assert!(e.starts_with("ERR expected"), "{e}");
        let e = parse_request("WORKLOAD").unwrap_err();
        assert!(e.starts_with("ERR expected"), "{e}");
        let e = parse_request("LINT").unwrap_err();
        assert!(e.starts_with("ERR expected"), "{e}");
        let e = parse_request("STATS now").unwrap_err();
        assert!(e.starts_with("ERR expected"), "{e}");
        // A negative dimension is a bad integer for usize, not a usage error.
        let e = parse_request("GEMM -8 8 8 1").unwrap_err();
        assert!(e.starts_with("ERR bad integer"), "{e}");
    }

    #[test]
    fn size_check_rejects_degenerate_and_huge() {
        assert!(check_size(0, 0, 0).is_err());
        assert!(check_size(8, 8, 8).is_ok());
        // Thin-K: tiny operands, gigabyte outputs — must be rejected.
        assert!(check_size(50_000, 1, 50_000).is_err());
        // Would overflow naive usize arithmetic; must be cleanly rejected.
        assert!(check_size(usize::MAX, usize::MAX, usize::MAX).is_err());
    }

    /// Drop the wall-clock `us=` token, the protocol's only
    /// nondeterministic bytes.
    fn sans_wall(resp: &str) -> String {
        resp.split(' ')
            .filter(|t| !t.starts_with("us="))
            .collect::<Vec<_>>()
            .join(" ")
    }

    #[test]
    fn gemm_is_deterministic_and_identical_across_lanes() {
        let cfg = ChipConfig::voltra();
        let tiles = SharedTileCache::new();
        let plans = PlanCache::new();
        let stats = RequestStats::new();
        let engine = Engine {
            cfg: &cfg,
            tiles: &tiles,
            plans: &plans,
            stats: &stats,
        };
        let req = parse_request("GEMM 64 64 64 1").unwrap();
        let mut backend = HostBackend;
        let mut inline = InlineLane {
            backend: &mut backend,
        };
        let a = engine.handle(&req, &mut inline);
        let b = engine.handle(&req, &mut inline);
        assert!(a.starts_with("OK checksum="), "{a}");
        assert_eq!(sans_wall(&a), sans_wall(&b));
        // A different seed changes the checksum.
        let other = parse_request("GEMM 64 64 64 2").unwrap();
        let c = engine.handle(&other, &mut inline);
        assert_ne!(sans_wall(&a), sans_wall(&c));
        // The worker lane answers byte-identically (modulo wall clock):
        // the same engine handler, a different numerics placement.
        let (job_tx, job_rx) = mpsc::sync_channel::<NumericsJob>(1);
        let worker = std::thread::spawn(move || {
            let mut backend = HostBackend;
            while let Ok(job) = job_rx.recv() {
                let r = run_numerics(&mut backend, job.m, job.k, job.n, job.seed);
                let _ = job.reply.send(r);
            }
        });
        let mut lane = WorkerLane { jobs: job_tx };
        let d = engine.handle(&req, &mut lane);
        assert_eq!(sans_wall(&a), sans_wall(&d));
        drop(lane);
        worker.join().unwrap();
        // Oversized and degenerate requests never reach a lane.
        let huge = parse_request("GEMM 50000 1 50000 1").unwrap();
        let e = engine.handle(&huge, &mut inline);
        assert!(e.starts_with("ERR unreasonable GEMM size"), "{e}");
    }

    #[test]
    fn serve_workload_answers_from_the_plan_cache() {
        let cfg = ChipConfig::voltra();
        let plans = PlanCache::new();
        let cold = serve_workload(&cfg, &plans, "lstm");
        let warm = serve_workload(&cfg, &plans, "lstm");
        // Byte-identical response, one plan compiled.
        assert_eq!(cold, warm);
        assert!(cold.starts_with("OK workload=lstm latency_cycles="), "{cold}");
        let s = plans.stats();
        assert_eq!(s.misses, 1, "second request must reuse the plan");
        assert!(s.hits >= 1);
        let e = serve_workload(&cfg, &plans, "nope");
        assert!(e.starts_with("ERR unknown workload"), "{e}");
    }

    #[test]
    fn serve_lint_reports_clean_plans_and_unknown_names() {
        let cfg = ChipConfig::voltra();
        let plans = PlanCache::new();
        let r = serve_lint(&cfg, &plans, "lstm");
        assert_eq!(r, "OK lint workload=lstm findings=0");
        // Answered from the same cache: linting after serving replans nothing.
        let before = plans.stats().misses;
        let again = serve_lint(&cfg, &plans, "lstm");
        assert_eq!(r, again);
        assert_eq!(plans.stats().misses, before);
        let e = serve_lint(&cfg, &plans, "nope");
        assert!(e.starts_with("ERR unknown workload"), "{e}");
    }

    #[test]
    fn stats_verb_reports_counters_without_counting_itself() {
        let cfg = ChipConfig::voltra();
        let tiles = SharedTileCache::new();
        let plans = PlanCache::new();
        let stats = RequestStats::new();
        let engine = Engine {
            cfg: &cfg,
            tiles: &tiles,
            plans: &plans,
            stats: &stats,
        };
        let mut backend = HostBackend;
        let mut lane = InlineLane {
            backend: &mut backend,
        };
        let empty = engine.handle(&Parsed::Stats, &mut lane);
        // Engine-scoped counters are exactly zero on a fresh engine;
        // the mapper_* fields read the process-GLOBAL MapperCache, so
        // under parallel test execution they are only shape-checked.
        assert!(
            empty.starts_with(
                "OK stats served=0 gemm=0 workload=0 lint=0 stats=0 errors=0 busy=0 \
                 plan_hits=0 plan_misses=0 plan_waits=0 tile_hits=0 tile_misses=0 \
                 tile_waits=0 mapper_hits="
            ),
            "{empty}"
        );
        assert!(empty.contains(" mapper_misses="), "{empty}");
        assert!(empty.contains(" mapper_waits="), "{empty}");
        // flight_aborts / rank_depth are also process-global (crate::sync
        // statics), so the tail is shape-checked, not value-pinned.
        assert!(empty.contains(" p50_us=0 p99_us=0 max_us=0 flight_aborts="), "{empty}");
        assert!(empty.contains(" rank_depth="), "{empty}");
        // Counters are the server's job (recorded after each response);
        // simulate two served requests and one rejection.
        stats.record(Verb::Workload, 7);
        stats.record(Verb::Gemm, 3);
        stats.reject();
        let r = engine.handle(&Parsed::Stats, &mut lane);
        assert!(r.starts_with("OK stats served=2 gemm=1 workload=1 "), "{r}");
        assert!(r.contains(" busy=1 "), "{r}");
        assert!(r.contains(" max_us=7 flight_aborts="), "{r}");
    }
}
