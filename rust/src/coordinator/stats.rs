//! Serving-tier SLO metrics (DESIGN.md §14): per-verb request counts,
//! admission rejections, and a lock-free latency histogram, surfaced
//! over the wire by the `STATS` protocol verb.
//!
//! Everything is atomics — recording a request is a handful of relaxed
//! increments, cheap enough to sit on every request path of both serve
//! engines. Latencies are bucketed at power-of-two microsecond
//! boundaries (31 buckets cover >35 minutes, far beyond any sane
//! request), so percentiles come from a 32-word cumulative walk with no
//! locks and no allocation; the reported percentile is the bucket's
//! inclusive upper bound, i.e. a conservative (never understated)
//! estimate. `max_us` is tracked exactly.

use std::sync::atomic::{AtomicU64, Ordering};

/// Request classification for the per-verb counters. `Error` covers
/// lines the parser rejected (usage / bad-integer responses); verbs
/// that parse but answer `ERR ...` (unknown workload, unreasonable
/// size) still count under their verb — the server did that work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Verb {
    Gemm,
    Workload,
    Lint,
    Stats,
    Error,
}

const VERBS: usize = 5;

/// Power-of-two latency buckets: bucket `i` holds requests whose
/// microsecond latency has bit-length `i` (bucket 0 = 0 us, bucket 1 =
/// 1 us, bucket 2 = 2-3 us, ...), saturating at the last bucket.
const HIST_BUCKETS: usize = 32;

fn bucket_of(us: u64) -> usize {
    ((u64::BITS - us.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Inclusive upper bound of bucket `i` in microseconds.
fn bucket_ceiling(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        (1u64 << i) - 1
    }
}

/// The serving tier's request counters and latency histogram. One
/// instance per serve engine invocation, shared by every connection
/// handler and dispatch worker of that server.
pub(crate) struct RequestStats {
    counts: [AtomicU64; VERBS],
    /// Requests refused at admission (`ERR busy`): never entered the
    /// dispatch queue, never recorded a latency.
    rejected: AtomicU64,
    hist: [AtomicU64; HIST_BUCKETS],
    max_us: AtomicU64,
}

impl RequestStats {
    pub(crate) fn new() -> Self {
        RequestStats {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            rejected: AtomicU64::new(0),
            hist: std::array::from_fn(|_| AtomicU64::new(0)),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one completed request: counted under its verb; answered
    /// (non-`Error`) requests also enter the latency histogram.
    pub(crate) fn record(&self, verb: Verb, us: u64) {
        self.counts[verb as usize].fetch_add(1, Ordering::Relaxed);
        if verb != Verb::Error {
            self.hist[bucket_of(us)].fetch_add(1, Ordering::Relaxed);
            self.max_us.fetch_max(us, Ordering::Relaxed);
        }
    }

    /// Record one admission rejection (`ERR busy`).
    pub(crate) fn reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn count(&self, verb: Verb) -> u64 {
        self.counts[verb as usize].load(Ordering::Relaxed)
    }

    pub(crate) fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Requests answered under a verb (everything but parse errors).
    pub(crate) fn served(&self) -> u64 {
        self.count(Verb::Gemm)
            + self.count(Verb::Workload)
            + self.count(Verb::Lint)
            + self.count(Verb::Stats)
    }

    /// The `p`-th latency percentile in microseconds (conservative:
    /// the matching bucket's upper bound). 0 when nothing is recorded.
    pub(crate) fn percentile_us(&self, p: f64) -> u64 {
        let counts: Vec<u64> = self.hist.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_ceiling(i);
            }
        }
        self.max_us()
    }

    pub(crate) fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_partition_the_microsecond_axis() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        // Ceilings are consistent with membership.
        for i in 0..HIST_BUCKETS - 1 {
            assert_eq!(bucket_of(bucket_ceiling(i)), i, "ceiling of bucket {i}");
            assert_eq!(bucket_of(bucket_ceiling(i) + 1), i + 1);
        }
    }

    #[test]
    fn percentiles_walk_the_histogram_conservatively() {
        let s = RequestStats::new();
        assert_eq!(s.percentile_us(99.0), 0, "empty histogram reports 0");
        // 99 fast requests (1 us) and one slow outlier (~1 ms).
        for _ in 0..99 {
            s.record(Verb::Gemm, 1);
        }
        s.record(Verb::Workload, 1000);
        assert_eq!(s.percentile_us(50.0), 1);
        assert_eq!(s.percentile_us(99.0), 1);
        // The 100th-percentile request is the outlier; its bucket's
        // ceiling bounds it from above.
        assert_eq!(s.percentile_us(100.0), 1023);
        assert_eq!(s.max_us(), 1000);
    }

    #[test]
    fn verbs_count_independently_and_errors_skip_the_histogram() {
        let s = RequestStats::new();
        s.record(Verb::Gemm, 5);
        s.record(Verb::Gemm, 5);
        s.record(Verb::Lint, 5);
        s.record(Verb::Error, 5);
        s.reject();
        assert_eq!(s.count(Verb::Gemm), 2);
        assert_eq!(s.count(Verb::Lint), 1);
        assert_eq!(s.count(Verb::Error), 1);
        assert_eq!(s.count(Verb::Workload), 0);
        assert_eq!(s.served(), 3, "errors are not served requests");
        assert_eq!(s.rejected(), 1);
        // Three histogram entries (the error is excluded).
        let total: u64 = s.hist.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        assert_eq!(total, 3);
    }
}
