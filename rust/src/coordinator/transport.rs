//! The transport layer of the serving stack (DESIGN.md §14): framing
//! and connection lifetime, nothing else. It reads the line-oriented
//! protocol off a [`TcpStream`], hands each line to a caller-supplied
//! handler, writes the handler's response line back, and drains the
//! connection gracefully when the handler signals close (QUIT) or the
//! peer disconnects.
//!
//! Keeping this layer verb-blind is the point of the split: both serve
//! modes (and any future fleet transport) share one framing
//! implementation, while everything that *interprets* a line lives in
//! the engine/dispatch layers above.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{Context, Result};

/// What the per-line handler wants done with its line.
pub(crate) enum Reply {
    /// Write this response line and keep serving the connection.
    Line(String),
    /// Drain and close the connection (QUIT): everything already
    /// written is flushed before the socket drops.
    Quit,
}

/// Serve one connection's line protocol: read request lines, write the
/// handler's response lines, until QUIT or EOF. The final flush is the
/// graceful-drain guarantee — a client that sends QUIT sees every
/// response to the requests it already sent.
pub(crate) fn serve_lines(
    stream: TcpStream,
    mut handle: impl FnMut(&str) -> Reply,
) -> Result<()> {
    let mut out = stream.try_clone().context("clone stream")?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        match handle(&line?) {
            Reply::Line(resp) => writeln!(out, "{resp}")?,
            Reply::Quit => break,
        }
    }
    out.flush().context("flush on close")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn lines_round_trip_and_quit_drains() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut seen = Vec::new();
            serve_lines(stream, |line| {
                seen.push(line.to_string());
                if line == "QUIT" {
                    Reply::Quit
                } else {
                    Reply::Line(format!("echo {line}"))
                }
            })
            .unwrap();
            seen
        });
        let client = TcpStream::connect(addr).unwrap();
        let mut w = client.try_clone().unwrap();
        writeln!(w, "alpha").unwrap();
        writeln!(w, "beta").unwrap();
        writeln!(w, "QUIT").unwrap();
        let replies: Vec<String> = BufReader::new(client)
            .lines()
            .map(|l| l.unwrap())
            .collect();
        // Both responses arrive before the QUIT-triggered close.
        assert_eq!(replies, vec!["echo alpha", "echo beta"]);
        assert_eq!(server.join().unwrap(), vec!["alpha", "beta", "QUIT"]);
    }
}
