//! Single-flight coalescing: at most one thread computes any given key
//! at a time, everyone else blocks on that computation and shares its
//! result (DESIGN.md §14).
//!
//! This is the thundering-herd guard the serving stack wraps around its
//! memoization stores: a burst of identical cold requests used to race
//! N planners/simulators at the same key (pure work, so merely wasted —
//! but N copies of a multi-millisecond plan compile is exactly the load
//! spike that sinks tail latency). With a [`FlightGroup`] in front, the
//! first caller becomes the *leader* and computes; every concurrent
//! caller for the same key registers as a *follower*, blocks on the
//! flight's condvar, and wakes with the leader's published value.
//!
//! Protocol:
//! * [`FlightGroup::join`] — the first caller for a key gets
//!   [`Role::Leader`] and MUST eventually [`Leader::publish`] a value;
//!   later callers get [`Role::Waited`] with the published value.
//! * A leader that drops without publishing (resolve failure, panic
//!   unwind) *aborts* the flight: followers wake with `Waited(None)`
//!   and retry the whole lookup — no caller can deadlock on a leader
//!   that died.
//! * The flight entry is removed from the in-flight map *before* the
//!   value is published, so a caller arriving after completion never
//!   waits on a finished flight — it re-reads its cache (callers always
//!   check their memoization store first) or leads a fresh flight.
//!
//! The group stores nothing but in-flight state: completed values live
//! in the caller's own store ([`SharedTileCache`] shards, [`PlanCache`]
//! shards), keeping this primitive policy-free.
//!
//! [`SharedTileCache`]: crate::coordinator::SharedTileCache
//! [`PlanCache`]: crate::plan::PlanCache

use crate::sync::{Condvar, Mutex, Rank};
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Arc;

/// One in-flight computation. The slot holds `None` while the leader
/// computes, `Some(Some(v))` once published, `Some(None)` if the leader
/// aborted (followers retry).
struct Flight<V> {
    slot: Mutex<Option<Option<V>>>,
    cv: Condvar,
}

impl<V> Flight<V> {
    fn new() -> Self {
        Flight {
            slot: Mutex::new(Rank::FlightSlot, None),
            cv: Condvar::new(),
        }
    }
}

/// The in-flight computations for one keyed store.
pub(crate) struct FlightGroup<K, V> {
    inflight: Mutex<HashMap<K, Arc<Flight<V>>>>,
}

impl<K, V> Default for FlightGroup<K, V> {
    fn default() -> Self {
        FlightGroup {
            inflight: Mutex::new(Rank::FlightMap, HashMap::new()),
        }
    }
}

/// What [`FlightGroup::join`] made of this caller.
pub(crate) enum Role<'g, K: Eq + Hash + Clone, V: Clone> {
    /// First caller for the key: compute, then [`Leader::publish`].
    /// Dropping without publishing aborts the flight (followers retry).
    Leader(Leader<'g, K, V>),
    /// Another caller led this key: its published value, or `None` if
    /// it aborted — re-check the cache and join again.
    Waited(Option<V>),
}

/// The leader's obligation token (see [`Role::Leader`]).
pub(crate) struct Leader<'g, K: Eq + Hash + Clone, V: Clone> {
    group: &'g FlightGroup<K, V>,
    key: K,
    flight: Arc<Flight<V>>,
    finished: bool,
}

impl<K: Eq + Hash + Clone, V: Clone> FlightGroup<K, V> {
    /// Join the flight for `key`. `on_coalesce` fires exactly when this
    /// caller becomes a follower — after registering on the flight,
    /// *before* blocking — so a leader can observe (through whatever
    /// counter the callback bumps) how many callers it is serving while
    /// it is still computing.
    pub(crate) fn join<F: FnOnce()>(&self, key: &K, on_coalesce: F) -> Role<'_, K, V> {
        let flight = {
            let mut map = self.inflight.lock();
            match map.get(key) {
                Some(f) => Arc::clone(f),
                None => {
                    let f = Arc::new(Flight::new());
                    map.insert(key.clone(), Arc::clone(&f));
                    return Role::Leader(Leader {
                        group: self,
                        key: key.clone(),
                        flight: f,
                        finished: false,
                    });
                }
            }
        };
        on_coalesce();
        // Predicate-loop wait: the facade's `wait_while` re-checks the
        // slot on every wakeup, so spurious wakeups cannot leak an
        // unpublished flight past this point (checked adversarially by
        // the `flight` model's wait-if mutation, `crate::check`).
        let slot = flight.slot.lock();
        let slot = flight.cv.wait_while(slot, |s| s.is_none());
        Role::Waited((*slot).clone().expect("loop exits only when published"))
    }
}

impl<K: Eq + Hash + Clone, V: Clone> Leader<'_, K, V> {
    /// Publish the computed value to every follower and retire the
    /// flight.
    pub(crate) fn publish(mut self, value: V) {
        self.finish(Some(value));
    }

    fn finish(&mut self, value: Option<V>) {
        if self.finished {
            return;
        }
        self.finished = true;
        if value.is_none() {
            // Abort path (unwind or resolve failure): every one of
            // these sent its followers around the retry loop — surfaced
            // as `flight_aborts` in STATS and `voltra report`.
            crate::sync::record_flight_abort();
        }
        // Retire the flight BEFORE publishing: a caller that arrives
        // after this point must lead a fresh flight (after re-checking
        // its cache), never wait on a completed one.
        self.group.inflight.lock().remove(&self.key);
        *self.flight.slot.lock() = Some(value);
        self.flight.cv.notify_all();
    }
}

impl<K: Eq + Hash + Clone, V: Clone> Drop for Leader<'_, K, V> {
    fn drop(&mut self) {
        // Abort path: unwinds (or forgotten leaders) wake followers
        // empty-handed instead of deadlocking them.
        self.finish(None);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::time::{Duration, Instant};

    /// Spin until `cond` holds (bounded so a regression fails loudly
    /// instead of hanging the suite).
    fn await_true(cond: impl Fn() -> bool, what: &str) {
        let t0 = Instant::now();
        while !cond() {
            assert!(t0.elapsed() < Duration::from_secs(10), "timed out: {what}");
            std::thread::yield_now();
        }
    }

    #[test]
    fn followers_share_the_leaders_value() {
        let group: FlightGroup<u32, u64> = FlightGroup::default();
        let registered = AtomicU64::new(0);
        std::thread::scope(|s| {
            let Role::Leader(lead) = group.join(&7, || unreachable!("first caller leads")) else {
                panic!("first caller must lead");
            };
            let followers: Vec<_> = (0..4)
                .map(|_| {
                    s.spawn(|| {
                        let role = group.join(&7, || {
                            registered.fetch_add(1, Ordering::SeqCst);
                        });
                        match role {
                            Role::Leader(_) => panic!("flight already led"),
                            Role::Waited(v) => v,
                        }
                    })
                })
                .collect();
            // Every follower registers (callback fires pre-block), THEN
            // the leader publishes — proving waiters really waited.
            await_true(|| registered.load(Ordering::SeqCst) == 4, "followers registering");
            lead.publish(42);
            for f in followers {
                assert_eq!(f.join().unwrap(), Some(42));
            }
        });
        // The flight retired: the next caller leads afresh.
        assert!(matches!(group.join(&7, || ()), Role::Leader(_)));
    }

    #[test]
    fn aborted_leader_wakes_followers_for_retry() {
        let group: FlightGroup<u32, u64> = FlightGroup::default();
        let registered = AtomicBool::new(false);
        std::thread::scope(|s| {
            let Role::Leader(lead) = group.join(&1, || ()) else {
                panic!("first caller must lead");
            };
            let follower = s.spawn(|| {
                let role = group.join(&1, || registered.store(true, Ordering::SeqCst));
                match role {
                    Role::Leader(_) => panic!("flight already led"),
                    Role::Waited(v) => v,
                }
            });
            await_true(|| registered.load(Ordering::SeqCst), "follower registering");
            drop(lead); // abort without publishing
            assert_eq!(follower.join().unwrap(), None, "abort must wake with None");
        });
        assert!(matches!(group.join(&1, || ()), Role::Leader(_)));
    }

    #[test]
    fn distinct_keys_fly_independently() {
        let group: FlightGroup<u32, u64> = FlightGroup::default();
        let a = group.join(&1, || ());
        let b = group.join(&2, || ());
        match (a, b) {
            (Role::Leader(la), Role::Leader(lb)) => {
                la.publish(1);
                lb.publish(2);
            }
            _ => panic!("distinct keys must both lead"),
        }
    }
}
