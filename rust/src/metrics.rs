//! Metric accounting: the quantities Fig. 6 / Fig. 7 report.
//!
//! * **Spatial utilization** — MACs doing useful work / (512 x active
//!   cycles); degraded by workload-vs-array dimension mismatch.
//! * **Temporal utilization** — cycles the array fires / total cycles of
//!   the tiled layer block; degraded by bank conflicts & memory latency.
//! * **Total latency** — compute + off-chip DMA for the whole workload.
//!
//! All counters are accumulated bottom-up: `TileMetrics` (one simulated
//! tile) -> `LayerMetrics` -> `WorkloadMetrics`.

/// Activity counters for one simulated GEMM tile.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TileMetrics {
    /// Cycles from tile start to last output write (on-chip only).
    pub total_cycles: u64,
    /// Cycles in which the spatial array fired.
    pub active_cycles: u64,
    /// Useful MAC operations performed (excludes padding lanes).
    pub useful_macs: u64,
    /// MAC slots offered = macs_per_array x active_cycles.
    pub offered_macs: u64,
    /// Shared-memory bank read/write word accesses.
    pub bank_reads: u64,
    pub bank_writes: u64,
    /// Requests that lost bank arbitration and were retried.
    pub bank_conflicts: u64,
    /// Cycles the array stalled waiting on operands.
    pub stall_cycles: u64,
    /// Cycles the SIMD quantizer was busy.
    pub simd_cycles: u64,
    /// FIFO push+pop events (energy accounting).
    pub fifo_events: u64,
}

impl TileMetrics {
    pub fn temporal_utilization(&self) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        self.active_cycles as f64 / self.total_cycles as f64
    }

    pub fn spatial_utilization(&self) -> f64 {
        if self.offered_macs == 0 {
            return 0.0;
        }
        self.useful_macs as f64 / self.offered_macs as f64
    }

    /// Accumulate another tile executed `count` times (tile memoization).
    pub fn add_scaled(&mut self, other: &TileMetrics, count: u64) {
        self.total_cycles += other.total_cycles * count;
        self.active_cycles += other.active_cycles * count;
        self.useful_macs += other.useful_macs * count;
        self.offered_macs += other.offered_macs * count;
        self.bank_reads += other.bank_reads * count;
        self.bank_writes += other.bank_writes * count;
        self.bank_conflicts += other.bank_conflicts * count;
        self.stall_cycles += other.stall_cycles * count;
        self.simd_cycles += other.simd_cycles * count;
        self.fifo_events += other.fifo_events * count;
    }
}

/// Hit/miss counters of a tile cache (the shared serving cache reports
/// these so the sweep/serve paths can show how much simulation work the
/// memoization removed).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups answered without simulating (0.0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let n = self.lookups();
        if n == 0 {
            0.0
        } else {
            self.hits as f64 / n as f64
        }
    }
}

/// Aggregated metrics for one network layer (all its tiles + DMA).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerMetrics {
    pub name: String,
    /// Compact description of the resolved array mapping(s) this layer's
    /// GEMMs ran under (e.g. `8x8x8`, `1x8x64` for a K-extended GEMV,
    /// `T`-suffixed when transposed; DESIGN.md §11).
    pub mapping: String,
    pub tiles: TileMetrics,
    /// Off-chip bytes moved for this layer (in + out).
    pub dma_bytes: u64,
    /// DMA cycles (bandwidth + burst overhead), before overlap.
    pub dma_cycles: u64,
    /// Layer latency as resolved by the event-driven pipeline scheduler
    /// (`sim::pipeline`): compute and DMA overlapped tile by tile where
    /// the allocator granted ping-pong regions.
    pub latency_cycles: u64,
    /// Cycles the schedule hid by overlapping DMA with compute:
    /// `(compute + dma) - latency`; 0 when fully serialized.
    pub overlap_cycles: u64,
    /// Reshuffler / maxpool / auxiliary cycles.
    pub aux_cycles: u64,
    /// Predecessor activation bytes the residency pass chained on chip
    /// for this layer (0 = input streamed from off-chip memory).
    pub chained_bytes: u64,
    /// On-chip memory footprint of the chosen tiling (bytes).
    pub tile_footprint_bytes: u64,
    /// Useful MACs (== tiles.useful_macs, kept for convenience).
    pub macs: u64,
}

/// Whole-workload aggregation (one bar of Fig. 6).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkloadMetrics {
    pub name: String,
    pub layers: Vec<LayerMetrics>,
}

impl WorkloadMetrics {
    pub fn total_compute_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.tiles.total_cycles + l.aux_cycles).sum()
    }

    pub fn total_dma_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.dma_cycles).sum()
    }

    pub fn total_dma_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.dma_bytes).sum()
    }

    /// End-to-end latency including off-chip movement (Fig. 6c metric).
    pub fn total_latency_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.latency_cycles).sum()
    }

    /// Cycles hidden by compute/DMA overlap across the whole workload
    /// (what double buffering bought; the scheduler's Fig. 6c levers).
    pub fn total_overlap_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.overlap_cycles).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// Activation bytes the residency pass kept on chip across layer
    /// boundaries (the plan-recorded PDMA chaining of Fig. 4).
    pub fn total_chained_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.chained_bytes).sum()
    }

    /// MAC-weighted mean of per-layer spatial utilization (the Fig. 6a
    /// metric: each tiled layer block's array fill, weighted by how much
    /// work the layer contributes).
    pub fn spatial_utilization(&self) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for l in &self.layers {
            if l.tiles.offered_macs == 0 {
                continue;
            }
            let u = l.tiles.useful_macs as f64 / l.tiles.offered_macs as f64;
            num += l.macs as f64 * u;
            den += l.macs as f64;
        }
        if den == 0.0 {
            0.0
        } else {
            num / den
        }
    }

    /// Aggregate fill ratio (useful / offered MAC slots) — the harsher
    /// cycle-weighted alternative to [`Self::spatial_utilization`].
    pub fn spatial_utilization_offered(&self) -> f64 {
        let useful: u64 = self.layers.iter().map(|l| l.tiles.useful_macs).sum();
        let offered: u64 = self.layers.iter().map(|l| l.tiles.offered_macs).sum();
        if offered == 0 {
            0.0
        } else {
            useful as f64 / offered as f64
        }
    }

    /// Cycle-weighted temporal utilization (the Fig. 6b metric).
    pub fn temporal_utilization(&self) -> f64 {
        let active: u64 = self.layers.iter().map(|l| l.tiles.active_cycles).sum();
        let total: u64 = self.layers.iter().map(|l| l.tiles.total_cycles).sum();
        if total == 0 {
            0.0
        } else {
            active as f64 / total as f64
        }
    }

    pub fn bank_conflicts(&self) -> u64 {
        self.layers.iter().map(|l| l.tiles.bank_conflicts).sum()
    }
}

/// Geometric mean helper used by the Fig. 6 "geomean" bars.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-12).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_ratios() {
        let t = TileMetrics {
            total_cycles: 100,
            active_cycles: 80,
            useful_macs: 512 * 40,
            offered_macs: 512 * 80,
            ..Default::default()
        };
        assert!((t.temporal_utilization() - 0.8).abs() < 1e-12);
        assert!((t.spatial_utilization() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn add_scaled_multiplies() {
        let t = TileMetrics {
            total_cycles: 10,
            active_cycles: 8,
            useful_macs: 100,
            offered_macs: 200,
            bank_reads: 5,
            bank_writes: 3,
            bank_conflicts: 1,
            stall_cycles: 2,
            simd_cycles: 4,
            fifo_events: 7,
        };
        let mut acc = TileMetrics::default();
        acc.add_scaled(&t, 3);
        assert_eq!(acc.total_cycles, 30);
        assert_eq!(acc.fifo_events, 21);
    }

    #[test]
    fn geomean_of_equal_values() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cache_stats_hit_rate() {
        let s = CacheStats { hits: 0, misses: 0 };
        assert_eq!(s.hit_rate(), 0.0);
        let s = CacheStats { hits: 3, misses: 1 };
        assert_eq!(s.lookups(), 4);
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let w = WorkloadMetrics::default();
        assert_eq!(w.spatial_utilization(), 0.0);
        assert_eq!(w.temporal_utilization(), 0.0);
        assert_eq!(w.total_latency_cycles(), 0);
        assert_eq!(w.total_overlap_cycles(), 0);
    }
}
