//! The per-layer mapping search (DESIGN.md §11): choose how a GEMM is
//! *placed* on the array — M/N permutation plus K-extension dimension
//! folding — together with its tiling, under one cycle-domain objective.
//!
//! Before this module, the mapping and the tiling were chosen in two
//! unconnected places: `Mapping::choose` maximized spatial fill with no
//! view of cycles, and `choose_tiling` minimized off-chip traffic with
//! no view of array under-fill — the pattern FlexNN (arXiv 2403.09026)
//! and OpenGeMM (arXiv 2411.09543) both show costs real utilization on
//! ragged layers. Here every legal [`Mapping`] candidate is scored with
//! the tiling it induces:
//!
//! * **compute envelope** — the mapping's ideal active cycles
//!   ([`Mapping::ideal_active_cycles`]), inflated by the bank pressure
//!   its streamer demand puts on the shared memory: a step that needs
//!   more bank grants than the fabric has sustains less than one fire
//!   per cycle. Folded mappings are additionally surcharged a minimum
//!   9/8 pressure — their extra weight super-bank channels contend with
//!   the fine input channels even when the raw bank count fits, an
//!   arbitration cost the closed form cannot see (calibrated against
//!   the cycle engine; keeps marginal folds from winning on paper and
//!   losing on cycles);
//! * **DMA envelope** — the induced tiling's off-chip traffic
//!   ([`Tiling::traffic_bytes`], from `traffic_parts`) over the DMA
//!   bandwidth;
//! * the two combine as the pipeline would run them: `max` when the
//!   tiling ping-pongs (transfers hide behind compute), sum when it is
//!   single-buffered.
//!
//! Ties resolve toward the bandwidth-adjusted compute envelope, then
//! fewer ideal steps (= higher spatial utilization: all candidates
//! offer the same 512 MACs per step), then the smaller fold, then the
//! unswapped orientation, then less traffic — so the search never
//! returns lower spatial utilization than the legacy swap-only choice
//! (property-tested over every suite layer in `tests/mapper.rs`).
//!
//! Results are memoized in a sharded, process-wide [`MapperCache`]
//! keyed by `(mapper fingerprint, M, K, N)` — the fingerprint covers
//! the geometry, memory organisation and the cycle-model knobs the
//! search reads — sitting beside [`crate::plan::PlanCache`] so suites,
//! sweeps and `serve` threads resolve each distinct layer shape once
//! per process.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use crate::sync::{Rank, RwLock};

use crate::config::{ArrayGeometry, ChipConfig, MappingSearch, MemoryOrg};
use crate::coordinator::singleflight::{FlightGroup, Role};
use crate::metrics::CacheStats;
use crate::sim::gemm_core::Mapping;
use crate::tiling::engine::{choose_tiling, choose_tiling_mapped, Tiling};

/// A mapping resolved together with the tiling it induces.
pub type Resolved = (Mapping, Tiling);

/// Every legal mapping of a GEMM onto `geometry`: both permutations,
/// and for the 3D array every K-extension fold that divides the row
/// count (the 2D baseline has no spatial K axis to extend).
pub fn candidate_mappings(geometry: ArrayGeometry) -> Vec<Mapping> {
    let folds: Vec<u8> = match geometry {
        ArrayGeometry::Spatial3D { m, .. } => (1..=m.min(u8::MAX as usize))
            .filter(|f| m % f == 0)
            .map(|f| f as u8)
            .collect(),
        ArrayGeometry::Spatial2D { .. } => vec![1],
    };
    let mut out = Vec::with_capacity(2 * folds.len());
    for swapped in [false, true] {
        for &fold in &folds {
            out.push(Mapping {
                geometry,
                swapped,
                fold,
            });
        }
    }
    out
}

/// Bank grants one compute step demands from the shared fabric under
/// `mapping` (input words + weight banks), with the folded-mapping
/// contention surcharge applied (see module docs). Shared with the
/// static verifier ([`crate::plan::verify`], rule `stream-demand-bounds`)
/// as the single bank-pressure authority.
pub(crate) fn banks_per_step(cfg: &ChipConfig, mapping: &Mapping) -> u64 {
    let bps = match cfg.array {
        // Input words per step (um * uk = m * k values, fold-invariant)
        // plus the folded weight fetch (un * uk = n * k * fold values,
        // one bank per 8-byte word): 8 + 8 * fold on the 8x8x8 chip.
        ArrayGeometry::Spatial3D { m, n, k } => {
            let f = mapping.fold.max(1) as u64;
            let (m, n, k) = (m as u64, n as u64, k as u64);
            (m * k).div_ceil(8).max(1) + (n * k * f).div_ceil(8).max(1)
        }
        ArrayGeometry::Spatial2D { m, n } => {
            let (ua_m, ua_n) = if mapping.swapped {
                (n as u64, m as u64)
            } else {
                (m as u64, n as u64)
            };
            ua_m.div_ceil(8).max(1) + ua_n.div_ceil(8).max(1)
        }
    };
    if mapping.fold > 1 {
        let nb = cfg.num_banks as u64;
        bps.max(nb + nb / 8)
    } else {
        bps
    }
}

/// The cycle-domain score of one candidate: `(score, compute envelope,
/// ideal steps, fold, swapped, traffic)`, compared lexicographically —
/// smaller is better.
type ScoreKey = (u64, u64, u64, u8, u8, u64);

fn score(cfg: &ChipConfig, mapping: &Mapping, tiling: &Tiling, m: u64, k: u64, n: u64) -> ScoreKey {
    let steps = mapping.ideal_active_cycles(m, k, n);
    let nb = (cfg.num_banks as u64).max(1);
    let compute_env = steps.max((steps * banks_per_step(cfg, mapping)).div_ceil(nb));
    let dma_env = tiling.traffic_bytes.div_ceil(cfg.dma_bytes_per_cycle.max(1));
    let total = if tiling.double_buffered {
        compute_env.max(dma_env)
    } else {
        compute_env + dma_env
    };
    (
        total,
        compute_env,
        steps,
        mapping.fold,
        mapping.swapped as u8,
        tiling.traffic_bytes,
    )
}

/// Score one candidate mapping: orient the GEMM onto the array (the row
/// side carries logical M, or N when swapped), tile with the mapped
/// unrolls, and attach the [`ScoreKey`]. `None` when no tiling fits.
fn evaluate(
    cfg: &ChipConfig,
    mapping: Mapping,
    m: u64,
    k: u64,
    n: u64,
) -> Option<(ScoreKey, Resolved)> {
    let (um, un, _) = mapping.array_dims();
    let (pm, pn) = if mapping.swapped { (n, m) } else { (m, n) };
    let (ua_m, ua_n) = if mapping.swapped { (un, um) } else { (um, un) };
    let tiling = choose_tiling_mapped(cfg, ua_m, ua_n, pm, k, pn)?;
    let key = score(cfg, &mapping, &tiling, m, k, n);
    Some((key, (mapping, tiling)))
}

/// Search the mapping space for GEMM `(m, k, n)` under `cfg`, returning
/// the winning mapping with its induced tiling. `None` only when no
/// tiling fits the memory organisation (never for the shipped presets).
///
/// Under [`MappingSearch::SwapOnly`] this reproduces the legacy model
/// exactly: the permutation-only choice, tiled with the raw geometry.
pub fn search(cfg: &ChipConfig, m: u64, k: u64, n: u64) -> Option<Resolved> {
    search_seeded(cfg, m, k, n, None)
}

/// [`search`] seeded with a hint mapping (typically the winner of an
/// adjacent layer shape in the same workload). Returns the *identical*
/// result to the unseeded search — the seeding is purely a pruning
/// accelerator, never a heuristic:
///
/// * the hint is evaluated first (tiling search included), establishing
///   an incumbent [`ScoreKey`] before the candidate sweep;
/// * each candidate's tiling-free compute envelope is a lower bound on
///   the first component of its eventual key (`total ≥ compute_env`
///   whether the envelopes combine by `max` or by sum), so a candidate
///   whose envelope strictly exceeds the incumbent's total can be
///   skipped without running its tiling search;
/// * distinct candidates can never tie on the full key — it ends in
///   `(fold, swapped, …)` which identifies the candidate — so the
///   minimum is unique and evaluation order (hint first, possibly
///   re-evaluating the hint inside the sweep) cannot change the winner.
pub fn search_seeded(
    cfg: &ChipConfig,
    m: u64,
    k: u64,
    n: u64,
    hint: Option<Mapping>,
) -> Option<Resolved> {
    if cfg.mapping == MappingSearch::SwapOnly {
        let mapping = Mapping::swap_only(cfg.array, m, n);
        let (pm, pn) = if mapping.swapped { (n, m) } else { (m, n) };
        let tiling = choose_tiling(cfg, pm, k, pn)?;
        return Some((mapping, tiling));
    }
    let mut best: Option<(ScoreKey, Resolved)> = None;
    if let Some(hint) = hint {
        if hint.geometry == cfg.array {
            best = evaluate(cfg, hint, m, k, n);
        }
    }
    let nb = (cfg.num_banks as u64).max(1);
    for mapping in candidate_mappings(cfg.array) {
        if let Some((bk, _)) = &best {
            // Tiling-free lower bound on the candidate's score: strictly
            // above the incumbent total ⇒ it cannot win; skip the
            // expensive tiling enumeration.
            let steps = mapping.ideal_active_cycles(m, k, n);
            let env = steps.max((steps * banks_per_step(cfg, &mapping)).div_ceil(nb));
            if env > bk.0 {
                continue;
            }
        }
        if let Some(cand) = evaluate(cfg, mapping, m, k, n) {
            match &best {
                Some((bk, _)) if cand.0 >= *bk => {}
                _ => best = Some(cand),
            }
        }
    }
    best.map(|(_, r)| r)
}

/// Fingerprint of every config field the mapping search reads: the
/// geometry, the memory organisation (tiling feasibility), the bank
/// count (bank-pressure term), the DMA bandwidth (DMA envelope), the
/// double-buffer grant (score combination) and the search mode itself.
/// Deliberately narrower than the plan fingerprint — prefetch depth,
/// SIMD width, latencies and the operating point do not change the
/// search, so e.g. the `no-prefetch` ablation shares mapper entries
/// with the full chip.
pub fn fingerprint(cfg: &ChipConfig) -> u64 {
    let mut h = DefaultHasher::new();
    match cfg.array {
        ArrayGeometry::Spatial3D { m, n, k } => {
            0u8.hash(&mut h);
            (m, n, k).hash(&mut h);
        }
        ArrayGeometry::Spatial2D { m, n } => {
            1u8.hash(&mut h);
            (m, n).hash(&mut h);
        }
    }
    match cfg.memory {
        MemoryOrg::Shared => 0u8.hash(&mut h),
        MemoryOrg::Separated {
            input,
            weight,
            output,
            psum,
        } => {
            1u8.hash(&mut h);
            (input, weight, output, psum).hash(&mut h);
        }
    }
    cfg.num_banks.hash(&mut h);
    cfg.dma_bytes_per_cycle.hash(&mut h);
    cfg.double_buffer.hash(&mut h);
    cfg.mapping.hash(&mut h);
    h.finish()
}

/// Shard count: mapper entries are tiny and layer-shape keyed; sixteen
/// shards keep sweep threads and serve connections off each other's
/// locks (same sizing as the coordinator's tile cache).
const MAPPER_SHARDS: usize = 16;

type MapKey = (u64, u64, u64, u64);

fn shard_of<K: Hash>(key: &K) -> usize {
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() as usize) % MAPPER_SHARDS
}

/// Sharded, thread-safe memoization of [`search`] keyed by
/// `(fingerprint, M, K, N)`. One process-wide instance serves every
/// cache/plan/serve path via [`MapperCache::global`]; fresh instances
/// exist only for cold-path benchmarking and tests.
///
/// Misses are single-flighted (DESIGN.md §14, same protocol as the
/// plan and tile tiers): a search herd hitting one hot GEMM shape runs
/// the mapping search exactly once — the first caller leads, everyone
/// else blocks on that search and shares its result, counted in
/// `coalesced`. The invariant `hits + misses + coalesced == calls`
/// holds for every interleaving.
pub struct MapperCache {
    shards: [RwLock<HashMap<MapKey, Option<Resolved>>>; MAPPER_SHARDS],
    /// In-flight searches: one searcher per key, everyone else waits.
    flights: FlightGroup<MapKey, Option<Resolved>>,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
}

impl Default for MapperCache {
    fn default() -> Self {
        MapperCache {
            shards: std::array::from_fn(|_| RwLock::new(Rank::MapperShard, HashMap::new())),
            flights: FlightGroup::default(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
        }
    }
}

impl MapperCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The process-wide instance: every distinct layer shape is
    /// searched once per process, whatever thread or cache asks.
    pub fn global() -> &'static MapperCache {
        static GLOBAL: OnceLock<MapperCache> = OnceLock::new();
        GLOBAL.get_or_init(MapperCache::new)
    }

    /// Memoized [`search`], callable from any thread. Misses search
    /// outside any lock and single-flighted: concurrent callers for
    /// one cold key block on the leader's search and share its result.
    pub fn resolve(&self, cfg: &ChipConfig, m: u64, k: u64, n: u64) -> Option<Resolved> {
        self.resolve_seeded(cfg, m, k, n, None)
    }

    /// [`MapperCache::resolve`] with a seed mapping forwarded to
    /// [`search_seeded`] on a miss. Cache contents are hint-independent
    /// (the seeded search returns the identical winner), so hits,
    /// seeded misses and coalesced waits interleave safely across
    /// threads — whichever caller leads the flight, the published
    /// value is the canonical one.
    pub fn resolve_seeded(
        &self,
        cfg: &ChipConfig,
        m: u64,
        k: u64,
        n: u64,
        hint: Option<Mapping>,
    ) -> Option<Resolved> {
        let key: MapKey = (fingerprint(cfg), m, k, n);
        let shard = &self.shards[shard_of(&key)];
        loop {
            if let Some(v) = shard.read().get(&key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return *v;
            }
            match self.flights.join(&key, || {
                self.coalesced.fetch_add(1, Ordering::Relaxed);
            }) {
                Role::Leader(lead) => {
                    // A racing leader may have published and retired its
                    // flight between our shard read and our join.
                    if let Some(v) = shard.read().get(&key) {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        lead.publish(*v);
                        return *v;
                    }
                    let v = search_seeded(cfg, m, k, n, hint);
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    // First insert wins (leaders of retried flights
                    // agree anyway — the search is pure).
                    let canonical = *shard.write().entry(key).or_insert(v);
                    lead.publish(canonical);
                    return canonical;
                }
                Role::Waited(Some(v)) => return v,
                Role::Waited(None) => continue,
            }
        }
    }

    /// Distinct layer shapes resolved so far (across all shards).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hit/miss counters since construction.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Calls that blocked on another thread's in-flight search and
    /// shared its result instead of searching themselves (the STATS
    /// verb's `mapper_waits`).
    pub fn coalesced_waits(&self) -> u64 {
        self.coalesced.load(Ordering::Relaxed)
    }
}

/// Resolve the mapping + tiling for one GEMM through the process-wide
/// [`MapperCache`] — the planner's entry point.
pub fn resolve(cfg: &ChipConfig, m: u64, k: u64, n: u64) -> Option<Resolved> {
    MapperCache::global().resolve(cfg, m, k, n)
}

/// A mapper handle that remembers the last winning [`Mapping`] and seeds
/// the next resolution with it (DESIGN.md §12). Adjacent layer shapes
/// within one workload overwhelmingly share their winner — transformer
/// blocks repeat three or four GEMM shapes, ResNet stages drift slowly
/// in (M, K, N) — so the seeded search usually establishes a tight
/// incumbent on its first evaluation and prunes most of the remaining
/// candidates' tiling enumerations.
///
/// Purely an accelerator: results are bit-identical to the unseeded
/// search (see [`search_seeded`]), so per-worker instances with
/// different traversal orders still produce one canonical plan.
pub struct IncrementalMapper<'a> {
    cache: &'a MapperCache,
    hint: Option<Mapping>,
}

impl<'a> IncrementalMapper<'a> {
    pub fn new(cache: &'a MapperCache) -> Self {
        IncrementalMapper { cache, hint: None }
    }

    /// An incremental view of the process-wide cache.
    pub fn global() -> IncrementalMapper<'static> {
        IncrementalMapper::new(MapperCache::global())
    }

    /// Memoized seeded resolution; updates the hint from the winner
    /// (cache hits included — a hit is still the shape's true winner
    /// and the best available seed for the next shape).
    pub fn resolve(&mut self, cfg: &ChipConfig, m: u64, k: u64, n: u64) -> Option<Resolved> {
        let r = self.cache.resolve_seeded(cfg, m, k, n, self.hint);
        if let Some((mapping, _)) = r {
            self.hint = Some(mapping);
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_cover_permutations_and_folds() {
        let c3 = candidate_mappings(ChipConfig::voltra().array);
        // 2 permutations x folds {1, 2, 4, 8}.
        assert_eq!(c3.len(), 8);
        assert!(c3.iter().any(|m| m.fold == 8 && !m.swapped));
        let c2 = candidate_mappings(ChipConfig::array2d().array);
        assert_eq!(c2.len(), 2);
        assert!(c2.iter().all(|m| m.fold == 1));
    }

    #[test]
    fn swap_only_mode_reproduces_the_legacy_choice() {
        let cfg = ChipConfig::swap_only();
        let (mapping, tiling) = search(&cfg, 512, 768, 3072).unwrap();
        assert_eq!(mapping.fold, 1);
        assert!(!mapping.swapped);
        let legacy = choose_tiling(&cfg, 512, 768, 3072).unwrap();
        assert_eq!(tiling, legacy);
    }

    #[test]
    fn gemv_folds_all_rows_onto_k() {
        // M = 1 on the 8x8x8 array: the search must K-extend instead of
        // idling 7 of 8 rows (12.5% fill).
        let cfg = ChipConfig::voltra();
        let (mapping, _) = search(&cfg, 1, 3072, 3072).unwrap();
        assert_eq!(mapping.fold, 8, "GEMV must fold fully: {mapping:?}");
        assert!(mapping.spatial_utilization(1, 3072, 3072) > 0.99);
    }

    #[test]
    fn aligned_gemm_keeps_the_identity_mapping() {
        // Nothing to gain: folding only costs weight bandwidth.
        let cfg = ChipConfig::voltra();
        let (mapping, _) = search(&cfg, 512, 768, 768).unwrap();
        assert_eq!(mapping.fold, 1);
        assert!(!mapping.swapped);
    }

    #[test]
    fn marginal_folds_lose_to_the_contention_surcharge() {
        // M = 196 (14x14 feature map): fold 2 shaves ~2% of the ideal
        // steps but costs real arbitration cycles — the surcharge must
        // keep the identity mapping.
        let cfg = ChipConfig::voltra();
        let (mapping, _) = search(&cfg, 196, 512, 256).unwrap();
        assert_eq!(mapping.fold, 1, "marginal fold must not win: {mapping:?}");
    }

    #[test]
    fn global_cache_memoizes_across_calls() {
        let cache = MapperCache::new();
        let cfg = ChipConfig::voltra();
        let a = cache.resolve(&cfg, 64, 64, 64);
        let b = cache.resolve(&cfg, 64, 64, 64);
        assert_eq!(a, b);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn herd_at_one_cold_shape_searches_exactly_once() {
        // The single-flight acceptance invariant, mapper tier: N
        // concurrent resolvers at one cold key produce exactly one
        // search (misses == 1); every other call either coalesced onto
        // the in-flight leader or hit the shard afterward.
        const HERD: u64 = 16;
        let cache = MapperCache::new();
        let cfg = ChipConfig::voltra();
        let canonical = search(&cfg, 192, 768, 768);
        std::thread::scope(|s| {
            for _ in 0..HERD {
                s.spawn(|| {
                    assert_eq!(cache.resolve(&cfg, 192, 768, 768), canonical);
                });
            }
        });
        let st = cache.stats();
        assert_eq!(st.misses, 1, "herd must search once");
        assert_eq!(st.hits + st.misses + cache.coalesced_waits(), HERD);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn fingerprint_splits_modes_and_geometries_not_prefetch() {
        let v = fingerprint(&ChipConfig::voltra());
        assert_ne!(v, fingerprint(&ChipConfig::swap_only()));
        assert_ne!(v, fingerprint(&ChipConfig::array2d()));
        assert_ne!(v, fingerprint(&ChipConfig::separated_memory()));
        // The search never reads the prefetch knob: the ablation shares
        // mapper entries with the full chip.
        assert_eq!(v, fingerprint(&ChipConfig::no_prefetch()));
    }

    #[test]
    fn resolved_search_is_deterministic() {
        let cfg = ChipConfig::voltra();
        for (m, k, n) in [(1, 128, 256), (6, 3072, 3072), (49, 4608, 512), (196, 64, 384)] {
            assert_eq!(search(&cfg, m, k, n), search(&cfg, m, k, n));
        }
    }

    #[test]
    fn seeded_search_matches_canonical_for_every_hint() {
        // The seeding must be a pure accelerator: whatever mapping is
        // offered as the hint — right, wrong, or geometry-mismatched —
        // the winner is the canonical one.
        for cfg in [ChipConfig::voltra(), ChipConfig::array2d()] {
            for (m, k, n) in [(1, 3072, 3072), (196, 512, 256), (512, 768, 768), (7, 7, 7)] {
                let canonical = search(&cfg, m, k, n);
                assert_eq!(search_seeded(&cfg, m, k, n, None), canonical);
                for hint in candidate_mappings(ChipConfig::voltra().array) {
                    assert_eq!(
                        search_seeded(&cfg, m, k, n, Some(hint)),
                        canonical,
                        "hint {hint:?} changed the winner for ({m},{k},{n})"
                    );
                }
            }
        }
    }

    #[test]
    fn incremental_mapper_agrees_with_plain_resolution() {
        // Walk a ResNet-ish shape drift through one incremental handle;
        // every resolution must equal the unseeded search, and the cache
        // must fill exactly once per distinct shape.
        let cache = MapperCache::new();
        let cfg = ChipConfig::voltra();
        let mut inc = IncrementalMapper::new(&cache);
        let shapes = [
            (3136u64, 64u64, 64u64),
            (3136, 576, 64),
            (784, 128, 128),
            (784, 1152, 128),
            (3136, 64, 64), // revisit: cache hit, hint still updates
        ];
        for &(m, k, n) in &shapes {
            assert_eq!(inc.resolve(&cfg, m, k, n), search(&cfg, m, k, n));
        }
        assert_eq!(cache.len(), 4);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 4));
    }
}
