//! On-chip memory allocation: PDMA's dynamic shared-space carving vs the
//! separated fixed-buffer baseline (Sec. II-C, Fig. 1).
//!
//! The shared organisation lets one layer give almost the whole 128 KiB
//! to whatever operand mix it needs (and re-partition per layer via
//! streamer base pointers); the separated organisation must fit each
//! operand class inside its dedicated buffer — "the tiling strategy must
//! conform to the size of the smallest buffer".

use crate::arch::{BANK_WIDTH_BYTES, SUPER_BANK_BANKS};
use crate::config::MemoryOrg;

/// Operand classes as the chip's streamers see them.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Operand {
    Input,
    Weight,
    Psum,
    Output,
}

/// Byte footprint of one tile residency (already including double
/// buffering where requested).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Footprint {
    pub input: usize,
    pub weight: usize,
    pub psum: usize,
    pub output: usize,
}

impl Footprint {
    pub fn total(&self) -> usize {
        self.input + self.weight + self.psum + self.output
    }
}

/// A concrete placement: word base addresses for each operand region.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Placement {
    pub input_base: u64,
    pub weight_base: u64,
    pub psum_base: u64,
    pub output_base: u64,
}

/// Does `fp` fit this memory organisation?
pub fn fits(org: &MemoryOrg, fp: &Footprint) -> bool {
    match *org {
        MemoryOrg::Shared => fp.total() <= org.total_bytes(),
        MemoryOrg::Separated {
            input,
            weight,
            output,
            psum,
        } => fp.input <= input && fp.weight <= weight && fp.psum <= psum && fp.output <= output,
    }
}

/// Place the regions. Shared memory packs them back-to-back (the PDMA
/// base pointers land wherever the allocator cursor is — this is what
/// makes the bank alignment of concurrent streams workload-dependent);
/// separated memory has fixed per-class bases.
pub fn place(org: &MemoryOrg, fp: &Footprint) -> Option<Placement> {
    if !fits(org, fp) {
        return None;
    }
    let wpb = BANK_WIDTH_BYTES; // bytes per word
    let align = |b: usize| -> u64 {
        // Super-bank alignment: weight regions must start on an 8-word
        // boundary so 512-bit accesses hit one aligned group.
        (b.div_ceil(wpb * SUPER_BANK_BANKS) * SUPER_BANK_BANKS) as u64
    };
    match *org {
        MemoryOrg::Shared => {
            let input_base = 0u64;
            let weight_base = align(fp.input);
            let psum_base = weight_base + align(fp.weight);
            let output_base = psum_base + align(fp.psum);
            Some(Placement {
                input_base,
                weight_base,
                psum_base,
                output_base,
            })
        }
        MemoryOrg::Separated { input, weight, psum, .. } => {
            // Dedicated SRAMs: model as disjoint address spaces laid out
            // consecutively (their bank conflicts are already suppressed
            // by the engine's separate-ports mode).
            let input_base = 0u64;
            let weight_base = align(input);
            let psum_base = weight_base + align(weight);
            let output_base = psum_base + align(psum);
            Some(Placement {
                input_base,
                weight_base,
                psum_base,
                output_base,
            })
        }
    }
}

/// Largest shared-memory share a single operand may claim under PDMA
/// (everything minus one super-bank row for each other operand).
pub fn max_operand_bytes(org: &MemoryOrg, op: Operand) -> usize {
    match *org {
        MemoryOrg::Shared => org.total_bytes(),
        MemoryOrg::Separated {
            input,
            weight,
            output,
            psum,
        } => match op {
            Operand::Input => input,
            Operand::Weight => weight,
            Operand::Psum => psum,
            Operand::Output => output,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::DATA_MEM_BYTES;

    fn fp(i: usize, w: usize, p: usize, o: usize) -> Footprint {
        Footprint {
            input: i,
            weight: w,
            psum: p,
            output: o,
        }
    }

    #[test]
    fn shared_fits_any_mix_up_to_capacity() {
        let org = MemoryOrg::Shared;
        assert!(fits(&org, &fp(100 * 1024, 20 * 1024, 4 * 1024, 4 * 1024)));
        assert!(fits(&org, &fp(4 * 1024, 120 * 1024, 2 * 1024, 2 * 1024)));
        assert!(!fits(&org, &fp(100 * 1024, 30 * 1024, 0, 0)));
    }

    #[test]
    fn separated_is_capped_per_class() {
        let org = MemoryOrg::separated_default();
        // Fits in total but not in the weight buffer.
        let f = fp(10 * 1024, 100 * 1024, 1024, 1024);
        assert!(f.total() <= DATA_MEM_BYTES);
        assert!(!fits(&org, &f));
        // The same total, balanced: fits.
        assert!(fits(&org, &fp(36 * 1024, 50 * 1024, 4 * 1024, 20 * 1024)));
    }

    #[test]
    fn placement_is_disjoint_and_aligned() {
        let org = MemoryOrg::Shared;
        let f = fp(1000, 2000, 512, 256);
        let p = place(&org, &f).unwrap();
        assert_eq!(p.input_base, 0);
        assert_eq!(p.weight_base % 8, 0, "weight base must be super-bank aligned");
        assert!(p.weight_base as usize * 8 >= f.input);
        assert!(p.psum_base > p.weight_base);
        assert!(p.output_base > p.psum_base);
    }

    #[test]
    fn overfull_returns_none() {
        let f = fp(DATA_MEM_BYTES, 8, 8, 8);
        assert_eq!(place(&MemoryOrg::Shared, &f), None);
    }

    #[test]
    fn pdma_lets_one_operand_take_everything() {
        assert_eq!(
            max_operand_bytes(&MemoryOrg::Shared, Operand::Weight),
            DATA_MEM_BYTES
        );
        let sep = MemoryOrg::separated_default();
        assert!(max_operand_bytes(&sep, Operand::Weight) < DATA_MEM_BYTES / 2);
    }
}
