//! Layer-wise tiling engine (Sec. III-A: "we apply layer-wise tiling,
//! where each layer is partitioned to fully exploit the GEMM core's
//! output-stationary dataflow", following ZigZag [22]).
//!
//! For a GEMM (M, K, N) and a memory organisation, enumerate tile sizes
//! (tm, tk, tn), keep those whose residency fits the allocator, and pick
//! the one minimizing off-chip traffic. This is exactly where PDMA wins:
//! a shared space admits larger, better-balanced tiles than fixed
//! per-operand buffers, cutting DMA traffic 1.15-2.36x (Fig. 6c).

use crate::config::{ArrayGeometry, ChipConfig};
use crate::tiling::allocator::{fits, place, Footprint, Placement};

/// A chosen tiling for one GEMM layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Tiling {
    pub tm: u64,
    pub tk: u64,
    pub tn: u64,
    /// Off-chip bytes moved for the whole layer under this tiling.
    pub traffic_bytes: u64,
    /// Whether in/weight tiles are double-buffered (DMA overlaps compute).
    pub double_buffered: bool,
    pub footprint: Footprint,
    pub placement: Placement,
}

impl Tiling {
    /// K accumulation rounds this tiling needs for a reduction dim `k` —
    /// what decides psum-in/spill-out variants. (The planner derives M/N
    /// round structure from its own edge decomposition, so the old
    /// `rounds()` triple — whose M/N counts every caller discarded — is
    /// gone.)
    pub fn k_rounds(&self, k: u64) -> u64 {
        k.div_ceil(self.tk)
    }
}

/// Candidate tile sizes: multiples of 8 on a coarse ladder + the full dim.
fn candidates(dim: u64) -> Vec<u64> {
    let ladder = [
        8u64, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512, 768, 1024, 1536, 2048, 3072,
        4096, 8192,
    ];
    let mut v: Vec<u64> = ladder.iter().copied().filter(|&t| t < dim).collect();
    v.push(dim);
    v
}

/// Per-operand off-chip traffic (bytes) for a tiling of GEMM (M, K, N).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TrafficParts {
    pub input: u64,
    pub weight: u64,
    pub psum: u64,
    pub output: u64,
}

impl TrafficParts {
    pub fn total(&self) -> u64 {
        self.input + self.weight + self.psum + self.output
    }
}

/// Off-chip traffic split by operand; see [`traffic_bytes`].
pub fn traffic_parts(m: u64, k: u64, n: u64, tm: u64, tk: u64, tn: u64) -> TrafficParts {
    let nm = m.div_ceil(tm);
    let nk = k.div_ceil(tk);
    let nn = n.div_ceil(tn);
    let in_bytes;
    let w_bytes;
    if nk == 1 {
        // Output-stationary sweep with a resident strip: the better of
        // keeping the input strip (loop n inner) or the weight strip
        // (loop m inner) resident across the inner loop.
        let in_if_m_outer = m * k; // input tile constant per mi
        let w_if_m_outer = k * n * nm;
        let in_if_n_outer = m * k * nn;
        let w_if_n_outer = k * n;
        if in_if_m_outer + w_if_m_outer <= in_if_n_outer + w_if_n_outer {
            in_bytes = in_if_m_outer;
            w_bytes = w_if_m_outer;
        } else {
            in_bytes = in_if_n_outer;
            w_bytes = w_if_n_outer;
        }
    } else {
        // K tiled: every (mi, ni) revisit reloads both operand tiles and
        // round-trips int32 partial sums (nk - 1) times.
        in_bytes = m * k * nn;
        w_bytes = k * n * nm;
    }
    let psum_spill = if nk > 1 { 2 * 4 * m * n * (nk - 1) } else { 0 };
    TrafficParts {
        input: in_bytes,
        weight: w_bytes,
        psum: psum_spill,
        output: m * n, // final int8 results
    }
}

/// Off-chip traffic (bytes) for a tiling of GEMM (M, K, N), INT8 in/out,
/// INT32 spilled partial sums. See DESIGN.md §7 for the reuse model.
pub fn traffic_bytes(m: u64, k: u64, n: u64, tm: u64, tk: u64, tn: u64) -> u64 {
    traffic_parts(m, k, n, tm, tk, tn).total()
}

/// Tile residency footprint in bytes (INT8 operands, INT32 psums).
pub fn footprint(tm: u64, tk: u64, tn: u64, k_tiled: bool, double_buffer: bool) -> Footprint {
    let db = if double_buffer { 2 } else { 1 };
    Footprint {
        input: (tm * tk) as usize * db,
        weight: (tk * tn) as usize * db,
        psum: if k_tiled { (4 * tm * tn) as usize } else { 0 },
        output: (tm * tn) as usize,
    }
}

/// Choose the minimum-traffic tiling that fits the memory organisation,
/// with tile minima taken from the raw array geometry (the unfolded
/// mapping). The mapper's searched path goes through
/// [`choose_tiling_mapped`] with the mapping's effective unrolls.
pub fn choose_tiling(cfg: &ChipConfig, m: u64, k: u64, n: u64) -> Option<Tiling> {
    let (am, an) = match cfg.array {
        ArrayGeometry::Spatial3D { m, n, .. } => (m as u64, n as u64),
        ArrayGeometry::Spatial2D { m, n } => (m as u64, n as u64),
    };
    choose_tiling_mapped(cfg, am, an, m, k, n)
}

/// Choose the minimum-traffic tiling that fits the memory organisation,
/// for a GEMM already oriented onto the array (`m` rides the row axis).
///
/// `um`/`un` are the mapped array unrolls: tiles must not under-fill the
/// spatial array — a tile narrower than the unroll wastes lanes in
/// *every* cycle, which no mapper would choose (unless the layer
/// dimension itself is smaller). A folded mapping lowers the row-axis
/// minimum, widening the search space.
///
/// Preference order: less traffic, then double-buffered (the DMA
/// overlap), then fewer tile launches, then larger `tk` (deeper
/// output-stationary accumulation — the chip's own bias, Fig. 7d).
pub fn choose_tiling_mapped(
    cfg: &ChipConfig,
    um: u64,
    un: u64,
    m: u64,
    k: u64,
    n: u64,
) -> Option<Tiling> {
    let tm_min = um.min(m);
    let tn_min = un.min(n);
    // Buffering options, deduplicated: with double buffering disabled
    // the old `[cfg.double_buffer, false]` pair degenerated to
    // `[false, false]` and probed every non-fitting footprint twice.
    let buffering: &[bool] = if cfg.double_buffer {
        &[true, false]
    } else {
        &[false]
    };
    let mut best: Option<Tiling> = None;
    for &tk in &candidates(k) {
        for &tm in &candidates(m) {
            if tm < tm_min {
                continue;
            }
            for &tn in &candidates(n) {
                if tn < tn_min {
                    continue;
                }
                let k_tiled = tk < k;
                // Try double-buffered first (overlap), fall back to single.
                for &db in buffering {
                    let fp = footprint(tm, tk, tn, k_tiled, db);
                    if !fits(&cfg.memory, &fp) {
                        continue;
                    }
                    let traffic = traffic_bytes(m, k, n, tm, tk, tn);
                    let ntiles = m.div_ceil(tm) * k.div_ceil(tk) * n.div_ceil(tn);
                    let cand = Tiling {
                        tm,
                        tk,
                        tn,
                        traffic_bytes: traffic,
                        double_buffered: db,
                        footprint: fp,
                        placement: place(&cfg.memory, &fp).unwrap(),
                    };
                    let better = match &best {
                        None => true,
                        Some(b) => {
                            let b_tiles = m.div_ceil(b.tm) * k.div_ceil(b.tk) * n.div_ceil(b.tn);
                            // Less traffic, then keep the DMA overlapped
                            // (double buffering hides the whole transfer),
                            // then fewer tile launches, then deeper K.
                            (traffic, std::cmp::Reverse(db), ntiles, std::cmp::Reverse(tk))
                                < (b.traffic_bytes, std::cmp::Reverse(b.double_buffered),
                                   b_tiles, std::cmp::Reverse(b.tk))
                        }
                    };
                    if better {
                        best = Some(cand);
                    }
                    break; // db=true fit; no need to try single-buffered
                }
            }
        }
    }
    best
}

/// Lower bound on traffic: every operand moved exactly once.
pub fn compulsory_traffic(m: u64, k: u64, n: u64) -> u64 {
    m * k + k * n + m * n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    #[test]
    fn small_layer_runs_untiled() {
        let cfg = ChipConfig::voltra();
        let t = choose_tiling(&cfg, 96, 96, 96).unwrap();
        assert_eq!((t.tm, t.tk, t.tn), (96, 96, 96));
        assert_eq!(t.traffic_bytes, compulsory_traffic(96, 96, 96));
        assert!(t.double_buffered);
    }

    #[test]
    fn traffic_never_below_compulsory() {
        for (m, k, n) in [(64, 64, 64), (3136, 576, 64), (512, 768, 768), (1, 3072, 8192)] {
            for tm in [8u64, 64] {
                for tk in [8u64, 64] {
                    for tn in [8u64, 64] {
                        assert!(
                            traffic_bytes(m, k, n, tm.min(m), tk.min(k), tn.min(n))
                                >= compulsory_traffic(m, k, n),
                            "m={m} k={k} n={n}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn shared_beats_separated_on_traffic() {
        // A big BERT-ish GEMM: PDMA should find a lower-traffic tiling.
        let shared = choose_tiling(&ChipConfig::voltra(), 512, 768, 3072).unwrap();
        let sep = choose_tiling(&ChipConfig::separated_memory(), 512, 768, 3072).unwrap();
        assert!(
            shared.traffic_bytes <= sep.traffic_bytes,
            "shared {} vs separated {}",
            shared.traffic_bytes,
            sep.traffic_bytes
        );
    }

    #[test]
    fn footprint_fits_memory() {
        let cfg = ChipConfig::voltra();
        let t = choose_tiling(&cfg, 3136, 576, 256).unwrap();
        assert!(t.footprint.total() <= 128 * 1024);
    }

    #[test]
    fn k_tiling_adds_psum_buffer() {
        let fp = footprint(64, 64, 64, true, false);
        assert_eq!(fp.psum, 4 * 64 * 64);
        let fp2 = footprint(64, 64, 64, false, false);
        assert_eq!(fp2.psum, 0);
    }

    #[test]
    fn single_buffer_fallback_survives_a_double_buffer_config() {
        // Regression companion to the `[cfg.double_buffer, false]`
        // dedupe: under a double-buffer config, a GEMM whose best
        // tiling only fits single-buffered must still be found via the
        // per-candidate fallback.
        let cfg = ChipConfig::voltra();
        assert!(cfg.double_buffer);
        let t = choose_tiling(&cfg, 512, 768, 768).unwrap();
        assert!(
            !t.double_buffered,
            "fixture: 512x768x768 should not fit ping-pong in 128 KiB"
        );
        assert!(fits(&cfg.memory, &t.footprint));
        // And a config with double buffering off reaches the same
        // single-buffered answer through the deduplicated option list.
        let mut off = ChipConfig::voltra();
        off.double_buffer = false;
        assert_eq!(choose_tiling(&off, 512, 768, 768).unwrap(), t);
    }

    #[test]
    fn mapped_minima_follow_the_fold() {
        // A folded mapping lowers the row-axis tile minimum; the search
        // result stays legal for the mapped unrolls.
        let cfg = ChipConfig::voltra();
        let t = choose_tiling_mapped(&cfg, 1, 8, 1, 3072, 3072).unwrap();
        assert_eq!(t.tm, 1);
        assert!(t.tn >= 8);
        assert!(fits(&cfg.memory, &t.footprint));
    }

    #[test]
    fn tiny_gemv_tiles_trivially() {
        let cfg = ChipConfig::voltra();
        let t = choose_tiling(&cfg, 1, 3072, 3072).unwrap();
        assert!(t.tm == 1);
        assert!(t.traffic_bytes < 2 * compulsory_traffic(1, 3072, 3072));
    }

    #[test]
    fn huge_layer_still_tiles() {
        // ResNet50 conv2_x-ish: M = 3136, K = 576, N = 64.
        let cfg = ChipConfig::voltra();
        let t = choose_tiling(&cfg, 3136, 576, 64).unwrap();
        let ntiles = 3136u64.div_ceil(t.tm) * t.k_rounds(576) * 64u64.div_ceil(t.tn);
        assert!(ntiles > 1);
        assert!(t.footprint.total() <= 128 * 1024);
    }
}
