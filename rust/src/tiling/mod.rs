//! Tiling & on-chip memory allocation: the PDMA mechanism (Sec. II-C)
//! and the layer-wise tiling engine (Sec. III-A).

pub mod allocator;
pub mod engine;

pub use allocator::{fits, place, Footprint, Operand, Placement};
pub use engine::{choose_tiling, compulsory_traffic, traffic_bytes, Tiling};
