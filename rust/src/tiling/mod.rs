//! Tiling & on-chip memory allocation: the PDMA mechanism (Sec. II-C),
//! the layer-wise tiling engine (Sec. III-A) and the cycle-domain
//! mapping search that chooses how each GEMM sits on the array
//! (DESIGN.md §11).

pub mod allocator;
pub mod engine;
pub mod mapper;

pub use allocator::{fits, place, Footprint, Operand, Placement};
pub use engine::{choose_tiling, compulsory_traffic, traffic_bytes, Tiling};
pub use mapper::{IncrementalMapper, MapperCache};
