//! Tiled GEMM execution on the PJRT runtime: the functional twin of the
//! coordinator's timing model.
//!
//! Arbitrary (M, K, N) INT8 GEMMs are executed by dispatching the
//! `gemm64` artifact tile by tile, chaining partial sums through the
//! `acc` output exactly like the chip's psum streamer re-injects them.
//! This is the request path of the end-to-end examples: Rust + PJRT
//! only, Python never runs.

use anyhow::{bail, Result};

use crate::runtime::artifacts::ArtifactLib;

/// Default tile edge used by the tiled executor (the gemm64 artifact).
pub const TILE: usize = 64;
/// Larger tile used when the operands amortize it (the gemm128 artifact).
pub const TILE_BIG: usize = 128;

/// Row-major int32 matrix (values in int8 range on int8 paths).
#[derive(Clone, Debug, PartialEq)]
pub struct MatI32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i32>,
}

impl MatI32 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatI32 {
            rows,
            cols,
            data: vec![0; rows * cols],
        }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> i32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        MatI32 { rows, cols, data }
    }

    pub fn at(&self, r: usize, c: usize) -> i32 {
        self.data[r * self.cols + c]
    }

    /// Copy a `tile x tile` tile starting at (r0, c0), zero-padded.
    fn tile(&self, r0: usize, c0: usize, tile: usize) -> Vec<i32> {
        let mut t = vec![0i32; tile * tile];
        let rmax = (self.rows - r0).min(tile);
        let cmax = (self.cols - c0).min(tile);
        for r in 0..rmax {
            let src = (r0 + r) * self.cols + c0;
            t[r * tile..r * tile + cmax].copy_from_slice(&self.data[src..src + cmax]);
        }
        t
    }

    /// Write back a tile (cropping the padding).
    fn set_tile(&mut self, r0: usize, c0: usize, t: &[i32], tile: usize) {
        let rmax = (self.rows - r0).min(tile);
        let cmax = (self.cols - c0).min(tile);
        for r in 0..rmax {
            let dst = (r0 + r) * self.cols + c0;
            self.data[dst..dst + cmax].copy_from_slice(&t[r * tile..r * tile + cmax]);
        }
    }
}

fn lit_tile(t: &[i32], tile: usize) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(t).reshape(&[tile as i64, tile as i64])?)
}

/// `q = requant(psum + x @ w)`, `acc = psum + x @ w` for arbitrary
/// shapes, executed tile-by-tile on the `gemm64` artifact.
///
/// Returns (quantized, accumulator). All int8-path values must be within
/// [-128, 127]; the kernel truncates to int8 internally.
pub fn gemm_tiled(
    lib: &mut ArtifactLib,
    x: &MatI32,
    w: &MatI32,
    psum: &MatI32,
    scale: f32,
) -> Result<(MatI32, MatI32)> {
    if x.cols != w.rows || psum.rows != x.rows || psum.cols != w.cols {
        bail!(
            "shape mismatch: x {}x{}, w {}x{}, psum {}x{}",
            x.rows,
            x.cols,
            w.rows,
            w.cols,
            psum.rows,
            psum.cols
        );
    }
    let (m, k, n) = (x.rows, x.cols, w.cols);
    // §Perf note: a 128-edge artifact (gemm128) was evaluated to cut the
    // number of PJRT dispatches 4x, but the interpret-lowered Pallas
    // while-loop costs more per byte at that block size and the padding
    // waste grows — the 64-edge tile measured fastest end-to-end (see
    // EXPERIMENTS.md §Perf, iterations 3-4). Kept available for callers
    // who batch very large aligned GEMMs.
    let (tile, art) = (TILE, "gemm64");
    let scale_lit = xla::Literal::vec1(&[scale]);
    let mut q = MatI32::zeros(m, n);
    let mut acc_out = MatI32::zeros(m, n);

    let mut mi = 0;
    while mi < m {
        let mut ni = 0;
        while ni < n {
            // Output-stationary accumulation over K tiles, psum-chained
            // exactly like the chip.
            let mut acc = psum.tile(mi, ni, tile);
            let mut q_tile = vec![0i32; tile * tile];
            let mut ki = 0;
            // §Perf iteration 5: an accumulate-only artifact for interior
            // K-rounds (skipping the requant epilogue) was measured and
            // REVERTED — the second executable's compile+dispatch overhead
            // outweighed the saved epilogue at this tile size.
            while ki < k {
                let xt = lit_tile(&x.tile(mi, ki, tile), tile)?;
                let wt = lit_tile(&w.tile(ki, ni, tile), tile)?;
                let pt = lit_tile(&acc, tile)?;
                let outs = lib.run(art, &[xt, wt, pt, scale_lit.clone()])?;
                q_tile = outs[0].to_vec::<i32>()?;
                acc = outs[1].to_vec::<i32>()?;
                ki += tile;
            }
            q.set_tile(mi, ni, &q_tile, tile);
            acc_out.set_tile(mi, ni, &acc, tile);
            ni += tile;
        }
        mi += tile;
    }
    Ok((q, acc_out))
}

/// Reference GEMM on the host for verification (int32 exact).
pub fn gemm_ref(x: &MatI32, w: &MatI32, psum: &MatI32) -> MatI32 {
    let mut out = MatI32::zeros(x.rows, w.cols);
    for r in 0..x.rows {
        for c in 0..w.cols {
            let mut s = psum.at(r, c) as i64;
            for i in 0..x.cols {
                s += x.at(r, i) as i64 * w.at(i, c) as i64;
            }
            out.data[r * w.cols + c] = s as i32;
        }
    }
    out
}

/// Host-side requantization oracle (matches kernels/quant.py + ref.py).
pub fn requant_ref(acc: &MatI32, scale: f32) -> MatI32 {
    let mut out = MatI32::zeros(acc.rows, acc.cols);
    for (o, &a) in out.data.iter_mut().zip(&acc.data) {
        let v = (a as f32 * scale).round_ties_even();
        *o = v.clamp(-128.0, 127.0) as i32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_tile_pads_and_crops() {
        let m = MatI32::from_fn(3, 5, |r, c| (r * 5 + c) as i32);
        let t = m.tile(0, 0, TILE);
        assert_eq!(t[0], 0);
        assert_eq!(t[4], 4);
        assert_eq!(t[5], 0, "padding must be zero");
        assert_eq!(t[TILE], 5, "second row starts at stride TILE");
        let mut back = MatI32::zeros(3, 5);
        back.set_tile(0, 0, &t, TILE);
        assert_eq!(back, m);
    }

    #[test]
    fn host_gemm_ref_small() {
        let x = MatI32::from_fn(2, 3, |r, c| (r + c) as i32);
        let w = MatI32::from_fn(3, 2, |r, c| (r as i32) - (c as i32));
        let p = MatI32::zeros(2, 2);
        let out = gemm_ref(&x, &w, &p);
        // row0 = [0,1,2] dot cols of w.
        assert_eq!(out.at(0, 0), 0 * 0 + 1 * 1 + 2 * 2);
        assert_eq!(out.at(0, 1), 0 * -1 + 1 * 0 + 2 * 1);
    }

    #[test]
    fn requant_ref_clamps() {
        let acc = MatI32 {
            rows: 1,
            cols: 4,
            data: vec![1000, -1000, 64, -64],
        };
        let q = requant_ref(&acc, 1.0);
        assert_eq!(q.data, vec![127, -128, 64, -64]);
    }
}
