//! The PJRT runtime: Rust loads the AOT-compiled HLO-text artifacts and
//! executes the chip's numerics directly — Python is build-time only.

pub mod artifacts;
pub mod backend;
pub mod executor;
pub mod json;
pub mod pool;

pub use artifacts::{default_dir, ArtifactLib, DType, TensorSpec};
pub use backend::{GemmBackend, HostBackend, PjrtBackend};
pub use executor::{gemm_ref, gemm_tiled, requant_ref, MatI32, TILE};
