//! Numerics backends for the serving engine: the single seam between
//! the coordinator's request path and whatever executes the GEMM.
//!
//! Two implementations:
//! * [`PjrtBackend`] — the AOT-artifact path ([`gemm_tiled`] over the
//!   PJRT client). Not `Send` in general (PJRT handles are pinned), so
//!   the server confines it to one dedicated worker thread.
//! * [`HostBackend`] — the bit-exact host oracle ([`gemm_ref`] +
//!   [`requant_ref`]). Always available; the serving engine falls back
//!   to it when artifacts are absent, and tests use it to exercise the
//!   full concurrent wire path deterministically. The two backends are
//!   interchangeable by construction: the runtime integration suite
//!   asserts the artifact path is bit-exact against exactly this oracle.

use std::path::Path;

use anyhow::{bail, Result};

use crate::runtime::artifacts::ArtifactLib;
use crate::runtime::executor::{gemm_ref, gemm_tiled, requant_ref, MatI32};

/// Executes `q = requant(psum + x @ w, scale)`, returning `(q, acc)`.
pub trait GemmBackend {
    /// Human-readable backend name for logs.
    fn name(&self) -> &'static str;

    fn gemm(
        &mut self,
        x: &MatI32,
        w: &MatI32,
        psum: &MatI32,
        scale: f32,
    ) -> Result<(MatI32, MatI32)>;
}

impl<B: GemmBackend + ?Sized> GemmBackend for Box<B> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn gemm(
        &mut self,
        x: &MatI32,
        w: &MatI32,
        psum: &MatI32,
        scale: f32,
    ) -> Result<(MatI32, MatI32)> {
        (**self).gemm(x, w, psum, scale)
    }
}

/// The real-numerics path: tiled dispatch onto the AOT artifacts.
pub struct PjrtBackend {
    lib: ArtifactLib,
}

impl PjrtBackend {
    pub fn new(lib: ArtifactLib) -> Self {
        PjrtBackend { lib }
    }

    /// Load the artifact library from `dir` (fails when `make artifacts`
    /// has not run or the PJRT runtime is unavailable).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        Ok(Self::new(ArtifactLib::load(dir)?))
    }
}

impl GemmBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn gemm(
        &mut self,
        x: &MatI32,
        w: &MatI32,
        psum: &MatI32,
        scale: f32,
    ) -> Result<(MatI32, MatI32)> {
        gemm_tiled(&mut self.lib, x, w, psum, scale)
    }
}

/// The host oracle: exact int32 accumulation + the same requant rule the
/// Pallas kernel implements. Bit-identical to [`PjrtBackend`] output.
pub struct HostBackend;

impl GemmBackend for HostBackend {
    fn name(&self) -> &'static str {
        "host"
    }

    fn gemm(
        &mut self,
        x: &MatI32,
        w: &MatI32,
        psum: &MatI32,
        scale: f32,
    ) -> Result<(MatI32, MatI32)> {
        if x.cols != w.rows || psum.rows != x.rows || psum.cols != w.cols {
            bail!(
                "shape mismatch: x {}x{}, w {}x{}, psum {}x{}",
                x.rows,
                x.cols,
                w.rows,
                w.cols,
                psum.rows,
                psum.cols
            );
        }
        let acc = gemm_ref(x, w, psum);
        let q = requant_ref(&acc, scale);
        Ok((q, acc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_backend_quantizes_its_accumulator() {
        let x = MatI32::from_fn(4, 3, |r, c| (r + c) as i32);
        let w = MatI32::from_fn(3, 5, |r, c| r as i32 - c as i32);
        let p = MatI32::zeros(4, 5);
        let (q, acc) = HostBackend.gemm(&x, &w, &p, 0.5).unwrap();
        assert_eq!(acc, gemm_ref(&x, &w, &p));
        assert_eq!(q, requant_ref(&acc, 0.5));
    }

    #[test]
    fn host_backend_rejects_shape_mismatch() {
        let x = MatI32::zeros(4, 3);
        let w = MatI32::zeros(4, 5); // wrong inner dim
        let p = MatI32::zeros(4, 5);
        assert!(HostBackend.gemm(&x, &w, &p, 1.0).is_err());
    }

    #[test]
    fn boxed_backends_forward() {
        let mut b: Box<dyn GemmBackend> = Box::new(HostBackend);
        assert_eq!(b.name(), "host");
        let x = MatI32::zeros(2, 2);
        assert!(b.gemm(&x, &x, &x, 1.0).is_ok());
    }
}
