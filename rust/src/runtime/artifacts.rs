//! Artifact library: loads the AOT manifest, compiles HLO-text modules
//! on the PJRT CPU client, and validates call signatures.
//!
//! Python lowers once at build time (`make artifacts`); from here on the
//! request path is pure Rust + PJRT.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::json::{parse, Json};

/// Tensor dtype as declared in the manifest (artifact I/O is i32/f32:
/// the xla crate's literal API has no i8; int8 values ride in i32).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    I32,
    F32,
}

impl DType {
    fn from_tag(s: &str) -> Result<Self> {
        match s {
            "i32" => Ok(DType::I32),
            "f32" => Ok(DType::F32),
            other => bail!("unsupported dtype tag {other:?}"),
        }
    }
}

/// Declared signature of one artifact entry point.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The manifest + lazily compiled executables.
pub struct ArtifactLib {
    pub dir: PathBuf,
    pub meta: HashMap<String, ArtifactMeta>,
    client: xla::PjRtClient,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("expected array"))?;
    arr.iter()
        .map(|t| {
            let shape = t
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            let dtype = DType::from_tag(
                t.get("dtype")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("missing dtype"))?,
            )?;
            Ok(TensorSpec { shape, dtype })
        })
        .collect()
}

impl ArtifactLib {
    /// Load `<dir>/manifest.json` and create the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!("reading {}/manifest.json (run `make artifacts`)", dir.display())
        })?;
        let j = parse(&text).map_err(|e| anyhow!("{e}"))?;
        let format = j
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing format"))?;
        if format != "hlo-text/v1" {
            bail!("unsupported manifest format {format:?}");
        }
        let mut meta = HashMap::new();
        for (name, a) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            meta.insert(
                name.clone(),
                ArtifactMeta {
                    file: dir.join(
                        a.get("file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("{name}: missing file"))?,
                    ),
                    inputs: tensor_specs(a.get("inputs").ok_or_else(|| anyhow!("inputs"))?)?,
                    outputs: tensor_specs(a.get("outputs").ok_or_else(|| anyhow!("outputs"))?)?,
                },
            );
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT: {e}"))?;
        Ok(ArtifactLib {
            dir,
            meta,
            client,
            compiled: HashMap::new(),
        })
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.meta.keys().map(String::as_str).collect();
        v.sort();
        v
    }

    /// Compile (once) and return the executable for `name`.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(name) {
            let meta = self
                .meta
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
            let proto = xla::HloModuleProto::from_text_file(
                meta.file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-UTF-8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e}", meta.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(&self.compiled[name])
    }

    /// Execute `name` with literal inputs; returns the tuple elements.
    /// Shapes/dtypes are validated against the manifest first.
    pub fn run(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let meta = self
            .meta
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?
            .clone();
        if inputs.len() != meta.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (lit, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            let n = lit.element_count();
            if n != spec.elements() {
                bail!(
                    "{name}: input {i} has {n} elements, manifest says {:?}",
                    spec.shape
                );
            }
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e}"))?;
        let outs = result.to_tuple().map_err(|e| anyhow!("{e}"))?;
        if outs.len() != meta.outputs.len() {
            bail!(
                "{name}: expected {} outputs, got {}",
                meta.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }
}

/// Default artifact directory: `$VOLTRA_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("VOLTRA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
