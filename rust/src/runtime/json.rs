//! Minimal JSON parser for the artifact manifest.
//!
//! Substrate note (DESIGN.md): the build environment vendors no JSON
//! crate, so the manifest parser is implemented here. It supports the
//! full JSON grammar the AOT pipeline emits (objects, arrays, strings
//! with escapes, numbers, booleans, null).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Canonical compact serialization: stable key order (objects are
    /// `BTreeMap`s), integer-exact numbers for every counter below 2^53,
    /// full string escaping. `parse(render(j)) == j` for any value this
    /// crate produces — the writer half of the parser, used by the lint
    /// CLI's `--json` report and the serving engine.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < (1u64 << 53) as f64 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => render_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_str(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn render_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError {
            pos: self.i,
            msg: msg.into(),
        })
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a JSON value"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err(format!("expected '{s}'"))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| ParseError {
                                        pos: self.i,
                                        msg: "bad \\u escape".into(),
                                    })?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| ParseError {
                                pos: self.i,
                                msg: "bad \\u escape".into(),
                            })?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // UTF-8 passthrough.
                    let start = self.i;
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    if start + len > self.b.len() {
                        return self.err("truncated UTF-8");
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..start + len]).map_err(|_| {
                            ParseError {
                                pos: start,
                                msg: "invalid UTF-8".into(),
                            }
                        })?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| ParseError {
                pos: start,
                msg: format!("bad number '{txt}'"),
            })
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
            "format": "hlo-text/v1",
            "artifacts": {
                "gemm8": {
                    "file": "gemm8.hlo.txt",
                    "inputs": [{"shape": [8, 8], "dtype": "i32"}],
                    "outputs": [{"shape": [8, 8], "dtype": "i32"}]
                }
            }
        }"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.get("format").unwrap().as_str().unwrap(), "hlo-text/v1");
        let g = j.get("artifacts").unwrap().get("gemm8").unwrap();
        assert_eq!(g.get("file").unwrap().as_str().unwrap(), "gemm8.hlo.txt");
        let shape = g.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap();
        assert_eq!(shape.as_arr().unwrap()[0].as_usize().unwrap(), 8);
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn render_round_trips() {
        let docs = [
            r#"{"a":[1,2.5,-3],"b":{"c":"x\ny","d":null},"e":true}"#,
            "[]",
            "{}",
            r#""quote \" slash \\""#,
        ];
        for d in docs {
            let j = parse(d).unwrap();
            let r = j.render();
            assert_eq!(parse(&r).unwrap(), j, "round trip of {d}");
        }
        // Canonical form is exactly reproduced for compact input.
        assert_eq!(parse(docs[0]).unwrap().render(), docs[0]);
    }

    #[test]
    fn render_keeps_counters_integer_exact() {
        let big = (1u64 << 52) as f64;
        assert_eq!(Json::Num(big).render(), format!("{}", 1u64 << 52));
        assert_eq!(Json::Num(0.5).render(), "0.5");
    }

    #[test]
    fn nested_arrays() {
        let j = parse("[[1,2],[3,[4]]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[1].as_arr().unwrap()[1].as_arr().unwrap()[0], Json::Num(4.0));
    }
}
