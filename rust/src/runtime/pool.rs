//! The scoped worker-pool idiom, extracted (DESIGN.md §15): N indexed
//! work items claimed off one atomic counter by a small set of scoped
//! threads, each carrying private per-worker state, results landing in
//! per-item slots so output order is item order regardless of claim
//! order.
//!
//! This shape was hand-rolled three times — `plan::build_parallel`
//! (per-worker `IncrementalMapper` state), the sweep/suite path
//! (`coordinator::run_suite_indexed`, stateless), and now the
//! architecture-search driver (per-worker mapper handle spanning grid
//! points) — so it lives here once. Work stealing is the atomic index
//! itself: a worker that finishes early simply claims the next
//! unclaimed item; no queues, no rebalancing, no idle tail while any
//! item remains.
//!
//! Determinism contract: `work` must be pure in `(item index, shared
//! caches)` up to memoization — per-worker state may accelerate (e.g.
//! a mapper hint that only prunes) but never change results. Under
//! that contract the returned vector is bit-identical for every
//! `threads` value, which is what lets callers pin parallel == serial
//! in tests.

use crate::sync::{Mutex, Rank};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Run `work(state, i)` for every `i in 0..n` across up to `threads`
/// scoped workers, returning results in index order. `init` constructs
/// each worker's private state (once per worker, on that worker's
/// thread). `threads <= 1` (or `n <= 1`) runs inline on the caller's
/// thread with a single state — no spawn cost on the degenerate path.
///
/// Panics in `work` propagate: the scope joins every worker and
/// re-raises the panic *before* any slot is read, so a panicking
/// closure can never hang the pool or return a partial result vector
/// (pinned by `tests/pool_edge.rs`).
pub fn scoped_indexed<S, T, I, F>(n: usize, threads: usize, init: I, work: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let workers = threads.clamp(1, n.max(1));
    if workers <= 1 {
        let mut state = init();
        return (0..n).map(|i| work(&mut state, i)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(Rank::PoolSlot, None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = work(&mut state, i);
                    *slots[i].lock() = Some(r);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().expect("pool worker skipped an item"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn results_are_in_index_order_for_any_thread_count() {
        for threads in [0, 1, 2, 4, 16] {
            let out = scoped_indexed(10, threads, || (), |_, i| i * i);
            assert_eq!(out, (0..10).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn empty_input_spawns_nothing() {
        let inits = AtomicUsize::new(0);
        let out: Vec<usize> = scoped_indexed(
            0,
            8,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
            },
            |_, i| i,
        );
        assert!(out.is_empty());
        assert_eq!(inits.load(Ordering::Relaxed), 1, "degenerate path: one inline state");
    }

    #[test]
    fn per_worker_state_is_private_and_bounded() {
        // Each worker gets exactly one state; every item sees some
        // worker's state, and total inits never exceed the worker count.
        let inits = AtomicUsize::new(0);
        let out = scoped_indexed(
            64,
            4,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                0usize
            },
            |count, _| {
                *count += 1;
                *count
            },
        );
        assert_eq!(out.len(), 64);
        let spawned = inits.load(Ordering::Relaxed);
        assert!(spawned <= 4, "got {spawned} states for 4 workers");
        // Per-worker counters partition the items: each worker that
        // claimed anything contributes exactly one first-claim (c == 1),
        // and every item was claimed by someone.
        let first_claims = out.iter().filter(|&&c| c == 1).count();
        assert!((1..=spawned).contains(&first_claims), "{first_claims} vs {spawned}");
        assert!(out.iter().all(|&c| c >= 1));
    }

    #[test]
    fn single_item_runs_inline() {
        let out = scoped_indexed(1, 8, || 41, |s, i| *s + 1 + i);
        assert_eq!(out, vec![42]);
    }
}
