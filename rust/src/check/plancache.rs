//! Protocol model of [`crate::plan::PlanCache::plan_named`]'s
//! accounting: `hits + misses + coalesced == resolved calls`, the
//! thundering herd plans exactly once, and every caller leaves with the
//! canonical (first-inserted) value — Arc canonicality, modeled as
//! value identity.
//!
//! Same flight machinery as the `flight` model (this cache sits on the
//! same `FlightGroup`), but abort-free and with the three counters the
//! serving tier's STATS verb reports. The mutations are bookkeeping
//! bugs a refactor could plausibly introduce: counting the double-check
//! hit as a miss too, dropping the coalesced count, forgetting the
//! read-path hit count, skipping the double-check (herd plans twice),
//! and retiring the flight before the shard insert (a window where a
//! second planner runs).

use super::sched::{Model, Violation};
use super::Mutation;

#[derive(Clone, Hash, PartialEq, Eq)]
struct Slot {
    published: Option<u8>,
    notified: bool,
}

#[derive(Clone, Copy, Hash, PartialEq, Eq, Debug)]
enum Pc {
    ReadShard,
    Join,
    LeaderCheck,
    Plan,
    Insert,
    Retire,
    PublishSlot,
    Wait,
    Done,
}

#[derive(Clone, Hash)]
struct Caller {
    pc: Pc,
    leading: Option<u8>,
    waiting_on: Option<u8>,
    value: Option<u8>,
    result: Option<u8>,
    spurious_budget: u8,
    /// The mutated leader retires before inserting; this remembers the
    /// pending insert across the reordering.
    retired_early: bool,
}

impl Caller {
    fn new() -> Self {
        Caller {
            pc: Pc::ReadShard,
            leading: None,
            waiting_on: None,
            value: None,
            result: None,
            spurious_budget: 1,
            retired_early: false,
        }
    }
}

/// See module docs. One key, three callers, no aborts.
#[derive(Clone, Hash)]
pub(crate) struct PlanCacheModel {
    mutation: Option<Mutation>,
    shard: Option<u8>,
    inflight: Option<u8>,
    slots: Vec<Slot>,
    next_value: u8,
    planner_runs: u8,
    hits: u8,
    misses: u8,
    coalesced: u8,
    callers: Vec<Caller>,
}

impl PlanCacheModel {
    pub(crate) fn new(mutation: Option<Mutation>) -> Self {
        PlanCacheModel {
            mutation,
            shard: None,
            inflight: None,
            slots: Vec::new(),
            next_value: 1,
            planner_runs: 0,
            hits: 0,
            misses: 0,
            coalesced: 0,
            callers: vec![Caller::new(), Caller::new(), Caller::new()],
        }
    }

    fn is(&self, m: Mutation) -> bool {
        self.mutation == Some(m)
    }

    fn real_wake(&self, g: u8) -> bool {
        let s = &self.slots[g as usize];
        s.published.is_some() && s.notified
    }
}

impl Model for PlanCacheModel {
    fn threads(&self) -> usize {
        self.callers.len()
    }

    fn done(&self, t: usize) -> bool {
        self.callers[t].pc == Pc::Done
    }

    fn enabled(&self, t: usize) -> bool {
        let c = &self.callers[t];
        match c.pc {
            Pc::Done => false,
            Pc::Wait => {
                let g = c.waiting_on.expect("parked caller has a generation");
                self.real_wake(g) || c.spurious_budget > 0
            }
            _ => true,
        }
    }

    fn step(&mut self, t: usize) -> String {
        let pc = self.callers[t].pc;
        match pc {
            Pc::ReadShard => {
                if let Some(v) = self.shard {
                    if !self.is(Mutation::CacheHitUncounted) {
                        self.hits += 1;
                    }
                    self.callers[t].result = Some(v);
                    self.callers[t].pc = Pc::Done;
                    "shard-hit".into()
                } else {
                    self.callers[t].pc = Pc::Join;
                    "shard-miss".into()
                }
            }
            Pc::Join => match self.inflight {
                Some(g) => {
                    if !self.is(Mutation::CacheLostCoalesced) {
                        self.coalesced += 1;
                    }
                    self.callers[t].waiting_on = Some(g);
                    self.callers[t].pc = Pc::Wait;
                    format!("join-follow(g{g})")
                }
                None => {
                    let g = self.slots.len() as u8;
                    self.slots.push(Slot {
                        published: None,
                        notified: false,
                    });
                    self.inflight = Some(g);
                    self.callers[t].leading = Some(g);
                    self.callers[t].pc = Pc::LeaderCheck;
                    format!("join-lead(g{g})")
                }
            },
            Pc::LeaderCheck => {
                if !self.is(Mutation::CacheSkipDoubleCheck) {
                    if let Some(v) = self.shard {
                        self.hits += 1;
                        if self.is(Mutation::CacheDoubleCountMiss) {
                            // Bug: the hit-behind-the-flight path also
                            // bumps the miss counter.
                            self.misses += 1;
                        }
                        self.callers[t].value = Some(v);
                        self.callers[t].pc = Pc::Retire;
                        return "double-check-hit".into();
                    }
                }
                self.callers[t].pc = Pc::Plan;
                "double-check-miss".into()
            }
            Pc::Plan => {
                self.planner_runs += 1;
                self.misses += 1;
                let v = self.next_value;
                self.next_value += 1;
                self.callers[t].value = Some(v);
                if self.is(Mutation::CacheRetireEarly) {
                    // Bug: publish/retire reordered before the insert.
                    self.callers[t].retired_early = true;
                    self.callers[t].pc = Pc::Retire;
                } else {
                    self.callers[t].pc = Pc::Insert;
                }
                "plan (count miss)".into()
            }
            Pc::Insert => {
                let v = self.callers[t].value.expect("leader planned");
                let canonical = *self.shard.get_or_insert(v);
                self.callers[t].value = Some(canonical);
                self.callers[t].pc = if self.callers[t].retired_early {
                    Pc::PublishSlot
                } else {
                    Pc::Retire
                };
                "insert(or_insert)".into()
            }
            Pc::Retire => {
                self.inflight = None;
                self.callers[t].pc = if self.callers[t].retired_early {
                    Pc::Insert
                } else {
                    Pc::PublishSlot
                };
                "retire".into()
            }
            Pc::PublishSlot => {
                let g = self.callers[t].leading.expect("leader has a generation");
                let v = self.callers[t].value.expect("leader holds the value");
                let slot = &mut self.slots[g as usize];
                slot.published = Some(v);
                slot.notified = true;
                self.callers[t].leading = None;
                self.callers[t].result = Some(v);
                self.callers[t].pc = Pc::Done;
                format!("publish(g{g})")
            }
            Pc::Wait => {
                let g = self.callers[t].waiting_on.expect("parked caller");
                if !self.real_wake(g) {
                    self.callers[t].spurious_budget -= 1;
                    if self.slots[g as usize].published.is_none() {
                        return format!("spurious-wake(g{g}) -> repark");
                    }
                }
                let v = self.slots[g as usize]
                    .published
                    .expect("left the wait only when published");
                self.callers[t].waiting_on = None;
                self.callers[t].result = Some(v);
                self.callers[t].pc = Pc::Done;
                format!("wake(g{g}) -> value")
            }
            Pc::Done => unreachable!("done callers are never scheduled"),
        }
    }

    fn invariant(&self) -> Result<(), Violation> {
        Ok(())
    }

    fn at_quiescence(&self) -> Result<(), Violation> {
        let calls = self.callers.len() as u8;
        let sum = self.hits + self.misses + self.coalesced;
        if sum != calls {
            return Err(Violation::new(
                "accounting",
                format!(
                    "hits({}) + misses({}) + coalesced({}) = {} != {} calls",
                    self.hits, self.misses, self.coalesced, sum, calls
                ),
            ));
        }
        if self.planner_runs > 1 {
            return Err(Violation::new(
                "plan-once",
                format!("{} planner runs for one key", self.planner_runs),
            ));
        }
        for (i, c) in self.callers.iter().enumerate() {
            if c.result.is_none() || c.result != self.shard {
                return Err(Violation::new(
                    "value-canonical",
                    format!(
                        "caller {i} finished with {:?}, shard holds {:?}",
                        c.result, self.shard
                    ),
                ));
            }
        }
        Ok(())
    }
}
