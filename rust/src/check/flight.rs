//! Protocol model of [`crate::coordinator::singleflight::FlightGroup`]:
//! leader/follower/abort-and-retry over one hot key.
//!
//! Three callers race one cold key through the exact protocol shape of
//! the real code — read the memo store, join the flight (lead or
//! follow), leaders double-check / compute / insert / retire / publish,
//! followers park on the flight slot behind a predicate loop. Caller 0
//! is scripted to *abort* its first leadership (the panic-unwind path),
//! so every exploration also covers the abort-and-retry loop: followers
//! of a dead leader must wake empty-handed, re-read the store, and
//! re-join.
//!
//! Condvar semantics are modeled adversarially: a notify sets the
//! generation's `notified` flag (a real wakeup needs it), and every
//! parked caller holds a spurious-wake budget of 1 — a wakeup the
//! protocol did not ask for, which a correct predicate loop re-parks
//! on. The mutations break exactly the things the real code is careful
//! about: publish without notify, abort without publish, `if` instead
//! of `while` around the wait, treating an abort as a published value.

use super::sched::{Model, Violation};
use super::Mutation;

/// What one flight generation's publish slot holds.
#[derive(Clone, Hash, PartialEq, Eq)]
struct Slot {
    /// `None` = unpublished; `Some(Some(v))` = value; `Some(None)` = abort.
    published: Option<Option<u8>>,
    /// The leader's notify reached this generation's waiters.
    notified: bool,
}

#[derive(Clone, Copy, Hash, PartialEq, Eq, Debug)]
enum Pc {
    ReadCache,
    Join,
    LeaderCheck,
    Compute,
    Insert,
    Retire,
    PublishSlot,
    AbortRetire,
    AbortPublish,
    Wait,
    Done,
}

#[derive(Clone, Hash)]
struct Caller {
    pc: Pc,
    /// Generation this caller is leading (set at join-lead).
    leading: Option<u8>,
    /// Generation this caller is parked on.
    waiting_on: Option<u8>,
    /// Value in hand: the leader's computed/cached value, then the
    /// published canonical on the way out.
    value: Option<u8>,
    result: Option<u8>,
    /// Remaining adversarial spurious wakeups while parked.
    spurious_budget: u8,
    /// Scripted to panic (abort) on its first leadership.
    will_abort: bool,
    aborted: bool,
}

impl Caller {
    fn new(will_abort: bool) -> Self {
        Caller {
            pc: Pc::ReadCache,
            leading: None,
            waiting_on: None,
            value: None,
            result: None,
            spurious_budget: 1,
            will_abort,
            aborted: false,
        }
    }
}

/// See module docs. One key, three callers, caller 0 aborts its first
/// leadership.
#[derive(Clone, Hash)]
pub(crate) struct FlightModel {
    mutation: Option<Mutation>,
    /// The callers' memoization store entry for the key.
    cache: Option<u8>,
    /// Generation currently in the in-flight map, if any.
    inflight: Option<u8>,
    /// One slot per generation ever started.
    slots: Vec<Slot>,
    next_value: u8,
    planner_runs: u8,
    callers: Vec<Caller>,
}

impl FlightModel {
    pub(crate) fn new(mutation: Option<Mutation>) -> Self {
        FlightModel {
            mutation,
            cache: None,
            inflight: None,
            slots: Vec::new(),
            next_value: 1,
            planner_runs: 0,
            callers: vec![Caller::new(true), Caller::new(false), Caller::new(false)],
        }
    }

    fn is(&self, m: Mutation) -> bool {
        self.mutation == Some(m)
    }

    /// A real (notified) wakeup is available for the caller parked on
    /// generation `g`.
    fn real_wake(&self, g: u8) -> bool {
        let s = &self.slots[g as usize];
        s.published.is_some() && s.notified
    }

    /// Leave the wait with the slot's current contents (predicate held,
    /// or bypassed by the wait-if mutation).
    fn consume_wake(&mut self, t: usize, g: u8) -> String {
        let published = self.slots[g as usize].published.clone();
        let c = &mut self.callers[t];
        c.waiting_on = None;
        match published {
            Some(Some(v)) => {
                c.result = Some(v);
                c.pc = Pc::Done;
                format!("wake(g{g}) -> value")
            }
            Some(None) => {
                if self.is(Mutation::FlightMissedAbortRetry) {
                    // Bug: treat the abort sentinel as a final answer.
                    c.pc = Pc::Done;
                    format!("wake(g{g}) -> abort taken as value")
                } else {
                    c.pc = Pc::ReadCache;
                    format!("wake(g{g}) -> abort, retry")
                }
            }
            None => {
                // Only reachable via the wait-if mutation: the caller
                // sailed past an unpublished slot.
                c.pc = Pc::Done;
                format!("wake(g{g}) -> unpublished slot consumed")
            }
        }
    }
}

impl Model for FlightModel {
    fn threads(&self) -> usize {
        self.callers.len()
    }

    fn done(&self, t: usize) -> bool {
        self.callers[t].pc == Pc::Done
    }

    fn enabled(&self, t: usize) -> bool {
        let c = &self.callers[t];
        match c.pc {
            Pc::Done => false,
            Pc::Wait => {
                let g = c.waiting_on.expect("parked caller has a generation");
                self.real_wake(g) || c.spurious_budget > 0
            }
            _ => true,
        }
    }

    fn step(&mut self, t: usize) -> String {
        let pc = self.callers[t].pc;
        match pc {
            Pc::ReadCache => {
                if let Some(v) = self.cache {
                    self.callers[t].result = Some(v);
                    self.callers[t].pc = Pc::Done;
                    "read-hit".into()
                } else {
                    self.callers[t].pc = Pc::Join;
                    "read-miss".into()
                }
            }
            Pc::Join => match self.inflight {
                Some(g) => {
                    self.callers[t].waiting_on = Some(g);
                    self.callers[t].pc = Pc::Wait;
                    format!("join-follow(g{g})")
                }
                None => {
                    let g = self.slots.len() as u8;
                    self.slots.push(Slot {
                        published: None,
                        notified: false,
                    });
                    self.inflight = Some(g);
                    self.callers[t].leading = Some(g);
                    self.callers[t].pc = Pc::LeaderCheck;
                    format!("join-lead(g{g})")
                }
            },
            Pc::LeaderCheck => {
                if let Some(v) = self.cache {
                    // Double-check hit: publish the cached value.
                    self.callers[t].value = Some(v);
                    self.callers[t].pc = Pc::Retire;
                    "double-check-hit".into()
                } else {
                    self.callers[t].pc = Pc::Compute;
                    "double-check-miss".into()
                }
            }
            Pc::Compute => {
                self.planner_runs += 1;
                let v = self.next_value;
                self.next_value += 1;
                self.callers[t].value = Some(v);
                if self.callers[t].will_abort && !self.callers[t].aborted {
                    self.callers[t].pc = Pc::AbortRetire;
                    "compute -> panic".into()
                } else {
                    self.callers[t].pc = Pc::Insert;
                    "compute".into()
                }
            }
            Pc::Insert => {
                let v = self.callers[t].value.expect("leader computed");
                let canonical = *self.cache.get_or_insert(v);
                self.callers[t].value = Some(canonical);
                self.callers[t].pc = Pc::Retire;
                "insert(or_insert)".into()
            }
            Pc::Retire => {
                self.inflight = None;
                self.callers[t].pc = Pc::PublishSlot;
                "retire".into()
            }
            Pc::PublishSlot => {
                let g = self.callers[t].leading.expect("leader has a generation");
                let v = self.callers[t].value.expect("leader holds the value");
                let slot = &mut self.slots[g as usize];
                slot.published = Some(Some(v));
                if !self.is(Mutation::FlightDroppedNotify) {
                    slot.notified = true;
                }
                self.callers[t].leading = None;
                self.callers[t].result = Some(v);
                self.callers[t].pc = Pc::Done;
                format!("publish(g{g})")
            }
            Pc::AbortRetire => {
                self.inflight = None;
                self.callers[t].pc = Pc::AbortPublish;
                "abort: retire".into()
            }
            Pc::AbortPublish => {
                let g = self.callers[t].leading.expect("leader has a generation");
                if !self.is(Mutation::FlightAbortSilent) {
                    let slot = &mut self.slots[g as usize];
                    slot.published = Some(None);
                    slot.notified = true;
                }
                self.callers[t].leading = None;
                self.callers[t].aborted = true;
                self.callers[t].pc = Pc::Done;
                format!("abort: publish-none(g{g})")
            }
            Pc::Wait => {
                let g = self.callers[t].waiting_on.expect("parked caller");
                if self.real_wake(g) {
                    return self.consume_wake(t, g);
                }
                // Spurious wakeup (no notify behind it).
                self.callers[t].spurious_budget -= 1;
                if self.is(Mutation::FlightWaitIf) {
                    // Bug: `if` instead of `while` — proceed without
                    // re-checking the predicate.
                    return self.consume_wake(t, g);
                }
                if self.slots[g as usize].published.is_some() {
                    // Predicate satisfied under the lock: leave.
                    return self.consume_wake(t, g);
                }
                format!("spurious-wake(g{g}) -> repark")
            }
            Pc::Done => unreachable!("done callers are never scheduled"),
        }
    }

    fn invariant(&self) -> Result<(), Violation> {
        // The herd compiles at most twice: the scripted abort plus the
        // retry's leader.
        if self.planner_runs > 2 {
            return Err(Violation::new(
                "plan-once",
                format!(
                    "{} planner runs for one key (abort allows at most 2)",
                    self.planner_runs
                ),
            ));
        }
        Ok(())
    }

    fn at_quiescence(&self) -> Result<(), Violation> {
        for (i, c) in self.callers.iter().enumerate() {
            if c.aborted {
                continue; // its panic propagated to its caller
            }
            if c.result.is_none() || c.result != self.cache {
                return Err(Violation::new(
                    "value-canonical",
                    format!(
                        "caller {i} finished with {:?}, store holds {:?}",
                        c.result, self.cache
                    ),
                ));
            }
        }
        Ok(())
    }
}
