//! Protocol model of the [`crate::sync`] lock-rank table: two threads
//! replaying the real call-path acquisition sequences (plan-leader
//! path with its `TileClassMap -> TileShard` nesting, tile-simulate +
//! pool path) under mutual exclusion, with the rank-monotonicity rule
//! checked at every acquisition — the same rule `sync::Mutex` debug-
//! asserts at runtime, here proved over *all* interleavings instead of
//! the ones a test happens to hit.
//!
//! The rank-inversion mutation models new code that nests
//! `FlightSlot -> FlightMap` on one thread while another nests them the
//! sanctioned way round: the monotonicity check fires, and the
//! exploration also exhibits the AB-BA deadlock the rule exists to
//! make impossible.

use super::sched::{Model, Violation};
use super::Mutation;
use crate::sync::Rank;

#[derive(Clone, Copy, Hash, PartialEq, Eq, Debug)]
enum Op {
    Acq(u8),
    Rel(u8),
}

fn r(rank: Rank) -> u8 {
    rank as u8
}

/// The plan-leader path: shard probe, flight join, tile-class walk
/// (the one real nested pair), shard insert, flight retire + publish.
fn script_planner() -> Vec<Op> {
    use Op::*;
    vec![
        Acq(r(Rank::PlanShard)),
        Rel(r(Rank::PlanShard)),
        Acq(r(Rank::FlightMap)),
        Rel(r(Rank::FlightMap)),
        Acq(r(Rank::TileClassMap)),
        Acq(r(Rank::TileShard)), // nested: unique_tiles() under the class map
        Rel(r(Rank::TileShard)),
        Rel(r(Rank::TileClassMap)),
        Acq(r(Rank::PlanShard)),
        Rel(r(Rank::PlanShard)),
        Acq(r(Rank::FlightMap)),
        Rel(r(Rank::FlightMap)),
        Acq(r(Rank::FlightSlot)),
        Rel(r(Rank::FlightSlot)),
    ]
}

/// The tile-simulate + pool-worker path.
fn script_simulator(inverted: bool) -> Vec<Op> {
    use Op::*;
    let mut s = vec![
        Acq(r(Rank::TileShard)),
        Rel(r(Rank::TileShard)),
        Acq(r(Rank::FlightMap)),
        Rel(r(Rank::FlightMap)),
    ];
    if inverted {
        // Bug: hold the flight slot while re-entering the flight map.
        s.extend([
            Acq(r(Rank::FlightSlot)),
            Acq(r(Rank::FlightMap)),
            Rel(r(Rank::FlightMap)),
            Rel(r(Rank::FlightSlot)),
        ]);
    } else {
        s.extend([
            Acq(r(Rank::FlightSlot)),
            Rel(r(Rank::FlightSlot)),
        ]);
    }
    s.push(Acq(r(Rank::PoolSlot)));
    s.push(Rel(r(Rank::PoolSlot)));
    s
}

/// Against the inverted simulator, the planner nests the pair the
/// sanctioned way round — giving the classic AB-BA shape.
fn script_planner_nested() -> Vec<Op> {
    use Op::*;
    let mut s = script_planner();
    s.extend([
        Acq(r(Rank::FlightMap)),
        Acq(r(Rank::FlightSlot)),
        Rel(r(Rank::FlightSlot)),
        Rel(r(Rank::FlightMap)),
    ]);
    s
}

/// See module docs.
#[derive(Clone, Hash)]
pub(crate) struct LockOrderModel {
    scripts: Vec<Vec<Op>>,
    /// Next op index per thread.
    idx: Vec<usize>,
    /// Ranks held per thread, in acquisition order.
    held: Vec<Vec<u8>>,
    /// Current owner of each rank's lock (one lock per rank suffices —
    /// shards of one rank are never nested with each other).
    owner: Vec<Option<u8>>,
}

impl LockOrderModel {
    pub(crate) fn new(mutation: Option<Mutation>) -> Self {
        let inverted = mutation == Some(Mutation::LockRankInversion);
        let scripts = if inverted {
            vec![script_planner_nested(), script_simulator(true)]
        } else {
            vec![script_planner(), script_simulator(false)]
        };
        let n = scripts.len();
        LockOrderModel {
            scripts,
            idx: vec![0; n],
            held: vec![Vec::new(); n],
            owner: vec![None; 256],
        }
    }
}

impl Model for LockOrderModel {
    fn threads(&self) -> usize {
        self.scripts.len()
    }

    fn done(&self, t: usize) -> bool {
        self.idx[t] == self.scripts[t].len()
    }

    fn enabled(&self, t: usize) -> bool {
        if self.done(t) {
            return false;
        }
        match self.scripts[t][self.idx[t]] {
            Op::Acq(l) => self.owner[l as usize].is_none(),
            Op::Rel(_) => true,
        }
    }

    fn step(&mut self, t: usize) -> String {
        let op = self.scripts[t][self.idx[t]];
        self.idx[t] += 1;
        match op {
            Op::Acq(l) => {
                self.owner[l as usize] = Some(t as u8);
                self.held[t].push(l);
                format!("acquire rank {l}")
            }
            Op::Rel(l) => {
                self.owner[l as usize] = None;
                if let Some(pos) = self.held[t].iter().rposition(|&h| h == l) {
                    self.held[t].remove(pos);
                }
                format!("release rank {l}")
            }
        }
    }

    fn invariant(&self) -> Result<(), Violation> {
        for (t, held) in self.held.iter().enumerate() {
            for w in held.windows(2) {
                if w[0] >= w[1] {
                    return Err(Violation::new(
                        "rank-monotone",
                        format!(
                            "t{t} acquired rank {} while holding rank {} \
                             (acquisition order must strictly increase)",
                            w[1], w[0]
                        ),
                    ));
                }
            }
        }
        Ok(())
    }

    fn at_quiescence(&self) -> Result<(), Violation> {
        for (t, held) in self.held.iter().enumerate() {
            if !held.is_empty() {
                return Err(Violation::new(
                    "lock-leak",
                    format!("t{t} terminated holding ranks {:?}", held),
                ));
            }
        }
        Ok(())
    }
}
