//! Protocol model of [`crate::runtime::pool::scoped_indexed`]'s
//! work-stealing claim loop: two workers racing an atomic index over
//! three items. The checked contract is the pool's determinism
//! contract — every index claimed exactly once, every result landing
//! in its own index's slot — which is what lets callers pin
//! `parallel == serial` in tests.
//!
//! Mutations: a claim stride bug (skips items), a torn
//! read-modify-write claim (two workers claim the same item — the bug
//! `fetch_add` exists to prevent), and claim-order slot placement
//! (results land in the order work finished, not item order).

use super::sched::{Model, Violation};
use super::Mutation;

const ITEMS: usize = 3;
const WORKERS: usize = 2;

/// The pure per-item work function: anything injective will do.
fn f(i: usize) -> u8 {
    10 + i as u8
}

#[derive(Clone, Copy, Hash, PartialEq, Eq)]
enum Pc {
    Claim,
    /// Second half of the torn claim: the loaded index is committed.
    ClaimStore(u8),
    Write(u8),
    Exited,
}

/// See module docs.
#[derive(Clone, Hash)]
pub(crate) struct PoolModel {
    mutation: Option<Mutation>,
    next: u8,
    claims: [u8; ITEMS],
    slots: [Option<u8>; ITEMS],
    pcs: [Pc; WORKERS],
    /// Items completed per worker (the wrong-slot mutation writes by
    /// this sequence number instead of the item index).
    seq: [u8; WORKERS],
}

impl PoolModel {
    pub(crate) fn new(mutation: Option<Mutation>) -> Self {
        PoolModel {
            mutation,
            next: 0,
            claims: [0; ITEMS],
            slots: [None; ITEMS],
            pcs: [Pc::Claim; WORKERS],
            seq: [0; WORKERS],
        }
    }

    fn is(&self, m: Mutation) -> bool {
        self.mutation == Some(m)
    }

    fn commit(&mut self, w: usize, i: u8) -> String {
        if (i as usize) < ITEMS {
            self.claims[i as usize] += 1;
            self.pcs[w] = Pc::Write(i);
            format!("claim {i}")
        } else {
            self.pcs[w] = Pc::Exited;
            "claim past end, exit".into()
        }
    }
}

impl Model for PoolModel {
    fn threads(&self) -> usize {
        WORKERS
    }

    fn done(&self, t: usize) -> bool {
        self.pcs[t] == Pc::Exited
    }

    fn enabled(&self, t: usize) -> bool {
        self.pcs[t] != Pc::Exited
    }

    fn step(&mut self, t: usize) -> String {
        match self.pcs[t] {
            Pc::Claim => {
                if self.is(Mutation::PoolRacyClaim) {
                    // Bug: load and store as two separate steps — the
                    // interleaving window `fetch_add` closes.
                    let i = self.next;
                    self.pcs[t] = Pc::ClaimStore(i);
                    return format!("racy load {i}");
                }
                let i = self.next;
                let stride = if self.is(Mutation::PoolClaimSkip) { 2 } else { 1 };
                self.next += stride;
                self.commit(t, i)
            }
            Pc::ClaimStore(i) => {
                self.next = i + 1;
                self.commit(t, i)
            }
            Pc::Write(i) => {
                let target = if self.is(Mutation::PoolWrongSlot) {
                    // Bug: land results in completion order.
                    self.seq[t] as usize
                } else {
                    i as usize
                };
                if target < ITEMS {
                    self.slots[target] = Some(f(i as usize));
                }
                self.seq[t] += 1;
                self.pcs[t] = Pc::Claim;
                format!("write f({i}) -> slot {target}")
            }
            Pc::Exited => unreachable!("exited workers are never scheduled"),
        }
    }

    fn invariant(&self) -> Result<(), Violation> {
        for (i, &c) in self.claims.iter().enumerate() {
            if c > 1 {
                return Err(Violation::new(
                    "claim-once",
                    format!("item {i} claimed {c} times"),
                ));
            }
        }
        Ok(())
    }

    fn at_quiescence(&self) -> Result<(), Violation> {
        for i in 0..ITEMS {
            if self.claims[i] == 0 || self.slots[i].is_none() {
                return Err(Violation::new(
                    "item-lost",
                    format!("item {i} never claimed/completed"),
                ));
            }
            if self.slots[i] != Some(f(i)) {
                return Err(Violation::new(
                    "index-order",
                    format!("slot {i} holds {:?}, expected {:?}", self.slots[i], f(i)),
                ));
            }
        }
        Ok(())
    }
}
