//! Protocol model of [`crate::coordinator::dispatch`] + the bounded
//! numerics channel (DESIGN.md §14): admission control (`ERR busy` at
//! the full queue, never silent loss), no lost wakeups, and graceful
//! drain — workers exit only when every submitter is gone AND the
//! queue is empty, numerics exits only after the workers.
//!
//! Threads: two connections (one request each), two pool workers, one
//! numerics thread. The queue depth is 1 and the numerics channel cap
//! is 1, so both admission decisions are reachable: a schedule where
//! both connections submit before any pickup fills the queue (second
//! submit must reject), and a schedule where both workers hold jobs
//! fills the numerics channel (second send must block).
//!
//! Blocking is modeled as disabledness — a worker at `recv` on an empty
//! queue with live senders simply has no enabled transition, which is
//! exactly what lets the scheduler call a lost wakeup what it is: a
//! deadlock.

use super::sched::{Model, Violation};
use super::Mutation;

const QUEUE_CAP: usize = 1;
const NUM_CAP: usize = 1;
const CONNS: usize = 2;
const WORKERS: usize = 2;

#[derive(Clone, Copy, Hash, PartialEq, Eq)]
enum ReqStatus {
    Pending,
    Rejected,
    Done,
}

#[derive(Clone, Copy, Hash, PartialEq, Eq)]
enum ConnPc {
    Submit,
    AwaitReply,
    Finished,
}

#[derive(Clone, Copy, Hash, PartialEq, Eq)]
enum WorkerPc {
    Recv,
    SendNum(u8),
    AwaitNum(u8),
    Exited,
}

#[derive(Clone, Copy, Hash, PartialEq, Eq)]
enum NumPc {
    Recv,
    Exited,
}

/// See module docs.
#[derive(Clone, Hash)]
pub(crate) struct DispatchModel {
    mutation: Option<Mutation>,
    queue: Vec<u8>,
    /// Live `Dispatcher` clones (connections that may still submit).
    senders: u8,
    workers_alive: u8,
    numq: Vec<u8>,
    num_done: [bool; CONNS],
    status: [ReqStatus; CONNS],
    conns: [ConnPc; CONNS],
    workers: [WorkerPc; WORKERS],
    numerics: NumPc,
}

impl DispatchModel {
    pub(crate) fn new(mutation: Option<Mutation>) -> Self {
        DispatchModel {
            mutation,
            queue: Vec::new(),
            senders: CONNS as u8,
            workers_alive: WORKERS as u8,
            numq: Vec::new(),
            num_done: [false; CONNS],
            status: [ReqStatus::Pending; CONNS],
            conns: [ConnPc::Submit; CONNS],
            workers: [WorkerPc::Recv; WORKERS],
            numerics: NumPc::Recv,
        }
    }

    fn is(&self, m: Mutation) -> bool {
        self.mutation == Some(m)
    }
}

// Thread layout: 0..CONNS = connections, CONNS..CONNS+WORKERS = pool
// workers, last = numerics.
impl Model for DispatchModel {
    fn threads(&self) -> usize {
        CONNS + WORKERS + 1
    }

    fn done(&self, t: usize) -> bool {
        if t < CONNS {
            self.conns[t] == ConnPc::Finished
        } else if t < CONNS + WORKERS {
            self.workers[t - CONNS] == WorkerPc::Exited
        } else {
            self.numerics == NumPc::Exited
        }
    }

    fn enabled(&self, t: usize) -> bool {
        if t < CONNS {
            return match self.conns[t] {
                ConnPc::Submit => true,
                // recv() on the reply channel: runnable only once the
                // worker has sent the response.
                ConnPc::AwaitReply => self.status[t] == ReqStatus::Done,
                ConnPc::Finished => false,
            };
        }
        if t < CONNS + WORKERS {
            return match self.workers[t - CONNS] {
                // recv() on the job queue: a job, or channel closure.
                WorkerPc::Recv => {
                    !self.queue.is_empty()
                        || self.senders == 0
                        || self.is(Mutation::DispatchWorkerExitOnEmpty)
                }
                // send() on the bounded numerics channel.
                WorkerPc::SendNum(_) => {
                    self.numq.len() < NUM_CAP || self.is(Mutation::DispatchNumericsUnbounded)
                }
                WorkerPc::AwaitNum(req) => self.num_done[req as usize],
                WorkerPc::Exited => false,
            };
        }
        match self.numerics {
            NumPc::Recv => !self.numq.is_empty() || self.workers_alive == 0,
            NumPc::Exited => false,
        }
    }

    fn step(&mut self, t: usize) -> String {
        if t < CONNS {
            return match self.conns[t] {
                ConnPc::Submit => {
                    if self.queue.len() < QUEUE_CAP || self.is(Mutation::DispatchUnboundedQueue) {
                        self.queue.push(t as u8);
                        self.conns[t] = ConnPc::AwaitReply;
                        format!("submit(r{t}) admitted")
                    } else if self.is(Mutation::DispatchSilentDrop) {
                        // Bug: the request vanishes — no queue entry,
                        // no busy reply. The connection blocks forever.
                        self.conns[t] = ConnPc::AwaitReply;
                        format!("submit(r{t}) dropped silently")
                    } else {
                        self.status[t] = ReqStatus::Rejected;
                        self.senders -= 1;
                        self.conns[t] = ConnPc::Finished;
                        format!("submit(r{t}) -> ERR busy")
                    }
                }
                ConnPc::AwaitReply => {
                    self.senders -= 1;
                    self.conns[t] = ConnPc::Finished;
                    format!("reply(r{t}) received, disconnect")
                }
                ConnPc::Finished => unreachable!("finished connections are never scheduled"),
            };
        }
        if t < CONNS + WORKERS {
            let w = t - CONNS;
            return match self.workers[w] {
                WorkerPc::Recv => {
                    if !self.queue.is_empty() {
                        let req = self.queue.remove(0);
                        self.workers[w] = WorkerPc::SendNum(req);
                        format!("recv -> r{req}")
                    } else {
                        // Channel closed (or the exit-on-empty bug).
                        self.workers_alive -= 1;
                        self.workers[w] = WorkerPc::Exited;
                        "recv -> disconnected, exit".into()
                    }
                }
                WorkerPc::SendNum(req) => {
                    self.numq.push(req);
                    self.workers[w] = WorkerPc::AwaitNum(req);
                    format!("numerics-send(r{req})")
                }
                WorkerPc::AwaitNum(req) => {
                    if !self.is(Mutation::DispatchReplyDropped) {
                        self.status[req as usize] = ReqStatus::Done;
                    }
                    self.workers[w] = WorkerPc::Recv;
                    format!("reply(r{req}) sent")
                }
                WorkerPc::Exited => unreachable!("exited workers are never scheduled"),
            };
        }
        match self.numerics {
            NumPc::Recv => {
                if !self.numq.is_empty() {
                    let req = self.numq.remove(0);
                    self.num_done[req as usize] = true;
                    format!("numerics r{req} computed")
                } else {
                    self.numerics = NumPc::Exited;
                    "numerics channel closed, exit".into()
                }
            }
            NumPc::Exited => unreachable!("exited numerics is never scheduled"),
        }
    }

    fn invariant(&self) -> Result<(), Violation> {
        if self.queue.len() > QUEUE_CAP {
            return Err(Violation::new(
                "queue-bound",
                format!("{} queued jobs exceed queue_depth {QUEUE_CAP}", self.queue.len()),
            ));
        }
        if self.numq.len() > NUM_CAP {
            return Err(Violation::new(
                "numerics-bound",
                format!("{} numerics jobs exceed channel cap {NUM_CAP}", self.numq.len()),
            ));
        }
        Ok(())
    }

    fn at_quiescence(&self) -> Result<(), Violation> {
        for (r, st) in self.status.iter().enumerate() {
            if *st == ReqStatus::Pending {
                return Err(Violation::new(
                    "request-lost",
                    format!("request r{r} neither served nor rejected"),
                ));
            }
        }
        if !self.queue.is_empty() {
            return Err(Violation::new(
                "drain-incomplete",
                format!("{} jobs left in the queue after shutdown", self.queue.len()),
            ));
        }
        Ok(())
    }
}
