//! In-tree deterministic-interleaving model checker for the serving and
//! cache stack's concurrency protocols (DESIGN.md §16).
//!
//! Loom-spirit, vendored-crate-free: [`sched`] owns a controlled
//! scheduler that exhaustively explores the bounded interleavings of an
//! explicit protocol model, and each submodule is one such model of a
//! real subsystem:
//!
//! | protocol    | models                                               |
//! |-------------|------------------------------------------------------|
//! | `flight`    | `FlightGroup` leader/follower/abort-and-retry        |
//! | `plancache` | `PlanCache` hit/miss/coalesced accounting            |
//! | `dispatch`  | admission control + bounded numerics channel drain   |
//! | `pool`      | `scoped_indexed` work-stealing claim loop            |
//! | `lockorder` | the `sync::Rank` lock-order table over real paths    |
//!
//! The models check the *protocols*, not the code — the contract that
//! keeps them honest is the [`Mutation`] catalog: every entry seeds one
//! concrete concurrency bug into one model and pins the finding id the
//! checker must produce (`tests/check_mutations.rs`). A clean tree
//! explores to quiescence with zero findings; `voltra check` exits 1
//! otherwise and CI runs both directions.

mod dispatch;
mod flight;
mod lockorder;
mod plancache;
mod pool;
mod sched;

pub use sched::{Exploration, Finding, Violation};

use crate::runtime::json::Json;

/// Every protocol `voltra check` knows, in report order.
pub const PROTOCOLS: &[&str] = &["flight", "plancache", "dispatch", "pool", "lockorder"];

/// Default schedule-depth bound. Generous: every shipped model quiesces
/// well under it (the CLI reports `truncated` if a future model does
/// not), while still bounding a runaway exploration.
pub const DEFAULT_DEPTH: usize = 64;

/// One seeded concurrency bug: which model it corrupts and the finding
/// id the checker is required to produce for it. The mutation rig
/// (`tests/check_mutations.rs`) walks [`Mutation::all`] and pins every
/// entry — this enum is the checker's own regression catalog, exactly
/// as `plan::verify::Mutation` is the lint verifier's.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Mutation {
    /// Leader publishes the value but never notifies the condvar.
    FlightDroppedNotify,
    /// Aborting leader retires the flight but publishes nothing.
    FlightAbortSilent,
    /// `if` instead of `while` around the follower's condvar wait.
    FlightWaitIf,
    /// Follower treats the abort sentinel as a final answer (no retry).
    FlightMissedAbortRetry,
    /// Double-check hit also bumps the miss counter.
    CacheDoubleCountMiss,
    /// Followers joining an in-flight plan are not counted coalesced.
    CacheLostCoalesced,
    /// Read-path shard hit is not counted.
    CacheHitUncounted,
    /// Leader skips the double-check behind the flight.
    CacheSkipDoubleCheck,
    /// Flight retired before the shard insert (re-plan window).
    CacheRetireEarly,
    /// Admission check dropped: the queue grows past `queue_depth`.
    DispatchUnboundedQueue,
    /// Full-queue submit neither enqueues nor replies `ERR busy`.
    DispatchSilentDrop,
    /// Worker exits on an empty queue instead of blocking on recv.
    DispatchWorkerExitOnEmpty,
    /// Numerics send skips the channel's capacity bound.
    DispatchNumericsUnbounded,
    /// Worker finishes a job but never sends the reply.
    DispatchReplyDropped,
    /// Claim loop strides by 2: every other item is skipped.
    PoolClaimSkip,
    /// Claim is a torn load+store instead of `fetch_add`.
    PoolRacyClaim,
    /// Results land in completion order, not item-index order.
    PoolWrongSlot,
    /// New code nests `FlightSlot -> FlightMap` against the rank table.
    LockRankInversion,
}

impl Mutation {
    /// Every mutation, in catalog order.
    pub fn all() -> &'static [Mutation] {
        use Mutation::*;
        &[
            FlightDroppedNotify,
            FlightAbortSilent,
            FlightWaitIf,
            FlightMissedAbortRetry,
            CacheDoubleCountMiss,
            CacheLostCoalesced,
            CacheHitUncounted,
            CacheSkipDoubleCheck,
            CacheRetireEarly,
            DispatchUnboundedQueue,
            DispatchSilentDrop,
            DispatchWorkerExitOnEmpty,
            DispatchNumericsUnbounded,
            DispatchReplyDropped,
            PoolClaimSkip,
            PoolRacyClaim,
            PoolWrongSlot,
            LockRankInversion,
        ]
    }

    /// Stable CLI/reporting name.
    pub fn id(&self) -> &'static str {
        use Mutation::*;
        match self {
            FlightDroppedNotify => "flight-dropped-notify",
            FlightAbortSilent => "flight-abort-silent",
            FlightWaitIf => "flight-wait-if",
            FlightMissedAbortRetry => "flight-missed-abort-retry",
            CacheDoubleCountMiss => "cache-double-count-miss",
            CacheLostCoalesced => "cache-lost-coalesced",
            CacheHitUncounted => "cache-hit-uncounted",
            CacheSkipDoubleCheck => "cache-skip-double-check",
            CacheRetireEarly => "cache-retire-early",
            DispatchUnboundedQueue => "dispatch-unbounded-queue",
            DispatchSilentDrop => "dispatch-silent-drop",
            DispatchWorkerExitOnEmpty => "dispatch-worker-exit-on-empty",
            DispatchNumericsUnbounded => "dispatch-numerics-unbounded",
            DispatchReplyDropped => "dispatch-reply-dropped",
            PoolClaimSkip => "pool-claim-skip",
            PoolRacyClaim => "pool-racy-claim",
            PoolWrongSlot => "pool-wrong-slot",
            LockRankInversion => "lock-rank-inversion",
        }
    }

    /// The protocol model this mutation corrupts.
    pub fn protocol(&self) -> &'static str {
        use Mutation::*;
        match self {
            FlightDroppedNotify | FlightAbortSilent | FlightWaitIf | FlightMissedAbortRetry => {
                "flight"
            }
            CacheDoubleCountMiss | CacheLostCoalesced | CacheHitUncounted
            | CacheSkipDoubleCheck | CacheRetireEarly => "plancache",
            DispatchUnboundedQueue | DispatchSilentDrop | DispatchWorkerExitOnEmpty
            | DispatchNumericsUnbounded | DispatchReplyDropped => "dispatch",
            PoolClaimSkip | PoolRacyClaim | PoolWrongSlot => "pool",
            LockRankInversion => "lockorder",
        }
    }

    /// The finding id the checker is required to produce. Pinned, not
    /// "any finding": a mutation caught for the wrong reason would let
    /// the intended invariant rot.
    pub fn expected_finding(&self) -> &'static str {
        use Mutation::*;
        match self {
            FlightDroppedNotify | FlightAbortSilent => "deadlock",
            FlightWaitIf | FlightMissedAbortRetry => "value-canonical",
            CacheDoubleCountMiss | CacheLostCoalesced | CacheHitUncounted => "accounting",
            CacheSkipDoubleCheck | CacheRetireEarly => "plan-once",
            DispatchUnboundedQueue => "queue-bound",
            DispatchSilentDrop | DispatchWorkerExitOnEmpty | DispatchReplyDropped => "deadlock",
            DispatchNumericsUnbounded => "numerics-bound",
            PoolClaimSkip => "item-lost",
            PoolRacyClaim => "claim-once",
            PoolWrongSlot => "index-order",
            LockRankInversion => "rank-monotone",
        }
    }
}

/// One protocol's exploration result.
#[derive(Clone, Debug)]
pub struct CheckReport {
    pub protocol: &'static str,
    pub states: u64,
    pub max_depth: usize,
    pub truncated: bool,
    pub findings: Vec<Finding>,
}

/// Explore one protocol (optionally with a seeded mutation — the
/// mutation must belong to the protocol or it is simply inert). Returns
/// `None` for an unknown protocol name.
pub fn check_protocol(protocol: &str, depth: usize, mutation: Option<Mutation>) -> Option<CheckReport> {
    let mut findings = Vec::new();
    let ex = match protocol {
        "flight" => sched::explore("flight", &flight::FlightModel::new(mutation), depth, &mut findings),
        "plancache" => sched::explore(
            "plancache",
            &plancache::PlanCacheModel::new(mutation),
            depth,
            &mut findings,
        ),
        "dispatch" => sched::explore(
            "dispatch",
            &dispatch::DispatchModel::new(mutation),
            depth,
            &mut findings,
        ),
        "pool" => sched::explore("pool", &pool::PoolModel::new(mutation), depth, &mut findings),
        "lockorder" => sched::explore(
            "lockorder",
            &lockorder::LockOrderModel::new(mutation),
            depth,
            &mut findings,
        ),
        _ => return None,
    };
    Some(CheckReport {
        protocol: match protocol {
            "flight" => "flight",
            "plancache" => "plancache",
            "dispatch" => "dispatch",
            "pool" => "pool",
            _ => "lockorder",
        },
        states: ex.states,
        max_depth: ex.max_depth,
        truncated: ex.truncated,
        findings,
    })
}

/// Explore every protocol on the clean (unmutated) models.
pub fn check_all(depth: usize) -> Vec<CheckReport> {
    PROTOCOLS
        .iter()
        .map(|p| check_protocol(p, depth, None).expect("PROTOCOLS entries are known"))
        .collect()
}

/// Machine-readable report for `voltra check --json`: same shape family
/// as `plan::verify::findings_json` — a top-level summary plus one
/// object per protocol with its findings and counterexample traces.
pub fn report_json(reports: &[CheckReport]) -> Json {
    let mut root = std::collections::BTreeMap::new();
    let total: usize = reports.iter().map(|r| r.findings.len()).sum();
    root.insert("protocols".into(), Json::Num(reports.len() as f64));
    root.insert("findings".into(), Json::Num(total as f64));
    root.insert(
        "clean".into(),
        Json::Bool(total == 0 && reports.iter().all(|r| !r.truncated)),
    );
    let protos = reports
        .iter()
        .map(|r| {
            let mut o = std::collections::BTreeMap::new();
            o.insert("protocol".into(), Json::Str(r.protocol.into()));
            o.insert("states".into(), Json::Num(r.states as f64));
            o.insert("max_depth".into(), Json::Num(r.max_depth as f64));
            o.insert("truncated".into(), Json::Bool(r.truncated));
            let findings = r
                .findings
                .iter()
                .map(|f| {
                    let mut fo = std::collections::BTreeMap::new();
                    fo.insert("id".into(), Json::Str(f.id.into()));
                    fo.insert("detail".into(), Json::Str(f.detail.clone()));
                    fo.insert(
                        "trace".into(),
                        Json::Arr(f.trace.iter().map(|s| Json::Str(s.clone())).collect()),
                    );
                    Json::Obj(fo)
                })
                .collect();
            o.insert("findings".into(), Json::Arr(findings));
            Json::Obj(o)
        })
        .collect();
    root.insert("by_protocol".into(), Json::Arr(protos));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_tree_has_zero_findings_and_full_coverage() {
        for report in check_all(DEFAULT_DEPTH) {
            assert!(
                report.findings.is_empty(),
                "{}: {:?}",
                report.protocol,
                report.findings
            );
            assert!(!report.truncated, "{} truncated", report.protocol);
            assert!(report.states > 0);
        }
    }

    #[test]
    fn unknown_protocol_is_none() {
        assert!(check_protocol("warp-drive", DEFAULT_DEPTH, None).is_none());
    }

    #[test]
    fn mutation_catalog_is_consistent() {
        let all = Mutation::all();
        assert!(all.len() >= 10, "rig floor: >= 10 mutations");
        let protocols: std::collections::HashSet<_> = all.iter().map(|m| m.protocol()).collect();
        assert!(protocols.len() >= 4, "rig floor: >= 4 protocols");
        let ids: std::collections::HashSet<_> = all.iter().map(|m| m.id()).collect();
        assert_eq!(ids.len(), all.len(), "mutation ids must be unique");
        for m in all {
            assert!(PROTOCOLS.contains(&m.protocol()), "{} unknown", m.id());
        }
    }

    #[test]
    fn report_json_shape() {
        let reports = check_all(DEFAULT_DEPTH);
        let j = report_json(&reports);
        let txt = j.render();
        assert!(txt.contains("\"clean\":true"), "{txt}");
        assert!(txt.contains("\"by_protocol\""));
        assert!(txt.contains("\"lockorder\""));
    }
}
