//! The deterministic-interleaving scheduler: exhaustive DFS over the
//! bounded interleavings of an explicit protocol [`Model`].
//!
//! A model is a small, cloneable, hashable state machine: `threads()`
//! logical threads, each with a program counter, stepping over shared
//! state. The scheduler owns *all* nondeterminism — at every state it
//! forks one child per enabled thread and recurses, so every reachable
//! interleaving (up to the depth bound) is visited exactly once:
//!
//! * **Pruning** is by state fingerprint (the model's `Hash`): two
//!   schedules that converge on the same state share their subtree.
//!   This is what makes exhaustive exploration tractable — the state
//!   *graph* is small even when the schedule *tree* is astronomical.
//! * **Invariants** are checked in every distinct state; a violation
//!   reports the schedule that reached it (the counterexample trace).
//! * **Deadlock** is structural: a state where some thread is not done
//!   yet *no* thread is enabled. Lost-wakeup bugs surface here — a
//!   waiter whose notify was dropped is permanently disabled.
//! * **Quiescence checks** run in states where every thread is done —
//!   the place end-to-end accounting invariants (`hits + misses +
//!   coalesced == calls`) belong.
//!
//! Spurious condvar wakeups are modeled *inside* the models (a parked
//! thread holds a small spurious-wake budget), not here: the scheduler
//! treats them as ordinary enabled transitions, which is exactly the
//! adversarial semantics — a wakeup may arrive at any moment, and
//! correctness may never depend on one arriving.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashSet;
use std::hash::{Hash, Hasher};

/// One invariant violation: a stable finding id (what the mutation rig
/// pins against) plus human-readable detail.
#[derive(Clone, Debug)]
pub struct Violation {
    pub id: &'static str,
    pub detail: String,
}

impl Violation {
    pub(crate) fn new(id: &'static str, detail: impl Into<String>) -> Self {
        Violation {
            id,
            detail: detail.into(),
        }
    }
}

/// A violation together with the schedule that produced it.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Which protocol model found it.
    pub protocol: &'static str,
    /// Stable finding id (`deadlock`, `accounting`, `plan-once`, …).
    pub id: &'static str,
    pub detail: String,
    /// The counterexample: one `t<i>: <label>` line per scheduled step,
    /// in order, from the initial state to the violating one.
    pub trace: Vec<String>,
}

/// An explicit protocol model the scheduler can explore. `Clone` forks
/// the state at scheduling points; `Hash` is the fingerprint for
/// visited-set pruning (hash ALL mutable state, or the pruning is
/// unsound).
pub(crate) trait Model: Clone + Hash {
    /// Number of logical threads (fixed for the model's lifetime).
    fn threads(&self) -> usize;
    /// Thread `t` has terminated.
    fn done(&self, t: usize) -> bool;
    /// Thread `t` can take a step from this state. A parked waiter with
    /// no pending notify (and no spurious budget) must report `false` —
    /// that is what lets the scheduler see lost wakeups as deadlocks.
    fn enabled(&self, t: usize) -> bool;
    /// Execute thread `t`'s next step, returning its trace label.
    /// Called only when `enabled(t)`.
    fn step(&mut self, t: usize) -> String;
    /// Safety invariant, checked in every distinct reachable state.
    fn invariant(&self) -> Result<(), Violation>;
    /// End-to-end invariant, checked when every thread is done.
    fn at_quiescence(&self) -> Result<(), Violation>;
}

/// Exploration statistics for one model run.
#[derive(Clone, Copy, Debug)]
pub struct Exploration {
    /// Distinct states visited.
    pub states: u64,
    /// Longest schedule explored.
    pub max_depth: usize,
    /// Some branch hit the depth bound before quiescing (coverage is
    /// incomplete — raise `--depth`).
    pub truncated: bool,
}

struct Ctx<'a> {
    protocol: &'static str,
    depth_limit: usize,
    seen: HashSet<u64>,
    path: Vec<String>,
    stats: Exploration,
    findings: &'a mut Vec<Finding>,
    /// Finding ids already reported for this protocol: the first
    /// counterexample per id is kept, later ones are duplicates of the
    /// same bug.
    reported: HashSet<&'static str>,
}

impl Ctx<'_> {
    fn report(&mut self, v: Violation) {
        if self.reported.insert(v.id) {
            self.findings.push(Finding {
                protocol: self.protocol,
                id: v.id,
                detail: v.detail,
                trace: self.path.clone(),
            });
        }
    }
}

fn fingerprint<M: Model>(m: &M) -> u64 {
    let mut h = DefaultHasher::new();
    m.hash(&mut h);
    h.finish()
}

/// Exhaustively explore `initial` to `depth_limit` scheduled steps,
/// appending every distinct violation (first counterexample per finding
/// id) to `findings`.
pub(crate) fn explore<M: Model>(
    protocol: &'static str,
    initial: &M,
    depth_limit: usize,
    findings: &mut Vec<Finding>,
) -> Exploration {
    let mut ctx = Ctx {
        protocol,
        depth_limit,
        seen: HashSet::new(),
        path: Vec::new(),
        stats: Exploration {
            states: 0,
            max_depth: 0,
            truncated: false,
        },
        findings,
        reported: HashSet::new(),
    };
    dfs(initial, 0, &mut ctx);
    ctx.stats
}

fn dfs<M: Model>(m: &M, depth: usize, ctx: &mut Ctx<'_>) {
    if !ctx.seen.insert(fingerprint(m)) {
        return;
    }
    ctx.stats.states += 1;
    ctx.stats.max_depth = ctx.stats.max_depth.max(depth);
    if let Err(v) = m.invariant() {
        ctx.report(v);
        return; // a corrupted state's futures are not interesting
    }
    let enabled: Vec<usize> = (0..m.threads())
        .filter(|&t| !m.done(t) && m.enabled(t))
        .collect();
    if enabled.is_empty() {
        if (0..m.threads()).all(|t| m.done(t)) {
            if let Err(v) = m.at_quiescence() {
                ctx.report(v);
            }
        } else {
            let stuck: Vec<String> = (0..m.threads())
                .filter(|&t| !m.done(t))
                .map(|t| format!("t{t}"))
                .collect();
            ctx.report(Violation::new(
                "deadlock",
                format!("no runnable thread; stuck: {}", stuck.join(", ")),
            ));
        }
        return;
    }
    if depth >= ctx.depth_limit {
        ctx.stats.truncated = true;
        return;
    }
    for t in enabled {
        let mut child = m.clone();
        let label = child.step(t);
        ctx.path.push(format!("t{t}: {label}"));
        dfs(&child, depth + 1, ctx);
        ctx.path.pop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two threads incrementing a shared counter through a "register"
    /// (load, then store) — the canonical lost-update race when the
    /// load/store pair is not atomic.
    #[derive(Clone, Hash)]
    struct RacyIncrement {
        counter: u8,
        regs: [Option<u8>; 2],
        pc: [u8; 2], // 0 = load, 1 = store, 2 = done
        atomic: bool,
    }

    impl Model for RacyIncrement {
        fn threads(&self) -> usize {
            2
        }
        fn done(&self, t: usize) -> bool {
            self.pc[t] == 2
        }
        fn enabled(&self, t: usize) -> bool {
            self.pc[t] < 2
        }
        fn step(&mut self, t: usize) -> String {
            if self.atomic {
                self.counter += 1;
                self.pc[t] = 2;
                return "fetch_add".into();
            }
            match self.pc[t] {
                0 => {
                    self.regs[t] = Some(self.counter);
                    self.pc[t] = 1;
                    "load".into()
                }
                _ => {
                    self.counter = self.regs[t].expect("loaded") + 1;
                    self.pc[t] = 2;
                    "store".into()
                }
            }
        }
        fn invariant(&self) -> Result<(), Violation> {
            Ok(())
        }
        fn at_quiescence(&self) -> Result<(), Violation> {
            if self.counter == 2 {
                Ok(())
            } else {
                Err(Violation::new(
                    "lost-update",
                    format!("counter == {} after two increments", self.counter),
                ))
            }
        }
    }

    fn racy(atomic: bool) -> RacyIncrement {
        RacyIncrement {
            counter: 0,
            regs: [None; 2],
            pc: [0; 2],
            atomic,
        }
    }

    #[test]
    fn atomic_increment_explores_clean() {
        let mut findings = Vec::new();
        let ex = explore("demo", &racy(true), 16, &mut findings);
        assert!(findings.is_empty(), "{findings:?}");
        assert!(ex.states >= 3);
        assert!(!ex.truncated);
    }

    #[test]
    fn torn_increment_is_found_with_a_trace() {
        let mut findings = Vec::new();
        explore("demo", &racy(false), 16, &mut findings);
        assert_eq!(findings.len(), 1, "{findings:?}");
        let f = &findings[0];
        assert_eq!(f.id, "lost-update");
        // The counterexample must interleave the loads before either
        // store — both threads read 0.
        assert_eq!(f.trace.len(), 4, "{:?}", f.trace);
        assert!(f.trace[0].ends_with("load") && f.trace[1].ends_with("load"));
    }

    #[test]
    fn depth_bound_reports_truncation() {
        let mut findings = Vec::new();
        let ex = explore("demo", &racy(true), 1, &mut findings);
        assert!(ex.truncated);
    }

    /// A thread that is never enabled and never done is a deadlock.
    #[derive(Clone, Hash)]
    struct Stuck;

    impl Model for Stuck {
        fn threads(&self) -> usize {
            1
        }
        fn done(&self, _t: usize) -> bool {
            false
        }
        fn enabled(&self, _t: usize) -> bool {
            false
        }
        fn step(&mut self, _t: usize) -> String {
            unreachable!("never enabled")
        }
        fn invariant(&self) -> Result<(), Violation> {
            Ok(())
        }
        fn at_quiescence(&self) -> Result<(), Violation> {
            Ok(())
        }
    }

    #[test]
    fn permanently_blocked_thread_is_a_deadlock() {
        let mut findings = Vec::new();
        explore("demo", &Stuck, 4, &mut findings);
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].id, "deadlock");
        assert!(findings[0].detail.contains("t0"));
    }
}
