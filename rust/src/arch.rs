//! Architectural constants of the Voltra chip, straight from the paper.
//!
//! Everything here is a *published* number (Sec. II, Fig. 5, Table I);
//! derived quantities carry the derivation in their doc comment.

/// Spatial unrolling of output rows in the 3D array (Sec. II-A).
pub const ARRAY_M: usize = 8;
/// Spatial unrolling of output columns (the 8x8 Dot-ProdU grid).
pub const ARRAY_N: usize = 8;
/// Dot-product width inside one Dot-ProdU.
pub const ARRAY_K: usize = 8;
/// Total MAC units: 8 x 8 x 8 = 512 (Table I "MAC Counts").
pub const MACS: usize = ARRAY_M * ARRAY_N * ARRAY_K;

/// Shared data memory banks (Sec. II: "32 banks, 64-bit width each").
pub const NUM_BANKS: usize = 32;
/// Bank word width in bits.
pub const BANK_WIDTH_BITS: usize = 64;
/// Bank word width in bytes.
pub const BANK_WIDTH_BYTES: usize = BANK_WIDTH_BITS / 8;
/// Banks combined into one super bank for the weight streamer (Sec. II-B).
pub const SUPER_BANK_BANKS: usize = 8;
/// Super-bank width in bytes: 512 bit.
pub const SUPER_BANK_BYTES: usize = SUPER_BANK_BANKS * BANK_WIDTH_BYTES;

/// On-chip data memory (Fig. 5: "128(D)" KB).
pub const DATA_MEM_BYTES: usize = 128 * 1024;
/// On-chip instruction memory (Fig. 5: "6(I)" KB).
pub const INSTR_MEM_BYTES: usize = 6 * 1024;
/// Words per bank: 128 KiB / 32 banks / 8 B.
pub const BANK_WORDS: usize = DATA_MEM_BYTES / NUM_BANKS / BANK_WIDTH_BYTES;

/// Streamer FIFO depth for input and weight streams (Sec. II-B).
pub const STREAM_FIFO_DEPTH: usize = 8;
/// FIFO depth for the partial-sum and output streams (output stationarity
/// makes deeper queues useless — Sec. II-B).
pub const PSUM_FIFO_DEPTH: usize = 1;

/// Quantization SIMD lanes (Sec. II-D: "only eight quantization PE lanes").
pub const SIMD_LANES: usize = 8;
/// Outputs produced by one 8x8 output-stationary tile.
pub const TILE_OUTPUTS: usize = ARRAY_M * ARRAY_N;

/// Number of flexible data streamers (Sec. II-B: "seven flexible data
/// streamers"): GEMM input / weight / psum / output, SIMD in / out,
/// reshuffler.
pub const NUM_STREAMERS: usize = 7;

/// Input-streamer AGU dimensionality (Sec. II-B: 6-D affine access).
pub const INPUT_AGU_DIMS: usize = 6;
/// Weight-streamer AGU dimensionality (Sec. II-B: 3-D).
pub const WEIGHT_AGU_DIMS: usize = 3;

/// Die area in mm^2 (Fig. 5).
pub const CORE_AREA_MM2: f64 = 0.654;
/// Operating voltage range (Fig. 5).
pub const VMIN: f64 = 0.6;
pub const VMAX: f64 = 1.0;
/// Frequency range in MHz (Fig. 5).
pub const FMIN_MHZ: f64 = 300.0;
pub const FMAX_MHZ: f64 = 800.0;

/// Peak throughput at INT8: 512 MACs x 2 ops x 800 MHz = 0.8192 TOPS
/// (Table I reports 0.82).
pub const PEAK_TOPS: f64 = (MACS as f64) * 2.0 * FMAX_MHZ * 1e6 / 1e12;

/// Published efficiency headlines (Fig. 5 / Table I) used as calibration
/// targets by `power::energy` — never read back as results.
pub const PAPER_PEAK_TOPS_W: f64 = 1.60;
pub const PAPER_PEAK_TOPS_MM2: f64 = 1.25;
pub const PAPER_POWER_MIN_MW: f64 = 171.0;
pub const PAPER_POWER_MAX_MW: f64 = 981.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_count_matches_table1() {
        assert_eq!(MACS, 512);
    }

    #[test]
    fn memory_geometry() {
        assert_eq!(BANK_WORDS, 512);
        assert_eq!(NUM_BANKS * BANK_WORDS * BANK_WIDTH_BYTES, 128 * 1024);
        assert_eq!(SUPER_BANK_BYTES, 64);
    }

    #[test]
    fn peak_throughput_matches_table1() {
        // Table I: 0.82 TOPS at INT8.
        assert!((PEAK_TOPS - 0.8192).abs() < 1e-9);
        assert!((PEAK_TOPS - 0.82).abs() < 0.01);
    }

    #[test]
    fn area_efficiency_is_consistent() {
        // 0.8192 TOPS / 0.654 mm^2 = 1.2526 TOPS/mm^2 — Table I's 1.25.
        let ae = PEAK_TOPS / CORE_AREA_MM2;
        assert!((ae - PAPER_PEAK_TOPS_MM2).abs() < 0.01);
    }
}
