//! Per-layer planning: the tiling search, K-round variant expansion,
//! tile-run emission and DMA attribution that used to live inline in the
//! coordinator's ~250-line `run_layer_planned` monolith.
//!
//! For each GEMM of a layer:
//!   1. resolve the array mapping — M/N permutation + K-extension fold —
//!      together with its induced tiling through the process-wide
//!      mapper cache ([`crate::tiling::mapper`], DESIGN.md §11);
//!   3. enumerate the distinct tile shapes (interior/edge x first/mid/
//!      last K-round), cycle-simulate each once and scale by its count;
//!   4. charge auxiliary cycles (Snitch CSR programming per tile,
//!      reshuffler passes for raw-layout feature maps);
//!   5. emit the dispatched tile sequence as per-GEMM [`TilePlan`]s with
//!      byte-proportional DMA shares, ready for the event-driven
//!      pipeline scheduler.
//!
//! The output is a [`LayerPlan`] with a default residency decision; the
//! workload-level [`super::residency`] pass fills that in afterwards.

use crate::config::ChipConfig;
use crate::coordinator::{tile_csr_cycles, SimCache};
use crate::metrics::LayerMetrics;
use crate::sim::dma::transfer_cost;
use crate::sim::engine::TileSpec;
use crate::sim::pipeline::{self, TilePlan, TileRun};
use crate::sim::reshuffler::reshuffle_cycles;
use crate::tiling::engine::traffic_parts;
use crate::tiling::mapper::IncrementalMapper;
use crate::workloads::{Layer, LayerKind};

use super::{LayerPlan, ResidencyDecision};

/// Bytes of feature map a conv layer must reshuffle (HWC -> C/8HWC8).
pub(crate) fn reshuffle_bytes(layer: &Layer) -> u64 {
    match layer.kind {
        LayerKind::Conv2d {
            h, w, cin, kh, kw, ..
        } if kh * kw > 1 => h * w * cin.div_ceil(8) * 8,
        _ => 0,
    }
}

/// Dimension residues of round `i` over tiles of `t` covering `d`.
fn edge(d: u64, t: u64) -> (u64, u64, u64) {
    // (interior_count, edge_count, edge_size)
    let full = d / t;
    let rem = d % t;
    if rem == 0 {
        (full, 0, 0)
    } else {
        (full, 1, rem)
    }
}

/// Off-chip traffic bytes one GEMM moves under its resolved tiling —
/// the planner's DMA byte envelope and the single authority the static
/// verifier re-derives ([`super::verify`], rule `dma-byte-conservation`).
/// `g` is the *post-swap* GEMM (the orientation the tiling was sized
/// for). PDMA weight residency: if the whole weight operand fits in the
/// memory the organisation can give it, recurrent repeats stream the
/// weights once instead of every step. The separated baseline is capped
/// by its fixed weight buffer.
pub(crate) fn gemm_traffic_bytes(
    cfg: &ChipConfig,
    g: &crate::workloads::GemmOp,
    tiling: &crate::tiling::Tiling,
) -> u64 {
    let parts = traffic_parts(g.m, g.k, g.n, tiling.tm, tiling.tk, tiling.tn);
    let weight_budget = match cfg.memory {
        crate::config::MemoryOrg::Shared => 3 * cfg.memory.total_bytes() as u64 / 4,
        crate::config::MemoryOrg::Separated { weight, .. } => weight as u64,
    };
    let w_groups = g.repeat / g.weight_reuse.max(1);
    if g.weight_reuse > 1 && g.k * g.n <= weight_budget {
        (parts.input + parts.psum + parts.output) * g.repeat + parts.weight * w_groups
    } else {
        parts.total() * g.repeat
    }
}

/// Split one GEMM's DMA cycles across its tile runs proportional to the
/// raw bytes each tile variant moves (operands in, psums in/out, results
/// out) — integer-exact via [`pipeline::DmaSplitter`]: the run totals
/// sum to `total_dma`, so the scheduler's DMA busy time equals the
/// layer's accounted DMA cycles. `raw` entries are
/// `(count, compute_cycles_per_tile, bytes_per_tile)`.
fn attribute_dma(raw: &[(u64, u64, u64)], total_dma: u64) -> Vec<TileRun> {
    let mut total_weight: u128 = raw.iter().map(|&(c, _, b)| c as u128 * b as u128).sum();
    // Degenerate zero-byte variants (tiling never emits them): fall back
    // to uniform attribution so no DMA time is dropped.
    let uniform = total_weight == 0;
    if uniform {
        total_weight = raw.iter().map(|&(c, _, _)| c as u128).sum();
    }
    let mut runs = Vec::with_capacity(raw.len() + 1);
    let mut split = pipeline::DmaSplitter::new(total_weight, total_dma);
    for &(count, compute, bytes) in raw {
        split.push(&mut runs, count, compute, if uniform { 1 } else { bytes });
    }
    runs
}

/// Plan one layer: tiling + memoized tile simulation + DMA attribution,
/// emitted as an immutable [`LayerPlan`] (residency decision defaulted;
/// the workload pass owns it). Mapping resolutions go through a fresh
/// incremental view of the process-wide mapper cache; callers planning
/// many layers in sequence should hold their own [`IncrementalMapper`]
/// and use [`plan_layer_mapped`] so the hint survives across layers.
pub fn plan_layer<C: SimCache>(cfg: &ChipConfig, layer: &Layer, cache: &mut C) -> LayerPlan {
    plan_layer_mapped(cfg, layer, cache, &mut IncrementalMapper::global())
}

/// [`plan_layer`] with an injected mapper handle: the hint chain of an
/// [`IncrementalMapper`] spans layers, so a planner walking a workload
/// seeds each layer's mapping search with the previous layer's winner
/// (DESIGN.md §12). Results are identical to [`plan_layer`] — the
/// seeding only prunes the search.
pub fn plan_layer_mapped<C: SimCache>(
    cfg: &ChipConfig,
    layer: &Layer,
    cache: &mut C,
    mapper: &mut IncrementalMapper<'_>,
) -> LayerPlan {
    let mut plan = LayerPlan {
        name: layer.name.clone(),
        tiles: Default::default(),
        macs: 0,
        aux_cycles: 0,
        dma_bytes: 0,
        dma_cycles: 0,
        tile_footprint_bytes: 0,
        dispatched_tiles: 0,
        latency_cycles: 0,
        overlap_cycles: 0,
        timeline: pipeline::LayerPlan::default(),
        residency: ResidencyDecision::default(),
        mappings: Vec::new(),
    };

    for mut g in layer.gemms() {
        // Resolve how this GEMM sits on the array — permutation +
        // K-extension fold — together with the tiling that placement
        // induces, through the process-wide mapper cache (DESIGN.md §11).
        let Some((mapping, tiling)) = mapper.resolve(cfg, g.m, g.k, g.n) else {
            continue; // cannot fit: skipped (never happens: 8x8x8 always fits)
        };
        if mapping.swapped {
            std::mem::swap(&mut g.m, &mut g.n);
        }
        plan.mappings.push(mapping);
        let nk = tiling.k_rounds(g.k);
        let (m_int, m_edge, m_rem) = edge(g.m, tiling.tm);
        let (k_int, k_edge, k_rem) = edge(g.k, tiling.tk);
        let (n_int, n_edge, n_rem) = edge(g.n, tiling.tn);

        let m_variants = [(tiling.tm, m_int), (m_rem, m_edge)];
        let n_variants = [(tiling.tn, n_int), (n_rem, n_edge)];
        // K-round variants: (size, count, psum_in, spill_out).
        let mut k_variants: Vec<(u64, u64, bool, bool)> = Vec::new();
        {
            let k_sizes = [(tiling.tk, k_int), (k_rem, k_edge)];
            let last_is_edge = k_edge == 1;
            for (i, &(sz, cnt)) in k_sizes.iter().enumerate() {
                if cnt == 0 {
                    continue;
                }
                let is_edge_slot = i == 1;
                if nk == 1 {
                    k_variants.push((sz, cnt, false, false));
                } else if is_edge_slot {
                    // The edge K-round is always the last.
                    k_variants.push((sz, cnt, true, false));
                } else {
                    // Interior rounds: the first has no psum-in; the last
                    // interior one quantizes only if there is no edge.
                    let mut first = 1u64.min(cnt);
                    let mut last = if last_is_edge {
                        0
                    } else {
                        1u64.min(cnt.saturating_sub(first))
                    };
                    if cnt == 1 && !last_is_edge {
                        // Single interior round that is both first & last.
                        first = 1;
                        last = 0;
                        k_variants.push((sz, 1, false, false));
                        continue;
                    }
                    if first > 0 {
                        k_variants.push((sz, first, false, true));
                    }
                    let mid = cnt - first - last;
                    if mid > 0 {
                        k_variants.push((sz, mid, true, true));
                    }
                    if last > 0 {
                        k_variants.push((sz, last, true, false));
                    }
                }
            }
        }

        let pl = tiling.placement;
        // Control overhead: one CSR program per dispatched tile (part of
        // the tile engine's per-tile busy time in the schedule).
        let csr_cycles = tile_csr_cycles(tiling.tk);
        let mut dispatched = 0u64;
        // (count, per-tile compute cycles, per-tile raw bytes) per
        // variant, in dispatch order — the scheduler's tile runs.
        let mut raw_runs: Vec<(u64, u64, u64)> = Vec::new();
        for &(tm, mc) in &m_variants {
            if mc == 0 {
                continue;
            }
            for &(tn, nc) in &n_variants {
                if nc == 0 {
                    continue;
                }
                for &(tk, kc, psum_in, spill_out) in &k_variants {
                    if kc == 0 {
                        continue;
                    }
                    let spec = TileSpec {
                        tm,
                        tk,
                        tn,
                        psum_in,
                        spill_out,
                        input_blocked: !g.raw_input,
                        fold: mapping.fold,
                        in_base: pl.input_base,
                        w_base: pl.weight_base,
                        p_base: pl.psum_base,
                        o_base: pl.output_base,
                    };
                    let tmetrics = cache.simulate(cfg, &spec);
                    let count = mc * nc * kc * g.repeat;
                    plan.tiles.add_scaled(&tmetrics, count);
                    dispatched += count;
                    // Raw byte weight of this variant for DMA
                    // attribution: operand tiles in, int32 psums
                    // round-tripped, results out.
                    let psum_bytes = if psum_in { 4 * tm * tn } else { 0 };
                    let out_bytes = if spill_out { 4 * tm * tn } else { tm * tn };
                    let tile_bytes = tm * tk + tk * tn + psum_bytes + out_bytes;
                    raw_runs.push((count, tmetrics.total_cycles + csr_cycles, tile_bytes));
                }
            }
        }

        plan.dispatched_tiles += dispatched;
        plan.aux_cycles += dispatched * csr_cycles;
        let gemm_traffic = gemm_traffic_bytes(cfg, &g, &tiling);
        plan.dma_bytes += gemm_traffic;
        plan.tile_footprint_bytes = plan.tile_footprint_bytes.max(tiling.footprint.total() as u64);
        plan.macs += g.macs();

        // DMA timing: bandwidth-limited, plus per-tile burst setup — a
        // config that tiles finer (separated buffers) pays more burst
        // overhead for the same bytes. The total is attributed across
        // this GEMM's tile runs so the scheduler can interleave it with
        // compute at tile granularity.
        let t = transfer_cost(cfg, gemm_traffic);
        let gemm_dma_cycles = t.cycles + dispatched * cfg.dma_burst_latency;
        plan.dma_cycles += gemm_dma_cycles;
        plan.timeline.gemms.push(TilePlan {
            runs: attribute_dma(&raw_runs, gemm_dma_cycles),
            // Ping-pong regions exist only when the allocator granted
            // double-buffer space for THIS GEMM — per-GEMM, never
            // inherited from whichever GEMM the layer lowered last.
            double_buffered: tiling.double_buffered && cfg.double_buffer,
        });
    }

    // Reshuffler pass for raw conv feature maps (serial, before the
    // tile timeline can stream the blocked layout).
    let rb = reshuffle_bytes(layer);
    if rb > 0 {
        plan.timeline.reshuffle_cycles = reshuffle_cycles(rb) * layer.repeat;
        plan.aux_cycles += plan.timeline.reshuffle_cycles;
    }

    // Resolve the timeline once, at plan time — execution is then a
    // pure field copy (the residency pass re-resolves chained layers).
    plan.reschedule();
    plan
}

/// Plan and immediately resolve one standalone layer (no workload-level
/// residency): the engine behind the coordinator's [`run_layer`]
/// convenience APIs and the server's per-request sim cost.
///
/// [`run_layer`]: crate::coordinator::run_layer
pub(crate) fn plan_layer_metrics<C: SimCache>(
    cfg: &ChipConfig,
    layer: &Layer,
    cache: &mut C,
) -> (LayerMetrics, u64) {
    let plan = plan_layer(cfg, layer, cache);
    let dispatched = plan.dispatched_tiles;
    (plan.resolve(), dispatched)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::TileCache;

    #[test]
    fn pool_layer_plans_to_empty_timeline() {
        let cfg = ChipConfig::voltra();
        let l = Layer::new(
            "pool",
            LayerKind::Pool {
                h: 112,
                w: 112,
                c: 64,
                window: 3,
                stride: 2,
            },
        );
        let mut cache = TileCache::new();
        let p = plan_layer(&cfg, &l, &mut cache);
        assert!(p.timeline.gemms.is_empty());
        assert_eq!(p.macs, 0);
        assert_eq!(p.dispatched_tiles, 0);
    }

    #[test]
    fn run_dma_shares_sum_to_layer_dma() {
        let cfg = ChipConfig::voltra();
        let l = Layer::new(
            "g",
            LayerKind::Gemm {
                m: 512,
                k: 8192,
                n: 256,
            },
        );
        let mut cache = TileCache::new();
        let p = plan_layer(&cfg, &l, &mut cache);
        let run_dma: u64 = p
            .timeline
            .gemms
            .iter()
            .flat_map(|g| g.runs.iter())
            .map(|r| r.count * r.dma_cycles)
            .sum();
        assert_eq!(run_dma, p.dma_cycles);
        let run_tiles: u64 = p
            .timeline
            .gemms
            .iter()
            .flat_map(|g| g.runs.iter())
            .map(|r| r.count)
            .sum();
        assert_eq!(run_tiles, p.dispatched_tiles);
    }

    #[test]
    fn plan_records_the_resolved_mapping_per_gemm() {
        let cfg = ChipConfig::voltra();
        let l = Layer::new(
            "gemv",
            LayerKind::Gemm {
                m: 1,
                k: 3072,
                n: 3072,
            },
        );
        let mut cache = TileCache::new();
        let p = plan_layer(&cfg, &l, &mut cache);
        assert_eq!(p.mappings.len(), 1);
        assert_eq!(p.mappings[0].fold, 8, "GEMV plans under K-extension");
        assert_eq!(p.mapping_summary(), "1x8x64");
        // And the planned tiles carry the fold into the cycle engine:
        // full spatial fill instead of the 12.5% row-idle floor.
        assert!(p.tiles.spatial_utilization() > 0.99);
    }

    #[test]
    fn fused_layer_keeps_per_gemm_grants() {
        let cfg = ChipConfig::voltra();
        let l = Layer::new(
            "fused",
            LayerKind::Fused(vec![(512, 768, 768), (64, 64, 64)]),
        );
        let mut cache = TileCache::new();
        let p = plan_layer(&cfg, &l, &mut cache);
        assert_eq!(p.timeline.gemms.len(), 2);
        // The big GEMM cannot ping-pong in 128 KiB; the small one can.
        assert!(!p.timeline.gemms[0].double_buffered);
        assert!(p.timeline.gemms[1].double_buffered);
    }
}
