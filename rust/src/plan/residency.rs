//! The shared-memory residency pass (DESIGN.md §10).
//!
//! PDMA's layer-chaining benefit (Fig. 4): with the shared organisation,
//! a layer's output region simply *becomes* the next layer's input
//! region — a streamer base-pointer update — whenever the dynamic
//! allocator can keep it on chip next to the live tiles. The separated
//! organisation must round-trip the activation through off-chip memory
//! because the output buffer is not the input buffer.
//!
//! This pass walks the planned layer sequence once and models the shared
//! space as a two-region dynamic allocator:
//!
//! * the **working region** — at least half the space is always held
//!   back for the live tile footprints and their double-buffer
//!   (ping-pong) grants, which the tiling search sized against the full
//!   organisation; PDMA re-partitions it per layer via base pointers;
//! * the **activation region** — whatever activation the previous layer
//!   left resident competes for the remainder. An activation larger
//!   than the region is evicted (it cannot sit next to any layer's
//!   working set), and a consumer can chain at most the bytes the
//!   region can hold.
//!
//! Decisions are *recorded in the plan* ([`ResidencyDecision`]) and the
//! chained layers' tile-run DMA shares are re-scaled right here — the
//! executor never mutates metrics after the fact (the old coordinator
//! heuristic patched `LayerMetrics` post-hoc).
//!
//! Chaining semantics: the chain saves the predecessor's output write
//! plus this layer's input read, once per layer *invocation* (recurrent
//! steps re-chain every iteration), and can trim at most half the
//! layer's off-chip traffic — weights and psum spills still move.

use crate::config::{ChipConfig, MemoryOrg};
use crate::sim::pipeline;
use crate::workloads::{Layer, LayerKind};

use super::LayerPlan;

/// Activation bytes a layer produces (what the next layer consumes).
///
/// Mirror of the [`activation_in_bytes`] fused rule: only the LAST GEMM
/// of a fused bundle produces the activation the successor reads — the
/// earlier outputs are on-chip intermediates consumed inside the layer.
pub(crate) fn activation_out_bytes(layer: &Layer) -> u64 {
    if let LayerKind::Fused(ref gemms) = layer.kind {
        return gemms.last().map(|&(m, _, n)| m * n).unwrap_or(0);
    }
    layer
        .gemms()
        .iter()
        .map(|g| g.m * g.n * g.repeat / layer.repeat.max(1))
        .sum()
}

/// Activation bytes a layer consumes from its predecessor.
///
/// For [`LayerKind::Fused`] only the FIRST GEMM reads the predecessor's
/// activation — the later GEMMs of the bundle consume on-chip
/// intermediates produced inside the layer — so chaining must not count
/// their inputs (summing every `m * k` overcounted the savings).
pub(crate) fn activation_in_bytes(layer: &Layer) -> u64 {
    match layer.kind {
        LayerKind::Conv2d { h, w, cin, .. } => h * w * cin,
        LayerKind::DepthwiseConv { h, w, c, .. } => h * w * c,
        LayerKind::Gemm { m, k, .. } => m * k,
        LayerKind::BatchedMatmul { batch, m, k, .. } => batch * m * k,
        LayerKind::Fused(ref gemms) => gemms.first().map(|&(m, k, _)| m * k).unwrap_or(0),
        LayerKind::Pool { h, w, c, .. } => h * w * c,
    }
}

/// The activation region's capacity: whatever the two-region allocator
/// does not hold back for live tiles + ping-pong grants.
pub(crate) fn activation_region_bytes(cfg: &ChipConfig) -> u64 {
    let capacity = cfg.memory.total_bytes() as u64;
    capacity - capacity / 2
}

/// The pure per-layer chaining decision: given the activation bytes the
/// predecessor left resident and this layer's planned DMA envelope,
/// return the [`ResidencyDecision`] plus the trimmed `(dma_bytes,
/// dma_cycles)` totals. This is the single authority replayed by the
/// static verifier ([`super::verify`], rule `residency-legality`), so
/// [`apply`] must stay a thin driver around it.
///
/// Saved bytes: the predecessor's output write + our input read, once
/// per layer invocation (not per repeat: recurrent steps re-chain every
/// iteration), capped at half the layer's off-chip traffic — weights and
/// psum spills still move. The product saturates: a pathological repeat
/// count must degrade to the cap, never wrap back into a small savings.
pub(crate) fn decide(
    cfg: &ChipConfig,
    layer: &Layer,
    resident_in: u64,
    dma_bytes: u64,
    dma_cycles: u64,
) -> (ResidencyDecision, u64, u64) {
    let activation_region = activation_region_bytes(cfg);
    let a_in = activation_in_bytes(layer);
    let chained = resident_in.min(a_in);
    // The eviction rule below already bounds what stays resident, so a
    // chained region can never exceed the activation region.
    debug_assert!(chained <= activation_region);
    let saved = 2u64
        .saturating_mul(chained)
        .saturating_mul(layer.repeat)
        .min(dma_bytes / 2);
    let mut decision = ResidencyDecision::default();
    let mut new_bytes = dma_bytes;
    let mut new_cycles = dma_cycles;
    // A chain is only recorded when it removes actual traffic — a
    // zero-DMA layer (e.g. Pool) passing its input through must not
    // inflate the chained-bytes metric.
    if saved > 0 {
        let saved_cycles = saved.div_ceil(cfg.dma_bytes_per_cycle.max(1));
        new_cycles = dma_cycles.saturating_sub(saved_cycles);
        decision.chained_bytes = chained;
        decision.saved_dma_bytes = saved;
        decision.saved_dma_cycles = dma_cycles - new_cycles;
        new_bytes = dma_bytes - saved;
    }
    // What this layer leaves behind: its output stays resident only if
    // the activation region can hold it (next to the successor's working
    // set); otherwise it is evicted to DRAM.
    let out = activation_out_bytes(layer);
    decision.resident_out_bytes = if out <= activation_region { out } else { 0 };
    (decision, new_bytes, new_cycles)
}

/// Run the residency pass over a planned layer sequence, recording the
/// chaining decisions and folding the saved transfers into each chained
/// layer's timeline. `layers` and `plans` are parallel (one plan per
/// workload layer, in order).
pub fn apply(cfg: &ChipConfig, layers: &[Layer], plans: &mut [LayerPlan]) {
    if !matches!(cfg.memory, MemoryOrg::Shared) {
        // Separated buffers cannot chain: the output buffer is not the
        // input buffer, every activation round-trips through DRAM.
        return;
    }
    debug_assert_eq!(layers.len(), plans.len());
    // Activation bytes currently resident from the previous layer.
    let mut resident: u64 = 0;
    for (layer, plan) in layers.iter().zip(plans.iter_mut()) {
        let (decision, new_bytes, new_cycles) =
            decide(cfg, layer, resident, plan.dma_bytes, plan.dma_cycles);
        if decision.saved_dma_bytes > 0 {
            // Trim the per-tile DMA attribution to the new total —
            // chaining shortens the transfers, it does not change the
            // overlap rules (each GEMM keeps its own ping-pong grant).
            pipeline::scale_dma(&mut plan.timeline.gemms, new_cycles);
            plan.dma_bytes = new_bytes;
            plan.dma_cycles = new_cycles;
            plan.residency = decision;
            // The trimmed timeline resolves to a new latency; refresh
            // the plan's stored schedule.
            plan.reschedule();
        } else {
            plan.residency = decision;
        }
        resident = decision.resident_out_bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::TileCache;
    use crate::plan::{self, ResidencyDecision};
    use crate::workloads::{by_name, Workload};

    fn gemm_layer(name: &str, m: u64, k: u64, n: u64) -> Layer {
        Layer::new(name, LayerKind::Gemm { m, k, n })
    }

    #[test]
    fn fused_input_counts_only_the_first_gemm() {
        let fused = Layer::new("f", LayerKind::Fused(vec![(64, 64, 64), (64, 64, 64)]));
        assert_eq!(activation_in_bytes(&fused), 64 * 64);
        let empty = Layer::new("e", LayerKind::Fused(vec![]));
        assert_eq!(activation_in_bytes(&empty), 0);
    }

    #[test]
    fn fused_chaining_no_longer_overcounts() {
        // Regression (ISSUE 4 satellite): a fused successor used to sum
        // m*k over ALL its GEMMs, so a predecessor producing more than
        // the first GEMM's input chained phantom bytes.
        let cfg = ChipConfig::voltra();
        let w = Workload::new(
            "fused-chain",
            vec![
                gemm_layer("producer", 128, 64, 128), // out = 16384 B
                Layer::new("consumer", LayerKind::Fused(vec![(64, 64, 64), (64, 64, 64)])),
            ],
        );
        let mut cache = TileCache::new();
        let p = plan::build(&cfg, &w, &mut cache);
        let d = &p.layers[1].residency;
        // Only the first GEMM's 4096-byte input chains — under the old
        // accounting this was min(16384, 8192) = 8192.
        assert_eq!(d.chained_bytes, 64 * 64);
        assert_eq!(d.saved_dma_bytes, 2 * 64 * 64);
    }

    #[test]
    fn fused_output_counts_only_the_last_gemm() {
        let fused = Layer::new("f", LayerKind::Fused(vec![(64, 64, 64), (128, 64, 128)]));
        assert_eq!(activation_out_bytes(&fused), 128 * 128);
        let empty = Layer::new("e", LayerKind::Fused(vec![]));
        assert_eq!(activation_out_bytes(&empty), 0);
        // End to end: a Gemm successor chains against the LAST bundle
        // output, not the sum of all of them.
        let cfg = ChipConfig::voltra();
        let w = Workload::new("fused-out", vec![fused, gemm_layer("consumer", 256, 256, 64)]);
        let mut cache = TileCache::new();
        let p = plan::build(&cfg, &w, &mut cache);
        assert_eq!(p.layers[0].residency.resident_out_bytes, 128 * 128);
        // consumer a_in = 256*256 > 16384: chains exactly the resident bytes.
        assert_eq!(p.layers[1].residency.chained_bytes, 128 * 128);
    }

    #[test]
    fn pool_breaks_the_activation_chain() {
        // A pool layer produces no GEMM output, so nothing stays
        // resident for the layer after it.
        let cfg = ChipConfig::voltra();
        let w = Workload::new(
            "pooled",
            vec![
                gemm_layer("a", 64, 64, 64),
                Layer::new(
                    "pool",
                    LayerKind::Pool {
                        h: 8,
                        w: 8,
                        c: 64,
                        window: 2,
                        stride: 2,
                    },
                ),
                gemm_layer("b", 64, 64, 64),
            ],
        );
        let mut cache = TileCache::new();
        let p = plan::build(&cfg, &w, &mut cache);
        assert_eq!(p.layers[1].residency.resident_out_bytes, 0);
        assert_eq!(p.layers[2].residency.chained_bytes, 0);
    }

    #[test]
    fn oversized_activation_is_evicted() {
        // 512 x 768 output = 384 KiB > the 64 KiB activation region:
        // nothing chains into the next layer.
        let cfg = ChipConfig::voltra();
        let w = Workload::new(
            "big",
            vec![gemm_layer("a", 512, 768, 768), gemm_layer("b", 512, 768, 768)],
        );
        let mut cache = TileCache::new();
        let p = plan::build(&cfg, &w, &mut cache);
        assert_eq!(p.layers[0].residency.resident_out_bytes, 0);
        assert_eq!(p.layers[1].residency.chained_bytes, 0);
    }

    #[test]
    fn separated_memory_never_chains() {
        let cfg = ChipConfig::separated_memory();
        let w = by_name("llama-decode").unwrap();
        let mut cache = TileCache::new();
        let p = plan::build(&cfg, &w, &mut cache);
        assert!(p.layers.iter().all(|l| l.residency == ResidencyDecision::default()));
    }

    #[test]
    fn pathological_repeat_saturates_to_the_traffic_cap() {
        // Overflow audit (DESIGN.md §13): 2 * chained * repeat with
        // repeat = u64::MAX must saturate and then degrade to the
        // half-traffic cap — wrapping arithmetic would fold it back into
        // a tiny (wrong, and exploitable) savings instead.
        let cfg = ChipConfig::voltra();
        let mut l = gemm_layer("r", 64, 64, 64);
        l.repeat = u64::MAX;
        let (d, new_bytes, new_cycles) = decide(&cfg, &l, 4096, 1_000_000, 500_000);
        assert_eq!(d.chained_bytes, 4096);
        assert_eq!(d.saved_dma_bytes, 500_000, "must clamp at dma_bytes / 2");
        assert_eq!(new_bytes, 500_000);
        assert_eq!(new_cycles + d.saved_dma_cycles, 500_000);
        assert!(new_cycles < 500_000, "the trim must remove DMA cycles");
    }

    #[test]
    fn decode_chains_projection_layers() {
        // LLaMA decode's small per-step activations (batch 6) sit well
        // inside the activation region: the pass must chain them and the
        // chained layers must move fewer bytes than their unchained plan.
        let cfg = ChipConfig::voltra();
        let w = by_name("llama-decode").unwrap();
        let mut cache = TileCache::new();
        let p = plan::build(&cfg, &w, &mut cache);
        let chained: Vec<_> = p
            .layers
            .iter()
            .filter(|l| l.residency.chained_bytes > 0)
            .collect();
        assert!(!chained.is_empty(), "decode must chain some layers");
        for l in chained {
            assert!(l.residency.saved_dma_bytes > 0, "{}", l.name);
            // The run shares were re-scaled to the trimmed total.
            let run_dma: u64 = l
                .timeline
                .gemms
                .iter()
                .flat_map(|g| g.runs.iter())
                .map(|r| r.count * r.dma_cycles)
                .sum();
            assert_eq!(run_dma, l.dma_cycles, "{}", l.name);
        }
    }
}
