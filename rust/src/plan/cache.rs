//! Process-wide plan memoization: plan each `(config, workload)` pair
//! exactly once, then share the immutable [`WorkloadPlan`] across every
//! thread that needs it (suite, sweep, shmoo, the serving engine).
//!
//! Modeled on the coordinator's `SharedTileCache`:
//! * sharded `RwLock` maps so unrelated lookups never contend;
//! * misses plan *outside* any lock and single-flighted (DESIGN.md
//!   §14): concurrent requests for the same key block on ONE planner
//!   and share its plan — a thundering herd of identical cold requests
//!   compiles exactly once; the first insert wins and every later
//!   lookup returns that exact `Arc` — warm hits are therefore
//!   bit-identical forever;
//! * tile-simulation memoization is scoped per *tile-structural*
//!   fingerprint ([`crate::sim::tile_fingerprint`]) — the minimal
//!   config slice the tile engine actually reads — so one `PlanCache`
//!   safely serves many presets at once AND configs differing only in
//!   planner-side knobs (DMA bandwidth, double buffering, mapping mode,
//!   separated split sizes) share one tile cache: an architecture
//!   search pays cold tile-simulation cost once per *equivalence
//!   class*, not once per grid point.
//!
//! Keying: [`fingerprint`] hashes every `ChipConfig` field the planner
//! reads — array geometry, memory organisation, prefetch/FIFO/SIMD/
//! crossbar knobs, bank count, latencies, DMA parameters, double
//! buffering — and deliberately EXCLUDES the operating point: plans are
//! cycle-domain, so every (V, f) point of a DVFS sweep shares one plan.
//! Plans stay keyed by this full fingerprint (they depend on all of
//! it); only the tile tier uses the narrower structural key.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::sync::{Rank, RwLock};

use crate::config::{ArrayGeometry, ChipConfig, MemoryOrg};
use crate::coordinator::singleflight::{FlightGroup, Role};
use crate::coordinator::{SharedTileCache, WorkloadReport};
use crate::metrics::CacheStats;
use crate::sim::tile_fingerprint;
use crate::tiling::mapper::IncrementalMapper;
use crate::workloads::Workload;

use super::WorkloadPlan;

/// Fingerprint of every config field the planner depends on. Two
/// configs with equal fingerprints produce identical plans for any
/// workload; the operating point is excluded (cycle-domain plans are
/// frequency-independent).
pub fn fingerprint(cfg: &ChipConfig) -> u64 {
    let mut h = DefaultHasher::new();
    match cfg.array {
        ArrayGeometry::Spatial3D { m, n, k } => {
            0u8.hash(&mut h);
            (m, n, k).hash(&mut h);
        }
        ArrayGeometry::Spatial2D { m, n } => {
            1u8.hash(&mut h);
            (m, n).hash(&mut h);
        }
    }
    match cfg.memory {
        MemoryOrg::Shared => 0u8.hash(&mut h),
        MemoryOrg::Separated {
            input,
            weight,
            output,
            psum,
        } => {
            1u8.hash(&mut h);
            (input, weight, output, psum).hash(&mut h);
        }
    }
    cfg.prefetch.hash(&mut h);
    cfg.stream_fifo_depth.hash(&mut h);
    cfg.psum_fifo_depth.hash(&mut h);
    cfg.simd_lanes.hash(&mut h);
    cfg.tmux_psum_output.hash(&mut h);
    cfg.num_banks.hash(&mut h);
    cfg.mem_latency.hash(&mut h);
    cfg.dma_bytes_per_cycle.hash(&mut h);
    cfg.dma_burst_latency.hash(&mut h);
    cfg.double_buffer.hash(&mut h);
    cfg.mapping.hash(&mut h);
    h.finish()
}

#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct PlanKey {
    fingerprint: u64,
    workload: String,
}

/// Shard count: plans are coarse objects (one per workload), so fewer
/// shards than the tile cache suffice to keep sweep threads apart.
const PLAN_SHARDS: usize = 8;

fn shard_of<K: Hash>(key: &K) -> usize {
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() as usize) % PLAN_SHARDS
}

/// Plan-level counters including single-flight coalescing. For a burst
/// of N concurrent requests at one cold key: `misses == 1` (the
/// leader's compile), `coalesced == N - 1` (everyone who blocked on it)
/// — the thundering-herd acceptance invariant.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Calls that blocked on another thread's in-flight compile and
    /// shared its plan instead of compiling their own.
    pub coalesced: u64,
}

/// Process-wide, thread-safe plan memoization (see module docs).
pub struct PlanCache {
    plans: [RwLock<HashMap<PlanKey, Arc<WorkloadPlan>>>; PLAN_SHARDS],
    /// One tile-simulation cache per *tile-structural* fingerprint
    /// ([`tile_fingerprint`]): tiles are keyed by `TileSpec` alone, so
    /// a cache may only be shared between configs whose structural
    /// slices agree — which is exactly what the key guarantees.
    tiles: RwLock<HashMap<u64, Arc<SharedTileCache>>>,
    /// In-flight compiles: one planner per key, everyone else waits.
    flights: FlightGroup<PlanKey, Arc<WorkloadPlan>>,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache {
            plans: std::array::from_fn(|_| RwLock::new(Rank::PlanShard, HashMap::new())),
            tiles: RwLock::new(Rank::TileClassMap, HashMap::new()),
            flights: FlightGroup::default(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
        }
    }
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The memoized plan for `(cfg, w)`: warm calls return the exact
    /// same `Arc` (bit-identical execution guaranteed); cold calls plan
    /// against the fingerprint's shared tile cache, outside any lock.
    pub fn plan(&self, cfg: &ChipConfig, w: &Workload) -> Arc<WorkloadPlan> {
        self.plan_named(cfg, &w.name, || Some(w.clone()))
            .expect("resolver always yields the workload")
    }

    /// Like [`PlanCache::plan`], but keyed by a caller-supplied name
    /// with the workload materialized LAZILY: warm hits never construct
    /// the layer graph — the serving engine's steady state is a pure
    /// shard read. Returns `None` (counting neither hit nor miss) when
    /// `resolve` cannot produce the workload.
    ///
    /// Cold keys are single-flighted: the first caller compiles (the
    /// shard's one `miss`), every concurrent caller for the same key
    /// blocks on that compile and shares the canonical `Arc` (counted
    /// in `coalesced`) — a thundering herd plans exactly once.
    pub fn plan_named<F>(
        &self,
        cfg: &ChipConfig,
        name: &str,
        resolve: F,
    ) -> Option<Arc<WorkloadPlan>>
    where
        F: FnOnce() -> Option<Workload>,
    {
        let key = PlanKey {
            fingerprint: fingerprint(cfg),
            workload: name.to_string(),
        };
        let shard = &self.plans[shard_of(&key)];
        // The resolver is FnOnce but the flight protocol can loop (an
        // aborted leader sends its waiters around again); a caller
        // leads at most one flight, so it is taken at most once.
        let mut resolve = Some(resolve);
        loop {
            if let Some(p) = shard.read().get(&key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Some(Arc::clone(p));
            }
            match self.flights.join(&key, || {
                self.coalesced.fetch_add(1, Ordering::Relaxed);
            }) {
                Role::Leader(lead) => {
                    // A racing leader may have published and retired its
                    // flight between our shard read and our join.
                    if let Some(p) = shard.read().get(&key) {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        let p = Arc::clone(p);
                        lead.publish(Arc::clone(&p));
                        return Some(p);
                    }
                    let resolve = resolve.take().expect("a caller leads at most one flight");
                    // An unknown name drops the leader, aborting the
                    // flight: waiters wake, retry, and fail their own
                    // resolve. Counts neither hit nor miss.
                    let w = resolve()?;
                    let tiles = self.tile_cache_for(tile_fingerprint(cfg));
                    // Cold plans compile their layers across a small
                    // scoped pool — bit-identical to the sequential
                    // build (see [`super::build_parallel`]), just
                    // faster on first touch.
                    let threads = std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(1)
                        .min(8);
                    let built = Arc::new(super::build_parallel(cfg, &w, &tiles, threads));
                    // Debug/test builds statically verify every plan
                    // before it can be cached (DESIGN.md §13) — any
                    // invariant violation panics at the insert instead
                    // of surfacing as a wrong number downstream.
                    if cfg!(debug_assertions) {
                        super::verify::assert_clean(cfg, &w, &built);
                    }
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    // First insert wins: racing planners agree on one
                    // canonical plan.
                    let canonical = {
                        let mut map = shard.write();
                        Arc::clone(map.entry(key.clone()).or_insert(built))
                    };
                    lead.publish(Arc::clone(&canonical));
                    return Some(canonical);
                }
                Role::Waited(Some(p)) => return Some(p),
                Role::Waited(None) => continue,
            }
        }
    }

    /// Like [`PlanCache::plan`], but the cold path compiles layers
    /// *sequentially* with the caller's persistent [`IncrementalMapper`]
    /// instead of fanning out a nested worker pool — the search driver's
    /// entry point (DESIGN.md §15): each search worker is already one
    /// lane of an outer pool (nesting pools would oversubscribe), and a
    /// mapper handle that survives across adjacent grid points carries
    /// its last winning mapping from one config to its neighbors, where
    /// it keeps pruning (seeding is exact — see
    /// [`crate::tiling::mapper::search_seeded`]).
    ///
    /// Same single-flight protocol and counters as [`PlanCache::plan`];
    /// the resulting plan is bit-identical to the parallel build.
    pub fn plan_seeded(
        &self,
        cfg: &ChipConfig,
        w: &Workload,
        mapper: &mut IncrementalMapper<'_>,
    ) -> Arc<WorkloadPlan> {
        let key = PlanKey {
            fingerprint: fingerprint(cfg),
            workload: w.name.clone(),
        };
        let shard = &self.plans[shard_of(&key)];
        loop {
            if let Some(p) = shard.read().get(&key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Arc::clone(p);
            }
            match self.flights.join(&key, || {
                self.coalesced.fetch_add(1, Ordering::Relaxed);
            }) {
                Role::Leader(lead) => {
                    if let Some(p) = shard.read().get(&key) {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        let p = Arc::clone(p);
                        lead.publish(Arc::clone(&p));
                        return p;
                    }
                    let tiles = self.tile_cache_for(tile_fingerprint(cfg));
                    let built = Arc::new(super::build_seeded(cfg, w, &tiles, mapper));
                    if cfg!(debug_assertions) {
                        super::verify::assert_clean(cfg, w, &built);
                    }
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    let canonical = {
                        let mut map = shard.write();
                        Arc::clone(map.entry(key.clone()).or_insert(built))
                    };
                    lead.publish(Arc::clone(&canonical));
                    return canonical;
                }
                Role::Waited(Some(p)) => return p,
                Role::Waited(None) => continue,
            }
        }
    }

    /// Plan (or reuse) and execute in one call — the serving/suite path.
    pub fn run(&self, cfg: &ChipConfig, w: &Workload) -> WorkloadReport {
        super::execute(&self.plan(cfg, w))
    }

    /// The shared tile-simulation cache this plan cache uses for `cfg`'s
    /// *structural* slice. Callers serving the same config (e.g. the
    /// server's per-GEMM sim-cost path) can adopt it so a tile any path
    /// ever simulated — planning or serving — is never simulated twice;
    /// configs in the same structural class receive the same cache.
    pub fn tile_cache(&self, cfg: &ChipConfig) -> Arc<SharedTileCache> {
        self.tile_cache_for(tile_fingerprint(cfg))
    }

    /// Distinct tile-structural equivalence classes this cache has
    /// touched — the search's "cold tile cost paid once per class"
    /// telemetry.
    pub fn tile_cache_count(&self) -> usize {
        self.tiles.read().len()
    }

    /// The tile-simulation cache backing one structural fingerprint.
    fn tile_cache_for(&self, fp: u64) -> Arc<SharedTileCache> {
        if let Some(c) = self.tiles.read().get(&fp) {
            return Arc::clone(c);
        }
        let mut map = self.tiles.write();
        Arc::clone(map.entry(fp).or_default())
    }

    /// Plans memoized so far (across all shards and fingerprints).
    pub fn len(&self) -> usize {
        self.plans.iter().map(|s| s.read().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Plan-level hit/miss counters since construction. A warm suite or
    /// shmoo pass must add hits only — `misses` staying flat is the
    /// "re-planned zero layers" assertion.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Like [`PlanCache::stats`], extended with the single-flight
    /// coalesced-wait counter (the serving tier's STATS verb reports
    /// all three).
    pub fn plan_stats(&self) -> PlanCacheStats {
        PlanCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
        }
    }

    /// Aggregate tile-simulation counters across every fingerprint's
    /// tile cache (what planning itself memoized).
    pub fn tile_stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for c in self.tiles.read().values() {
            let s = c.stats();
            total.hits += s.hits;
            total.misses += s.misses;
        }
        total
    }

    /// Distinct tile specs simulated across every fingerprint.
    pub fn unique_tiles(&self) -> usize {
        let map = self.tiles.read();
        map.values().map(|c| c.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OperatingPoint;
    use crate::workloads;

    #[test]
    fn fingerprint_separates_presets() {
        let presets = [
            ChipConfig::voltra(),
            ChipConfig::separated_memory(),
            ChipConfig::no_prefetch(),
            ChipConfig::array2d(),
            ChipConfig::simd64(),
            ChipConfig::full_crossbar(),
            ChipConfig::swap_only(),
        ];
        let fps: Vec<u64> = presets.iter().map(fingerprint).collect();
        for i in 0..fps.len() {
            for j in (i + 1)..fps.len() {
                assert_ne!(fps[i], fps[j], "presets {i} and {j} collide");
            }
        }
    }

    #[test]
    fn fingerprint_ignores_operating_point() {
        let a = ChipConfig::voltra();
        let b = ChipConfig::voltra().with_operating_point(OperatingPoint::efficiency());
        assert_eq!(fingerprint(&a), fingerprint(&b));
    }

    #[test]
    fn warm_plan_is_the_same_arc() {
        let pc = PlanCache::new();
        let cfg = ChipConfig::voltra();
        let w = workloads::by_name("lstm").unwrap();
        let a = pc.plan(&cfg, &w);
        let b = pc.plan(&cfg, &w);
        assert!(Arc::ptr_eq(&a, &b), "warm hit must return the cached plan");
        let s = pc.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(pc.len(), 1);
    }

    #[test]
    fn plan_named_is_lazy_and_counts_unknowns_as_neither() {
        let pc = PlanCache::new();
        let cfg = ChipConfig::voltra();
        let cold = pc
            .plan_named(&cfg, "lstm", || workloads::by_name("lstm"))
            .unwrap();
        // Warm probe by the same name: never materializes the workload.
        let warm = pc
            .plan_named(&cfg, "lstm", || unreachable!("warm hit must not resolve"))
            .unwrap();
        assert!(Arc::ptr_eq(&cold, &warm));
        // Unknown names count neither hit nor miss.
        let before = pc.stats();
        assert!(pc.plan_named(&cfg, "nope", || None).is_none());
        assert_eq!(pc.stats(), before);
    }

    #[test]
    fn dvfs_points_share_one_plan() {
        let pc = PlanCache::new();
        let w = workloads::by_name("pointnext").unwrap();
        let perf = ChipConfig::voltra();
        let eff = ChipConfig::voltra().with_operating_point(OperatingPoint::efficiency());
        let a = pc.plan(&perf, &w);
        let b = pc.plan(&eff, &w);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(pc.stats().misses, 1);
    }

    #[test]
    fn distinct_configs_get_distinct_tile_caches() {
        // voltra and separated differ in the memory *kind* — a
        // tile-structural field — so they must not share a tile cache.
        let pc = PlanCache::new();
        let w = workloads::by_name("lstm").unwrap();
        pc.plan(&ChipConfig::voltra(), &w);
        let after_one = pc.unique_tiles();
        assert!(after_one > 0);
        pc.plan(&ChipConfig::separated_memory(), &w);
        assert!(
            pc.unique_tiles() > after_one,
            "separated preset must simulate into its own tile cache"
        );
        assert_eq!(pc.len(), 2);
        assert_eq!(pc.tile_cache_count(), 2);
    }

    #[test]
    fn structural_class_shares_one_tile_cache() {
        // swap-only differs from voltra ONLY in planner-side fields
        // (mapping mode): distinct plans, one shared tile cache.
        let pc = PlanCache::new();
        let voltra = ChipConfig::voltra();
        let swap = ChipConfig::swap_only();
        assert!(Arc::ptr_eq(&pc.tile_cache(&voltra), &pc.tile_cache(&swap)));
        let w = workloads::by_name("lstm").unwrap();
        let a = pc.plan(&voltra, &w);
        let b = pc.plan(&swap, &w);
        assert!(!Arc::ptr_eq(&a, &b), "plans stay keyed by full fingerprint");
        assert_eq!(pc.len(), 2);
        assert_eq!(pc.tile_cache_count(), 1);
    }

    #[test]
    fn plan_seeded_matches_parallel_plan_bit_identically() {
        let cfg = ChipConfig::voltra();
        let w = workloads::by_name("pointnext").unwrap();
        let canonical = PlanCache::new().plan(&cfg, &w);
        let pc = PlanCache::new();
        let mappers = crate::tiling::MapperCache::new();
        let mut im = IncrementalMapper::new(&mappers);
        let seeded = pc.plan_seeded(&cfg, &w, &mut im);
        assert_eq!(*seeded, *canonical);
        // Warm: same Arc, hit counted, mapper untouched.
        let warm = pc.plan_seeded(&cfg, &w, &mut im);
        assert!(Arc::ptr_eq(&seeded, &warm));
        let s = pc.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }
}
