//! Static verification of [`WorkloadPlan`]s (DESIGN.md §13).
//!
//! Every claim the model makes rests on the compiled plan IR respecting
//! the paper's hardware contracts — the 8x8x8 array geometry, the
//! 32-bank shared memory, the two-region dynamic allocator, the stream
//! FIFO discipline. Until now those contracts were enforced only by
//! pinned end-to-end numbers; this pass proves them *structurally*,
//! without running the cycle engine, by re-deriving each layer's
//! envelope from the same single-authority helpers the planner used
//! ([`planner::gemm_traffic_bytes`], [`residency::decide`],
//! [`mapper::resolve`], [`allocator::place`],
//! [`pipeline::schedule_layer`]) and checking the stored plan against
//! them field by field.
//!
//! Each violation is a structured [`LintFinding`] with a stable rule id
//! (the full catalog is [`RULES`]; rule id → paper constraint →
//! enforcement site is tabulated in DESIGN.md §13). Wired in three
//! places:
//!
//! * the `voltra lint` CLI (exit nonzero on findings);
//! * a debug-build hook at [`super::PlanCache`] insert, so every plan
//!   ever cached is verified in debug/test builds;
//! * the mutation rig `tests/verifier_mutations.rs`, which corrupts
//!   single fields of valid plans and asserts each invariant class
//!   catches its seeded corruption — a verifier never tested against
//!   broken plans is just comments.

use std::collections::BTreeMap;
use std::fmt;

use crate::config::{ArrayGeometry, ChipConfig, MappingSearch, MemoryOrg};
use crate::coordinator::tile_csr_cycles;
use crate::runtime::json::Json;
use crate::sim::dma::transfer_cost;
use crate::sim::gemm_core::{MAX_INPUT_CHANNELS, MAX_WEIGHT_CHANNELS};
use crate::sim::pipeline;
use crate::sim::reshuffler::reshuffle_cycles;
use crate::tiling::allocator;
use crate::tiling::mapper;
use crate::workloads::{Layer, Workload};

use super::{cache, planner, residency, LayerPlan, ResidencyDecision, WorkloadPlan};

/// Finding severity. Every rule in the current catalog is an error —
/// the enum exists so advisory rules can join without an API break.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    Warning,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Warning => write!(f, "warning"),
            Severity::Error => write!(f, "error"),
        }
    }
}

/// One verified-invariant violation: which rule, where, and why.
#[derive(Clone, Debug, PartialEq)]
pub struct LintFinding {
    /// Stable rule id from [`RULES`].
    pub rule: &'static str,
    pub severity: Severity,
    /// Layer (optionally `layer/gemm[i]`) the violation anchors to;
    /// empty for plan-level rules.
    pub layer: String,
    pub detail: String,
}

impl fmt::Display for LintFinding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.layer.is_empty() {
            write!(f, "{}[{}]: {}", self.severity, self.rule, self.detail)
        } else {
            write!(
                f,
                "{}[{}] {}: {}",
                self.severity, self.rule, self.layer, self.detail
            )
        }
    }
}

impl LintFinding {
    /// Structured form for machine consumers (the CLI's `--json` mode
    /// and the serving engine), through the runtime's own [`Json`].
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        let severity = self.severity.to_string();
        m.insert("rule".to_string(), Json::Str(self.rule.to_string()));
        m.insert("severity".to_string(), Json::Str(severity));
        m.insert("layer".to_string(), Json::Str(self.layer.clone()));
        m.insert("detail".to_string(), Json::Str(self.detail.clone()));
        Json::Obj(m)
    }
}

/// The invariant catalog. One entry per rule id a [`LintFinding`] can
/// carry; DESIGN.md §13 maps each to the paper constraint it encodes.
pub const RULES: &[&str] = &[
    "plan-fingerprint",
    "plan-shape",
    "config-legality",
    "fifo-depth",
    "mac-conservation",
    "tile-activity",
    "tile-population",
    "dma-cycle-attribution",
    "dma-byte-conservation",
    "dma-cycle-envelope",
    "footprint-capacity",
    "mapping-legality",
    "pingpong-exclusivity",
    "schedule-consistency",
    "residency-legality",
    "aux-accounting",
    "stream-demand-bounds",
];

/// Render findings as the lint report body, one line per finding.
pub fn render(findings: &[LintFinding]) -> String {
    findings
        .iter()
        .map(|f| f.to_string())
        .collect::<Vec<_>>()
        .join("\n")
}

/// Structured report: a JSON array of findings.
pub fn findings_json(findings: &[LintFinding]) -> Json {
    Json::Arr(findings.iter().map(|f| f.to_json()).collect())
}

fn push(out: &mut Vec<LintFinding>, rule: &'static str, layer: &str, detail: String) {
    out.push(LintFinding {
        rule,
        severity: Severity::Error,
        layer: layer.to_string(),
        detail,
    });
}

/// Statically verify `plan` against the workload it claims to compile
/// and the config it claims to compile under. Returns every violation
/// found; an empty vec is a machine-checked proof that the plan
/// satisfies the full invariant catalog.
pub fn verify(cfg: &ChipConfig, w: &Workload, plan: &WorkloadPlan) -> Vec<LintFinding> {
    let mut out = Vec::new();

    // -- config-legality / fifo-depth: the config itself must describe
    // realizable hardware before any plan check is meaningful.
    if cfg.array.macs() == 0 {
        push(
            &mut out,
            "config-legality",
            "",
            "array geometry offers zero MACs".to_string(),
        );
    }
    if cfg.num_banks == 0 {
        push(
            &mut out,
            "config-legality",
            "",
            "shared memory has zero banks".to_string(),
        );
    }
    if cfg.dma_bytes_per_cycle == 0 {
        push(
            &mut out,
            "config-legality",
            "",
            "DMA bandwidth is zero bytes/cycle".to_string(),
        );
    }
    if cfg.stream_fifo_depth == 0 || cfg.psum_fifo_depth == 0 {
        push(
            &mut out,
            "fifo-depth",
            "",
            format!(
                "stream/psum FIFO depths must be >= 1 (got {}/{}): the \
                 streamer in-flight queue is sized from them",
                cfg.stream_fifo_depth, cfg.psum_fifo_depth
            ),
        );
    }

    // -- plan-fingerprint: the plan must carry the fingerprint of the
    // config it is being executed under (a cross-config plan reuse is
    // exactly the bug the PlanCache keying exists to prevent).
    let fp = cache::fingerprint(cfg);
    if plan.fingerprint != fp {
        push(
            &mut out,
            "plan-fingerprint",
            "",
            format!(
                "plan fingerprint {:#x} != config fingerprint {:#x}",
                plan.fingerprint, fp
            ),
        );
    }

    // -- plan-shape: layer sequence parallel to the workload.
    if plan.workload != w.name {
        push(
            &mut out,
            "plan-shape",
            "",
            format!("plan names workload '{}', got '{}'", plan.workload, w.name),
        );
    }
    if plan.layers.len() != w.layers.len() {
        push(
            &mut out,
            "plan-shape",
            "",
            format!(
                "plan has {} layers, workload has {}",
                plan.layers.len(),
                w.layers.len()
            ),
        );
        // Nothing below can be aligned layer-by-layer.
        return out;
    }
    let total_dispatched: u64 = plan.layers.iter().map(|l| l.dispatched_tiles).sum();
    if plan.dispatched_tiles != total_dispatched {
        push(
            &mut out,
            "plan-shape",
            "",
            format!(
                "plan dispatched_tiles {} != sum of layer counts {}",
                plan.dispatched_tiles, total_dispatched
            ),
        );
    }

    for (layer, lp) in w.layers.iter().zip(plan.layers.iter()) {
        verify_layer(cfg, layer, lp, &mut out);
    }

    verify_residency(cfg, w, plan, &mut out);
    out
}

/// Re-derive one layer's envelope from the planner's own authorities
/// and check every stored aggregate against it.
fn verify_layer(cfg: &ChipConfig, layer: &Layer, lp: &LayerPlan, out: &mut Vec<LintFinding>) {
    let at = layer.name.as_str();
    if lp.name != layer.name {
        push(
            out,
            "plan-shape",
            at,
            format!("plan layer named '{}'", lp.name),
        );
        return;
    }

    // Canonical re-resolution of every GEMM, mirroring the planner:
    // original orientation into the mapper, swap applied to the dims the
    // tiling was sized for, unresolvable GEMMs skipped.
    let mut resolved = Vec::new();
    for mut g in layer.gemms() {
        let Some((mapping, tiling)) = mapper::resolve(cfg, g.m, g.k, g.n) else {
            continue;
        };
        if mapping.swapped {
            std::mem::swap(&mut g.m, &mut g.n);
        }
        resolved.push((g, mapping, tiling));
    }
    if lp.mappings.len() != resolved.len() || lp.timeline.gemms.len() != resolved.len() {
        push(
            out,
            "mapping-legality",
            at,
            format!(
                "layer lowers to {} mappable GEMMs but the plan records {} \
                 mappings / {} timeline GEMMs",
                resolved.len(),
                lp.mappings.len(),
                lp.timeline.gemms.len()
            ),
        );
        // Per-GEMM alignment is gone; skip the rest of this layer.
        return;
    }

    // -- mac-conservation: layer MACs equal the workload's analytic
    // count, and the aggregated tile activity performed exactly them.
    let expected_macs: u64 = resolved.iter().map(|(g, _, _)| g.macs()).sum();
    if lp.macs != expected_macs {
        push(
            out,
            "mac-conservation",
            at,
            format!("plan macs {} != workload macs {}", lp.macs, expected_macs),
        );
    }
    if lp.tiles.useful_macs != lp.macs {
        push(
            out,
            "mac-conservation",
            at,
            format!(
                "dispatched tiles performed {} useful MACs, layer accounts {}",
                lp.tiles.useful_macs, lp.macs
            ),
        );
    }

    // -- tile-activity: the aggregated tile counters must describe a
    // physically possible array occupancy.
    let array_macs = cfg.array.macs() as u64;
    if lp.tiles.useful_macs > lp.tiles.offered_macs {
        push(
            out,
            "tile-activity",
            at,
            format!(
                "useful MACs {} exceed offered MACs {}",
                lp.tiles.useful_macs, lp.tiles.offered_macs
            ),
        );
    }
    if lp.tiles.offered_macs != array_macs * lp.tiles.active_cycles {
        push(
            out,
            "tile-activity",
            at,
            format!(
                "offered MACs {} != array macs {} x active cycles {}",
                lp.tiles.offered_macs, array_macs, lp.tiles.active_cycles
            ),
        );
    }
    if lp.tiles.active_cycles > lp.tiles.total_cycles {
        push(
            out,
            "tile-activity",
            at,
            format!(
                "active cycles {} exceed total cycles {}",
                lp.tiles.active_cycles, lp.tiles.total_cycles
            ),
        );
    }

    let mut dispatched_sum = 0u64;
    let mut traffic_sum = 0u64;
    let mut dma_env = 0u64;
    let mut aux_expected = 0u64;
    let mut fp_max = 0u64;
    for (gi, (g, mapping, tiling)) in resolved.iter().enumerate() {
        let gat = format!("{at}/gemm[{gi}]");
        let stored = &lp.mappings[gi];

        // -- mapping-legality: the stored mapping must be structurally
        // legal for the geometry/search mode AND equal the canonical
        // search winner (the mapper is the single mapping authority).
        verify_mapping_shape(cfg, stored, &gat, out);
        if stored != mapping {
            push(
                out,
                "mapping-legality",
                &gat,
                format!(
                    "stored mapping {} != canonical search winner {}",
                    stored.describe(),
                    mapping.describe()
                ),
            );
        }

        // -- stream-demand-bounds: the stored mapping's per-step operand
        // demand must fit the streamer fabric (8 fine input channels,
        // 128-channel weight id space) and claim at least the two bank
        // grants any step needs (one input-side, one weight-side).
        let d = stored.demand();
        if d.input_channels > MAX_INPUT_CHANNELS {
            push(
                out,
                "stream-demand-bounds",
                &gat,
                format!(
                    "mapping demands {} input channels, fabric has {}",
                    d.input_channels, MAX_INPUT_CHANNELS
                ),
            );
        }
        if d.weight_channels > MAX_WEIGHT_CHANNELS {
            push(
                out,
                "stream-demand-bounds",
                &gat,
                format!(
                    "mapping demands {} weight channels, id space has {}",
                    d.weight_channels, MAX_WEIGHT_CHANNELS
                ),
            );
        }
        if mapper::banks_per_step(cfg, stored) < 2 {
            push(
                out,
                "stream-demand-bounds",
                &gat,
                "a compute step must claim at least two bank grants".to_string(),
            );
        }

        // -- tile-population: closed-form dispatch count per GEMM.
        let expected_tiles = g.m.div_ceil(tiling.tm)
            * g.k.div_ceil(tiling.tk)
            * g.n.div_ceil(tiling.tn)
            * g.repeat;
        let run_tiles: u64 = lp.timeline.gemms[gi].runs.iter().map(|r| r.count).sum();
        if run_tiles != expected_tiles {
            push(
                out,
                "tile-population",
                &gat,
                format!(
                    "timeline dispatches {run_tiles} tiles, tiling requires {expected_tiles}"
                ),
            );
        }
        let csr = tile_csr_cycles(tiling.tk);
        for (ri, run) in lp.timeline.gemms[gi].runs.iter().enumerate() {
            if run.count == 0 {
                push(
                    out,
                    "tile-population",
                    &gat,
                    format!("run[{ri}] has count 0 (the planner never emits empty runs)"),
                );
            }
            if run.compute_cycles < csr {
                push(
                    out,
                    "tile-population",
                    &gat,
                    format!(
                        "run[{ri}] compute {} below the {} CSR programming floor",
                        run.compute_cycles, csr
                    ),
                );
            }
        }
        dispatched_sum += expected_tiles;
        aux_expected += expected_tiles * csr;

        // -- dma-byte-conservation inputs (summed after the loop).
        let traffic = planner::gemm_traffic_bytes(cfg, g, tiling);
        traffic_sum += traffic;
        dma_env += transfer_cost(cfg, traffic).cycles + expected_tiles * cfg.dma_burst_latency;

        // -- footprint-capacity: the induced tiling must fit the memory
        // organisation and its placement must re-derive exactly (the
        // allocator's packing is what keeps operand regions disjoint).
        verify_footprint(cfg, tiling, &gat, out);
        fp_max = fp_max.max(tiling.footprint.total() as u64);

        // -- pingpong-exclusivity: a ping-pong grant exists only when
        // the allocator held double-buffer space for THIS GEMM and the
        // config enables overlap at all.
        let expected_db = tiling.double_buffered && cfg.double_buffer;
        if lp.timeline.gemms[gi].double_buffered != expected_db {
            push(
                out,
                "pingpong-exclusivity",
                &gat,
                format!(
                    "ping-pong grant {} but allocator grant x config allow = {}",
                    lp.timeline.gemms[gi].double_buffered, expected_db
                ),
            );
        }
    }

    if lp.dispatched_tiles != dispatched_sum {
        push(
            out,
            "tile-population",
            at,
            format!(
                "layer dispatched_tiles {} != tiling requirement {}",
                lp.dispatched_tiles, dispatched_sum
            ),
        );
    }

    // -- dma-cycle-attribution: the per-run DMA shares must sum exactly
    // to the layer's accounted DMA busy time (residency trim included —
    // `scale_dma` preserves the total by construction).
    let run_dma: u64 = lp
        .timeline
        .gemms
        .iter()
        .flat_map(|g| g.runs.iter())
        .map(|r| r.count * r.dma_cycles)
        .sum();
    if run_dma != lp.dma_cycles {
        push(
            out,
            "dma-cycle-attribution",
            at,
            format!(
                "run DMA shares sum to {}, layer accounts {}",
                run_dma, lp.dma_cycles
            ),
        );
    }

    // -- dma-byte-conservation / dma-cycle-envelope: stored totals plus
    // whatever the residency pass removed must equal the re-derived
    // traffic envelope.
    let orig_bytes = lp.dma_bytes + lp.residency.saved_dma_bytes;
    if orig_bytes != traffic_sum {
        push(
            out,
            "dma-byte-conservation",
            at,
            format!(
                "dma_bytes {} + chained savings {} != traffic envelope {}",
                lp.dma_bytes, lp.residency.saved_dma_bytes, traffic_sum
            ),
        );
    }
    let orig_cycles = lp.dma_cycles + lp.residency.saved_dma_cycles;
    if orig_cycles != dma_env {
        push(
            out,
            "dma-cycle-envelope",
            at,
            format!(
                "dma_cycles {} + chained savings {} != transfer-cost envelope {}",
                lp.dma_cycles, lp.residency.saved_dma_cycles, dma_env
            ),
        );
    }

    // -- footprint-capacity: the stored peak footprint is the max over
    // the layer's induced tilings.
    if lp.tile_footprint_bytes != fp_max {
        push(
            out,
            "footprint-capacity",
            at,
            format!(
                "tile_footprint_bytes {} != max induced footprint {}",
                lp.tile_footprint_bytes, fp_max
            ),
        );
    }

    // -- aux-accounting: CSR programming per dispatched tile plus the
    // reshuffler pass, both re-derived.
    let rb = planner::reshuffle_bytes(layer);
    let expected_reshuffle = if rb > 0 {
        reshuffle_cycles(rb) * layer.repeat
    } else {
        0
    };
    if lp.timeline.reshuffle_cycles != expected_reshuffle {
        push(
            out,
            "aux-accounting",
            at,
            format!(
                "timeline reshuffle {} != reshuffler model {}",
                lp.timeline.reshuffle_cycles, expected_reshuffle
            ),
        );
    }
    aux_expected += expected_reshuffle;
    if lp.aux_cycles != aux_expected {
        push(
            out,
            "aux-accounting",
            at,
            format!(
                "aux_cycles {} != CSR + reshuffle accounting {}",
                lp.aux_cycles, aux_expected
            ),
        );
    }

    // -- schedule-consistency: the stored latency/overlap must be the
    // pipeline scheduler's fixed point over the stored timeline, inside
    // the overlap envelope, with the compute side cross-linked to the
    // tile activity + aux accounting.
    let s = pipeline::schedule_layer(&lp.timeline);
    if lp.latency_cycles != s.latency_cycles || lp.overlap_cycles != s.hidden_cycles() {
        push(
            out,
            "schedule-consistency",
            at,
            format!(
                "stored latency/overlap {}/{} != scheduler fixed point {}/{}",
                lp.latency_cycles,
                lp.overlap_cycles,
                s.latency_cycles,
                s.hidden_cycles()
            ),
        );
    }
    let lower = s.compute_cycles.max(s.dma_cycles);
    let upper = s.compute_cycles + s.dma_cycles;
    if s.latency_cycles < lower || s.latency_cycles > upper {
        push(
            out,
            "schedule-consistency",
            at,
            format!(
                "latency {} outside the overlap envelope [{}, {}]",
                s.latency_cycles, lower, upper
            ),
        );
    }
    if s.compute_cycles != lp.tiles.total_cycles + lp.aux_cycles {
        push(
            out,
            "schedule-consistency",
            at,
            format!(
                "scheduled compute {} != tile cycles {} + aux {}",
                s.compute_cycles, lp.tiles.total_cycles, lp.aux_cycles
            ),
        );
    }
}

/// Structural legality of one stored mapping: right geometry, legal
/// fold for the geometry and search mode.
fn verify_mapping_shape(
    cfg: &ChipConfig,
    m: &crate::sim::gemm_core::Mapping,
    at: &str,
    out: &mut Vec<LintFinding>,
) {
    if m.geometry != cfg.array {
        push(
            out,
            "mapping-legality",
            at,
            format!("mapping geometry {:?} != config array {:?}", m.geometry, cfg.array),
        );
        return;
    }
    let fold = m.fold as usize;
    match cfg.array {
        ArrayGeometry::Spatial3D { m: rows, .. } => {
            if fold == 0 || fold > rows || rows % fold != 0 {
                push(
                    out,
                    "mapping-legality",
                    at,
                    format!("fold {fold} does not divide the {rows}-row array"),
                );
            }
            if cfg.mapping == MappingSearch::SwapOnly && fold != 1 {
                push(
                    out,
                    "mapping-legality",
                    at,
                    format!("fold {fold} under SwapOnly search (folding disabled)"),
                );
            }
        }
        ArrayGeometry::Spatial2D { .. } => {
            if fold != 1 {
                push(
                    out,
                    "mapping-legality",
                    at,
                    format!("fold {fold} on the 2D baseline (no spatial K axis)"),
                );
            }
        }
    }
}

/// Capacity + placement legality of one induced tiling: it must fit the
/// organisation, place exactly where the allocator packs it, and keep
/// the four operand regions disjoint.
fn verify_footprint(
    cfg: &ChipConfig,
    tiling: &crate::tiling::Tiling,
    at: &str,
    out: &mut Vec<LintFinding>,
) {
    let fp = &tiling.footprint;
    if !allocator::fits(&cfg.memory, fp) {
        push(
            out,
            "footprint-capacity",
            at,
            format!(
                "footprint {} B does not fit the memory organisation",
                fp.total()
            ),
        );
        return;
    }
    match allocator::place(&cfg.memory, fp) {
        None => push(
            out,
            "footprint-capacity",
            at,
            "footprint fits but the allocator refuses to place it".to_string(),
        ),
        Some(pl) => {
            if pl != tiling.placement {
                push(
                    out,
                    "footprint-capacity",
                    at,
                    format!(
                        "stored placement {:?} != allocator packing {:?}",
                        tiling.placement, pl
                    ),
                );
            }
            // Region disjointness in word space (8-byte words): each
            // region's occupied words must end at or before the next
            // region's base.
            let words = |bytes: usize| -> u64 { (bytes as u64).div_ceil(8) };
            let spans = [
                ("input", pl.input_base, words(fp.input), pl.weight_base),
                ("weight", pl.weight_base, words(fp.weight), pl.psum_base),
                ("psum", pl.psum_base, words(fp.psum), pl.output_base),
            ];
            for (name, base, len, next) in spans {
                if base + len > next {
                    push(
                        out,
                        "footprint-capacity",
                        at,
                        format!(
                            "{name} region [{base}, {}) overlaps the next base {next}",
                            base + len
                        ),
                    );
                }
            }
        }
    }
}

/// Replay the residency pass over the whole layer sequence with
/// [`residency::decide`] (the pass's own decision authority) and check
/// every stored [`ResidencyDecision`] and trimmed DMA total against the
/// canonical replay.
fn verify_residency(
    cfg: &ChipConfig,
    w: &Workload,
    plan: &WorkloadPlan,
    out: &mut Vec<LintFinding>,
) {
    if !matches!(cfg.memory, MemoryOrg::Shared) {
        // Separated buffers never chain: every decision must be default.
        for lp in &plan.layers {
            if lp.residency != ResidencyDecision::default() {
                push(
                    out,
                    "residency-legality",
                    &lp.name,
                    "separated memory cannot chain activations".to_string(),
                );
            }
        }
        return;
    }
    let region = residency::activation_region_bytes(cfg);
    let mut resident = 0u64;
    for (layer, lp) in w.layers.iter().zip(plan.layers.iter()) {
        // Reconstruct the pre-trim envelope the pass saw, replay its
        // decision, and compare. The replay advances on the *canonical*
        // resident bytes so one corrupted layer cannot cascade into
        // phantom findings downstream.
        let orig_bytes = lp.dma_bytes + lp.residency.saved_dma_bytes;
        let orig_cycles = lp.dma_cycles + lp.residency.saved_dma_cycles;
        let (expect, new_bytes, new_cycles) =
            residency::decide(cfg, layer, resident, orig_bytes, orig_cycles);
        if lp.residency != expect || lp.dma_bytes != new_bytes || lp.dma_cycles != new_cycles {
            push(
                out,
                "residency-legality",
                &lp.name,
                format!(
                    "stored decision {:?} (dma {}/{}) != replayed decision {:?} (dma {}/{})",
                    lp.residency, lp.dma_bytes, lp.dma_cycles, expect, new_bytes, new_cycles
                ),
            );
        }
        // Two-region allocator bounds: nothing chained or left resident
        // may exceed the activation region next to the working reserve.
        if lp.residency.chained_bytes > region || lp.residency.resident_out_bytes > region {
            push(
                out,
                "residency-legality",
                &lp.name,
                format!(
                    "chained {} / resident-out {} exceed the {} B activation region",
                    lp.residency.chained_bytes, lp.residency.resident_out_bytes, region
                ),
            );
        }
        resident = expect.resident_out_bytes;
    }
}

/// Debug-build gate: panic with the rendered report if `plan` violates
/// any invariant. Wired at the [`super::PlanCache`] insert so every
/// plan ever cached is verified in debug/test builds.
pub fn assert_clean(cfg: &ChipConfig, w: &Workload, plan: &WorkloadPlan) {
    let findings = verify(cfg, w, plan);
    assert!(
        findings.is_empty(),
        "plan verifier found {} violation(s) in '{}':\n{}",
        findings.len(),
        plan.workload,
        render(&findings)
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::TileCache;
    use crate::plan;
    use crate::workloads;

    fn built(cfg: &ChipConfig, name: &str) -> (Workload, WorkloadPlan) {
        let w = workloads::by_name(name).unwrap();
        let mut cache = TileCache::new();
        let p = plan::build(cfg, &w, &mut cache);
        (w, p)
    }

    #[test]
    fn clean_plans_verify_clean() {
        for cfg in [
            ChipConfig::voltra(),
            ChipConfig::separated_memory(),
            ChipConfig::swap_only(),
        ] {
            let (w, p) = built(&cfg, "lstm");
            let f = verify(&cfg, &w, &p);
            assert!(f.is_empty(), "lstm findings: {}", render(&f));
        }
    }

    #[test]
    fn corrupted_macs_are_caught() {
        let cfg = ChipConfig::voltra();
        let (w, mut p) = built(&cfg, "lstm");
        p.layers[0].macs += 1;
        let f = verify(&cfg, &w, &p);
        assert!(f.iter().any(|x| x.rule == "mac-conservation"), "{}", render(&f));
    }

    #[test]
    fn cross_config_plan_reuse_is_caught() {
        let voltra = ChipConfig::voltra();
        let (w, p) = built(&voltra, "lstm");
        let other = ChipConfig::no_prefetch();
        let f = verify(&other, &w, &p);
        assert!(f.iter().any(|x| x.rule == "plan-fingerprint"), "{}", render(&f));
    }

    #[test]
    fn findings_render_and_serialize() {
        let f = LintFinding {
            rule: "mac-conservation",
            severity: Severity::Error,
            layer: "fc1".to_string(),
            detail: "plan macs 2 != workload macs 1".to_string(),
        };
        assert_eq!(
            f.to_string(),
            "error[mac-conservation] fc1: plan macs 2 != workload macs 1"
        );
        let j = f.to_json();
        assert_eq!(j.get("rule").unwrap().as_str(), Some("mac-conservation"));
        assert_eq!(j.get("severity").unwrap().as_str(), Some("error"));
        let rendered = findings_json(&[f]).render();
        let round = crate::runtime::json::parse(&rendered).unwrap();
        assert_eq!(round.as_arr().unwrap().len(), 1);
    }

    #[test]
    fn rule_catalog_is_distinct() {
        let mut seen = std::collections::HashSet::new();
        for r in RULES {
            assert!(seen.insert(r), "duplicate rule id {r}");
        }
    }
}
