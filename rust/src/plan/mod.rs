//! Compile-once workload planning (DESIGN.md §10).
//!
//! The model's planning decisions — the layer-wise tiling search, the
//! K-round variant expansion, the byte-proportional DMA attribution and
//! the shared-memory residency (activation chaining) — are pure
//! functions of `(ChipConfig, Workload)`. This module separates that
//! *planning* from *execution*, the structure the paper's flexible
//! streamers + dynamic memory allocation imply (plans are programmed
//! once into CSRs; the datapath then just runs them):
//!
//! * [`build`] turns `(cfg, workload)` into an immutable [`WorkloadPlan`]
//!   — one [`LayerPlan`] per layer holding the dispatched tile runs,
//!   per-GEMM ping-pong grants, aggregated tile activity and the
//!   [`ResidencyDecision`] the residency pass recorded for it;
//! * [`residency`] is the first-class pass that models the shared space
//!   as a dynamic allocator and decides which layer boundaries chain
//!   their activation on chip (replacing the old inline heuristic that
//!   mutated metrics after the fact);
//! * [`execute`] resolves a plan to a [`WorkloadReport`] — a thin, pure
//!   pass over [`pipeline::schedule_layer`] with no tiling search and no
//!   tile simulation;
//! * [`PlanCache`] memoizes plans process-wide, keyed by the config
//!   fingerprint + workload name, so `suite` / `sweep` / `shmoo` /
//!   `serve` plan each `(config, workload)` pair exactly once across
//!   threads.
//!
//! Plans are cycle-domain and therefore *frequency-independent*: the
//! operating point is deliberately excluded from the fingerprint, so a
//! DVFS sweep (shmoo) reuses one plan across every (V, f) point.

pub mod cache;
pub mod planner;
pub mod residency;
pub mod verify;

pub use cache::{fingerprint, PlanCache, PlanCacheStats};
pub use verify::{verify, LintFinding, Severity};

use crate::config::ChipConfig;
use crate::coordinator::{SharedTileCache, SimCache, WorkloadReport};
use crate::metrics::{LayerMetrics, TileMetrics, WorkloadMetrics};
use crate::sim::gemm_core::Mapping;
use crate::sim::pipeline;
use crate::tiling::mapper::IncrementalMapper;
use crate::workloads::Workload;

/// What the residency pass decided at this layer's input boundary.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResidencyDecision {
    /// Predecessor activation bytes consumed directly from the shared
    /// space (streamer base-pointer update instead of a DRAM round trip).
    pub chained_bytes: u64,
    /// Off-chip bytes the chain removed (predecessor write + our read,
    /// once per layer invocation).
    pub saved_dma_bytes: u64,
    /// DMA cycles the chain removed (already folded into the layer's
    /// tile runs by the pass).
    pub saved_dma_cycles: u64,
    /// Activation bytes this layer leaves resident for its successor
    /// (0 = evicted: too large for the allocator's activation region).
    pub resident_out_bytes: u64,
}

/// One layer, fully planned: the dispatched tile timeline plus every
/// aggregate the metrics need. Immutable once [`build`] returns.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerPlan {
    pub name: String,
    /// Aggregated activity of all dispatched tiles (memoized sims,
    /// scaled by dispatch counts).
    pub tiles: TileMetrics,
    pub macs: u64,
    /// CSR programming + reshuffler cycles.
    pub aux_cycles: u64,
    /// Off-chip bytes, after the residency pass trimmed chained traffic.
    pub dma_bytes: u64,
    /// DMA engine busy cycles, after the residency pass.
    pub dma_cycles: u64,
    pub tile_footprint_bytes: u64,
    pub dispatched_tiles: u64,
    /// Resolved pipeline latency of [`Self::timeline`] — computed once
    /// at plan time (and re-resolved by the residency pass when it trims
    /// a chained layer's transfers), so executing a warm plan never
    /// re-schedules anything.
    pub latency_cycles: u64,
    /// Cycles the schedule hid by overlapping DMA with compute.
    pub overlap_cycles: u64,
    /// The tile runs + per-GEMM ping-pong grants the scheduler consumed
    /// (run DMA shares already reflect the residency decision).
    pub timeline: pipeline::LayerPlan,
    pub residency: ResidencyDecision,
    /// The resolved array mapping of each GEMM of this layer, in
    /// dispatch order (DESIGN.md §11) — what `voltra report` surfaces
    /// per layer.
    pub mappings: Vec<Mapping>,
}

impl LayerPlan {
    /// Re-resolve this layer's timeline through the pipeline scheduler
    /// and refresh the stored latency/overlap (planning-time only: the
    /// planner calls this once per layer, the residency pass once more
    /// for each layer it trims).
    pub(crate) fn reschedule(&mut self) {
        let s = pipeline::schedule_layer(&self.timeline);
        self.latency_cycles = s.latency_cycles;
        self.overlap_cycles = s.hidden_cycles();
    }

    /// Compact mapping summary for the report: consecutive duplicate
    /// GEMM mappings collapse (a fused bundle usually maps uniformly).
    pub fn mapping_summary(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        for m in &self.mappings {
            let d = m.describe();
            if parts.last() != Some(&d) {
                parts.push(d);
            }
        }
        parts.join("+")
    }

    /// This layer's metrics (the per-layer unit of [`execute`]): a pure
    /// field copy — the schedule was resolved at plan time.
    pub fn resolve(&self) -> LayerMetrics {
        LayerMetrics {
            name: self.name.clone(),
            mapping: self.mapping_summary(),
            tiles: self.tiles,
            dma_bytes: self.dma_bytes,
            dma_cycles: self.dma_cycles,
            latency_cycles: self.latency_cycles,
            overlap_cycles: self.overlap_cycles,
            aux_cycles: self.aux_cycles,
            tile_footprint_bytes: self.tile_footprint_bytes,
            macs: self.macs,
            chained_bytes: self.residency.chained_bytes,
        }
    }
}

/// An immutable compiled workload: what every run of `(cfg, workload)`
/// shares, and what [`PlanCache`] stores.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadPlan {
    pub workload: String,
    /// Fingerprint of the [`ChipConfig`] this plan was built under (see
    /// [`cache::fingerprint`]; excludes the operating point).
    pub fingerprint: u64,
    pub layers: Vec<LayerPlan>,
    /// Distinct tile specs the backing cache had simulated when planning
    /// finished (the report's `unique_tiles`).
    pub unique_tiles: usize,
    pub dispatched_tiles: u64,
}

impl WorkloadPlan {
    /// Total planned latency without materializing a report.
    pub fn total_latency_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.latency_cycles).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    pub fn total_dma_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.dma_bytes).sum()
    }

    /// Total tile-engine busy cycles (compute + CSR/reshuffle aux).
    pub fn total_compute_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.tiles.total_cycles + l.aux_cycles).sum()
    }

    pub fn total_dma_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.dma_cycles).sum()
    }
}

/// Compile a workload: per-layer planning, then the residency pass over
/// the layer sequence. Pure in `(cfg, w)` — the cache only memoizes.
pub fn build<C: SimCache>(cfg: &ChipConfig, w: &Workload, cache: &mut C) -> WorkloadPlan {
    let mut layers: Vec<LayerPlan> = Vec::with_capacity(w.layers.len());
    for l in &w.layers {
        layers.push(planner::plan_layer(cfg, l, cache));
    }
    residency::apply(cfg, &w.layers, &mut layers);
    let dispatched_tiles = layers.iter().map(|l| l.dispatched_tiles).sum();
    WorkloadPlan {
        workload: w.name.clone(),
        fingerprint: cache::fingerprint(cfg),
        layers,
        unique_tiles: cache.unique_tiles(),
        dispatched_tiles,
    }
}

/// [`build`] with the per-layer planning fanned out over the shared
/// scoped worker pool ([`crate::runtime::pool::scoped_indexed`], the
/// `sweep --threads` idiom, one level down): layers are claimed off an
/// atomic index, planned into per-layer slots, and reassembled in
/// workload order before the sequential residency pass.
///
/// Bit-identical to the sequential [`build`]: `plan_layer` is a pure
/// function of `(cfg, layer)` (the tile and mapper caches only
/// memoize, and each worker's [`IncrementalMapper`] hint only prunes),
/// the residency pass runs after the barrier exactly as the sequential
/// path runs it, and `unique_tiles` is read from the shared cache once
/// planning is complete — pinned by `tests/plan_cache.rs`.
pub fn build_parallel(
    cfg: &ChipConfig,
    w: &Workload,
    tiles: &SharedTileCache,
    threads: usize,
) -> WorkloadPlan {
    let n = w.layers.len();
    if threads.clamp(1, n.max(1)) <= 1 {
        let mut handle = tiles;
        return build(cfg, w, &mut handle);
    }
    let mut layers = crate::runtime::pool::scoped_indexed(
        n,
        threads,
        IncrementalMapper::global,
        |mapper, i| {
            let mut handle = tiles;
            planner::plan_layer_mapped(cfg, &w.layers[i], &mut handle, mapper)
        },
    );
    residency::apply(cfg, &w.layers, &mut layers);
    let dispatched_tiles = layers.iter().map(|l| l.dispatched_tiles).sum();
    WorkloadPlan {
        workload: w.name.clone(),
        fingerprint: cache::fingerprint(cfg),
        layers,
        unique_tiles: tiles.len(),
        dispatched_tiles,
    }
}

/// [`build`] against a shared tile cache with a caller-persistent
/// [`IncrementalMapper`] — the search driver's per-worker build
/// (DESIGN.md §15). Strictly sequential over layers: each search
/// worker is already one lane of the outer config pool, and the
/// surviving mapper hint seeds the first layer of the *next* grid
/// point (adjacent points usually share their mapper equivalence
/// class, so the incumbent prunes immediately). Bit-identical to
/// [`build`] / [`build_parallel`] — the hint only prunes.
pub fn build_seeded(
    cfg: &ChipConfig,
    w: &Workload,
    tiles: &SharedTileCache,
    mapper: &mut IncrementalMapper<'_>,
) -> WorkloadPlan {
    let mut handle = tiles;
    let mut layers: Vec<LayerPlan> = Vec::with_capacity(w.layers.len());
    for l in &w.layers {
        layers.push(planner::plan_layer_mapped(cfg, l, &mut handle, mapper));
    }
    residency::apply(cfg, &w.layers, &mut layers);
    let dispatched_tiles = layers.iter().map(|l| l.dispatched_tiles).sum();
    WorkloadPlan {
        workload: w.name.clone(),
        fingerprint: cache::fingerprint(cfg),
        layers,
        unique_tiles: tiles.len(),
        dispatched_tiles,
    }
}

/// Execute a plan: resolve every layer's timeline through the pipeline
/// scheduler and assemble the report. Deterministic — the same plan
/// always yields a bit-identical [`WorkloadReport`].
pub fn execute(plan: &WorkloadPlan) -> WorkloadReport {
    WorkloadReport {
        metrics: WorkloadMetrics {
            name: plan.workload.clone(),
            layers: plan.layers.iter().map(|l| l.resolve()).collect(),
        },
        unique_tiles: plan.unique_tiles,
        dispatched_tiles: plan.dispatched_tiles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::TileCache;
    use crate::workloads;

    #[test]
    fn build_then_execute_matches_macs() {
        let cfg = ChipConfig::voltra();
        let w = workloads::by_name("pointnext").unwrap();
        let mut cache = TileCache::new();
        let plan = build(&cfg, &w, &mut cache);
        assert_eq!(plan.total_macs(), w.total_macs());
        let r = execute(&plan);
        assert_eq!(r.metrics.total_macs(), w.total_macs());
        assert_eq!(r.metrics.total_latency_cycles(), plan.total_latency_cycles());
        assert_eq!(r.dispatched_tiles, plan.dispatched_tiles);
    }

    #[test]
    fn execute_is_repeatable_bit_identical() {
        let cfg = ChipConfig::voltra();
        let w = workloads::by_name("lstm").unwrap();
        let mut cache = TileCache::new();
        let plan = build(&cfg, &w, &mut cache);
        let a = execute(&plan);
        let b = execute(&plan);
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_build_is_bit_identical_to_sequential() {
        let cfg = ChipConfig::voltra();
        let w = workloads::by_name("resnet50").unwrap();
        let shared = crate::coordinator::SharedTileCache::new();
        let mut handle = &shared;
        let seq = build(&cfg, &w, &mut handle);
        for threads in [1, 4] {
            let tiles = crate::coordinator::SharedTileCache::new();
            let par = build_parallel(&cfg, &w, &tiles, threads);
            assert_eq!(par, seq, "threads={threads}");
        }
    }

    #[test]
    fn plan_dma_cycles_match_timeline_runs() {
        // Invariant the scheduler depends on: a layer's accounted DMA
        // cycles equal the sum of its run shares, chained or not.
        let cfg = ChipConfig::voltra();
        for name in ["llama-decode", "resnet50"] {
            let w = workloads::by_name(name).unwrap();
            let mut cache = TileCache::new();
            let plan = build(&cfg, &w, &mut cache);
            for l in &plan.layers {
                let run_dma: u64 = l
                    .timeline
                    .gemms
                    .iter()
                    .flat_map(|g| g.runs.iter())
                    .map(|r| r.count * r.dma_cycles)
                    .sum();
                assert_eq!(run_dma, l.dma_cycles, "{}/{}", name, l.name);
            }
        }
    }
}
