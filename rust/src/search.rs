//! Architecture/mapping co-search over the `ChipConfig` space
//! (DESIGN.md §15) — `voltra search`.
//!
//! `shmoo` walks one axis at a time; real design-space exploration
//! searches the axes *jointly* — the Timeloop-style factor sweeps of
//! focus_scheduler, and what FlexNN (arXiv 2403.09026) argues flexible
//! accelerators need. This module enumerates joint (array geometry,
//! bank count, stream-FIFO depth, memory organisation) design points,
//! plans each over the full eight-workload suite through the existing
//! `PlanCache` / `MapperCache` / `SharedTileCache` stack, scores every
//! point with the in-tree `power/` area/energy models, and emits a
//! three-axis Pareto frontier (TOPS/W vs TOPS/mm² vs suite latency)
//! that reproduces the shipped 16 nm config as one dot on the curve.
//!
//! Feasibility rests on two mechanisms this PR added underneath:
//!
//! * **structural cache keying** — tile-simulation caches are keyed by
//!   [`crate::sim::tile_fingerprint`] (the slice the tile engine reads)
//!   and mapper entries by the mapper's own narrow fingerprint, so
//!   near-identical grid neighbors share cold work: the 32-point grid
//!   collapses to 16 tile-structural and 16 mapper equivalence classes,
//!   and a point whose class was already visited pays only plan
//!   assembly, not tile simulation or mapping search;
//! * **a work-stealing search pool** — grid points are claimed off the
//!   shared scoped pool ([`crate::runtime::pool::scoped_indexed`]) by
//!   `min(cores, 8)` workers, each carrying one [`IncrementalMapper`]
//!   whose hint survives *across* adjacent grid points (the
//!   seeded-neighborhood mode): consecutive points usually share their
//!   mapper class, so the incumbent prunes immediately. Workers plan
//!   through [`PlanCache::plan_seeded`] — sequential per point, since
//!   the pool is already saturated at the config level and nesting the
//!   per-layer pool would oversubscribe.
//!
//! The `perf_search` bench gates the whole construction: shared-cache
//! parallel search must beat the isolated-cache serial baseline ≥4x on
//! the fixed 32-point grid.

use std::collections::BTreeMap;
use std::collections::HashSet;

use crate::config::{ArrayGeometry, ChipConfig, MemoryOrg, OperatingPoint};
use crate::metrics::CacheStats;
use crate::plan::{self, PlanCache, PlanCacheStats};
use crate::power::energy::workload_energy_j;
use crate::power::{Activity, AreaModel, EnergyParams};
use crate::runtime::json::Json;
use crate::runtime::pool;
use crate::tiling::mapper::{self, IncrementalMapper, MapperCache};
use crate::workloads::{self, Workload};

/// One enumerated design point, scored over the full workload suite.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    /// `<geometry>/b<banks>/f<fifo>/<memory>` — unique within a grid.
    pub label: String,
    pub config: ChipConfig,
    /// Die area from [`AreaModel::config_area`] (mm²).
    pub area_mm2: f64,
    /// Suite latency, summed over the eight workloads (cycle-domain,
    /// frequency-independent).
    pub suite_latency_cycles: u64,
    /// Suite energy at the efficiency point 0.6 V / 300 MHz (mJ).
    pub suite_energy_mj: f64,
    /// Effective suite TOPS/W at the efficiency point: total useful
    /// ops over total energy.
    pub tops_per_watt: f64,
    /// Peak TOPS (performance point) per die mm².
    pub tops_per_mm2: f64,
    /// On the three-axis Pareto frontier of its grid.
    pub pareto: bool,
}

/// Cache telemetry of one search run — the evidence that structural
/// keying collapsed the grid into equivalence classes.
#[derive(Clone, Copy, Debug, Default)]
pub struct SearchStats {
    /// Distinct tile-structural classes the grid touched (tile caches
    /// materialized by the plan cache).
    pub tile_classes: usize,
    /// Distinct mapper fingerprints across the grid.
    pub mapper_classes: usize,
    pub plan: PlanCacheStats,
    pub tiles: CacheStats,
    pub mapper: CacheStats,
    pub mapper_waits: u64,
}

/// The outcome of [`run_grid`]: scored points (grid order) + telemetry.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub points: Vec<DesignPoint>,
    pub stats: SearchStats,
}

fn geometry_axis() -> [(&'static str, ArrayGeometry); 2] {
    [
        ("3d8x8x8", ArrayGeometry::Spatial3D { m: 8, n: 8, k: 8 }),
        ("2d16x32", ArrayGeometry::Spatial2D { m: 16, n: 32 }),
    ]
}

/// Memory-organisation axis. Every separated split keeps the proven
/// 24 KB output / 8 KB psum regions of the Fig. 6c baseline and varies
/// only the input/weight partition, so all points stay feasible for
/// every suite layer; `sep-weight` is bit-identical to
/// [`ChipConfig::separated_memory`]'s organisation.
fn memory_axis() -> [(&'static str, MemoryOrg); 4] {
    let sep = |input: usize, weight: usize| MemoryOrg::Separated {
        input: input * 1024,
        weight: weight * 1024,
        output: 24 * 1024,
        psum: 8 * 1024,
    };
    [
        ("shared", MemoryOrg::Shared),
        ("sep-weight", sep(40, 56)),
        ("sep-even", sep(48, 48)),
        ("sep-input", sep(56, 40)),
    ]
}

/// One grid/neighbor config: the shipped chip with the four searched
/// axes overridden. Separated points drop double buffering — fixed
/// per-operand buffers cannot ping-pong (the Fig. 6c argument), and
/// keeping the physics consistent makes the `sep-weight/b32/f8` point
/// coincide exactly with the `separated` preset.
fn grid_config(geom: ArrayGeometry, banks: usize, fifo: usize, memory: MemoryOrg) -> ChipConfig {
    let mut cfg = ChipConfig::voltra();
    cfg.array = geom;
    cfg.num_banks = banks;
    cfg.stream_fifo_depth = fifo;
    cfg.memory = memory;
    if matches!(memory, MemoryOrg::Separated { .. }) {
        cfg.double_buffer = false;
    }
    cfg
}

fn label(geom: &str, banks: usize, fifo: usize, mem: &str) -> String {
    format!("{geom}/b{banks}/f{fifo}/{mem}")
}

/// The fixed 32-point search grid: 2 geometries × {16, 32} banks ×
/// stream-FIFO depth {4, 8} × 4 memory organisations, memory innermost
/// so the three separated splits of each cell sit adjacently (they
/// share one tile-structural class). The shipped config is the
/// `3d8x8x8/b32/f8/shared` point.
pub fn full_grid() -> Vec<(String, ChipConfig)> {
    let mut out = Vec::with_capacity(32);
    for (gname, geom) in geometry_axis() {
        for banks in [16usize, 32] {
            for fifo in [4usize, 8] {
                for (mname, mem) in memory_axis() {
                    out.push((
                        label(gname, banks, fifo, mname),
                        grid_config(geom, banks, fifo, mem),
                    ));
                }
            }
        }
    }
    out
}

/// A 6-point subgrid covering every axis once (banks, FIFO depth,
/// geometry, memory kind) around the shipped point — what the golden
/// CLI test and debug builds drive, cheap enough for the debug-build
/// verifier to check every compiled plan.
pub fn quick_grid() -> Vec<(String, ChipConfig)> {
    let g3 = geometry_axis()[0].1;
    let g2 = geometry_axis()[1].1;
    let sep = memory_axis()[1].1;
    vec![
        (
            label("3d8x8x8", 16, 8, "shared"),
            grid_config(g3, 16, 8, MemoryOrg::Shared),
        ),
        (
            label("3d8x8x8", 32, 4, "shared"),
            grid_config(g3, 32, 4, MemoryOrg::Shared),
        ),
        (
            label("3d8x8x8", 32, 8, "shared"),
            grid_config(g3, 32, 8, MemoryOrg::Shared),
        ),
        (
            label("3d8x8x8", 32, 8, "sep-weight"),
            grid_config(g3, 32, 8, sep),
        ),
        (
            label("3d8x8x8", 16, 4, "shared"),
            grid_config(g3, 16, 4, MemoryOrg::Shared),
        ),
        (
            label("2d16x32", 32, 8, "shared"),
            grid_config(g2, 32, 8, MemoryOrg::Shared),
        ),
    ]
}

/// Every one-step move along a single search axis away from the
/// shipped config — the neighborhood the Pareto-optimality test pins
/// (`tests/search_pareto.rs`): none of these may dominate the shipped
/// point on all three score axes.
pub fn one_step_neighbors() -> Vec<(String, ChipConfig)> {
    let v = ChipConfig::voltra();
    let mut out: Vec<(String, ChipConfig)> = Vec::new();
    for banks in [16usize, 64] {
        let mut c = v.clone();
        c.num_banks = banks;
        out.push((label("3d8x8x8", banks, 8, "shared"), c));
    }
    for fifo in [4usize, 16] {
        let mut c = v.clone();
        c.stream_fifo_depth = fifo;
        out.push((label("3d8x8x8", 32, fifo, "shared"), c));
    }
    out.push((
        label("2d16x32", 32, 8, "shared"),
        grid_config(geometry_axis()[1].1, 32, 8, MemoryOrg::Shared),
    ));
    out.push((
        label("3d8x8x8", 32, 8, "sep-weight"),
        grid_config(geometry_axis()[0].1, 32, 8, memory_axis()[1].1),
    ));
    out
}

/// Score one design point over `suite`: plan every workload through
/// the shared caches (seeded, sequential — see module docs), then
/// fold latency, energy-point efficiency and area efficiency.
pub fn score_config(
    label: &str,
    cfg: &ChipConfig,
    suite: &[Workload],
    plans: &PlanCache,
    mapper: &mut IncrementalMapper<'_>,
) -> DesignPoint {
    let params = EnergyParams::default();
    let act = Activity::default();
    let op = OperatingPoint::efficiency();
    let mut latency: u64 = 0;
    let mut macs: u64 = 0;
    let mut energy_j: f64 = 0.0;
    for w in suite {
        let plan = plans.plan_seeded(cfg, w, mapper);
        let report = plan::execute(&plan);
        latency += plan.total_latency_cycles();
        macs += plan.total_macs();
        energy_j += workload_energy_j(&params, &report.metrics, &act, op);
    }
    let area_mm2 = AreaModel::default().config_area(cfg);
    DesignPoint {
        label: label.to_string(),
        config: cfg.clone(),
        area_mm2,
        suite_latency_cycles: latency,
        suite_energy_mj: energy_j * 1e3,
        tops_per_watt: 2.0 * macs as f64 / energy_j / 1e12,
        tops_per_mm2: cfg.peak_tops() / area_mm2,
        pareto: false,
    }
}

/// Three-axis Pareto dominance: `a` dominates `b` when it is no worse
/// on suite latency, TOPS/W and TOPS/mm², and strictly better on at
/// least one.
pub fn dominates(a: &DesignPoint, b: &DesignPoint) -> bool {
    let no_worse = a.suite_latency_cycles <= b.suite_latency_cycles
        && a.tops_per_watt >= b.tops_per_watt
        && a.tops_per_mm2 >= b.tops_per_mm2;
    let better = a.suite_latency_cycles < b.suite_latency_cycles
        || a.tops_per_watt > b.tops_per_watt
        || a.tops_per_mm2 > b.tops_per_mm2;
    no_worse && better
}

/// Mark each point's frontier membership: on the frontier iff no other
/// point dominates it.
pub fn mark_pareto(points: &mut [DesignPoint]) {
    let on: Vec<bool> = points
        .iter()
        .map(|p| !points.iter().any(|o| dominates(o, p)))
        .collect();
    for (p, keep) in points.iter_mut().zip(on) {
        p.pareto = keep;
    }
}

/// The search pool width: `min(cores, 8)` — the plan-compile sizing,
/// one level up.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

/// Run the co-search over `grid` on `threads` pool workers with fresh
/// shared caches, mark the Pareto frontier, and collect telemetry.
/// Deterministic for a fixed grid: every point's score is a pure
/// function of its config (caches memoize, seeds prune), so thread
/// count and claim order never change the output.
pub fn run_grid(grid: &[(String, ChipConfig)], threads: usize) -> SearchResult {
    let suite = workloads::evaluation_suite();
    let plans = PlanCache::new();
    let mappers = MapperCache::new();
    let mut points = pool::scoped_indexed(
        grid.len(),
        threads,
        || IncrementalMapper::new(&mappers),
        |im, i| score_config(&grid[i].0, &grid[i].1, &suite, &plans, im),
    );
    mark_pareto(&mut points);
    let mapper_classes = grid
        .iter()
        .map(|(_, c)| mapper::fingerprint(c))
        .collect::<HashSet<u64>>()
        .len();
    let stats = SearchStats {
        tile_classes: plans.tile_cache_count(),
        mapper_classes,
        plan: plans.plan_stats(),
        tiles: plans.tile_stats(),
        mapper: mappers.stats(),
        mapper_waits: mappers.coalesced_waits(),
    };
    SearchResult { points, stats }
}

/// The label of the grid point that is plan-identical to the shipped
/// chip (same full plan fingerprint as [`ChipConfig::voltra`]), if the
/// grid contains one.
pub fn shipped_label(points: &[DesignPoint]) -> Option<&str> {
    let shipped = plan::fingerprint(&ChipConfig::voltra());
    points
        .iter()
        .find(|p| plan::fingerprint(&p.config) == shipped)
        .map(|p| p.label.as_str())
}

fn memory_name(m: MemoryOrg) -> String {
    match m {
        MemoryOrg::Shared => "shared".to_string(),
        MemoryOrg::Separated {
            input,
            weight,
            output,
            psum,
        } => format!(
            "separated-{}-{}-{}-{}",
            input / 1024,
            weight / 1024,
            output / 1024,
            psum / 1024
        ),
    }
}

fn geometry_name(g: ArrayGeometry) -> String {
    match g {
        ArrayGeometry::Spatial3D { m, n, k } => format!("3d{m}x{n}x{k}"),
        ArrayGeometry::Spatial2D { m, n } => format!("2d{m}x{n}"),
    }
}

/// Machine-readable search output (`voltra search --json`), schema in
/// DESIGN.md §15. Deterministic — no timings, no cache counters that
/// depend on interleaving; golden-tested in `tests/search_cli.rs`.
pub fn result_json(grid_name: &str, r: &SearchResult) -> Json {
    let shipped = shipped_label(&r.points);
    let shipped_json = match shipped {
        Some(label) => Json::Str(label.to_string()),
        None => Json::Null,
    };
    let mut frontier = Vec::new();
    for p in &r.points {
        if p.pareto {
            frontier.push(Json::Str(p.label.clone()));
        }
    }
    let mut results = Vec::new();
    for p in &r.points {
        results.push(point_json(p, shipped == Some(p.label.as_str())));
    }
    let mut doc = BTreeMap::new();
    doc.insert("grid".to_string(), Json::Str(grid_name.to_string()));
    doc.insert("points".to_string(), Json::Num(r.points.len() as f64));
    doc.insert(
        "tile_classes".to_string(),
        Json::Num(r.stats.tile_classes as f64),
    );
    doc.insert(
        "mapper_classes".to_string(),
        Json::Num(r.stats.mapper_classes as f64),
    );
    doc.insert("shipped".to_string(), shipped_json);
    doc.insert("frontier".to_string(), Json::Arr(frontier));
    doc.insert("results".to_string(), Json::Arr(results));
    Json::Obj(doc)
}

fn point_json(p: &DesignPoint, is_shipped: bool) -> Json {
    let geometry = Json::Str(geometry_name(p.config.array));
    let memory = Json::Str(memory_name(p.config.memory));
    let fifo = Json::Num(p.config.stream_fifo_depth as f64);
    let latency = Json::Num(p.suite_latency_cycles as f64);
    let energy = Json::Num(p.suite_energy_mj);
    let mut o = BTreeMap::new();
    o.insert("label".to_string(), Json::Str(p.label.clone()));
    o.insert("geometry".to_string(), geometry);
    o.insert("banks".to_string(), Json::Num(p.config.num_banks as f64));
    o.insert("fifo_depth".to_string(), fifo);
    o.insert("memory".to_string(), memory);
    o.insert("area_mm2".to_string(), Json::Num(p.area_mm2));
    o.insert("suite_latency_cycles".to_string(), latency);
    o.insert("suite_energy_mj".to_string(), energy);
    o.insert("tops_per_watt".to_string(), Json::Num(p.tops_per_watt));
    o.insert("tops_per_mm2".to_string(), Json::Num(p.tops_per_mm2));
    o.insert("pareto".to_string(), Json::Bool(p.pareto));
    o.insert("shipped".to_string(), Json::Bool(is_shipped));
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::tile_fingerprint;

    #[test]
    fn full_grid_is_32_unique_points_with_the_shipped_one() {
        let grid = full_grid();
        assert_eq!(grid.len(), 32);
        let labels: HashSet<&str> = grid.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels.len(), 32, "labels must be unique");
        let fps: HashSet<u64> = grid.iter().map(|(_, c)| plan::fingerprint(c)).collect();
        assert_eq!(fps.len(), 32, "configs must be pairwise distinct");
        assert!(
            fps.contains(&plan::fingerprint(&ChipConfig::voltra())),
            "the shipped chip must be one grid point"
        );
        // The separated preset is also a grid point, bit-identically.
        assert!(fps.contains(&plan::fingerprint(&ChipConfig::separated_memory())));
    }

    #[test]
    fn grid_collapses_into_the_advertised_equivalence_classes() {
        let grid = full_grid();
        let tile: HashSet<u64> = grid.iter().map(|(_, c)| tile_fingerprint(c)).collect();
        assert_eq!(tile.len(), 16, "3 separated splits share each tile class");
        let map: HashSet<u64> = grid.iter().map(|(_, c)| mapper::fingerprint(c)).collect();
        assert_eq!(map.len(), 16, "FIFO depth is mapper-invariant");
    }

    #[test]
    fn quick_grid_is_a_subgrid_containing_the_shipped_point() {
        let quick = quick_grid();
        assert_eq!(quick.len(), 6);
        let full: HashSet<String> = full_grid().iter().map(|(l, _)| l.clone()).collect();
        for (l, _) in &quick {
            assert!(full.contains(l), "{l} is not a full-grid point");
        }
        let fps: HashSet<u64> = quick.iter().map(|(_, c)| plan::fingerprint(c)).collect();
        assert!(fps.contains(&plan::fingerprint(&ChipConfig::voltra())));
    }

    #[test]
    fn neighbors_move_exactly_one_axis() {
        let shipped = plan::fingerprint(&ChipConfig::voltra());
        let n = one_step_neighbors();
        assert_eq!(n.len(), 6);
        for (l, c) in &n {
            assert_ne!(plan::fingerprint(c), shipped, "{l} must differ");
            let v = ChipConfig::voltra();
            let moved = [
                c.num_banks != v.num_banks,
                c.stream_fifo_depth != v.stream_fifo_depth,
                c.array != v.array,
                c.memory != v.memory,
            ]
            .iter()
            .filter(|&&b| b)
            .count();
            assert_eq!(moved, 1, "{l} must move exactly one axis");
        }
    }

    #[test]
    fn dominance_is_strict_and_pareto_marks_the_frontier() {
        let mk = |lat: u64, tw: f64, tm: f64| DesignPoint {
            label: format!("{lat}-{tw}-{tm}"),
            config: ChipConfig::voltra(),
            area_mm2: 1.0,
            suite_latency_cycles: lat,
            suite_energy_mj: 1.0,
            tops_per_watt: tw,
            tops_per_mm2: tm,
            pareto: false,
        };
        let a = mk(100, 2.0, 2.0);
        let b = mk(200, 1.0, 1.0); // dominated by a
        let c = mk(50, 0.5, 3.0); // trades latency/TOPS-W against a
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        assert!(!dominates(&a, &c) && !dominates(&c, &a));
        assert!(!dominates(&a, &a), "equal points never dominate");
        let mut pts = vec![a, b, c];
        mark_pareto(&mut pts);
        assert_eq!(
            pts.iter().map(|p| p.pareto).collect::<Vec<_>>(),
            vec![true, false, true]
        );
    }
}
