//! Rank-tagged synchronization facade (DESIGN.md §16): every lock in
//! the engine is a [`Mutex`], [`RwLock`] or [`Condvar`] from this
//! module, never `std::sync` directly (mechanically enforced by
//! `clippy.toml`'s `disallowed-types`). The facade buys three
//! correctness properties the raw primitives do not have:
//!
//! * **Deadlock freedom by construction.** Every lock carries a
//!   [`Rank`] from the static lock-rank table below, and debug builds
//!   assert that each thread acquires ranks in strictly increasing
//!   order. A system whose every thread acquires locks monotonically
//!   in one global order cannot build a cyclic wait — the classic
//!   lock-ordering argument, here checked on every acquisition instead
//!   of asserted in a comment. *Strictly* increasing means same-rank
//!   nesting is banned too — including re-entrant reads of one
//!   [`RwLock`] on a single thread, which `std::sync::RwLock` itself
//!   documents may deadlock when a writer is queued between the two
//!   read acquisitions. Concurrent readers on *distinct* threads are
//!   of course fine: the rank stack is thread-local. The `lockorder`
//!   protocol model (`crate::check`) explores the same table
//!   adversarially.
//!
//! * **No bare condition-variable waits.** [`Condvar`] exposes only
//!   [`Condvar::wait_while`]: the predicate loop is part of the call,
//!   so a spurious wakeup can never leak past an unmet condition. The
//!   missed-notify half of the argument is the `flight` protocol model.
//!
//! * **A defined lock-poisoning policy.** Every acquisition recovers
//!   from poison (`PoisonError::into_inner`) instead of propagating a
//!   panic. This is a deliberate policy, not a shrug: every critical
//!   section in the engine either only reads, or performs a single
//!   atomic-shaped mutation (one `insert`, one slot store) — there is
//!   no partially-applied state a panicking holder could expose. A
//!   panic inside single-flight leadership is converted by
//!   [`crate::coordinator::singleflight`]'s abort protocol into
//!   "followers retry", which is the recovery the serving tier wants —
//!   one failed request, not a poison cascade that takes the whole
//!   cache tier down with `.expect("poisoned")`.
//!
//! # The lock-rank table
//!
//! | rank | lock | holder |
//! |---|---|---|
//! | 10 `PlanShard`     | `PlanCache` plan-map shard            | `plan::cache` |
//! | 20 `TileClassMap`  | `PlanCache` structural tile-class map | `plan::cache` |
//! | 30 `MapperShard`   | `MapperCache` shard                   | `tiling::mapper` |
//! | 40 `TileShard`     | `SharedTileCache` shard               | `coordinator` |
//! | 50 `FlightMap`     | `FlightGroup` in-flight map           | `coordinator::singleflight` |
//! | 60 `FlightSlot`    | per-flight publish slot (+ condvar)   | `coordinator::singleflight` |
//! | 70 `DispatchQueue` | dispatch-pool receiver                | `coordinator::dispatch` |
//! | 80 `PoolSlot`      | scoped-pool result slot               | `runtime::pool` |
//!
//! The only *nested* acquisitions in the tree today are
//! `TileClassMap -> TileShard` (`PlanCache::unique_tiles` walks every
//! class's cache under the class map) — monotone under the table. New
//! concurrency code must pick a rank that keeps its nesting monotone
//! and extend the table + the `lockorder` model (the bless protocol,
//! DESIGN.md §16).
//!
//! # Telemetry
//!
//! Two process-wide counters feed the serving tier's `STATS` verb and
//! the `voltra report` footer: [`flight_aborts`] (single-flight leaders
//! that died without publishing — every one is a herd that retried) and
//! [`max_rank_depth`] (the deepest lock nesting any thread has actually
//! built — the observed ceiling on hold chains; 2 in the tree today).

// The facade is the one sanctioned home of the raw primitives.
#![allow(clippy::disallowed_types)]

use std::cell::RefCell;
use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::PoisonError;

/// The static lock-rank table (see module docs). Discriminants are the
/// ranks; gaps leave room for future tiers without renumbering.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum Rank {
    PlanShard = 10,
    TileClassMap = 20,
    MapperShard = 30,
    TileShard = 40,
    FlightMap = 50,
    FlightSlot = 60,
    DispatchQueue = 70,
    PoolSlot = 80,
}

impl fmt::Display for Rank {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}({})", self, *self as u8)
    }
}

thread_local! {
    /// Ranks this thread currently holds, in acquisition order.
    static HELD: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
}

/// Deepest lock nesting observed by any thread since process start.
static MAX_RANK_DEPTH: AtomicU64 = AtomicU64::new(0);

/// Single-flight leaders that retired without publishing (panic unwind
/// or resolve failure): each one sent its followers around the
/// abort-and-retry loop. Bumped by `coordinator::singleflight`.
static FLIGHT_ABORTS: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of aborted single-flight leaderships.
pub fn flight_aborts() -> u64 {
    FLIGHT_ABORTS.load(Ordering::Relaxed)
}

pub(crate) fn record_flight_abort() {
    FLIGHT_ABORTS.fetch_add(1, Ordering::Relaxed);
}

/// Deepest lock-rank nesting any thread has built since process start
/// (the serving tier's `rank_depth` STATS field).
pub fn max_rank_depth() -> u64 {
    MAX_RANK_DEPTH.load(Ordering::Relaxed)
}

/// Record one acquisition: assert the rank table, track the depth.
fn acquired(rank: Rank) {
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some(&top) = held.last() {
            debug_assert!(
                top < rank as u8,
                "lock-rank inversion: acquiring {rank} while holding rank {top} \
                 (acquisition order must be strictly increasing — see the \
                 rank table in sync/mod.rs)"
            );
        }
        held.push(rank as u8);
        MAX_RANK_DEPTH.fetch_max(held.len() as u64, Ordering::Relaxed);
    });
}

/// Record one release. Guards usually unwind in reverse acquisition
/// order, but the bookkeeping tolerates any order (drop the latest
/// holding of that rank).
fn released(rank: Rank) {
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some(pos) = held.iter().rposition(|&r| r == rank as u8) {
            held.remove(pos);
        }
    });
}

/// A rank-tagged mutual-exclusion lock (poison-recovering; see the
/// module docs for the policy).
pub struct Mutex<T> {
    rank: Rank,
    inner: std::sync::Mutex<T>,
}

impl<T> Mutex<T> {
    pub fn new(rank: Rank, value: T) -> Self {
        Mutex {
            rank,
            inner: std::sync::Mutex::new(value),
        }
    }

    /// Acquire the lock. Asserts the rank table in debug builds and
    /// recovers from poison (the policy: critical sections never hold
    /// partially-applied state).
    pub fn lock(&self) -> MutexGuard<'_, T> {
        let inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        acquired(self.rank);
        MutexGuard {
            inner: Some(inner),
            rank: self.rank,
        }
    }

    /// Consume the lock, returning its value (poison-recovering).
    pub fn into_inner(self) -> T {
        self.inner
            .into_inner()
            .unwrap_or_else(PoisonError::into_inner)
    }
}

/// Guard for [`Mutex::lock`]. The `Option` is a facade-internal
/// implementation detail: [`Condvar::wait_while`] moves the underlying
/// guard out across the wait without double-counting the rank.
pub struct MutexGuard<'a, T> {
    inner: Option<std::sync::MutexGuard<'a, T>>,
    rank: Rank,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard is live")
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard is live")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if self.inner.is_some() {
            released(self.rank);
        }
    }
}

/// A rank-tagged condition variable. Deliberately narrower than
/// `std::sync::Condvar`: there is no bare `wait` — every wait states
/// its predicate, so spurious wakeups are structurally harmless
/// (satellite of DESIGN.md §16; the `flight` model checks the
/// protocol-level half).
pub struct Condvar {
    inner: std::sync::Condvar,
}

impl Condvar {
    pub fn new() -> Self {
        Condvar {
            inner: std::sync::Condvar::new(),
        }
    }

    /// Block while `condition` holds, rechecking on every wakeup. The
    /// rank stays accounted to this thread for the duration: a blocked
    /// waiter still *owns* its slot lock between wakeups, and it
    /// acquires nothing else while parked.
    pub fn wait_while<'a, T, F>(&self, mut guard: MutexGuard<'a, T>, condition: F) -> MutexGuard<'a, T>
    where
        F: FnMut(&mut T) -> bool,
    {
        let rank = guard.rank;
        let inner = guard.inner.take().expect("guard is live");
        drop(guard); // rank deliberately NOT released (inner is None)
        let inner = self
            .inner
            .wait_while(inner, condition)
            .unwrap_or_else(PoisonError::into_inner);
        MutexGuard {
            inner: Some(inner),
            rank,
        }
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Condvar::new()
    }
}

/// A rank-tagged reader-writer lock (poison-recovering). Read and
/// write acquisitions observe the same rank discipline — a read guard
/// held across a lower-rank acquisition is just as much an inversion
/// as a write guard, and a *re-entrant* read (two read guards of one
/// lock held by one thread) is banned outright: `std::sync::RwLock`
/// documents that a recursive read may deadlock once a writer queues
/// between the two acquisitions, so the strict `top < rank` assert
/// deliberately refuses it in debug builds rather than letting it
/// deadlock rarely in production. Readers on distinct threads share
/// freely — the rank stack is per-thread.
pub struct RwLock<T> {
    rank: Rank,
    inner: std::sync::RwLock<T>,
}

impl<T> RwLock<T> {
    pub fn new(rank: Rank, value: T) -> Self {
        RwLock {
            rank,
            inner: std::sync::RwLock::new(value),
        }
    }

    pub fn read(&self) -> RwLockReadGuard<'_, T> {
        let inner = self.inner.read().unwrap_or_else(PoisonError::into_inner);
        acquired(self.rank);
        RwLockReadGuard {
            inner,
            rank: self.rank,
        }
    }

    pub fn write(&self) -> RwLockWriteGuard<'_, T> {
        let inner = self.inner.write().unwrap_or_else(PoisonError::into_inner);
        acquired(self.rank);
        RwLockWriteGuard {
            inner,
            rank: self.rank,
        }
    }
}

pub struct RwLockReadGuard<'a, T> {
    inner: std::sync::RwLockReadGuard<'a, T>,
    rank: Rank,
}

impl<T> Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> Drop for RwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        released(self.rank);
    }
}

pub struct RwLockWriteGuard<'a, T> {
    inner: std::sync::RwLockWriteGuard<'a, T>,
    rank: Rank,
}

impl<T> Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

impl<T> Drop for RwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        released(self.rank);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn mutex_round_trips_and_tracks_depth() {
        let m = Mutex::new(Rank::PoolSlot, 41);
        {
            let mut g = m.lock();
            *g += 1;
        }
        assert_eq!(m.into_inner(), 42);
        assert!(max_rank_depth() >= 1);
    }

    #[test]
    fn rwlock_readers_share_and_writers_exclude() {
        let l = RwLock::new(Rank::TileShard, vec![1, 2, 3]);
        // Readers share — proven from *distinct* threads: the main
        // thread holds a read guard while a spawned reader acquires
        // its own; if reads excluded each other the join would hang.
        // (Two read guards on ONE thread would be same-rank nesting,
        // which the rank table bans — see the RwLock docs.)
        std::thread::scope(|s| {
            let a = l.read();
            let b = s.spawn(|| l.read().len());
            assert_eq!(a.len() + b.join().unwrap(), 6);
        });
        l.write().push(4);
        assert_eq!(l.read().len(), 4);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "lock-rank inversion")]
    fn same_rank_nesting_is_banned_even_for_reads() {
        // Re-entrant reads can deadlock against a writer that queues
        // between the two acquisitions (std::sync::RwLock documents
        // this), so the table treats them as inversions too.
        let l = RwLock::new(Rank::TileShard, ());
        let _a = l.read();
        let _b = l.read();
    }

    #[test]
    fn monotone_nesting_is_accepted() {
        // The one real nesting in the tree: class map -> tile shard.
        let outer = RwLock::new(Rank::TileClassMap, ());
        let inner = RwLock::new(Rank::TileShard, 7);
        let g = outer.read();
        assert_eq!(*inner.read(), 7);
        drop(g);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "lock-rank inversion")]
    fn rank_inversion_is_asserted_in_debug() {
        let hi = Mutex::new(Rank::FlightSlot, ());
        let lo = Mutex::new(Rank::FlightMap, ());
        let _g = hi.lock();
        let _h = lo.lock(); // 50 after 60: inversion
    }

    #[test]
    fn condvar_wait_while_rechecks_the_predicate() {
        let m = Mutex::new(Rank::FlightSlot, false);
        let cv = Condvar::new();
        std::thread::scope(|s| {
            let waiter = s.spawn(|| {
                let g = m.lock();
                let g = cv.wait_while(g, |ready| !*ready);
                *g
            });
            // Set under the lock, then notify — the waiter's predicate
            // loop absorbs any wakeup ordering.
            *m.lock() = true;
            cv.notify_all();
            assert!(waiter.join().unwrap());
        });
    }

    #[test]
    fn poisoned_locks_recover_instead_of_cascading() {
        static BOOM: AtomicBool = AtomicBool::new(false);
        let m = Mutex::new(Rank::DispatchQueue, 7u32);
        let r = std::thread::scope(|s| {
            s.spawn(|| {
                let _g = m.lock();
                BOOM.store(true, Ordering::SeqCst);
                panic!("poison the mutex");
            })
            .join()
        });
        assert!(r.is_err(), "holder must have panicked");
        assert!(BOOM.load(Ordering::SeqCst));
        // The policy: later acquirers see the (valid) state, no cascade.
        assert_eq!(*m.lock(), 7);
    }
}
