//! The workload zoo of Fig. 6: eight networks spanning CNN, point-cloud,
//! RNN and transformer families, defined layer-by-layer with their real
//! published geometries.

pub mod im2col;
pub mod layer;
pub mod lstm;
pub mod mobilenetv2;
pub mod pointnext;
pub mod resnet50;
pub mod transformers;

pub use layer::{GemmOp, Layer, LayerKind, Workload};

/// The eight evaluation workloads in the paper's Fig. 6 order:
/// MobileNetV2 (1), ResNet50 (2), ViT-B (3), PointNeXt (4), LSTM (5),
/// BERT-Base T=512 (6), LLaMA3.2-3B prefill T=256 (7), decode (8).
pub fn evaluation_suite() -> Vec<Workload> {
    vec![
        mobilenetv2::mobilenetv2(),
        resnet50::resnet50(),
        transformers::vit_b(),
        pointnext::pointnext_s(),
        lstm::lstm(),
        transformers::bert_base(512),
        transformers::llama_prefill(256),
        transformers::llama_decode(256, 6),
    ]
}

/// Look a workload up by a CLI-friendly name.
pub fn by_name(name: &str) -> Option<Workload> {
    let n = name.to_ascii_lowercase();
    Some(match n.as_str() {
        "mobilenetv2" | "mobilenet" => mobilenetv2::mobilenetv2(),
        "resnet50" | "resnet" => resnet50::resnet50(),
        "vit" | "vit-b" | "vitb" => transformers::vit_b(),
        "pointnext" => pointnext::pointnext_s(),
        "lstm" => lstm::lstm(),
        "bert" | "bert-base" => transformers::bert_base(512),
        "llama-prefill" | "prefill" => transformers::llama_prefill(256),
        "llama-decode" | "decode" => transformers::llama_decode(256, 6),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_eight_workloads_in_paper_order() {
        let s = evaluation_suite();
        let names: Vec<&str> = s.iter().map(|w| w.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "MobileNetV2",
                "ResNet50",
                "ViT-B",
                "PointNeXt",
                "LSTM",
                "BERT-Base",
                "LLaMA3.2-3B-prefill",
                "LLaMA3.2-3B-decode",
            ]
        );
    }

    #[test]
    fn every_workload_has_nonzero_macs() {
        for w in evaluation_suite() {
            assert!(w.total_macs() > 0, "{} has no MACs", w.name);
        }
    }

    #[test]
    fn by_name_resolves_all() {
        for n in [
            "mobilenetv2",
            "resnet50",
            "vit",
            "pointnext",
            "lstm",
            "bert",
            "llama-prefill",
            "llama-decode",
        ] {
            assert!(by_name(n).is_some(), "{n} not resolvable");
        }
        assert!(by_name("nope").is_none());
    }
}
