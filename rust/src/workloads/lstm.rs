//! Two-layer edge LSTM (hidden 128, batch 32, 128 time steps): the RNN
//! of Fig. 6 (workload 5). The recurrent weight matrices (64 KiB per
//! projection) fit on chip under PDMA and stay resident across all 128
//! steps, while a fixed separated weight buffer must re-stream them —
//! the mechanism behind the Fig. 6c latency gap on recurrent nets.

use crate::workloads::layer::{Layer, LayerKind, Workload};

pub const BATCH: u64 = 64;
pub const HIDDEN: u64 = 128;
pub const INPUT: u64 = 64;
pub const STEPS: u64 = 128;
pub const LAYERS: u64 = 2;

pub fn lstm() -> Workload {
    let mut layers = Vec::new();
    for l in 0..LAYERS {
        let k_x = if l == 0 { INPUT } else { HIDDEN };
        // Per time step: gates = x @ Wx + h @ Wh (accumulated on-chip by
        // the psum streamer), N = 4 * hidden gate columns.
        layers.push(
            Layer::new(
                format!("l{l}_x_gates"),
                LayerKind::Gemm {
                    m: BATCH,
                    k: k_x,
                    n: 4 * HIDDEN,
                },
            )
            .repeated(STEPS),
        );
        layers.push(
            Layer::new(
                format!("l{l}_h_gates"),
                LayerKind::Gemm {
                    m: BATCH,
                    k: HIDDEN,
                    n: 4 * HIDDEN,
                },
            )
            .repeated(STEPS),
        );
    }
    layers.push(Layer::new(
        "fc",
        LayerKind::Gemm {
            m: BATCH,
            k: HIDDEN,
            n: 1000,
        },
    ));
    Workload::new("LSTM", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recurrence_repeats_per_step() {
        let w = lstm();
        let g = w.layers[0].gemms()[0];
        assert_eq!(g.repeat, STEPS);
        assert_eq!(g.m, BATCH);
        assert_eq!(g.n, 4 * HIDDEN);
    }

    #[test]
    fn mac_count() {
        // 2 layers x 128 steps x 8 x (k + 512) x 2048 MACs.
        let w = lstm();
        let expected: u64 = STEPS * BATCH * 4 * HIDDEN * (INPUT + HIDDEN)
            + STEPS * BATCH * 4 * HIDDEN * (HIDDEN + HIDDEN)
            + BATCH * HIDDEN * 1000;
        assert_eq!(w.total_macs(), expected);
    }

    #[test]
    fn batch_fits_3d_m_axis() {
        assert_eq!(BATCH % 8, 0);
    }
}
