//! ResNet-50 (224x224, batch 1): the canonical CNN of Fig. 1c / Fig. 6.

use crate::workloads::layer::{Layer, LayerKind, Workload};

fn conv(name: &str, h: u64, w: u64, cin: u64, cout: u64, k: u64, s: u64) -> Layer {
    Layer::new(
        name,
        LayerKind::Conv2d {
            h,
            w,
            cin,
            cout,
            kh: k,
            kw: k,
            stride: s,
        },
    )
}

/// One bottleneck block: 1x1 reduce, 3x3, 1x1 expand (+ projection on the
/// first block of a stage).
fn bottleneck(
    layers: &mut Vec<Layer>,
    stage: &str,
    idx: u64,
    h: u64,
    cin: u64,
    cmid: u64,
    cout: u64,
    stride: u64,
) {
    let name = |p: &str| format!("{stage}_{idx}_{p}");
    layers.push(conv(&name("1x1a"), h, h, cin, cmid, 1, 1));
    let h2 = h.div_ceil(stride);
    layers.push(conv(&name("3x3"), h, h, cmid, cmid, 3, stride));
    layers.push(conv(&name("1x1b"), h2, h2, cmid, cout, 1, 1));
    if idx == 0 {
        layers.push(conv(&name("proj"), h, h, cin, cout, 1, stride));
    }
}

pub fn resnet50() -> Workload {
    let mut layers = Vec::new();
    layers.push(conv("conv1", 224, 224, 3, 64, 7, 2));
    layers.push(Layer::new(
        "pool1",
        LayerKind::Pool {
            h: 112,
            w: 112,
            c: 64,
            window: 3,
            stride: 2,
        },
    ));
    // (stage, blocks, h_in, cin, cmid, cout, stride of first block)
    let stages: [(&str, u64, u64, u64, u64, u64, u64); 4] = [
        ("conv2", 3, 56, 64, 64, 256, 1),
        ("conv3", 4, 56, 256, 128, 512, 2),
        ("conv4", 6, 28, 512, 256, 1024, 2),
        ("conv5", 3, 14, 1024, 512, 2048, 2),
    ];
    for (name, blocks, h_in, cin, cmid, cout, s0) in stages {
        let mut h = h_in;
        let mut ci = cin;
        for b in 0..blocks {
            let s = if b == 0 { s0 } else { 1 };
            bottleneck(&mut layers, name, b, h, ci, cmid, cout, s);
            h = h.div_ceil(s);
            ci = cout;
        }
    }
    layers.push(Layer::new("fc", LayerKind::Gemm { m: 1, k: 2048, n: 1000 }));
    Workload::new("ResNet50", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_count_is_about_4_gflops() {
        // Published ResNet-50: ~4.1 GMACs (2 ops each).
        let w = resnet50();
        let g = w.total_macs() as f64 / 1e9;
        assert!(
            (3.4..4.6).contains(&g),
            "expected ~3.8-4.1 GMACs, got {g:.2}"
        );
    }

    #[test]
    fn layer_count_is_resnet50_shaped() {
        let w = resnet50();
        // 1 stem + pool + 16 bottlenecks x 3 conv + 4 projections + fc.
        assert_eq!(
            w.layers.len(),
            1 + 1 + 16 * 3 + 4 + 1,
            "layer inventory changed"
        );
    }

    #[test]
    fn spatial_dims_chain() {
        // Last stage convs must be at 7x7 resolution: their gemm M = 49.
        let w = resnet50();
        let last_conv = w
            .layers
            .iter()
            .rev()
            .find(|l| l.name.starts_with("conv5"))
            .unwrap();
        assert_eq!(last_conv.gemms()[0].m, 49);
    }
}
