//! Implicit im2col: the dimension arithmetic that lowers Conv2D onto the
//! GEMM core (Sec. II-B; [21]).
//!
//! The 6-D input-streamer AGU walks the patch matrix *in place* — no
//! buffer is materialized; functionally the conv becomes a GEMM with
//! M = Ho x Wo, K = Kh x Kw x Cin, N = Cout. SAME padding, as the
//! evaluated CNNs use.

use crate::sim::agu::{AffineAgu, LoopDim};
use crate::workloads::layer::GemmOp;

/// Output spatial dims for SAME padding.
pub fn out_dims(h: u64, w: u64, _kh: u64, _kw: u64, stride: u64) -> (u64, u64) {
    (h.div_ceil(stride), w.div_ceil(stride))
}

/// The GEMM a convolution becomes.
pub fn conv_to_gemm(h: u64, w: u64, cin: u64, cout: u64, kh: u64, kw: u64, stride: u64) -> GemmOp {
    let (oh, ow) = out_dims(h, w, kh, kw, stride);
    GemmOp::new(oh * ow, kh * kw * cin, cout)
}

/// Build the 6-D AGU program that implements the implicit im2col walk of
/// a C/8HWC8-laid-out feature map (one 64-bit word = 8 channels of one
/// pixel). Loop order (innermost first):
///   c8 group, kernel-x, kernel-y, out-x, out-y, channel-group-row
/// which is the order the GEMM core consumes K for each output row.
pub fn im2col_agu(
    base_word: u64,
    h: u64,
    w: u64,
    cin: u64,
    kh: u64,
    kw: u64,
    stride: u64,
) -> AffineAgu {
    let c8 = cin.div_ceil(8);
    let (oh, ow) = out_dims(h, w, kh, kw, stride);
    // Word layout of C/8HWC8: word(g, y, x) = g*h*w + y*w + x.
    AffineAgu::new(
        base_word,
        vec![
            LoopDim {
                bound: c8,
                stride: (h * w) as i64,
            }, // channel group (innermost K)
            LoopDim { bound: kw, stride: 1 }, // kernel x
            LoopDim {
                bound: kh,
                stride: w as i64,
            }, // kernel y
            LoopDim {
                bound: ow,
                stride: stride as i64,
            }, // output x
            LoopDim {
                bound: oh,
                stride: (stride * w) as i64,
            }, // output y
            LoopDim { bound: 1, stride: 0 }, // batch (1)
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_padding_dims() {
        assert_eq!(out_dims(56, 56, 3, 3, 1), (56, 56));
        assert_eq!(out_dims(56, 56, 3, 3, 2), (28, 28));
        assert_eq!(out_dims(7, 7, 3, 3, 2), (4, 4));
        assert_eq!(out_dims(224, 224, 7, 7, 2), (112, 112));
    }

    #[test]
    fn resnet_conv1_gemm() {
        // 224x224x3 7x7/2 -> 64: M = 112*112, K = 147, N = 64.
        let g = conv_to_gemm(224, 224, 3, 64, 7, 7, 2);
        assert_eq!(g.m, 112 * 112);
        assert_eq!(g.k, 147);
        assert_eq!(g.n, 64);
    }

    #[test]
    fn pointwise_conv_is_plain_gemm() {
        let g = conv_to_gemm(28, 28, 144, 32, 1, 1, 1);
        assert_eq!((g.m, g.k, g.n), (784, 144, 32));
    }

    #[test]
    fn agu_walks_whole_patch_matrix() {
        let agu = im2col_agu(0, 8, 8, 16, 3, 3, 1);
        // Total addresses = oh*ow * kh*kw * c8 = 64 * 9 * 2.
        assert_eq!(agu.total(), 64 * 9 * 2);
    }

    #[test]
    fn agu_first_patch_is_kernel_window() {
        let mut agu = im2col_agu(0, 8, 8, 8, 3, 3, 1);
        let mut first = Vec::new();
        for _ in 0..9 {
            first.push(agu.next_addr().unwrap());
        }
        // c8 = 1, so the 9 kernel taps of output (0,0):
        assert_eq!(first, vec![0, 1, 2, 8, 9, 10, 16, 17, 18]);
    }

    #[test]
    fn agu_fits_input_streamer_depth() {
        use crate::arch::INPUT_AGU_DIMS;
        // The im2col program must fit the chip's 6-D AGU.
        let agu = im2col_agu(0, 56, 56, 64, 3, 3, 1);
        let _ = agu; // construction asserts bounds > 0
        assert!(6 <= INPUT_AGU_DIMS);
    }
}
