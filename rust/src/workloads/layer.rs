//! Layer abstraction: every network in the Fig. 6 evaluation is a list
//! of layers, and every layer lowers to GEMM operations on the core
//! (Conv2D via implicit im2col, Sec. II-B / [21]).

use crate::workloads::im2col;

/// A single GEMM as dispatched to the core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmOp {
    pub m: u64,
    pub k: u64,
    pub n: u64,
    /// How many times this exact GEMM executes (head count, time steps,
    /// depthwise channels, decode batch...).
    pub repeat: u64,
    /// How many consecutive repeats share the same weight operand
    /// (recurrent time steps re-use weights; attention heads do not).
    /// PDMA exploits this by keeping resident weights on chip.
    pub weight_reuse: u64,
    /// Input operand arrives in a raw (non-reshuffled) layout and the
    /// reshuffler must run first (or the streamers eat bank conflicts).
    pub raw_input: bool,
}

impl GemmOp {
    pub fn new(m: u64, k: u64, n: u64) -> Self {
        GemmOp {
            m,
            k,
            n,
            repeat: 1,
            weight_reuse: 1,
            raw_input: false,
        }
    }

    pub fn repeated(mut self, r: u64) -> Self {
        self.repeat = r;
        self
    }

    pub fn macs(&self) -> u64 {
        self.m * self.k * self.n * self.repeat
    }
}

/// The operation zoo of Table I ("GEMM/CONV2D/MHA" + auxiliaries).
#[derive(Clone, Debug, PartialEq)]
pub enum LayerKind {
    /// Plain GEMM (fully-connected, attention projection, MLP...).
    Gemm { m: u64, k: u64, n: u64 },
    /// Standard convolution, NHWC x HWIO, batch 1.
    Conv2d {
        h: u64,
        w: u64,
        cin: u64,
        cout: u64,
        kh: u64,
        kw: u64,
        stride: u64,
    },
    /// Depthwise convolution: one tiny GEMM per channel.
    DepthwiseConv {
        h: u64,
        w: u64,
        c: u64,
        kh: u64,
        kw: u64,
        stride: u64,
    },
    /// Batched matmul (attention score / context): `batch` heads.
    BatchedMatmul { batch: u64, m: u64, k: u64, n: u64 },
    /// A fused bundle of GEMMs dispatched back-to-back as ONE layer
    /// (LSTM gate bundle, attention QKV): each entry is `(m, k, n)`.
    /// The coordinator tiles — and double-buffers — every GEMM
    /// independently, so one layer can mix ping-pong grants.
    Fused(Vec<(u64, u64, u64)>),
    /// Max pooling (runs on the maxpool unit, not the GEMM core).
    Pool {
        h: u64,
        w: u64,
        c: u64,
        window: u64,
        stride: u64,
    },
}

/// One network layer with a repeat count (e.g. identical transformer
/// blocks or LSTM time steps).
#[derive(Clone, Debug, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub repeat: u64,
}

impl Layer {
    pub fn new(name: impl Into<String>, kind: LayerKind) -> Self {
        Layer {
            name: name.into(),
            kind,
            repeat: 1,
        }
    }

    pub fn repeated(mut self, r: u64) -> Self {
        self.repeat = r;
        self
    }

    /// Lower to the GEMMs the coordinator dispatches.
    pub fn gemms(&self) -> Vec<GemmOp> {
        let ops = match self.kind {
            LayerKind::Gemm { m, k, n } => vec![GemmOp::new(m, k, n)],
            LayerKind::Conv2d {
                h,
                w,
                cin,
                cout,
                kh,
                kw,
                stride,
            } => {
                let g = im2col::conv_to_gemm(h, w, cin, cout, kh, kw, stride);
                // Feature maps arrive HWC from the previous layer or DRAM
                // and go through the reshuffler (C/8HWC8) — represented
                // by raw_input=false here with the reshuffle charged by
                // the coordinator; a 1x1 conv needs no patch gather.
                vec![g]
            }
            LayerKind::DepthwiseConv {
                h,
                w,
                c,
                kh,
                kw,
                stride,
            } => {
                let (oh, ow) = im2col::out_dims(h, w, kh, kw, stride);
                vec![GemmOp::new(oh * ow, kh * kw, 1).repeated(c)]
            }
            LayerKind::BatchedMatmul { batch, m, k, n } => {
                vec![GemmOp::new(m, k, n).repeated(batch)]
            }
            LayerKind::Fused(ref gemms) => {
                gemms.iter().map(|&(m, k, n)| GemmOp::new(m, k, n)).collect()
            }
            LayerKind::Pool { .. } => vec![],
        };
        // Layer-level repeats run the same weights again (recurrent
        // steps); kind-level repeats (heads, channels) use fresh data.
        ops.into_iter()
            .map(|mut g| {
                g.repeat *= self.repeat;
                g.weight_reuse *= self.repeat;
                g
            })
            .collect()
    }

    pub fn macs(&self) -> u64 {
        self.gemms().iter().map(|g| g.macs()).sum()
    }
}

/// A full network: the unit of Fig. 6's bars.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Workload {
    pub fn new(name: impl Into<String>, layers: Vec<Layer>) -> Self {
        Workload {
            name: name.into(),
            layers,
        }
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn gemm_count(&self) -> u64 {
        self.layers
            .iter()
            .flat_map(|l| l.gemms())
            .map(|g| g.repeat)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_lowers_to_one_gemm() {
        let l = Layer::new(
            "conv3x3",
            LayerKind::Conv2d {
                h: 56,
                w: 56,
                cin: 64,
                cout: 64,
                kh: 3,
                kw: 3,
                stride: 1,
            },
        );
        let g = l.gemms();
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].m, 56 * 56);
        assert_eq!(g[0].k, 9 * 64);
        assert_eq!(g[0].n, 64);
    }

    #[test]
    fn depthwise_is_per_channel_gemv() {
        let l = Layer::new(
            "dw",
            LayerKind::DepthwiseConv {
                h: 28,
                w: 28,
                c: 144,
                kh: 3,
                kw: 3,
                stride: 1,
            },
        );
        let g = l.gemms();
        assert_eq!(g[0].n, 1);
        assert_eq!(g[0].k, 9);
        assert_eq!(g[0].repeat, 144);
    }

    #[test]
    fn repeat_multiplies_macs() {
        let base = Layer::new("fc", LayerKind::Gemm { m: 8, k: 512, n: 2048 });
        let rep = base.clone().repeated(128);
        assert_eq!(rep.macs(), 128 * base.macs());
    }

    #[test]
    fn fused_bundle_lowers_to_multiple_gemms() {
        let l = Layer::new("qkv", LayerKind::Fused(vec![(512, 768, 768), (64, 64, 64)]));
        let gs = l.gemms();
        assert_eq!(gs.len(), 2);
        assert_eq!((gs[0].m, gs[0].k, gs[0].n), (512, 768, 768));
        assert_eq!((gs[1].m, gs[1].k, gs[1].n), (64, 64, 64));
        assert_eq!(l.macs(), 512 * 768 * 768 + 64 * 64 * 64);
        // Layer-level repeats apply to every GEMM of the bundle.
        let r = l.repeated(3);
        assert!(r.gemms().iter().all(|g| g.repeat == 3 && g.weight_reuse == 3));
    }

    #[test]
    fn pool_contributes_no_gemms() {
        let l = Layer::new(
            "pool",
            LayerKind::Pool {
                h: 112,
                w: 112,
                c: 64,
                window: 3,
                stride: 2,
            },
        );
        assert!(l.gemms().is_empty());
        assert_eq!(l.macs(), 0);
    }
}
