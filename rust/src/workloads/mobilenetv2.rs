//! MobileNetV2 (224x224, batch 1): the skinny-channel CNN whose
//! pointwise/depthwise mix stresses spatial utilization (Fig. 6 workload
//! 1 — depthwise layers are the worst case for wide arrays).

use crate::workloads::layer::{Layer, LayerKind, Workload};

fn conv(name: String, h: u64, cin: u64, cout: u64, k: u64, s: u64) -> Layer {
    Layer::new(
        name,
        LayerKind::Conv2d {
            h,
            w: h,
            cin,
            cout,
            kh: k,
            kw: k,
            stride: s,
        },
    )
}

fn dw(name: String, h: u64, c: u64, s: u64) -> Layer {
    Layer::new(
        name,
        LayerKind::DepthwiseConv {
            h,
            w: h,
            c,
            kh: 3,
            kw: 3,
            stride: s,
        },
    )
}

/// Inverted residual: 1x1 expand (t*cin), 3x3 depthwise, 1x1 project.
fn inverted_residual(
    layers: &mut Vec<Layer>,
    id: String,
    h: u64,
    cin: u64,
    cout: u64,
    t: u64,
    s: u64,
) -> u64 {
    let cexp = cin * t;
    if t != 1 {
        layers.push(conv(format!("{id}_expand"), h, cin, cexp, 1, 1));
    }
    layers.push(dw(format!("{id}_dw"), h, cexp, s));
    let h2 = h.div_ceil(s);
    layers.push(conv(format!("{id}_project"), h2, cexp, cout, 1, 1));
    h2
}

pub fn mobilenetv2() -> Workload {
    let mut layers = Vec::new();
    layers.push(conv("conv0".into(), 224, 3, 32, 3, 2));
    // (expansion t, cout, repeats n, stride s) — the paper's Table 2.
    let cfg: [(u64, u64, u64, u64); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut h = 112;
    let mut cin = 32;
    for (bi, (t, c, n, s)) in cfg.iter().enumerate() {
        for r in 0..*n {
            let stride = if r == 0 { *s } else { 1 };
            h = inverted_residual(
                &mut layers,
                format!("block{bi}_{r}"),
                h,
                cin,
                *c,
                *t,
                stride,
            );
            cin = *c;
        }
    }
    layers.push(conv("conv_last".into(), 7, 320, 1280, 1, 1));
    layers.push(Layer::new("fc", LayerKind::Gemm { m: 1, k: 1280, n: 1000 }));
    Workload::new("MobileNetV2", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_count_is_about_300_mflops() {
        // Published MobileNetV2: ~300 MMACs.
        let w = mobilenetv2();
        let m = w.total_macs() as f64 / 1e6;
        assert!((250.0..420.0).contains(&m), "expected ~300 MMACs, got {m:.0}");
    }

    #[test]
    fn has_depthwise_gemvs() {
        let w = mobilenetv2();
        let dw_gemms: Vec<_> = w
            .layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::DepthwiseConv { .. }))
            .collect();
        assert_eq!(dw_gemms.len(), 17, "one depthwise per inverted residual");
        for l in dw_gemms {
            assert_eq!(l.gemms()[0].n, 1);
        }
    }

    #[test]
    fn final_resolution_is_7x7() {
        let w = mobilenetv2();
        let last = w
            .layers
            .iter()
            .find(|l| l.name == "conv_last")
            .unwrap();
        assert_eq!(last.gemms()[0].m, 49);
    }
}
