//! PointNeXt-S (point-cloud classification, 1024 points): per-point MLP
//! stacks inside set-abstraction blocks — mid-sized GEMMs with odd
//! channel counts (Fig. 6 workload 4).

use crate::workloads::layer::{Layer, LayerKind, Workload};

fn mlp(name: String, points: u64, cin: u64, cout: u64) -> Layer {
    // A shared per-point MLP is exactly a GEMM over the point dimension.
    Layer::new(name, LayerKind::Gemm { m: points, k: cin, n: cout })
}

/// PointNeXt-S: stem MLP + 4 set-abstraction stages, each halving the
/// point count and widening channels; grouped local aggregation adds a
/// neighbourhood factor to K (k-NN = 32, xyz concat = +3).
pub fn pointnext_s() -> Workload {
    let mut layers = Vec::new();
    let knn = 32;
    layers.push(mlp("stem".into(), 1024, 3, 32));
    // (points after sampling, cin, cout)
    let stages: [(u64, u64, u64); 4] = [
        (512, 32, 64),
        (256, 64, 128),
        (128, 128, 256),
        (64, 256, 512),
    ];
    for (i, (pts, cin, cout)) in stages.iter().enumerate() {
        // Grouped MLP over k-NN neighbourhoods: M = pts * knn rows.
        layers.push(mlp(
            format!("sa{i}_group"),
            pts * knn,
            cin + 3,
            *cout,
        ));
        // Post-aggregation pointwise MLP.
        layers.push(mlp(format!("sa{i}_point"), *pts, *cout, *cout));
    }
    // Classification head.
    layers.push(mlp("head0".into(), 1, 512, 256));
    layers.push(mlp("head1".into(), 1, 256, 40));
    Workload::new("PointNeXt", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_counts_halve() {
        let w = pointnext_s();
        let g0 = w.layers[1].gemms()[0]; // sa0_group
        let g1 = w.layers[3].gemms()[0]; // sa1_group
        assert_eq!(g0.m, 512 * 32);
        assert_eq!(g1.m, 256 * 32);
    }

    #[test]
    fn k_includes_xyz_concat() {
        let w = pointnext_s();
        let g = w.layers[1].gemms()[0];
        assert_eq!(g.k, 35); // 32 + 3: deliberately 8-misaligned
    }

    #[test]
    fn macs_in_expected_band() {
        // PointNeXt-S is ~1.6 GMACs class; our reduced trace sits lower
        // but must stay within an order of magnitude.
        let m = pointnext_s().total_macs() as f64 / 1e6;
        assert!((100.0..2000.0).contains(&m), "got {m:.0} MMACs");
    }
}
