//! Transformer workloads of Fig. 6: ViT-B/16, BERT-Base (T=512) and
//! LLaMA3.2-3B prefill (T=256) / decode.
//!
//! Decode note (DESIGN.md substitution log): the paper measures the
//! decode stage where "a lot of GEMV operations occur" at 69.71% spatial
//! utilization. A strictly single-stream decode is pure GEMV (M=1) and
//! would sit at 12.5% on *any* 512-MAC array; the reported number implies
//! a small serving batch. We model decode as a 6-way batched step (a
//! realistic edge-serving batch), which lands the projections at M=6
//! (75% fill on the 8-wide M axis) plus per-sequence M=1 attention — the
//! combination reproduces the ~0.7 utilization and the ~2x gap to the 2D
//! baseline.

use crate::workloads::layer::{Layer, LayerKind, Workload};

fn gemm(name: impl Into<String>, m: u64, k: u64, n: u64) -> Layer {
    Layer::new(name, LayerKind::Gemm { m, k, n })
}

fn bmm(name: impl Into<String>, batch: u64, m: u64, k: u64, n: u64) -> Layer {
    Layer::new(name, LayerKind::BatchedMatmul { batch, m, k, n })
}

/// One encoder block: fused QKV, per-head attention, projection, MLP.
fn encoder_block(
    layers: &mut Vec<Layer>,
    prefix: &str,
    t: u64,
    d: u64,
    heads: u64,
    d_ff: u64,
    repeat: u64,
) {
    let dh = d / heads;
    layers.push(gemm(format!("{prefix}_qkv"), t, d, 3 * d).repeated(repeat));
    layers.push(bmm(format!("{prefix}_scores"), heads, t, dh, t).repeated(repeat));
    layers.push(bmm(format!("{prefix}_context"), heads, t, t, dh).repeated(repeat));
    layers.push(gemm(format!("{prefix}_proj"), t, d, d).repeated(repeat));
    layers.push(gemm(format!("{prefix}_mlp_up"), t, d, d_ff).repeated(repeat));
    layers.push(gemm(format!("{prefix}_mlp_down"), t, d_ff, d).repeated(repeat));
}

/// ViT-B/16 at 224x224: 196 patch tokens + CLS = 197; 12 blocks, d=768.
pub fn vit_b() -> Workload {
    let mut layers = Vec::new();
    // Patch embedding: a 16x16/16 conv == GEMM (196, 768, 768).
    layers.push(gemm("patch_embed", 196, 16 * 16 * 3, 768));
    encoder_block(&mut layers, "enc", 197, 768, 12, 3072, 12);
    layers.push(gemm("head", 1, 768, 1000));
    Workload::new("ViT-B", layers)
}

/// BERT-Base, input token size 512 (Fig. 6 workload 6).
pub fn bert_base(t: u64) -> Workload {
    let mut layers = Vec::new();
    encoder_block(&mut layers, "enc", t, 768, 12, 3072, 12);
    Workload::new("BERT-Base", layers)
}

/// LLaMA3.2-3B geometry: 28 layers, d=3072, 24 Q heads / 8 KV heads
/// (GQA), head dim 128, FFN 8192 (SwiGLU: gate+up+down).
const LLAMA_LAYERS: u64 = 28;
const LLAMA_D: u64 = 3072;
const LLAMA_QH: u64 = 24;
const LLAMA_KVH: u64 = 8;
const LLAMA_DH: u64 = 128;
const LLAMA_FF: u64 = 8192;

/// Prefill stage, input token size 256 (Fig. 6 workload 7).
pub fn llama_prefill(t: u64) -> Workload {
    let mut layers = Vec::new();
    let kv = LLAMA_KVH * LLAMA_DH;
    layers.push(gemm("q_proj", t, LLAMA_D, LLAMA_QH * LLAMA_DH).repeated(LLAMA_LAYERS));
    layers.push(gemm("kv_proj", t, LLAMA_D, 2 * kv).repeated(LLAMA_LAYERS));
    layers.push(bmm("scores", LLAMA_QH, t, LLAMA_DH, t).repeated(LLAMA_LAYERS));
    layers.push(bmm("context", LLAMA_QH, t, t, LLAMA_DH).repeated(LLAMA_LAYERS));
    layers.push(gemm("o_proj", t, LLAMA_QH * LLAMA_DH, LLAMA_D).repeated(LLAMA_LAYERS));
    layers.push(gemm("gate_up", t, LLAMA_D, 2 * LLAMA_FF).repeated(LLAMA_LAYERS));
    layers.push(gemm("ffn_down", t, LLAMA_FF, LLAMA_D).repeated(LLAMA_LAYERS));
    Workload::new("LLaMA3.2-3B-prefill", layers)
}

/// Decode stage with context length `t` and serving batch `batch`
/// (see module doc): one generated token per sequence.
pub fn llama_decode(t: u64, batch: u64) -> Workload {
    let mut layers = Vec::new();
    let kv = LLAMA_KVH * LLAMA_DH;
    let b = batch;
    layers.push(gemm("q_proj", b, LLAMA_D, LLAMA_QH * LLAMA_DH).repeated(LLAMA_LAYERS));
    layers.push(gemm("kv_proj", b, LLAMA_D, 2 * kv).repeated(LLAMA_LAYERS));
    // Attention against the KV cache is strictly per-sequence GEMV:
    // q (1 x dh) x K^T (dh x t), then scores (1 x t) x V (t x dh).
    layers.push(bmm("scores", b * LLAMA_QH, 1, LLAMA_DH, t).repeated(LLAMA_LAYERS));
    layers.push(bmm("context", b * LLAMA_QH, 1, t, LLAMA_DH).repeated(LLAMA_LAYERS));
    layers.push(gemm("o_proj", b, LLAMA_QH * LLAMA_DH, LLAMA_D).repeated(LLAMA_LAYERS));
    layers.push(gemm("gate_up", b, LLAMA_D, 2 * LLAMA_FF).repeated(LLAMA_LAYERS));
    layers.push(gemm("ffn_down", b, LLAMA_FF, LLAMA_D).repeated(LLAMA_LAYERS));
    Workload::new("LLaMA3.2-3B-decode", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vit_macs_are_about_17g() {
        // Published ViT-B/16: ~17.6 GMACs at 224x224.
        let g = vit_b().total_macs() as f64 / 1e9;
        assert!((15.0..20.0).contains(&g), "got {g:.1} GMACs");
    }

    #[test]
    fn bert_macs_scale_with_tokens() {
        let m512 = bert_base(512).total_macs();
        let m64 = bert_base(64).total_macs();
        assert!(m512 > m64 * 7, "quadratic attention term should show");
    }

    #[test]
    fn llama_prefill_macs() {
        // 3B params, 256 tokens: >= 2 * 256 * 3e9 MACs on projections
        // alone is the wrong metric (GQA shrinks KV); sanity-band check.
        let g = llama_prefill(256).total_macs() as f64 / 1e9;
        assert!((500.0..900.0).contains(&g), "got {g:.0} GMACs");
    }

    #[test]
    fn decode_is_gemv_heavy() {
        let w = llama_decode(256, 6);
        let attn_macs: u64 = w
            .layers
            .iter()
            .filter(|l| l.name.contains("scores") || l.name.contains("context"))
            .map(|l| l.macs())
            .sum();
        let m1_ops: u64 = w
            .layers
            .iter()
            .flat_map(|l| l.gemms())
            .filter(|g| g.m == 1)
            .map(|g| g.repeat)
            .sum();
        assert!(attn_macs > 0);
        // 2 GEMVs per head per layer x 6 sequences x 24 heads x 28 layers.
        assert_eq!(m1_ops, 2 * 6 * 24 * 28);
    }

    #[test]
    fn decode_projections_are_batch_6() {
        let w = llama_decode(256, 6);
        let q = w.layers.iter().find(|l| l.name == "q_proj").unwrap();
        assert_eq!(q.gemms()[0].m, 6);
    }
}
