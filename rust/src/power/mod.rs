//! Energy, area and DVFS models calibrated to the published silicon
//! numbers (Fig. 5, Fig. 7, Table I).

pub mod area;
pub mod dvfs;
pub mod energy;

pub use area::AreaModel;
pub use energy::{
    energy_breakdown, power_mw, tops_per_watt, Activity, EnergyBreakdown, EnergyParams,
};
