//! Area model: the 0.654 mm^2 die budget (Fig. 5) split across modules,
//! with the two time-multiplexing scaling laws of Sec. II-D:
//!   * SIMD area(lanes): 64 lanes cost 4.92x the 8-lane unit;
//!   * crossbar area ~ ports^1.3: 32 ports cost 1.46x the 24-port
//!     time-multiplexed design.

use crate::config::ChipConfig;
use crate::sim::crossbar::crossbar_ports;

/// Fixed module areas (mm^2) for the fabricated configuration
/// (8-lane SIMD, 24-port crossbar). Sums to the published core area.
#[derive(Clone, Copy, Debug)]
pub struct AreaModel {
    pub gemm_array: f64,
    pub shared_mem: f64,
    pub streamers: f64,
    pub reshuffler: f64,
    pub maxpool: f64,
    pub snitch: f64,
    pub dma: f64,
    /// SIMD per-lane slope / fixed offset: area(n) = a*n + b with
    /// area(64) = 4.92 * area(8).
    pub simd_lane_mm2: f64,
    pub simd_fixed_mm2: f64,
    /// Crossbar area at the 24-port reference and its port exponent.
    pub xbar_ref_mm2: f64,
    pub xbar_exp: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        // SIMD: solve a*64+b = 4.92*(a*8+b)  =>  b = (64-8*4.92)a/3.92
        //   = 6.2857a; pick area(8) = 0.0080 mm^2 => a = 0.000560.
        let a = 0.008 / (8.0 + 6.2857);
        AreaModel {
            gemm_array: 0.300,
            shared_mem: 0.200,
            streamers: 0.050,
            reshuffler: 0.010,
            maxpool: 0.004,
            snitch: 0.030,
            dma: 0.015,
            simd_lane_mm2: a,
            simd_fixed_mm2: 6.2857 * a,
            xbar_ref_mm2: 0.037,
            xbar_exp: 1.3,
        }
    }
}

impl AreaModel {
    pub fn simd_area(&self, lanes: usize) -> f64 {
        self.simd_lane_mm2 * lanes as f64 + self.simd_fixed_mm2
    }

    pub fn crossbar_area(&self, tmux_psum_output: bool) -> f64 {
        let p = crossbar_ports(tmux_psum_output) as f64;
        let pref = crossbar_ports(true) as f64;
        self.xbar_ref_mm2 * (p / pref).powf(self.xbar_exp)
    }

    /// Total core area for a configuration.
    pub fn total(&self, simd_lanes: usize, tmux_psum_output: bool) -> f64 {
        self.gemm_array
            + self.shared_mem
            + self.streamers
            + self.reshuffler
            + self.maxpool
            + self.snitch
            + self.dma
            + self.simd_area(simd_lanes)
            + self.crossbar_area(tmux_psum_output)
    }

    /// Area efficiency (TOPS/mm^2) at peak throughput `tops`.
    pub fn area_efficiency(&self, tops: f64, simd_lanes: usize, tmux: bool) -> f64 {
        tops / self.total(simd_lanes, tmux)
    }

    /// Total core area for an arbitrary [`ChipConfig`] — the search's
    /// area axis (DESIGN.md §15). Extends the Sec. II-D scaling laws to
    /// the searched knobs; every scale factor is exactly 1.0 at the
    /// fabricated design point, so
    /// `config_area(&ChipConfig::voltra()) == total(8, true)` bit-for-bit.
    ///
    /// * MAC array — linear in MAC count (all shipped presets keep the
    ///   512-MAC budget, so this is 1.0 across Fig. 6);
    /// * shared memory — capacity-dominated SRAM macros plus per-bank
    ///   periphery (sense amps, arbitration): 15% of the module is
    ///   bank-proportional at the shipped 32 banks;
    /// * streamers — control plus the FIFO register files: 20% of the
    ///   module is depth-proportional at the shipped depth 8;
    /// * crossbar — the ports^1.3 law times a sqrt bank-radix term
    ///   (more banks widen the memory-side fan-out);
    /// * SIMD / fixed blocks — the existing laws, unchanged.
    pub fn config_area(&self, cfg: &ChipConfig) -> f64 {
        let shipped_macs = crate::arch::MACS as f64;
        let array = self.gemm_array * cfg.array.macs() as f64 / shipped_macs;
        let mem = self.shared_mem
            * (0.85 + 0.15 * cfg.num_banks as f64 / crate::arch::NUM_BANKS as f64);
        let streamers = self.streamers
            * (0.80 + 0.20 * cfg.stream_fifo_depth as f64 / crate::arch::STREAM_FIFO_DEPTH as f64);
        let xbar = self.crossbar_area(cfg.tmux_psum_output)
            * (cfg.num_banks as f64 / crate::arch::NUM_BANKS as f64).sqrt();
        array
            + mem
            + streamers
            + xbar
            + self.reshuffler
            + self.maxpool
            + self.snitch
            + self.dma
            + self.simd_area(cfg.simd_lanes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{CORE_AREA_MM2, PEAK_TOPS};

    #[test]
    fn fabricated_config_matches_die_area() {
        let a = AreaModel::default();
        let total = a.total(8, true);
        assert!(
            (total - CORE_AREA_MM2).abs() < 0.01,
            "module split must sum to 0.654 mm^2, got {total:.3}"
        );
    }

    #[test]
    fn simd_scaling_is_4_92x() {
        let a = AreaModel::default();
        let ratio = a.simd_area(64) / a.simd_area(8);
        assert!((ratio - 4.92).abs() < 0.01, "got {ratio:.3}");
    }

    #[test]
    fn crossbar_scaling_is_1_46x() {
        let a = AreaModel::default();
        let ratio = a.crossbar_area(false) / a.crossbar_area(true);
        assert!((ratio - 1.46).abs() < 0.02, "got {ratio:.3}");
    }

    #[test]
    fn area_efficiency_matches_table1() {
        let a = AreaModel::default();
        let ae = a.area_efficiency(PEAK_TOPS, 8, true);
        assert!((ae - 1.25).abs() < 0.03, "got {ae:.3} TOPS/mm^2");
    }

    #[test]
    fn ablations_grow_the_die() {
        let a = AreaModel::default();
        assert!(a.total(64, true) > a.total(8, true));
        assert!(a.total(8, false) > a.total(8, true));
    }

    #[test]
    fn config_area_is_exact_at_the_shipped_point() {
        // Every search-axis scale factor must be exactly 1.0 at the
        // fabricated values, so the search scores the shipped config
        // with the same die area the spec sheet prints.
        let a = AreaModel::default();
        let cfg = crate::config::ChipConfig::voltra();
        assert_eq!(a.config_area(&cfg), a.total(8, true));
    }

    #[test]
    fn config_area_responds_to_every_search_axis() {
        let a = AreaModel::default();
        let base = a.config_area(&crate::config::ChipConfig::voltra());
        let mut banks = crate::config::ChipConfig::voltra();
        banks.num_banks = 64;
        assert!(a.config_area(&banks) > base, "more banks cost area");
        let mut fifo = crate::config::ChipConfig::voltra();
        fifo.stream_fifo_depth = 16;
        assert!(a.config_area(&fifo) > base, "deeper FIFOs cost area");
        let mut fewer = crate::config::ChipConfig::voltra();
        fewer.num_banks = 16;
        fewer.stream_fifo_depth = 4;
        assert!(a.config_area(&fewer) < base, "trimmed fabric saves area");
        // Memory-org splits and DVFS points are area-neutral.
        let sep = crate::config::ChipConfig::separated_memory();
        assert_eq!(a.config_area(&sep), base);
    }
}
