//! DVFS / shmoo model (Fig. 7a): which (voltage, frequency) points the
//! die passes at, and the voltage curve of the maximum frequency.
//!
//! Published anchors: 0.6 V / 300 MHz (min) and 1.0 V / 800 MHz (max).
//! Between them we use the near-linear fmax(V) a 16 nm FinFET logic
//! corner shows over this range.

use crate::config::OperatingPoint;

/// Voltage anchors of the measured curve (V, fmax MHz).
pub const FMAX_TABLE: [(f64, f64); 9] = [
    (0.60, 300.0),
    (0.65, 380.0),
    (0.70, 450.0),
    (0.75, 525.0),
    (0.80, 600.0),
    (0.85, 660.0),
    (0.90, 710.0),
    (0.95, 760.0),
    (1.00, 800.0),
];

/// Maximum passing frequency at `v` volts (linear interpolation).
pub fn fmax_mhz(v: f64) -> f64 {
    let t = &FMAX_TABLE;
    if v <= t[0].0 {
        return if v < t[0].0 - 1e-9 { 0.0 } else { t[0].1 };
    }
    if v >= t[t.len() - 1].0 {
        return t[t.len() - 1].1;
    }
    for w in t.windows(2) {
        let (v0, f0) = w[0];
        let (v1, f1) = w[1];
        if v <= v1 {
            return f0 + (f1 - f0) * (v - v0) / (v1 - v0);
        }
    }
    unreachable!()
}

/// Does the die pass at this operating point? (the shmoo's green cells)
pub fn passes(op: OperatingPoint) -> bool {
    op.voltage >= 0.6 - 1e-9
        && op.voltage <= 1.0 + 1e-9
        && op.freq_mhz <= fmax_mhz(op.voltage) + 1e-9
}

/// The full shmoo grid (Fig. 7a): voltages x frequencies -> pass/fail.
pub fn shmoo_grid() -> Vec<(f64, f64, bool)> {
    let mut grid = Vec::new();
    let mut v: f64 = 0.55;
    while v <= 1.001 {
        let mut f = 250.0;
        while f <= 850.0 {
            grid.push((
                (v * 100.0).round() / 100.0,
                f,
                passes(OperatingPoint {
                    voltage: (v * 100.0).round() / 100.0,
                    freq_mhz: f,
                }),
            ));
            f += 50.0;
        }
        v += 0.05;
    }
    grid
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_match_fig5() {
        assert_eq!(fmax_mhz(0.6), 300.0);
        assert_eq!(fmax_mhz(1.0), 800.0);
    }

    #[test]
    fn fmax_is_monotonic() {
        let mut prev = 0.0;
        let mut v = 0.6;
        while v <= 1.0 {
            let f = fmax_mhz(v);
            assert!(f >= prev);
            prev = f;
            v += 0.01;
        }
    }

    #[test]
    fn published_points_pass() {
        assert!(passes(OperatingPoint::efficiency()));
        assert!(passes(OperatingPoint::performance()));
        // 800 MHz at 0.6 V must fail.
        assert!(!passes(OperatingPoint {
            voltage: 0.6,
            freq_mhz: 800.0
        }));
        // Below 0.6 V: out of the operating range.
        assert!(!passes(OperatingPoint {
            voltage: 0.55,
            freq_mhz: 300.0
        }));
    }

    #[test]
    fn shmoo_grid_has_pass_and_fail_regions() {
        let g = shmoo_grid();
        let pass = g.iter().filter(|(_, _, p)| *p).count();
        assert!(pass > 10 && pass < g.len());
    }
}
