//! Activity-based energy model, calibrated to the published silicon
//! measurements (Fig. 5 / Fig. 7b):
//!   * 1.60 TOPS/W peak system efficiency at 0.6 V / 300 MHz on the
//!     dense M=N=K=96 GEMM;
//!   * 171-981 mW power envelope over the 0.6-1.0 V range.
//!
//! Per-event energies are specified at VREF = 0.8 V and scaled with an
//! effective exponent fitted to the measured power range: the published
//! min/max powers imply dynamic-energy scaling of about V^1.5 across the
//! range (a mix of pure CV^2 switching, clock tree and short-circuit
//! components) — see EXPERIMENTS.md §Calibration. Leakage scales ~V^3.
//!
//! The *activity counts* come from the cycle simulator; nothing in the
//! sparsity/matrix-size trends (Fig. 7c/d) is hard-coded.

use crate::config::OperatingPoint;
use crate::metrics::{TileMetrics, WorkloadMetrics};

/// Reference voltage for the per-event constants.
pub const VREF: f64 = 0.8;
/// Effective dynamic-energy voltage exponent (fit, see module docs).
pub const DYN_EXP: f64 = 1.5;

/// Per-event energies at VREF, picojoules. Tuned once against the
/// Fig. 7b calibration targets (test `peak_efficiency_matches_paper`).
#[derive(Clone, Copy, Debug)]
pub struct EnergyParams {
    /// One INT8 MAC (two ops) in the array, active lane.
    pub mac_pj: f64,
    /// An idle (under-filled) MAC lane still clocked, per cycle.
    pub mac_idle_pj: f64,
    /// One 64-bit bank access (read or write).
    pub bank_pj: f64,
    /// One word through the crossbar.
    pub xbar_pj: f64,
    /// One FIFO push or pop.
    pub fifo_pj: f64,
    /// One quantization-SIMD result.
    pub simd_pj: f64,
    /// Control overhead (Snitch + loop controllers) per cycle.
    pub ctrl_cycle_pj: f64,
    /// One off-chip DMA byte (LPDDR-class interface energy).
    pub dma_byte_pj: f64,
    /// Leakage power at VREF, milliwatts.
    pub leak_mw: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        EnergyParams {
            mac_pj: 0.99,
            mac_idle_pj: 0.075,
            bank_pj: 20.8,
            xbar_pj: 3.3,
            fifo_pj: 0.85,
            simd_pj: 2.1,
            ctrl_cycle_pj: 22.6,
            dma_byte_pj: 15.0,
            leak_mw: 10.4,
        }
    }
}

/// Datapath activity factors for the sparsity study (Fig. 7c).
#[derive(Clone, Copy, Debug)]
pub struct Activity {
    /// Fraction of weights that are zero (clock-gates the multiplier).
    pub weight_sparsity: f64,
    /// Input toggle rate, 1.0 = the dense-random reference stimulus.
    pub input_toggle: f64,
}

impl Default for Activity {
    fn default() -> Self {
        Activity {
            weight_sparsity: 0.0,
            input_toggle: 1.0,
        }
    }
}

fn dyn_scale(v: f64) -> f64 {
    (v / VREF).powf(DYN_EXP)
}

fn leak_mw_at(p: &EnergyParams, v: f64) -> f64 {
    p.leak_mw * (v / VREF).powi(3)
}

/// Energy (joules) of one tile/layer activity bundle at an operating
/// point, excluding off-chip DMA (added separately by workload_energy).
pub fn tile_energy_j(
    p: &EnergyParams,
    t: &TileMetrics,
    act: &Activity,
    op: OperatingPoint,
) -> f64 {
    let s = dyn_scale(op.voltage);
    // Zero weights gate the multiplier (85% of MAC switching); the
    // residual 15% is operand latching. Input toggle scales the
    // remaining datapath switching linearly around the reference.
    let mac_eff = p.mac_pj
        * (0.15 + 0.85 * (1.0 - act.weight_sparsity))
        * (0.30 + 0.70 * act.input_toggle);
    let idle_macs = t.offered_macs.saturating_sub(t.useful_macs) as f64;
    let dyn_pj = t.useful_macs as f64 * mac_eff
        + idle_macs * p.mac_idle_pj
        + (t.bank_reads + t.bank_writes) as f64 * (p.bank_pj + p.xbar_pj)
        + t.fifo_events as f64 * p.fifo_pj
        + t.simd_cycles as f64 * 8.0 * p.simd_pj
        + t.total_cycles as f64 * p.ctrl_cycle_pj;
    let leak_j = leak_mw_at(p, op.voltage) * 1e-3 * t.total_cycles as f64
        / (op.freq_mhz * 1e6);
    dyn_pj * 1e-12 * s + leak_j
}

/// Per-module energy decomposition of a workload (the "where do the
/// joules go" analysis every chip paper runs; Fig. 7c's saturation is
/// exactly the non-MAC floor visible here).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub mac_j: f64,
    pub idle_j: f64,
    pub memory_j: f64,
    pub fifo_j: f64,
    pub simd_j: f64,
    pub ctrl_j: f64,
    pub leak_j: f64,
    pub dma_j: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.mac_j
            + self.idle_j
            + self.memory_j
            + self.fifo_j
            + self.simd_j
            + self.ctrl_j
            + self.leak_j
            + self.dma_j
    }
}

/// Decompose a workload's energy by module.
pub fn energy_breakdown(
    p: &EnergyParams,
    w: &WorkloadMetrics,
    act: &Activity,
    op: OperatingPoint,
) -> EnergyBreakdown {
    let s = dyn_scale(op.voltage);
    let mac_eff = p.mac_pj
        * (0.15 + 0.85 * (1.0 - act.weight_sparsity))
        * (0.30 + 0.70 * act.input_toggle);
    let mut b = EnergyBreakdown::default();
    for l in &w.layers {
        let t = &l.tiles;
        let idle = t.offered_macs.saturating_sub(t.useful_macs) as f64;
        b.mac_j += t.useful_macs as f64 * mac_eff * 1e-12 * s;
        b.idle_j += idle * p.mac_idle_pj * 1e-12 * s;
        b.memory_j += (t.bank_reads + t.bank_writes) as f64 * (p.bank_pj + p.xbar_pj) * 1e-12 * s;
        b.fifo_j += t.fifo_events as f64 * p.fifo_pj * 1e-12 * s;
        b.simd_j += t.simd_cycles as f64 * 8.0 * p.simd_pj * 1e-12 * s;
        b.ctrl_j += t.total_cycles as f64 * p.ctrl_cycle_pj * 1e-12 * s;
        b.dma_j += l.dma_bytes as f64 * p.dma_byte_pj * 1e-12 * s;
        let leak_cycles = l.latency_cycles.max(t.total_cycles);
        b.leak_j += leak_mw_at(p, op.voltage) * 1e-3 * leak_cycles as f64 / (op.freq_mhz * 1e6);
    }
    b
}

/// Total workload energy (joules) including DMA traffic.
pub fn workload_energy_j(
    p: &EnergyParams,
    w: &WorkloadMetrics,
    act: &Activity,
    op: OperatingPoint,
) -> f64 {
    let s = dyn_scale(op.voltage);
    let mut e = 0.0;
    for l in &w.layers {
        e += tile_energy_j(p, &l.tiles, act, op);
        e += l.dma_bytes as f64 * p.dma_byte_pj * 1e-12 * s;
        // Leakage during the DMA-only portion of the layer.
        let extra_cycles = l.latency_cycles.saturating_sub(l.tiles.total_cycles);
        e += leak_mw_at(p, op.voltage) * 1e-3 * extra_cycles as f64 / (op.freq_mhz * 1e6);
    }
    e
}

/// System efficiency in TOPS/W for an activity bundle (2 ops per MAC).
pub fn tops_per_watt(
    p: &EnergyParams,
    t: &TileMetrics,
    act: &Activity,
    op: OperatingPoint,
) -> f64 {
    let e = tile_energy_j(p, t, act, op);
    if e <= 0.0 {
        return 0.0;
    }
    // Effective ops: sparsity-gated MACs still count as delivered ops
    // (the chip reports *effective* efficiency, Fig. 7c).
    2.0 * t.useful_macs as f64 / e / 1e12
}

/// Average power in milliwatts while executing `t` at `op`.
pub fn power_mw(p: &EnergyParams, t: &TileMetrics, act: &Activity, op: OperatingPoint) -> f64 {
    let e = tile_energy_j(p, t, act, op);
    let time_s = t.total_cycles as f64 / (op.freq_mhz * 1e6);
    if time_s <= 0.0 {
        0.0
    } else {
        e / time_s * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;
    use crate::sim::{simulate_tile, TileSpec};

    fn dense96() -> TileMetrics {
        simulate_tile(&ChipConfig::voltra(), &TileSpec::simple(96, 96, 96))
    }

    #[test]
    fn peak_efficiency_matches_paper() {
        // Fig. 7b: 1.60 TOPS/W at 0.6 V / 300 MHz on dense 96^3 GEMM.
        let p = EnergyParams::default();
        let t = dense96();
        let eff = tops_per_watt(&p, &t, &Activity::default(), OperatingPoint::efficiency());
        assert!(
            (eff - 1.60).abs() < 0.12,
            "expected ~1.60 TOPS/W, got {eff:.3}"
        );
    }

    #[test]
    fn power_envelope_matches_fig5() {
        let p = EnergyParams::default();
        let t = dense96();
        let pmin = power_mw(&p, &t, &Activity::default(), OperatingPoint::efficiency());
        let pmax = power_mw(&p, &t, &Activity::default(), OperatingPoint::performance());
        assert!((140.0..230.0).contains(&pmin), "min power {pmin:.0} mW");
        assert!((800.0..1150.0).contains(&pmax), "max power {pmax:.0} mW");
    }

    #[test]
    fn efficiency_falls_with_voltage() {
        let p = EnergyParams::default();
        let t = dense96();
        let a = Activity::default();
        let e06 = tops_per_watt(&p, &t, &a, OperatingPoint::efficiency());
        let e08 = tops_per_watt(
            &p,
            &t,
            &a,
            OperatingPoint {
                voltage: 0.8,
                freq_mhz: 600.0,
            },
        );
        let e10 = tops_per_watt(&p, &t, &a, OperatingPoint::performance());
        assert!(e06 > e08 && e08 > e10, "{e06:.2} > {e08:.2} > {e10:.2}");
    }

    #[test]
    fn sparsity_raises_efficiency_but_saturates() {
        let p = EnergyParams::default();
        let t = dense96();
        let op = OperatingPoint::efficiency();
        let mut prev = 0.0;
        let mut e0 = 0.0;
        for s in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let a = Activity {
                weight_sparsity: s,
                input_toggle: 1.0,
            };
            let e = tops_per_watt(&p, &t, &a, op);
            assert!(e >= prev, "efficiency must not fall with sparsity");
            if s == 0.0 {
                e0 = e;
            }
            prev = e;
        }
        // Saturation: even fully sparse weights cannot beat the
        // non-datapath energy floor (memory, control, leakage) — the
        // total gain stays bounded, as Fig. 7c shows.
        assert!(prev / e0 > 1.05, "sparsity should help: {:.3}x", prev / e0);
        assert!(prev / e0 < 2.5, "gain must saturate: {:.3}x", prev / e0);
    }

    #[test]
    fn lower_toggle_rate_saves_energy() {
        let p = EnergyParams::default();
        let t = dense96();
        let op = OperatingPoint::efficiency();
        let dense = tops_per_watt(&p, &t, &Activity::default(), op);
        let calm = tops_per_watt(
            &p,
            &t,
            &Activity {
                weight_sparsity: 0.0,
                input_toggle: 0.25,
            },
            op,
        );
        assert!(calm > dense);
    }

    #[test]
    fn breakdown_components_sum_close_to_total() {
        use crate::coordinator::run_workload;
        use crate::workloads::by_name;
        let cfg = ChipConfig::voltra();
        let w = by_name("pointnext").unwrap();
        let m = run_workload(&cfg, &w).metrics;
        let p = EnergyParams::default();
        let a = Activity::default();
        let op = OperatingPoint::efficiency();
        let b = energy_breakdown(&p, &m, &a, op);
        let total = workload_energy_j(&p, &m, &a, op);
        // The breakdown's leakage window differs slightly (max vs sum of
        // latency/compute), so allow a small tolerance.
        assert!(
            (b.total() - total).abs() / total < 0.1,
            "breakdown {} vs total {}",
            b.total(),
            total
        );
        // Every component is positive and MACs are not the whole story.
        assert!(b.mac_j > 0.0 && b.memory_j > 0.0 && b.ctrl_j > 0.0);
        assert!(b.mac_j / b.total() < 0.9);
    }

    #[test]
    fn idle_lanes_cost_less_than_active() {
        let p = EnergyParams::default();
        let cfg = ChipConfig::voltra();
        let full = simulate_tile(&cfg, &TileSpec::simple(64, 64, 64));
        let ragged = simulate_tile(&cfg, &TileSpec::simple(33, 64, 64));
        let op = OperatingPoint::efficiency();
        let a = Activity::default();
        let e_full = tile_energy_j(&p, &full, &a, op) / full.useful_macs as f64;
        let e_rag = tile_energy_j(&p, &ragged, &a, op) / ragged.useful_macs as f64;
        // Ragged tiles pay idle-lane overhead per useful MAC.
        assert!(e_rag > e_full);
    }
}
