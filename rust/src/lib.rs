//! Voltra: a production-quality reproduction of the 16 nm 1.60 TOPS/W
//! high-utilization DNN accelerator (3D spatial data reuse + efficient
//! shared-memory access), as a cycle-accurate architectural model plus a
//! PJRT-based functional runtime.
//!
//! Layout (see DESIGN.md):
//! * [`config`] / [`arch`] — chip parameters straight from the paper.
//! * [`sim`] — the cycle-accurate chip model (GEMM core, banked shared
//!   memory, streamers/AGUs/FIFOs, crossbar, SIMD, reshuffler, maxpool,
//!   Snitch control, DMA).
//! * [`tiling`] — PDMA shared-memory allocator, separated-buffer
//!   baseline, the layer-wise tiling engine, and the per-layer mapping
//!   search ([`tiling::mapper`], DESIGN.md §11) that folds idle array
//!   rows onto extra K lanes (GEMV K-extension) and memoizes each layer
//!   shape's resolved mapping process-wide.
//! * [`workloads`] — the eight evaluated networks as layer graphs.
//! * [`power`] — energy/area/DVFS models calibrated to the die.
//! * [`plan`] — the compile-once planning layer (DESIGN.md §10): builds
//!   an immutable [`plan::WorkloadPlan`] per `(config, workload)` — the
//!   tiling/K-round/DMA-attribution decisions plus the shared-memory
//!   residency pass — executes it as a thin pipeline-scheduler pass, and
//!   memoizes plans process-wide in the [`PlanCache`].
//! * [`coordinator`] — thin run wrappers over `plan::build` +
//!   `plan::execute`, the tile memoization stores, and the serving +
//!   sweep engine that runs many connections/workloads concurrently
//!   against one process-wide [`SharedTileCache`] and [`PlanCache`]
//!   (DESIGN.md §Concurrency).
//! * [`runtime`] — loads AOT artifacts (HLO text) and executes the real
//!   numerics through the PJRT CPU client behind the pluggable
//!   [`runtime::GemmBackend`] seam; Python never runs at runtime.
//! * [`search`] — parallel architecture/mapping co-search (DESIGN.md
//!   §15): enumerates joint array/bank/FIFO/memory design points, plans
//!   each over the full suite through the shared caches with structural
//!   keying, and emits the TOPS/W vs TOPS/mm² vs latency Pareto
//!   frontier with the shipped chip as one point.
//! * [`sync`] — the rank-tagged lock facade (DESIGN.md §16): every
//!   `Mutex`/`RwLock`/`Condvar` in the crate, tagged with a static
//!   lock-rank table (deadlock freedom by construction), predicate-loop
//!   condvar waits only, and a defined poison-recovery policy.
//! * [`check`] — the deterministic-interleaving model checker
//!   (DESIGN.md §16, `voltra check`): exhaustively explores bounded
//!   thread interleavings of explicit models of the single-flight,
//!   cache-accounting, dispatch-admission, work-stealing-pool and
//!   lock-order protocols, with counterexample traces on violation.

// Static-analysis posture (DESIGN.md §13): the model is pure safe Rust —
// any future `unsafe` must arrive as a deliberate, reviewed exception —
// and every `pub` item must actually be reachable from outside the
// crate, so the public API surface stays the one DESIGN.md documents.
#![forbid(unsafe_code)]
#![deny(unreachable_pub)]

pub mod arch;
pub mod check;
pub mod config;
pub mod coordinator;
pub mod metrics;
pub mod plan;
pub mod power;
pub mod runtime;
pub mod search;
pub mod sim;
pub mod sync;
pub mod tiling;
pub mod workloads;

pub use config::ChipConfig;
pub use coordinator::{
    run_suite_parallel, run_suite_planned, run_workload, run_workload_shared, SharedTileCache,
    SimCache, TileCache, WorkloadReport,
};
pub use metrics::{CacheStats, LayerMetrics, TileMetrics, WorkloadMetrics};
pub use plan::{PlanCache, PlanCacheStats, WorkloadPlan};
pub use search::{DesignPoint, SearchResult};
pub use tiling::MapperCache;
