//! Chip / simulation configuration: the knobs the paper ablates.
//!
//! Presets correspond to the evaluation configurations of Fig. 6:
//! * [`ChipConfig::voltra`] — the full chip (3D array + MGDP + PDMA).
//! * [`ChipConfig::no_prefetch`] — MGDP disabled (Fig. 6b left bars):
//!   demand-fetched operands, bank conflicts fully exposed.
//! * [`ChipConfig::separated_memory`] — PDMA disabled (Fig. 6c left
//!   bars): fixed per-operand buffers constrain the tiling.
//! * [`ChipConfig::array2d`] — the conventional 2D spatial array
//!   baseline (Fig. 6a left bars).

use crate::arch;

/// How the 512 MACs are arranged spatially.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrayGeometry {
    /// Voltra's 3D array: 8x8 Dot-ProdUs x 8-wide dot product, with
    /// flexible dimension mapping (incl. GEMV K-extension by spatial
    /// accumulation, inherited from OpenGeMM).
    Spatial3D { m: usize, n: usize, k: usize },
    /// Conventional 2D output-stationary array (K temporal), the Fig. 6a
    /// baseline. Same MAC budget arranged M x N.
    Spatial2D { m: usize, n: usize },
}

impl ArrayGeometry {
    pub fn macs(&self) -> usize {
        match *self {
            ArrayGeometry::Spatial3D { m, n, k } => m * n * k,
            ArrayGeometry::Spatial2D { m, n } => m * n,
        }
    }
}

/// On-chip memory organisation (the PDMA ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemoryOrg {
    /// One unified multi-bank space; streamers carve regions dynamically
    /// via programmable base pointers (Sec. II-C).
    Shared,
    /// Fixed dedicated buffers per operand class (the Fig. 1a template).
    /// Sizes in bytes; must sum to <= DATA_MEM_BYTES.
    Separated {
        input: usize,
        weight: usize,
        output: usize,
        psum: usize,
    },
}

impl MemoryOrg {
    /// The conventional split used by the separated baseline: weights get
    /// the largest dedicated buffer, as in most 2D-template accelerators.
    pub fn separated_default() -> Self {
        MemoryOrg::Separated {
            input: 40 * 1024,
            weight: 56 * 1024,
            output: 24 * 1024,
            psum: 8 * 1024,
        }
    }

    pub fn total_bytes(&self) -> usize {
        match *self {
            MemoryOrg::Shared => arch::DATA_MEM_BYTES,
            MemoryOrg::Separated {
                input,
                weight,
                output,
                psum,
            } => input + weight + output + psum,
        }
    }
}

/// How the per-layer dimension mapping is chosen (DESIGN.md §11).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MappingSearch {
    /// Legacy M/N-permutation-only choice (the pre-mapper model): pick
    /// the better-filling orientation, never fold. Kept as the
    /// ablation baseline the mapper is measured against.
    SwapOnly,
    /// Full 3D mapping search: M/N permutation plus K-extension
    /// dimension folding, each candidate scored together with its
    /// tiling under the cycle-domain objective in
    /// [`crate::tiling::mapper`].
    Fold3D,
}

/// A legal (voltage, frequency) operating point from the shmoo (Fig. 7a).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OperatingPoint {
    pub voltage: f64,
    pub freq_mhz: f64,
}

impl OperatingPoint {
    /// Peak-energy-efficiency point: 0.6 V / 300 MHz (Sec. III-B).
    pub fn efficiency() -> Self {
        OperatingPoint {
            voltage: 0.6,
            freq_mhz: 300.0,
        }
    }

    /// Peak-performance point: 1.0 V / 800 MHz (Sec. III-B).
    pub fn performance() -> Self {
        OperatingPoint {
            voltage: 1.0,
            freq_mhz: 800.0,
        }
    }
}

/// Full chip + simulation configuration.
#[derive(Clone, Debug)]
pub struct ChipConfig {
    pub array: ArrayGeometry,
    pub memory: MemoryOrg,
    /// Mixed-grained data prefetching (Sec. II-B). When false, streamers
    /// demand-fetch with depth-1 buffering and every bank conflict or
    /// access-latency cycle stalls the array.
    pub prefetch: bool,
    /// Input/weight stream FIFO depth (8 on the chip).
    pub stream_fifo_depth: usize,
    /// Psum/output FIFO depth (1 on the chip).
    pub psum_fifo_depth: usize,
    /// Quantization SIMD lanes (8 on the chip; 64 in the ablation).
    pub simd_lanes: usize,
    /// Time-multiplex the psum-read and output-write crossbar ports
    /// (Sec. II-D). Psum reads have priority.
    pub tmux_psum_output: bool,
    /// Number of shared-memory banks (32 on the chip; ablation axis).
    pub num_banks: usize,
    /// Shared-memory access latency in cycles (bank + crossbar).
    pub mem_latency: u64,
    /// Off-chip DMA bandwidth, bytes per core cycle. Integer so DMA
    /// timing is exact `div_ceil` arithmetic (platform-deterministic,
    /// no precision loss on huge transfers).
    pub dma_bytes_per_cycle: u64,
    /// Fixed DMA burst setup latency in cycles.
    pub dma_burst_latency: u64,
    /// Overlap DMA with compute via double buffering when the allocator
    /// can hold two tiles (true for the chip).
    pub double_buffer: bool,
    /// Per-layer dimension-mapping search mode (DESIGN.md §11): the full
    /// cycle-domain search with K-extension folding, or the legacy
    /// permutation-only baseline.
    pub mapping: MappingSearch,
    pub operating_point: OperatingPoint,
}

impl ChipConfig {
    /// The full Voltra chip as fabricated.
    pub fn voltra() -> Self {
        ChipConfig {
            array: ArrayGeometry::Spatial3D {
                m: arch::ARRAY_M,
                n: arch::ARRAY_N,
                k: arch::ARRAY_K,
            },
            memory: MemoryOrg::Shared,
            prefetch: true,
            stream_fifo_depth: arch::STREAM_FIFO_DEPTH,
            psum_fifo_depth: arch::PSUM_FIFO_DEPTH,
            simd_lanes: arch::SIMD_LANES,
            tmux_psum_output: true,
            num_banks: arch::NUM_BANKS,
            mem_latency: 2,
            dma_bytes_per_cycle: 8,
            dma_burst_latency: 24,
            double_buffer: true,
            mapping: MappingSearch::Fold3D,
            operating_point: OperatingPoint::performance(),
        }
    }

    /// Mapper ablation baseline: the chip with the legacy
    /// permutation-only mapping (no K-extension folding) — what the
    /// model did before the mapping search existed.
    pub fn swap_only() -> Self {
        ChipConfig {
            mapping: MappingSearch::SwapOnly,
            ..Self::voltra()
        }
    }

    /// Fig. 6b baseline: plain shared memory without MGDP.
    pub fn no_prefetch() -> Self {
        ChipConfig {
            prefetch: false,
            ..Self::voltra()
        }
    }

    /// Fig. 6c baseline: separated per-operand buffers (no PDMA). The
    /// dedicated dispatchers do not contend across operand classes, so
    /// bank conflicts vanish — but the tiling is capped by the smallest
    /// buffer, activations round-trip through DRAM between layers, and
    /// without dynamic re-partitioning the fixed buffers cannot
    /// ping-pong, so DMA cannot overlap compute.
    pub fn separated_memory() -> Self {
        ChipConfig {
            memory: MemoryOrg::separated_default(),
            double_buffer: false,
            ..Self::voltra()
        }
    }

    /// Fig. 6a baseline: same 512 MACs as a conventional 2D array
    /// (16 x 32 output-stationary, K temporal).
    pub fn array2d() -> Self {
        ChipConfig {
            array: ArrayGeometry::Spatial2D { m: 16, n: 32 },
            ..Self::voltra()
        }
    }

    /// Ablation of Sec. II-D: a 64-lane SIMD unit (no time-multiplexing).
    pub fn simd64() -> Self {
        ChipConfig {
            simd_lanes: 64,
            ..Self::voltra()
        }
    }

    /// Ablation of Sec. II-D: dedicated (non-multiplexed) psum/output
    /// crossbar ports.
    pub fn full_crossbar() -> Self {
        ChipConfig {
            tmux_psum_output: false,
            ..Self::voltra()
        }
    }

    pub fn with_operating_point(mut self, op: OperatingPoint) -> Self {
        self.operating_point = op;
        self
    }

    /// Peak INT8 TOPS at this configuration's operating point.
    pub fn peak_tops(&self) -> f64 {
        self.array.macs() as f64 * 2.0 * self.operating_point.freq_mhz * 1e6 / 1e12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_keep_mac_budget() {
        assert_eq!(ChipConfig::voltra().array.macs(), 512);
        assert_eq!(ChipConfig::array2d().array.macs(), 512);
    }

    #[test]
    fn separated_split_fits_data_memory() {
        let m = MemoryOrg::separated_default();
        assert!(m.total_bytes() <= arch::DATA_MEM_BYTES);
    }

    #[test]
    fn peak_tops_at_performance_point() {
        let c = ChipConfig::voltra();
        assert!((c.peak_tops() - 0.8192).abs() < 1e-9);
    }

    #[test]
    fn ablation_presets_flip_one_knob() {
        let v = ChipConfig::voltra();
        assert!(!ChipConfig::no_prefetch().prefetch && v.prefetch);
        assert_eq!(ChipConfig::simd64().simd_lanes, 64);
        assert!(!ChipConfig::full_crossbar().tmux_psum_output);
        assert!(matches!(
            ChipConfig::separated_memory().memory,
            MemoryOrg::Separated { .. }
        ));
        assert_eq!(v.mapping, MappingSearch::Fold3D);
        assert_eq!(ChipConfig::swap_only().mapping, MappingSearch::SwapOnly);
    }
}
