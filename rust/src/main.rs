//! `voltra` CLI: run workloads through the chip model, print the Fig. 5
//! spec sheet, sweep the shmoo, and smoke-test the PJRT artifact path.
//!
//! (Substrate note: the build environment vendors no argument-parsing
//! crate, so the CLI is hand-rolled — see DESIGN.md.)

use std::collections::HashMap;

use voltra::config::{ChipConfig, OperatingPoint};
use voltra::coordinator::run_workload;
use voltra::power::{dvfs, tops_per_watt, Activity, AreaModel, EnergyParams};
use voltra::runtime::{default_dir, ArtifactLib, GemmBackend, HostBackend, MatI32, PjrtBackend};
use voltra::workloads;
use voltra::{arch, metrics};

fn usage() -> ! {
    eprintln!(
        "voltra — cycle-accurate model + PJRT runtime of the 16nm Voltra DNN accelerator

USAGE:
    voltra <COMMAND> [OPTIONS]

COMMANDS:
    info                         print the chip specification (Fig. 5)
    run --workload <name>        run one workload through the simulator
    suite                        run the full Fig. 6 evaluation suite
    lint                         statically verify compiled plans against
                                 the hardware invariant catalog
                                 (DESIGN.md §13); exits 1 on findings.
                                 Default: all eight suite workloads;
                                 --workload <name> checks one,
                                 --json prints machine-readable findings,
                                 --selftest corrupts a plan on purpose
    check                        model-check the serving/cache concurrency
                                 protocols (DESIGN.md §16): exhaustively
                                 explore bounded thread interleavings of
                                 the flight/plancache/dispatch/pool/
                                 lockorder models; exits 1 on findings.
                                 --protocol <name> checks one,
                                 --depth <n> schedule bound (default 64),
                                 --json machine-readable findings,
                                 --selftest seeds a known bug on purpose
    sweep                        run all eight networks across a thread
                                 pool sharing one tile cache
    shmoo                        print the Fig. 7a shmoo grid
    artifacts                    list + smoke-test the AOT artifacts
    serve --port <p>             concurrent serving over TCP: GEMM
                                 numerics (PJRT when artifacts load,
                                 host-oracle fallback), WORKLOAD/LINT
                                 answered from the plan cache, STATS
                                 for serving counters; --workers <n>
                                 engine workers (default: cores, max 8),
                                 --queue-depth <d> waiting requests
                                 before ERR busy (default: 64)
    report --workload <name>     per-layer table + energy breakdown
    search                       architecture/mapping co-search: score a
                                 joint array/bank/FIFO/memory grid over
                                 the full suite through shared
                                 structurally-keyed caches and print the
                                 TOPS/W vs TOPS/mm^2 vs latency Pareto
                                 frontier (the shipped chip is one dot);
                                 --grid full|quick (default: full),
                                 --threads <n> pool width (default:
                                 cores, max 8), --json machine output

OPTIONS:
    --workload <name>   mobilenetv2|resnet50|vit|pointnext|lstm|bert|
                        llama-prefill|llama-decode
    --config <preset>   voltra|no-prefetch|separated|2d|simd64|full-xbar|
                        swap-only (default: voltra; swap-only disables
                        the 3D mapping search's K-extension folding —
                        the pre-mapper baseline)
    --threads <n>       sweep thread-pool size (default: all cores)
    --vdd <volts>       supply voltage (default 1.0)
    --freq <MHz>        clock (default fmax at --vdd)
    --artifacts <dir>   artifact directory (default: ./artifacts)"
    );
    std::process::exit(2);
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(k) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(k.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(k.to_string(), String::from("true"));
                i += 1;
            }
        } else {
            eprintln!("unexpected argument {:?}", args[i]);
            usage();
        }
    }
    m
}

fn config_from(flags: &HashMap<String, String>) -> ChipConfig {
    let mut cfg = match flags.get("config").map(String::as_str).unwrap_or("voltra") {
        "voltra" => ChipConfig::voltra(),
        "no-prefetch" => ChipConfig::no_prefetch(),
        "separated" => ChipConfig::separated_memory(),
        "2d" => ChipConfig::array2d(),
        "simd64" => ChipConfig::simd64(),
        "full-xbar" => ChipConfig::full_crossbar(),
        "swap-only" => ChipConfig::swap_only(),
        other => {
            eprintln!("unknown config preset {other:?}");
            usage();
        }
    };
    let vdd: f64 = flags
        .get("vdd")
        .map(|v| v.parse().expect("--vdd must be a number"))
        .unwrap_or(1.0);
    let freq: f64 = flags
        .get("freq")
        .map(|v| v.parse().expect("--freq must be a number"))
        .unwrap_or_else(|| dvfs::fmax_mhz(vdd));
    let op = OperatingPoint {
        voltage: vdd,
        freq_mhz: freq,
    };
    if !dvfs::passes(op) {
        eprintln!(
            "operating point {}V/{}MHz fails the shmoo (fmax at {}V is {}MHz)",
            vdd,
            freq,
            vdd,
            dvfs::fmax_mhz(vdd)
        );
        std::process::exit(1);
    }
    cfg.operating_point = op;
    cfg
}

fn cmd_info() {
    let area = AreaModel::default();
    println!("Voltra chip specification (Fig. 5)");
    println!("  Technology                16 nm (modeled)");
    println!("  Core area                 {:.3} mm^2", area.total(8, true));
    println!("  Operating voltage         0.6 - 1.0 V");
    println!("  Frequency                 300 - 800 MHz");
    println!(
        "  On-chip memory            {} KB data + {} KB instr",
        arch::DATA_MEM_BYTES / 1024,
        arch::INSTR_MEM_BYTES / 1024
    );
    println!("  MACs                      {} (8 x 8 x 8)", arch::MACS);
    println!("  Peak throughput           {:.2} TOPS (INT8)", arch::PEAK_TOPS);
    println!(
        "  Peak area efficiency      {:.2} TOPS/mm^2",
        arch::PEAK_TOPS / area.total(8, true)
    );
}

fn report_line(cfg: &ChipConfig, w: &workloads::Workload) {
    let r = run_workload(cfg, w);
    print_report(cfg, &r);
}

fn print_report(cfg: &ChipConfig, r: &voltra::WorkloadReport) {
    let m = &r.metrics;
    let p = EnergyParams::default();
    let e = voltra::power::energy::workload_energy_j(
        &p,
        m,
        &Activity::default(),
        cfg.operating_point,
    );
    let t_s = m.total_latency_cycles() as f64 / (cfg.operating_point.freq_mhz * 1e6);
    println!(
        "{:<22} spatial {:>6.2}%  temporal {:>6.2}%  latency {:>12} cyc  {:>9.3} ms  {:>9.3} mJ  ({} unique tiles / {} dispatched)",
        m.name,
        100.0 * m.spatial_utilization(),
        100.0 * m.temporal_utilization(),
        m.total_latency_cycles(),
        t_s * 1e3,
        e * 1e3,
        r.unique_tiles,
        r.dispatched_tiles,
    );
}

fn cmd_report(cfg: &ChipConfig, name: &str) {
    let Some(w) = workloads::by_name(name) else {
        eprintln!("unknown workload {name:?}");
        usage();
    };
    let r = run_workload(cfg, &w);
    let m = &r.metrics;
    println!(
        "{:<16} {:>10} {:>9} {:>9} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "layer", "mapping", "spatial", "temporal", "compute cyc", "dma cyc", "overlap", "latency",
        "KB moved"
    );
    for l in &m.layers {
        if l.macs == 0 {
            continue;
        }
        println!(
            "{:<16} {:>10} {:>8.1}% {:>8.1}% {:>12} {:>12} {:>12} {:>12} {:>10}",
            if l.name.len() > 16 {
                &l.name[..16]
            } else {
                &l.name
            },
            if l.mapping.len() > 10 {
                &l.mapping[..10]
            } else {
                &l.mapping
            },
            100.0 * l.tiles.spatial_utilization(),
            100.0 * l.tiles.temporal_utilization(),
            l.tiles.total_cycles,
            l.dma_cycles,
            l.overlap_cycles,
            l.latency_cycles,
            l.dma_bytes / 1024,
        );
    }
    println!(
        "pipeline: {} compute cyc + {} dma cyc -> {} latency cyc ({} hidden by overlap)",
        m.total_compute_cycles(),
        m.total_dma_cycles(),
        m.total_latency_cycles(),
        m.total_overlap_cycles(),
    );
    println!(
        "residency: {} KB of activations chained on chip across layer boundaries",
        m.total_chained_bytes() / 1024,
    );
    let p = EnergyParams::default();
    let act = Activity::default();
    let b = voltra::power::energy_breakdown(&p, m, &act, cfg.operating_point);
    let tot = b.total();
    println!(
        "\nenergy breakdown ({:.3} mJ total @{:.1}V/{:.0}MHz):",
        tot * 1e3,
        cfg.operating_point.voltage,
        cfg.operating_point.freq_mhz
    );
    for (name, j) in [
        ("MAC array (active)", b.mac_j),
        ("MAC array (idle lanes)", b.idle_j),
        ("shared memory + crossbar", b.memory_j),
        ("streamer FIFOs", b.fifo_j),
        ("quant SIMD", b.simd_j),
        ("control (Snitch + loops)", b.ctrl_j),
        ("leakage", b.leak_j),
        ("off-chip DMA", b.dma_j),
    ] {
        let pct = 100.0 * j / tot;
        let bar = "#".repeat((pct / 2.0).round() as usize);
        println!("  {name:<26} {:>7.3} mJ {pct:>5.1}%  {bar}", j * 1e3);
    }
    // Mapping-search telemetry: `run_workload` resolves layer mappings
    // through the process-wide MapperCache, so these counters cover
    // exactly the report above.
    let mc = voltra::MapperCache::global();
    let ms = mc.stats();
    println!(
        "\nmapper cache: {} layer shapes resolved ({} hits / {} misses / {} coalesced waits)",
        mc.len(),
        ms.hits,
        ms.misses,
        mc.coalesced_waits()
    );
    println!(
        "concurrency: {} single-flight abort(s), max lock-rank depth {}",
        voltra::sync::flight_aborts(),
        voltra::sync::max_rank_depth()
    );
}

/// `voltra search`: parallel architecture/mapping co-search (DESIGN.md
/// §15). Scores every grid point over the eight-workload suite through
/// one shared structurally-keyed cache stack and prints the three-axis
/// Pareto frontier. `--json` output is deterministic (no timings) and
/// golden-tested in `tests/search_cli.rs`.
fn cmd_search(flags: &HashMap<String, String>) {
    let grid_name = flags.get("grid").map(String::as_str).unwrap_or("full");
    let grid = match grid_name {
        "full" => voltra::search::full_grid(),
        "quick" => voltra::search::quick_grid(),
        other => {
            eprintln!("unknown grid {other:?} (expected full|quick)");
            usage();
        }
    };
    let threads = flags
        .get("threads")
        .map(|v| v.parse::<usize>().expect("--threads must be an integer"))
        .unwrap_or_else(voltra::search::default_threads);
    let t0 = std::time::Instant::now();
    let result = voltra::search::run_grid(&grid, threads);
    let dt = t0.elapsed();
    if flags.contains_key("json") {
        println!(
            "{}",
            voltra::search::result_json(grid_name, &result).render()
        );
        return;
    }
    let shipped = voltra::search::shipped_label(&result.points).map(str::to_string);
    println!(
        "{:<26} {:>9} {:>14} {:>9} {:>10}",
        "design point", "mm^2", "latency cyc", "TOPS/W", "TOPS/mm^2"
    );
    for p in &result.points {
        let mark = match (p.pareto, shipped.as_deref() == Some(p.label.as_str())) {
            (true, true) => "  * shipped",
            (true, false) => "  *",
            (false, true) => "    shipped",
            (false, false) => "",
        };
        println!(
            "{:<26} {:>9.3} {:>14} {:>9.3} {:>10.3}{mark}",
            p.label, p.area_mm2, p.suite_latency_cycles, p.tops_per_watt, p.tops_per_mm2
        );
    }
    let s = &result.stats;
    let frontier = result.points.iter().filter(|p| p.pareto).count();
    println!(
        "\nsearch: {} points on {} threads in {:.2}s — {} on the Pareto frontier (*)",
        result.points.len(),
        threads,
        dt.as_secs_f64(),
        frontier
    );
    println!(
        "structural sharing: {} tile classes, {} mapper classes across {} configs",
        s.tile_classes,
        s.mapper_classes,
        result.points.len()
    );
    println!(
        "caches: plans {} hits / {} misses ({} waits); tiles {:.1}% hit rate; \
         mapper {} hits / {} misses ({} waits)",
        s.plan.hits,
        s.plan.misses,
        s.plan.coalesced,
        100.0 * s.tiles.hit_rate(),
        s.mapper.hits,
        s.mapper.misses,
        s.mapper_waits
    );
}

fn cmd_run(cfg: &ChipConfig, name: &str) {
    let Some(w) = workloads::by_name(name) else {
        eprintln!("unknown workload {name:?}");
        usage();
    };
    report_line(cfg, &w);
}

fn cmd_suite(cfg: &ChipConfig) {
    let plans = voltra::PlanCache::new();
    let mut spatial = Vec::new();
    let mut temporal = Vec::new();
    for w in workloads::evaluation_suite() {
        let r = plans.run(cfg, &w);
        spatial.push(r.metrics.spatial_utilization());
        temporal.push(r.metrics.temporal_utilization());
        print_report(cfg, &r);
    }
    println!(
        "{:<22} spatial {:>6.2}%  temporal {:>6.2}%  (geomean)",
        "geomean",
        100.0 * metrics::geomean(&spatial),
        100.0 * metrics::geomean(&temporal)
    );
    let s = plans.stats();
    println!(
        "plan cache: {} workload plans compiled ({} hits / {} misses)",
        plans.len(),
        s.hits,
        s.misses
    );
}

/// Multi-workload sweep: all eight networks across a thread pool sharing
/// one process-wide plan cache (each network is planned exactly once;
/// repeated tile shapes across networks simulate once for the sweep).
fn cmd_sweep(cfg: &ChipConfig, threads: usize) {
    let suite = workloads::evaluation_suite();
    let plans = voltra::PlanCache::new();
    let t0 = std::time::Instant::now();
    let reports = voltra::run_suite_planned(cfg, &suite, threads, &plans);
    let dt = t0.elapsed();
    let mut spatial = Vec::new();
    let mut temporal = Vec::new();
    for r in &reports {
        spatial.push(r.metrics.spatial_utilization());
        temporal.push(r.metrics.temporal_utilization());
        print_report(cfg, r);
    }
    println!(
        "{:<22} spatial {:>6.2}%  temporal {:>6.2}%  (geomean)",
        "geomean",
        100.0 * metrics::geomean(&spatial),
        100.0 * metrics::geomean(&temporal)
    );
    let p = plans.stats();
    let t = plans.tile_stats();
    println!(
        "sweep: {} workloads on {} threads in {:.2}s — {} plans ({} hits / {} misses), \
         {} unique tiles ({:.1}% tile hit rate)",
        reports.len(),
        threads,
        dt.as_secs_f64(),
        plans.len(),
        p.hits,
        p.misses,
        plans.unique_tiles(),
        100.0 * t.hit_rate(),
    );
}

fn cmd_shmoo() {
    println!("shmoo (Fig. 7a): rows = freq MHz, cols = VDD; o = pass, . = fail");
    let mut freqs: Vec<f64> = (0..=12).map(|i| 250.0 + 50.0 * i as f64).collect();
    freqs.reverse();
    let volts: Vec<f64> = (0..=9).map(|i| 0.55 + 0.05 * i as f64).collect();
    print!("{:>6} ", "");
    for v in &volts {
        print!("{v:>6.2}");
    }
    println!();
    for f in freqs {
        print!("{f:>6} ");
        for &v in &volts {
            let ok = dvfs::passes(OperatingPoint {
                voltage: (v * 100.0).round() / 100.0,
                freq_mhz: f,
            });
            print!("{:>6}", if ok { "o" } else { "." });
        }
        println!();
    }
    let p = EnergyParams::default();
    let cfg = ChipConfig::voltra();
    let t = voltra::sim::simulate_tile(&cfg, &voltra::sim::TileSpec::simple(96, 96, 96));
    let eff = tops_per_watt(&p, &t, &Activity::default(), OperatingPoint::efficiency());
    println!("peak system energy efficiency @0.6V/300MHz: {eff:.2} TOPS/W");

    // DVFS scaling of a real network: plans are cycle-domain, so every
    // operating point of the sweep reuses ONE compiled plan — the plan
    // cache fingerprints the config without its (V, f) point.
    let plans = voltra::PlanCache::new();
    let w = workloads::by_name("bert").unwrap();
    println!("\nBERT-Base latency across the DVFS ladder (one shared plan):");
    for i in 0..=4 {
        let vdd = 0.6 + 0.1 * i as f64;
        let vdd = (vdd * 100.0).round() / 100.0;
        let op = OperatingPoint {
            voltage: vdd,
            freq_mhz: dvfs::fmax_mhz(vdd),
        };
        let cfg = ChipConfig::voltra().with_operating_point(op);
        let r = plans.run(&cfg, &w);
        println!(
            "  {:>4.2} V / {:>3.0} MHz: {:>9.3} ms",
            vdd,
            op.freq_mhz,
            r.metrics.total_latency_cycles() as f64 / (op.freq_mhz * 1e3)
        );
    }
    let s = plans.stats();
    println!(
        "plan cache: {} plan ({} hits / {} misses) — re-planned zero layers across the ladder",
        plans.len(),
        s.hits,
        s.misses
    );
}

fn cmd_artifacts(dir: &str) {
    let mut lib = match ArtifactLib::load(dir) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("failed to load artifacts: {e:#}");
            std::process::exit(1);
        }
    };
    println!("artifacts in {dir}:");
    for n in lib.names() {
        let m = &lib.meta[n];
        println!(
            "  {:<12} {} inputs, {} outputs",
            n,
            m.inputs.len(),
            m.outputs.len()
        );
    }
    // Smoke: run a 96x96x96 GEMM through the tiled executor vs host ref.
    let x = MatI32::from_fn(96, 96, |r, c| ((r * 7 + c * 13) % 255) as i32 - 127);
    let w = MatI32::from_fn(96, 96, |r, c| ((r * 11 + c * 3) % 255) as i32 - 127);
    let p = MatI32::zeros(96, 96);
    match voltra::runtime::gemm_tiled(&mut lib, &x, &w, &p, 0.001) {
        Ok((_q, acc)) => {
            let expect = voltra::runtime::gemm_ref(&x, &w, &p);
            assert_eq!(acc, expect, "PJRT result mismatch vs host reference");
            println!("smoke test: 96^3 tiled GEMM on PJRT matches host reference ✓");
        }
        Err(e) => {
            eprintln!("smoke test failed: {e:#}");
            std::process::exit(1);
        }
    }
}

/// `voltra lint`: build each requested workload's plan and statically
/// verify it against the invariant catalog (DESIGN.md §13). Stdout is
/// deterministic (no timings, no cache counters), so the plumbing
/// itself is golden-tested in `tests/lint_cli.rs`; exit code 1 when any
/// finding surfaces.
fn cmd_lint(cfg: &ChipConfig, flags: &HashMap<String, String>) {
    if flags.contains_key("selftest") {
        lint_selftest(cfg);
    }
    let suite: Vec<workloads::Workload> = match flags.get("workload") {
        Some(name) => match workloads::by_name(name) {
            Some(w) => vec![w],
            None => {
                eprintln!("unknown workload {name:?}");
                usage();
            }
        },
        None => workloads::evaluation_suite(),
    };
    let json = flags.contains_key("json");
    let plans = voltra::PlanCache::new();
    let mut all = Vec::new();
    for w in &suite {
        let plan = plans.plan(cfg, w);
        let findings = voltra::plan::verify(cfg, w, &plan);
        if !json {
            if findings.is_empty() {
                println!(
                    "lint {:<22} clean ({} layers, {} tiles dispatched)",
                    w.name,
                    plan.layers.len(),
                    plan.dispatched_tiles
                );
            } else {
                println!("lint {:<22} {} finding(s)", w.name, findings.len());
                for f in &findings {
                    println!("  {f}");
                }
            }
        }
        all.extend(findings);
    }
    if json {
        println!("{}", voltra::plan::verify::findings_json(&all).render());
    } else {
        println!("lint: {} workload(s), {} finding(s)", suite.len(), all.len());
    }
    if !all.is_empty() {
        std::process::exit(1);
    }
}

/// `voltra lint --selftest`: deliberately corrupt a freshly built plan
/// and prove the verifier catches it — the CLI-level nonzero-exit path,
/// exercised end to end by `tests/lint_cli.rs`. Exits 1 when the
/// corruption is caught (findings exist), 2 if the verifier missed it.
fn lint_selftest(cfg: &ChipConfig) -> ! {
    let w = workloads::by_name("lstm").expect("lstm is a suite workload");
    let mut cache = voltra::TileCache::new();
    let mut plan = voltra::plan::build(cfg, &w, &mut cache);
    plan.layers[0].macs += 1; // seeded single-field corruption
    let findings = voltra::plan::verify(cfg, &w, &plan);
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!("lint selftest: verifier MISSED the seeded corruption");
        std::process::exit(2);
    }
    println!(
        "lint selftest: verifier caught the seeded corruption ({} finding(s))",
        findings.len()
    );
    std::process::exit(1);
}

/// `voltra check`: exhaustively model-check the concurrency protocols
/// behind the serving and cache stack (DESIGN.md §16). Stdout is
/// deterministic (exploration is DFS over a fixed state graph), so the
/// plumbing is golden-tested in `tests/check_cli.rs`; exit code 1 when
/// any finding surfaces, 2 on usage errors.
fn cmd_check(flags: &HashMap<String, String>) {
    if flags.contains_key("selftest") {
        check_selftest();
    }
    let depth = match flags.get("depth") {
        Some(d) => match d.parse::<usize>() {
            Ok(n) => n,
            Err(_) => {
                eprintln!("--depth must be an integer, got {d:?}");
                usage();
            }
        },
        None => voltra::check::DEFAULT_DEPTH,
    };
    let json = flags.contains_key("json");
    let reports = match flags.get("protocol") {
        Some(p) => match voltra::check::check_protocol(p, depth, None) {
            Some(r) => vec![r],
            None => {
                eprintln!(
                    "unknown protocol {p:?} (expected one of: {})",
                    voltra::check::PROTOCOLS.join(", ")
                );
                usage();
            }
        },
        None => voltra::check::check_all(depth),
    };
    let total: usize = reports.iter().map(|r| r.findings.len()).sum();
    if json {
        println!("{}", voltra::check::report_json(&reports).render());
    } else {
        for r in &reports {
            if r.findings.is_empty() {
                // A truncated exploration is NOT clean: coverage is
                // incomplete and the run exits 1, so say so.
                let (word, suffix) = if r.truncated {
                    ("incomplete", ", TRUNCATED — raise --depth")
                } else {
                    ("clean", "")
                };
                println!(
                    "check {:<10} {word} ({} states, depth {}{suffix})",
                    r.protocol, r.states, r.max_depth
                );
            } else {
                println!(
                    "check {:<10} {} finding(s) ({} states)",
                    r.protocol,
                    r.findings.len(),
                    r.states
                );
                for f in &r.findings {
                    println!("  [{}] {}", f.id, f.detail);
                    for step in &f.trace {
                        println!("    {step}");
                    }
                }
            }
        }
        println!("check: {} protocol(s), {total} finding(s)", reports.len());
    }
    if total > 0 || reports.iter().any(|r| r.truncated) {
        std::process::exit(1);
    }
}

/// `voltra check --selftest`: seed a known concurrency bug (a leader
/// that publishes without notifying) and prove the checker catches it —
/// the CLI-level nonzero-exit path, mirrored from `lint --selftest`.
/// Exits 1 when the seeded bug is caught, 2 if the checker missed it.
fn check_selftest() -> ! {
    let m = voltra::check::Mutation::FlightDroppedNotify;
    let report =
        voltra::check::check_protocol(m.protocol(), voltra::check::DEFAULT_DEPTH, Some(m))
            .expect("mutation protocols are known");
    for f in &report.findings {
        println!("[{}] {}", f.id, f.detail);
    }
    let caught = report.findings.iter().any(|f| f.id == m.expected_finding());
    if !caught {
        println!("check selftest: checker MISSED the seeded {} bug", m.id());
        std::process::exit(2);
    }
    println!(
        "check selftest: checker caught the seeded {} bug ({} finding(s))",
        m.id(),
        report.findings.len()
    );
    std::process::exit(1);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "info" => cmd_info(),
        "run" => {
            let cfg = config_from(&flags);
            let Some(w) = flags.get("workload") else {
                eprintln!("run requires --workload");
                usage();
            };
            cmd_run(&cfg, w);
        }
        "suite" => {
            let cfg = config_from(&flags);
            cmd_suite(&cfg);
        }
        "lint" => {
            let cfg = config_from(&flags);
            cmd_lint(&cfg, &flags);
        }
        "check" => cmd_check(&flags),
        "sweep" => {
            let cfg = config_from(&flags);
            let threads = flags
                .get("threads")
                .map(|v| v.parse::<usize>().expect("--threads must be an integer"))
                .unwrap_or_else(|| {
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
                });
            cmd_sweep(&cfg, threads);
        }
        "shmoo" => cmd_shmoo(),
        "search" => cmd_search(&flags),
        "artifacts" => {
            let dir = flags
                .get("artifacts")
                .cloned()
                .unwrap_or_else(|| default_dir().display().to_string());
            cmd_artifacts(&dir);
        }
        "report" => {
            let cfg = config_from(&flags);
            let Some(w) = flags.get("workload") else {
                eprintln!("report requires --workload");
                usage();
            };
            cmd_report(&cfg, w);
        }
        "serve" => {
            let dir = flags
                .get("artifacts")
                .cloned()
                .unwrap_or_else(|| default_dir().display().to_string());
            let port = flags
                .get("port")
                .map(|p| p.parse::<u16>().expect("--port"))
                .unwrap_or(0);
            let mut opts = voltra::coordinator::server::ServeOptions::default();
            if let Some(w) = flags.get("workers") {
                opts.workers = w.parse().expect("--workers must be an integer");
            }
            if let Some(d) = flags.get("queue-depth") {
                opts.queue_depth = d.parse().expect("--queue-depth must be an integer");
            }
            let cfg = config_from(&flags);
            let listener =
                match voltra::coordinator::server::bind(&format!("127.0.0.1:{port}")) {
                    Ok(l) => l,
                    Err(e) => {
                        eprintln!("serve failed: {e:#}");
                        std::process::exit(1);
                    }
                };
            println!(
                "voltra serving on {} ({} workers, queue depth {}) — protocol: \
                 GEMM <m> <k> <n> <seed> | WORKLOAD <name> | LINT <name> | STATS | QUIT",
                listener.local_addr().unwrap(),
                opts.workers,
                opts.queue_depth
            );
            // The backend is constructed on the dedicated numerics worker
            // thread (PJRT handles are not Send): real artifacts when they
            // load, bit-identical host oracle otherwise.
            let factory = move || -> anyhow::Result<Box<dyn GemmBackend>> {
                match PjrtBackend::load(&dir) {
                    Ok(b) => {
                        eprintln!("numerics backend: pjrt (artifacts from {dir})");
                        Ok(Box::new(b))
                    }
                    Err(e) => {
                        eprintln!("numerics backend: host oracle (PJRT unavailable: {e:#})");
                        Ok(Box::new(HostBackend))
                    }
                }
            };
            let plans = voltra::PlanCache::new();
            // One tile cache for both request kinds: GEMM sim costs and
            // WORKLOAD planning share every memoized tile simulation.
            let cache = plans.tile_cache(&cfg);
            match voltra::coordinator::server::serve_threaded(
                factory,
                &cfg,
                listener,
                opts,
                cache.as_ref(),
                &plans,
            ) {
                Ok(stats) => println!(
                    "served {} connections ({} failed)",
                    stats.served, stats.failed
                ),
                Err(e) => {
                    eprintln!("serve failed: {e:#}");
                    std::process::exit(1);
                }
            }
        }
        _ => usage(),
    }
}
