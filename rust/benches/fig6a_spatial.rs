//! Fig. 6a: spatial-utilization benefit of the 3D spatial array vs a
//! conventional 2D array, across the eight evaluation workloads.
//!
//! Paper: Voltra reaches 69.71-100% spatial utilization, up to 2.0x over
//! the 2D design; the LLM decode stage is the floor.

#[path = "common.rs"]
mod common;

use voltra::config::ChipConfig;
use voltra::coordinator::run_workload;
use voltra::metrics::geomean;
use voltra::workloads::evaluation_suite;

fn main() {
    common::header("Fig. 6a — spatial utilization: 3D array (Voltra) vs 2D baseline");
    let v = ChipConfig::voltra();
    let b = ChipConfig::array2d();
    println!(
        "{:<24} {:>10} {:>10} {:>8}",
        "workload", "2D array", "3D array", "ratio"
    );
    common::rule();
    let mut r3 = Vec::new();
    let mut r2 = Vec::new();
    for w in evaluation_suite() {
        let s3 = run_workload(&v, &w).metrics.spatial_utilization();
        let s2 = run_workload(&b, &w).metrics.spatial_utilization();
        println!(
            "{:<24} {:>9.2}% {:>9.2}% {:>7.2}x",
            w.name,
            100.0 * s2,
            100.0 * s3,
            s3 / s2
        );
        r3.push(s3);
        r2.push(s2);
    }
    common::rule();
    let g3 = geomean(&r3);
    let g2 = geomean(&r2);
    println!(
        "{:<24} {:>9.2}% {:>9.2}% {:>7.2}x",
        "geomean",
        100.0 * g2,
        100.0 * g3,
        g3 / g2
    );
    println!("paper: 3D reaches 69.71-100%, up to 2.0x over 2D; decode is the floor.");

    // Hot-path timing: regenerating the whole figure.
    common::report("fig6a full regeneration", 3, || {
        for w in evaluation_suite() {
            let _ = run_workload(&v, &w).metrics.spatial_utilization();
            let _ = run_workload(&b, &w).metrics.spatial_utilization();
        }
    });
}
