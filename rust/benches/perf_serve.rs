//! §Perf: the layered serving stack (DESIGN.md §14) under concurrent
//! load — a client fleet drives the real `serve_threaded` TCP engine
//! (transport threads, bounded dispatch queue, engine workers, the
//! dedicated host-numerics worker) with the protocol's request mix and
//! gates the tail: p99 request RTT must stay under the SLO ceiling and
//! the fleet must sustain the throughput floor.
//!
//! A warm-up connection pays the cold plan compiles first (single-flight
//! collapses concurrent compiles to one anyway — tests/plan_cache.rs
//! pins the exact split), so the measured window is the steady serving
//! state: warm plan answers, memoized tile costs, live numerics.

#[path = "common.rs"]
mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::thread;
use std::time::Instant;

use voltra::config::ChipConfig;
use voltra::coordinator::server::{bind, serve_threaded, ServeOptions};
use voltra::coordinator::SharedTileCache;
use voltra::plan::PlanCache;
use voltra::runtime::HostBackend;

const CLIENTS: usize = 8;
const CONNS_PER_CLIENT: usize = 64;

/// The per-connection request mix: live numerics on the worker lane,
/// warm plan-cache answers, and a verifier pass.
const MIX: [&str; 6] = [
    "GEMM 32 32 32 7",
    "WORKLOAD bert",
    "LINT lstm",
    "WORKLOAD llama-decode",
    "GEMM 48 32 64 9",
    "WORKLOAD mobilenetv2",
];

/// SLO gates, sized for noisy shared CI runners: the serving stack
/// answers this mix in well under a millisecond at p50 on an idle
/// machine, so a 150 ms p99 / 500 req/s floor only fails on a real
/// serving regression (queue collapse, lost backpressure, re-planning).
const P99_CEILING_US: u64 = 150_000;
const THROUGHPUT_FLOOR_RPS: f64 = 500.0;

/// Play `conns` connections of the mix; per-request RTTs in microseconds.
fn run_client(addr: SocketAddr, conns: usize) -> Vec<u64> {
    let mut rtts = Vec::with_capacity(conns * MIX.len());
    for _ in 0..conns {
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        for req in MIX {
            let t0 = Instant::now();
            writeln!(conn, "{req}").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            rtts.push(t0.elapsed().as_micros() as u64);
            // The mix is all-valid and the queue is deep enough for the
            // fleet: any ERR (busy included) is a serving bug.
            assert!(line.starts_with("OK "), "load generator got {line:?} for {req:?}");
        }
        writeln!(conn, "QUIT").unwrap();
    }
    rtts
}

/// Nearest-rank percentile over an ascending-sorted sample.
fn percentile(sorted_us: &[u64], p: f64) -> u64 {
    let rank = ((p / 100.0) * sorted_us.len() as f64).ceil().max(1.0) as usize;
    sorted_us[rank - 1]
}

fn main() {
    common::header("§Perf — serving stack under concurrent load (SLO gate)");
    let listener = bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // The fleet, plus the warm-up connection and the final STATS probe.
    let total_conns = CLIENTS * CONNS_PER_CLIENT + 2;
    let server = thread::spawn(move || {
        let cfg = ChipConfig::voltra();
        let cache = SharedTileCache::new();
        let plans = PlanCache::new();
        serve_threaded(
            || Ok(HostBackend),
            &cfg,
            listener,
            ServeOptions {
                max_conns: Some(total_conns),
                queue_depth: 256,
                ..ServeOptions::default()
            },
            &cache,
            &plans,
        )
        .unwrap()
    });

    run_client(addr, 1); // warm-up: cold plans compile here

    let t0 = Instant::now();
    let fleet: Vec<_> = (0..CLIENTS)
        .map(|_| thread::spawn(move || run_client(addr, CONNS_PER_CLIENT)))
        .collect();
    let mut rtts: Vec<u64> = Vec::new();
    for t in fleet {
        rtts.extend(t.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();

    rtts.sort_unstable();
    let total = rtts.len();
    let (p50, p99) = (percentile(&rtts, 50.0), percentile(&rtts, 99.0));
    let max = *rtts.last().unwrap();
    let rps = total as f64 / wall;

    // The serving tier's own telemetry must agree: nothing was refused
    // at admission, and the mix's four workloads compiled exactly once
    // (every post-warm-up WORKLOAD/LINT answered from the plan cache).
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    writeln!(conn, "STATS").unwrap();
    let mut stats_line = String::new();
    reader.read_line(&mut stats_line).unwrap();
    writeln!(conn, "QUIT").unwrap();
    let stats_line = stats_line.trim();
    assert!(stats_line.starts_with("OK stats "), "{stats_line}");
    assert!(
        stats_line.contains(" busy=0 "),
        "admission refused requests under nominal load: {stats_line}"
    );
    assert!(
        stats_line.contains(" plan_misses=4 "),
        "each workload must compile exactly once: {stats_line}"
    );
    let stats = server.join().unwrap();
    assert_eq!((stats.served, stats.failed), (total_conns, 0));

    common::rule();
    println!(
        "bench {:<40} p50 {p50:>8} us   p99 {p99:>8} us   max {max:>8} us",
        "request RTT under concurrent load"
    );
    println!(
        "bench {:<40} {rps:>10.0} req/s   ({total} requests / {} connections / {CLIENTS} \
         clients in {wall:.2} s)",
        "sustained throughput",
        CLIENTS * CONNS_PER_CLIENT
    );
    assert!(
        p99 <= P99_CEILING_US,
        "SLO: p99 request RTT {p99} us exceeds the {P99_CEILING_US} us ceiling"
    );
    assert!(
        rps >= THROUGHPUT_FLOOR_RPS,
        "SLO: throughput {rps:.0} req/s is under the {THROUGHPUT_FLOOR_RPS} req/s floor"
    );
}
