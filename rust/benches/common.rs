//! Shared mini-bench harness for the figure-regeneration benches.
//!
//! Substrate note (DESIGN.md): criterion is not vendored in the build
//! image, so `cargo bench` targets use this harness: warmup + repeated
//! timing with mean/min/max, plus table-printing helpers so every bench
//! emits the rows/series of the paper figure it regenerates.

use std::time::Instant;

/// Time `f`, returning (mean_s, min_s, max_s) over `iters` runs.
pub fn time<F: FnMut()>(iters: usize, mut f: F) -> (f64, f64, f64) {
    // Warmup.
    f();
    let mut total = 0.0;
    let mut min = f64::INFINITY;
    let mut max: f64 = 0.0;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        min = min.min(dt);
        max = max.max(dt);
    }
    (total / iters as f64, min, max)
}

/// Print already-collected `time` samples in the stable, grep-friendly
/// bench format (use when the caller also needs the samples, e.g. for a
/// speedup assertion over the SAME measurements it prints).
pub fn show(name: &str, iters: usize, timing: (f64, f64, f64)) {
    let (mean, min, max) = timing;
    println!(
        "bench {name:<40} mean {:>10.3} ms   min {:>10.3} ms   max {:>10.3} ms   ({iters} iters)",
        mean * 1e3,
        min * 1e3,
        max * 1e3
    );
}

/// Report one hot-path timing in a stable, grep-friendly format.
pub fn report(name: &str, iters: usize, f: impl FnMut()) {
    show(name, iters, time(iters, f));
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

pub fn rule() {
    println!("{}", "-".repeat(100));
}
