//! Hot-path performance tracking (the §Perf deliverable): timings of the
//! simulator's inner loops and the planning/execution pipeline, recorded
//! before/after each optimization in EXPERIMENTS.md §Perf.
//!
//! Workload-level sections go through the compile-once planning layer
//! (`plan::build` + `plan::execute`, DESIGN.md §10) — the legacy
//! `choose_tiling`/`run_workload` entry points this bench once timed are
//! themselves thin wrappers over it now.

#[path = "common.rs"]
mod common;

use voltra::config::ChipConfig;
use voltra::coordinator::TileCache;
use voltra::plan;
use voltra::sim::memory::{BankRequest, BankedMemory, Requester};
use voltra::sim::{simulate_tile, simulate_tile_reference, TileSpec};
use voltra::tiling::mapper;
use voltra::workloads::{evaluation_suite, resnet50::resnet50};

fn main() {
    common::header("§Perf — simulator hot paths");
    let cfg = ChipConfig::voltra();

    // 1. Bank arbitration micro-benchmark (the per-cycle inner loop).
    let mut mem = BankedMemory::new();
    let reqs: Vec<BankRequest> = (0..12)
        .map(|i| BankRequest {
            word_addr: i * 3,
            write: false,
            requester: Requester::Input((i % 8) as u8),
            super_bank: i == 11,
        })
        .collect();
    common::report("bank arbitration x 100k cycles", 10, || {
        for _ in 0..100_000 {
            let r = mem.arbitrate(&reqs);
            std::hint::black_box(&r);
        }
    });

    // 2. One large tile: the dispatcher (row-recurrence fast path,
    //    DESIGN.md §12) against the per-cycle reference walk it must
    //    match bit for bit.
    let big = TileSpec::simple(128, 1024, 128);
    common::report("simulate_tile 128x1024x128 (fast)", 10, || {
        let m = simulate_tile(&cfg, &big);
        std::hint::black_box(&m);
    });
    common::report("simulate_tile_reference 128x1024x128", 10, || {
        let m = simulate_tile_reference(&cfg, &big);
        std::hint::black_box(&m);
    });

    // 3. Mapping + tiling search for a transformer-scale layer (the
    //    planner's per-GEMM resolution, uncached).
    common::report("mapper::search 4096x4096x4096", 10, || {
        let r = mapper::search(&cfg, 4096, 4096, 4096);
        std::hint::black_box(&r);
    });

    // 4. Full ResNet-50: compile the plan cold (tiling search + tile
    //    simulation + residency), then execute the compiled plan warm.
    let net = resnet50();
    common::report("plan::build(ResNet50) cold", 10, || {
        let mut cache = TileCache::new();
        let p = plan::build(&cfg, &net, &mut cache);
        std::hint::black_box(&p);
    });
    let mut cache = TileCache::new();
    let compiled = plan::build(&cfg, &net, &mut cache);
    common::report("plan::execute(ResNet50) warm", 100, || {
        let r = plan::execute(&compiled);
        std::hint::black_box(&r);
    });

    // 5. The whole Fig. 6 suite, cold-compiled + executed per iteration
    //    (private per-workload tile caches; see perf_suite_cold for the
    //    gated walked-vs-fast comparison and perf_plan for warm plans).
    common::report("suite build+execute (8 workloads)", 3, || {
        for w in evaluation_suite() {
            let mut cache = TileCache::new();
            let p = plan::build(&cfg, &w, &mut cache);
            std::hint::black_box(plan::execute(&p));
        }
    });
}
