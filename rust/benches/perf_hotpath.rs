//! Hot-path performance tracking (the §Perf deliverable): timings of the
//! simulator's inner loops and the full-workload pipeline, recorded
//! before/after each optimization in EXPERIMENTS.md §Perf.

#[path = "common.rs"]
mod common;

use voltra::config::ChipConfig;
use voltra::coordinator::run_workload;
use voltra::sim::memory::{BankRequest, BankedMemory, Requester};
use voltra::sim::{simulate_tile, TileSpec};
use voltra::tiling::engine::choose_tiling;
use voltra::workloads::{evaluation_suite, resnet50::resnet50};

fn main() {
    common::header("§Perf — simulator hot paths");
    let cfg = ChipConfig::voltra();

    // 1. Bank arbitration micro-benchmark (the per-cycle inner loop).
    let mut mem = BankedMemory::new();
    let reqs: Vec<BankRequest> = (0..12)
        .map(|i| BankRequest {
            word_addr: i * 3,
            write: false,
            requester: Requester::Input((i % 8) as u8),
            super_bank: i == 11,
        })
        .collect();
    common::report("bank arbitration x 100k cycles", 10, || {
        for _ in 0..100_000 {
            let r = mem.arbitrate(&reqs);
            std::hint::black_box(&r);
        }
    });

    // 2. One large tile, cycle by cycle.
    common::report("simulate_tile 128x1024x128", 10, || {
        let m = simulate_tile(&cfg, &TileSpec::simple(128, 1024, 128));
        std::hint::black_box(&m);
    });

    // 3. Tiling search for a transformer-scale layer.
    common::report("choose_tiling 4096x4096x4096", 10, || {
        let t = choose_tiling(&cfg, 4096, 4096, 4096);
        std::hint::black_box(&t);
    });

    // 4. Full ResNet-50 workload through the coordinator (memoized).
    let net = resnet50();
    common::report("run_workload(ResNet50)", 10, || {
        let r = run_workload(&cfg, &net);
        std::hint::black_box(&r);
    });

    // 5. The whole Fig. 6 suite on one configuration.
    common::report("evaluation suite (8 workloads)", 3, || {
        for w in evaluation_suite() {
            let r = run_workload(&cfg, &w);
            std::hint::black_box(&r);
        }
    });
}
