//! Fig. 4c: data-access savings of programmable dynamic memory
//! allocation on the BERT-Base MHA sequence (one head, token size 64).
//!
//! Paper: PDMA avoids the transfers between separated buffers and
//! off-chip memory, cutting total data access count by 14.3%; the weight
//! streamer's built-in transposer provides K^T for free.

#[path = "common.rs"]
mod common;

use voltra::config::ChipConfig;
use voltra::coordinator::run_workload;
use voltra::workloads::layer::{Layer, LayerKind, Workload};

const T: u64 = 64;
const D: u64 = 768;
const DH: u64 = 64;

/// The Fig. 4a computation sequence as a workload.
fn mha_workload() -> Workload {
    Workload::new(
        "BERT-MHA-head",
        vec![
            Layer::new("q_proj", LayerKind::Gemm { m: T, k: D, n: DH }),
            Layer::new("k_proj", LayerKind::Gemm { m: T, k: D, n: DH }),
            Layer::new("v_proj", LayerKind::Gemm { m: T, k: D, n: DH }),
            // S = Q K^T: K^T comes from the weight streamer's transposer.
            Layer::new("scores", LayerKind::Gemm { m: T, k: DH, n: T }),
            Layer::new("context", LayerKind::Gemm { m: T, k: T, n: DH }),
        ],
    )
}

fn main() {
    common::header("Fig. 4c — MHA data-access count: PDMA shared vs separated");
    let w = mha_workload();
    let shared = run_workload(&ChipConfig::voltra(), &w).metrics;
    let sep = run_workload(&ChipConfig::separated_memory(), &w).metrics;

    println!(
        "{:<12} {:>14} {:>14}",
        "step", "shared bytes", "separated bytes"
    );
    common::rule();
    for (ls, lp) in shared.layers.iter().zip(sep.layers.iter()) {
        println!("{:<12} {:>14} {:>14}", ls.name, ls.dma_bytes, lp.dma_bytes);
    }
    common::rule();
    let a = shared.total_dma_bytes();
    let b = sep.total_dma_bytes();
    println!(
        "total off-chip accesses: shared {} vs separated {} -> {:.1}% saved (paper: 14.3%)",
        a,
        b,
        100.0 * (1.0 - a as f64 / b as f64)
    );
    println!(
        "total latency: shared {} vs separated {} cycles ({:.2}x)",
        shared.total_latency_cycles(),
        sep.total_latency_cycles(),
        sep.total_latency_cycles() as f64 / shared.total_latency_cycles() as f64
    );

    common::report("fig4c regeneration", 20, || {
        let _ = run_workload(&ChipConfig::voltra(), &w);
        let _ = run_workload(&ChipConfig::separated_memory(), &w);
    });
}
