//! Fig. 7d: effective energy efficiency vs GEMM matrix size.
//!
//! Paper: larger matrices enable more data reuse; the K dimension helps
//! most because the output-stationary dataflow turns K depth directly
//! into temporal locality of the high-precision accumulators.

#[path = "common.rs"]
mod common;

use voltra::config::{ChipConfig, OperatingPoint};
use voltra::power::{tops_per_watt, Activity, EnergyParams};
use voltra::sim::{simulate_tile, TileSpec};

fn main() {
    common::header("Fig. 7d — effective TOPS/W vs GEMM matrix size (@0.6V/300MHz)");
    let cfg = ChipConfig::voltra();
    let p = EnergyParams::default();
    let act = Activity::default();
    let op = OperatingPoint::efficiency();

    println!("square GEMMs (M = N = K):");
    println!("{:>8} {:>10} {:>12} {:>10}", "size", "TOPS/W", "cycles", "temporal");
    common::rule();
    for s in [8u64, 16, 32, 48, 64, 96, 128] {
        let t = simulate_tile(&cfg, &TileSpec::simple(s, s, s));
        let eff = tops_per_watt(&p, &t, &act, op);
        println!(
            "{s:>8} {eff:>10.3} {:>12} {:>9.1}%",
            t.total_cycles,
            100.0 * t.temporal_utilization()
        );
    }

    println!("\nK sweep at M = N = 64 (output-stationary depth):");
    println!("{:>8} {:>10} {:>14}", "K", "TOPS/W", "acc reuse (K/8)");
    common::rule();
    let mut prev = 0.0;
    for k in [8u64, 16, 32, 64, 128, 256, 512, 1024] {
        let t = simulate_tile(&cfg, &TileSpec::simple(64, k, 64));
        let eff = tops_per_watt(&p, &t, &act, op);
        println!("{k:>8} {eff:>10.3} {:>14}", k / 8);
        assert!(eff >= prev * 0.98, "efficiency should grow with K");
        prev = eff;
    }
    common::rule();
    println!("paper: efficiency grows with matrix size; K grows it fastest.");

    common::report("fig7d sweeps", 5, || {
        for s in [8u64, 32, 96] {
            let t = simulate_tile(&cfg, &TileSpec::simple(s, s, s));
            let _ = tops_per_watt(&p, &t, &act, op);
        }
    });
}
