//! Fig. 1c: on-chip memory usage for the *same* ResNet-50 tiling under
//! the shared vs the separated memory organisation.
//!
//! Paper: the shared structure uses ~50% less memory, because a
//! separated design must provision every dedicated buffer for its
//! worst-case layer while the shared space only needs the worst-case
//! *sum*.

#[path = "common.rs"]
mod common;

use voltra::config::ChipConfig;
use voltra::tiling::engine::{choose_tiling, footprint};
use voltra::workloads::resnet50::resnet50;

fn main() {
    common::header("Fig. 1c — memory usage, shared vs separated, same ResNet-50 tiling");
    let cfg = ChipConfig::voltra();
    let net = resnet50();

    // For every layer, take Voltra's chosen tiling and measure the
    // per-operand residency it needs (single-buffered, like the figure).
    let mut max_sum = 0usize; // shared provisioning: max over layers of the sum
    let mut max_in = 0usize; // separated provisioning: per-buffer maxima
    let mut max_w = 0usize;
    let mut max_p = 0usize;
    let mut max_o = 0usize;
    let mut rows = Vec::new();
    for layer in &net.layers {
        for g in layer.gemms() {
            let t = match choose_tiling(&cfg, g.m, g.k, g.n) {
                Some(t) => t,
                None => continue,
            };
            let fp = footprint(t.tm, t.tk, t.tn, t.tk < g.k, false);
            max_sum = max_sum.max(fp.total());
            max_in = max_in.max(fp.input);
            max_w = max_w.max(fp.weight);
            max_p = max_p.max(fp.psum);
            max_o = max_o.max(fp.output);
            rows.push((layer.name.clone(), fp));
        }
    }
    let separated = max_in + max_w + max_p + max_o;

    println!("sample layers (per-operand tile residency, bytes):");
    println!(
        "{:<16} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "layer", "input", "weight", "psum", "output", "sum"
    );
    common::rule();
    for (name, fp) in rows.iter().step_by(rows.len() / 12) {
        println!(
            "{:<16} {:>9} {:>9} {:>9} {:>9} {:>9}",
            name,
            fp.input,
            fp.weight,
            fp.psum,
            fp.output,
            fp.total()
        );
    }
    common::rule();
    println!(
        "shared provisioning   (max over layers of SUM):    {:>7} bytes = {:>5.1} KiB",
        max_sum,
        max_sum as f64 / 1024.0
    );
    println!(
        "separated provisioning (sum of per-buffer maxima): {:>7} bytes = {:>5.1} KiB",
        separated,
        separated as f64 / 1024.0
    );
    println!(
        "shared uses {:.0}% less memory for the same tiling (paper: ~50%)",
        100.0 * (1.0 - max_sum as f64 / separated as f64)
    );

    common::report("fig1c regeneration", 10, || {
        for layer in &net.layers {
            for g in layer.gemms() {
                let _ = choose_tiling(&cfg, g.m, g.k, g.n);
            }
        }
    });
}
