//! perf: the event-driven layer pipeline scheduler's hot path
//! (DESIGN.md §9) — the per-layer timeline resolution that replaced the
//! analytic overlap heuristic, plus the consumer path (a full workload
//! run) where the scheduler must stay invisible in the profile.

#[path = "common.rs"]
mod common;

use voltra::config::ChipConfig;
use voltra::coordinator::run_workload;
use voltra::sim::pipeline::{schedule, TilePlan, TileRun};
use voltra::workloads::by_name;

fn main() {
    common::header("perf — layer pipeline scheduler");

    // Synthetic stress: many short mixed runs, which defeats the
    // closed-form fast path as hard as any real dispatch sequence can
    // (real layers have a handful of long runs, not 4096 short ones).
    let plans: Vec<TilePlan> = (0..512u64)
        .map(|i| TilePlan {
            double_buffered: i % 2 == 0,
            runs: (0..8u64)
                .map(|j| TileRun {
                    count: 1 + (i + j) % 7,
                    compute_cycles: 500 + 37 * j,
                    dma_cycles: 400 + 53 * ((i + j) % 11),
                })
                .collect(),
        })
        .collect();
    let s = schedule(&plans);
    println!(
        "synthetic: {} runs -> latency {} (compute {}, dma {}, hidden {})",
        512 * 8,
        s.latency_cycles,
        s.compute_cycles,
        s.dma_cycles,
        s.hidden_cycles()
    );
    assert!(s.latency_cycles >= s.compute_cycles.max(s.dma_cycles));
    assert!(s.latency_cycles <= s.compute_cycles + s.dma_cycles);
    common::report("schedule 4096 mixed tile runs", 200, || {
        let _ = schedule(&plans);
    });

    // Consumer path: tiling + memoized tile simulation + scheduling for
    // a real network, fresh cache each iteration.
    let cfg = ChipConfig::voltra();
    let w = by_name("resnet50").unwrap();
    common::report("resnet50 end-to-end (fresh cache)", 3, || {
        let _ = run_workload(&cfg, &w);
    });
}
