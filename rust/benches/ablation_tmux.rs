//! Sec. II-D ablations: the two time-multiplexing design choices.
//!
//! Paper:
//!  * 8-lane SIMD (vs 64-lane): 0.7% performance loss on ResNet-50 for a
//!    4.92x SIMD-area reduction;
//!  * time-multiplexed psum/output crossbar port: 0.02% performance loss
//!    on ResNet-50 for a 1.46x crossbar-area reduction.

#[path = "common.rs"]
mod common;

use voltra::config::ChipConfig;
use voltra::coordinator::run_workload;
use voltra::power::AreaModel;
use voltra::workloads::resnet50::resnet50;

fn main() {
    common::header("Sec. II-D ablation — time-multiplexed SIMD & crossbar on ResNet-50");
    let net = resnet50();
    let area = AreaModel::default();

    let base = run_workload(&ChipConfig::voltra(), &net).metrics;
    let simd64 = run_workload(&ChipConfig::simd64(), &net).metrics;
    let fullx = run_workload(&ChipConfig::full_crossbar(), &net).metrics;

    let base_c = base.total_compute_cycles() as f64;
    let simd_loss = (base_c - simd64.total_compute_cycles() as f64) / base_c;
    let xbar_loss = (base_c - fullx.total_compute_cycles() as f64) / base_c;

    println!(
        "{:<34} {:>16} {:>12} {:>14}",
        "configuration", "compute cycles", "perf loss", "module area"
    );
    common::rule();
    println!(
        "{:<34} {:>16} {:>12} {:>11.4} mm2",
        "Voltra (8-lane SIMD, tmux xbar)",
        base.total_compute_cycles(),
        "-",
        area.simd_area(8)
    );
    println!(
        "{:<34} {:>16} {:>11.2}% {:>11.4} mm2",
        "64-lane SIMD",
        simd64.total_compute_cycles(),
        100.0 * simd_loss,
        area.simd_area(64)
    );
    println!(
        "{:<34} {:>16} {:>11.2}% {:>11.4} mm2",
        "full (non-tmux) crossbar",
        fullx.total_compute_cycles(),
        100.0 * xbar_loss,
        area.crossbar_area(false)
    );
    common::rule();
    println!(
        "8-lane SIMD costs {:.2}% perf for a {:.2}x area cut   (paper: 0.7% / 4.92x)",
        100.0 * simd_loss,
        area.simd_area(64) / area.simd_area(8)
    );
    println!(
        "tmux crossbar costs {:.3}% perf for a {:.2}x area cut (paper: 0.02% / 1.46x)",
        100.0 * xbar_loss,
        area.crossbar_area(false) / area.crossbar_area(true)
    );

    // Shape assertions (the paper's qualitative claims).
    assert!(simd_loss.abs() < 0.03, "SIMD tmux loss should be ~1%");
    assert!(xbar_loss.abs() < 0.01, "crossbar tmux loss should be ~0%");
    assert!((area.simd_area(64) / area.simd_area(8) - 4.92).abs() < 0.01);
    assert!((area.crossbar_area(false) / area.crossbar_area(true) - 1.46).abs() < 0.02);
    println!("ablation shapes match Sec. II-D ✓");

    common::report("ablation regeneration", 3, || {
        let _ = run_workload(&ChipConfig::simd64(), &net);
        let _ = run_workload(&ChipConfig::full_crossbar(), &net);
    });
}
