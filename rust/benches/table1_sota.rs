//! Table I: chip summary and state-of-the-art comparison.
//!
//! The SotA rows are the published numbers of DIANA (ISSCC'22), RBE
//! (JSSC'24), Ayaka (JSSC'24) and Cygnus (VLSI'25); the Voltra row is
//! *derived from our model* (area model, DVFS, energy model, simulator)
//! — matching it against the paper's own row is the regression.

#[path = "common.rs"]
mod common;

use voltra::arch;
use voltra::config::{ChipConfig, OperatingPoint};
use voltra::power::{power_mw, tops_per_watt, Activity, AreaModel, EnergyParams};
use voltra::sim::{simulate_tile, TileSpec};

struct Row {
    name: &'static str,
    tech: &'static str,
    ops: &'static str,
    macs: &'static str,
    mem_kb: &'static str,
    area_mm2: &'static str,
    volt: &'static str,
    freq: &'static str,
    tops: String,
    power: String,
    eff: String,
    area_eff: String,
}

fn main() {
    common::header("Table I — chip summary & SotA comparison");
    // Published comparison rows (from the paper's Table I).
    let sota = [
        Row {
            name: "DIANA ISSCC22",
            tech: "22nm",
            ops: "CONV2D",
            macs: "1024/512/256",
            mem_kb: "320",
            area_mm2: "N/A",
            volt: "0.6-0.9",
            freq: "50-340",
            tops: "0.22".into(),
            power: "N/A".into(),
            eff: "4.1".into(),
            area_eff: "N/A".into(),
        },
        Row {
            name: "RBE JSSC24",
            tech: "22nm",
            ops: "CONV2D",
            macs: "Configurable",
            mem_kb: "128",
            area_mm2: "2.42",
            volt: "0.5-0.8",
            freq: "-420",
            tops: "0.09".into(),
            power: "N/A".into(),
            eff: "0.74".into(),
            area_eff: "0.037".into(),
        },
        Row {
            name: "Ayaka JSSC24",
            tech: "28nm",
            ops: "MHA",
            macs: "4096",
            mem_kb: "544",
            area_mm2: "10.76",
            volt: "0.68-1.0",
            freq: "85-430",
            tops: "0.17-6.53".into(),
            power: "38-396".into(),
            eff: "2.22-49.7".into(),
            area_eff: "0.016-0.61".into(),
        },
        Row {
            name: "Cygnus VLSI25",
            tech: "16nm",
            ops: "GEMM/CONV2D",
            macs: "160",
            mem_kb: "768",
            area_mm2: "16",
            volt: "0.6-1.0",
            freq: "100-1010",
            tops: "0.32".into(),
            power: "62-1542".into(),
            eff: "0.41".into(),
            area_eff: "0.02".into(),
        },
    ];

    // Voltra row: everything derived from the model.
    let cfg = ChipConfig::voltra();
    let t = simulate_tile(&cfg, &TileSpec::simple(96, 96, 96));
    let p = EnergyParams::default();
    let act = Activity::default();
    let area = AreaModel::default();
    let die = area.total(8, true);
    let eff06 = tops_per_watt(&p, &t, &act, OperatingPoint::efficiency());
    let p06 = power_mw(&p, &t, &act, OperatingPoint::efficiency());
    let p10 = power_mw(&p, &t, &act, OperatingPoint::performance());
    let voltra = Row {
        name: "Voltra (this work)",
        tech: "16nm",
        ops: "GEMM/CONV2D/MHA",
        macs: "512",
        mem_kb: "134",
        area_mm2: "",
        volt: "0.6-1.0",
        freq: "300-800",
        tops: format!("{:.2}", arch::PEAK_TOPS),
        power: format!("{:.0}-{:.0}", p06, p10),
        eff: format!("{:.2}", eff06),
        area_eff: format!("{:.2}", arch::PEAK_TOPS / die),
    };

    println!(
        "{:<20} {:>5} {:>16} {:>13} {:>7} {:>7} {:>9} {:>9} {:>10} {:>10} {:>11} {:>12}",
        "chip", "tech", "ops", "MACs", "mem KB", "mm^2", "V", "MHz", "TOPS", "mW", "TOPS/W", "TOPS/mm^2"
    );
    common::rule();
    for r in &sota {
        println!(
            "{:<20} {:>5} {:>16} {:>13} {:>7} {:>7} {:>9} {:>9} {:>10} {:>10} {:>11} {:>12}",
            r.name, r.tech, r.ops, r.macs, r.mem_kb, r.area_mm2, r.volt, r.freq, r.tops, r.power, r.eff, r.area_eff
        );
    }
    common::rule();
    println!(
        "{:<20} {:>5} {:>16} {:>13} {:>7} {:>7.3} {:>9} {:>9} {:>10} {:>10} {:>11} {:>12}",
        voltra.name,
        voltra.tech,
        voltra.ops,
        voltra.macs,
        voltra.mem_kb,
        die,
        voltra.volt,
        voltra.freq,
        voltra.tops,
        voltra.power,
        voltra.eff,
        voltra.area_eff
    );
    println!(
        "\npaper's Voltra row: 0.654 mm^2, 0.82 TOPS, 171-981 mW, 1.60 TOPS/W, 1.25 TOPS/mm^2"
    );

    // Regression assertions: the derived row must match the silicon.
    assert!((die - 0.654).abs() < 0.01);
    assert!((arch::PEAK_TOPS - 0.82).abs() < 0.01);
    assert!((eff06 - 1.60).abs() < 0.15);
    assert!((arch::PEAK_TOPS / die - 1.25).abs() < 0.03);
    println!("derived Voltra row matches the published Table I entries ✓");

    common::report("table1 row derivation", 20, || {
        let t = simulate_tile(&cfg, &TileSpec::simple(96, 96, 96));
        let _ = tops_per_watt(&p, &t, &act, OperatingPoint::efficiency());
    });
}
