//! Fig. 7a: the shmoo plot — pass/fail over the voltage x frequency grid.
//!
//! Paper anchors: the die operates 0.6-1.0 V, 300-800 MHz, with fmax
//! rising near-linearly in VDD.

#[path = "common.rs"]
mod common;

use voltra::config::OperatingPoint;
use voltra::power::dvfs::{fmax_mhz, passes, shmoo_grid};

fn main() {
    common::header("Fig. 7a — shmoo plot (o = pass, . = fail)");
    let volts: Vec<f64> = (0..=9).map(|i| 0.55 + 0.05 * i as f64).collect();
    let mut freqs: Vec<f64> = (0..=12).map(|i| 250.0 + 50.0 * i as f64).collect();
    freqs.reverse();
    print!("{:>8} ", "MHz\\V");
    for v in &volts {
        print!("{v:>6.2}");
    }
    println!();
    for f in &freqs {
        print!("{f:>8} ");
        for v in &volts {
            let ok = passes(OperatingPoint {
                voltage: (v * 100.0).round() / 100.0,
                freq_mhz: *f,
            });
            print!("{:>6}", if ok { "o" } else { "." });
        }
        println!();
    }
    common::rule();
    println!(
        "fmax anchors: {} MHz @ 0.6 V, {} MHz @ 1.0 V  (paper: 300 / 800)",
        fmax_mhz(0.6),
        fmax_mhz(1.0)
    );
    let grid = shmoo_grid();
    let pass = grid.iter().filter(|(_, _, p)| *p).count();
    println!("grid: {} points, {} pass", grid.len(), pass);

    common::report("fig7a grid evaluation", 50, || {
        let _ = shmoo_grid();
    });
}
