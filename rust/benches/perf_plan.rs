//! §Perf: the compile-once planning layer (DESIGN.md §10) — cold
//! planning (tiling search + tile simulation + residency pass) vs
//! warm-plan execution (metric assembly over the memoized, already
//! scheduled `WorkloadPlan`s) for the eight-workload evaluation suite.
//!
//! The acceptance bar (ISSUE 4): warm-plan execution must beat cold
//! planning by at least 2x. In practice the gap is orders of magnitude —
//! execution never touches the tiling engine or the cycle simulator.

#[path = "common.rs"]
mod common;

use voltra::config::ChipConfig;
use voltra::plan::PlanCache;
use voltra::workloads::evaluation_suite;

fn main() {
    common::header("§Perf — compile-once planning: cold build vs warm execution");
    let cfg = ChipConfig::voltra();
    let suite = evaluation_suite();

    // Measure once per configuration and print from the same samples
    // the speedup assertion uses (no duplicated measurement passes).
    //
    // Cold: a fresh plan cache per iteration — every workload pays the
    // full tiling search + tile simulation + residency pass.
    let cold = common::time(3, || {
        let plans = PlanCache::new();
        for w in &suite {
            std::hint::black_box(plans.run(&cfg, w));
        }
    });
    common::show("suite x8, cold planning (fresh cache)", 3, cold);

    // Warm: one shared cache, pre-planned — every run is plan-cache hit
    // + execute.
    let plans = PlanCache::new();
    for w in &suite {
        plans.run(&cfg, w);
    }
    let planned_misses = plans.stats().misses;
    let warm = common::time(20, || {
        for w in &suite {
            std::hint::black_box(plans.run(&cfg, w));
        }
    });
    common::show("suite x8, warm plans (execute only)", 20, warm);
    assert_eq!(
        plans.stats().misses,
        planned_misses,
        "a warm pass must re-plan zero workloads"
    );
    let (cold_mean, _, _) = cold;
    let (warm_mean, _, _) = warm;

    common::rule();
    let speedup = cold_mean / warm_mean;
    let s = plans.stats();
    println!(
        "warm-plan execution is {speedup:.1}x faster than cold planning \
         ({} plans, {} hits / {} misses, {} unique tiles)",
        plans.len(),
        s.hits,
        s.misses,
        plans.unique_tiles()
    );
    assert!(
        speedup >= 2.0,
        "acceptance: warm execution must be >= 2x cold planning, got {speedup:.2}x"
    );
}
