//! Fig. 7b: system energy efficiency and area efficiency across the
//! supply-voltage range, on the fully-dense GEMM with M = N = K = 96.
//!
//! Paper: 1.60 TOPS/W peak at 0.6 V / 300 MHz; 1.25 TOPS/mm^2 peak at
//! 1.0 V / 800 MHz; power envelope 171-981 mW (Fig. 5).

#[path = "common.rs"]
mod common;

use voltra::config::{ChipConfig, OperatingPoint};
use voltra::power::dvfs::fmax_mhz;
use voltra::power::{power_mw, tops_per_watt, Activity, AreaModel, EnergyParams};
use voltra::sim::{simulate_tile, TileSpec};

fn main() {
    common::header("Fig. 7b — efficiency vs supply voltage (dense GEMM, M=N=K=96)");
    let cfg = ChipConfig::voltra();
    let t = simulate_tile(&cfg, &TileSpec::simple(96, 96, 96));
    let p = EnergyParams::default();
    let act = Activity::default();
    let area = AreaModel::default();
    let die = area.total(8, true);

    println!(
        "{:>6} {:>8} {:>10} {:>10} {:>12} {:>14}",
        "VDD", "fmax", "power", "TOPS/W", "eff. TOPS", "TOPS/mm^2"
    );
    common::rule();
    let mut peak_eff: (f64, f64) = (0.0, 0.0);
    let mut peak_ae: (f64, f64) = (0.0, 0.0);
    for i in 0..=8 {
        let v = 0.6 + 0.05 * i as f64;
        let f = fmax_mhz(v);
        let op = OperatingPoint {
            voltage: v,
            freq_mhz: f,
        };
        let mw = power_mw(&p, &t, &act, op);
        let eff = tops_per_watt(&p, &t, &act, op);
        let tops = 2.0 * t.useful_macs as f64 / (t.total_cycles as f64 / (f * 1e6)) / 1e12;
        // Area efficiency uses *peak* throughput at this frequency, as
        // Table I / Fig. 7b do.
        let peak = 512.0 * 2.0 * f * 1e6 / 1e12;
        let ae = peak / die;
        println!(
            "{v:>6.2} {f:>6.0}MHz {mw:>8.1}mW {eff:>10.3} {tops:>12.3} {ae:>14.3}"
        );
        if eff > peak_eff.1 {
            peak_eff = (v, eff);
        }
        if ae > peak_ae.1 {
            peak_ae = (v, ae);
        }
    }
    common::rule();
    println!(
        "peak energy efficiency: {:.2} TOPS/W @ {:.1} V   (paper: 1.60 @ 0.6 V)",
        peak_eff.1, peak_eff.0
    );
    println!(
        "peak area efficiency:   {:.2} TOPS/mm^2 @ {:.1} V (paper: 1.25 @ 1.0 V)",
        peak_ae.1, peak_ae.0
    );

    common::report("fig7b voltage sweep", 10, || {
        for i in 0..=8 {
            let v = 0.6 + 0.05 * i as f64;
            let op = OperatingPoint {
                voltage: v,
                freq_mhz: fmax_mhz(v),
            };
            let _ = tops_per_watt(&p, &t, &act, op);
        }
    });
}
