//! Mapper-cache hot path: the cycle-domain mapping search (DESIGN.md
//! §11) cold, vs warm hits on the process-wide cache.
//!
//! The search enumerates permutation x fold candidates and runs a full
//! tiling search for each — hundreds of microseconds per distinct layer
//! shape. The sharded cache memoizes it per (fingerprint, M, K, N), so
//! a warm suite / sweep / serve pass pays a shard read per GEMM. The
//! bench asserts the warm path is at least 2x the cold one (it is
//! orders of magnitude faster; 2x keeps the smoke test robust on noisy
//! CI runners).

#[path = "common.rs"]
mod common;

use voltra::config::ChipConfig;
use voltra::tiling::MapperCache;
use voltra::workloads::evaluation_suite;

fn suite_shapes() -> Vec<(u64, u64, u64)> {
    let mut shapes = std::collections::BTreeSet::new();
    for w in evaluation_suite() {
        for l in &w.layers {
            for g in l.gemms() {
                shapes.insert((g.m, g.k, g.n));
            }
        }
    }
    shapes.into_iter().collect()
}

fn main() {
    common::header("perf — mapping search: cold search vs warm mapper-cache hit");
    let cfg = ChipConfig::voltra();
    let shapes = suite_shapes();
    println!(
        "{} distinct GEMM shapes across the eight suite workloads",
        shapes.len()
    );

    let iters = 5;
    // Cold: a fresh cache every iteration — every shape searches.
    let cold = common::time(iters, || {
        let cache = MapperCache::new();
        for &(m, k, n) in &shapes {
            let _ = cache.resolve(&cfg, m, k, n);
        }
    });
    common::show("mapper cold (fresh cache, full search)", iters, cold);

    // Warm: one cache reused — every shape is a shard read.
    let warm_cache = MapperCache::new();
    for &(m, k, n) in &shapes {
        let _ = warm_cache.resolve(&cfg, m, k, n);
    }
    let warm = common::time(iters, || {
        for &(m, k, n) in &shapes {
            let _ = warm_cache.resolve(&cfg, m, k, n);
        }
    });
    common::show("mapper warm (process-wide cache hits)", iters, warm);

    let speedup = cold.0 / warm.0;
    println!("warm speedup: {speedup:.1}x");
    assert!(
        speedup >= 2.0,
        "warm mapper hits must be at least 2x the cold search, got {speedup:.2}x"
    );

    let stats = warm_cache.stats();
    println!(
        "cache: {} shapes, {} hits / {} misses",
        warm_cache.len(),
        stats.hits,
        stats.misses
    );
}
