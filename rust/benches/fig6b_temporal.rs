//! Fig. 6b: temporal-utilization benefit of mixed-grained data
//! prefetching (MGDP) vs a plain shared-memory architecture.
//!
//! Paper: 76.99-97.32% with MGDP, a 2.12-2.94x improvement over the
//! demand-fetched baseline that eats every bank conflict.

#[path = "common.rs"]
mod common;

use voltra::config::ChipConfig;
use voltra::coordinator::run_workload;
use voltra::metrics::geomean;
use voltra::workloads::evaluation_suite;

fn main() {
    common::header("Fig. 6b — temporal utilization: MGDP vs no-prefetch shared memory");
    let v = ChipConfig::voltra();
    let np = ChipConfig::no_prefetch();
    println!(
        "{:<24} {:>12} {:>10} {:>8} {:>14}",
        "workload", "no-prefetch", "MGDP", "ratio", "bank conflicts"
    );
    common::rule();
    let mut rv = Vec::new();
    let mut rn = Vec::new();
    for w in evaluation_suite() {
        let mv = run_workload(&v, &w).metrics;
        let mn = run_workload(&np, &w).metrics;
        let tv = mv.temporal_utilization();
        let tn = mn.temporal_utilization();
        println!(
            "{:<24} {:>11.2}% {:>9.2}% {:>7.2}x {:>9} -> {:<9}",
            w.name,
            100.0 * tn,
            100.0 * tv,
            tv / tn,
            mn.bank_conflicts(),
            mv.bank_conflicts(),
        );
        rv.push(tv);
        rn.push(tn);
    }
    common::rule();
    let gv = geomean(&rv);
    let gn = geomean(&rn);
    println!(
        "{:<24} {:>11.2}% {:>9.2}% {:>7.2}x",
        "geomean",
        100.0 * gn,
        100.0 * gv,
        gv / gn
    );
    println!("paper: MGDP reaches 76.99-97.32%, a 2.12-2.94x improvement.");

    common::report("fig6b full regeneration", 3, || {
        for w in evaluation_suite() {
            let _ = run_workload(&v, &w);
            let _ = run_workload(&np, &w);
        }
    });
}
