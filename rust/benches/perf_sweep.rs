//! §Perf: the multi-workload sweep engine — private per-run caches vs
//! one process-wide shared cache, sequential vs thread pool.
//!
//! The shared cache is the serving story in miniature: networks share
//! tile shapes (transformer blocks, ResNet stages, common GEMM ladders),
//! so one warm cache answers the whole suite with zero new simulations.

#[path = "common.rs"]
mod common;

use voltra::config::ChipConfig;
use voltra::coordinator::{
    run_suite_parallel, run_workload, run_workload_shared, SharedTileCache,
};
use voltra::workloads::evaluation_suite;

fn main() {
    common::header("§Perf — multi-workload sweep: cache sharing & parallelism");
    let cfg = ChipConfig::voltra();
    let suite = evaluation_suite();

    common::report("suite x8, sequential, private caches", 3, || {
        for w in &suite {
            std::hint::black_box(run_workload(&cfg, w));
        }
    });

    common::report("suite x8, sequential, one shared cache", 3, || {
        let cache = SharedTileCache::new();
        for w in &suite {
            std::hint::black_box(run_workload_shared(&cfg, w, &cache));
        }
    });

    for threads in [2usize, 4, 8] {
        common::report(&format!("suite x8, parallel x{threads}, shared cache"), 3, || {
            let cache = SharedTileCache::new();
            std::hint::black_box(run_suite_parallel(&cfg, &suite, threads, &cache));
        });
    }

    // Steady-state serving: a warm cache answers the whole suite without
    // a single new simulation.
    let warm = SharedTileCache::new();
    for w in &suite {
        run_workload_shared(&cfg, w, &warm);
    }
    let cold_misses = warm.stats().misses;
    common::report("suite x8, warm shared cache (pure hits)", 5, || {
        for w in &suite {
            std::hint::black_box(run_workload_shared(&cfg, w, &warm));
        }
    });
    assert_eq!(
        warm.stats().misses,
        cold_misses,
        "a warm sweep must not simulate anything new"
    );

    common::rule();
    let s = warm.stats();
    println!(
        "shared cache after the full suite: {} unique tiles, {} hits / {} misses ({:.1}% hit rate)",
        warm.len(),
        s.hits,
        s.misses,
        100.0 * s.hit_rate()
    );
}
