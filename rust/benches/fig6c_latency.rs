//! Fig. 6c: total-latency benefit of programmable dynamic memory
//! allocation (PDMA, shared memory) vs a separated-buffer architecture,
//! including off-chip data movement.
//!
//! Paper: 1.15-2.36x lower total latency with PDMA, even though the
//! separated configuration's GEMM compute cycles are slightly better
//! (its dedicated buffers never contend).

#[path = "common.rs"]
mod common;

use voltra::config::ChipConfig;
use voltra::coordinator::run_workload;
use voltra::workloads::evaluation_suite;

fn main() {
    common::header("Fig. 6c — total latency: PDMA shared memory vs separated buffers");
    let v = ChipConfig::voltra();
    let s = ChipConfig::separated_memory();
    println!(
        "{:<22} {:>13} {:>13} {:>13} {:>13} {:>12} {:>12} {:>7}",
        "workload", "sep compute", "sep DMA", "pdma compute", "pdma DMA", "sep total", "pdma total", "ratio"
    );
    common::rule();
    for w in evaluation_suite() {
        let mv = run_workload(&v, &w).metrics;
        let ms = run_workload(&s, &w).metrics;
        println!(
            "{:<22} {:>13} {:>13} {:>13} {:>13} {:>12} {:>12} {:>6.2}x",
            w.name,
            ms.total_compute_cycles(),
            ms.total_dma_cycles(),
            mv.total_compute_cycles(),
            mv.total_dma_cycles(),
            ms.total_latency_cycles(),
            mv.total_latency_cycles(),
            ms.total_latency_cycles() as f64 / mv.total_latency_cycles() as f64,
        );
    }
    common::rule();
    println!("paper: PDMA cuts total latency 1.15-2.36x; its compute cycles are");
    println!("slightly higher (shared-bank contention) but DMA shrinks far more.");

    common::report("fig6c full regeneration", 3, || {
        for w in evaluation_suite() {
            let _ = run_workload(&v, &w);
            let _ = run_workload(&s, &w);
        }
    });
}
