//! Architecture ablations beyond the paper's own (DESIGN.md §5 extras):
//! the design choices the paper fixes without sweeping.
//!
//!  * FIFO depth — why eight? sweep 1..16 and watch temporal utilization
//!    saturate;
//!  * bank count — why 32 x 64-bit? sweep 8..64;
//!  * DMA bandwidth — where the Fig. 6c PDMA advantage grows/shrinks.

#[path = "common.rs"]
mod common;

use voltra::config::ChipConfig;
use voltra::coordinator::run_workload;
use voltra::sim::{simulate_tile, TileSpec};
use voltra::workloads::resnet50::resnet50;

fn main() {
    common::header("Ablation A — streamer FIFO depth (64x512x64 tile)");
    println!("{:>7} {:>10} {:>12}", "depth", "temporal", "conflicts");
    common::rule();
    let spec = TileSpec::simple(64, 512, 64);
    let mut prev = 0.0;
    for depth in [1usize, 2, 4, 6, 8, 12, 16] {
        let mut cfg = ChipConfig::voltra();
        cfg.stream_fifo_depth = depth;
        let m = simulate_tile(&cfg, &spec);
        let u = m.temporal_utilization();
        println!("{depth:>7} {:>9.2}% {:>12}", 100.0 * u, m.bank_conflicts);
        assert!(u >= prev - 0.02, "deeper FIFOs must not hurt");
        prev = u;
    }
    println!("-> the chip's depth-8 choice sits at the knee of the curve.");

    common::header("Ablation B — shared-memory bank count (64x512x64 tile)");
    println!("{:>7} {:>10} {:>12}", "banks", "temporal", "conflicts");
    common::rule();
    for banks in [8usize, 16, 32, 64] {
        let mut cfg = ChipConfig::voltra();
        cfg.num_banks = banks;
        let m = simulate_tile(&cfg, &spec);
        println!(
            "{banks:>7} {:>9.2}% {:>12}",
            100.0 * m.temporal_utilization(),
            m.bank_conflicts
        );
    }
    println!("-> 32 banks already serve the 17 words/cycle demand; 64 buys ~nothing.");

    common::header("Ablation C — DMA bandwidth vs the PDMA advantage (ResNet-50)");
    println!(
        "{:>10} {:>14} {:>14} {:>8}",
        "bytes/cyc", "pdma latency", "sep latency", "ratio"
    );
    common::rule();
    let net = resnet50();
    for bw in [2u64, 4, 8, 16, 32] {
        let mut v = ChipConfig::voltra();
        v.dma_bytes_per_cycle = bw;
        let mut s = ChipConfig::separated_memory();
        s.dma_bytes_per_cycle = bw;
        let lv = run_workload(&v, &net).metrics.total_latency_cycles();
        let ls = run_workload(&s, &net).metrics.total_latency_cycles();
        println!(
            "{bw:>10} {lv:>14} {ls:>14} {:>7.2}x",
            ls as f64 / lv as f64
        );
    }
    println!("-> PDMA matters most when off-chip bandwidth is scarce (edge SoCs).");

    common::report("ablation_arch sweeps", 3, || {
        let mut cfg = ChipConfig::voltra();
        cfg.stream_fifo_depth = 4;
        let _ = simulate_tile(&cfg, &spec);
    });
}
